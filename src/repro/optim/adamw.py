"""Sharded AdamW. Moments inherit each parameter's sharding (specs are
shape-preserving pytrees), so FSDP keeps optimizer state fully sharded.
``moment_dtype`` lets the XXL configs halve optimizer memory (documented in
the per-arch configs)."""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array   # i32 scalar
    m: Any            # pytree like params
    v: Any


def adamw_init(params, moment_dtype=jnp.float32) -> AdamWState:
    def zeros(p):
        return jnp.zeros(p.shape, moment_dtype)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gn


def adamw_update(params, grads, state: AdamWState, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, max_grad_norm: float = 1.0):
    """Returns (new_params, new_state, grad_norm)."""
    grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mn = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vn = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        u = (mn / c1) / (jnp.sqrt(vn / c2) + eps)
        pn = p.astype(jnp.float32) * (1.0 - lr * weight_decay) - lr * u
        return pn.astype(p.dtype), mn.astype(m.dtype), vn.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_p = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_p, AdamWState(step, new_m, new_v), gnorm
