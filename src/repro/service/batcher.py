"""Cross-session batching: one padded, shape-bucketed device batch per
scan kind, vmapped over a session axis.

The multi-tenant service advances many ``MiningSession``s concurrently.
Each session's miner bottoms out in a handful of jit'd scans (A1
bounded-list, A2 single-slot, MapConcatenate segment map); running S
sessions naively issues S small dispatches per level per window. This
module is the barrier executor that turns those into one dispatch per
shape bucket:

* each session step runs in its own worker thread and installs this
  executor into its counters (``StreamingCounter.executor`` seam);
* a counter's scan call becomes ``submit()`` — the thread parks on an
  event;
* when every in-flight session step is parked (or finished), the *last*
  arriver becomes the flush leader: it groups the pending requests by
  shape bucket, stacks each group's operands along a new leading session
  axis, runs one jit'd ``vmap`` of the underlying scan per bucket, and
  scatters the per-lane results back.

The carried Pallas kernels ride the same barrier: ``a1_kernel_scan`` /
``a2_kernel_scan`` take operands already in kernel brick layout (every
lane in a group shares (NP, LCAP, MP, EP) shapes — the counters'
shape-bucketed staging guarantees that), and the flush leader runs one
``vmap`` of the state-in/state-out ``pallas_call`` per group (Pallas
lowers the mapped session axis onto the grid, so the whole fleet's
machines advance in a single kernel launch). Lane results come back in
kernel layout — the counters keep their state resident there.

The multi-device MapConcatenate rides it too: ``mapc_sharded_scan``
fuses same-shape tenants' sharded commits into one launch that vmaps the
segmented kernel over the lane (session) axis *inside* the shard_map —
devices split the segment axis while lanes fill each device's grid
(``kernels.ops.a1_mapc_sharded_vmapped``).

Every scan in this engine is integer-only (i32 compares/adds, bool
masks), so the vmapped lane computation is bit-identical to the
standalone dispatch — the service's exactness guarantee rests on that and
is asserted by tests/test_service.py. Group sizes are padded to powers of
two (lane 0 repeated) so jit compiles once per (kind, bucket, S-bucket).

Adaptive L re-bucketing: requests are grouped *without* regard to their
event-buffer length — at flush time each lane's event operands are padded
to the group's max L (padded events are machine no-ops: PAD types never
match an episode row, so per-lane results stay bit-identical to the
standalone dispatch). Heterogeneous tenants — different window sizes,
different ingest rates — therefore fuse into one launch instead of
fragmenting into singleton groups keyed by L (the ROADMAP
adaptive-shape-bucketing item). The guardrail on the other side is
``max_pad_ratio``: a group whose lanes' event lengths spread beyond that
factor is split before flushing (``_split_oversized``), so one tenant's
giant windows cap — rather than multiply — the fleet's pad waste.
"""

from __future__ import annotations

import functools
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.count_a1 import _a1_scan_core
from repro.core.count_a2 import _a2_scan_core
from repro.core.events import PAD_TYPE, TIME_NEG_INF
from repro.core.mapconcat import _map_all_segments
from repro.core.streaming import bucket_size
from repro.obs import REGISTRY, span


@functools.lru_cache(maxsize=None)
def _vmapped_a1():
    return jax.jit(jax.vmap(_a1_scan_core))


@functools.lru_cache(maxsize=None)
def _vmapped_a2():
    return jax.jit(jax.vmap(_a2_scan_core))


@functools.lru_cache(maxsize=None)
def _vmapped_mapc(lcap: int):
    return jax.jit(jax.vmap(
        lambda *args: _map_all_segments(*args, lcap)))


# per-kind padding specs for the episode (M) axis: (axis in each operand,
# pad value). Episodes are independent lanes of every scan (no cross-M
# interaction), so padding rows with inert machines is bit-safe for the
# real rows — results are sliced back to the caller's M.
_NEG = int(TIME_NEG_INF)  # "empty slot" filler for padded machine state
_PAD_A1 = ((0, 0), (0, 0), (0, 1), (None, 0), (None, 0),
           (0, _NEG), (0, 0), (0, 0), (0, 0))
_PAD_A2 = ((0, 0), (0, 0), (0, 1), (None, 0), (None, 0),
           (0, _NEG), (0, 0))
_PAD_MAPC = ((None, 0), (None, 0), (0, 0), (0, 0), (0, 1), (None, 0),
             (0, 1))

# event-operand spec per kind for the adaptive L re-bucketing:
# {operand index: event axis}. Padded events are machine no-ops (type =
# PAD_TYPE never matches an episode row; the derived successor-duplicate
# flags are false on and before the pad tail), so padding a lane's event
# operands to the fused group's max length is bit-safe.
_EV_AXES = {
    "a1": {3: 0, 4: 0},    # ev_types[L], ev_times[L]
    "a2": {3: 0, 4: 0},
    "mapc": {0: 1, 1: 1},  # wt[Q, L], wtt[Q, L]
    "a1k": {3: 1},         # ev brick [3, EP]
    "a2k": {3: 1},         # ev brick [2, EP]
    "mapck": {5: 2},       # segment bricks [P, 5, LW]
    "mapcs": {5: 2},       # sharded segment bricks [P, 5, LW]
}


def _pad_m(args, spec, m_to: int):
    out = []
    for a, (axis, fill) in zip(args, spec):
        a = jnp.asarray(a)
        if axis is None or a.shape[axis] == m_to:
            out.append(a)
            continue
        pad = [(0, 0)] * a.ndim
        pad[axis] = (0, m_to - a.shape[axis])
        out.append(jnp.pad(a, pad, constant_values=fill))
    return tuple(out)


def _pad_events(kind: str, args, l_to: int):
    """Pad a lane's event operands along the event axis to the fused
    group's max length. Only the *types* slot needs the PAD_TYPE fill
    (kind "a1"/"a2" operand 3, the ``wt`` half of "mapc", row 0 of the
    kernel bricks); times/dup/τ entries of padded events are never
    consulted — no episode row matches type -1 — so they zero-fill."""
    args = list(args)
    for idx, axis in _EV_AXES[kind].items():
        a = jnp.asarray(args[idx])
        grow = l_to - a.shape[axis]
        if grow == 0:
            continue
        pad = [(0, 0)] * a.ndim
        pad[axis] = (0, grow)
        all_types = (kind in ("a1", "a2") and idx == 3) or \
            (kind == "mapc" and idx == 0)
        a = jnp.pad(a, pad, constant_values=PAD_TYPE if all_types else 0)
        if kind in ("a1k", "a2k"):          # ev brick: types = row 0
            a = a.at[0, l_to - grow:].set(PAD_TYPE)
        elif kind in ("mapck", "mapcs"):    # segment brick: types = row 0
            a = a.at[:, 0, l_to - grow:].set(PAD_TYPE)
        args[idx] = a
    return tuple(args)


class _Request:
    __slots__ = ("kind", "key", "args", "spec", "static", "m", "mb",
                 "event", "result", "error")

    def __init__(self, kind, key, args, spec, static, m, mb):
        self.kind = kind
        self.key = key
        self.args = args    # raw (unpadded) operands
        self.spec = spec    # episode-axis pad spec, applied only on fusion
        self.static = static
        self.m = m          # real episode count (fused results sliced back)
        self.mb = mb        # shared M bucket this request groups under
        self.event = threading.Event()
        self.result = None
        self.error = None


class CrossSessionBatcher:
    """Barrier executor for cross-session scan batching.

    Protocol (driven by the scheduler): call ``begin_step()`` once per
    session step about to run, run each step in its own thread, have the
    step call ``end_step()`` when done. Counters inside the step call
    ``a1_scan``/``a2_scan``/``mapc_scan``, which block until the flush
    leader executes the batch. Single-request groups fall through to the
    plain (unvmapped) dispatch so a lone tenant pays no batching tax and
    shares jit caches with standalone runs.
    """

    def __init__(self, max_pad_ratio: float = 4.0):
        self._lock = threading.Lock()
        self._pending: list[_Request] = []
        self._inflight = 0
        self.batches = 0        # flushes that actually fused >1 request
        self.fused_requests = 0
        self.split_groups = 0   # oversized groups split to cap pad waste
        self.pad_events = 0     # event slots added padding lanes to max L
        self.pad_lanes = 0      # repeated lanes padding groups to 2^k
        # adaptive-L guardrail: a lane may be padded to at most this
        # multiple of its own event-buffer length inside a fused group;
        # beyond it the group splits (one tenant's giant windows must not
        # make the whole fleet's lanes pay giant pads). None disables.
        self.max_pad_ratio = max_pad_ratio

    # ------------------------------------------------------------ seams

    def a1_scan(self, args):
        # (etypes[M,N], tlo, thi, ev_t[L], ev_tt[L], s[M,N,C], ptr, c, ovf)
        # — event length L deliberately absent from the key (adaptive L
        # re-bucketing: lanes pad to the group max at flush)
        m, n = args[0].shape
        mb = bucket_size(m, 8)
        key = ("a1", mb, n, args[5].shape[-1])
        return self._submit(
            _Request("a1", key, args, _PAD_A1, None, m, mb))

    def a2_scan(self, args):
        # (etypes[M,N], tlo, thi, ev_t[L], ev_tt[L], s[M,N], c)
        m, n = args[0].shape
        mb = bucket_size(m, 8)
        key = ("a2", mb, n)
        return self._submit(
            _Request("a2", key, args, _PAD_A2, None, m, mb))

    def mapc_scan(self, args, lcap: int):
        # (wt[Q,L], wtt, etypes[M,N], tlo, thi, tau[Q+1], w[M]) — the
        # segment count Q stays in the key, the window length L does not
        m, n = args[2].shape
        mb = bucket_size(m, 8)
        key = ("mapc", mb, n, args[0].shape[0], lcap)
        return self._submit(
            _Request("mapc", key, args, _PAD_MAPC, lcap, m, mb))

    def a1_kernel_scan(self, args, n_levels: int, lcap: int,
                       interpret: bool):
        # kernel-layout operands: (et[NP,MP], tlo, thi, ev[3,EP],
        # s[NP,LCAP,MP], po, cnt[8,MP], ovf) — lanes fuse on identical
        # episode/state shapes; the event brick pads to the group max EP
        key = ("a1k", n_levels, lcap, interpret, tuple(args[0].shape))
        return self._submit(_Request("a1k", key, args, None,
                                     (n_levels, lcap, interpret), None,
                                     None))

    def a2_kernel_scan(self, args, n_levels: int, interpret: bool):
        # kernel-layout operands: (et[NP,MP], tlo, thi, ev[2,EP], s[NP,MP],
        # cnt[8,MP])
        key = ("a2k", n_levels, interpret, tuple(args[0].shape))
        return self._submit(_Request("a2k", key, args, None,
                                     (n_levels, interpret), None, None))

    def mapc_kernel_scan(self, args, n_levels: int, lcap: int,
                         interpret: bool):
        # segmented-kernel operands: (et[NP,MP], tlo, thi, cum[NP,MP],
        # w[8,MP], segs[P,5,LW]) — P stays in the key, LW pads to the
        # group max
        key = ("mapck", n_levels, lcap, interpret, tuple(args[0].shape),
               args[5].shape[0])
        return self._submit(_Request("mapck", key, args, None,
                                     (n_levels, lcap, interpret), None,
                                     None))

    def mapc_sharded_scan(self, args, n_levels: int, lcap: int,
                          interpret: bool, num_devices: int):
        # mesh-sharded segmented launch: same operands as mapc_kernel_scan
        # with the segment axis sharded over ``num_devices`` mesh devices
        # at dispatch. Fused groups vmap over the lane (session) axis
        # inside the shard_map, so the whole fleet's commits run as one
        # per-device launch; P and the device count stay in the key.
        key = ("mapcs", n_levels, lcap, interpret, tuple(args[0].shape),
               args[5].shape[0], num_devices)
        return self._submit(_Request("mapcs", key, args, None,
                                     (n_levels, lcap, interpret,
                                      num_devices), None, None))

    # --------------------------------------------------- step accounting

    def begin_step(self) -> None:
        with self._lock:
            self._inflight += 1

    def end_step(self) -> None:
        with self._lock:
            self._inflight -= 1
            self._maybe_flush_locked()

    # ----------------------------------------------------------- engine

    def _submit(self, req: _Request):
        with self._lock:
            if self._inflight == 0:
                # no barrier in effect (counter used outside a scheduled
                # step): degenerate to the direct dispatch
                return self._run_group([req])[0]
            self._pending.append(req)
            self._maybe_flush_locked()
        # the parked time: for a non-leader this covers co-tenant staging
        # skew plus the leader's flush work (pad/fuse + fused launch); the
        # flush leader itself ran the flush inside _maybe_flush_locked
        # above and passes straight through (~0) here.
        # obs.trace.step_breakdown separates the two.
        with span("batch.barrier_wait", kind=req.kind):
            req.event.wait()
        if req.error is not None:
            raise req.error
        return req.result

    def _maybe_flush_locked(self) -> None:
        """Flush when every in-flight step is parked on a pending request.
        Called with the lock held; at that moment no other session thread
        is runnable, so executing under the lock is race-free."""
        if not self._pending or len(self._pending) < self._inflight:
            return
        pending, self._pending = self._pending, []
        groups: dict[tuple, list[_Request]] = {}
        for r in pending:
            groups.setdefault(r.key, []).append(r)
        for whole in groups.values():
            for group in self._split_oversized(whole):
                self._flush_group(group)

    def _flush_group(self, group: list[_Request]) -> None:
        try:
            results = self._run_group(group)
            for r, out in zip(group, results):
                r.result = out
        except Exception as e:  # surface in every parked thread
            for r in group:
                r.error = e
        for r in group:
            r.event.set()

    def _split_oversized(self, group: list[_Request]):
        """Cap the adaptive-L pad waste: within one fused group every
        lane's event operands pad to the group max, so a single tenant
        with huge windows would make every small lane pay
        ``max_L / own_L`` wasted machine steps. Sort by event length and
        cut wherever a lane would exceed ``max_pad_ratio`` × the smallest
        length of its (sub)group — each side still fuses (lengths are
        power-of-two buckets, so splits are rare and stable)."""
        if (self.max_pad_ratio is None or len(group) < 2
                or group[0].kind not in _EV_AXES):
            return [group]
        ev_axes = _EV_AXES[group[0].kind]

        def ev_len(r):
            return max(np.shape(r.args[i])[ax] for i, ax in ev_axes.items())

        order = sorted(group, key=ev_len)
        subs, cur, lo = [], [order[0]], ev_len(order[0])
        for r in order[1:]:
            if ev_len(r) > lo * self.max_pad_ratio:
                subs.append(cur)
                cur, lo = [r], ev_len(r)
            else:
                cur.append(r)
        subs.append(cur)
        if len(subs) > 1:
            self.split_groups += len(subs) - 1
            REGISTRY.counter("batcher_split_groups_total").inc(
                len(subs) - 1)
        return subs

    @staticmethod
    def _slice(req: _Request, out):
        """Cut one fused lane's outputs back to the request's real episode
        count (episode axis is leading for a1/a2 state, trailing for mapc
        tuples)."""
        if req.kind == "mapc":
            return tuple(o[..., :req.m] for o in out)
        return tuple(o[:req.m] for o in out)

    def _run_group(self, group: list[_Request]):
        kind = group[0].kind
        if len(group) == 1:
            with span("batch.device_launch", kind=kind, lanes=1):
                return [self._run_single(group[0])]
        self.batches += 1
        self.fused_requests += len(group)
        REGISTRY.counter("batcher_batches_total").inc()
        REGISTRY.counter("batcher_fused_requests_total").inc(len(group))
        s = bucket_size(len(group), 1)
        lanes = group + [group[0]] * (s - len(group))  # pad: repeat lane 0
        # adaptive L re-bucketing: lanes with shorter event buffers pad to
        # the group max. Every producer pads to a LANES multiple (and past
        # one chunk, to a DEFAULT_BLOCK_E multiple — see ops.event_brick),
        # so the group max still divides the kernels' chunked event
        # BlockSpec evenly. np.shape: reading a length must not trigger a
        # host→device transfer of the whole buffer.
        ev_axes = _EV_AXES[kind]
        l_to = max(np.shape(r.args[i])[ax] for r in group
                   for i, ax in ev_axes.items())
        with span("batch.pad_fuse", kind=kind, lanes=len(group)):
            waste = sum(
                l_to - max(np.shape(r.args[i])[ax]
                           for i, ax in ev_axes.items())
                for r in group)
            self.pad_events += waste
            self.pad_lanes += s - len(group)
            REGISTRY.counter("batcher_pad_events_total").inc(waste)
            REGISTRY.counter("batcher_pad_lanes_total").inc(
                s - len(group))
            lane_args = [_pad_events(kind, r.args, l_to) for r in lanes]
            if kind not in ("a1k", "a2k", "mapck", "mapcs"):  # M-axis pad
                lane_args = [_pad_m(p, r.spec, r.mb)
                             for p, r in zip(lane_args, lanes)]
            stacked = tuple(jnp.stack([jnp.asarray(p[i])
                                       for p in lane_args])
                            for i in range(len(group[0].args)))
        with span("batch.device_launch", kind=kind, lanes=len(group)):
            if kind in ("a1k", "a2k", "mapck", "mapcs"):
                from repro.kernels import ops as kops
                if kind == "mapcs":
                    d = group[0].static[3]
                    kops.KERNEL_CALLS["a1_mapc_shard"] += len(group) * d
                    out = kops.a1_mapc_sharded_vmapped(
                        *group[0].static)(*stacked)
                    return [tuple(o[i] for o in out)
                            for i in range(len(group))]
                kops.KERNEL_CALLS[
                    {"a1k": "a1_state", "a2k": "a2_state",
                     "mapck": "a1_mapc"}[kind]] += len(group)
                if kind == "a1k":
                    out = kops.a1_state_vmapped(*group[0].static)(*stacked)
                elif kind == "a2k":
                    out = kops.a2_state_vmapped(*group[0].static)(*stacked)
                else:
                    out = kops.a1_mapc_vmapped(*group[0].static)(*stacked)
                return [tuple(o[i] for o in out)
                        for i in range(len(group))]
            if kind == "a1":
                out = _vmapped_a1()(*stacked)
            elif kind == "a2":
                out = _vmapped_a2()(*stacked)
            else:
                out = _vmapped_mapc(group[0].static)(*stacked)
            return [self._slice(r, tuple(o[i] for o in out))
                    for i, r in enumerate(group)]

    @staticmethod
    def _run_single(req: _Request):
        """Lone request: the plain unpadded dispatch — zero batching tax,
        same jit cache entries a standalone (executor-less) run warms."""
        from repro.core.count_a1 import _a1_carry_scan
        from repro.core.count_a2 import _a2_carry_scan
        if req.kind == "a1":
            return _a1_carry_scan()(*req.args)
        if req.kind == "a2":
            return _a2_carry_scan()(*req.args)
        if req.kind == "a1k":
            from repro.kernels import ops as kops
            n_levels, lcap, interpret = req.static
            return kops.a1_state_call(*req.args, n_levels=n_levels,
                                      lcap=lcap, interpret=interpret)
        if req.kind == "a2k":
            from repro.kernels import ops as kops
            n_levels, interpret = req.static
            return kops.a2_state_call(*req.args, n_levels=n_levels,
                                      interpret=interpret)
        if req.kind == "mapck":
            from repro.kernels import ops as kops
            n_levels, lcap, interpret = req.static
            return kops.a1_mapconcat_tuples(*req.args, n_levels=n_levels,
                                            lcap=lcap, interpret=interpret)
        if req.kind == "mapcs":
            from repro.kernels import ops as kops
            n_levels, lcap, interpret, d = req.static
            return kops.a1_mapconcat_sharded_tuples(
                *req.args, n_levels=n_levels, lcap=lcap,
                interpret=interpret, num_devices=d)
        return _map_all_segments(*req.args, req.static)
