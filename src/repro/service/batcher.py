"""Cross-session batching: shape-bucketed device batches per scan kind,
vmapped over a session axis, with group-scoped flushes and a measured
fusion gate.

The multi-tenant service advances many ``MiningSession``s concurrently.
Each session's miner bottoms out in a handful of jit'd scans (A1
bounded-list, A2 single-slot, MapConcatenate segment map); running S
sessions naively issues S small dispatches per level per window. This
module is the executor that turns those into one dispatch per shape
bucket:

* each session step runs in its own worker thread and installs this
  executor into its counters (``StreamingCounter.executor`` seam);
* a counter's scan call becomes ``submit()`` — the thread parks on an
  event;
* each pending shape-group flushes **the moment its own members are
  parked** (group-scoped flush): expected membership per group is
  learned from the session's previous step's request keys (or declared
  at ``begin_step``), so a group never waits on tenants that were never
  going to join it. The thread whose submit (or ``end_step``) completes
  a group executes its flush: it stacks the group's operands along a new
  leading session axis and runs one jit'd ``vmap`` of the underlying
  scan, scattering per-lane results back. Singleton lanes dispatch
  immediately through the plain unvmapped call. Sessions with no
  prediction yet (first step) are wildcards — all groups then wait for
  every live step to park, the old global barrier — and a
  ``flush_deadline_s`` timeout force-flushes a group should a stale
  prediction ever strand it.
* fusion is **cost-gated** (``FusionCostModel``): per-(key, lane-bucket)
  EWMAs of fused vs standalone launch seconds, fed from the flush paths'
  own timings, decide per group whether the vmapped launch actually
  beats per-lane dispatches; losing groups release their lanes to
  launch concurrently (``batch.self_launch``). Decisions are exported
  as ``batcher_fusion_gate_total{decision=...}``.

The carried Pallas kernels ride the same protocol: ``a1_kernel_scan`` /
``a2_kernel_scan`` take operands already in kernel brick layout (every
lane in a group shares (NP, LCAP, MP, EP) shapes — the counters'
shape-bucketed staging guarantees that), and a fused flush runs one
``vmap`` of the state-in/state-out ``pallas_call`` per group (Pallas
lowers the mapped session axis onto the grid, so the whole fleet's
machines advance in a single kernel launch). Lane results come back in
kernel layout — the counters keep their state resident there.

The multi-device MapConcatenate rides it too: ``mapc_sharded_scan``
fuses same-shape tenants' sharded commits into one launch that vmaps the
segmented kernel over the lane (session) axis *inside* the shard_map —
devices split the segment axis while lanes fill each device's grid
(``kernels.ops.a1_mapc_sharded_vmapped``).

Every scan in this engine is integer-only (i32 compares/adds, bool
masks), so the vmapped lane computation is bit-identical to the
standalone dispatch — the service's exactness guarantee rests on that and
is asserted by tests/test_service.py. Group sizes are padded to powers of
two (lane 0 repeated) so jit compiles once per (kind, bucket, S-bucket).

Adaptive L re-bucketing: requests are grouped *without* regard to their
event-buffer length — at flush time each lane's event operands are padded
to the group's max L (padded events are machine no-ops: PAD types never
match an episode row, so per-lane results stay bit-identical to the
standalone dispatch). Heterogeneous tenants — different window sizes,
different ingest rates — therefore fuse into one launch instead of
fragmenting into singleton groups keyed by L (the ROADMAP
adaptive-shape-bucketing item). The guardrail on the other side is
``max_pad_ratio``: a group whose lanes' event lengths spread beyond that
factor is split before flushing (``_split_oversized``), so one tenant's
giant windows cap — rather than multiply — the fleet's pad waste.
"""

from __future__ import annotations

import functools
import itertools
import threading
import time
from collections import Counter, deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.count_a1 import _a1_scan_core
from repro.core.count_a2 import _a2_scan_core
from repro.core.events import PAD_TYPE, TIME_NEG_INF
from repro.core.mapconcat import _map_all_segments
from repro.core.streaming import bucket_size
from repro.obs import REGISTRY, span


@functools.lru_cache(maxsize=None)
def _vmapped_a1():
    return jax.jit(jax.vmap(_a1_scan_core))


@functools.lru_cache(maxsize=None)
def _vmapped_a2():
    return jax.jit(jax.vmap(_a2_scan_core))


@functools.lru_cache(maxsize=None)
def _vmapped_mapc(lcap: int):
    return jax.jit(jax.vmap(lambda *args: _map_all_segments(*args, lcap)))


# per-kind padding specs for the episode (M) axis: (axis in each operand,
# pad value). Episodes are independent lanes of every scan (no cross-M
# interaction), so padding rows with inert machines is bit-safe for the
# real rows — results are sliced back to the caller's M.
_NEG = int(TIME_NEG_INF)  # "empty slot" filler for padded machine state
_PAD_A1 = (
    (0, 0), (0, 0), (0, 1), (None, 0), (None, 0), (0, _NEG), (0, 0), (0, 0), (0, 0)
)
_PAD_A2 = ((0, 0), (0, 0), (0, 1), (None, 0), (None, 0), (0, _NEG), (0, 0))
_PAD_MAPC = ((None, 0), (None, 0), (0, 0), (0, 0), (0, 1), (None, 0), (0, 1))

# event-operand spec per kind for the adaptive L re-bucketing:
# {operand index: event axis}. Padded events are machine no-ops (type =
# PAD_TYPE never matches an episode row; the derived successor-duplicate
# flags are false on and before the pad tail), so padding a lane's event
# operands to the fused group's max length is bit-safe.
_EV_AXES = {
    "a1": {3: 0, 4: 0},  # ev_types[L], ev_times[L]
    "a2": {3: 0, 4: 0},
    "mapc": {0: 1, 1: 1},  # wt[Q, L], wtt[Q, L]
    "a1k": {3: 1},  # ev brick [3, EP]
    "a2k": {3: 1},  # ev brick [2, EP]
    "mapck": {5: 2},  # segment bricks [P, 5, LW]
    "mapcs": {5: 2},  # sharded segment bricks [P, 5, LW]
}


def _pad_m(args, spec, m_to: int):
    out = []
    for a, (axis, fill) in zip(args, spec):
        a = jnp.asarray(a)
        if axis is None or a.shape[axis] == m_to:
            out.append(a)
            continue
        pad = [(0, 0)] * a.ndim
        pad[axis] = (0, m_to - a.shape[axis])
        out.append(jnp.pad(a, pad, constant_values=fill))
    return tuple(out)


def _pad_events(kind: str, args, l_to: int):
    """Pad a lane's event operands along the event axis to the fused
    group's max length. Only the *types* slot needs the PAD_TYPE fill
    (kind "a1"/"a2" operand 3, the ``wt`` half of "mapc", row 0 of the
    kernel bricks); times/dup/τ entries of padded events are never
    consulted — no episode row matches type -1 — so they zero-fill."""
    args = list(args)
    for idx, axis in _EV_AXES[kind].items():
        a = jnp.asarray(args[idx])
        grow = l_to - a.shape[axis]
        if grow == 0:
            continue
        pad = [(0, 0)] * a.ndim
        pad[axis] = (0, grow)
        all_types = (kind in ("a1", "a2") and idx == 3) or (kind == "mapc" and idx == 0)
        a = jnp.pad(a, pad, constant_values=PAD_TYPE if all_types else 0)
        if kind in ("a1k", "a2k"):  # ev brick: types = row 0
            a = a.at[0, l_to - grow:].set(PAD_TYPE)
        elif kind in ("mapck", "mapcs"):  # segment brick: types = row 0
            a = a.at[:, 0, l_to - grow:].set(PAD_TYPE)
        args[idx] = a
    return tuple(args)


# seam kind -> the calibrated engine whose standalone cost stands in for
# one lane of that seam (a2 has no separate table entry: its scan is the
# same event walk with a narrower state, ptpe is the honest stand-in)
_PRIOR_ENGINE = {
    "a1": "ptpe",
    "a2": "ptpe",
    "a1k": "ptpe",
    "a2k": "ptpe",
    "mapc": "mapconcatenate",
    "mapck": "mapconcat_kernel",
    "mapcs": "mapconcat_sharded",
}


def _policy_prior(key) -> float | None:
    """Calibrated standalone-launch estimate for one seam key, or
    ``None`` when no table is installed (the gate then keeps its
    optimistic fuse-first prior).  Decodes the per-seam key layouts
    documented on the seam methods below."""
    from repro.core.calibrate import get_policy
    kind = key[0]
    engine = _PRIOR_ENGINE.get(kind)
    if engine is None:
        return None
    q = devices = 1
    if kind in ("a1", "a2"):  # ("a1", mb, n[, lcap])
        m, n = key[1], key[2]
    elif kind == "mapc":  # ("mapc", mb, n, Q, lcap)
        m, n, q = key[1], key[2], key[3]
    elif kind == "a1k":  # ("a1k", n, lcap, interp, shape)
        n, m = key[1], key[4][1]
    elif kind == "a2k":  # ("a2k", n, interp, shape)
        n, m = key[1], key[3][1]
    else:  # ("mapck"/"mapcs", n, lcap,
        n, m, q = key[1], key[4][1], key[5]  # interp, shape, P[, d])
        if kind == "mapcs":
            devices = key[6]
    return get_policy().predict_single(engine, n_episode=n, m=m, q=q, devices=devices)


class FusionCostModel:
    """Measured fusion gate: EWMA launch costs fed from the flush paths.

    ``observe_fused`` records pad/fuse + vmapped-launch seconds for a
    (key, power-of-two lane bucket) combo; ``observe_single`` one plain
    dispatch of the same key. The first sample of every combo carries
    the jit compile and is discarded — the gate compares steady states.
    ``decide`` returns ``"fuse"`` when the fused estimate beats
    ``threshold`` × lanes × the standalone estimate, and also while
    either side is still unmeasured: fusing is the optimistic prior (it
    is the only way to measure the fused side, and forcing per-lane
    probe rounds would pay the standalone jit compiles *on top of* the
    fused ones — ruinous on compile-bound hosts). Standalone estimates
    accrue organically from singleton flushes and declined groups.
    ``"standalone"`` means the measurement says per-lane dispatches
    win."""

    def __init__(self, alpha: float = 0.25, threshold: float = 1.0, prior=None):
        self.alpha = alpha
        self.threshold = threshold
        self.prior = prior  # key -> est. standalone seconds | None
        self._fused: dict = {}  # (key, lane bucket) -> EWMA seconds
        self._single: dict = {}  # key -> EWMA seconds
        self._warm: set = set()  # combos whose compile sample is spent

    def _ewma(self, table: dict, key, dt: float) -> None:
        prev = table.get(key)
        table[key] = dt if prev is None else prev + self.alpha * (dt - prev)

    def observe_fused(self, key, lanes: int, dt: float) -> None:
        k = ("f", key, bucket_size(lanes, 1))
        if k not in self._warm:
            self._warm.add(k)
            return
        self._ewma(self._fused, (key, bucket_size(lanes, 1)), dt)

    def observe_single(self, key, dt: float) -> None:
        k = ("s", key)
        if k not in self._warm:
            self._warm.add(k)
            return
        self._ewma(self._single, key, dt)

    def decide(self, key, lanes: int) -> str:
        single = self._single.get(key)
        fused = self._fused.get((key, bucket_size(lanes, 1)))
        if single is None and self.prior is not None:
            # calibrated standalone estimate: lets a measured fused cost
            # trigger "standalone" before any organic singleton flush of
            # this key has been observed
            single = self.prior(key)
            if single is not None:
                REGISTRY.counter("batcher_fusion_prior_total", kind=key[0]).inc()
        if fused is None or single is None:
            return "fuse"  # optimistic until both sides are measured
        if fused <= self.threshold * lanes * single:
            return "fuse"
        return "standalone"


class _Request:
    __slots__ = (
        "kind",
        "key",
        "args",
        "spec",
        "static",
        "m",
        "mb",
        "event",
        "result",
        "error",
        "sid",
        "run_self",
    )

    def __init__(self, kind, key, args, spec, static, m, mb):
        self.kind = kind
        self.key = key
        self.args = args  # raw (unpadded) operands
        self.spec = spec  # episode-axis pad spec, applied only on fusion
        self.static = static
        self.m = m  # real episode count (fused results sliced back)
        self.mb = mb  # shared M bucket this request groups under
        self.event = threading.Event()
        self.result = None
        self.error = None
        self.sid = None  # owning step's session id
        self.run_self = False  # gate verdict: owner launches its own lane


class CrossSessionBatcher:
    """Group-scoped flush executor for cross-session scan batching.

    Protocol (driven by the scheduler): ``begin_step(session_id)`` once
    per session step about to run — from the dispatching thread, before
    any worker starts, so no group ever flushes early because a slow
    thread had not registered yet. Each step then runs in its own worker
    thread, which calls ``bind_session(session_id)`` first and
    ``end_step(session_id)`` when the step finishes, error or not (that
    re-check is what keeps co-tenants from wedging when a step dies
    before its first submit). Counters inside the step call
    ``a1_scan``/``a2_scan``/``mapc_scan``, which park until their shape
    group flushes; single-request groups fall through to the plain
    (unvmapped) dispatch so a lone tenant pays no batching tax and
    shares jit caches with standalone runs. Anonymous ``begin_step()``
    (legacy callers) registers a wildcard step that the first unbound
    submitting thread claims — an all-wildcard fleet reproduces the old
    all-parked global barrier exactly."""

    def __init__(
        self,
        max_pad_ratio: float = 4.0,
        fusion_gate: bool = True,
        flush_deadline_s: float = 0.5,
    ):
        self._lock = threading.Lock()
        self._local = threading.local()
        # group-scoped flush state: pending requests per shape key, the
        # live step set, and per-step predicted/observed key multisets
        self._pending: dict[tuple, list[_Request]] = {}
        self._alive: set[str] = set()
        self._wildcard: set[str] = set()  # steps with no prediction
        self._remaining: dict[str, Counter] = {}  # predicted, not yet seen
        self._seen: dict[str, Counter] = {}  # submitted this step
        self._predicted: dict[str, Counter] = {}  # learned at end_step
        self._parked: Counter = Counter()  # parked requests per step
        self._anon_pool: deque[str] = deque()
        self._anon_ids = itertools.count()
        self.cost_model = FusionCostModel(prior=_policy_prior)
        self.fusion_gate = fusion_gate
        # safety net for stale predictions: a parked group force-flushes
        # after this many seconds even if a predicted member never shows
        self.flush_deadline_s = flush_deadline_s
        self.batches = 0  # flushes that actually fused >1 request
        self.fused_requests = 0
        self.split_groups = 0  # oversized groups split to cap pad waste
        self.pad_events = 0  # event slots added padding lanes to max L
        self.pad_lanes = 0  # repeated lanes padding groups to 2^k
        self.flush_groups = 0  # group flushes, any gate decision
        self.deadline_flushes = 0
        self.gate_decisions: Counter = Counter()
        # adaptive-L guardrail: a lane may be padded to at most this
        # multiple of its own event-buffer length inside a fused group;
        # beyond it the group splits (one tenant's giant windows must not
        # make the whole fleet's lanes pay giant pads). None disables.
        self.max_pad_ratio = max_pad_ratio

    # ------------------------------------------------------------ seams

    def a1_scan(self, args):
        # (etypes[M,N], tlo, thi, ev_t[L], ev_tt[L], s[M,N,C], ptr, c, ovf)
        # — event length L deliberately absent from the key (adaptive L
        # re-bucketing: lanes pad to the group max at flush)
        m, n = args[0].shape
        mb = bucket_size(m, 8)
        key = ("a1", mb, n, args[5].shape[-1])
        return self._submit(_Request("a1", key, args, _PAD_A1, None, m, mb))

    def a2_scan(self, args):
        # (etypes[M,N], tlo, thi, ev_t[L], ev_tt[L], s[M,N], c)
        m, n = args[0].shape
        mb = bucket_size(m, 8)
        key = ("a2", mb, n)
        return self._submit(_Request("a2", key, args, _PAD_A2, None, m, mb))

    def mapc_scan(self, args, lcap: int):
        # (wt[Q,L], wtt, etypes[M,N], tlo, thi, tau[Q+1], w[M]) — the
        # segment count Q stays in the key, the window length L does not
        m, n = args[2].shape
        mb = bucket_size(m, 8)
        key = ("mapc", mb, n, args[0].shape[0], lcap)
        return self._submit(_Request("mapc", key, args, _PAD_MAPC, lcap, m, mb))

    def a1_kernel_scan(self, args, n_levels: int, lcap: int, interpret: bool):
        # kernel-layout operands: (et[NP,MP], tlo, thi, ev[3,EP],
        # s[NP,LCAP,MP], po, cnt[8,MP], ovf) — lanes fuse on identical
        # episode/state shapes; the event brick pads to the group max EP
        key = ("a1k", n_levels, lcap, interpret, tuple(args[0].shape))
        return self._submit(
            _Request("a1k", key, args, None, (n_levels, lcap, interpret), None, None)
        )

    def a2_kernel_scan(self, args, n_levels: int, interpret: bool):
        # kernel-layout operands: (et[NP,MP], tlo, thi, ev[2,EP], s[NP,MP],
        # cnt[8,MP])
        key = ("a2k", n_levels, interpret, tuple(args[0].shape))
        return self._submit(
            _Request("a2k", key, args, None, (n_levels, interpret), None, None)
        )

    def mapc_kernel_scan(self, args, n_levels: int, lcap: int, interpret: bool):
        # segmented-kernel operands: (et[NP,MP], tlo, thi, cum[NP,MP],
        # w[8,MP], segs[P,5,LW]) — P stays in the key, LW pads to the
        # group max
        key = ("mapck", n_levels, lcap, interpret, tuple(args[0].shape), args[5].shape[0])
        return self._submit(
            _Request("mapck", key, args, None, (n_levels, lcap, interpret), None, None)
        )

    def mapc_sharded_scan(
        self, args, n_levels: int, lcap: int, interpret: bool, num_devices: int
    ):
        # mesh-sharded segmented launch: same operands as mapc_kernel_scan
        # with the segment axis sharded over ``num_devices`` mesh devices
        # at dispatch. Fused groups vmap over the lane (session) axis
        # inside the shard_map, so the whole fleet's commits run as one
        # per-device launch; P and the device count stay in the key.
        key = (
            "mapcs",
            n_levels,
            lcap,
            interpret,
            tuple(args[0].shape),
            args[5].shape[0],
            num_devices,
        )
        return self._submit(
            _Request(
                "mapcs",
                key,
                args,
                None,
                (n_levels, lcap, interpret, num_devices),
                None,
                None,
            ),
        )

    # --------------------------------------------------- step accounting

    def begin_step(self, session: str | None = None, expected=None) -> str:
        """Register one session step about to run. ``session`` names the
        tenant so its flush-group membership can be predicted from its
        previous step's request keys; ``expected`` (an iterable of
        request keys, duplicates meaning counts) declares the membership
        explicitly and overrides the learned prediction. An anonymous
        step (no session) is a wildcard — every group waits for it to
        park or finish, the old global-barrier behavior."""
        with self._lock:
            sid = session
            if sid is None:
                sid = f"anon-{next(self._anon_ids)}"
                self._anon_pool.append(sid)
            self._alive.add(sid)
            self._seen[sid] = Counter()
            pred = (
                Counter(expected) if expected is not None else self._predicted.get(sid)
            )
            if pred is None:
                self._wildcard.add(sid)
                self._remaining[sid] = Counter()
            else:
                self._wildcard.discard(sid)
                self._remaining[sid] = Counter(pred)
            return sid

    def bind_session(self, session: str) -> None:
        """Tie the calling thread's submissions to ``session``'s step."""
        self._local.sid = session

    def end_step(self, session: str | None = None) -> None:
        """Retire a step: record its submitted keys as the session's next
        prediction and re-check every pending group — a step that ends
        without submitting (early error included) must release any group
        that was waiting on it."""
        with self._lock:
            sid = (session if session is not None else self._thread_sid_locked())
            self._local.sid = None
            if sid is not None:
                self._alive.discard(sid)
                self._wildcard.discard(sid)
                seen = self._seen.pop(sid, None)
                if seen is not None:
                    self._predicted[sid] = seen
                self._remaining.pop(sid, None)
                self._parked.pop(sid, None)
            ready = self._collect_ready_locked()
        self._run_flushes(ready)

    def forget(self, session: str) -> None:
        """Drop an (evicted) session's learned membership prediction."""
        with self._lock:
            self._predicted.pop(session, None)

    def predicted_signature(self, session: str) -> tuple | None:
        """The session's learned shape-group membership as a sortable
        signature (or None before its first completed step). The
        scheduler orders a step's lanes by this so tenants that will
        park on the same flush groups run in the same bounded-width
        chunk — with fewer concurrent lanes than sessions, adjacency is
        what keeps groups filling instead of timing out."""
        with self._lock:
            pred = self._predicted.get(session)
        if not pred:
            return None
        return tuple(sorted(str(k) for k in pred))

    def _thread_sid_locked(self) -> str | None:
        sid = getattr(self._local, "sid", None)
        if sid is not None and sid in self._alive:
            return sid
        if self._anon_pool:  # unbound thread claims an anonymous step
            sid = self._local.sid = self._anon_pool.popleft()
            return sid
        return None

    # ----------------------------------------------------------- engine

    def _submit(self, req: _Request):
        with self._lock:
            sid = self._thread_sid_locked() if self._alive else None
            if sid is not None:
                req.sid = sid
                self._seen[sid][req.key] += 1
                rem = self._remaining.get(sid)
                if rem is not None and rem[req.key] > 0:
                    rem[req.key] -= 1
                self._pending.setdefault(req.key, []).append(req)
                self._parked[sid] += 1
                ready = self._collect_ready_locked()
        if sid is None:
            # no step barrier applies to this thread (counter used outside
            # a scheduled step): degenerate to the direct dispatch
            return self._run_single_timed(req)
        self._run_flushes(ready)
        # the parked time: co-tenant staging skew plus whichever thread
        # executes this group's flush (it completed the group, so it runs
        # the launch while we park). obs.trace.step_breakdown separates
        # wait from flush work.
        with span("batch.barrier_wait", kind=req.kind):
            while not req.event.wait(timeout=self.flush_deadline_s):
                late = []
                with self._lock:
                    if not req.event.is_set() and req.key in self._pending:
                        # a predicted member never showed and never parked
                        # elsewhere — stale prediction; force the flush
                        self.deadline_flushes += 1
                        REGISTRY.counter("batcher_deadline_flush_total").inc()
                        late = self._take_group_locked(req.key)
                self._run_flushes(late)
        if req.run_self:
            # gate chose per-lane dispatch: every owner thread launches
            # its own request concurrently (XLA releases the GIL), which
            # is also the standalone measurement the cost model needs
            return self._run_single_timed(req)
        if req.error is not None:
            raise req.error
        return req.result

    # Flush-readiness, with the lock held. A group may flush when every
    # live step is accounted for: parked on this key, parked on another
    # key (a thread is in one place at a time — if it is expected here
    # too, it joins a later flush of this key instead of wedging two
    # groups against each other), finished, or not predicted to submit
    # this key. Wildcard steps (no prediction) hold every group until
    # they park or end.
    def _group_ready_locked(self, key) -> bool:
        here = {r.sid for r in self._pending[key]}
        for sid in self._alive:
            if sid in here or self._parked[sid] > 0:
                continue
            if sid in self._wildcard or self._remaining[sid][key] > 0:
                return False
        return True

    def _collect_ready_locked(self) -> list[list[_Request]]:
        ready = []
        for key in list(self._pending):
            if self._group_ready_locked(key):
                ready.extend(self._take_group_locked(key))
        return ready

    def _take_group_locked(self, key) -> list[list[_Request]]:
        group = self._pending.pop(key, [])
        if not group:
            return []
        for r in group:
            self._parked[r.sid] -= 1
        self.flush_groups += 1
        REGISTRY.counter("batcher_flush_groups_total").inc()
        return [group]

    def _run_flushes(self, groups: list[list[_Request]]) -> None:
        """Execute flushed groups OUTSIDE the lock: other groups keep
        collecting and flushing concurrently — that overlap (one group's
        device launch against another's host staging) is the point of
        group-scoped flushes."""
        for group in groups:
            for sub in self._split_oversized(group):
                self._dispatch_group(sub)

    def _dispatch_group(self, sub: list[_Request]) -> None:
        kind, key, lanes = sub[0].kind, sub[0].key, len(sub)
        if lanes == 1:
            decision = "singleton"
        elif not self.fusion_gate:
            decision = "fuse"
        else:
            decision = self.cost_model.decide(key, lanes)
        with self._lock:
            self.gate_decisions[decision] += 1
        REGISTRY.counter("batcher_fusion_gate_total", decision=decision).inc()
        with span("batch.gate", kind=kind, lanes=lanes, decision=decision):
            pass  # zero-width marker: step_breakdown tallies decisions
        if decision != "fuse":
            # singleton fall-through or measured loss: release every
            # lane to run its own plain dispatch
            for r in sub:
                r.run_self = True
                r.event.set()
            return
        try:
            results = self._run_fused(sub)
            for r, out in zip(sub, results):
                r.result = out
        except Exception as e:  # surface in every parked thread
            for r in sub:
                r.error = e
        for r in sub:
            r.event.set()

    def _split_oversized(self, group: list[_Request]):
        """Cap the adaptive-L pad waste: within one fused group every
        lane's event operands pad to the group max, so a single tenant
        with huge windows would make every small lane pay
        ``max_L / own_L`` wasted machine steps. Sort by event length and
        cut wherever a lane would exceed ``max_pad_ratio`` × the smallest
        length of its (sub)group — each side still fuses (lengths are
        power-of-two buckets, so splits are rare and stable)."""
        if (
            self.max_pad_ratio is None or len(group) < 2 or group[0].kind not in _EV_AXES
        ):
            return [group]
        ev_axes = _EV_AXES[group[0].kind]

        def ev_len(r):
            return max(np.shape(r.args[i])[ax] for i, ax in ev_axes.items())

        order = sorted(group, key=ev_len)
        subs, cur, lo = [], [order[0]], ev_len(order[0])
        for r in order[1:]:
            if ev_len(r) > lo * self.max_pad_ratio:
                subs.append(cur)
                cur, lo = [r], ev_len(r)
            else:
                cur.append(r)
        subs.append(cur)
        if len(subs) > 1:
            with self._lock:
                self.split_groups += len(subs) - 1
            REGISTRY.counter("batcher_split_groups_total").inc(len(subs) - 1)
        return subs

    @staticmethod
    def _slice(req: _Request, out):
        """Cut one fused lane's outputs back to the request's real episode
        count (episode axis is leading for a1/a2 state, trailing for mapc
        tuples)."""
        if req.kind == "mapc":
            return tuple(o[..., :req.m] for o in out)
        return tuple(o[:req.m] for o in out)

    def _run_fused(self, group: list[_Request]):
        kind, key = group[0].kind, group[0].key
        t0 = time.perf_counter()
        with self._lock:
            self.batches += 1
            self.fused_requests += len(group)
        REGISTRY.counter("batcher_batches_total").inc()
        REGISTRY.counter("batcher_fused_requests_total").inc(len(group))
        s = bucket_size(len(group), 1)
        lanes = group + [group[0]] * (s - len(group))  # pad: repeat lane 0
        # adaptive L re-bucketing: lanes with shorter event buffers pad to
        # the group max. Every producer pads to a LANES multiple (and past
        # one chunk, to a DEFAULT_BLOCK_E multiple — see ops.event_brick),
        # so the group max still divides the kernels' chunked event
        # BlockSpec evenly. np.shape: reading a length must not trigger a
        # host→device transfer of the whole buffer.
        ev_axes = _EV_AXES[kind]
        l_to = max(np.shape(r.args[i])[ax] for r in group for i, ax in ev_axes.items())
        with span("batch.pad_fuse", kind=kind, lanes=len(group)):
            waste = sum(
                l_to - max(np.shape(r.args[i])[ax] for i, ax in ev_axes.items())
                for r in group
            )
            with self._lock:
                self.pad_events += waste
                self.pad_lanes += s - len(group)
            REGISTRY.counter("batcher_pad_events_total").inc(waste)
            REGISTRY.counter("batcher_pad_lanes_total").inc(s - len(group))
            lane_args = [_pad_events(kind, r.args, l_to) for r in lanes]
            if kind not in ("a1k", "a2k", "mapck", "mapcs"):  # M-axis pad
                lane_args = [_pad_m(p, r.spec, r.mb) for p, r in zip(lane_args, lanes)]
            stacked = tuple(
                jnp.stack([jnp.asarray(p[i]) for p in lane_args])
                for i in range(len(group[0].args))
            )
        with span("batch.device_launch", kind=kind, lanes=len(group)):
            if kind in ("a1k", "a2k", "mapck", "mapcs"):
                from repro.kernels import ops as kops
                if kind == "mapcs":
                    d = group[0].static[3]
                    kops.KERNEL_CALLS["a1_mapc_shard"] += len(group) * d
                    out = kops.a1_mapc_sharded_vmapped(*group[0].static)(*stacked)
                else:
                    kops.KERNEL_CALLS[
                        {"a1k": "a1_state", "a2k": "a2_state",
                         "mapck": "a1_mapc"}[kind]] += len(group)
                    if kind == "a1k":
                        out = kops.a1_state_vmapped(*group[0].static)(*stacked)
                    elif kind == "a2k":
                        out = kops.a2_state_vmapped(*group[0].static)(*stacked)
                    else:
                        out = kops.a1_mapc_vmapped(*group[0].static)(*stacked)
                results = [tuple(o[i] for o in out) for i in range(len(group))]
            else:
                if kind == "a1":
                    out = _vmapped_a1()(*stacked)
                elif kind == "a2":
                    out = _vmapped_a2()(*stacked)
                else:
                    out = _vmapped_mapc(group[0].static)(*stacked)
                results = [
                    self._slice(r, tuple(o[i] for o in out)) for i, r in enumerate(group)
                ]
        with self._lock:
            self.cost_model.observe_fused(key, len(group), time.perf_counter() - t0)
        return results

    def _run_single_timed(self, req: _Request):
        """One lane's plain dispatch, in the owning thread, timed for the
        cost model. ``batch.self_launch`` is a per-thread device phase in
        ``step_breakdown`` — concurrent self-launches must not read as
        serialized flush work."""
        t0 = time.perf_counter()
        with span("batch.self_launch", kind=req.kind):
            out = self._run_single(req)
        with self._lock:
            self.cost_model.observe_single(req.key, time.perf_counter() - t0)
        return out

    @staticmethod
    def _run_single(req: _Request):
        """Lone request: the plain unpadded dispatch — zero batching tax,
        same jit cache entries a standalone (executor-less) run warms."""
        from repro.core.count_a1 import _a1_carry_scan
        from repro.core.count_a2 import _a2_carry_scan
        if req.kind == "a1":
            return _a1_carry_scan()(*req.args)
        if req.kind == "a2":
            return _a2_carry_scan()(*req.args)
        if req.kind == "a1k":
            from repro.kernels import ops as kops
            n_levels, lcap, interpret = req.static
            return kops.a1_state_call(
                *req.args, n_levels=n_levels, lcap=lcap, interpret=interpret
            )
        if req.kind == "a2k":
            from repro.kernels import ops as kops
            n_levels, interpret = req.static
            return kops.a2_state_call(*req.args, n_levels=n_levels, interpret=interpret)
        if req.kind == "mapck":
            from repro.kernels import ops as kops
            n_levels, lcap, interpret = req.static
            return kops.a1_mapconcat_tuples(
                *req.args, n_levels=n_levels, lcap=lcap, interpret=interpret
            )
        if req.kind == "mapcs":
            from repro.kernels import ops as kops
            n_levels, lcap, interpret, d = req.static
            return kops.a1_mapconcat_sharded_tuples(
                *req.args,
                n_levels=n_levels,
                lcap=lcap,
                interpret=interpret,
                num_devices=d,
            )
        return _map_all_segments(*req.args, req.static)
