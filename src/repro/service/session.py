"""Per-tenant mining session: a ``StreamingMiner`` with its own config,
bounded memory, ingest/result queues, and checkpointable state.

One session = one electrode-array (or any other event-emitting chip)
stream. The session owns the mining semantics — window size, θ and its
mode, episode level cap, engine — while the service owns scheduling and
cross-session batching. ``history_limit`` (the checkpoint interval) keeps
a long-lived session's retained state O(interval) instead of O(stream):
counters checkpoint machine state per interval and replay only the suffix
(core.streaming). ``state_dict``/``load_state_dict`` snapshot the whole
session; ``save``/``restore_into`` route that through the atomic
two-phase ``checkpoint.ckpt`` store, which is also what makes the
scheduler's retry-on-failure sound for a stateful step."""

from __future__ import annotations

import dataclasses
import json
from collections import deque
from pathlib import Path

import numpy as np

from repro.checkpoint import ckpt
from repro.core.events import EventStream
from repro.core.miner import MiningResult
from repro.core.streaming import StagedWindow, StreamingMiner, _state_sub
from repro.obs import span
from repro.telemetry import ThroughputMeter


@dataclasses.dataclass(frozen=True)
class SessionConfig:
    """Per-session mining parameters (the multi-tenant axis: every session
    may differ in all of them)."""

    intervals: tuple = ((5, 10),)
    theta: int = 4
    theta_mode: str = "per_window"  # or "cumulative"
    max_level: int = 3
    window_ms: int = 2000  # advisory: the tenant's partition size
    engine: str = "hybrid"
    two_pass: bool = True
    history_limit: int | None = 8  # checkpoint interval (None = unbounded)
    lcap: int = 4
    num_segments: int = 8
    # On-chip counting (the chip-on-chip promise): sessions run the carried
    # Pallas kernels whenever the dispatch policy allows, falling back to
    # the XLA scans (bit-identical) otherwise. Unified with StreamingMiner
    # and the one-shot engines — a service session must never silently get
    # a slower engine than a standalone miner would.
    use_kernel: bool = True

    def make_miner(self, executor=None) -> StreamingMiner:
        return StreamingMiner(
            [tuple(iv) for iv in self.intervals],
            self.theta,
            max_level=self.max_level,
            mode=self.theta_mode,
            engine=self.engine,
            two_pass=self.two_pass,
            use_kernel=self.use_kernel,
            lcap=self.lcap,
            num_segments=self.num_segments,
            history_limit=self.history_limit,
            executor=executor,
        )


@dataclasses.dataclass
class WindowDelta:
    """One mined window's report, queued for ``poll``."""

    window_idx: int
    result: MiningResult
    n_events: int
    final: bool

    def episodes(self, level: int | None = None):
        """Flatten the frequent episodes to (etypes tuple, count) pairs —
        the wire-friendly per-window delta a client consumes. ``level`` is
        1-based (level 1 = single events); out-of-range levels yield []."""
        res = self.result
        out = []
        levels = (range(len(res.frequent)) if level is None else [level - 1])
        for li in levels:
            if li < 0 or li >= len(res.frequent):
                continue
            batch = res.frequent[li]
            for i in range(batch.M):
                out.append(
                    (tuple(int(x) for x in batch.etypes[i]), int(res.counts[li][i]))
                )
        return out


@dataclasses.dataclass
class PreparedStep:
    """One window's host-side preparation, ready for device execution.

    Produced by ``MiningSession.prepare``: the raw window (so an evicted
    prep can be re-queued), its ``StagedWindow`` (PAD strip + histogram
    already done), the retry ``state_dict`` snapshot, and the meter
    rewind mark. The scheduler double-buffers these — step p+1's preps
    are built on session threads while step p's scans hold the device —
    then runs ``execute`` and ``commit``."""

    window: EventStream
    final: bool
    window_idx: int
    staged: StagedWindow
    snapshot: dict | None
    meter_mark: int


class MiningSession:
    """A tenant's streaming miner plus its ingest/result queues.

    The step lifecycle is split for the pipelined scheduler:
    ``prepare()`` pops the next window and does every host-only piece
    (retry snapshot, meter mark, PAD strip, histogram); ``execute()``
    runs the miner update (the device work); ``commit()`` publishes the
    delta. ``step()`` composes the three for serial callers. A prepared
    step that will not run — watchdog rewind, eviction — is returned to
    the queue with ``unstage()`` (or dropped with ``discard()`` when a
    snapshot restore is about to re-queue its window anyway)."""

    def __init__(
        self,
        session_id: str,
        config: SessionConfig,
        executor=None,
        max_results: int = 256,
    ):
        self.session_id = session_id
        self.config = config
        self.miner = config.make_miner(executor=executor)
        self.meter = ThroughputMeter(label=session_id)
        self.pending: deque[tuple[EventStream, bool]] = deque()
        self.results: deque[WindowDelta] = deque(maxlen=max_results)
        self.windows_done = 0
        self.staged_count = 0  # prepared-but-uncommitted windows
        self.closed = False

    # ------------------------------------------------------------- data

    def enqueue(self, window: EventStream, final: bool = False) -> None:
        if self.closed:
            raise RuntimeError(f"session {self.session_id} is closed")
        self.pending.append((window, final))
        self.closed = final

    @property
    def queue_depth(self) -> int:
        # staged windows still count: backpressure, drain, and close must
        # see prepared-but-uncommitted work as queued
        return len(self.pending) + self.staged_count

    def prepare(self, snapshot: bool = True) -> PreparedStep | None:
        """Host-side half of a step: snapshot (retry insurance — taken
        *before* the pop so a restore re-queues the window), pop the
        oldest pending window, and stage it. Mines nothing."""
        if not self.pending:
            return None
        snap = self.state_dict() if snapshot else None
        mark = self.meter.mark()
        window, final = self.pending.popleft()
        staged = self.miner.stage(window)
        prep = PreparedStep(
            window, final, self.windows_done + self.staged_count, staged, snap, mark
        )
        self.staged_count += 1
        return prep

    def execute(self, prep: PreparedStep) -> WindowDelta:
        """Device half: run the miner over the staged window (this is
        where the step parks in the cross-session batcher)."""
        self.meter.start()
        with span("session.mine_window", session=self.session_id, window=prep.window_idx):
            res = self.miner.update(prep.staged, final=prep.final)
        self.meter.stop(prep.staged.n_events)
        return WindowDelta(prep.window_idx, res, prep.staged.n_events, prep.final)

    def commit(self, prep: PreparedStep, delta: WindowDelta) -> WindowDelta:
        """Publish an executed step: count the window and queue the delta
        for ``poll``. Runs before the *next* ``prepare`` of the same
        session so its snapshot includes this delta."""
        self.windows_done += 1
        self.staged_count -= 1
        self.results.append(delta)
        return delta

    def discard(self, prep: PreparedStep) -> None:
        """Drop a prepared step whose window is about to come back via a
        snapshot restore (watchdog rewind) — only the staging accounting
        unwinds here."""
        self.staged_count -= 1

    def unstage(self, prep: PreparedStep) -> None:
        """Return a prepared step's window to the front of the queue (no
        restore coming — e.g. eviction of a double-buffered session)."""
        self.pending.appendleft((prep.window, prep.final))
        self.staged_count -= 1

    def step(self) -> WindowDelta | None:
        """Mine the oldest pending window (called by the scheduler, inside
        a batching step). Returns the delta, also queued for ``poll``."""
        prep = self.prepare(snapshot=False)
        if prep is None:
            return None
        return self.commit(prep, self.execute(prep))

    def poll(self, max_items: int | None = None) -> list[WindowDelta]:
        out = []
        while self.results and (max_items is None or len(out) < max_items):
            out.append(self.results.popleft())
        return out

    # ------------------------------------------------------------ state

    def state_dict(self) -> dict[str, np.ndarray]:
        """Session state as a flat array pytree: miner machine state, the
        not-yet-mined ingest queue, and the mined-but-unpolled result
        queue — a restored session replays nothing and drops nothing (the
        miner is already past queued deltas' windows, so they could never
        be regenerated)."""
        d = {f"miner/{k}": v for k, v in self.miner.state_dict().items()}
        d["windows_done"] = np.asarray(self.windows_done, np.int64)
        d["closed"] = np.asarray(int(self.closed), np.int64)
        for j, (w, final) in enumerate(self.pending):
            d[f"pending/{j}/types"] = w.types.copy()
            d[f"pending/{j}/times"] = w.times.copy()
            d[f"pending/{j}/meta"] = np.asarray([w.num_types, int(final)], np.int64)
        for j, delta in enumerate(self.results):
            p = f"results/{j}/"
            d[p + "meta"] = np.asarray(
                [
                    delta.window_idx,
                    delta.n_events,
                    int(delta.final),
                    len(delta.result.frequent),
                ],
                np.int64,
            )
            for li, (batch, cnts) in enumerate(
                zip(delta.result.frequent, delta.result.counts)
            ):
                d[p + f"L{li}/etypes"] = batch.etypes.copy()
                d[p + f"L{li}/tlo"] = batch.tlo.copy()
                d[p + f"L{li}/thi"] = batch.thi.copy()
                d[p + f"L{li}/counts"] = np.asarray(cnts, np.int64).copy()
            d[p + "stats"] = np.asarray(
                [
                    [
                        s.level,
                        s.num_candidates,
                        s.num_survived_a2,
                        s.num_frequent,
                        s.seconds,
                    ]
                    for s in delta.result.stats
                ],
                np.float64,
            )
        return d

    def load_state_dict(self, d: dict) -> None:
        from repro.core.episodes import EpisodeBatch
        from repro.core.miner import LevelStats
        d = {k: np.asarray(v) for k, v in d.items()}
        self.miner.load_state_dict(_state_sub(d, "miner/"))
        self.windows_done = int(d["windows_done"])
        self.closed = bool(int(d["closed"]))
        self.pending.clear()
        j = 0
        while f"pending/{j}/types" in d:
            num_types, final = (int(x) for x in d[f"pending/{j}/meta"])
            self.pending.append(
                (
                    EventStream(
                        d[f"pending/{j}/types"].astype(np.int32),
                        d[f"pending/{j}/times"].astype(np.int32),
                        num_types,
                    ),
                    bool(final),
                ),
            )
            j += 1
        self.results.clear()
        j = 0
        while f"results/{j}/meta" in d:
            p = f"results/{j}/"
            widx, n_ev, final, n_levels = (int(x) for x in d[p + "meta"])
            frequent, counts = [], []
            for li in range(n_levels):
                et = d[p + f"L{li}/etypes"].astype(np.int32)
                m, n = et.shape
                frequent.append(
                    EpisodeBatch(
                        et,
                        d[p + f"L{li}/tlo"].astype(np.int32).reshape(m, max(n - 1, 0)),
                        d[p + f"L{li}/thi"].astype(np.int32).reshape(m, max(n - 1, 0)),
                    ),
                )
                counts.append(d[p + f"L{li}/counts"].astype(np.int64))
            stats = [
                LevelStats(int(r[0]), int(r[1]), int(r[2]), int(r[3]), float(r[4]))
                for r in np.atleast_2d(d[p + "stats"])
                if len(r)
            ]
            self.results.append(
                WindowDelta(
                    widx,
                    MiningResult(frequent=frequent, counts=counts, stats=stats),
                    n_ev,
                    bool(final),
                ),
            )
            j += 1

    # ------------------------------------------------- durable snapshots

    def save(
        self, root: str | Path, step: int | None = None, extra: dict | None = None
    ) -> Path:
        """Atomic on-disk checkpoint through ``checkpoint.ckpt`` (two-phase
        rename protocol; a crash leaves a complete checkpoint or none).

        ``extra`` adds transport-layer leaves (e.g. the wire server's
        ``wire/last_seq`` ingest sequence number) to the same atomic
        checkpoint, so the durable mining state and the durable dedup
        horizon can never disagree after a crash. ``load_state_dict``
        ignores unknown keys; readers fetch them via
        ``checkpoint.ckpt.read_leaf``."""
        step = self.windows_done if step is None else step
        d = self.state_dict()
        if extra:
            d.update({k: np.asarray(v) for k, v in extra.items()})
        return ckpt.save(
            Path(root) / self.session_id,
            step,
            d,
            config_hash=ckpt.config_fingerprint(self.config),
        )

    def restore(self, root: str | Path, step: int | None = None) -> "MiningSession":
        """Load the newest (or given) checkpoint into this freshly
        constructed session (same config as the saved one). The on-disk
        manifest is self-describing, so the flat tree structure is rebuilt
        from it — no template state needed (cold restore after a crash).
        Returns self."""
        sdir = Path(root) / self.session_id
        if step is None:
            step = ckpt.latest_step(sdir)
            if step is None:
                raise FileNotFoundError(f"no checkpoint under {sdir}")
        manifest = json.loads((sdir / f"step_{step:08d}" / "MANIFEST.json").read_text())
        tree_like = {e["key"]: np.zeros((), np.int64) for e in manifest["leaves"]}
        tree, _ = ckpt.restore(
            sdir, tree_like, step=step, config_hash=ckpt.config_fingerprint(self.config)
        )
        self.load_state_dict(tree)
        return self
