"""Multi-tenant real-time mining service (arXiv:0905.2203's "accelerator
service" framing): many concurrent electrode-array sessions share the
devices through cross-session batched streaming with bounded per-session
memory."""

from .batcher import CrossSessionBatcher
from .scheduler import (AdmissionError, BackpressureError,
                        RoundRobinScheduler, SchedulerPolicy)
from .server import MiningService
from .session import MiningSession, SessionConfig, WindowDelta

__all__ = [
    "MiningService", "MiningSession", "SessionConfig", "WindowDelta",
    "CrossSessionBatcher", "RoundRobinScheduler", "SchedulerPolicy",
    "AdmissionError", "BackpressureError",
]
