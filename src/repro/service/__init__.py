"""Multi-tenant real-time mining service (arXiv:0905.2203's "accelerator
service" framing): many concurrent electrode-array sessions share the
devices through cross-session batched streaming with bounded per-session
memory."""

from .batcher import CrossSessionBatcher, FusionCostModel
from .client import DeadlineExceeded, MiningClient, WireError
from .daemon import DaemonConfig, MiningDaemon
from .scheduler import (
    AdmissionError,
    BackpressureError,
    RoundRobinScheduler,
    SchedulerPolicy,
    UnknownSessionError,
)
from .server import MiningService
from .session import (MiningSession, PreparedStep, SessionConfig, WindowDelta)
from .wire import Frame, FrameType, ProtocolError, Status, WireServer

__all__ = [
    "MiningService",
    "MiningSession",
    "SessionConfig",
    "WindowDelta",
    "PreparedStep",
    "CrossSessionBatcher",
    "FusionCostModel",
    "RoundRobinScheduler",
    "SchedulerPolicy",
    "AdmissionError",
    "BackpressureError",
    "UnknownSessionError",
    "WireServer",
    "Frame",
    "FrameType",
    "Status",
    "ProtocolError",
    "MiningClient",
    "WireError",
    "DeadlineExceeded",
    "MiningDaemon",
    "DaemonConfig",
]
