"""Admission control, backpressure, round-robin fairness, and step
pipelining for the multi-tenant mining service — with fault tolerance
from ``runtime.ft``.

Policies, in the order a window meets them:

* **admission** — at most ``max_sessions`` live tenants; a new session is
  rejected (``AdmissionError``) rather than degrading everyone already
  admitted.
* **backpressure** — each session's ingest queue is capped at
  ``max_pending_windows``; a producer that outruns the miner gets a
  ``BackpressureError`` (the chip-side acquisition host is the right
  place to shed or spool — silently buffering unbounded windows is how
  real-time loops die).
* **fairness** — ``step()`` services up to ``max_batch_sessions`` sessions
  with pending work in round-robin order starting *after* the last tenant
  served, so a firehose session cannot starve a trickle session.
* **lane concurrency** — within a batched step at most
  ``max_concurrent_lanes`` session threads run at once (default: host
  core count, min 2); extra lanes run in later chunks of the same step,
  affinity-ordered by the batcher's learned shape signatures so tenants
  that fuse together stay co-resident. Oversubscribing a small host
  only time-slices the mining work and inflates every co-resident
  window's latency without adding parallelism.
* **pipelining** — a step runs in three phases (prepare → execute →
  commit, see ``session.PreparedStep``). With ``pipeline_depth > 1`` the
  scheduler double-buffers: while step p's fused scans hold the device,
  each lane that will run in step p+1 prepares its next window (PAD
  strip, histogram, the retry ``state_dict`` snapshot) on its own session
  thread — host work that used to be a serial ``schedule.snapshot`` span
  up front. The overlap is measured (``schedule.stage`` spans,
  ``pipeline_overlap_s``).
* **retry** — each batched step runs under ``runtime.ft.StepWatchdog``.
  Mining steps are stateful, so naive retry would double-count; every
  prepared step carries a pre-pop ``state_dict`` snapshot and a meter
  mark, and a retry rewinds each lane to them (``ThroughputMeter.truncate``
  / ``abort``) — including dropping any step-p+1 preps the failed attempt
  had staged, whose windows the snapshot restore re-queues — making the
  step functionally pure in the watchdog's sense (same state in ⇒ same
  result out, nothing double-counted).
"""

from __future__ import annotations

import dataclasses
import os
import threading
import time
from collections import deque

from repro.core.events import EventStream
from repro.obs import REGISTRY, span
from repro.runtime.ft import StepFailure, StepWatchdog, WatchdogConfig

from .session import MiningSession, PreparedStep, SessionConfig, WindowDelta


class AdmissionError(RuntimeError):
    """Service at tenant capacity — retry later or scale out."""


class BackpressureError(RuntimeError):
    """Session ingest queue full — producer must slow down or spool."""


class UnknownSessionError(KeyError):
    """Operation addressed a session id the scheduler does not know —
    never admitted, or already evicted. Subclasses ``KeyError`` so
    callers that guarded the old bare dict lookup keep working."""


@dataclasses.dataclass
class SchedulerPolicy:
    max_sessions: int = 64
    max_pending_windows: int = 8
    max_batch_sessions: int = 16
    # Pre-step state snapshots make retry sound but copy every chosen
    # session's machine state to host each step; disable to trade retry
    # capability (a failed step then surfaces as StepFailure immediately)
    # for a leaner hot path.
    retry_snapshots: bool = True
    # Step staging depth: 2 double-buffers (step p+1's host prepare —
    # snapshots included — overlaps step p's device work on the session
    # threads); 1 restores the serial prepare-then-run schedule.
    pipeline_depth: int = 2
    # Gate fusion on the batcher's measured cost model; off = always
    # fuse multi-lane groups (the pre-cost-model behavior).
    fusion_gate: bool = True
    # Safety-net flush for a parked group whose predicted member never
    # arrives (stale membership prediction after a tenant's phase change).
    flush_deadline_s: float = 0.5
    # Concurrent lane (session thread) cap per batched step. None adapts
    # to the host: max(2, cpu_count). More lanes than cores just
    # time-slices the host mining work and inflates every co-resident
    # window's latency; lanes beyond the cap run in later chunks of the
    # same step (affinity-ordered, so same-shape tenants stay
    # co-resident and their flush groups still fill).
    max_concurrent_lanes: int | None = None
    # Calibrated-dispatch table (core.calibrate) to install at service
    # construction: a path to a cached table JSON. None keeps whatever
    # policy the process already has (heuristic unless the environment
    # opted in); a stale/wrong-device table degrades to the heuristic.
    policy_table: str | None = None
    watchdog: WatchdogConfig = dataclasses.field(
        default_factory=lambda: WatchdogConfig(min_deadline_s=60.0)
    )


class RoundRobinScheduler:
    """Owns the session table and drives batched steps through the
    cross-session batcher (one worker thread per chosen session; the
    batcher fuses their scans into per-bucket vmapped calls, flushing
    each shape group as soon as its own members are parked)."""

    def __init__(self, policy: SchedulerPolicy | None = None, batcher=None):
        self.policy = policy or SchedulerPolicy()
        self.batcher = batcher
        self.sessions: dict[str, MiningSession] = {}
        self._rr: deque[str] = deque()  # round-robin service order
        self.watchdog = StepWatchdog(self.policy.watchdog)
        self.steps = 0
        # double-buffer state: next step's planned service order and the
        # preps already built for it on last step's session threads
        self._plan: list[str] = []
        self._staged: dict[str, PreparedStep] = {}
        self.pipeline_overlap_s = 0.0  # staging time overlapped with device

    # -------------------------------------------------------- admission

    def admit(self, session_id: str, config: SessionConfig) -> MiningSession:
        if session_id in self.sessions:
            REGISTRY.counter("scheduler_admission_rejected_total").inc()
            raise AdmissionError(f"session {session_id!r} already admitted")
        if len(self.sessions) >= self.policy.max_sessions:
            REGISTRY.counter("scheduler_admission_rejected_total").inc()
            raise AdmissionError(
                f"at capacity ({self.policy.max_sessions} sessions); "
                f"admission of {session_id!r} refused")
        s = MiningSession(session_id, config, executor=self.batcher)
        self.sessions[session_id] = s
        self._rr.append(session_id)
        REGISTRY.gauge("scheduler_sessions").set(len(self.sessions))
        return s

    def session(self, session_id: str) -> MiningSession:
        """Typed lookup: raises ``UnknownSessionError`` (a ``KeyError``
        subclass) instead of leaking the session-table dict's bare
        ``KeyError``."""
        try:
            return self.sessions[session_id]
        except KeyError:
            raise UnknownSessionError(f"unknown session {session_id!r}") from None

    def evict(self, session_id: str) -> MiningSession:
        s = self.session(session_id)
        prep = self._staged.pop(session_id, None)
        if prep is not None:
            s.unstage(prep)  # prepared window back to its queue
        self._plan = [sid for sid in self._plan if sid != session_id]
        del self.sessions[session_id]
        self._rr = deque(x for x in self._rr if x != session_id)
        if self.batcher is not None:
            self.batcher.forget(session_id)
        REGISTRY.gauge("scheduler_sessions").set(len(self.sessions))
        # the evicted session's queued windows leave with it — the depth
        # gauge must not keep reporting them
        REGISTRY.gauge("scheduler_queue_depth").set(self.pending_windows)
        return s

    # ------------------------------------------------------- ingestion

    def submit(self, session_id: str, window: EventStream, final: bool = False) -> None:
        s = self.session(session_id)
        if s.queue_depth >= self.policy.max_pending_windows:
            # the producer must shed or spool this window upstream —
            # count it: shed pressure is the service's earliest overload
            # signal and invisible in throughput numbers alone
            REGISTRY.counter("scheduler_backpressure_total").inc()
            REGISTRY.counter("scheduler_shed_windows_total", session=session_id).inc()
            raise BackpressureError(
                f"session {session_id!r} queue at depth {s.queue_depth} "
                f"(cap {self.policy.max_pending_windows})")
        s.enqueue(window, final=final)
        REGISTRY.gauge("scheduler_queue_depth").set(self.pending_windows)

    @property
    def pending_windows(self) -> int:
        return sum(s.queue_depth for s in self.sessions.values())

    # --------------------------------------------------------- stepping

    def _choose(self) -> list[MiningSession]:
        """Round-robin scan starting after the last session served.
        Selects on un-staged pending windows — a session whose only
        remaining window is already prepared for the coming step must
        not be chosen again."""
        chosen = []
        for _ in range(len(self._rr)):
            sid = self._rr[0]
            self._rr.rotate(-1)
            s = self.sessions[sid]
            if len(s.pending):
                chosen.append(s)
                if len(chosen) >= self.policy.max_batch_sessions:
                    break
        return chosen

    def _collect(self):
        """Assemble this step's prepared lanes: adopt the preps staged on
        last step's session threads, serial-prepare whatever the plan
        still misses (or, with no plan, a fresh round-robin choice)."""
        plan, self._plan = self._plan, []
        prestaged, self._staged = self._staged, {}
        staged: dict[str, PreparedStep] = {}
        order: list[MiningSession] = []
        need: list[MiningSession] = []
        for sid in plan:
            s = self.sessions.get(sid)
            if s is None:
                continue
            prep = prestaged.pop(sid, None)
            if prep is not None:
                staged[sid] = prep
                order.append(s)
            elif len(s.pending):
                need.append(s)
        for sid, prep in prestaged.items():  # plan drift: back to queue
            self.sessions[sid].unstage(prep)
        if not staged and not need:
            need = self._choose()
        if need:
            with span("schedule.snapshot", sessions=len(need)):
                for s in need:
                    prep = s.prepare(snapshot=self.policy.retry_snapshots)
                    if prep is not None:
                        staged[s.session_id] = prep
                        order.append(s)
        return staged, order

    def step(self) -> dict[str, WindowDelta]:
        """Service one window for each chosen session (batched). Returns
        {session_id: delta}; empty when nothing is pending."""
        staged, order = self._collect()
        if not staged:
            return {}
        with span("schedule.step", step=self.steps, sessions=len(order)):
            out = self._step_staged(staged, order)
        REGISTRY.counter("scheduler_steps_total").inc()
        REGISTRY.gauge("scheduler_queue_depth").set(self.pending_windows)
        REGISTRY.gauge("scheduler_heartbeat_ts").set_now()
        return out

    def _step_staged(self, staged: dict[str, PreparedStep], order: list[MiningSession]):
        pipelined = (
            self.batcher is not None and len(order) > 1 and self.policy.pipeline_depth > 1
        )
        # Next step's service order, fixed before this step runs: staging
        # already popped this step's windows, so queue depths and the
        # rotated _rr are exactly what _choose would see afterwards.
        next_plan = ([s.session_id for s in self._choose()] if pipelined else [])
        if not self.policy.retry_snapshots:
            def runner():
                try:
                    return self._run_batch(staged, order, next_plan)
                except Exception as e:
                    raise StepFailure(
                        f"step {self.steps} failed and retry_snapshots is "
                        "off (no safe state to rewind to)") from e
        else:
            attempt = [0]

            def runner():
                if attempt[0]:  # retry: rewind every lane to its snapshot
                    REGISTRY.counter("scheduler_watchdog_retries_total").inc()
                    self._rewind(staged, order)
                attempt[0] += 1
                return self._run_batch(staged, order, next_plan)
        try:
            out = self.watchdog.run_step(self.steps, runner)
        except Exception:
            # step abandoned: prestaged next windows go back to their
            # queues; this step's windows are consumed-and-lost (the old
            # serial-step failure semantics), so only unwind accounting
            for sid, nprep in self._staged.items():
                self.sessions[sid].unstage(nprep)
            self._staged.clear()
            for s in order:
                # lanes that committed in the last attempt are already at
                # zero; zeroing (not decrementing) is exact for both
                s.staged_count = 0
                s.meter.abort()
            raise
        self.steps += 1
        self._plan = next_plan
        return out

    def _rewind(
        self, staged: dict[str, PreparedStep], order: list[MiningSession]
    ) -> None:
        """Watchdog retry: restore every lane to its pre-step snapshot
        without double-counting. Preps the failed attempt staged for the
        *next* step are dropped first — their windows predate nothing:
        the snapshot restore re-queues them along with the current one —
        then each lane rewinds its meter and re-prepares."""
        self._staged.clear()
        for s in order:
            prep = staged[s.session_id]
            # state_dict covers miner state + both queues (results from
            # the failed attempt are dropped by the reload); the meter
            # un-counts the attempt's rows and any dangling start()
            s.meter.truncate(prep.meter_mark)
            s.meter.abort()
            s.load_state_dict(prep.snapshot)
            s.staged_count = 0  # every pop was undone by the restore
            staged[s.session_id] = s.prepare(snapshot=True)

    def quiesce(self) -> int:
        """Return every double-buffered prepared step to its session's
        queue and drop the pipeline plan. Returns preps unstaged.

        This is the graceful-shutdown ordering fix: a prepared-but-
        uncommitted step's window lives in *neither* the session's
        pending queue nor the miner's machine state, so a checkpoint
        taken while it is staged would silently lose that window — and a
        restart would mine a stream with a hole in it. Every external
        checkpoint (SIGTERM drain, daemon periodic checkpoint, operator
        ``checkpoint`` control frame) must quiesce first; the unstaged
        windows land back at the front of their queues and are captured
        by ``state_dict`` like any other pending work, so restart
        replays them exactly once."""
        n = 0
        for sid, prep in list(self._staged.items()):
            s = self.sessions.get(sid)
            if s is not None:
                s.unstage(prep)
                n += 1
        self._staged.clear()
        self._plan = []
        if n:
            REGISTRY.counter("scheduler_quiesced_preps_total").inc(n)
        REGISTRY.gauge("scheduler_queue_depth").set(self.pending_windows)
        return n

    def drain(self, max_steps: int = 10_000) -> int:
        """Step until no session has pending windows; returns steps run."""
        n = 0
        while self.pending_windows and n < max_steps:
            self.step()
            n += 1
        return n

    def _run_batch(
        self,
        staged: dict[str, PreparedStep],
        order: list[MiningSession],
        next_plan: list[str],
    ):
        if self.batcher is None or len(order) == 1:
            out = {}
            for s in order:
                prep = staged[s.session_id]
                out[s.session_id] = s.commit(prep, s.execute(prep))
            return out
        results: dict[str, WindowDelta] = {}
        errors: list[Exception] = []
        next_set = set(next_plan)
        overlaps: list[float] = []

        def run_one(s: MiningSession):
            sid = s.session_id
            self.batcher.bind_session(sid)
            prep = staged[sid]
            try:
                # commit here, not after join: the prepare below must
                # snapshot a state that includes this window's delta
                results[sid] = s.commit(prep, s.execute(prep))
            except Exception as e:  # watchdog retries the whole batch
                errors.append(e)
            finally:
                self.batcher.end_step(sid)
            if sid in next_set and not errors:
                # double-buffer: this lane's device work has retired and
                # its step has left the batcher (co-tenant groups are not
                # gated on us), so prepare the next window while other
                # lanes still hold the device
                t0 = time.perf_counter()
                with span("schedule.stage", session=sid):
                    nprep = s.prepare(snapshot=self.policy.retry_snapshots)
                if nprep is not None:
                    self._staged[sid] = nprep
                    overlaps.append(time.perf_counter() - t0)

        width = self.policy.max_concurrent_lanes
        if width is None:
            width = max(2, os.cpu_count() or 1)
        lanes = self._affinity_order(order)
        for i in range(0, len(lanes), max(width, 1)):
            chunk = lanes[i:i + max(width, 1)]
            for s in chunk:  # register before any worker runs: no early
                self.batcher.begin_step(s.session_id)  # flush
            threads = [
                threading.Thread(target=run_one, args=(s,), daemon=True) for s in chunk
            ]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            if errors:  # fail fast: the watchdog retries the whole step
                break
        self.pipeline_overlap_s += sum(overlaps)
        if errors:
            raise errors[0]
        return results

    def _affinity_order(self, order: list[MiningSession]):
        """Lanes sorted so tenants predicted to park on the same flush
        groups are adjacent (stable sort: ties keep round-robin order).
        With bounded lane concurrency the batcher can only fuse lanes
        co-resident in a chunk — adjacency is what keeps shape groups
        filling instead of flushing as singletons. Cold sessions (no
        learned prediction yet) cluster by config shape instead."""
        def sig(s: MiningSession):
            learned = self.batcher.predicted_signature(s.session_id)
            if learned is not None:
                return ("0",) + learned
            c = s.config
            return ("1", c.engine, str(c.window_ms), str(c.max_level), str(c.intervals))
        return sorted(order, key=sig)
