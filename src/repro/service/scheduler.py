"""Admission control, backpressure, and round-robin fairness for the
multi-tenant mining service — with fault tolerance from ``runtime.ft``.

Policies, in the order a window meets them:

* **admission** — at most ``max_sessions`` live tenants; a new session is
  rejected (``AdmissionError``) rather than degrading everyone already
  admitted.
* **backpressure** — each session's ingest queue is capped at
  ``max_pending_windows``; a producer that outruns the miner gets a
  ``BackpressureError`` (the chip-side acquisition host is the right
  place to shed or spool — silently buffering unbounded windows is how
  real-time loops die).
* **fairness** — ``step()`` services up to ``max_batch_sessions`` sessions
  with pending work in round-robin order starting *after* the last tenant
  served, so a firehose session cannot starve a trickle session.
* **retry** — each batched step runs under ``runtime.ft.StepWatchdog``.
  Mining steps are stateful, so naive retry would double-count; the
  scheduler snapshots every chosen session's ``state_dict`` before the
  attempt and restores it on retry, making the step functionally pure in
  the watchdog's sense (same state in ⇒ same result out).
"""

from __future__ import annotations

import dataclasses
import threading
from collections import deque

from repro.core.events import EventStream
from repro.obs import REGISTRY, span
from repro.runtime.ft import StepFailure, StepWatchdog, WatchdogConfig

from .session import MiningSession, SessionConfig, WindowDelta


class AdmissionError(RuntimeError):
    """Service at tenant capacity — retry later or scale out."""


class BackpressureError(RuntimeError):
    """Session ingest queue full — producer must slow down or spool."""


@dataclasses.dataclass
class SchedulerPolicy:
    max_sessions: int = 64
    max_pending_windows: int = 8
    max_batch_sessions: int = 16
    # Pre-step state snapshots make retry sound but copy every chosen
    # session's machine state to host each step; disable to trade retry
    # capability (a failed step then surfaces as StepFailure immediately)
    # for a leaner hot path.
    retry_snapshots: bool = True
    watchdog: WatchdogConfig = dataclasses.field(
        default_factory=lambda: WatchdogConfig(min_deadline_s=60.0))


class RoundRobinScheduler:
    """Owns the session table and drives batched steps through the
    cross-session batcher (one worker thread per chosen session; the
    batcher's barrier fuses their scans into per-bucket vmapped calls)."""

    def __init__(self, policy: SchedulerPolicy | None = None, batcher=None):
        self.policy = policy or SchedulerPolicy()
        self.batcher = batcher
        self.sessions: dict[str, MiningSession] = {}
        self._rr: deque[str] = deque()  # round-robin service order
        self.watchdog = StepWatchdog(self.policy.watchdog)
        self.steps = 0

    # -------------------------------------------------------- admission

    def admit(self, session_id: str, config: SessionConfig) -> MiningSession:
        if session_id in self.sessions:
            REGISTRY.counter("scheduler_admission_rejected_total").inc()
            raise AdmissionError(f"session {session_id!r} already admitted")
        if len(self.sessions) >= self.policy.max_sessions:
            REGISTRY.counter("scheduler_admission_rejected_total").inc()
            raise AdmissionError(
                f"at capacity ({self.policy.max_sessions} sessions); "
                f"admission of {session_id!r} refused")
        s = MiningSession(session_id, config, executor=self.batcher)
        self.sessions[session_id] = s
        self._rr.append(session_id)
        REGISTRY.gauge("scheduler_sessions").set(len(self.sessions))
        return s

    def evict(self, session_id: str) -> MiningSession:
        s = self.sessions.pop(session_id)
        self._rr = deque(x for x in self._rr if x != session_id)
        REGISTRY.gauge("scheduler_sessions").set(len(self.sessions))
        return s

    # ------------------------------------------------------- ingestion

    def submit(self, session_id: str, window: EventStream,
               final: bool = False) -> None:
        s = self.sessions[session_id]
        if s.queue_depth >= self.policy.max_pending_windows:
            # the producer must shed or spool this window upstream —
            # count it: shed pressure is the service's earliest overload
            # signal and invisible in throughput numbers alone
            REGISTRY.counter("scheduler_backpressure_total").inc()
            REGISTRY.counter("scheduler_shed_windows_total",
                             session=session_id).inc()
            raise BackpressureError(
                f"session {session_id!r} queue at depth {s.queue_depth} "
                f"(cap {self.policy.max_pending_windows})")
        s.enqueue(window, final=final)
        REGISTRY.gauge("scheduler_queue_depth").set(self.pending_windows)

    @property
    def pending_windows(self) -> int:
        return sum(s.queue_depth for s in self.sessions.values())

    # --------------------------------------------------------- stepping

    def _choose(self) -> list[MiningSession]:
        """Round-robin scan starting after the last session served."""
        chosen = []
        for _ in range(len(self._rr)):
            sid = self._rr[0]
            self._rr.rotate(-1)
            s = self.sessions[sid]
            if s.queue_depth:
                chosen.append(s)
                if len(chosen) >= self.policy.max_batch_sessions:
                    break
        return chosen

    def step(self) -> dict[str, WindowDelta]:
        """Service one window for each chosen session (batched). Returns
        {session_id: delta}; empty when nothing is pending."""
        chosen = self._choose()
        if not chosen:
            return {}
        with span("schedule.step", step=self.steps, sessions=len(chosen)):
            out = self._step_chosen(chosen)
        REGISTRY.counter("scheduler_steps_total").inc()
        REGISTRY.gauge("scheduler_queue_depth").set(self.pending_windows)
        REGISTRY.gauge("scheduler_heartbeat_ts").set_now()
        return out

    def _step_chosen(self, chosen: list[MiningSession]):
        if not self.policy.retry_snapshots:
            def run_once():
                try:
                    return self._run_batch(chosen)
                except Exception as e:
                    raise StepFailure(
                        f"step {self.steps} failed and retry_snapshots is "
                        "off (no safe state to rewind to)") from e
            out = self.watchdog.run_step(self.steps, run_once)
            self.steps += 1
            return out
        with span("schedule.snapshot", sessions=len(chosen)):
            snapshots = {s.session_id: s.state_dict() for s in chosen}
            meter_marks = {s.session_id: len(s.meter.rows) for s in chosen}
        attempt = [0]

        def run_batch():
            if attempt[0]:  # retry: rewind every tenant to the snapshot
                REGISTRY.counter("scheduler_watchdog_retries_total").inc()
                for s in chosen:
                    # state_dict covers miner state + both queues (results
                    # from the failed attempt are dropped by the reload)
                    del s.meter.rows[meter_marks[s.session_id]:]
                    s.meter._t0 = None  # a failed step may never stop()
                    s.load_state_dict(snapshots[s.session_id])
            attempt[0] += 1
            return self._run_batch(chosen)

        out = self.watchdog.run_step(self.steps, run_batch)
        self.steps += 1
        return out

    def drain(self, max_steps: int = 10_000) -> int:
        """Step until no session has pending windows; returns steps run."""
        n = 0
        while self.pending_windows and n < max_steps:
            self.step()
            n += 1
        return n

    def _run_batch(self, chosen: list[MiningSession]):
        if self.batcher is None or len(chosen) == 1:
            return {s.session_id: s.step() for s in chosen}
        results: dict[str, WindowDelta] = {}
        errors: list[Exception] = []

        def run_one(s: MiningSession):
            try:
                results[s.session_id] = s.step()
            except Exception as e:  # watchdog retries the whole batch
                errors.append(e)
            finally:
                self.batcher.end_step()

        for _ in chosen:
            self.batcher.begin_step()
        threads = [threading.Thread(target=run_one, args=(s,), daemon=True)
                   for s in chosen]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if errors:
            raise errors[0]
        return results
