"""Fault-tolerant mining client: exactly-once ingest over a lossy wire.

``MiningClient`` is the producer-side half of the transport contract in
``wire.py``. It hides every transient failure mode the link can produce —
dropped/duplicated/truncated frames, severed connections, a server that
was SIGKILLed and restarted — behind a blocking API whose observable
behavior is: every submitted window is counted exactly once, and polled
deltas arrive exactly once, in window order.

The machinery:

* **Deadlines + retries.** Every RPC has a deadline; transport errors
  and timeouts trigger reconnect + retry with exponential backoff and
  decorrelated jitter (full-jitter would synchronize a fleet of array
  clients hammering a restarting server).
* **Monotonic sequence numbers.** Each ``submit`` gets ``seq = applied +
  1``. A retried batch whose first ACK was lost is deduplicated
  server-side; an ``OUT_OF_ORDER`` status rewinds the client's cursor to
  the server's expected seq.
* **Resend buffer + durability horizon.** Batches are buffered until the
  server reports them ``durable`` (covered by an on-disk checkpoint).
  After a server crash the restored ``applied`` may be behind what we
  submitted — everything past it is resent from the buffer, re-mined,
  and lands bit-identical.
* **Poll cursor.** Deltas are delivered at-least-once (the server keeps
  them cached until acknowledged via ``ack_through``); the client dedups
  by ``window_idx`` so the caller sees each window once.
"""

from __future__ import annotations

import random
import socket
import time

from repro.core.events import EventStream
from repro.obs import REGISTRY

from . import wire
from .session import SessionConfig
from .wire import (
    ConnectionClosed, Frame, FrameType, ProtocolError, Status, parse_address
)


class WireError(RuntimeError):
    """Typed server-side refusal (carries the ``Status`` code)."""

    def __init__(self, code: Status, detail: str = "", info: dict | None = None):
        super().__init__(f"{code.name}: {detail}")
        self.code = code
        self.detail = detail
        self.info = info or {}


class DeadlineExceeded(WireError):
    def __init__(self, detail: str):
        super().__init__(Status.INTERNAL, detail)


class MiningClient:
    """One session's producer endpoint. Not thread-safe (one array, one
    stream, one client — run several clients for several arrays).

    ``backoff_base``/``backoff_cap`` bound the reconnect schedule;
    ``rng_seed`` makes the jitter deterministic for tests.
    """

    # client request ids live far above any session batch seq so a
    # duplicated request frame can never collide with a batch in the
    # server's per-connection reply cache
    _REQ_BASE = 1 << 32

    def __init__(
        self,
        address: str,
        session_id: str,
        config: SessionConfig | None = None,
        *,
        deadline_s: float = 30.0,
        connect_timeout_s: float = 5.0,
        rpc_timeout_s: float = 5.0,
        backoff_base: float = 0.05,
        backoff_cap: float = 2.0,
        max_attempts: int = 64,
        rng_seed: int | None = None,
    ):
        self.address = address
        self.session_id = session_id
        self.config = config or SessionConfig()
        self.deadline_s = deadline_s
        self.connect_timeout_s = connect_timeout_s
        # per-attempt reply timeout: a dropped frame must cost one rpc
        # timeout and a retry, not the whole deadline
        self.rpc_timeout_s = rpc_timeout_s
        self.backoff_base = backoff_base
        self.backoff_cap = backoff_cap
        self.max_attempts = max_attempts
        self._rng = random.Random(rng_seed)
        self._sock: socket.socket | None = None
        self._req = self._REQ_BASE
        self.applied = 0  # highest seq the server has in memory
        self.durable = 0  # highest seq the server has on disk
        self.next_seq = 1
        self._resend: dict[int, tuple[bytes, bool]] = {}  # seq -> payload
        self._seen_windows: set[int] = set()
        self.deltas_received = 0
        self.reconnects = 0

    # ---------------------------------------------------------- transport

    def _connect(self) -> socket.socket:
        kind, target = parse_address(self.address)
        fam = socket.AF_UNIX if kind == "unix" else socket.AF_INET
        sock = socket.socket(fam, socket.SOCK_STREAM)
        sock.settimeout(self.connect_timeout_s)
        sock.connect(target)
        return sock

    def close(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    def _drop_connection(self) -> None:
        self.close()
        self.reconnects += 1
        REGISTRY.counter("client_reconnects_total").inc()

    def _backoff(self, attempt: int, deadline: float) -> None:
        # decorrelated jitter, capped, never sleeping past the deadline
        hi = min(self.backoff_cap, self.backoff_base * (2 ** attempt))
        delay = self._rng.uniform(0, hi)
        delay = min(delay, max(0.0, deadline - time.monotonic()))
        if delay > 0:
            time.sleep(delay)

    def _ensure_session(self, deadline: float) -> None:
        """(Re)connect and resynchronize: open/resume the session, learn
        the server's ``applied``/``durable`` horizons, and resend every
        buffered batch past ``applied`` (lost to a crash or to frames
        that never arrived)."""
        self._sock = self._connect()
        self._arm_timeout(deadline)
        reply = self._rpc_once(
            Frame(
                FrameType.OPEN_SESSION,
                self._next_req(),
                wire._j(
                    {
                        "session": self.session_id,
                        "config": wire.config_to_wire(self.config),
                    },
                ),
            ),
        )
        doc = wire._unj(reply.payload)
        self.applied = int(doc["applied"])
        self.durable = int(doc.get("durable", self.applied))
        self._trim_resend()
        for seq in sorted(self._resend):
            if seq <= self.applied:
                continue
            payload, _final = self._resend[seq]
            ack = self._rpc_once(Frame(FrameType.EVENT_BATCH, seq, payload))
            self._absorb_ack(ack)

    def _next_req(self) -> int:
        self._req += 1
        return self._req

    def _arm_timeout(self, deadline: float) -> None:
        self._sock.settimeout(
            max(0.05, min(self.rpc_timeout_s, deadline - time.monotonic()))
        )

    def _rpc_once(self, frame: Frame) -> Frame:
        """Send one frame and read its reply on the live socket. Raises
        transport errors through; raises ``WireError`` for STATUS replies
        (except DUPLICATE acks, which are success)."""
        self._sock.sendall(wire.encode_frame(frame))
        while True:
            reply = wire.read_frame(self._sock)
            # a reply to an earlier request (duplicated frame in flight,
            # or a retry racing its first attempt's reply) is stale: skip
            if reply.seq != frame.seq:
                REGISTRY.counter("client_stale_replies_total").inc()
                continue
            break
        if reply.ftype == FrameType.STATUS:
            doc = wire._unj(reply.payload)
            raise WireError(Status(doc["code"]), doc.get("detail", ""), doc)
        return reply

    def _rpc(self, make_frame, deadline_s: float | None = None) -> Frame:
        """At-least-once RPC with reconnect/backoff; the server's dedup
        layers make the composite exactly-once. ``make_frame()`` is
        called fresh per attempt so rewinds take effect."""
        deadline = time.monotonic() + (
            self.deadline_s if deadline_s is None else deadline_s
        )
        last = None
        for attempt in range(self.max_attempts):
            if time.monotonic() >= deadline:
                break
            try:
                if self._sock is None:
                    self._ensure_session(deadline)
                self._arm_timeout(deadline)
                return self._rpc_once(make_frame())
            except (ConnectionClosed, ProtocolError, OSError) as e:
                last = e
                self._drop_connection()
                self._backoff(attempt, deadline)
            except WireError as e:
                if e.code in (Status.BACKPRESSURE, Status.SHUTTING_DOWN):
                    # transient: wait out the queue / the restart
                    last = e
                    self._backoff(attempt, deadline)
                    if e.code == Status.SHUTTING_DOWN:
                        self._drop_connection()
                    continue
                if e.code == Status.OUT_OF_ORDER and "expect" in e.info:
                    # crash rewound the server; resync via reconnect
                    last = e
                    self._drop_connection()
                    continue
                raise
        raise DeadlineExceeded(
            f"RPC failed after {self.max_attempts} attempts / "
            f"{self.deadline_s}s: {last!r}")

    # ---------------------------------------------------------------- api

    def open(self) -> None:
        """Eagerly open/resume the session (otherwise lazy on first RPC)."""
        self._rpc(
            lambda: Frame(FrameType.CONTROL, self._next_req(), wire._j({"op": "ping"}))
        )

    def submit(self, window: EventStream, final: bool = False) -> int:
        """Ingest one partition window, exactly once, surviving any
        transient failure. Returns the batch's sequence number."""
        payload = wire.encode_events(self.session_id, window, final=final)
        seq = self.next_seq
        self._resend[seq] = (payload, final)
        ack = self._rpc(lambda: Frame(FrameType.EVENT_BATCH, seq, payload))
        self._absorb_ack(ack)
        self.next_seq = max(self.next_seq, seq) + 1
        return seq

    def _absorb_ack(self, ack: Frame) -> None:
        doc = wire._unj(ack.payload)
        self.applied = max(self.applied, int(doc["applied"]))
        self.durable = max(self.durable, int(doc.get("durable", 0)))
        self._trim_resend()

    def _trim_resend(self) -> None:
        # only durability releases a batch: an applied-but-uncheckpointed
        # window still dies with the server
        for seq in [s for s in self._resend if s <= self.durable]:
            del self._resend[seq]

    def poll(self, ack: bool = True) -> list[dict]:
        """Fetch mined window deltas; each window is returned exactly
        once across any number of retries/redeliveries."""
        reply = self._rpc(
            lambda: Frame(
                FrameType.POLL,
                self._next_req(),
                wire._j(
                    {
                        "session": self.session_id,
                        "ack_through": (
                            max(self._seen_windows) if ack and self._seen_windows else -1
                        ),
                    },
                ),
            ),
        )
        doc = wire._unj(reply.payload)
        self.applied = max(self.applied, int(doc.get("applied", 0)))
        self.durable = max(self.durable, int(doc.get("durable", 0)))
        self._trim_resend()
        fresh = []
        for d in doc["deltas"]:
            if d["window_idx"] in self._seen_windows:
                continue
            self._seen_windows.add(d["window_idx"])
            fresh.append(d)
        self.deltas_received += len(fresh)
        return fresh

    def drain(
        self, poll_interval_s: float = 0.01, deadline_s: float | None = None
    ) -> list[dict]:
        """Poll until every submitted window's delta has arrived."""
        deadline = time.monotonic() + (
            self.deadline_s if deadline_s is None else deadline_s
        )
        want = self.next_seq - 1
        out = []
        while True:
            out.extend(self.poll())
            if len(self._seen_windows) >= want:
                return out
            if time.monotonic() >= deadline:
                raise DeadlineExceeded(
                    f"drain: {len(self._seen_windows)}/{want} windows "
                    f"after {deadline_s or self.deadline_s}s")
            time.sleep(poll_interval_s)

    def stats(self) -> dict:
        reply = self._rpc(lambda: Frame(FrameType.STATS, self._next_req(), b""))
        return wire._unj(reply.payload)

    def control(self, op: str, deadline_s: float | None = None, **kw) -> dict:
        reply = self._rpc(
            lambda: Frame(FrameType.CONTROL, self._next_req(), wire._j({"op": op, **kw})),
            deadline_s=deadline_s,
        )
        return wire._unj(reply.payload)

    def ping(self) -> dict:
        return self.control("ping")

    def close_session(self) -> list[dict]:
        """Close the session server-side; returns any final deltas."""
        reply = self._rpc(
            lambda: Frame(
                FrameType.CLOSE_SESSION,
                self._next_req(),
                wire._j({"session": self.session_id}),
            ),
        )
        doc = wire._unj(reply.payload)
        fresh = [
            d for d in doc.get("deltas", []) if d["window_idx"] not in self._seen_windows
        ]
        for d in fresh:
            self._seen_windows.add(d["window_idx"])
        self.deltas_received += len(fresh)
        self.close()
        return fresh

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
