"""The multi-tenant real-time mining service: ingest/poll facade.

The chip-on-chip loop, generalized to a fleet: many electrode arrays (or
any event-emitting chips) stream partition windows in; the service mines
them concurrently on shared devices and emits per-window frequent-episode
deltas per session. The pieces:

* ``MiningSession`` (session.py) — per-tenant miner, bounded memory,
  checkpointable state;
* ``CrossSessionBatcher`` (batcher.py) — scans from concurrently stepping
  sessions fused into per-shape-bucket vmapped dispatches;
* ``RoundRobinScheduler`` (scheduler.py) — admission, backpressure,
  fairness, watchdog retry.

Guarantee: per-session outputs are bit-identical to a standalone
``StreamingMiner`` over the same windows — batching and scheduling are
pure throughput optimizations (tests/test_service.py asserts this for
every engine × two-pass combination).

Usage::

    svc = MiningService()
    svc.create_session("array-0", SessionConfig(theta=4, window_ms=2000))
    svc.ingest("array-0", window)          # may raise BackpressureError
    svc.pump()                             # run pending batched steps
    for delta in svc.poll("array-0"):
        ...                                # per-window episode deltas
"""

from __future__ import annotations

import itertools

from repro.core import calibrate
from repro.core.events import EventStream
from repro.obs import REGISTRY, span
from repro.obs.jaxprof import ensure_recompile_listener
from repro.telemetry import MeterBank

from .batcher import CrossSessionBatcher
from .scheduler import RoundRobinScheduler, SchedulerPolicy
from .session import MiningSession, SessionConfig, WindowDelta


class MiningService:
    def __init__(self, policy: SchedulerPolicy | None = None, batching: bool = True):
        policy = policy or SchedulerPolicy()
        if policy.policy_table:
            # install the calibrated dispatch table for this process;
            # a stale/wrong-device file degrades to the heuristic (the
            # outcome is visible in stats()["calibration"]["source"])
            calibrate.install_table(policy.policy_table)
        self.batcher = CrossSessionBatcher(
            fusion_gate=policy.fusion_gate, flush_deadline_s=policy.flush_deadline_s
        ) if batching else None
        self.scheduler = RoundRobinScheduler(policy, self.batcher)
        self._auto_ids = itertools.count()
        # recompilation is a serving SLO hazard (a shape-bucket miss mid-
        # stream stalls every fused tenant); count every one from the start
        ensure_recompile_listener()

    # --------------------------------------------------------- sessions

    def create_session(
        self, session_id: str | None = None, config: SessionConfig | None = None
    ) -> str:
        """Admit a tenant (raises ``AdmissionError`` at capacity)."""
        if session_id is None:
            session_id = f"session-{next(self._auto_ids)}"
        self.scheduler.admit(session_id, config or SessionConfig())
        return session_id

    def close_session(self, session_id: str) -> MiningSession:
        """Drain the session's remaining windows, then remove it."""
        s = self.scheduler.session(session_id)
        while s.queue_depth:
            self.scheduler.step()
        return self.scheduler.evict(session_id)

    def session(self, session_id: str) -> MiningSession:
        return self.scheduler.session(session_id)

    # ------------------------------------------------------ ingest/poll

    def ingest(self, session_id: str, window: EventStream, final: bool = False) -> None:
        """Queue one partition window (raises ``BackpressureError`` when
        the tenant's queue is full — shed or spool upstream)."""
        with span("service.ingest", session=session_id):
            self.scheduler.submit(session_id, window, final=final)

    def pump(self, max_steps: int | None = None) -> int:
        """Run batched scheduler steps until queues drain (or the step
        budget runs out). Returns steps run."""
        return self.scheduler.drain(max_steps=10_000 if max_steps is None else max_steps)

    def poll(self, session_id: str, max_items: int | None = None) -> list[WindowDelta]:
        """Per-window frequent-episode deltas mined since the last poll."""
        return self.scheduler.session(session_id).poll(max_items)

    # ------------------------------------------------------- durability

    def checkpoint_all(self, root, extra=None) -> dict:
        """Checkpoint every session's full state atomically to
        ``root/<session_id>/`` — after quiescing the pipeline.

        Ordering matters: with ``pipeline_depth > 1`` the scheduler may
        hold prepared-but-uncommitted next-step windows that live in
        neither a session's pending queue nor its miner state. They are
        unstaged *first* (``scheduler.quiesce``) so every checkpoint
        captures them as pending work — a restart replays each window
        exactly once, never zero times (lost) and never twice
        (double-counted). ``extra(session_id)`` may contribute
        transport-layer leaves (the wire server's dedup sequence number)
        to the same atomic snapshot. Returns {session_id: path}."""
        self.scheduler.quiesce()
        paths = {}
        with span("service.checkpoint", sessions=len(self.scheduler.sessions)):
            for sid, s in self.scheduler.sessions.items():
                paths[sid] = s.save(root, extra=None if extra is None else extra(sid))
                REGISTRY.counter("service_checkpoints_total").inc()
        return paths

    # ------------------------------------------------------------ stats

    def stats(self) -> dict:
        """Full service health snapshot.

        Per-session sustained events/sec + latency percentiles and the
        cross-session aggregate (the exact meter rows), plus the registry-
        backed operational counters: scheduler queue/heartbeat gauges,
        backpressure/shed/retry counts, batcher fusion and pad-waste
        counters, and the kernel plane's dispatch/fallback/recompile
        tallies. ``metrics`` is the full flat registry snapshot the
        structured fields are drawn from — one set of numbers, whether
        read here, from ``KERNEL_CALLS``, or from ``--metrics-out``."""
        from repro.kernels.tally import KERNEL_CALLS, fallback_counts

        bank = MeterBank()
        for sid, s in self.scheduler.sessions.items():
            bank.meters[sid] = s.meter
        out = bank.summary()
        out["scheduler"] = {
            "steps": self.scheduler.steps,
            "retries": self.scheduler.watchdog.retries,
            "watchdog_retries": int(
                REGISTRY.counter("scheduler_watchdog_retries_total").value
            ),
            "sessions": len(self.scheduler.sessions),
            "pending_windows": self.scheduler.pending_windows,
            "queue_depth": int(REGISTRY.gauge("scheduler_queue_depth").value),
            "heartbeat_ts": float(REGISTRY.gauge("scheduler_heartbeat_ts").value),
            "backpressure": int(REGISTRY.counter("scheduler_backpressure_total").value),
            "admission_rejected": int(
                REGISTRY.counter("scheduler_admission_rejected_total").value
            ),
            "pipeline_overlap_s": self.scheduler.pipeline_overlap_s,
        }
        if self.batcher is not None:
            out["batcher"] = {
                "batches": self.batcher.batches,
                "fused_requests": self.batcher.fused_requests,
                "pad_events": self.batcher.pad_events,
                "pad_lanes": self.batcher.pad_lanes,
                "split_groups": int(REGISTRY.counter("batcher_split_groups_total").value),
                "flush_groups": self.batcher.flush_groups,
                "deadline_flushes": self.batcher.deadline_flushes,
                "fusion_gate": dict(self.batcher.gate_decisions),
            }
        # dispatch-policy health: table provenance + per-engine decision
        # counts (dispatch_policy_total{engine=...,source=...})
        out["calibration"] = calibrate.policy_stats()
        out["wire"] = {
            "connections": int(REGISTRY.gauge("wire_connections").value),
            "connections_total": int(REGISTRY.counter("wire_connections_total").value),
            "frames_rx": int(REGISTRY.counter("wire_frames_total", dir="rx").value),
            "frames_tx": int(REGISTRY.counter("wire_frames_total", dir="tx").value),
            "bytes_rx": int(REGISTRY.counter("wire_bytes_total", dir="rx").value),
            "bytes_tx": int(REGISTRY.counter("wire_bytes_total", dir="tx").value),
            "backpressure": int(REGISTRY.counter("wire_backpressure_total").value),
            "dedup_hits": int(REGISTRY.counter("wire_dedup_hits_total").value),
            "out_of_order": int(REGISTRY.counter("wire_out_of_order_total").value),
            "errors": {
                labels.get("code", "?"): int(m.value)
                for labels, m in REGISTRY.family_items("wire_errors_total")
            },
        }
        out["recovery"] = {
            "cold_boots": int(REGISTRY.counter("recovery_boots_total").value),
            "sessions_restored": int(REGISTRY.counter("recovery_sessions_total").value),
            "windows_requeued": int(
                REGISTRY.counter("recovery_windows_requeued_total").value
            ),
            "checkpoints": int(REGISTRY.counter("service_checkpoints_total").value),
            "quiesced_preps": int(
                REGISTRY.counter("scheduler_quiesced_preps_total").value
            ),
        }
        out["daemon"] = {
            "heartbeat_ts": float(REGISTRY.gauge("daemon_heartbeat_ts").value),
            "uptime_s": float(REGISTRY.gauge("daemon_uptime_s").value),
        }
        out["kernel"] = {
            "calls": {
                k: v
                for k, v in sorted(KERNEL_CALLS.items())
                if not k.startswith("fallback:")
            },
            "fallbacks": fallback_counts(),
            "recompiles": {
                labels.get("kernel", "?"): m.value
                for labels, m in REGISTRY.family_items("recompiles")
            },
        }
        out["metrics"] = REGISTRY.snapshot()
        return out
