"""Long-running-service lifecycle for the wire-served mining service.

``MiningDaemon`` wraps a ``WireServer`` with the operational plumbing a
fleet deployment needs and the in-process demos never did:

* **pidfile** — a JSON record (pid, resolved listen address, data dir,
  start time) written atomically next to the data dir. ``status`` and
  ``stop`` resolve the daemon through it; a stale pidfile from a
  SIGKILLed process is detected (``os.kill(pid, 0)``) and cleaned up.
* **heartbeat thread** — feeds ``daemon_heartbeat_ts`` / ``daemon_
  uptime_s`` registry gauges every ``heartbeat_s``; they surface in
  ``MiningService.stats()["daemon"]`` so a monitor can alarm on a wedged
  pump without OS-level probes.
* **graceful drain** — SIGTERM (and the wire ``shutdown`` control op)
  trigger one ordered teardown: stop accepting work, quiesce staged
  uncommitted preps back to the pending queues (see
  ``MiningService.checkpoint_all`` for why the order matters), mine out
  the queues, checkpoint every session, then exit 0.
* **cold-boot recovery** — on start, ``WireServer.recover`` rebuilds
  every session named in the data dir's manifest from its newest
  complete checkpoint: miner state, pending windows, unpolled results,
  and the wire dedup horizon in one consistent cut.

Foreground use (tests, containers, process supervisors)::

    MiningDaemon(config).run()        # blocks until SIGTERM/shutdown

Detached use (the ``mine_serve --daemon`` CLI)::

    daemon.start_detached()           # double-fork, returns in parent
    MiningDaemon.status(pidfile)      # -> dict | None
    MiningDaemon.stop(pidfile)        # SIGTERM + wait
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import signal
import sys
import time
from pathlib import Path

from repro.obs import REGISTRY


@dataclasses.dataclass
class DaemonConfig:
    address: str = "127.0.0.1:0"
    data_dir: str = "serve-data"
    pidfile: str | None = None  # default: <data_dir>/daemon.pid
    checkpoint_every: int = 1
    keep_checkpoints: int = 2
    heartbeat_s: float = 0.5
    max_sessions: int = 64
    queue_depth: int = 8
    pipeline_depth: int = 2
    batching: bool = True
    policy_table: str | None = None  # calibrated dispatch table path
    crash_after_commits: int | None = None  # fault injection

    @property
    def pidfile_path(self) -> Path:
        return Path(self.pidfile if self.pidfile else Path(self.data_dir) / "daemon.pid")


class MiningDaemon:
    def __init__(self, config: DaemonConfig | None = None, service=None):
        from repro.service.server import MiningService
        from repro.service.scheduler import SchedulerPolicy
        from repro.service.wire import WireServer

        self.config = config or DaemonConfig()
        self.service = service or MiningService(
            policy=SchedulerPolicy(
                max_sessions=self.config.max_sessions,
                max_pending_windows=self.config.queue_depth,
                pipeline_depth=self.config.pipeline_depth,
                policy_table=self.config.policy_table,
            ),
            batching=self.config.batching,
        )
        self.server = WireServer(
            self.service,
            self.config.address,
            data_dir=self.config.data_dir,
            checkpoint_every=self.config.checkpoint_every,
            keep_checkpoints=self.config.keep_checkpoints,
            crash_after_commits=self.config.crash_after_commits,
        )
        self.started_at: float | None = None
        self._hb_thread = None

    # ----------------------------------------------------------- pidfile

    def _write_pidfile(self) -> None:
        p = self.config.pidfile_path
        p.parent.mkdir(parents=True, exist_ok=True)
        tmp = p.with_suffix(".pid.tmp")
        tmp.write_text(
            json.dumps(
                {
                    "pid": os.getpid(),
                    "address": self.server.address,
                    "data_dir": str(self.config.data_dir),
                    "started_at": self.started_at,
                },
                indent=1,
            ),
        )
        os.replace(tmp, p)

    @staticmethod
    def read_pidfile(pidfile: str | os.PathLike) -> dict | None:
        try:
            return json.loads(Path(pidfile).read_text())
        except (FileNotFoundError, json.JSONDecodeError):
            return None

    @staticmethod
    def status(pidfile: str | os.PathLike) -> dict | None:
        """The pidfile record if the daemon is alive, else None (stale
        pidfiles — a SIGKILLed daemon leaves one — are removed)."""
        doc = MiningDaemon.read_pidfile(pidfile)
        if doc is None:
            return None
        try:
            os.kill(doc["pid"], 0)
        except (ProcessLookupError, PermissionError):
            with contextlib.suppress(FileNotFoundError):
                Path(pidfile).unlink()
            return None
        return doc

    @staticmethod
    def stop(pidfile: str | os.PathLike, timeout_s: float = 60.0) -> bool:
        """SIGTERM the daemon behind ``pidfile`` and wait for a graceful
        exit (drain + checkpoint happen in its handler). True if it
        stopped (or was already gone)."""
        doc = MiningDaemon.status(pidfile)
        if doc is None:
            return True
        os.kill(doc["pid"], signal.SIGTERM)
        deadline = time.monotonic() + timeout_s
        while time.monotonic() < deadline:
            if MiningDaemon.status(pidfile) is None:
                return True
            time.sleep(0.05)
        return False

    # --------------------------------------------------------- lifecycle

    def _heartbeat_loop(self) -> None:
        while not self.server.stop_requested:
            REGISTRY.gauge("daemon_heartbeat_ts").set(time.time())
            REGISTRY.gauge("daemon_uptime_s").set(time.time() - self.started_at)
            self.server.wait_stop(self.config.heartbeat_s)

    def run(self) -> None:
        """Foreground daemon: start, serve, block until SIGTERM or a wire
        ``shutdown`` op, then drain + checkpoint + exit."""
        import threading

        self.started_at = time.time()
        addr = self.server.start()
        self._write_pidfile()
        signal.signal(signal.SIGTERM, lambda *_: self.server._stop.set())
        signal.signal(signal.SIGINT, lambda *_: self.server._stop.set())
        self._hb_thread = threading.Thread(
            target=self._heartbeat_loop, daemon=True, name="daemon-hb"
        )
        self._hb_thread.start()
        print(
            f"[daemon] serving on {addr} "
            f"(data: {self.config.data_dir}, pid {os.getpid()})",
            flush=True,
        )
        self.server.wait_stop()
        print("[daemon] draining...", flush=True)
        self.server.shutdown(drain=True)
        with contextlib.suppress(FileNotFoundError):
            self.config.pidfile_path.unlink()
        print("[daemon] stopped.", flush=True)

    def start_detached(self, ready_timeout_s: float = 120.0) -> dict:
        """Double-fork + exec detach: the grandchild re-execs a *fresh*
        interpreter running ``python -m repro.service.daemon`` (forking a
        process with an initialized jax runtime copies locked XLA
        thread-pool mutexes — exec sidesteps that). The parent returns
        the pidfile record once the daemon has bound its socket (jax
        import makes cold starts slow — generous timeout)."""
        pidpath = self.config.pidfile_path
        with contextlib.suppress(FileNotFoundError):
            pidpath.unlink()
        cfg = self.config
        argv = [sys.executable, "-m", "repro.service.daemon",
                "--listen", cfg.address, "--data-dir", str(cfg.data_dir),
                "--checkpoint-every", str(cfg.checkpoint_every),
                "--keep-checkpoints", str(cfg.keep_checkpoints),
                "--queue-depth", str(cfg.queue_depth),
                "--max-sessions", str(cfg.max_sessions),
                "--pipeline-depth", str(cfg.pipeline_depth)]
        if cfg.pidfile:
            argv += ["--pidfile", str(cfg.pidfile)]
        if cfg.policy_table:
            argv += ["--policy-table", str(cfg.policy_table)]
        if cfg.crash_after_commits is not None:
            argv += ["--crash-after-commits", str(cfg.crash_after_commits)]
        pid = os.fork()
        if pid == 0:
            os.setsid()
            if os.fork() > 0:
                os._exit(0)
            devnull = os.open(os.devnull, os.O_RDWR)
            os.dup2(devnull, 0)
            Path(cfg.data_dir).mkdir(parents=True, exist_ok=True)
            log = os.open(
                str(Path(cfg.data_dir) / "daemon.log"),
                os.O_WRONLY | os.O_CREAT | os.O_APPEND,
                0o644,
            )
            os.dup2(log, 1)
            os.dup2(log, 2)
            env = dict(os.environ)
            src = str(Path(__file__).resolve().parents[2])
            env["PYTHONPATH"] = src + (
                os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
            )
            os.execve(sys.executable, argv, env)
        os.waitpid(pid, 0)  # reap the intermediate
        deadline = time.monotonic() + ready_timeout_s
        while time.monotonic() < deadline:
            doc = MiningDaemon.read_pidfile(pidpath)
            if doc and doc.get("address"):
                return doc
            time.sleep(0.05)
        raise TimeoutError(
            f"daemon did not become ready within {ready_timeout_s}s "
            f"(see {Path(cfg.data_dir) / 'daemon.log'})")


def serve_foreground(config: DaemonConfig) -> None:
    """Entry point used by ``python -m repro.service.daemon`` and the
    fault-injection harness's re-exec'd server processes."""
    MiningDaemon(config).run()


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(description="Run the wire-served mining daemon.")
    ap.add_argument(
        "--listen", default="127.0.0.1:0", help='"host:port" or "unix:/path/to.sock"'
    )
    ap.add_argument("--data-dir", default="serve-data")
    ap.add_argument("--pidfile", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=1)
    ap.add_argument("--keep-checkpoints", type=int, default=2)
    ap.add_argument("--queue-depth", type=int, default=8)
    ap.add_argument("--max-sessions", type=int, default=64)
    ap.add_argument("--pipeline-depth", type=int, default=2)
    ap.add_argument(
        "--policy-table",
        default=None,
        metavar="PATH",
        help="calibrated dispatch table to install " "(core.calibrate)",
    )
    ap.add_argument(
        "--crash-after-commits",
        type=int,
        default=None,
        help="fault injection: SIGKILL self after N commits",
    )
    args = ap.parse_args(argv)
    serve_foreground(
        DaemonConfig(
            address=args.listen,
            data_dir=args.data_dir,
            pidfile=args.pidfile,
            checkpoint_every=args.checkpoint_every,
            keep_checkpoints=args.keep_checkpoints,
            queue_depth=args.queue_depth,
            max_sessions=args.max_sessions,
            pipeline_depth=args.pipeline_depth,
            policy_table=args.policy_table,
            crash_after_commits=args.crash_after_commits,
        ),
    )


if __name__ == "__main__":
    sys.exit(main())
