"""Fault-tolerant wire transport for the mining service.

The paper's chip-on-chip loop puts the acquisition hardware (the MEA)
and the miner (the GPGPU) on one board; at fleet scale they are
different *machines*, and the link between them is a failure domain the
in-process ``MiningService`` never had. This module is the networked
front: a length-prefixed binary frame protocol over TCP or Unix-domain
sockets, and ``WireServer`` — the server loop that makes disconnects,
crashes, and restarts invisible to the counts.

Framing (all integers big-endian)::

    offset  size  field
    0       4     magic     0x46454D31 ("FEM1")
    4       1     version   PROTO_VERSION (1)
    5       1     type      FrameType
    6       2     flags     reserved (0)
    8       8     seq       session sequence (EVENT_BATCH) / request id
    16      4     length    payload bytes (<= MAX_PAYLOAD)
    20      4     crc32     zlib.crc32 of the payload
    24      ...   payload

Control/stats payloads are JSON; event batches are a packed binary
record (see ``encode_events``). Every frame is CRC-checked; a torn or
mutated frame yields a typed ``STATUS`` reply (``BAD_FRAME`` /
``BAD_CRC`` / ``BAD_VERSION``) — never a crashed server thread, and
never a silent drop.

Exactly-once ingest: each session's batches carry a client-assigned
monotonic sequence number starting at 1. The server applies ``seq ==
applied + 1`` only; a replayed batch (retry after a lost ACK) is
acknowledged without re-applying (``wire_dedup_hits_total``), and a gap
is refused with ``OUT_OF_ORDER`` so the client rewinds. The ACK carries
both ``applied`` (in memory) and ``durable`` (checkpointed): the
sequence horizon is saved as a ``wire/last_seq`` leaf *inside* the
session's atomic checkpoint, so after a crash the restored mining state
and the restored dedup horizon cannot disagree — the client resends
everything past ``durable`` and the re-mined windows are bit-identical.

Backpressure and shed decisions travel as typed status codes
(``Status.BACKPRESSURE`` with the queue depth) instead of silent drops,
and are counted (``wire_backpressure_total``) next to the scheduler's
own shed counters in ``MiningService.stats()``.
"""

from __future__ import annotations

import dataclasses
import enum
import json
import os
import signal
import socket
import struct
import threading
import time
import zlib
from pathlib import Path

import numpy as np

from repro.checkpoint import ckpt
from repro.core.events import EventStream
from repro.obs import REGISTRY, span

from .scheduler import AdmissionError, BackpressureError, UnknownSessionError
from .session import SessionConfig, WindowDelta

MAGIC = 0x46454D31  # "FEM1": Frequent Episode Mining, wire v1
PROTO_VERSION = 1
MAX_PAYLOAD = 16 << 20
HEADER = struct.Struct("!IBBHQII")
_EVENTS_HEAD = struct.Struct("!HIIB")


class FrameType(enum.IntEnum):
    HELLO = 1
    HELLO_OK = 2
    OPEN_SESSION = 3
    SESSION_OK = 4
    CLOSE_SESSION = 5
    EVENT_BATCH = 6
    ACK = 7
    POLL = 8
    DELTAS = 9
    STATS = 10
    STATS_OK = 11
    CONTROL = 12
    CONTROL_OK = 13
    STATUS = 14


class Status(enum.IntEnum):
    """Machine-readable status codes carried by STATUS frames."""

    OK = 0
    BACKPRESSURE = 1  # session queue full: slow down or spool
    SHED = 2  # window refused and not queued anywhere
    UNKNOWN_SESSION = 3  # never admitted, or already evicted
    ADMISSION_REJECTED = 4  # service at tenant capacity
    BAD_FRAME = 5  # malformed frame or payload
    BAD_CRC = 6  # payload CRC mismatch
    BAD_VERSION = 7  # protocol version not supported
    OUT_OF_ORDER = 8  # sequence gap: client must rewind
    DUPLICATE = 9  # batch already applied (informational)
    CONFIG_CONFLICT = 10  # session exists with a different config
    SESSION_CLOSED = 11  # final batch already ingested
    SHUTTING_DOWN = 12  # server draining: reconnect after restart
    INTERNAL = 13  # unexpected server-side failure


class ProtocolError(RuntimeError):
    """Malformed wire data. ``code`` is the typed status the server
    reports; ``fatal`` marks the byte stream as unsynchronized (framing
    broken — the connection must close; a payload-level error keeps it)."""

    code = Status.BAD_FRAME
    fatal = False


class BadMagic(ProtocolError):
    fatal = True


class BadCrc(ProtocolError):
    code = Status.BAD_CRC
    fatal = True


class BadVersion(ProtocolError):
    code = Status.BAD_VERSION
    fatal = True


class FrameTooLarge(ProtocolError):
    fatal = True


class ConnectionClosed(RuntimeError):
    """Peer went away (EOF mid-frame or clean close)."""


@dataclasses.dataclass(frozen=True)
class Frame:
    ftype: int
    seq: int
    payload: bytes = b""
    flags: int = 0


def encode_frame(frame: Frame) -> bytes:
    if len(frame.payload) > MAX_PAYLOAD:
        raise FrameTooLarge(f"payload {len(frame.payload)} > {MAX_PAYLOAD}")
    head = HEADER.pack(
        MAGIC,
        PROTO_VERSION,
        int(frame.ftype),
        frame.flags,
        frame.seq,
        len(frame.payload),
        zlib.crc32(frame.payload) & 0xFFFFFFFF,
    )
    return head + frame.payload


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise ConnectionClosed(f"EOF after {len(buf)}/{n} bytes")
        buf.extend(chunk)
    return bytes(buf)


def read_frame(sock: socket.socket) -> Frame:
    """Read one frame off a socket; raises a typed ``ProtocolError`` on
    malformed data and ``ConnectionClosed`` on EOF."""
    head = _recv_exact(sock, HEADER.size)
    magic, version, ftype, flags, seq, length, crc = HEADER.unpack(head)
    if magic != MAGIC:
        raise BadMagic(f"bad magic {magic:#010x}")
    if version != PROTO_VERSION:
        raise BadVersion(f"unsupported protocol version {version}")
    if length > MAX_PAYLOAD:
        raise FrameTooLarge(f"payload {length} > {MAX_PAYLOAD}")
    payload = _recv_exact(sock, length) if length else b""
    if zlib.crc32(payload) & 0xFFFFFFFF != crc:
        raise BadCrc(f"payload CRC mismatch on frame type {ftype}")
    return Frame(ftype, seq, payload, flags)


# ------------------------------------------------------------- payloads


def _j(obj) -> bytes:
    return json.dumps(obj, sort_keys=True).encode()


def _unj(payload: bytes):
    try:
        return json.loads(payload.decode())
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise ProtocolError(f"bad JSON payload: {e}") from None


def encode_events(session_id: str, stream: EventStream, final: bool = False) -> bytes:
    """EVENT_BATCH payload: session id + the window's raw int32 arrays."""
    sid = session_id.encode()
    n = int(stream.types.shape[0])
    return (_EVENTS_HEAD.pack(len(sid), n, stream.num_types, int(final))
            + sid
            + np.ascontiguousarray(stream.types, "<i4").tobytes()
            + np.ascontiguousarray(stream.times, "<i4").tobytes())


def decode_events(payload: bytes) -> tuple[str, EventStream, bool]:
    if len(payload) < _EVENTS_HEAD.size:
        raise ProtocolError("event batch shorter than its header")
    sid_len, n, num_types, final = _EVENTS_HEAD.unpack_from(payload)
    want = _EVENTS_HEAD.size + sid_len + 8 * n
    if len(payload) != want:
        raise ProtocolError(f"event batch length {len(payload)} != expected {want}")
    off = _EVENTS_HEAD.size
    try:
        sid = payload[off:off + sid_len].decode()
    except UnicodeDecodeError as e:
        raise ProtocolError(f"bad session id: {e}") from None
    off += sid_len
    types = np.frombuffer(payload, "<i4", count=n, offset=off)
    times = np.frombuffer(payload, "<i4", count=n, offset=off + 4 * n)
    try:
        stream = EventStream(types.copy(), times.copy(), num_types)
    except ValueError as e:
        raise ProtocolError(f"invalid event stream: {e}") from None
    return sid, stream, bool(final)


def config_to_wire(cfg: SessionConfig) -> dict:
    return dataclasses.asdict(cfg)


def config_from_wire(d: dict) -> SessionConfig:
    """Rebuild a ``SessionConfig`` normalizing JSON's list/tuple drift —
    the checkpoint config fingerprint is ``repr``-based, so a round-trip
    through the wire (or the sessions manifest) must reproduce the exact
    dataclass, tuples included."""
    fields = {f.name for f in dataclasses.fields(SessionConfig)}
    unknown = set(d) - fields
    if unknown:
        raise ProtocolError(f"unknown session config fields {sorted(unknown)}")
    kw = dict(d)
    if "intervals" in kw:
        try:
            kw["intervals"] = tuple(tuple(int(x) for x in iv) for iv in kw["intervals"])
        except (TypeError, ValueError) as e:
            raise ProtocolError(f"bad intervals: {e}") from None
    try:
        return SessionConfig(**kw)
    except (TypeError, ValueError) as e:
        raise ProtocolError(f"bad session config: {e}") from None


def delta_payload(d: WindowDelta) -> dict:
    """The wire-facing form of one mined window — also what the load
    generator's ``--verify`` computes locally, so the wire codec and the
    verification codec cannot drift."""
    return {
        "window_idx": int(d.window_idx),
        "n_events": int(d.n_events),
        "final": bool(d.final),
        "episodes": [[list(et), int(c)] for et, c in d.episodes()],
    }


def _jsonify(obj):
    """Best-effort JSON coercion for stats snapshots (numpy scalars and
    arrays show up in meter rows and registry families)."""
    if isinstance(obj, dict):
        return {str(k): _jsonify(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonify(v) for v in obj]
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    return repr(obj)


def parse_address(address) -> tuple[str, object]:
    """``"host:port"`` | ``"unix:/path"`` | ``(host, port)`` →
    ``("tcp", (host, port))`` or ``("unix", path)``."""
    if isinstance(address, (tuple, list)):
        return "tcp", (str(address[0]), int(address[1]))
    if address.startswith("unix:"):
        return "unix", address[5:]
    host, _, port = address.rpartition(":")
    if not host or not port.isdigit():
        raise ValueError(f"address {address!r} is not host:port or unix:path")
    return "tcp", (host, int(port))


# --------------------------------------------------------------- server


@dataclasses.dataclass
class WireSessionState:
    """Transport-side per-session state: the exactly-once horizon and the
    at-least-once delivery cache. ``applied`` is the highest batch seq in
    the live mining state; ``durable`` the highest covered by an on-disk
    checkpoint (what survives SIGKILL). ``delta_cache`` holds delivered-
    but-unacknowledged poll results so a reply lost to a dropped
    connection is re-delivered on the next poll (clients dedup by
    ``window_idx``)."""

    config: SessionConfig
    applied: int = 0
    durable: int = 0
    delta_cache: list = dataclasses.field(default_factory=list)


class WireServer:
    """Socket front for a ``MiningService``: one reader thread per
    connection, one pump thread mining pending windows and checkpointing
    every ``checkpoint_every`` steps. All service access is serialized
    under one lock — the wire layer adds fault tolerance, not a second
    scheduler.

    ``crash_after_commits`` is the fault-injection hook: the process
    SIGKILLs itself the moment total committed windows reach the given
    count — after the commit, *before* the checkpoint, the exact spot
    where a naive transport double-counts or loses windows on restart.
    """

    def __init__(
        self,
        service,
        address: str = "127.0.0.1:0",
        *,
        data_dir: str | os.PathLike | None = None,
        checkpoint_every: int = 1,
        keep_checkpoints: int = 2,
        pump_interval_s: float = 0.002,
        auto_pump: bool = True,
        crash_after_commits: int | None = None,
    ):
        self.service = service
        self._requested_address = address
        self.data_dir = Path(data_dir) if data_dir is not None else None
        self.checkpoint_every = checkpoint_every
        self.keep_checkpoints = keep_checkpoints
        self.pump_interval_s = pump_interval_s
        self.auto_pump = auto_pump
        self.crash_after_commits = crash_after_commits
        self.sessions: dict[str, WireSessionState] = {}
        self.commits = 0
        self.draining = False
        self.unexpected: list[str] = []  # handler bugs; fuzz asserts empty
        self.address: str | None = None
        self._lock = threading.RLock()
        self._listener: socket.socket | None = None
        self._conns: set[socket.socket] = set()
        self._threads: list[threading.Thread] = []
        self._stop = threading.Event()
        self._running = False
        self._steps_since_ckpt = 0

    # ---------------------------------------------------------- lifecycle

    def start(self) -> str:
        """Bind, recover from the data dir if present, and serve. Returns
        the bound address (resolved port for ``host:0``)."""
        kind, target = parse_address(self._requested_address)
        if kind == "unix":
            if os.path.exists(target):
                os.unlink(target)  # stale socket from a crashed server
            sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            sock.bind(target)
            self.address = f"unix:{target}"
        else:
            sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
            sock.bind(target)
            host, port = sock.getsockname()[:2]
            self.address = f"{host}:{port}"
        sock.listen(64)
        self._listener = sock
        if self.data_dir is not None:
            self.recover()
        self._running = True
        t = threading.Thread(target=self._accept_loop, daemon=True, name="wire-accept")
        t.start()
        self._threads.append(t)
        if self.auto_pump:
            t = threading.Thread(target=self._pump_loop, daemon=True, name="wire-pump")
            t.start()
            self._threads.append(t)
        return self.address

    def shutdown(self, drain: bool = True) -> None:
        """Graceful stop: refuse new windows (``SHUTTING_DOWN``), mine
        what is queued, quiesce staged preps, checkpoint every session,
        then tear the sockets down. SIGKILL can interrupt any point of
        this — that is what the checkpoints are for."""
        self.draining = True
        if self._listener is not None:
            try:
                self._listener.close()
            except OSError:
                pass
        with self._lock:
            if drain:
                with span("daemon.drain", pending=self.service.scheduler.pending_windows):
                    self.service.scheduler.drain()
            if self.data_dir is not None:
                self._checkpoint_locked()
                self._write_manifest_locked()
        self._running = False
        self._stop.set()
        for conn in list(self._conns):
            try:
                conn.close()
            except OSError:
                pass
        for t in self._threads:
            t.join(timeout=5.0)
        self._threads.clear()

    # ----------------------------------------------------------- recovery

    def recover(self) -> int:
        """Cold-boot recovery: rebuild every session named by the
        sessions manifest from its newest complete checkpoint, restoring
        the mining state, the pending queue, the unpolled results, and
        the wire dedup horizon in one consistent cut. Returns sessions
        restored."""
        manifest = self.data_dir / "SESSIONS.json"
        if not manifest.exists():
            return 0
        doc = json.loads(manifest.read_text())
        restored = 0
        with span("wire.recover", sessions=len(doc.get("sessions", {}))):
            for sid, cfgd in sorted(doc.get("sessions", {}).items()):
                cfg = config_from_wire(cfgd)
                self.service.create_session(sid, cfg)
                s = self.service.session(sid)
                applied = 0
                step = ckpt.latest_step(self.data_dir / sid)
                if step is not None:
                    s.restore(self.data_dir, step=step)
                    applied = int(
                        ckpt.read_leaf(
                            self.data_dir / sid, "wire/last_seq", step=step, default=0
                        ),
                    )
                    REGISTRY.counter("recovery_windows_requeued_total").inc(
                        len(s.pending)
                    )
                self.sessions[sid] = WireSessionState(
                    config=cfg, applied=applied, durable=applied
                )
                REGISTRY.counter("recovery_sessions_total").inc()
                restored += 1
        REGISTRY.counter("recovery_boots_total").inc()
        return restored

    def _write_manifest_locked(self) -> None:
        if self.data_dir is None:
            return
        self.data_dir.mkdir(parents=True, exist_ok=True)
        doc = {
            "sessions": {
                sid: config_to_wire(st.config) for sid, st in self.sessions.items()
            },
        }
        tmp = self.data_dir / "SESSIONS.json.tmp"
        tmp.write_text(json.dumps(doc, indent=1, sort_keys=True))
        os.replace(tmp, self.data_dir / "SESSIONS.json")

    def _checkpoint_locked(self) -> None:
        if self.data_dir is None:
            return
        snap = {sid: st.applied for sid, st in self.sessions.items()}
        self.service.checkpoint_all(
            self.data_dir,
            extra=lambda sid: {"wire/last_seq": np.asarray(snap.get(sid, 0), np.int64)},
        )
        for sid, seq in snap.items():
            if sid in self.service.scheduler.sessions:
                self.sessions[sid].durable = seq
                ckpt.prune(self.data_dir / sid, keep=self.keep_checkpoints)
        self._steps_since_ckpt = 0

    # --------------------------------------------------------------- pump

    def pump_once(self) -> bool:
        """One scheduler step (if work is pending) + the crash hook + the
        checkpoint cadence. Returns whether a step ran."""
        with self._lock:
            if not self.service.scheduler.pending_windows:
                return False
            before = sum(s.windows_done for s in self.service.scheduler.sessions.values())
            self.service.scheduler.step()
            after = sum(s.windows_done for s in self.service.scheduler.sessions.values())
            self.commits += max(0, after - before)
            if (self.crash_after_commits is not None
                    and self.commits >= self.crash_after_commits):
                # fault injection: die at a window-commit boundary,
                # after the commit and before the checkpoint — a real
                # SIGKILL, no cleanup, no atexit
                os.kill(os.getpid(), signal.SIGKILL)
            self._steps_since_ckpt += 1
            if (self.data_dir is not None and self.checkpoint_every
                    and self._steps_since_ckpt >= self.checkpoint_every):
                self._checkpoint_locked()
            return True

    def _pump_loop(self) -> None:
        while self._running:
            try:
                if not self.pump_once():
                    self._stop.wait(self.pump_interval_s)
            except Exception as e:  # noqa: BLE001 — keep serving
                self.unexpected.append(f"pump: {e!r}")
                self._stop.wait(self.pump_interval_s)

    # -------------------------------------------------------- connections

    def _accept_loop(self) -> None:
        while self._running or not self._stop.is_set():
            try:
                conn, _ = self._listener.accept()
            except OSError:
                return  # listener closed: shutdown
            REGISTRY.gauge("wire_connections").inc(1)
            REGISTRY.counter("wire_connections_total").inc()
            self._conns.add(conn)
            t = threading.Thread(
                target=self._serve_conn, args=(conn,), daemon=True, name="wire-conn"
            )
            t.start()

    def _send(self, conn: socket.socket, frames: list[Frame]) -> None:
        for f in frames:
            raw = encode_frame(f)
            conn.sendall(raw)
            REGISTRY.counter("wire_frames_total", dir="tx").inc()
            REGISTRY.counter("wire_bytes_total", dir="tx").inc(len(raw))

    def _serve_conn(self, conn: socket.socket) -> None:
        # single-entry reply cache: at-most-once execution for a frame
        # duplicated in flight (POLL is not idempotent — re-executing it
        # would drop deltas into a reply the client discards as stale)
        last_key, last_replies = None, None
        try:
            while True:
                try:
                    frame = read_frame(conn)
                except ConnectionClosed:
                    return
                except ProtocolError as e:
                    REGISTRY.counter("wire_errors_total", code=e.code.name.lower()).inc()
                    try:
                        self._send(conn, [self._status(0, e.code, str(e))])
                    except OSError:
                        pass
                    return  # stream unsynchronized: close
                except OSError:
                    return
                REGISTRY.counter("wire_frames_total", dir="rx").inc()
                REGISTRY.counter("wire_bytes_total", dir="rx").inc(
                    HEADER.size + len(frame.payload)
                )
                key = (frame.ftype, frame.seq)
                if key == last_key and last_replies is not None:
                    REGISTRY.counter("wire_rpc_replays_total").inc()
                    self._send(conn, last_replies)
                    continue
                try:
                    replies = self._handle(frame)
                except ProtocolError as e:  # payload-level: stream intact
                    REGISTRY.counter("wire_errors_total", code=e.code.name.lower()).inc()
                    replies = [self._status(frame.seq, e.code, str(e))]
                    if e.fatal:
                        self._send(conn, replies)
                        return
                except Exception as e:  # noqa: BLE001 — typed, not torn
                    name = (FrameType(frame.ftype).name
                            if frame.ftype in FrameType._value2member_map_
                            else str(frame.ftype))
                    self.unexpected.append(f"{name}: {e!r}")
                    REGISTRY.counter("wire_errors_total", code="internal").inc()
                    replies = [self._status(frame.seq, Status.INTERNAL, repr(e))]
                self._send(conn, replies)
                # cache only success replies: a BACKPRESSURE retry of the
                # same seq must re-execute against the drained queue
                if any(f.ftype == FrameType.STATUS for f in replies):
                    last_key, last_replies = None, None
                else:
                    last_key, last_replies = key, replies
        except OSError:
            return
        finally:
            self._conns.discard(conn)
            try:
                conn.close()
            except OSError:
                pass
            REGISTRY.gauge("wire_connections").inc(-1)

    # ------------------------------------------------------------ handlers

    @staticmethod
    def _status(seq: int, code: Status, detail: str = "", **extra) -> Frame:
        return Frame(
            FrameType.STATUS,
            seq,
            _j({"code": int(code), "code_name": code.name, "detail": detail, **extra}),
        )

    def _handle(self, frame: Frame) -> list[Frame]:
        ftype = frame.ftype
        if ftype == FrameType.HELLO:
            with self._lock:
                return [
                    Frame(
                        FrameType.HELLO_OK,
                        frame.seq,
                        _j(
                            {
                                "version": PROTO_VERSION,
                                "draining": self.draining,
                                "sessions": {
                                    sid: st.applied for sid, st in self.sessions.items()
                                },
                            }
                        ),
                    )
                ]
        if ftype == FrameType.OPEN_SESSION:
            return self._handle_open(frame)
        if ftype == FrameType.CLOSE_SESSION:
            return self._handle_close(frame)
        if ftype == FrameType.EVENT_BATCH:
            return self._handle_batch(frame)
        if ftype == FrameType.POLL:
            return self._handle_poll(frame)
        if ftype == FrameType.STATS:
            with self._lock:
                stats = _jsonify(self.service.stats())
            return [Frame(FrameType.STATS_OK, frame.seq, _j(stats))]
        if ftype == FrameType.CONTROL:
            return self._handle_control(frame)
        raise ProtocolError(f"unknown frame type {ftype}")

    def _handle_open(self, frame: Frame) -> list[Frame]:
        doc = _unj(frame.payload)
        sid = doc.get("session")
        if not isinstance(sid, str) or not sid:
            raise ProtocolError("open_session: missing session id")
        cfg = config_from_wire(doc.get("config") or {})
        with self._lock:
            st = self.sessions.get(sid)
            if st is not None:
                if (ckpt.config_fingerprint(st.config) != ckpt.config_fingerprint(cfg)):
                    return [self._status(
                        frame.seq, Status.CONFIG_CONFLICT,
                        f"session {sid!r} exists with a different config")]
                return [Frame(FrameType.SESSION_OK, frame.seq, _j({
                    "session": sid, "applied": st.applied,
                    "durable": st.durable, "resumed": True}))]
            if self.draining:
                return [self._status(frame.seq, Status.SHUTTING_DOWN,
                                     "server is draining")]
            try:
                self.service.create_session(sid, cfg)
            except AdmissionError as e:
                return [self._status(frame.seq, Status.ADMISSION_REJECTED, str(e))]
            self.sessions[sid] = WireSessionState(config=cfg)
            self._write_manifest_locked()
            return [Frame(FrameType.SESSION_OK, frame.seq, _j({
                "session": sid, "applied": 0, "durable": 0,
                "resumed": False}))]

    def _handle_close(self, frame: Frame) -> list[Frame]:
        doc = _unj(frame.payload)
        sid = doc.get("session")
        with self._lock:
            st = self.sessions.get(sid)
            if st is None:
                return [
                    self._status(
                        frame.seq, Status.UNKNOWN_SESSION, f"unknown session {sid!r}"
                    )
                ]
            s = self.service.close_session(sid)
            deltas = st.delta_cache + [delta_payload(d) for d in s.poll()]
            del self.sessions[sid]
            self._write_manifest_locked()
            return [Frame(FrameType.SESSION_OK, frame.seq, _j({
                "session": sid, "applied": st.applied, "deltas": deltas,
                "closed": True}))]

    def _handle_batch(self, frame: Frame) -> list[Frame]:
        sid, stream, final = decode_events(frame.payload)
        seq = frame.seq
        with self._lock, span("wire.ingest", session=sid, seq=seq):
            st = self.sessions.get(sid)
            if st is None:
                return [
                    self._status(seq, Status.UNKNOWN_SESSION, f"unknown session {sid!r}")
                ]
            if seq <= st.applied:
                REGISTRY.counter("wire_dedup_hits_total").inc()
                return [Frame(FrameType.ACK, seq, _j({
                    "applied": st.applied, "durable": st.durable,
                    "duplicate": True}))]
            if self.draining:
                return [self._status(seq, Status.SHUTTING_DOWN, "server is draining")]
            if seq > st.applied + 1:
                REGISTRY.counter("wire_out_of_order_total").inc()
                return [
                    self._status(
                        seq,
                        Status.OUT_OF_ORDER,
                        f"expected seq {st.applied + 1}, " f"got {seq}",
                        expect=st.applied + 1,
                    )
                ]
            try:
                self.service.ingest(sid, stream, final=final)
            except BackpressureError as e:
                REGISTRY.counter("wire_backpressure_total").inc()
                depth = self.service.session(sid).queue_depth
                return [self._status(seq, Status.BACKPRESSURE, str(e), queue_depth=depth)]
            except UnknownSessionError:
                return [
                    self._status(seq, Status.UNKNOWN_SESSION, f"unknown session {sid!r}")
                ]
            except RuntimeError as e:
                return [self._status(seq, Status.SESSION_CLOSED, str(e))]
            st.applied = seq
            return [Frame(FrameType.ACK, seq, _j({
                "applied": st.applied, "durable": st.durable,
                "duplicate": False}))]

    def _handle_poll(self, frame: Frame) -> list[Frame]:
        doc = _unj(frame.payload)
        sid = doc.get("session")
        ack_through = doc.get("ack_through", -1)
        with self._lock:
            st = self.sessions.get(sid)
            if st is None:
                return [
                    self._status(
                        frame.seq, Status.UNKNOWN_SESSION, f"unknown session {sid!r}"
                    )
                ]
            if isinstance(ack_through, int):
                st.delta_cache = [
                    d for d in st.delta_cache if d["window_idx"] > ack_through
                ]
            try:
                fresh = self.service.poll(sid)
            except UnknownSessionError:
                fresh = []
            st.delta_cache.extend(delta_payload(d) for d in fresh)
            return [Frame(FrameType.DELTAS, frame.seq, _j({
                "session": sid, "deltas": st.delta_cache,
                "applied": st.applied, "durable": st.durable}))]

    def _handle_control(self, frame: Frame) -> list[Frame]:
        doc = _unj(frame.payload)
        op = doc.get("op")
        if op == "ping":
            return [Frame(FrameType.CONTROL_OK, frame.seq, _j({
                "op": op, "ts": time.time(),
                "draining": self.draining}))]
        if op == "drain":
            with self._lock:
                steps = self.service.scheduler.drain()
                if self.data_dir is not None:
                    self._checkpoint_locked()
            return [Frame(FrameType.CONTROL_OK, frame.seq, _j({
                "op": op, "steps": steps}))]
        if op == "checkpoint":
            with self._lock:
                if self.data_dir is None:
                    return [self._status(frame.seq, Status.INTERNAL,
                                         "server has no data dir")]
                self._checkpoint_locked()
                self._write_manifest_locked()
                durable = {sid: st.durable for sid, st in self.sessions.items()}
            return [Frame(FrameType.CONTROL_OK, frame.seq, _j({
                "op": op, "durable": durable}))]
        if op == "shutdown":
            self._stop.set()  # daemon's run loop observes and drains
            return [Frame(FrameType.CONTROL_OK, frame.seq, _j({"op": op}))]
        raise ProtocolError(f"unknown control op {op!r}")

    # ---------------------------------------------------------- test hooks

    @property
    def stop_requested(self) -> bool:
        return self._stop.is_set()

    def wait_stop(self, timeout: float | None = None) -> bool:
        return self._stop.wait(timeout)
