"""Synthetic spike-train generators (paper §6.1.1).

``sym26`` mirrors the paper's mathematical model: 26 neurons, ~20 Hz basal
inhomogeneous-Poisson firing, with two embedded causal chains (one short, one
long) whose inter-event delays fall inside a known constraint interval —
so ground-truth frequent episodes are known by construction.

Times are integer milliseconds (the engine's tick).
"""

from __future__ import annotations

import numpy as np

from repro.core.events import EventStream


def random_stream(num_types: int, num_events: int, t_max: int,
                  seed: int = 0) -> EventStream:
    """Homogeneous noise stream: uniform types, sorted uniform times."""
    rng = np.random.default_rng(seed)
    times = np.sort(rng.integers(1, t_max + 1, size=num_events))
    types = rng.integers(0, num_types, size=num_events)
    return EventStream(types.astype(np.int32), times.astype(np.int32),
                       num_types)


def embedded_chain_stream(num_types: int, chain: list[int],
                          delay_range: tuple[int, int],
                          num_occurrences: int, noise_events: int,
                          t_max: int, seed: int = 0) -> EventStream:
    """Noise + ``num_occurrences`` embedded occurrences of ``chain`` whose
    consecutive delays are uniform in (delay_range[0], delay_range[1]]."""
    rng = np.random.default_rng(seed)
    lo, hi = delay_range
    pairs: list[tuple[int, int]] = []
    # place occurrences at well-separated anchors so they never overlap
    span = (len(chain) - 1) * hi + 1
    anchors = np.linspace(1, max(t_max - span - 1, 1), num_occurrences)
    for a in anchors:
        t = int(a)
        for j, e in enumerate(chain):
            if j > 0:
                t += int(rng.integers(lo + 1, hi + 1))
            pairs.append((e, t))
    for _ in range(noise_events):
        pairs.append((int(rng.integers(0, num_types)),
                      int(rng.integers(1, t_max + 1))))
    return EventStream.from_pairs(pairs, num_types)


def sym26(seconds: int = 60, rate_hz: float = 20.0, seed: int = 0,
          num_types: int = 26) -> tuple[EventStream, dict]:
    """Paper's Sym26 analogue: 26 neurons @ ~20 Hz for ``seconds`` s with two
    embedded causal chains (short A→B→C, long H→I→J→K→L), delays in (5,10] ms.

    Returns (stream, truth) where truth maps chain name → (chain, interval,
    planted occurrence count).
    """
    rng = np.random.default_rng(seed)
    t_max = seconds * 1000
    # basal firing: Poisson(rate) per neuron → exponential gaps
    pairs: list[tuple[int, int]] = []
    for nt in range(num_types):
        t = 0.0
        while True:
            t += rng.exponential(1000.0 / rate_hz)
            if t >= t_max:
                break
            pairs.append((nt, int(t)))
    short = [0, 1, 2]          # A→B→C
    long_ = [7, 8, 9, 10, 11]  # H→I→J→K→L
    interval = (5, 10)
    n_short = seconds * 8      # ~8 planted occurrences / s
    n_long = seconds * 5
    for chain, n_occ in ((short, n_short), (long_, n_long)):
        span = (len(chain) - 1) * interval[1] + 1
        anchors = rng.integers(1, t_max - span, size=n_occ)
        for a in np.sort(anchors):
            t = int(a)
            for j, e in enumerate(chain):
                if j > 0:
                    t += int(rng.integers(interval[0] + 1, interval[1] + 1))
                pairs.append((e, t))
    stream = EventStream.from_pairs(pairs, num_types)
    truth = {"short": (short, interval, n_short),
             "long": (long_, interval, n_long)}
    return stream, truth
