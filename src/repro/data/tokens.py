"""Token pipeline for LM training examples: deterministic synthetic corpora
(so loss curves are reproducible) with a next-token objective. Real
deployments would swap in an array-record/TFDS reader behind the same
iterator contract: dict batches keyed like model.loss_fn expects."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


def synthetic_lm_batches(cfg: ModelConfig, batch: int, seq: int,
                         seed: int = 0, start: int = 0):
    """Infinite iterator of learnable synthetic LM batches: a noisy
    order-1 Markov chain over the vocab (so CE can drop well below
    log-uniform)."""
    vocab = cfg.vocab_size
    rng = np.random.default_rng(seed)
    # random sparse transition table: each symbol prefers 4 successors
    succ = rng.integers(0, vocab, size=(vocab, 4))
    i = start
    while True:
        brng = np.random.default_rng((seed, i))
        toks = np.empty((batch, seq + 1), np.int32)
        toks[:, 0] = brng.integers(0, vocab, size=batch)
        for t in range(seq):
            pick = brng.integers(0, 4, size=batch)
            nxt = succ[toks[:, t], pick]
            noise = brng.random(batch) < 0.1
            nxt = np.where(noise, brng.integers(0, vocab, size=batch), nxt)
            toks[:, t + 1] = nxt
        out = {"labels": jnp.asarray(toks[:, 1:])}
        if cfg.stub_frontend:
            erng = np.random.default_rng((seed + 1, i))
            # frame/patch embeddings stand-in derived from the token ids
            emb = erng.standard_normal((vocab, cfg.d_model)).astype(
                np.float32) * 0.02
            out["embeddings"] = jnp.asarray(emb[toks[:, :-1]])
        else:
            out["tokens"] = jnp.asarray(toks[:, :-1])
        yield out
        i += 1
