"""Partition-window streaming front (paper §1: the solution is "not a
complete data streaming solution; nevertheless, we achieve real-time
responsiveness by processing partitions of the data stream in turn").

``partition_windows`` slices an EventStream into fixed-duration windows that
the miner consumes one at a time — the MEA→miner hand-off of the
chip-on-chip loop. On a real deployment each window arrives from the
acquisition host; here the generator yields them from a recorded stream.
"""

from __future__ import annotations

from typing import Iterator

import numpy as np

from repro.core.events import PAD_TYPE, EventStream


def partition_windows(stream: EventStream, window_ms: int,
                      overlap_ms: int = 0) -> Iterator[EventStream]:
    """Yield successive windows of ``window_ms`` (with optional overlap so
    boundary-straddling occurrences are seen by one of the two windows —
    callers typically pass the episode span W as overlap)."""
    real = stream.types != PAD_TYPE
    types, times = stream.types[real], stream.times[real]
    if times.size == 0:
        return
    t0, t1 = int(times[0]), int(times[-1])
    step = window_ms - overlap_ms
    if step <= 0:
        raise ValueError("overlap must be smaller than the window")
    start = t0
    while start <= t1:
        end = start + window_ms
        lo = np.searchsorted(times, start, side="left")
        hi = np.searchsorted(times, end, side="left")
        if hi > lo:
            yield EventStream(types[lo:hi], times[lo:hi], stream.num_types)
        start += step
