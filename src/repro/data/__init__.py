from .synthetic import embedded_chain_stream, random_stream, sym26
from .spikes import partition_windows

__all__ = ["embedded_chain_stream", "random_stream", "sym26",
           "partition_windows"]
