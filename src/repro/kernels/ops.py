"""jit'd public wrappers for the episode-counting kernels.

Handles host→kernel layout (episode-major → level-major, lane/sublane
padding), dispatch policy, and result unpacking.

Dispatch policy:
  * on TPU — compiled Pallas kernel;
  * anywhere with ``REPRO_INTERPRET_KERNELS=1`` (or ``force="interpret"``) —
    ``interpret=True`` (kernel body executed by XLA CPU; used by tests);
  * otherwise — raise NotImplementedError so callers (core/count_*.py) fall
    back to the XLA-scan engine, which is the fast CPU path.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.episodes import EpisodeBatch
from repro.core.events import PAD_TYPE, EventStream, count_level1

from .a1_count import a1_count_kernel
from .a2_count import LANES, PAD_ROW_TYPE, SUBLANES, a2_count_kernel


def _mode(force: str | None) -> bool:
    """Returns interpret flag, or raises NotImplementedError to decline."""
    if force == "compiled":
        return False
    if force == "interpret":
        return True
    if jax.default_backend() == "tpu":
        return False
    if os.environ.get("REPRO_INTERPRET_KERNELS") == "1":
        return True
    raise NotImplementedError("no TPU and interpret mode not requested")


def _round_up(x: int, k: int) -> int:
    return ((x + k - 1) // k) * k


def episode_layout(eps: EpisodeBatch, inclusive_lower: bool,
                   block_m: int = LANES):
    """(M,N) episode-major → (NP, MP) level-major kernel layout."""
    m, n = eps.etypes.shape
    np_ = _round_up(max(n, 1), SUBLANES)
    mp = _round_up(m, block_m)
    et = np.full((np_, mp), PAD_ROW_TYPE, np.int32)
    et[:n, :m] = eps.etypes.T
    # row i of tlo/thi = edge i→i+1; padded rows get empty intervals (0, 0]
    tlo = np.zeros((np_, mp), np.int32)
    thi = np.zeros((np_, mp), np.int32)
    tlo[: n - 1, :m] = eps.tlo.T - (1 if inclusive_lower else 0)
    thi[: n - 1, :m] = eps.thi.T
    return jnp.asarray(et), jnp.asarray(tlo), jnp.asarray(thi)


def event_layout(stream: EventStream, with_dup: bool):
    """Events → i32[2 or 3, EP] (types; times; [dup]), EP padded to 128."""
    n = stream.types.shape[0]
    ep = _round_up(max(n, 1), LANES)
    rows = 3 if with_dup else 2
    ev = np.zeros((rows, ep), np.int32)
    ev[0, :] = PAD_TYPE
    ev[0, :n] = stream.types
    last = stream.times[-1] if n else 0
    ev[1, :] = last
    ev[1, :n] = stream.times
    if with_dup:
        dup = np.zeros(ep, np.int32)
        if n > 1:
            dup[: n - 1] = ((stream.times[1:] == stream.times[:-1])
                            & (stream.types[1:] != PAD_TYPE)).astype(np.int32)
        ev[2, :] = dup
    return jnp.asarray(ev)


def a2_count(stream: EventStream, eps: EpisodeBatch,
             force: str | None = None) -> np.ndarray:
    """Kernel-backed Algorithm 3 (inclusive-lower strengthening built in).
    ``eps`` must already be relaxed (tlo == 0). Returns int64[M]."""
    interpret = _mode(force)
    if eps.N == 1:
        return count_level1(stream, eps.etypes[:, 0])
    et, tlo, thi = episode_layout(eps, inclusive_lower=True)
    ev = event_layout(stream, with_dup=False)
    out = a2_count_kernel(et, tlo, thi, ev, n_levels=eps.N,
                          interpret=interpret)
    return np.asarray(out[0, : eps.M], dtype=np.int64)


def a1_count(stream: EventStream, eps: EpisodeBatch, lcap: int = 4,
             force: str | None = None):
    """Kernel-backed bounded-list Algorithm 1.
    Returns (counts int64[M], ovf bool[M]); see core.count_a1 for the
    exactness-restoring fallback on flagged episodes."""
    interpret = _mode(force)
    if eps.N == 1:
        return count_level1(stream, eps.etypes[:, 0]), \
            np.zeros(eps.M, dtype=bool)
    et, tlo, thi = episode_layout(eps, inclusive_lower=False)
    ev = event_layout(stream, with_dup=True)
    cnt, ovf = a1_count_kernel(et, tlo, thi, ev, n_levels=eps.N, lcap=lcap,
                               interpret=interpret)
    return (np.asarray(cnt[0, : eps.M], dtype=np.int64),
            np.asarray(ovf[0, : eps.M], dtype=bool))
