"""jit'd public wrappers for the episode-counting kernels.

Handles host→kernel layout (episode-major → level-major, lane/sublane
padding), dispatch policy, and result unpacking — including the
state-in/state-out layout contract for the carried (streaming) kernels:

  * ``a1_state_layout`` / ``a1_state_unpack`` convert between
    ``core.count_a1.A1State``'s episode-major [M, N, L] arrays and the
    kernel's level-major (NP, LCAP, MP) brick + one-hot write-pointer
    mask + (8, MP) count/ovf rows;
  * ``a2_state_layout`` / ``a2_state_unpack`` do the single-slot analogue;
  * ``a1_state_call`` / ``a2_state_call`` dispatch one carried chunk in
    kernel layout (the streaming hot path keeps state resident in this
    layout — no per-window repacking);
  * ``a1_count_stateful`` / ``a2_count_stateful`` are the one-shot-chunk
    conveniences used by ``count_a1``/``count_a2`` stateful modes (host
    layout in, host layout out);
  * ``mapconcat_layout`` / ``segment_bricks`` pack the segmented kernels'
    operands (phase-start cumsum + span rows, per-segment
    types/times/dup/τ bricks), and ``a1_mapconcat_tuples`` /
    ``a2_mapconcat_tuples`` / ``a1_mapconcat_count`` /
    ``a2_mapconcat_count`` dispatch the in-kernel MapConcatenate (grid =
    episode tile × time segment, Concatenate fold fused on-chip).

Dispatch policy:
  * on TPU — compiled Pallas kernel;
  * anywhere with ``REPRO_INTERPRET_KERNELS=1`` / ``REPRO_KERNEL_INTERPRET=1``
    (or ``force="interpret"``) — ``interpret=True`` (kernel body executed by
    XLA CPU; used by tests and the CI kernel job);
  * otherwise — raise NotImplementedError so callers (core/count_*.py) fall
    back to the XLA-scan engine, which is the fast CPU path.

``KERNEL_CALLS`` tallies host-side kernel dispatches per kind ("a1", "a2",
"a1_state", "a2_state", "a1_mapc", "a2_mapc", and the per-device
"a1_mapc_shard"/"a2_mapc_shard" of the mesh-sharded MapConcatenate
dispatch) — the interpret-mode
instrumentation tests use it to assert the Pallas path actually executed
(the bug this module's stateful API fixes was exactly a silent bypass that
no test could see).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.count_a1 import A1State, DEFAULT_LCAP, init_a1_state
from repro.core.count_a2 import A2State, init_a2_state
from repro.core.episodes import EpisodeBatch
from repro.core.events import (PAD_TYPE, TIME_NEG_INF, EventStream,
                               count_level1)

from repro.core.mapconcat import (data_mesh, make_segments, phase_cum,
                                  shard_device_count)

from .a1_count import (a1_count_kernel, a1_count_state_kernel,
                       a1_mapconcat_kernel)
from .a2_count import (DEFAULT_BLOCK_E, LANES, PAD_ROW_TYPE, SEG_ROWS,
                       SUBLANES, a2_count_kernel, a2_count_state_kernel,
                       a2_mapconcat_kernel)
from repro.obs.jaxprof import annotate

from .tally import KERNEL_CALLS, interpret_requested
from .tally import record_fallback, reset_kernel_calls  # noqa: F401

# Largest per-segment event-window length (LW) the segmented-kernel
# dispatch admits. The segment brick is DMA'd whole per grid step —
# 5 rows × LW × 4 bytes, double-buffered — so an unbounded LW can blow
# the VMEM budget with a runtime crash as the only signal. Beyond this
# the dispatch declines (NotImplementedError) and callers take the XLA
# MapConcatenate, which has no VMEM ceiling; the admitted value is
# validated against the budget by ``repro.analysis.vmem``.
MAX_SEG_BRICK_LW = 1 << 17


def _mode(force: str | None) -> bool:
    """Returns interpret flag, or raises NotImplementedError to decline."""
    if force == "compiled":
        return False
    if force == "interpret":
        return True
    if jax.default_backend() == "tpu":
        return False
    if interpret_requested():
        return True
    raise NotImplementedError("no TPU and interpret mode not requested")


def kernel_mode(force: str | None = None) -> bool:
    """Public dispatch probe: the interpret flag the kernels should run
    with, or NotImplementedError when the caller should use the XLA-scan
    engine instead. Streaming counters probe once at construction."""
    return _mode(force)


def _round_up(x: int, k: int) -> int:
    return ((x + k - 1) // k) * k


def episode_layout(eps: EpisodeBatch, inclusive_lower: bool,
                   block_m: int = LANES):
    """(M,N) episode-major → (NP, MP) level-major kernel layout."""
    m, n = eps.etypes.shape
    np_ = _round_up(max(n, 1), SUBLANES)
    mp = _round_up(m, block_m)
    et = np.full((np_, mp), PAD_ROW_TYPE, np.int32)
    et[:n, :m] = eps.etypes.T
    # row i of tlo/thi = edge i→i+1; padded rows get empty intervals (0, 0]
    tlo = np.zeros((np_, mp), np.int32)
    thi = np.zeros((np_, mp), np.int32)
    tlo[: n - 1, :m] = eps.tlo.T - (1 if inclusive_lower else 0)
    thi[: n - 1, :m] = eps.thi.T
    return jnp.asarray(et), jnp.asarray(tlo), jnp.asarray(thi)


def event_brick(types, times, with_dup: bool, length: int | None = None):
    """Raw event arrays → padded i32[2 or 3, EP] kernel brick
    (types; times; [dup]). ``length`` overrides the default padding
    (streaming uses its shape buckets): round-up-to-128, and for streams
    longer than one event chunk round-up-to-``DEFAULT_BLOCK_E`` so the
    kernels' chunked event ``BlockSpec`` divides the brick evenly."""
    types = np.asarray(types, np.int32)
    times = np.asarray(times, np.int32)
    n = types.shape[0]
    if length is None:
        ep = _round_up(max(n, 1), LANES)
        if ep > DEFAULT_BLOCK_E:
            ep = _round_up(ep, DEFAULT_BLOCK_E)
    else:
        ep = length
    rows = 3 if with_dup else 2
    ev = np.zeros((rows, ep), np.int32)
    ev[0, :] = PAD_TYPE
    ev[0, :n] = types
    last = times[-1] if n else 0
    ev[1, :] = last
    ev[1, :n] = times
    if with_dup and n > 1:
        ev[2, : n - 1] = ((times[1:] == times[:-1])
                          & (types[1:] != PAD_TYPE)).astype(np.int32)
    return jnp.asarray(ev)


def event_layout(stream: EventStream, with_dup: bool):
    """Events → i32[2 or 3, EP] (types; times; [dup]), EP padded to 128."""
    return event_brick(stream.types, stream.times, with_dup)


def a2_count(stream: EventStream, eps: EpisodeBatch,
             force: str | None = None) -> np.ndarray:
    """Kernel-backed Algorithm 3 (inclusive-lower strengthening built in).
    ``eps`` must already be relaxed (tlo == 0). Returns int64[M]."""
    interpret = _mode(force)
    if eps.N == 1:
        return count_level1(stream, eps.etypes[:, 0])
    et, tlo, thi = episode_layout(eps, inclusive_lower=True)
    ev = event_layout(stream, with_dup=False)
    KERNEL_CALLS["a2"] += 1
    with annotate("kernel:a2"):
        out = a2_count_kernel(et, tlo, thi, ev, n_levels=eps.N,
                              interpret=interpret)
    return np.asarray(out[0, : eps.M], dtype=np.int64)


def a1_count(stream: EventStream, eps: EpisodeBatch, lcap: int = 4,
             force: str | None = None):
    """Kernel-backed bounded-list Algorithm 1.
    Returns (counts int64[M], ovf bool[M]); see core.count_a1 for the
    exactness-restoring fallback on flagged episodes."""
    interpret = _mode(force)
    if eps.N == 1:
        return count_level1(stream, eps.etypes[:, 0]), \
            np.zeros(eps.M, dtype=bool)
    et, tlo, thi = episode_layout(eps, inclusive_lower=False)
    ev = event_layout(stream, with_dup=True)
    KERNEL_CALLS["a1"] += 1
    with annotate("kernel:a1"):
        cnt, ovf = a1_count_kernel(et, tlo, thi, ev, n_levels=eps.N,
                                   lcap=lcap, interpret=interpret)
    return (np.asarray(cnt[0, : eps.M], dtype=np.int64),
            np.asarray(ovf[0, : eps.M], dtype=bool))


# --------------------------------------------------------------------------
# State-carried (streaming) dispatch: pack/unpack + instrumented kernel calls
# --------------------------------------------------------------------------


def a1_state_layout(state: A1State, block_m: int = LANES):
    """``A1State`` ([M, N, L] episode-major) → kernel brick layout.

    Returns (s, po, cnt, ovf):
      s    i32(NP, L, MP)  s[lvl, slot, m] = state.s[m, lvl, slot]
      po   i32(NP, L, MP)  one-hot of state.ptr (padded lanes: slot 0 hot)
      cnt  i32(8, MP)      row 0 = state.count
      ovf  i32(8, MP)      row 0 = state.ovf
    """
    s_host = np.asarray(state.s)
    m, n, lcap = s_host.shape
    np_ = _round_up(max(n, 1), SUBLANES)
    mp = _round_up(m, block_m)
    s = np.full((np_, lcap, mp), TIME_NEG_INF, np.int32)
    s[:n, :, :m] = s_host.transpose(1, 2, 0)
    ptr = np.zeros((np_, mp), np.int32)
    ptr[:n, :m] = np.asarray(state.ptr).T
    po = (np.arange(lcap, dtype=np.int32)[None, :, None]
          == ptr[:, None, :]).astype(np.int32)
    cnt = np.zeros((SUBLANES, mp), np.int32)
    cnt[0, :m] = np.asarray(state.count)
    ovf = np.zeros((SUBLANES, mp), np.int32)
    ovf[0, :m] = np.asarray(state.ovf)
    return (jnp.asarray(s), jnp.asarray(po), jnp.asarray(cnt),
            jnp.asarray(ovf))


def a1_state_unpack(s, po, cnt, ovf, m: int, n: int) -> A1State:
    """Inverse of ``a1_state_layout`` (kernel brick → episode-major)."""
    s_host = np.asarray(s)[:n, :, :m].transpose(2, 0, 1)
    ptr = np.argmax(np.asarray(po)[:n, :, :m], axis=1).T.astype(np.int32)
    return A1State(
        s=jnp.asarray(s_host),
        ptr=jnp.asarray(ptr),
        count=jnp.asarray(np.asarray(cnt)[0, :m]),
        ovf=jnp.asarray(np.asarray(ovf)[0, :m] != 0))


def a2_state_layout(state: A2State, block_m: int = LANES):
    """``A2State`` ([M, N] episode-major) → kernel (s, cnt) layout."""
    s_host = np.asarray(state.s)
    m, n = s_host.shape
    np_ = _round_up(max(n, 1), SUBLANES)
    mp = _round_up(m, block_m)
    s = np.full((np_, mp), TIME_NEG_INF, np.int32)
    s[:n, :m] = s_host.T
    cnt = np.zeros((SUBLANES, mp), np.int32)
    cnt[0, :m] = np.asarray(state.count)
    return jnp.asarray(s), jnp.asarray(cnt)


def a2_state_unpack(s, cnt, m: int, n: int) -> A2State:
    """Inverse of ``a2_state_layout``."""
    return A2State(
        s=jnp.asarray(np.asarray(s)[:n, :m].T),
        count=jnp.asarray(np.asarray(cnt)[0, :m]))


def a1_state_call(et, tlo, thi, ev, s, po, cnt, ovf, *, n_levels: int,
                  lcap: int, interpret: bool):
    """One carried A1 chunk in kernel layout (instrumented). Returns
    (cnt, ovf, s, po); the passed state arrays are donated."""
    KERNEL_CALLS["a1_state"] += 1
    with annotate("kernel:a1_state"):
        return a1_count_state_kernel(et, tlo, thi, ev, s, po, cnt, ovf,
                                     n_levels=n_levels, lcap=lcap,
                                     interpret=interpret)


def a2_state_call(et, tlo, thi, ev, s, cnt, *, n_levels: int,
                  interpret: bool):
    """One carried A2 chunk in kernel layout (instrumented). Returns
    (cnt, s); the passed state arrays are donated."""
    KERNEL_CALLS["a2_state"] += 1
    with annotate("kernel:a2_state"):
        return a2_count_state_kernel(et, tlo, thi, ev, s, cnt,
                                     n_levels=n_levels, interpret=interpret)


# --------------------------------------------------------------------------
# Segment-parallel (MapConcatenate) dispatch: layout + instrumented calls
# --------------------------------------------------------------------------


def mapconcat_layout(eps: EpisodeBatch, inclusive_lower: bool,
                     block_m: int = LANES):
    """Episode layout for the segmented kernels: the usual level-major
    bricks plus the phase-start offsets and per-episode span.

    Returns (et, tlo, thi, cum, w):
      cum  i32(NP, MP)  row k = Σ_{i<k} thi (``core.mapconcat.phase_cum``)
                        — machine k of the segment starts that far before
                        the boundary; rows >= N zero (never read)
      w    i32(8, MP)   row 0 = per-episode max occurrence span
    """
    et, tlo, thi = episode_layout(eps, inclusive_lower, block_m)
    m, n = eps.etypes.shape
    np_ = _round_up(max(n, 1), SUBLANES)
    mp = _round_up(m, block_m)
    cum = np.zeros((np_, mp), np.int32)
    cum[:n, :m] = np.asarray(phase_cum(eps.thi), np.int32).T
    w = np.zeros((SUBLANES, mp), np.int32)
    w[0, :m] = np.asarray(eps.max_span, np.int32)
    return et, tlo, thi, jnp.asarray(cum), jnp.asarray(w)


def segment_bricks(wt, wtt, tau, length: int | None = None):
    """Per-segment event windows → i32[P, 5, LW] kernel bricks.

    Rows: (types, times, dup, τ_p, τ_{p+1}) — the boundary rows are
    broadcast along the window (the kernel reads them as scalars at column
    0). ``dup`` marks a same-timestamp real successor *within the window*,
    matching the per-window ``core.count_a1.dup_flags`` semantics the XLA
    Map step uses. ``length`` overrides the round-up-to-128 window padding
    (the cross-session batcher re-buckets to the fused group's max).
    """
    wt = np.asarray(wt, np.int32)
    wtt = np.asarray(wtt, np.int32)
    p, lw = wt.shape
    lwp = _round_up(max(lw, 1), LANES) if length is None else length
    if lwp > MAX_SEG_BRICK_LW:
        # an unadmitted brick would overflow VMEM at launch; decline so
        # the caller's graceful-degradation path takes the XLA engine
        raise NotImplementedError(
            f"segment brick LW={lwp} exceeds the admitted "
            f"MAX_SEG_BRICK_LW={MAX_SEG_BRICK_LW} (VMEM budget)")
    ev = np.zeros((p, SEG_ROWS, lwp), np.int32)
    ev[:, 0, :] = PAD_TYPE
    ev[:, 0, :lw] = wt
    ev[:, 1, :lw] = wtt
    if lw > 1:
        ev[:, 2, : lw - 1] = ((wtt[:, 1:] == wtt[:, :-1])
                              & (wt[:, 1:] != PAD_TYPE)).astype(np.int32)
    tau = np.asarray(tau, np.int64)
    ev[:, 3, :] = tau[:-1, None].astype(np.int32)
    ev[:, 4, :] = tau[1:, None].astype(np.int32)
    return jnp.asarray(ev)


def a1_mapconcat_tuples(et, tlo, thi, cum, w, segs, *, n_levels: int,
                        lcap: int, interpret: bool):
    """One segmented A1 launch in kernel layout (instrumented). Returns the
    stitched (a, c, b, f) bricks plus the ovf rows."""
    KERNEL_CALLS["a1_mapc"] += 1
    with annotate("kernel:a1_mapc"):
        return a1_mapconcat_kernel(et, tlo, thi, cum, w, segs,
                                   n_levels=n_levels, lcap=lcap,
                                   interpret=interpret)


def a2_mapconcat_tuples(et, tlo, thi, cum, w, segs, *, n_levels: int,
                        interpret: bool):
    """One segmented A2 launch in kernel layout (instrumented)."""
    KERNEL_CALLS["a2_mapc"] += 1
    with annotate("kernel:a2_mapc"):
        return a2_mapconcat_kernel(et, tlo, thi, cum, w, segs,
                                   n_levels=n_levels, interpret=interpret)


def _mapc_inputs(stream: EventStream, eps: EpisodeBatch, num_segments: int,
                 inclusive_lower: bool):
    """Host side of a one-shot segmented launch: segment the stream
    (``core.mapconcat.make_segments`` — same boundaries as the XLA path)
    and pack the kernel bricks."""
    w_max = int(np.asarray(eps.max_span).max())
    tau, wt, wtt = make_segments(stream, num_segments, w_max)
    layout = mapconcat_layout(eps, inclusive_lower=inclusive_lower)
    return layout + (segment_bricks(wt, wtt, tau),)


def a1_mapconcat_count(stream: EventStream, eps: EpisodeBatch,
                       num_segments: int = 8, lcap: int = DEFAULT_LCAP,
                       force: str | None = None):
    """Kernel-backed MapConcatenate: one launch runs the segment Map and
    the fused Concatenate fold. Returns (counts int64[M], bad bool[M]);
    ``bad`` marks episodes needing the caller's exact fallback (unmatched
    stitch or possibly-live eviction — same containment as
    ``core.mapconcat.mapconcatenate``)."""
    interpret = _mode(force)
    if eps.N == 1:
        return (count_level1(stream, eps.etypes[:, 0]),
                np.zeros(eps.M, dtype=bool))
    if len(stream) == 0:
        return np.zeros(eps.M, np.int64), np.zeros(eps.M, dtype=bool)
    et, tlo, thi, cum, w, segs = _mapc_inputs(stream, eps, num_segments,
                                              inclusive_lower=False)
    _, c, _, f, ovf = a1_mapconcat_tuples(et, tlo, thi, cum, w, segs,
                                          n_levels=eps.N, lcap=lcap,
                                          interpret=interpret)
    counts = np.asarray(c[0, : eps.M], dtype=np.int64)
    bad = np.asarray((f[0, : eps.M] != 0) | (ovf[0, : eps.M] != 0))
    return counts, bad


def a2_mapconcat_count(stream: EventStream, eps: EpisodeBatch,
                       num_segments: int = 8, force: str | None = None):
    """Kernel-backed segmented A2 (single-slot) counting of ``eps`` under
    its own bounds with the inclusive-lower strengthening (callers pass the
    relaxed batch). Returns (counts int64[M], bad bool[M]); ``bad`` = the
    stitch's unmatched flag (single-slot machines cannot overflow)."""
    interpret = _mode(force)
    if eps.N == 1:
        return (count_level1(stream, eps.etypes[:, 0]),
                np.zeros(eps.M, dtype=bool))
    if len(stream) == 0:
        return np.zeros(eps.M, np.int64), np.zeros(eps.M, dtype=bool)
    et, tlo, thi, cum, w, segs = _mapc_inputs(stream, eps, num_segments,
                                              inclusive_lower=True)
    _, c, _, f, _ = a2_mapconcat_tuples(et, tlo, thi, cum, w, segs,
                                        n_levels=eps.N, interpret=interpret)
    counts = np.asarray(c[0, : eps.M], dtype=np.int64)
    bad = np.asarray(f[0, : eps.M] != 0)
    return counts, bad


# --------------------------------------------------------------------------
# Multi-device (mesh-sharded) MapConcatenate dispatch
# --------------------------------------------------------------------------


# shard_device_count is re-exported from core.mapconcat (the single
# source of truth for the sharded dispatch's device-set policy).


@functools.lru_cache(maxsize=None)
def _stream_mesh(d: int):
    """Cached 1-D ``("data",)`` mesh over the first ``d`` devices
    (``core.mapconcat.data_mesh`` — same builder the XLA fallback and
    ``launch.mesh.make_stream_mesh`` use)."""
    return data_mesh(d)


@functools.lru_cache(maxsize=None)
def _mapc_sharded_fn(kind: str, n_levels: int, lcap: int, interpret: bool,
                     d: int, lanes: bool):
    """Build (and cache) the sharded segmented launch: a ``shard_map`` over
    the mesh ``data`` axis where each device runs ONE segmented Pallas
    launch on its contiguous segment group (grid = episode tile × local
    segments, in-group Concatenate fused on-chip), then all-gathers the
    O(P·N) per-device (a, count, b, f) tuples and folds them replicated —
    the cross-device half of the paper's MapConcatenate (§5.2.2), sound
    because the tuple fold is associative across arbitrary cut points.

    ``lanes`` adds a leading session axis (the cross-session batcher's
    fused variant): the per-device kernel is vmapped over lanes while the
    segment axis shards over devices. Returns a jitted callable with the
    same (a, c, b, f, ovf) output contract as ``a1_mapconcat_kernel``.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core.mapconcat import fold_pair

    mesh = _stream_mesh(d)
    if kind == "a1":
        base = functools.partial(a1_mapconcat_kernel, n_levels=n_levels,
                                 lcap=lcap, interpret=interpret)
    else:
        base = functools.partial(a2_mapconcat_kernel, n_levels=n_levels,
                                 interpret=interpret)
    call = jax.vmap(base) if lanes else base
    k = n_levels

    def dev_fn(et, tlo, thi, cum, w, segs):
        # one kernel launch over this device's P/d-segment group
        a, c, b, f, ovf = call(et, tlo, thi, cum, w, segs)
        tup = jnp.stack([a, c, b, f], axis=-3)     # [..., 4, NP, M]
        g = jax.lax.all_gather(tup, "data")        # [d, ..., 4, NP, M]
        og = jax.lax.all_gather(ovf, "data")       # [d, ..., 8, M]

        def tup_at(i):
            s = g[i]
            return (s[..., 0, :k, :], s[..., 1, :k, :],
                    s[..., 2, :k, :], s[..., 3, :k, :] != 0)

        # replicated left fold across the device axis (Fig. 6; d is small
        # and static, so the unrolled loop is one fused XLA computation)
        carry = tup_at(0)
        for i in range(1, d):
            carry = fold_pair(carry, tup_at(i))
        np_ = a.shape[-2]

        def pad_rows(x):
            x = x.astype(jnp.int32)
            if np_ == k:
                return x
            zshape = x.shape[:-2] + (np_ - k, x.shape[-1])
            return jnp.concatenate([x, jnp.zeros(zshape, jnp.int32)],
                                   axis=-2)

        a2_, c2_, b2_, f2_ = (pad_rows(x) for x in carry)
        return a2_, c2_, b2_, f2_, og.max(axis=0)

    seg_spec = P(None, "data") if lanes else P("data")
    in_specs = (P(), P(), P(), P(), P(), seg_spec)
    return jax.jit(shard_map(dev_fn, mesh=mesh, in_specs=in_specs,
                             out_specs=(P(),) * 5, check_rep=False))


def a1_mapconcat_sharded_tuples(et, tlo, thi, cum, w, segs, *,
                                n_levels: int, lcap: int, interpret: bool,
                                num_devices: int):
    """One mesh-sharded segmented A1 launch (instrumented): ``segs``'s
    leading segment axis must be divisible by ``num_devices``. Same output
    contract as ``a1_mapconcat_tuples`` — the stitched (a, c, b, f) bricks
    plus the ovf rows OR'd over devices."""
    KERNEL_CALLS["a1_mapc_shard"] += num_devices
    fn = _mapc_sharded_fn("a1", n_levels, lcap, interpret, num_devices,
                          lanes=False)
    with annotate("kernel:a1_mapc_shard"):
        return fn(et, tlo, thi, cum, w, segs)


def a2_mapconcat_sharded_tuples(et, tlo, thi, cum, w, segs, *,
                                n_levels: int, interpret: bool,
                                num_devices: int):
    """Single-slot analogue of ``a1_mapconcat_sharded_tuples``."""
    KERNEL_CALLS["a2_mapc_shard"] += num_devices
    fn = _mapc_sharded_fn("a2", n_levels, 0, interpret, num_devices,
                          lanes=False)
    with annotate("kernel:a2_mapc_shard"):
        return fn(et, tlo, thi, cum, w, segs)


def _sharded_segments(stream: EventStream, eps: EpisodeBatch,
                      num_segments: int, d: int):
    """Segment the stream for a d-device launch: at least one segment per
    device, total divisible by d. Returns (tau, wt, wtt) or None when the
    stream is too short to give every device a stitch-safe (> W) segment —
    the caller then takes the single-device path."""
    w_max = int(np.asarray(eps.max_span).max())
    tau, wt, wtt = make_segments(stream, max(num_segments, d), w_max)
    p = wt.shape[0]
    if p < d or p % d:
        return None
    return tau, wt, wtt


def a1_mapconcat_sharded_count(stream: EventStream, eps: EpisodeBatch,
                               num_segments: int = 8,
                               lcap: int = DEFAULT_LCAP,
                               num_devices: int | None = None,
                               force: str | None = None):
    """Mesh-sharded MapConcatenate: one segmented kernel launch per device
    with the per-device tuples all-gathered and folded replicated. Returns
    (counts int64[M], bad bool[M]) exactly like ``a1_mapconcat_count``;
    delegates to the single-device launch when fewer than two devices are
    usable or the stream is too short to shard stitch-safely."""
    interpret = _mode(force)
    if eps.N == 1:
        return (count_level1(stream, eps.etypes[:, 0]),
                np.zeros(eps.M, dtype=bool))
    d = shard_device_count() if num_devices is None else num_devices
    made = (_sharded_segments(stream, eps, num_segments, d)
            if d >= 2 and len(stream) else None)
    if made is None:
        return a1_mapconcat_count(stream, eps, num_segments=num_segments,
                                  lcap=lcap, force=force)
    tau, wt, wtt = made
    et, tlo, thi, cum, w = mapconcat_layout(eps, inclusive_lower=False)
    segs = segment_bricks(wt, wtt, tau)
    _, c, _, f, ovf = a1_mapconcat_sharded_tuples(
        et, tlo, thi, cum, w, segs, n_levels=eps.N, lcap=lcap,
        interpret=interpret, num_devices=d)
    counts = np.asarray(c[0, : eps.M], dtype=np.int64)
    bad = np.asarray((f[0, : eps.M] != 0) | (ovf[0, : eps.M] != 0))
    return counts, bad


def a2_mapconcat_sharded_count(stream: EventStream, eps: EpisodeBatch,
                               num_segments: int = 8,
                               num_devices: int | None = None,
                               force: str | None = None):
    """Mesh-sharded segmented A2 counting (relaxed batch, inclusive-lower
    strengthening) — see ``a2_mapconcat_count`` for the contract."""
    interpret = _mode(force)
    if eps.N == 1:
        return (count_level1(stream, eps.etypes[:, 0]),
                np.zeros(eps.M, dtype=bool))
    d = shard_device_count() if num_devices is None else num_devices
    made = (_sharded_segments(stream, eps, num_segments, d)
            if d >= 2 and len(stream) else None)
    if made is None:
        return a2_mapconcat_count(stream, eps, num_segments=num_segments,
                                  force=force)
    tau, wt, wtt = made
    et, tlo, thi, cum, w = mapconcat_layout(eps, inclusive_lower=True)
    segs = segment_bricks(wt, wtt, tau)
    _, c, _, f, _ = a2_mapconcat_sharded_tuples(
        et, tlo, thi, cum, w, segs, n_levels=eps.N, interpret=interpret,
        num_devices=d)
    counts = np.asarray(c[0, : eps.M], dtype=np.int64)
    bad = np.asarray(f[0, : eps.M] != 0)
    return counts, bad


def a1_mapc_sharded_vmapped(n_levels: int, lcap: int, interpret: bool,
                            num_devices: int):
    """Fused-lane variant of the sharded segmented launch: the per-device
    kernel is vmapped over a leading session axis while the segment axis
    shards over the mesh — the cross-session batcher's multi-device
    MapConcatenate seam. Operands carry a leading lane axis; ``segs`` is
    [S, P, 5, LW] with P divisible by ``num_devices``."""
    return _mapc_sharded_fn("a1", n_levels, lcap, interpret, num_devices,
                            lanes=True)


@functools.lru_cache(maxsize=None)
def a1_mapc_vmapped(n_levels: int, lcap: int, interpret: bool):
    """vmap of the segmented A1 kernel over a leading session axis (the
    cross-session batcher's fused MapConcatenate launch)."""
    f = functools.partial(a1_mapconcat_kernel, n_levels=n_levels, lcap=lcap,
                          interpret=interpret)
    return jax.jit(jax.vmap(f))


@functools.lru_cache(maxsize=None)
def a1_state_vmapped(n_levels: int, lcap: int, interpret: bool):
    """vmap of the carried A1 kernel over a leading session axis — the
    cross-session batcher fuses same-shape tenants through this (Pallas
    lowers the mapped axis onto the grid)."""
    f = functools.partial(a1_count_state_kernel, n_levels=n_levels,
                          lcap=lcap, interpret=interpret)
    return jax.jit(jax.vmap(f))


@functools.lru_cache(maxsize=None)
def a2_state_vmapped(n_levels: int, interpret: bool):
    """vmap of the carried A2 kernel over a leading session axis."""
    f = functools.partial(a2_count_state_kernel, n_levels=n_levels,
                          interpret=interpret)
    return jax.jit(jax.vmap(f))


def a1_count_stateful(stream: EventStream, eps: EpisodeBatch,
                      state: A1State | None = None,
                      lcap: int = DEFAULT_LCAP, force: str | None = None):
    """Kernel-backed carried A1 chunk (host layout in/out).

    Returns (counts int64[M], ovf bool[M], new ``A1State``) cumulative over
    everything the carried machines have seen. ``eps.N`` must be >= 2
    (callers shortcut N == 1 to the histogram). Exactness caveats are the
    scan engine's: chunk boundaries must not split tie groups, and
    ``ovf``-flagged episodes need a host recount over the concatenated
    history (``StreamingCounter`` automates both).
    """
    interpret = _mode(force)
    if state is None:
        state = init_a1_state(eps, lcap)
    lcap = int(state.s.shape[-1])  # the brick's static capacity
    et, tlo, thi = episode_layout(eps, inclusive_lower=False)
    ev = event_layout(stream, with_dup=True)
    s, po, cnt, ovf = a1_state_layout(state)
    cnt, ovf, s, po = a1_state_call(et, tlo, thi, ev, s, po, cnt, ovf,
                                    n_levels=eps.N, lcap=lcap,
                                    interpret=interpret)
    new_state = a1_state_unpack(s, po, cnt, ovf, eps.M, eps.N)
    return (np.asarray(cnt[0, : eps.M], dtype=np.int64),
            np.asarray(ovf[0, : eps.M] != 0), new_state)


def a2_count_stateful(stream: EventStream, eps: EpisodeBatch,
                      state: A2State | None = None,
                      inclusive_lower: bool = True,
                      force: str | None = None):
    """Kernel-backed carried single-slot chunk (host layout in/out).

    Counts ``eps`` under its *own* bounds (the A2 use passes the relaxed
    batch with ``inclusive_lower=True``, matching ``count_single_slot``).
    Returns (counts int64[M], new ``A2State``); unconditionally bit-exact
    under any chunking (Obs. 5.1). ``eps.N`` must be >= 2.
    """
    interpret = _mode(force)
    if state is None:
        state = init_a2_state(eps)
    et, tlo, thi = episode_layout(eps, inclusive_lower=inclusive_lower)
    ev = event_layout(stream, with_dup=False)
    s, cnt = a2_state_layout(state)
    cnt, s = a2_state_call(et, tlo, thi, ev, s, cnt, n_levels=eps.N,
                           interpret=interpret)
    new_state = a2_state_unpack(s, cnt, eps.M, eps.N)
    return (np.asarray(cnt[0, : eps.M], dtype=np.int64), new_state)
