"""Pure-jnp oracles for the Pallas kernels, in KERNEL layout.

These mirror the kernels' (levels × episodes) data layout op-for-op but run
as plain jnp (lax.scan over events). tests/test_kernels.py sweeps shapes and
asserts the interpret-mode kernels equal these oracles bit-exactly; the
oracles themselves are asserted equal to the sequential pseudocode oracles
in core/ref.py, closing the chain kernel == layout-oracle == paper.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.events import TIME_NEG_INF


@functools.partial(jax.jit, static_argnames=("n_levels",))
def a2_count_ref(etypes, tlo, thi, events, *, n_levels: int):
    """i32[NP, M] layout oracle for a2_count_kernel. Returns i32[M]."""
    np_, m = etypes.shape

    def step(carry, ev):
        s, cnt = carry
        e, t = ev
        match = etypes == e
        delta = t - s
        ok = (delta > tlo) & (delta <= thi)
        ok_shift = jnp.concatenate(
            [jnp.ones((1, m), jnp.bool_), ok[:-1, :]], axis=0)
        advance = match & ok_shift
        complete = advance[n_levels - 1, :]
        store = advance.at[n_levels - 1, :].set(False)
        s = jnp.where(store, t, s)
        s = jnp.where(complete[None, :], TIME_NEG_INF, s)
        return (s, cnt + complete.astype(jnp.int32)), None

    s0 = jnp.full((np_, m), TIME_NEG_INF, jnp.int32)
    (_, cnt), _ = jax.lax.scan(step, (s0, jnp.zeros((m,), jnp.int32)),
                               (events[0], events[1]))
    return cnt


@functools.partial(jax.jit, static_argnames=("n_levels", "lcap"))
def a1_count_ref(etypes, tlo, thi, events, *, n_levels: int, lcap: int = 4):
    """i32[NP, M] layout oracle for a1_count_kernel.
    Returns (counts i32[M], ovf bool[M])."""
    np_, m = etypes.shape

    def step(carry, ev):
        s, po, cnt, ovf = carry
        e, t, dup = ev
        match = etypes == e
        delta = t - s
        witness = (delta > tlo[:, None, :]) & (delta <= thi[:, None, :])
        ok = witness.any(axis=1)
        ok_shift = jnp.concatenate(
            [jnp.ones((1, m), jnp.bool_), ok[:-1, :]], axis=0)
        advance = match & ok_shift
        complete = advance[n_levels - 1, :]
        store = advance.at[n_levels - 1, :].set(False)
        store = store & ~complete[None, :]
        write = store[:, None, :] & po
        v = jnp.where(write, s, TIME_NEG_INF).max(axis=1)
        live = (v > TIME_NEG_INF) & (t - v <= thi) & ((tlo > 0) | (dup != 0))
        ovf = ovf | live.any(axis=0)
        s = jnp.where(write, t, s)
        po = jnp.where(store[:, None, :], jnp.roll(po, 1, axis=1), po)
        s = jnp.where(complete[None, None, :], TIME_NEG_INF, s)
        po0 = jnp.zeros_like(po).at[:, 0, :].set(True)
        po = jnp.where(complete[None, None, :], po0, po)
        return (s, po, cnt + complete.astype(jnp.int32), ovf), None

    s0 = jnp.full((np_, lcap, m), TIME_NEG_INF, jnp.int32)
    po0 = jnp.zeros((np_, lcap, m), jnp.bool_).at[:, 0, :].set(True)
    (_, _, cnt, ovf), _ = jax.lax.scan(
        step, (s0, po0, jnp.zeros((m,), jnp.int32),
               jnp.zeros((m,), jnp.bool_)),
        (events[0], events[1], events[2]))
    return cnt, ovf
