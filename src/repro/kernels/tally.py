"""Dispatch tally + interpret-mode env accessor (dependency-light).

This module deliberately imports nothing heavy (no jax, no numpy) so the
pure-XLA engines in ``core/`` can record kernel→XLA downgrades even when
the Pallas stack itself is unimportable — the ``ImportError`` arm of the
graceful-degradation ``except`` clauses is exactly the situation in which
``kernels.ops`` cannot be loaded.  (``repro.obs.registry`` is pure
stdlib, so depending on it keeps that property.)

``KERNEL_CALLS`` tallies host-side kernel dispatches per kind ("a1",
"a1_state", "a1_mapc", "a1_mapc_shard", the "a2"/"a2_*" analogues) and —
since PR 6 — every graceful degradation under a ``fallback:<site>`` kind
(``record_fallback``).  A downgrade that does not move a tally is
invisible to both the service telemetry and the contract auditor
(``repro.analysis``), which is how PR 3's silent-bypass bug survived
review; the auditor's KC105 rule now rejects any
``except NotImplementedError`` degradation path that does not call
``record_fallback``.

Since the obs PR the tally is a *view* over the process-global metrics
registry: ``KERNEL_CALLS[kind]`` reads/writes the
``kernel_calls{kind=...}`` counter family in ``repro.obs.REGISTRY``, so
the audit artifact (``dict(KERNEL_CALLS)``), the service health snapshot,
and exported metrics are one set of numbers that cannot drift.  Audit
rule KC107 rejects any shadow tally or direct ``fallback:`` write outside
this accessor module.

``interpret_requested`` is the single accessor for the
``REPRO_KERNEL_INTERPRET`` / ``REPRO_INTERPRET_KERNELS`` environment
aliases (both spellings remain accepted; earlier PRs read them
inconsistently from two call sites).  The auditor's KC106 rule rejects
direct ``os.environ`` reads of either name anywhere else.
"""

from __future__ import annotations

import os
from collections.abc import MutableMapping

from repro.obs.registry import REGISTRY

# Accepted spellings for "run the Pallas kernels in interpret mode".
# REPRO_KERNEL_INTERPRET is the documented name; the other is a legacy
# alias kept so existing CI configs and scripts don't break.
INTERPRET_ENV_VARS = ("REPRO_KERNEL_INTERPRET", "REPRO_INTERPRET_KERNELS")

_FAMILY = "kernel_calls"


class _KernelCallsView(MutableMapping):
    """``collections.Counter``-compatible view over the registry's
    ``kernel_calls`` family.

    Supports everything the codebase and tests do with the old Counter:
    ``KERNEL_CALLS[k] += n`` (missing keys read as 0), ``dict(...)``,
    ``.items()``, ``.clear()``, comparisons against ints. Iteration
    yields only kinds that have been touched, like a Counter that never
    stored zero-count keys."""

    def __getitem__(self, kind: str) -> int:
        for labels, m in REGISTRY.family_items(_FAMILY):
            if labels.get("kind") == kind:
                return m.value
        return 0

    def __setitem__(self, kind: str, value: int) -> None:
        REGISTRY.counter(_FAMILY, kind=kind)._force_set(value)

    def __delitem__(self, kind: str) -> None:
        REGISTRY.counter(_FAMILY, kind=kind)._force_set(0)

    def __iter__(self):
        return iter([labels["kind"]
                     for labels, _ in REGISTRY.family_items(_FAMILY)])

    def __len__(self) -> int:
        return len(REGISTRY.family_items(_FAMILY))

    def clear(self) -> None:
        REGISTRY.clear_family(_FAMILY)

    def __repr__(self) -> str:
        return f"KERNEL_CALLS({dict(self)})"


KERNEL_CALLS = _KernelCallsView()


def reset_kernel_calls() -> None:
    """Zero the dispatch tally (test / audit instrumentation)."""
    KERNEL_CALLS.clear()


def record_fallback(site: str) -> None:
    """Record one kernel→XLA graceful degradation at ``site``.

    Every ``except (ImportError, NotImplementedError)`` arm that reroutes
    a kernel dispatch onto an XLA engine must call this, so downgrades
    show up in the same tally the kernel dispatches do —
    ``KERNEL_CALLS["fallback:<site>"]``. Enforced by
    ``repro.analysis.contracts`` rule KC105; writing the ``fallback:``
    kind anywhere else is a KC107 violation.
    """
    REGISTRY.counter(_FAMILY, kind="fallback:" + site).inc()


def fallback_counts() -> dict:
    """The ``fallback:*`` slice of the tally (site → count)."""
    return {k.split(":", 1)[1]: v for k, v in KERNEL_CALLS.items()
            if k.startswith("fallback:")}


def interpret_requested() -> bool:
    """Whether the environment asks for interpret-mode kernels.

    Single source of truth for the ``REPRO_KERNEL_INTERPRET`` /
    ``REPRO_INTERPRET_KERNELS`` aliases — read the env through this
    accessor only (audit rule KC106)."""
    return any(os.environ.get(v) == "1" for v in INTERPRET_ENV_VARS)
