"""Pallas TPU kernel: A2 (single-slot) episode counting.

Computation-to-core mapping (the TPU re-derivation of the paper's PTPE):
episodes live on the 128-wide **lane** axis, episode levels on the
**sublane** axis, so one VPU op advances 8×128 state machines. The grid's
first axis tiles the episode batch; the second axis blocks the **event
stream** into ``block_e``-sized chunks with ``arbitrary`` (sequential)
grid semantics — each chunk is DMA'd/double-buffered into VMEM per grid
step while the machine state carries across steps in the revisited output
block, so the stream is never broadcast whole and VMEM no longer caps the
events-per-call (the seed's "stream re-read by every grid step" layout is
gone; the fresh-state wrapper shares the chunked launch with the
state-carried one).

Layouts (all i32):
  etypes  (NP,  BM)  episode types, level-major  (NP = levels padded to 8k)
  tlo/thi (NP,  BM)  edge bounds, row i = edge i→i+1 (row N-1.. padded)
  events  (2, EP)    row 0 = types, row 1 = times (EP = events padded)
  count   (8, BM)    output; row 0 holds the counts (8 sublanes for tiling)

Event padding uses type = PAD_TYPE (-1); level-row padding uses -2, so a
padded event never matches a padded row. Validated in ``interpret=True``
against ``ref.a2_count_ref`` (tests/test_kernels.py sweeps shapes+dtypes).

State-in/state-out variant (``a2_count_state_kernel``): the single-slot
timestamp tile and the count row become kernel I/O with in-place aliasing,
so chunk-by-chunk streaming stays on-chip. A single slot per level is
complete machine state (Obs. 5.1), so carried chunked counting is
unconditionally bit-exact under any partitioning — no tie-group caveat.
Pack/unpack to ``core.count_a2.A2State`` lives in ``ops.a2_state_layout``
/ ``ops.a2_state_unpack``.

Segment-parallel variant (``a2_mapconcat_kernel``): the paper's
MapConcatenate mapping (§5.2.2) brought on-chip — the grid is
(episode tile × time segment); each segment runs K = N phase-shifted
single-slot machines (start offsets from ``core.mapconcat.phase_cum``,
stitch zones from ``core.mapconcat.stitch_zones``) and emits the
(a, count, b) tuple of Fig. 5, with the Concatenate stage fused into the
same launch: the tuple lives in output blocks revisited across the segment
axis and each segment folds onto it with the first-match stitch
(``core.mapconcat.fold_pair_unrolled``, carrying the ``unmatched`` flag
for the host's exact-recount fallback).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.events import PAD_TYPE, TIME_NEG_INF
from repro.core.mapconcat import fold_pair_unrolled, stitch_zones

LANES = 128
SUBLANES = 8
PAD_ROW_TYPE = -2

# event-axis chunk: events per grid step on the ``arbitrary`` grid axis
# (the DMA/double-buffer granularity; also the padding quantum for long
# streams — see ops.event_brick)
DEFAULT_BLOCK_E = 1024

# segmented-kernel event-brick rows (see ops.segment_bricks):
# types, times, successor-duplicate flags, then the segment boundaries
# τ_p / τ_{p+1} broadcast along the row (read as scalars at column 0)
SEG_TYPE, SEG_TIME, SEG_DUP, SEG_TAU_LO, SEG_TAU_HI = range(5)
SEG_ROWS = 5

try:  # jax >= 0.5 spells it CompilerParams
    _CompilerParams = pltpu.CompilerParams
except AttributeError:
    _CompilerParams = pltpu.TPUCompilerParams

# episode tiles are independent (parallel); the event-chunk / time-segment
# axis carries machine state or the stitch fold across steps (arbitrary)
SEQ_GRID = _CompilerParams(dimension_semantics=("parallel", "arbitrary"))


def _a2_body(n_levels: int, et, tlo, thi, ev_ref):
    """Per-event step over the (s, cnt) carry — shared by the fresh-state
    and state-carried kernels."""
    np_, bm = et.shape

    def body(j, carry):
        s, cnt = carry
        e = ev_ref[0, j]
        t = ev_ref[1, j]
        match = et == e                                   # (NP, BM)
        delta = t - s                                     # (NP, BM)
        ok = (delta > tlo) & (delta <= thi)               # row i: edge i→i+1
        # advance row 0 = match; row i>0 = match & ok[i-1]
        ok_shift = jnp.concatenate(
            [jnp.ones((1, bm), jnp.bool_), ok[:-1, :]], axis=0)
        advance = match & ok_shift                        # (NP, BM)
        complete = advance[n_levels - 1, :]               # (BM,)
        store = advance.at[n_levels - 1, :].set(False)
        s = jnp.where(store, t, s)
        s = jnp.where(complete[None, :], TIME_NEG_INF, s)
        cnt = cnt + complete.astype(jnp.int32)[None, :]
        return s, cnt

    return body


def _a2_state_kernel(n_levels: int, et_ref, tlo_ref, thi_ref, ev_ref,
                     sin_ref, cin_ref, cnt_ref, sout_ref):
    """One (episode tile × event chunk) grid step: resume the machines from
    the carried output blocks (seeded from the state inputs at chunk 0),
    walk this chunk's events, and leave the advanced state in the revisited
    output blocks for the next chunk."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        sout_ref[...] = sin_ref[...]
        cnt_ref[...] = cin_ref[...]

    et = et_ref[...]
    tlo = tlo_ref[...]
    thi = thi_ref[...]
    body = _a2_body(n_levels, et, tlo, thi, ev_ref)
    s, cnt = jax.lax.fori_loop(0, ev_ref.shape[1], body,
                               (sout_ref[...], cnt_ref[0:1, :]))
    cnt_ref[...] = jnp.broadcast_to(cnt, cnt_ref.shape)
    sout_ref[...] = s


def _block_e(ep: int, block_e: int) -> int:
    """Effective event-chunk length: ``block_e`` when it divides the padded
    stream (ops.event_brick pads long streams to a block_e multiple), else
    one whole-stream chunk (short streams — the status-quo single fetch)."""
    return block_e if 0 < block_e < ep and ep % block_e == 0 else ep


@functools.partial(jax.jit,
                   static_argnames=("n_levels", "block_m", "block_e",
                                    "interpret"))
def a2_count_kernel(etypes, tlo, thi, events, *, n_levels: int,
                    block_m: int = LANES, block_e: int = DEFAULT_BLOCK_E,
                    interpret: bool = False):
    """pallas_call wrapper (fresh machines).

    Args:
      etypes/tlo/thi: i32[NP, M] (level-major, padded rows = PAD_ROW_TYPE /
        zero-width intervals); M multiple of ``block_m``.
      events: i32[2, EP] (types; times).
      n_levels: true episode size N (static).
    Returns i32[8, M]; row 0 = counts.

    Delegates to the state-carried launch with empty machines, so the
    non-streaming API pays the same chunked event ``BlockSpec`` (no
    whole-stream broadcast) as the streaming hot path.
    """
    np_, m = etypes.shape
    s0 = jnp.full((np_, m), TIME_NEG_INF, jnp.int32)
    c0 = jnp.zeros((SUBLANES, m), jnp.int32)
    cnt, _ = a2_count_state_kernel(etypes, tlo, thi, events, s0, c0,
                                   n_levels=n_levels, block_m=block_m,
                                   block_e=block_e, interpret=interpret)
    return cnt


@functools.partial(jax.jit,
                   static_argnames=("n_levels", "block_m", "block_e",
                                    "interpret"))
def a2_count_state_kernel(etypes, tlo, thi, events, s, cnt, *, n_levels: int,
                          block_m: int = LANES,
                          block_e: int = DEFAULT_BLOCK_E,
                          interpret: bool = False):
    """State-in/state-out pallas_call wrapper.

    State operands (i32, kernel layout): ``s`` (NP, M) last-accepted
    timestamp per level (TIME_NEG_INF = empty); ``cnt`` (8, M) cumulative
    counts, row 0 meaningful. Returns (cnt, s) advanced past ``events``;
    state inputs are aliased onto the outputs (donated) — never reuse the
    passed arrays. Events are walked in ``block_e`` chunks on the second
    (``arbitrary``) grid axis with the state carried on-chip between
    chunks.
    """
    np_, m = etypes.shape
    ep = events.shape[1]
    be = _block_e(ep, block_e)
    grid = (m // block_m, ep // be)
    kernel = functools.partial(_a2_state_kernel, n_levels)
    tile = lambda i, j: (0, i)  # noqa: E731 — episode tile, chunk-invariant
    out_shape = [jax.ShapeDtypeStruct((SUBLANES, m), jnp.int32),
                 jax.ShapeDtypeStruct((np_, m), jnp.int32)]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((np_, block_m), tile),
            pl.BlockSpec((np_, block_m), tile),
            pl.BlockSpec((np_, block_m), tile),
            pl.BlockSpec((events.shape[0], be), lambda i, j: (0, j)),
            pl.BlockSpec((np_, block_m), tile),
            pl.BlockSpec((SUBLANES, block_m), tile),
        ],
        out_specs=[pl.BlockSpec((SUBLANES, block_m), tile),
                   pl.BlockSpec((np_, block_m), tile)],
        out_shape=out_shape,
        input_output_aliases={5: 0, 4: 1},
        compiler_params=SEQ_GRID,
        interpret=interpret,
    )(etypes, tlo, thi, events, s, cnt)


# --------------------------------------------------------------------------
# Segment-parallel MapConcatenate (paper §5.2.2) — single-slot machines
# --------------------------------------------------------------------------


def _pad_phase_rows(x, np_: int):
    """[K, BM] phase block → (NP, BM) output brick (rows >= K zero)."""
    k, bm = x.shape
    x = x.astype(jnp.int32)
    if k == np_:
        return x
    return jnp.concatenate([x, jnp.zeros((np_ - k, bm), jnp.int32)], axis=0)


def _a2_mapc_body(n_levels: int, et, tlo, thi, starts, tau_lo, tau_hi,
                  w_row, ev_ref):
    """Per-event step for the K = N phase-shifted single-slot machines of
    one time segment (the kernel analogue of
    ``core.mapconcat._segment_scan`` with Obs. 5.1 state)."""
    k = n_levels
    np_, bm = et.shape

    def body(j, carry):
        s, cnt, a, b, done, a_set = carry
        e = ev_ref[0, SEG_TYPE, j]
        t = ev_ref[0, SEG_TIME, j]
        match = et == e                                     # (NP, BM)
        delta = t - s                                       # (K, NP, BM)
        ok = (delta > tlo[None]) & (delta <= thi[None])
        ok_shift = jnp.concatenate(
            [jnp.ones((k, 1, bm), jnp.bool_), ok[:, :-1, :]], axis=1)
        advance = match[None] & ok_shift                    # (K, NP, BM)
        raw_complete = advance[:, n_levels - 1, :]          # (K, BM)
        store = advance.at[:, n_levels - 1, :].set(False)
        s2 = jnp.where(store, t, s)
        s2 = jnp.where(raw_complete[:, None, :], TIME_NEG_INF, s2)
        # zone gating (single source of truth: core.mapconcat.stitch_zones)
        seg_z, a_z, live_z, cross_z = stitch_zones(t, tau_lo, tau_hi, w_row)
        in_window = (t > starts) & live_z & ~done           # (K, BM)
        live = in_window & (e != PAD_TYPE)
        s = jnp.where(live[:, None, :], s2, s)
        complete = raw_complete & in_window
        in_seg = complete & seg_z
        cnt = cnt + in_seg.astype(jnp.int32)
        rec_a = in_seg & ~a_set & a_z
        a = jnp.where(rec_a, t, a)
        a_set = a_set | rec_a
        crossing = complete & cross_z
        b = jnp.where(crossing, t, b)
        done = done | crossing
        return s, cnt, a, b, done, a_set

    return body


def _mapc_fold_and_emit(n_levels: int, seg, ovf_any, a_ref, c_ref, b_ref,
                        f_ref, ovf_ref):
    """Fused Concatenate: fold this segment's tuple onto the carried tuple
    held in the revisited output blocks (shared by the A1 and A2 segmented
    kernels). ``seg`` = (a, cnt, b) each (K, BM); ``ovf_any`` (BM,) bool."""
    k = n_levels
    np_, bm = a_ref.shape
    a, cnt, b = seg
    zf = jnp.zeros((k, bm), jnp.bool_)
    ovf_row = jnp.broadcast_to(ovf_any[None, :].astype(jnp.int32),
                               ovf_ref.shape)
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _():
        a_ref[...] = _pad_phase_rows(a, np_)
        c_ref[...] = _pad_phase_rows(cnt, np_)
        b_ref[...] = _pad_phase_rows(b, np_)
        f_ref[...] = jnp.zeros((np_, bm), jnp.int32)
        ovf_ref[...] = ovf_row

    @pl.when(p > 0)
    def _():
        carry = (a_ref[...][:k], c_ref[...][:k], b_ref[...][:k],
                 f_ref[...][:k] != 0)
        a2, c2, b2, f2 = fold_pair_unrolled(carry, (a, cnt, b, zf), k)
        a_ref[...] = _pad_phase_rows(a2, np_)
        c_ref[...] = _pad_phase_rows(c2, np_)
        b_ref[...] = _pad_phase_rows(b2, np_)
        f_ref[...] = _pad_phase_rows(f2, np_)
        ovf_ref[...] = ovf_ref[...] | ovf_row


def _a2_mapc_kernel(n_levels: int, et_ref, tlo_ref, thi_ref, cum_ref, w_ref,
                    ev_ref, a_ref, c_ref, b_ref, f_ref, ovf_ref):
    """One (episode tile × time segment) grid step: Map this segment with
    K phase machines, then fold its tuple onto the carried Concatenate
    state."""
    et = et_ref[...]
    tlo = tlo_ref[...]
    thi = thi_ref[...]
    np_, bm = et.shape
    k = n_levels
    tau_lo = ev_ref[0, SEG_TAU_LO, 0]
    tau_hi = ev_ref[0, SEG_TAU_HI, 0]
    w_row = w_ref[0, :]                        # (BM,) per-episode max span
    starts = tau_lo - cum_ref[...][:k]         # (K, BM) phase start times
    body = _a2_mapc_body(n_levels, et, tlo, thi, starts, tau_lo, tau_hi,
                         w_row, ev_ref)
    s0 = jnp.full((k, np_, bm), TIME_NEG_INF, jnp.int32)
    zi = jnp.zeros((k, bm), jnp.int32)
    zb = jnp.zeros((k, bm), jnp.bool_)
    a0 = jnp.full((k, bm), tau_lo, jnp.int32)
    b0 = jnp.full((k, bm), tau_hi, jnp.int32)
    _, cnt, a, b, _, _ = jax.lax.fori_loop(
        0, ev_ref.shape[2], body, (s0, zi, a0, b0, zb, zb))
    _mapc_fold_and_emit(n_levels, (a, cnt, b), jnp.zeros(bm, jnp.bool_),
                        a_ref, c_ref, b_ref, f_ref, ovf_ref)


@functools.partial(jax.jit,
                   static_argnames=("n_levels", "block_m", "interpret"))
def a2_mapconcat_kernel(etypes, tlo, thi, cum, w, segs, *, n_levels: int,
                        block_m: int = LANES, interpret: bool = False):
    """Segment-parallel single-slot pallas_call: grid = (episode tile ×
    time segment), Map + fused Concatenate in one launch.

    Args (see ``ops.mapconcat_layout`` / ``ops.segment_bricks``):
      etypes/tlo/thi: i32[NP, M] level-major bricks (``tlo`` already
        shifted for the inclusive lower bound — A2 counts the relaxed
        batch);
      cum: i32[NP, M] phase-start offsets (row k = Σ_{i<k} thi);
      w: i32[8, M] per-episode max span, row 0 meaningful;
      segs: i32[P, 5, LW] per-segment event windows
        (types/times/dup/τ_p/τ_{p+1}).
    Returns (a, c, b, f) each i32[NP, M] — the stitched tuple, phase rows
    0..N-1 meaningful — plus ovf i32[8, M] (always zero for A2; kept for
    output-shape parity with the A1 variant). Row 0 of ``c`` is the count,
    row 0 of ``f`` the unmatched flag.
    """
    np_, m = etypes.shape
    p = segs.shape[0]
    grid = (m // block_m, p)
    kernel = functools.partial(_a2_mapc_kernel, n_levels)
    tile = lambda i, j: (0, i)  # noqa: E731
    out_shape = ([jax.ShapeDtypeStruct((np_, m), jnp.int32)] * 4
                 + [jax.ShapeDtypeStruct((SUBLANES, m), jnp.int32)])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((np_, block_m), tile),
            pl.BlockSpec((np_, block_m), tile),
            pl.BlockSpec((np_, block_m), tile),
            pl.BlockSpec((np_, block_m), tile),
            pl.BlockSpec((SUBLANES, block_m), tile),
            pl.BlockSpec((1, SEG_ROWS, segs.shape[2]),
                         lambda i, j: (j, 0, 0)),
        ],
        out_specs=([pl.BlockSpec((np_, block_m), tile)] * 4
                   + [pl.BlockSpec((SUBLANES, block_m), tile)]),
        out_shape=out_shape,
        compiler_params=SEQ_GRID,
        interpret=interpret,
    )(etypes, tlo, thi, cum, w, segs)
