"""Pallas TPU kernel: A2 (single-slot) episode counting.

Computation-to-core mapping (the TPU re-derivation of the paper's PTPE):
episodes live on the 128-wide **lane** axis, episode levels on the
**sublane** axis, so one VPU op advances 8×128 state machines. The grid
tiles the episode batch; each program walks the whole event stream with a
``fori_loop``, carrying the (levels × episodes) timestamp tile and the count
row as loop values (VREG/VMEM resident).

Layouts (all i32):
  etypes  (NP,  BM)  episode types, level-major  (NP = levels padded to 8k)
  tlo/thi (NP,  BM)  edge bounds, row i = edge i→i+1 (row N-1.. padded)
  events  (2, EP)    row 0 = types, row 1 = times (EP = events padded)
  count   (8, BM)    output; row 0 holds the counts (8 sublanes for tiling)

The event stream is re-read by every grid step (episode tile); on a real
TPU the (2, EP) block would be served from VMEM once per program — the
stream is tiny next to the state tile math, so this is compute-, not
memory-bound (§Roofline in EXPERIMENTS.md).

Event padding uses type = PAD_TYPE (-1); level-row padding uses -2, so a
padded event never matches a padded row. Validated in ``interpret=True``
against ``ref.a2_count_ref`` (tests/test_kernels.py sweeps shapes+dtypes).

State-in/state-out variant (``a2_count_state_kernel``): the single-slot
timestamp tile and the count row become kernel I/O with in-place aliasing,
so chunk-by-chunk streaming stays on-chip. A single slot per level is
complete machine state (Obs. 5.1), so carried chunked counting is
unconditionally bit-exact under any partitioning — no tie-group caveat.
Pack/unpack to ``core.count_a2.A2State`` lives in ``ops.a2_state_layout``
/ ``ops.a2_state_unpack``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.events import TIME_NEG_INF

LANES = 128
SUBLANES = 8
PAD_ROW_TYPE = -2


def _a2_body(n_levels: int, et, tlo, thi, ev_ref):
    """Per-event step over the (s, cnt) carry — shared by the fresh-state
    and state-carried kernels."""
    np_, bm = et.shape

    def body(j, carry):
        s, cnt = carry
        e = ev_ref[0, j]
        t = ev_ref[1, j]
        match = et == e                                   # (NP, BM)
        delta = t - s                                     # (NP, BM)
        ok = (delta > tlo) & (delta <= thi)               # row i: edge i→i+1
        # advance row 0 = match; row i>0 = match & ok[i-1]
        ok_shift = jnp.concatenate(
            [jnp.ones((1, bm), jnp.bool_), ok[:-1, :]], axis=0)
        advance = match & ok_shift                        # (NP, BM)
        complete = advance[n_levels - 1, :]               # (BM,)
        store = advance.at[n_levels - 1, :].set(False)
        s = jnp.where(store, t, s)
        s = jnp.where(complete[None, :], TIME_NEG_INF, s)
        cnt = cnt + complete.astype(jnp.int32)[None, :]
        return s, cnt

    return body


def _a2_kernel(n_levels: int, et_ref, tlo_ref, thi_ref, ev_ref, cnt_ref):
    """One episode tile × all events. n_levels is static (>= 2)."""
    et = et_ref[...]          # (NP, BM)
    tlo = tlo_ref[...]        # (NP, BM) row i = edge (i, i+1)
    thi = thi_ref[...]
    np_, bm = et.shape
    n_events = ev_ref.shape[1]
    body = _a2_body(n_levels, et, tlo, thi, ev_ref)
    s0 = jnp.full((np_, bm), TIME_NEG_INF, jnp.int32)
    c0 = jnp.zeros((1, bm), jnp.int32)
    _, cnt = jax.lax.fori_loop(0, n_events, body, (s0, c0))
    cnt_ref[...] = jnp.broadcast_to(cnt, cnt_ref.shape)


def _a2_state_kernel(n_levels: int, et_ref, tlo_ref, thi_ref, ev_ref,
                     sin_ref, cin_ref, cnt_ref, sout_ref):
    """State-carried variant: resume from the input tile, emit the advanced
    tile (aliased in place by the wrapper)."""
    et = et_ref[...]
    tlo = tlo_ref[...]
    thi = thi_ref[...]
    n_events = ev_ref.shape[1]
    body = _a2_body(n_levels, et, tlo, thi, ev_ref)
    s, cnt = jax.lax.fori_loop(0, n_events, body,
                               (sin_ref[...], cin_ref[0:1, :]))
    cnt_ref[...] = jnp.broadcast_to(cnt, cnt_ref.shape)
    sout_ref[...] = s


@functools.partial(jax.jit,
                   static_argnames=("n_levels", "block_m", "interpret"))
def a2_count_kernel(etypes, tlo, thi, events, *, n_levels: int,
                    block_m: int = LANES, interpret: bool = False):
    """pallas_call wrapper.

    Args:
      etypes/tlo/thi: i32[NP, M] (level-major, padded rows = PAD_ROW_TYPE /
        zero-width intervals); M multiple of ``block_m``.
      events: i32[2, EP] (types; times).
      n_levels: true episode size N (static).
    Returns i32[8, M]; row 0 = counts.
    """
    np_, m = etypes.shape
    grid = (m // block_m,)
    kernel = functools.partial(_a2_kernel, n_levels)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((np_, block_m), lambda i: (0, i)),
            pl.BlockSpec((np_, block_m), lambda i: (0, i)),
            pl.BlockSpec((np_, block_m), lambda i: (0, i)),
            pl.BlockSpec(events.shape, lambda i: (0, 0)),  # stream: every tile
        ],
        out_specs=pl.BlockSpec((SUBLANES, block_m), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((SUBLANES, m), jnp.int32),
        interpret=interpret,
    )(etypes, tlo, thi, events)


@functools.partial(jax.jit,
                   static_argnames=("n_levels", "block_m", "interpret"))
def a2_count_state_kernel(etypes, tlo, thi, events, s, cnt, *, n_levels: int,
                          block_m: int = LANES, interpret: bool = False):
    """State-in/state-out pallas_call wrapper.

    State operands (i32, kernel layout): ``s`` (NP, M) last-accepted
    timestamp per level (TIME_NEG_INF = empty); ``cnt`` (8, M) cumulative
    counts, row 0 meaningful. Returns (cnt, s) advanced past ``events``;
    state inputs are aliased onto the outputs (donated) — never reuse the
    passed arrays.
    """
    np_, m = etypes.shape
    grid = (m // block_m,)
    kernel = functools.partial(_a2_state_kernel, n_levels)
    out_shape = [jax.ShapeDtypeStruct((SUBLANES, m), jnp.int32),
                 jax.ShapeDtypeStruct((np_, m), jnp.int32)]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((np_, block_m), lambda i: (0, i)),
            pl.BlockSpec((np_, block_m), lambda i: (0, i)),
            pl.BlockSpec((np_, block_m), lambda i: (0, i)),
            pl.BlockSpec(events.shape, lambda i: (0, 0)),
            pl.BlockSpec((np_, block_m), lambda i: (0, i)),
            pl.BlockSpec((SUBLANES, block_m), lambda i: (0, i)),
        ],
        out_specs=[pl.BlockSpec((SUBLANES, block_m), lambda i: (0, i)),
                   pl.BlockSpec((np_, block_m), lambda i: (0, i))],
        out_shape=out_shape,
        input_output_aliases={5: 0, 4: 1},
        interpret=interpret,
    )(etypes, tlo, thi, events, s, cnt)
