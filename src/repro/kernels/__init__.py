"""Pallas TPU kernels for episode counting — the paper's GPGPU mining
loop re-derived for the TPU VPU with the paper's *two-axis*
computation-to-core mapping: episodes on lanes/sublanes (grid axis 0,
parallel) and the **time axis on grid axis 1** — event chunks for the
PTPE kernels, time segments for the MapConcatenate kernels — with
``arbitrary`` (sequential) semantics carrying state across steps.

Modules:
  a1_count — bounded-list Algorithm 1 (``a1_count_kernel``), its
    state-in/state-out streaming variant (``a1_count_state_kernel``: the
    (NP, LCAP, BM) timestamp brick, one-hot write-pointer mask, and
    count/ovf rows are kernel I/O with in-place aliasing), and the
    segment-parallel ``a1_mapconcat_kernel`` (§5.2.2: each grid step runs
    K = N phase-shifted machines over one segment's event window and
    folds its (a, count, b) tuple onto the carried Concatenate state —
    Map + Concatenate fused into one launch).
  a2_count — single-slot Algorithm 3 (``a2_count_kernel``), the
    streaming analogue (``a2_count_state_kernel``), and the single-slot
    segmented variant (``a2_mapconcat_kernel``) used by the two-pass
    cull.
  ops — dispatch policy (TPU compiled / interpret mode / decline to the
    XLA scans), host↔kernel layout contract (``episode_layout``,
    ``event_brick``, ``a1_state_layout``/``a1_state_unpack``,
    ``a2_state_layout``/``a2_state_unpack``, ``mapconcat_layout``,
    ``segment_bricks``), the instrumented entry points (``a1_state_call``,
    ``a2_state_call``, ``a1_mapconcat_tuples``/``a2_mapconcat_tuples``,
    ``a1_mapconcat_count``/``a2_mapconcat_count``, vmapped fused variants
    for the cross-session batcher), and the one-shot wrappers.
  ref — pure-jnp layout oracles the interpret-mode tests pin the kernels
    against.

Event streaming: the stream is never broadcast whole. Event bricks are
blocked on the second grid axis (``block_e`` events per step, default
``DEFAULT_BLOCK_E``) and DMA'd/double-buffered per step while the machine
state lives in output blocks revisited across the axis — fresh-state and
state-carried wrappers share the same chunked launch, so VMEM bounds the
*chunk*, not the stream. Segmented kernels block by time segment instead
(one (types/times/dup/τ_p/τ_{p+1}) brick per step).

Layout contract for the carried state (see ``ops``): episode-major host
state (``core.count_a1.A1State`` [M, N, L] / ``core.count_a2.A2State``
[M, N]) packs to level-major lane/sublane bricks — s (NP, LCAP, MP),
po one-hot (NP, LCAP, MP), cnt/ovf (8, MP) with row 0 meaningful —
padded with TIME_NEG_INF / PAD_ROW_TYPE so padded lanes and rows are
inert. Chunked carried calls are bit-identical to one call on the
concatenation (A1 additionally requires chunk boundaries not to split
timestamp tie groups; ``core.streaming.StreamingCounter`` holds back the
trailing tie group to guarantee that). The segmented kernels share their
phase starts (``core.mapconcat.phase_cum``), stitch zones
(``stitch_zones``), and fold semantics (``fold_pair_unrolled``) with the
XLA MapConcatenate so the two paths cannot drift.
"""
