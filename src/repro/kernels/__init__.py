"""Pallas TPU kernels for episode counting — the paper's GPGPU mining
loop re-derived for the TPU VPU (episodes on lanes, levels on sublanes).

Modules:
  a1_count — bounded-list Algorithm 1 (``a1_count_kernel``) and its
    state-in/state-out streaming variant (``a1_count_state_kernel``): the
    (NP, LCAP, BM) timestamp brick, one-hot write-pointer mask, and
    count/ovf rows are kernel I/O with in-place aliasing, so carried
    window-by-window counting stays on-chip.
  a2_count — single-slot Algorithm 3 (``a2_count_kernel``) and the
    single-slot streaming analogue (``a2_count_state_kernel``).
  ops — dispatch policy (TPU compiled / interpret mode / decline to the
    XLA scans), host↔kernel layout contract (``episode_layout``,
    ``event_brick``, ``a1_state_layout``/``a1_state_unpack``,
    ``a2_state_layout``/``a2_state_unpack``), the instrumented carried
    entry points (``a1_state_call``, ``a2_state_call``, vmapped fused
    variants for the cross-session batcher), and the one-shot wrappers.
  ref — pure-jnp layout oracles the interpret-mode tests pin the kernels
    against.

Layout contract for the carried state (see ``ops``): episode-major host
state (``core.count_a1.A1State`` [M, N, L] / ``core.count_a2.A2State``
[M, N]) packs to level-major lane/sublane bricks — s (NP, LCAP, MP),
po one-hot (NP, LCAP, MP), cnt/ovf (8, MP) with row 0 meaningful —
padded with TIME_NEG_INF / PAD_ROW_TYPE so padded lanes and rows are
inert. Chunked carried calls are bit-identical to one call on the
concatenation (A1 additionally requires chunk boundaries not to split
timestamp tie groups; ``core.streaming.StreamingCounter`` holds back the
trailing tie group to guarantee that).
"""
