"""Pallas TPU kernel: A1 (bounded-list) episode counting.

Same computation-to-core mapping as ``a2_count`` (episodes on lanes, levels
on sublanes, events chunked on an ``arbitrary`` second grid axis with the
machine state carried in the revisited output blocks) plus a bounded witness
list per level: state is an (NP, LCAP, BM) timestamp brick. The paper's
data-dependent list walk becomes a masked reduction over the LCAP axis; the
circular write pointer is kept as a one-hot (NP, LCAP, BM) mask rotated on
append — no gathers, no scatters, pure VPU ops (this is the TPU answer to
the divergence/local-memory costs the paper profiles in Fig. 10).

Outputs: counts AND a live-eviction flag per episode (see
core/count_a1.py — flagged episodes are recounted exactly by the host).

Event stream layout: i32[3, EP] = (types; times; dup) where dup marks a
same-timestamp real successor (needed for exact eviction accounting).

State-in/state-out variant (``a1_count_state_kernel``): the ``fori_loop``
carry — the (NP, LCAP, BM) timestamp brick, the one-hot write-pointer
mask (i32 0/1), and the count/ovf rows — becomes kernel I/O, with
``input_output_aliases`` donating each state input to its output so a
long-running stream mutates one persistent on-chip allocation per shape
bucket. Chunked carried calls are bit-identical to one call on the
concatenation provided chunk boundaries never split a tie group (the dup
row is computed per chunk; ``core.streaming.StreamingCounter`` holds back
the trailing tie group to guarantee that). Layout contract (pack/unpack
between this brick layout and ``core.count_a1.A1State``'s episode-major
[M, N, L] arrays) lives in ``ops.a1_state_layout`` / ``a1_state_unpack``.

Segment-parallel variant (``a1_mapconcat_kernel``): MapConcatenate
(§5.2.2) on-chip — grid = (episode tile × time segment); each segment runs
K = N phase-shifted bounded-list machines and emits the (a, count, b)
tuple (Fig. 5), with the Concatenate stage fused into the launch (the
stitched tuple carries in revisited output blocks, folded per segment via
``core.mapconcat.fold_pair_unrolled``; the ``unmatched`` flag and the
per-phase live-eviction flags feed the host's exact-recount fallback).
Phase starts and stitch zones are shared with the XLA Map step
(``core.mapconcat.phase_cum`` / ``stitch_zones``) so the paths cannot
drift.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.events import PAD_TYPE, TIME_NEG_INF
from repro.core.mapconcat import stitch_zones

from .a2_count import (DEFAULT_BLOCK_E, LANES, SEG_DUP,
                       SEG_ROWS, SEG_TAU_HI, SEG_TAU_LO, SEG_TIME, SEG_TYPE,
                       SEQ_GRID, SUBLANES, _block_e, _mapc_fold_and_emit)


def _a1_body(n_levels: int, et, tlo, thi, ev_ref):
    """Per-event step over the (s, po, cnt, ovf) carry — shared by the
    fresh-state and state-carried kernels."""
    np_, bm = et.shape

    def body(j, carry):
        s, po, cnt, ovf = carry  # s,(NP,L,BM) po one-hot,(NP,L,BM)
        e = ev_ref[0, j]
        t = ev_ref[1, j]
        dup = ev_ref[2, j] != 0
        match = et == e                                     # (NP, BM)
        delta = t - s                                       # (NP, L, BM)
        witness = (delta > tlo[:, None, :]) & (delta <= thi[:, None, :])
        ok = witness.any(axis=1)                            # (NP, BM) row i =
        ok_shift = jnp.concatenate(                         # edge i→i+1 holds
            [jnp.ones((1, bm), jnp.bool_), ok[:-1, :]], axis=0)
        advance = match & ok_shift
        complete = advance[n_levels - 1, :]                 # (BM,)
        store = advance.at[n_levels - 1, :].set(False)
        store = store & ~complete[None, :]
        write = store[:, None, :] & po                      # (NP, L, BM)
        # live-eviction: evicted witness may still have a same-tick or
        # lower-bounded consumer (see core/count_a1.py docstring)
        v = jnp.where(write, s, TIME_NEG_INF).max(axis=1)   # (NP, BM)
        live = (v > TIME_NEG_INF) & (t - v <= thi) & ((tlo > 0) | dup)
        ovf = ovf | live.any(axis=0)[None, :].astype(jnp.int32)
        s = jnp.where(write, t, s)
        po = jnp.where(store[:, None, :], jnp.roll(po, 1, axis=1), po)
        s = jnp.where(complete[None, None, :], TIME_NEG_INF, s)
        po0 = jnp.zeros_like(po).at[:, 0, :].set(True)
        po = jnp.where(complete[None, None, :], po0, po)
        cnt = cnt + complete.astype(jnp.int32)[None, :]
        return s, po, cnt, ovf

    return body


def _a1_state_kernel(n_levels: int, lcap: int, et_ref, tlo_ref, thi_ref,
                     ev_ref, sin_ref, poin_ref, cin_ref, oin_ref,
                     cnt_ref, ovf_ref, sout_ref, poout_ref):
    """One (episode tile × event chunk) grid step: resume the machines from
    the carried output blocks (seeded from the state inputs at chunk 0) and
    advance them past this chunk's events."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        sout_ref[...] = sin_ref[...]
        poout_ref[...] = poin_ref[...]
        cnt_ref[...] = cin_ref[...]
        ovf_ref[...] = oin_ref[...]

    et = et_ref[...]
    tlo = tlo_ref[...]
    thi = thi_ref[...]
    body = _a1_body(n_levels, et, tlo, thi, ev_ref)
    s, po, cnt, ovf = jax.lax.fori_loop(
        0, ev_ref.shape[1], body,
        (sout_ref[...], poout_ref[...] != 0, cnt_ref[0:1, :],
         ovf_ref[0:1, :]))
    cnt_ref[...] = jnp.broadcast_to(cnt, cnt_ref.shape)
    ovf_ref[...] = jnp.broadcast_to(ovf, ovf_ref.shape)
    sout_ref[...] = s
    poout_ref[...] = po.astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("n_levels", "lcap", "block_m", "block_e",
                              "interpret"))
def a1_count_kernel(etypes, tlo, thi, events, *, n_levels: int,
                    lcap: int = 4, block_m: int = LANES,
                    block_e: int = DEFAULT_BLOCK_E,
                    interpret: bool = False):
    """pallas_call wrapper (fresh machines). See a2_count_kernel; events
    here are i32[3, EP] (types; times; dup). Returns
    (counts i32[8, M], ovf i32[8, M]), row 0 meaningful. Delegates to the
    state-carried launch with empty machines so the one-shot API shares the
    chunked event ``BlockSpec`` (no whole-stream broadcast) — the final
    state bricks it emits are discarded, a conscious HBM-write trade for
    one kernel body across both call styles."""
    np_, m = etypes.shape
    s0 = jnp.full((np_, lcap, m), TIME_NEG_INF, jnp.int32)
    po0 = jnp.zeros((np_, lcap, m), jnp.int32).at[:, 0, :].set(1)
    c0 = jnp.zeros((SUBLANES, m), jnp.int32)
    o0 = jnp.zeros((SUBLANES, m), jnp.int32)
    cnt, ovf, _, _ = a1_count_state_kernel(
        etypes, tlo, thi, events, s0, po0, c0, o0, n_levels=n_levels,
        lcap=lcap, block_m=block_m, block_e=block_e, interpret=interpret)
    return cnt, ovf


@functools.partial(
    jax.jit, static_argnames=("n_levels", "lcap", "block_m", "block_e",
                              "interpret"))
def a1_count_state_kernel(etypes, tlo, thi, events, s, po, cnt, ovf, *,
                          n_levels: int, lcap: int = 4,
                          block_m: int = LANES,
                          block_e: int = DEFAULT_BLOCK_E,
                          interpret: bool = False):
    """State-in/state-out pallas_call wrapper.

    State operands (all i32, kernel brick layout — see ``ops``):
      s    (NP, LCAP, M)  circular timestamp brick (TIME_NEG_INF = empty)
      po   (NP, LCAP, M)  one-hot write-pointer mask (0/1)
      cnt  (8, M)         cumulative counts, row 0 meaningful
      ovf  (8, M)         sticky live-eviction flags, row 0 meaningful

    Returns (cnt, ovf, s, po) advanced past ``events``; each state input is
    aliased onto its output (donated), so never reuse the passed arrays.
    Events are walked in ``block_e`` chunks on the second (``arbitrary``)
    grid axis with the state carried on-chip between chunks.
    """
    np_, m = etypes.shape
    ep = events.shape[1]
    be = _block_e(ep, block_e)
    grid = (m // block_m, ep // be)
    kernel = functools.partial(_a1_state_kernel, n_levels, lcap)
    tile = lambda i, j: (0, i)  # noqa: E731 — episode tile, chunk-invariant
    tile3 = lambda i, j: (0, 0, i)  # noqa: E731
    out_shape = [jax.ShapeDtypeStruct((SUBLANES, m), jnp.int32),
                 jax.ShapeDtypeStruct((SUBLANES, m), jnp.int32),
                 jax.ShapeDtypeStruct((np_, lcap, m), jnp.int32),
                 jax.ShapeDtypeStruct((np_, lcap, m), jnp.int32)]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((np_, block_m), tile),
            pl.BlockSpec((np_, block_m), tile),
            pl.BlockSpec((np_, block_m), tile),
            pl.BlockSpec((events.shape[0], be), lambda i, j: (0, j)),
            pl.BlockSpec((np_, lcap, block_m), tile3),
            pl.BlockSpec((np_, lcap, block_m), tile3),
            pl.BlockSpec((SUBLANES, block_m), tile),
            pl.BlockSpec((SUBLANES, block_m), tile),
        ],
        out_specs=[pl.BlockSpec((SUBLANES, block_m), tile),
                   pl.BlockSpec((SUBLANES, block_m), tile),
                   pl.BlockSpec((np_, lcap, block_m), tile3),
                   pl.BlockSpec((np_, lcap, block_m), tile3)],
        out_shape=out_shape,
        input_output_aliases={6: 0, 7: 1, 4: 2, 5: 3},
        compiler_params=SEQ_GRID,
        interpret=interpret,
    )(etypes, tlo, thi, events, s, po, cnt, ovf)


# --------------------------------------------------------------------------
# Segment-parallel MapConcatenate (paper §5.2.2) — bounded-list machines
# --------------------------------------------------------------------------


def _a1_mapc_body(n_levels: int, lcap: int, et, tlo, thi, starts, tau_lo,
                  tau_hi, w_row, ev_ref):
    """Per-event step for the K = N phase-shifted bounded-list machines of
    one time segment (kernel analogue of ``core.mapconcat._segment_scan``'s
    scan body; zone predicates shared via ``core.mapconcat.stitch_zones``).

    Carry: s/po (K, NP, LCAP, BM); cnt/ovf/a/b/done/a_set (K, BM)."""
    k = n_levels
    np_, bm = et.shape

    def body(j, carry):
        s, po, cnt, ovf, a, b, done, a_set = carry
        e = ev_ref[0, SEG_TYPE, j]
        t = ev_ref[0, SEG_TIME, j]
        dup = ev_ref[0, SEG_DUP, j] != 0
        match = et == e                                       # (NP, BM)
        delta = t - s                                         # (K,NP,L,BM)
        witness = ((delta > tlo[None, :, None, :])
                   & (delta <= thi[None, :, None, :]))
        ok = witness.any(axis=2)                              # (K, NP, BM)
        ok_shift = jnp.concatenate(
            [jnp.ones((k, 1, bm), jnp.bool_), ok[:, :-1, :]], axis=1)
        advance = match[None] & ok_shift                      # (K, NP, BM)
        raw_complete = advance[:, n_levels - 1, :]            # (K, BM)
        store = advance.at[:, n_levels - 1, :].set(False)
        store = store & ~raw_complete[:, None, :]
        write = store[:, :, None, :] & po                     # (K,NP,L,BM)
        v = jnp.where(write, s, TIME_NEG_INF).max(axis=2)     # (K, NP, BM)
        live_ev = ((v > TIME_NEG_INF) & (t - v <= thi[None])
                   & ((tlo[None] > 0) | dup))
        ovf2 = ovf | live_ev.any(axis=1)                      # (K, BM)
        s2 = jnp.where(write, t, s)
        po2 = jnp.where(store[:, :, None, :], jnp.roll(po, 1, axis=2), po)
        s2 = jnp.where(raw_complete[:, None, None, :], TIME_NEG_INF, s2)
        po_reset = jnp.zeros_like(po).at[:, :, 0, :].set(True)
        po2 = jnp.where(raw_complete[:, None, None, :], po_reset, po2)
        # zone gating (single source of truth: core.mapconcat.stitch_zones)
        seg_z, a_z, live_z, cross_z = stitch_zones(t, tau_lo, tau_hi, w_row)
        in_window = (t > starts) & live_z & ~done             # (K, BM)
        live = in_window & (e != PAD_TYPE)
        s = jnp.where(live[:, None, None, :], s2, s)
        po = jnp.where(live[:, None, None, :], po2, po)
        ovf = jnp.where(live, ovf2, ovf)
        complete = raw_complete & in_window
        in_seg = complete & seg_z
        cnt = cnt + in_seg.astype(jnp.int32)
        rec_a = in_seg & ~a_set & a_z
        a = jnp.where(rec_a, t, a)
        a_set = a_set | rec_a
        crossing = complete & cross_z
        b = jnp.where(crossing, t, b)
        done = done | crossing
        return s, po, cnt, ovf, a, b, done, a_set

    return body


def _a1_mapc_kernel(n_levels: int, lcap: int, et_ref, tlo_ref, thi_ref,
                    cum_ref, w_ref, ev_ref, a_ref, c_ref, b_ref, f_ref,
                    ovf_ref):
    """One (episode tile × time segment) grid step: Map this segment with
    K phase-shifted bounded-list machines, then fold its tuple onto the
    carried Concatenate state (revisited output blocks)."""
    et = et_ref[...]
    tlo = tlo_ref[...]
    thi = thi_ref[...]
    np_, bm = et.shape
    k = n_levels
    tau_lo = ev_ref[0, SEG_TAU_LO, 0]
    tau_hi = ev_ref[0, SEG_TAU_HI, 0]
    w_row = w_ref[0, :]                        # (BM,) per-episode max span
    starts = tau_lo - cum_ref[...][:k]         # (K, BM) phase start times
    body = _a1_mapc_body(n_levels, lcap, et, tlo, thi, starts, tau_lo,
                         tau_hi, w_row, ev_ref)
    s0 = jnp.full((k, np_, lcap, bm), TIME_NEG_INF, jnp.int32)
    po0 = jnp.zeros((k, np_, lcap, bm), jnp.bool_).at[:, :, 0, :].set(True)
    zi = jnp.zeros((k, bm), jnp.int32)
    zb = jnp.zeros((k, bm), jnp.bool_)
    a0 = jnp.full((k, bm), tau_lo, jnp.int32)
    b0 = jnp.full((k, bm), tau_hi, jnp.int32)
    _, _, cnt, ovf, a, b, _, _ = jax.lax.fori_loop(
        0, ev_ref.shape[2], body, (s0, po0, zi, zb, a0, b0, zb, zb))
    _mapc_fold_and_emit(n_levels, (a, cnt, b), ovf.any(axis=0),
                        a_ref, c_ref, b_ref, f_ref, ovf_ref)


@functools.partial(
    jax.jit, static_argnames=("n_levels", "lcap", "block_m", "interpret"))
def a1_mapconcat_kernel(etypes, tlo, thi, cum, w, segs, *, n_levels: int,
                        lcap: int = 4, block_m: int = LANES,
                        interpret: bool = False):
    """Segment-parallel bounded-list pallas_call: grid = (episode tile ×
    time segment), Map + fused Concatenate in one launch.

    Args as ``a2_mapconcat_kernel`` (``tlo`` unshifted — A1 keeps the
    strict lower bound). Returns (a, c, b, f) each i32[NP, M] — the
    stitched tuple, phase rows 0..N-1 meaningful — plus ovf i32[8, M]
    whose row 0 ORs the live-eviction flags over every (segment, phase).
    Row 0 of ``c`` is the count; an episode needs the host's exact
    fallback iff ``f[0] | ovf[0]``.
    """
    np_, m = etypes.shape
    p = segs.shape[0]
    grid = (m // block_m, p)
    kernel = functools.partial(_a1_mapc_kernel, n_levels, lcap)
    tile = lambda i, j: (0, i)  # noqa: E731
    out_shape = ([jax.ShapeDtypeStruct((np_, m), jnp.int32)] * 4
                 + [jax.ShapeDtypeStruct((SUBLANES, m), jnp.int32)])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((np_, block_m), tile),
            pl.BlockSpec((np_, block_m), tile),
            pl.BlockSpec((np_, block_m), tile),
            pl.BlockSpec((np_, block_m), tile),
            pl.BlockSpec((SUBLANES, block_m), tile),
            pl.BlockSpec((1, SEG_ROWS, segs.shape[2]),
                         lambda i, j: (j, 0, 0)),
        ],
        out_specs=([pl.BlockSpec((np_, block_m), tile)] * 4
                   + [pl.BlockSpec((SUBLANES, block_m), tile)]),
        out_shape=out_shape,
        compiler_params=SEQ_GRID,
        interpret=interpret,
    )(etypes, tlo, thi, cum, w, segs)
