"""Pallas TPU kernel: A1 (bounded-list) episode counting.

Same computation-to-core mapping as ``a2_count`` (episodes on lanes, levels
on sublanes) plus a bounded witness list per level: state is an
(NP, LCAP, BM) timestamp brick. The paper's data-dependent list walk becomes
a masked reduction over the LCAP axis; the circular write pointer is kept as
a one-hot (NP, LCAP, BM) mask rotated on append — no gathers, no scatters,
pure VPU ops (this is the TPU answer to the divergence/local-memory costs
the paper profiles in Fig. 10).

Outputs: counts AND a live-eviction flag per episode (see
core/count_a1.py — flagged episodes are recounted exactly by the host).

Event stream layout: i32[3, EP] = (types; times; dup) where dup marks a
same-timestamp real successor (needed for exact eviction accounting).

State-in/state-out variant (``a1_count_state_kernel``): the ``fori_loop``
carry — the (NP, LCAP, BM) timestamp brick, the one-hot write-pointer
mask (i32 0/1), and the count/ovf rows — becomes kernel I/O, with
``input_output_aliases`` donating each state input to its output so a
long-running stream mutates one persistent on-chip allocation per shape
bucket. Chunked carried calls are bit-identical to one call on the
concatenation provided chunk boundaries never split a tie group (the dup
row is computed per chunk; ``core.streaming.StreamingCounter`` holds back
the trailing tie group to guarantee that). Layout contract (pack/unpack
between this brick layout and ``core.count_a1.A1State``'s episode-major
[M, N, L] arrays) lives in ``ops.a1_state_layout`` / ``a1_state_unpack``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.events import TIME_NEG_INF

from .a2_count import LANES, SUBLANES, PAD_ROW_TYPE


def _a1_body(n_levels: int, et, tlo, thi, ev_ref):
    """Per-event step over the (s, po, cnt, ovf) carry — shared by the
    fresh-state and state-carried kernels."""
    np_, bm = et.shape

    def body(j, carry):
        s, po, cnt, ovf = carry  # s,(NP,L,BM) po one-hot,(NP,L,BM)
        e = ev_ref[0, j]
        t = ev_ref[1, j]
        dup = ev_ref[2, j] != 0
        match = et == e                                     # (NP, BM)
        delta = t - s                                       # (NP, L, BM)
        witness = (delta > tlo[:, None, :]) & (delta <= thi[:, None, :])
        ok = witness.any(axis=1)                            # (NP, BM) row i =
        ok_shift = jnp.concatenate(                         # edge i→i+1 holds
            [jnp.ones((1, bm), jnp.bool_), ok[:-1, :]], axis=0)
        advance = match & ok_shift
        complete = advance[n_levels - 1, :]                 # (BM,)
        store = advance.at[n_levels - 1, :].set(False)
        store = store & ~complete[None, :]
        write = store[:, None, :] & po                      # (NP, L, BM)
        # live-eviction: evicted witness may still have a same-tick or
        # lower-bounded consumer (see core/count_a1.py docstring)
        v = jnp.where(write, s, TIME_NEG_INF).max(axis=1)   # (NP, BM)
        live = (v > TIME_NEG_INF) & (t - v <= thi) & ((tlo > 0) | dup)
        ovf = ovf | live.any(axis=0)[None, :].astype(jnp.int32)
        s = jnp.where(write, t, s)
        po = jnp.where(store[:, None, :], jnp.roll(po, 1, axis=1), po)
        s = jnp.where(complete[None, None, :], TIME_NEG_INF, s)
        po0 = jnp.zeros_like(po).at[:, 0, :].set(True)
        po = jnp.where(complete[None, None, :], po0, po)
        cnt = cnt + complete.astype(jnp.int32)[None, :]
        return s, po, cnt, ovf

    return body


def _a1_kernel(n_levels: int, lcap: int, et_ref, tlo_ref, thi_ref, ev_ref,
               cnt_ref, ovf_ref):
    et = et_ref[...]      # (NP, BM)
    tlo = tlo_ref[...]    # (NP, BM) row i = edge i→i+1 (incoming of level i+1)
    thi = thi_ref[...]
    np_, bm = et.shape
    n_events = ev_ref.shape[1]
    body = _a1_body(n_levels, et, tlo, thi, ev_ref)
    s0 = jnp.full((np_, lcap, bm), TIME_NEG_INF, jnp.int32)
    po0 = jnp.zeros((np_, lcap, bm), jnp.bool_).at[:, 0, :].set(True)
    c0 = jnp.zeros((1, bm), jnp.int32)
    o0 = jnp.zeros((1, bm), jnp.int32)
    _, _, cnt, ovf = jax.lax.fori_loop(0, n_events, body,
                                       (s0, po0, c0, o0))
    cnt_ref[...] = jnp.broadcast_to(cnt, cnt_ref.shape)
    ovf_ref[...] = jnp.broadcast_to(ovf, ovf_ref.shape)


def _a1_state_kernel(n_levels: int, lcap: int, et_ref, tlo_ref, thi_ref,
                     ev_ref, sin_ref, poin_ref, cin_ref, oin_ref,
                     cnt_ref, ovf_ref, sout_ref, poout_ref):
    """State-carried variant: resume the machines from the input brick and
    emit the advanced brick (aliased in place by the wrapper)."""
    et = et_ref[...]
    tlo = tlo_ref[...]
    thi = thi_ref[...]
    n_events = ev_ref.shape[1]
    body = _a1_body(n_levels, et, tlo, thi, ev_ref)
    s, po, cnt, ovf = jax.lax.fori_loop(
        0, n_events, body,
        (sin_ref[...], poin_ref[...] != 0, cin_ref[0:1, :], oin_ref[0:1, :]))
    cnt_ref[...] = jnp.broadcast_to(cnt, cnt_ref.shape)
    ovf_ref[...] = jnp.broadcast_to(ovf, ovf_ref.shape)
    sout_ref[...] = s
    poout_ref[...] = po.astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("n_levels", "lcap", "block_m", "interpret"))
def a1_count_kernel(etypes, tlo, thi, events, *, n_levels: int,
                    lcap: int = 4, block_m: int = LANES,
                    interpret: bool = False):
    """pallas_call wrapper. See a2_count_kernel; events here are i32[3, EP]
    (types; times; dup). Returns (counts i32[8, M], ovf i32[8, M]), row 0
    meaningful."""
    np_, m = etypes.shape
    grid = (m // block_m,)
    kernel = functools.partial(_a1_kernel, n_levels, lcap)
    out_shape = [jax.ShapeDtypeStruct((SUBLANES, m), jnp.int32),
                 jax.ShapeDtypeStruct((SUBLANES, m), jnp.int32)]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((np_, block_m), lambda i: (0, i)),
            pl.BlockSpec((np_, block_m), lambda i: (0, i)),
            pl.BlockSpec((np_, block_m), lambda i: (0, i)),
            pl.BlockSpec(events.shape, lambda i: (0, 0)),
        ],
        out_specs=[pl.BlockSpec((SUBLANES, block_m), lambda i: (0, i)),
                   pl.BlockSpec((SUBLANES, block_m), lambda i: (0, i))],
        out_shape=out_shape,
        interpret=interpret,
    )(etypes, tlo, thi, events)


@functools.partial(
    jax.jit, static_argnames=("n_levels", "lcap", "block_m", "interpret"))
def a1_count_state_kernel(etypes, tlo, thi, events, s, po, cnt, ovf, *,
                          n_levels: int, lcap: int = 4,
                          block_m: int = LANES, interpret: bool = False):
    """State-in/state-out pallas_call wrapper.

    State operands (all i32, kernel brick layout — see ``ops``):
      s    (NP, LCAP, M)  circular timestamp brick (TIME_NEG_INF = empty)
      po   (NP, LCAP, M)  one-hot write-pointer mask (0/1)
      cnt  (8, M)         cumulative counts, row 0 meaningful
      ovf  (8, M)         sticky live-eviction flags, row 0 meaningful

    Returns (cnt, ovf, s, po) advanced past ``events``; each state input is
    aliased onto its output (donated), so never reuse the passed arrays.
    """
    np_, m = etypes.shape
    grid = (m // block_m,)
    kernel = functools.partial(_a1_state_kernel, n_levels, lcap)
    out_shape = [jax.ShapeDtypeStruct((SUBLANES, m), jnp.int32),
                 jax.ShapeDtypeStruct((SUBLANES, m), jnp.int32),
                 jax.ShapeDtypeStruct((np_, lcap, m), jnp.int32),
                 jax.ShapeDtypeStruct((np_, lcap, m), jnp.int32)]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((np_, block_m), lambda i: (0, i)),
            pl.BlockSpec((np_, block_m), lambda i: (0, i)),
            pl.BlockSpec((np_, block_m), lambda i: (0, i)),
            pl.BlockSpec(events.shape, lambda i: (0, 0)),
            pl.BlockSpec((np_, lcap, block_m), lambda i: (0, 0, i)),
            pl.BlockSpec((np_, lcap, block_m), lambda i: (0, 0, i)),
            pl.BlockSpec((SUBLANES, block_m), lambda i: (0, i)),
            pl.BlockSpec((SUBLANES, block_m), lambda i: (0, i)),
        ],
        out_specs=[pl.BlockSpec((SUBLANES, block_m), lambda i: (0, i)),
                   pl.BlockSpec((SUBLANES, block_m), lambda i: (0, i)),
                   pl.BlockSpec((np_, lcap, block_m), lambda i: (0, 0, i)),
                   pl.BlockSpec((np_, lcap, block_m), lambda i: (0, 0, i))],
        out_shape=out_shape,
        input_output_aliases={6: 0, 7: 1, 4: 2, 5: 3},
        interpret=interpret,
    )(etypes, tlo, thi, events, s, po, cnt, ovf)
