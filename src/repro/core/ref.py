"""Sequential oracles — faithful transcriptions of the paper's pseudocode.

``count_a1_sequential``  = Algorithm 1 (full (t_low, t_high] constraints,
list-of-lists state).  ``count_a2_sequential`` = Algorithm 3 (lower bounds
relaxed, single-timestamp state per level — Observation 5.1).

These run one episode at a time in pure Python and are the ground truth every
vectorized / Pallas / distributed counter is asserted *exactly equal* to
(integer ticks ⇒ bit-exact comparisons).

Notes on the pseudocode (the published listing has OCR-level typos):
  * the outer loop scans levels top-down (i = N..1) so an event extends the
    deepest level first; one event may extend several levels (repeated event
    types, e.g. A→A);
  * completion happens when the *last* level is extended (the listing's
    ``i = |α|-1`` is an off-by-one artifact; Algorithm 3 line 9 has ``i=|α|``);
  * on completion: count++, the whole state resets, and the scan moves to the
    next event — this is what makes counts non-overlapped;
  * level-1 events are always recorded (no incoming constraint).
"""

from __future__ import annotations

import numpy as np

from .episodes import EpisodeBatch
from .events import PAD_TYPE, EventStream


def count_a1_sequential(stream: EventStream, eps: EpisodeBatch) -> np.ndarray:
    """Algorithm 1 per episode. Returns int64[M] non-overlapped counts."""
    out = np.zeros(eps.M, dtype=np.int64)
    types, times = stream.types, stream.times
    if eps.N == 1:  # 1-node episodes: every occurrence is non-overlapped
        for m in range(eps.M):
            out[m] = int((types == eps.etypes[m, 0]).sum())
        return out
    for m in range(eps.M):
        et = eps.etypes[m]
        tlo, thi = eps.tlo[m], eps.thi[m]
        n = eps.N
        s: list[list[int]] = [[] for _ in range(n)]
        count = 0
        for e, t in zip(types, times):
            if e == PAD_TYPE:
                continue
            completed = False
            for i in range(n - 1, -1, -1):  # top-down over levels
                if e != et[i]:
                    continue
                if i == 0:
                    s[0].append(int(t))
                    continue
                # walk s[i-1] most-recent-first for a witness
                for t_prev in reversed(s[i - 1]):
                    if tlo[i - 1] < t - t_prev <= thi[i - 1]:
                        if i == n - 1:
                            count += 1
                            s = [[] for _ in range(n)]
                            completed = True
                        else:
                            s[i].append(int(t))
                        break
                if completed:
                    break  # next event
            # (continue scanning events)
        out[m] = count
    return out


def count_a2_sequential(stream: EventStream, eps: EpisodeBatch,
                        inclusive_lower: bool = True) -> np.ndarray:
    """Algorithm 3 on the *relaxed* episode α' (lower bounds ignored).

    ``inclusive_lower=True`` (our default) applies Δ ∈ [0, thi] instead of the
    paper's (0, thi]. On streams with distinct timestamps the two are
    identical; with repeated timestamps (integer-binned multi-neuron data!)
    the paper's strict bound breaks both Obs. 5.1 (latest-timestamp
    sufficiency) and Thm. 5.1 (count(α') ≥ count(α)) — a same-tick consumer
    can only chain off an *older* same-level witness, which the single slot
    just clobbered. The inclusive bound restores both properties
    unconditionally: the newest witness then dominates every older one, and
    every A1 occurrence (Δ > tlo ≥ 0 ⇒ Δ ≥ 0) remains an α' occurrence.
    ``inclusive_lower=False`` gives the paper's literal Algorithm 3 (used by
    tests on tie-free streams). Returns int64[M].
    """
    out = np.zeros(eps.M, dtype=np.int64)
    types, times = stream.types, stream.times
    if eps.N == 1:
        for m in range(eps.M):
            out[m] = int((types == eps.etypes[m, 0]).sum())
        return out
    NEG = None  # "no timestamp" sentinel
    for m in range(eps.M):
        et = eps.etypes[m]
        thi = eps.thi[m]
        n = eps.N
        s: list[int | None] = [NEG] * n
        count = 0
        for e, t in zip(types, times):
            if e == PAD_TYPE:
                continue
            completed = False
            for i in range(n - 1, -1, -1):
                if e != et[i]:
                    continue
                if i == 0:
                    s[0] = int(t)
                    continue
                lo_ok = (t - s[i - 1] >= 0 if inclusive_lower
                         else t - s[i - 1] > 0) if s[i - 1] is not None \
                    else False
                if lo_ok and t - s[i - 1] <= thi[i - 1]:
                    if i == n - 1:
                        count += 1
                        s = [NEG] * n
                        completed = True
                    else:
                        s[i] = int(t)
                if completed:
                    break
            # next event
        out[m] = count
    return out


def count_occurrences_naive(stream: EventStream, eps: EpisodeBatch,
                            greedy_from: int | None = None) -> np.ndarray:
    """Greedy earliest-completion counter used to cross-check Algorithm 1 on
    tiny streams: repeatedly find the earliest-completing occurrence whose
    events all come after the previous occurrence's completion (non-overlap),
    restarting the search after each find. Exponential-ish; tests only."""
    out = np.zeros(eps.M, dtype=np.int64)
    ev = [(int(e), int(t)) for e, t in zip(stream.types, stream.times)
          if e != PAD_TYPE]
    for m in range(eps.M):
        et, tlo, thi = eps.etypes[m], eps.tlo[m], eps.thi[m]
        n = eps.N
        start, count = 0, 0
        while True:
            # DFS for earliest completion using events[start:]
            best_end = None

            def dfs(level, prev_t, idx):
                nonlocal best_end
                for j in range(idx, len(ev)):
                    e, t = ev[j]
                    if best_end is not None and t >= best_end:
                        return
                    if e != et[level]:
                        continue
                    if level > 0:
                        d = t - prev_t
                        if d > thi[level - 1]:
                            return  # later events only get worse at this level
                        if not (tlo[level - 1] < d):
                            continue
                    if level == n - 1:
                        if best_end is None or t < best_end:
                            best_end = t
                        return
                    dfs(level + 1, t, j + 1)

            dfs(0, 0, start)
            if best_end is None:
                break
            count += 1
            # next occurrence must start strictly after this completion time
            start = next((j for j, (_, t) in enumerate(ev) if t > best_end),
                         len(ev))
        out[m] = count
    return out
