"""Vectorized A2 counting (Algorithm 3 / Observation 5.1).

With lower bounds relaxed, each episode level needs exactly ONE timestamp of
state (Obs. 5.1), so counting M episodes is a dense ``lax.scan`` over events
with an int32[M, N] state matrix — the paper's "per-thread per-episode"
(PTPE) mapping becomes per-*lane* per-episode on the TPU VPU.

The step function is shared with MapConcatenate (``mapconcat.py``) and the
Pallas kernel oracle (``kernels/ref.py``). It also accepts lower bounds so
the same code path expresses the *single-slot approximation* of A1 (used only
in tests to show why A1 needs lists — the paper's motivation for Obs. 5.1).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .episodes import EpisodeBatch
from .events import TIME_NEG_INF, EventStream


def step_single_slot(s, count, etypes, tlo, thi, e, t):
    """One event against M single-slot state machines.

    Args:
      s:      i32[M, N] last-accepted timestamp per level (TIME_NEG_INF = none)
      count:  i32[M]
      etypes: i32[M, N]; tlo/thi: i32[M, N-1]
      e, t:   scalar i32 event type / time (e == PAD_TYPE is a no-op)

    Returns (s', count'). All reads see the pre-event state, which matches the
    sequential top-down level walk (see core/ref.py notes).
    """
    match = etypes == e  # [M, N]; PAD_TYPE never matches (etypes >= 0)
    delta = t - s[:, :-1]  # [M, N-1]
    ok = (delta > tlo) & (delta <= thi)  # [M, N-1]
    # level 0 always records; level i>0 records iff level i-1 witnesses
    advance = jnp.concatenate(
        [jnp.ones_like(match[:, :1]), ok], axis=1) & match  # [M, N]
    complete = advance[:, -1]  # [M]
    # the last level never stores (completion resets instead)
    store = advance.at[:, -1].set(False)
    s_new = jnp.where(store, t, s)
    s_new = jnp.where(complete[:, None], TIME_NEG_INF, s_new)
    return s_new, count + complete.astype(count.dtype)


@functools.partial(jax.jit, static_argnames=())
def _scan_count(etypes, tlo, thi, ev_types, ev_times):
    m, _ = etypes.shape
    s0 = jnp.full(etypes.shape, TIME_NEG_INF, dtype=jnp.int32)
    c0 = jnp.zeros((m,), dtype=jnp.int32)

    def body(carry, ev):
        s, c = carry
        e, t = ev
        s, c = step_single_slot(s, c, etypes, tlo, thi, e, t)
        return (s, c), None

    (_, count), _ = jax.lax.scan(body, (s0, c0), (ev_types, ev_times))
    return count


def count_single_slot(stream: EventStream, eps: EpisodeBatch,
                      inclusive_lower: bool = False) -> np.ndarray:
    """Single-slot scan with eps' own bounds (A2 ⇔ bounds already relaxed).

    ``inclusive_lower`` applies Δ ∈ [tlo.., thi] by shifting the exclusive
    integer bound down one tick — see ref.count_a2_sequential for why A2
    needs this on streams with repeated timestamps."""
    if eps.N == 1:
        return np.array([(stream.types == e).sum() for e in eps.etypes[:, 0]],
                        dtype=np.int64)
    tlo = jnp.asarray(eps.tlo) - (1 if inclusive_lower else 0)
    count = _scan_count(jnp.asarray(eps.etypes), tlo,
                        jnp.asarray(eps.thi), jnp.asarray(stream.types),
                        jnp.asarray(stream.times))
    return np.asarray(count, dtype=np.int64)


def count_a2(stream: EventStream, eps: EpisodeBatch,
             use_kernel: bool = True) -> np.ndarray:
    """Paper Algorithm 3: upper-bound counts of the relaxed episodes α'.

    Dispatches to the Pallas kernel path when available (TPU target;
    interpret-mode on CPU is slower than the XLA scan, so default CPU path is
    the scan — see kernels/ops.py for the dispatch policy).
    """
    relaxed = eps.relaxed()
    if use_kernel:
        try:
            from repro.kernels import ops as kops
            return kops.a2_count(stream, relaxed)
        except (ImportError, NotImplementedError):
            pass
    return count_single_slot(stream, relaxed, inclusive_lower=True)
