"""Vectorized A2 counting (Algorithm 3 / Observation 5.1).

With lower bounds relaxed, each episode level needs exactly ONE timestamp of
state (Obs. 5.1), so counting M episodes is a dense ``lax.scan`` over events
with an int32[M, N] state matrix — the paper's "per-thread per-episode"
(PTPE) mapping becomes per-*lane* per-episode on the TPU VPU.

The step function is shared with MapConcatenate (``mapconcat.py``) and the
Pallas kernel oracle (``kernels/ref.py``). It also accepts lower bounds so
the same code path expresses the *single-slot approximation* of A1 (used only
in tests to show why A1 needs lists — the paper's motivation for Obs. 5.1).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.tally import record_fallback

from .episodes import EpisodeBatch
from .events import TIME_NEG_INF, EventStream, count_level1


def step_single_slot(s, count, etypes, tlo, thi, e, t):
    """One event against M single-slot state machines.

    Args:
      s:      i32[M, N] last-accepted timestamp per level (TIME_NEG_INF = none)
      count:  i32[M]
      etypes: i32[M, N]; tlo/thi: i32[M, N-1]
      e, t:   scalar i32 event type / time (e == PAD_TYPE is a no-op)

    Returns (s', count'). All reads see the pre-event state, which matches the
    sequential top-down level walk (see core/ref.py notes).
    """
    match = etypes == e  # [M, N]; PAD_TYPE never matches (etypes >= 0)
    delta = t - s[:, :-1]  # [M, N-1]
    ok = (delta > tlo) & (delta <= thi)  # [M, N-1]
    # level 0 always records; level i>0 records iff level i-1 witnesses
    advance = jnp.concatenate(
        [jnp.ones_like(match[:, :1]), ok], axis=1) & match  # [M, N]
    complete = advance[:, -1]  # [M]
    # the last level never stores (completion resets instead)
    store = advance.at[:, -1].set(False)
    s_new = jnp.where(store, t, s)
    s_new = jnp.where(complete[:, None], TIME_NEG_INF, s_new)
    return s_new, count + complete.astype(count.dtype)


@dataclasses.dataclass
class A2State:
    """Carry of the M single-slot machines between stream chunks.

    Unlike A1's bounded lists, a single slot per level is *complete* state
    (Obs. 5.1) — carrying it across any chunk boundary is unconditionally
    bit-exact, ties included. After a carried call the passed state may have
    been donated; never reuse it.
    """

    s: jax.Array      # i32[M, N] last-accepted timestamp per level
    count: jax.Array  # i32[M]


def init_a2_state(eps: EpisodeBatch) -> A2State:
    return A2State(
        s=jnp.full(eps.etypes.shape, TIME_NEG_INF, dtype=jnp.int32),
        count=jnp.zeros((eps.M,), dtype=jnp.int32))


def _a2_scan_core(etypes, tlo, thi, ev_types, ev_times, s, c):
    def body(carry, ev):
        s_, c_ = carry
        e, t = ev
        return step_single_slot(s_, c_, etypes, tlo, thi, e, t), None

    carry, _ = jax.lax.scan(body, (s, c), (ev_types, ev_times))
    return carry


@functools.lru_cache(maxsize=None)
def _a2_carry_scan():
    donate = (5, 6) if jax.default_backend() != "cpu" else ()
    return jax.jit(_a2_scan_core, donate_argnums=donate)


@functools.partial(jax.jit, static_argnames=())
def _scan_count(etypes, tlo, thi, ev_types, ev_times):
    m, _ = etypes.shape
    s0 = jnp.full(etypes.shape, TIME_NEG_INF, dtype=jnp.int32)
    c0 = jnp.zeros((m,), dtype=jnp.int32)
    _, count = _a2_scan_core(etypes, tlo, thi, ev_types, ev_times, s0, c0)
    return count


def count_single_slot(stream: EventStream, eps: EpisodeBatch,
                      inclusive_lower: bool = False,
                      state: A2State | None = None,
                      return_state: bool = False,
                      use_kernel: bool = False):
    """Single-slot scan with eps' own bounds (A2 ⇔ bounds already relaxed).

    ``inclusive_lower`` applies Δ ∈ [tlo.., thi] by shifting the exclusive
    integer bound down one tick — see ref.count_a2_sequential for why A2
    needs this on streams with repeated timestamps.

    With ``state``/``return_state`` the machines resume carried state and
    also return the new ``A2State``; cumulative counts over chunks are
    bit-identical to one scan over the concatenation. ``use_kernel`` routes
    the carried chunk through the state-in/state-out Pallas kernel
    (``kernels.ops.a2_count_stateful``) when the dispatch policy allows —
    same bits, on-chip state."""
    if eps.N == 1:
        counts = count_level1(stream, eps.etypes[:, 0])
        if state is not None:
            counts = counts + np.asarray(state.count, np.int64)
        if return_state:
            st = state if state is not None else init_a2_state(eps)
            st = dataclasses.replace(st,
                                     count=jnp.asarray(counts, jnp.int32))
            return counts, st
        return counts
    tlo = jnp.asarray(eps.tlo) - (1 if inclusive_lower else 0)
    if state is None and not return_state:
        count = _scan_count(jnp.asarray(eps.etypes), tlo,
                            jnp.asarray(eps.thi), jnp.asarray(stream.types),
                            jnp.asarray(stream.times))
        return np.asarray(count, dtype=np.int64)
    if use_kernel:
        try:
            from repro.kernels import ops as kops
            counts, new_state = kops.a2_count_stateful(
                stream, eps, state=state, inclusive_lower=inclusive_lower)
            if return_state:
                return counts, new_state
            return counts
        except (ImportError, NotImplementedError):
            record_fallback("a2_stateful")
    st = state if state is not None else init_a2_state(eps)
    s, count = _a2_carry_scan()(
        jnp.asarray(eps.etypes), tlo, jnp.asarray(eps.thi),
        jnp.asarray(stream.types), jnp.asarray(stream.times),
        st.s, st.count)
    new_state = A2State(s=s, count=count)
    counts = np.asarray(count, dtype=np.int64)
    if return_state:
        return counts, new_state
    return counts


def count_a2(stream: EventStream, eps: EpisodeBatch,
             use_kernel: bool = True, state: A2State | None = None,
             return_state: bool = False, segments: int | None = None,
             sharded: bool = False):
    """Paper Algorithm 3: upper-bound counts of the relaxed episodes α'.

    Dispatches to the Pallas kernel path when available (TPU target;
    interpret-mode on CPU is slower than the XLA scan, so default CPU path is
    the scan — see kernels/ops.py for the dispatch policy). Stateful calls
    (``state``/``return_state``) return ``(counts, A2State)`` with
    cumulative counts over everything the carried machines have seen, and
    with ``use_kernel`` run the chunk through the state-in/state-out Pallas
    kernel — the carried single-slot tile stays on-chip.

    ``segments`` routes the one-shot count through the segment-parallel
    kernel (``kernels.ops.a2_mapconcat_count`` — grid = episode tile × time
    segment with the Concatenate fold fused on-chip); with ``sharded`` the
    segment axis additionally shards over the mesh ``data`` devices — one
    segmented launch per device, per-device tuples all-gathered and folded
    replicated (``a2_mapconcat_sharded_count``; single-device hosts take
    the plain segmented launch). Episodes whose tuples fail to stitch are
    recounted by the exact single-slot scan, so the result is *the* A2
    count either way and Theorem 5.1's cull stays sound. Ignored in
    stateful mode (cross-chunk carry is a single sequential scan) and when
    the kernel dispatch declines.
    """
    relaxed = eps.relaxed()
    if state is not None or return_state:
        return count_single_slot(stream, relaxed, inclusive_lower=True,
                                 state=state, return_state=True,
                                 use_kernel=use_kernel)
    if use_kernel and segments is not None and eps.N > 1:
        try:
            from repro.kernels import ops as kops
            if sharded:
                counts, bad = kops.a2_mapconcat_sharded_count(
                    stream, relaxed, num_segments=segments)
            else:
                counts, bad = kops.a2_mapconcat_count(stream, relaxed,
                                                      num_segments=segments)
            if bad.any():
                idx = np.nonzero(bad)[0]
                counts = counts.copy()
                counts[idx] = count_single_slot(stream, relaxed.select(idx),
                                                inclusive_lower=True)
            return counts
        except (ImportError, NotImplementedError):
            record_fallback("a2_segments")
    if use_kernel:
        try:
            from repro.kernels import ops as kops
            return kops.a2_count(stream, relaxed)
        except (ImportError, NotImplementedError):
            record_fallback("a2_count")
    return count_single_slot(stream, relaxed, inclusive_lower=True)
