"""Serial-episode containers (paper Def. 2.2 + Problem 1).

An N-node serial episode with inter-event constraints is

    E(1) --(tlo^1, thi^1]--> E(2) --...--> E(N)

A *batch* of M same-size episodes (level-wise mining counts one size at a
time) is stored dense:

  * ``etypes`` — int32[M, N]  event types per level
  * ``tlo``    — int32[M, N-1] exclusive lower bounds per edge
  * ``thi``    — int32[M, N-1] inclusive upper bounds per edge

The relaxed counterpart α' (Algorithm A2, §5.3.1) keeps ``thi`` and zeroes
``tlo`` — `relaxed()` below.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class EpisodeBatch:
    etypes: np.ndarray  # int32[M, N]
    tlo: np.ndarray     # int32[M, N-1]
    thi: np.ndarray     # int32[M, N-1]

    def __post_init__(self):
        etypes = np.atleast_2d(np.asarray(self.etypes, dtype=np.int32))
        tlo = np.atleast_2d(np.asarray(self.tlo, dtype=np.int32))
        thi = np.atleast_2d(np.asarray(self.thi, dtype=np.int32))
        object.__setattr__(self, "etypes", etypes)
        object.__setattr__(self, "tlo", tlo)
        object.__setattr__(self, "thi", thi)
        m, n = etypes.shape
        if tlo.shape != (m, n - 1) or thi.shape != (m, n - 1):
            raise ValueError(f"constraint shapes {tlo.shape}/{thi.shape} "
                             f"inconsistent with episodes {etypes.shape}")
        if n > 1:
            if (tlo < 0).any():
                raise ValueError("lower bounds must be >= 0 (t_low >= 0)")
            if (thi <= tlo).any():
                raise ValueError("need t_high > t_low (non-empty intervals)")

    @property
    def M(self) -> int:
        return self.etypes.shape[0]

    @property
    def N(self) -> int:
        return self.etypes.shape[1]

    @property
    def max_span(self) -> np.ndarray:
        """int32[M] — W = sum_i thi^i, the max temporal extent of an
        occurrence. Drives MapConcatenate lookback/lookahead zones."""
        if self.N == 1:
            return np.zeros(self.M, dtype=np.int64)
        return self.thi.astype(np.int64).sum(axis=1)

    def relaxed(self) -> "EpisodeBatch":
        """α → α' : drop lower bounds (paper §5.3.1)."""
        return EpisodeBatch(self.etypes, np.zeros_like(self.tlo), self.thi)

    def select(self, mask_or_idx) -> "EpisodeBatch":
        return EpisodeBatch(self.etypes[mask_or_idx], self.tlo[mask_or_idx],
                            self.thi[mask_or_idx])

    def padded_to(self, m: int, pad_type: int = 0) -> "EpisodeBatch":
        """Right-pad the batch to M=m episodes (repeats a trivial episode);
        callers slice counts back. Keeps kernel grids static."""
        cur = self.M
        if cur >= m:
            return self
        reps = m - cur
        et = np.concatenate(
            [self.etypes,
             np.full((reps, self.N), pad_type, np.int32)], axis=0)
        tl = np.concatenate(
            [self.tlo, np.zeros((reps, self.N - 1), np.int32)], axis=0)
        th = np.concatenate(
            [self.thi, np.ones((reps, self.N - 1), np.int32)], axis=0)
        return EpisodeBatch(et, tl, th)

    @staticmethod
    def single(etypes, tlo, thi) -> "EpisodeBatch":
        return EpisodeBatch(np.asarray(etypes, np.int32)[None, :],
                            np.asarray(tlo, np.int32)[None, :],
                            np.asarray(thi, np.int32)[None, :])
