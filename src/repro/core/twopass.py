"""Two-pass elimination — paper Algorithm 4 (A2 + A1).

Pass 1 counts every candidate under the *relaxed* constraints with the cheap
single-slot engine (A2). Theorem 5.1: ``count(α') >= count(α)``, so culling
``count(α') < θ`` never removes a truly frequent episode. Pass 2 runs the
exact A1 engine only on survivors.

Returns exact counts for survivors and the A2 upper bound (plus a culled
mask) for the rest — enough for the level-wise miner to proceed, and for the
benchmarks to report elimination rates (paper Fig. 9: >=99.9 % culled at
realistic thresholds).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .count_a1 import count_a1 as _count_a1
from .count_a2 import count_a2 as _count_a2
from .hybrid import count_dispatch as _count_dispatch
from .episodes import EpisodeBatch
from .events import EventStream


@dataclasses.dataclass(frozen=True)
class TwoPassResult:
    counts: np.ndarray        # int64[M] — exact for survivors, A2 UB for culled
    survived: np.ndarray      # bool[M]  — passed the A2 cull
    frequent: np.ndarray      # bool[M]  — exact count >= theta
    a2_counts: np.ndarray     # int64[M] — pass-1 upper bounds
    eliminated_frac: float    # fraction culled in pass 1


def count_two_pass(stream: EventStream, eps: EpisodeBatch, theta: int,
                   use_kernel: bool = True,
                   engine: str = "hybrid") -> TwoPassResult:
    """Algorithm 4. ``engine`` picks the pass-2 mapping: "ptpe",
    "mapconcatenate", or "hybrid" (Eq. 2 dispatcher)."""
    a2 = _count_a2(stream, eps, use_kernel=use_kernel)
    survived = a2 >= theta
    counts = a2.copy()
    if survived.any():
        idx = np.nonzero(survived)[0]
        sub = eps.select(idx)
        exact = _count_dispatch(stream, sub, engine=engine,
                                       use_kernel=use_kernel)
        counts[idx] = exact
    frequent = survived & (counts >= theta)
    return TwoPassResult(
        counts=counts, survived=survived, frequent=frequent, a2_counts=a2,
        eliminated_frac=float(1.0 - survived.mean()) if eps.M else 0.0)


def count_one_pass(stream: EventStream, eps: EpisodeBatch, theta: int,
                   use_kernel: bool = True,
                   engine: str = "hybrid") -> TwoPassResult:
    """Baseline: run the exact engine on every candidate (paper's "one-pass"
    comparison arm in Fig. 9)."""
    exact = _count_dispatch(stream, eps, engine=engine,
                                   use_kernel=use_kernel)
    frequent = exact >= theta
    return TwoPassResult(counts=exact, survived=np.ones(eps.M, bool),
                         frequent=frequent, a2_counts=exact,
                         eliminated_frac=0.0)
