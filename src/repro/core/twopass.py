"""Two-pass elimination — paper Algorithm 4 (A2 + A1).

Pass 1 counts every candidate under the *relaxed* constraints with the cheap
single-slot engine (A2). Theorem 5.1: ``count(α') >= count(α)``, so culling
``count(α') < θ`` never removes a truly frequent episode. Pass 2 runs the
exact A1 engine only on survivors.

Returns exact counts for survivors and the A2 upper bound (plus a culled
mask) for the rest — enough for the level-wise miner to proceed, and for the
benchmarks to report elimination rates (paper Fig. 9: >=99.9 % culled at
realistic thresholds).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .count_a1 import A1State, DEFAULT_LCAP
from .count_a2 import A2State, count_a2 as _count_a2
from .hybrid import count_dispatch as _count_dispatch
from .episodes import EpisodeBatch
from .events import EventStream


@dataclasses.dataclass(frozen=True)
class TwoPassResult:
    counts: np.ndarray        # int64[M] — exact for survivors, A2 UB for culled
    survived: np.ndarray      # bool[M]  — passed the A2 cull
    frequent: np.ndarray      # bool[M]  — exact count >= theta
    a2_counts: np.ndarray     # int64[M] — pass-1 upper bounds
    eliminated_frac: float    # fraction culled in pass 1


@dataclasses.dataclass
class TwoPassState:
    """Carried machines for streaming two-pass counting: the relaxed A2
    upper-bound machines plus the exact A1 machines, both threaded across
    window boundaries. Cull decisions use *cumulative* A2 counts, so
    Theorem 5.1 keeps holding on the concatenated stream."""

    a2: A2State
    a1: A1State


def count_two_pass(stream: EventStream, eps: EpisodeBatch, theta: int,
                   use_kernel: bool = True,
                   engine: str = "hybrid", lcap: int = DEFAULT_LCAP,
                   num_segments: int = 8,
                   state: TwoPassState | None = None,
                   return_state: bool = False):
    """Algorithm 4. ``engine`` picks the pass-2 mapping: "ptpe",
    "mapconcatenate", "mapconcat_kernel" (the in-kernel segment-parallel
    mapping — with it, the pass-1 A2 cull also runs its segmented kernel,
    so *both* passes use the paper's two-axis grid), "mapconcat_sharded"
    (the multi-device form: BOTH passes shard their segmented launches
    over the mesh ``data`` axis — pass 1's A2 cull via
    ``a2_mapconcat_sharded_count``, pass 2's exact A1 via
    ``mapconcatenate_sharded_kernel`` — degrading bit-identically to the
    single-device mappings when devices/kernels are unavailable), or
    "hybrid" (Eq. 2 dispatcher). ``num_segments`` feeds the
    segment-parallel mappings.

    Stateful mode (``state``/``return_state``) returns
    ``(TwoPassResult, TwoPassState)`` where counts are cumulative over
    everything the carried machines have seen; with ``use_kernel`` both
    passes run through the state-in/state-out Pallas kernels when the
    dispatch policy allows. Both passes run carried
    full-batch steps — the A2 cull then gates only the *reported* survivor
    set, not pass-2 compute (a culled episode may become a survivor in a
    later window, so its exact machines must have seen the whole stream;
    ``StreamingMiner`` instead promotes lazily with history replay to keep
    the compute saving). Exactness for ``state.a1.ovf``-flagged episodes
    requires an oracle recount over the concatenated history — see
    ``count_a1``; ``StreamingCounter`` automates it.
    """
    if state is not None or return_state:
        a2_st = state.a2 if state is not None else None
        a1_st = state.a1 if state is not None else None
        a2, a2_new = _count_a2(stream, eps, use_kernel=use_kernel,
                               state=a2_st, return_state=True)
        exact, a1_new = _count_dispatch(stream, eps, engine=engine,
                                        use_kernel=use_kernel, lcap=lcap,
                                        state=a1_st, return_state=True)
        survived = a2 >= theta
        counts = np.where(survived, exact, a2)
        frequent = survived & (counts >= theta)
        res = TwoPassResult(
            counts=counts, survived=survived, frequent=frequent,
            a2_counts=a2,
            eliminated_frac=float(1.0 - survived.mean()) if eps.M else 0.0)
        return res, TwoPassState(a2=a2_new, a1=a1_new)
    segmented = engine in ("mapconcat_kernel", "mapconcat_sharded")
    a2 = _count_a2(stream, eps, use_kernel=use_kernel,
                   segments=(num_segments if segmented else None),
                   sharded=engine == "mapconcat_sharded")
    survived = a2 >= theta
    counts = a2.copy()
    if survived.any():
        idx = np.nonzero(survived)[0]
        sub = eps.select(idx)
        exact = _count_dispatch(stream, sub, engine=engine,
                                use_kernel=use_kernel, lcap=lcap,
                                num_segments=num_segments)
        counts[idx] = exact
    frequent = survived & (counts >= theta)
    return TwoPassResult(
        counts=counts, survived=survived, frequent=frequent, a2_counts=a2,
        eliminated_frac=float(1.0 - survived.mean()) if eps.M else 0.0)


def count_one_pass(stream: EventStream, eps: EpisodeBatch, theta: int,
                   use_kernel: bool = True,
                   engine: str = "hybrid",
                   lcap: int = DEFAULT_LCAP,
                   num_segments: int = 8) -> TwoPassResult:
    """Baseline: run the exact engine on every candidate (paper's "one-pass"
    comparison arm in Fig. 9)."""
    exact = _count_dispatch(stream, eps, engine=engine,
                            use_kernel=use_kernel, lcap=lcap,
                            num_segments=num_segments)
    frequent = exact >= theta
    return TwoPassResult(counts=exact, survived=np.ones(eps.M, bool),
                         frequent=frequent, a2_counts=exact,
                         eliminated_frac=0.0)
