"""Functional-connectivity reconstruction from mined episodes — the
paper's stated end goal (§1: "reconstructing the functional connectivity of
neuronal circuits"; Fig. 1: mined episodes are "summarized to reconstruct
the underlying neuronal circuitry", after Patnaik et al. [10]).

We estimate pairwise excitation from frequent 2-episodes: the weight of
edge A→B is the *excess* non-overlapped count of (A → B within (tlo, thi])
over what independent firing would produce, normalized by A's rate. Longer
frequent episodes corroborate paths (each adjacent pair contributes)."""

from __future__ import annotations

import dataclasses

import numpy as np

from .episodes import EpisodeBatch
from .events import EventStream
from .miner import MiningResult


@dataclasses.dataclass
class ConnectivityGraph:
    weights: np.ndarray      # f64[V, V] — excess co-firing strength A→B
    counts: np.ndarray       # i64[V, V] — raw 2-episode counts
    num_types: int

    def top_edges(self, k: int = 10):
        idx = np.dstack(np.unravel_index(
            np.argsort(-self.weights, axis=None), self.weights.shape))[0]
        out = []
        for a, b in idx[:k]:
            if self.weights[a, b] <= 0:
                break
            out.append((int(a), int(b), float(self.weights[a, b]),
                        int(self.counts[a, b])))
        return out


def reconstruct(stream: EventStream, result: MiningResult,
                min_level: int = 2) -> ConnectivityGraph:
    """Build the circuit graph from a MiningResult's frequent episodes."""
    v = stream.num_types
    counts = np.zeros((v, v), np.int64)
    rate = np.array([(stream.types == t).sum() for t in range(v)],
                    np.float64)
    span_ticks = max(stream.span[1] - stream.span[0], 1)
    for level in range(min_level - 1, len(result.frequent)):
        eps: EpisodeBatch = result.frequent[level]
        if eps.N < 2:
            continue
        for row, c in zip(range(eps.M), result.counts[level]):
            et = eps.etypes[row]
            thi = eps.thi[row]
            for a, b, w in zip(et[:-1], et[1:], thi):
                if a != b:
                    counts[a, b] += int(c)
    # expected chance co-firings of (A then B within thi): rate_A × p(B in
    # a thi-window) — use the level-2 thi if uniform, else median
    weights = np.zeros((v, v), np.float64)
    thi_typ = float(np.median(result.frequent[1].thi)) \
        if len(result.frequent) > 1 and result.frequent[1].M else 1.0
    for a in range(v):
        for b in range(v):
            if counts[a, b] == 0 or a == b:
                continue
            p_b = rate[b] * thi_typ / span_ticks
            expected = rate[a] * p_b
            weights[a, b] = (counts[a, b] - expected) / max(rate[a], 1.0)
    return ConnectivityGraph(weights=weights, counts=counts, num_types=v)
