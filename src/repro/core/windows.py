"""Window-based episode frequency (WINEPI, Mannila et al. [9]) — the
baseline algorithm class the paper compares its state-machine approach
against (§3 "Mining Frequent Episodes": window-based vs state-machine).

Frequency of a serial episode = the number (or fraction) of width-w sliding
windows that contain at least one occurrence, *events in order within the
window* (no inter-event constraints — that is the definition's semantics;
the paper's state-machine class adds them).

Efficient counting without enumerating windows: for every completion
position we track the **latest possible start** of an occurrence ending
there (a max-start DP over levels, one forward scan); a window starting at
s contains an occurrence iff some completion e has end t_e < s + w and
max-start m_e >= s — i.e. s ∈ (t_e − w, m_e]. The answer is the measure of
a union of integer intervals. O(n·N + C log C).
"""

from __future__ import annotations

import numpy as np

from .episodes import EpisodeBatch
from .events import PAD_TYPE, EventStream, TIME_NEG_INF


def count_windows(stream: EventStream, eps: EpisodeBatch,
                  window: int) -> np.ndarray:
    """int64[M] — number of window start ticks s (over the stream span,
    s ∈ [t_first − window + 1, t_last]) whose window [s, s+window) contains
    an in-order occurrence."""
    real = stream.types != PAD_TYPE
    types, times = stream.types[real], stream.times[real]
    if types.size == 0:
        return np.zeros(eps.M, np.int64)
    t_first, t_last = int(times[0]), int(times[-1])
    out = np.zeros(eps.M, np.int64)
    for m in range(eps.M):
        et = eps.etypes[m]
        n = eps.N
        # max-start DP: best[k] = max over occurrences of nodes 0..k seen so
        # far of their start time (strictly increasing positions)
        best = np.full(n, TIME_NEG_INF, np.int64)
        intervals = []  # (lo, hi] of window-start ticks covered
        for e, t in zip(types, times):
            # top-down so one event can't serve two levels in one step
            for k in range(n - 1, -1, -1):
                if e != et[k]:
                    continue
                if k == 0:
                    best[0] = max(best[0], int(t))
                elif best[k - 1] > TIME_NEG_INF:
                    best[k] = max(best[k], best[k - 1])
                if k == n - 1 and best[n - 1] > TIME_NEG_INF:
                    lo = max(int(t) - window, t_first - window)  # exclusive
                    hi = min(int(best[n - 1]), t_last)           # inclusive
                    if hi > lo:
                        intervals.append((lo, hi))
        out[m] = _union_measure(intervals)
    return out


def frequency_windows(stream: EventStream, eps: EpisodeBatch,
                      window: int) -> np.ndarray:
    """Mannila frequency: fraction of windows containing the episode."""
    real = stream.types != PAD_TYPE
    times = stream.times[real]
    if times.size == 0:
        return np.zeros(eps.M)
    total = int(times[-1]) - (int(times[0]) - window + 1) + 1
    return count_windows(stream, eps, window) / max(total, 1)


def count_windows_bruteforce(stream: EventStream, eps: EpisodeBatch,
                             window: int) -> np.ndarray:
    """O(span · n) oracle: literally slide every window (tests only)."""
    real = stream.types != PAD_TYPE
    types, times = stream.types[real], stream.times[real]
    t_first, t_last = int(times[0]), int(times[-1])
    out = np.zeros(eps.M, np.int64)
    for m in range(eps.M):
        et = eps.etypes[m]
        c = 0
        for s in range(t_first - window + 1, t_last + 1):
            lo = np.searchsorted(times, s, side="left")
            hi = np.searchsorted(times, s + window, side="left")
            # subsequence check, in order
            k = 0
            for j in range(lo, hi):
                if types[j] == et[k]:
                    k += 1
                    if k == eps.N:
                        break
            c += k == eps.N
        out[m] = c
    return out


def _union_measure(intervals) -> int:
    """Total integer measure of a union of (lo, hi] intervals."""
    if not intervals:
        return 0
    intervals.sort()
    total, cur_lo, cur_hi = 0, *intervals[0]
    for lo, hi in intervals[1:]:
        if lo > cur_hi:
            total += cur_hi - cur_lo
            cur_lo, cur_hi = lo, hi
        else:
            cur_hi = max(cur_hi, hi)
    return total + (cur_hi - cur_lo)
