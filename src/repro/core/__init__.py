"""Frequent-episode mining engine — the paper's contribution, in JAX.

Public API:
  EventStream, EpisodeBatch — data containers
  count_a1 / count_a2       — exact / relaxed-upper-bound counting
  mapconcatenate            — segment-parallel exact counting
  count_two_pass            — Algorithm 4 (A2 cull → A1 exact)
  mine / mine_partitions    — level-wise miner, streaming windows
"""

from .candidates import join_next_level, level1, level2
from .count_a1 import A1State, count_a1, count_a1_vectorized, init_a1_state
from .count_a2 import A2State, count_a2, count_single_slot, init_a2_state
from .episodes import EpisodeBatch
from .events import (PAD_TYPE, TIME_NEG_INF, EventStream, count_level1,
                     type_histogram)
from .hybrid import count_dispatch, crossover, f_of_n
from .mapconcat import (concatenate_tree, data_mesh, fold_pair,
                        fold_pair_unrolled, make_segments, mapconcatenate,
                        mapconcatenate_kernel, mapconcatenate_sharded,
                        mapconcatenate_sharded_kernel, phase_cum,
                        stitch_zones)
from .miner import MiningResult, mine, mine_partitions
from .connectivity import ConnectivityGraph, reconstruct
from .ref import (count_a1_sequential, count_a2_sequential,
                  count_occurrences_naive)
from .streaming import (StreamingA2Counter, StreamingCounter, StreamingMiner,
                        bucket_size)
from .twopass import (TwoPassResult, TwoPassState, count_one_pass,
                      count_two_pass)
from .windows import count_windows, frequency_windows

__all__ = [
    "EventStream", "EpisodeBatch", "PAD_TYPE", "TIME_NEG_INF",
    "type_histogram", "count_level1",
    "count_a1", "count_a1_vectorized", "count_a2", "count_single_slot",
    "A1State", "A2State", "init_a1_state", "init_a2_state",
    "mapconcatenate", "mapconcatenate_kernel", "concatenate_tree",
    "fold_pair", "fold_pair_unrolled", "make_segments", "phase_cum",
    "stitch_zones",
    "count_two_pass", "count_one_pass", "TwoPassResult", "TwoPassState",
    "count_dispatch", "crossover", "f_of_n",
    "mine", "mine_partitions", "MiningResult",
    "StreamingCounter", "StreamingA2Counter", "StreamingMiner",
    "bucket_size",
    "level1", "level2", "join_next_level",
    "count_a1_sequential", "count_a2_sequential", "count_occurrences_naive",
    "count_windows", "frequency_windows", "reconstruct",
    "ConnectivityGraph",
]
