"""Frequent-episode mining engine — the paper's contribution, in JAX.

Public API:
  EventStream, EpisodeBatch — data containers
  count_a1 / count_a2       — exact / relaxed-upper-bound counting
  mapconcatenate            — segment-parallel exact counting
  count_two_pass            — Algorithm 4 (A2 cull → A1 exact)
  mine / mine_partitions    — level-wise miner, streaming windows
"""

from .candidates import join_next_level, level1, level2
from .count_a1 import count_a1, count_a1_vectorized
from .count_a2 import count_a2, count_single_slot
from .episodes import EpisodeBatch
from .events import PAD_TYPE, TIME_NEG_INF, EventStream
from .hybrid import count_dispatch, crossover, f_of_n
from .mapconcat import concatenate_tree, make_segments, mapconcatenate
from .miner import MiningResult, mine, mine_partitions
from .connectivity import ConnectivityGraph, reconstruct
from .ref import (count_a1_sequential, count_a2_sequential,
                  count_occurrences_naive)
from .twopass import TwoPassResult, count_one_pass, count_two_pass
from .windows import count_windows, frequency_windows

__all__ = [
    "EventStream", "EpisodeBatch", "PAD_TYPE", "TIME_NEG_INF",
    "count_a1", "count_a1_vectorized", "count_a2", "count_single_slot",
    "mapconcatenate", "concatenate_tree", "make_segments",
    "count_two_pass", "count_one_pass", "TwoPassResult",
    "count_dispatch", "crossover", "f_of_n",
    "mine", "mine_partitions", "MiningResult",
    "level1", "level2", "join_next_level",
    "count_a1_sequential", "count_a2_sequential", "count_occurrences_naive",
    "count_windows", "frequency_windows", "reconstruct",
    "ConnectivityGraph",
]
