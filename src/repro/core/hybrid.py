"""Hybrid dispatcher — paper Algorithm 2 / Eq. 2.

Choose PTPE (episode-parallel single scan) when there are enough episodes to
saturate the machine, else MapConcatenate (segment-parallel). The paper's
utilization bound ``S > MP × B_MP × T_B × f(N)`` translates on TPU to
"enough episode lanes per core": our unit of episode parallelism is a
VPU lane tile (128 episodes), and segment parallelism is worth its
concatenate overhead only below ``U × f(N)`` episodes with the paper's
empirically fitted ``f(N) = a/N + b`` (Fig. 8 — the *reciprocal* fit beat
the linear one; we re-fit a, b on this host in benchmarks/fig8).
"""

from __future__ import annotations

import numpy as np

from .count_a1 import A1State, DEFAULT_LCAP, count_a1 as _count_a1
from .mapconcat import mapconcatenate as _mapconcatenate
from .episodes import EpisodeBatch
from .events import EventStream

# Re-fit by benchmarks/fig8_crossover.py (written into EXPERIMENTS.md §Paper);
# defaults follow the paper's shape: crossover shrinks with episode size.
FN_A = 420.0
FN_B = 40.0


def parallel_units() -> int:
    """Segment-parallel capacity — the paper's MP×B_MP×T_B term is the
    machine's parallel slots; ours is the device count the Map step can
    shard over. On a single device MapConcatenate has no hardware to use
    (fig7: PTPE wins at every M there, with up to 10× dispatcher regret
    under a mis-tuned constant — hence capacity-aware, not fixed)."""
    import jax
    return jax.device_count()


def f_of_n(n: int, a: float = FN_A, b: float = FN_B) -> float:
    return a / max(n, 1) + b


def crossover(n: int) -> int:
    """#episodes above which PTPE wins (Eq. 2 RHS)."""
    return int(max(parallel_units() - 1, 0) * f_of_n(n))


def count_dispatch(stream: EventStream, eps: EpisodeBatch,
                   engine: str = "hybrid", use_kernel: bool = True,
                   num_segments: int = 8, lcap: int = DEFAULT_LCAP,
                   state: A1State | None = None,
                   return_state: bool = False):
    """Exact A1 counts through the selected computation-to-core mapping.

    ``use_kernel`` and ``lcap`` are plumbed into every mapping — including
    MapConcatenate's exactness fallback — so hybrid/mapconcatenate callers
    control the fallback engine the same way ptpe callers do.

    Stateful mode (``state``/``return_state``) carries the bounded-list
    machines across calls and returns ``(counts, A1State)`` with cumulative
    raw counts (see ``count_a1`` — with ``use_kernel`` the chunk runs
    through the state-in/state-out Pallas kernel when available).
    Cross-window machine carry is inherently a single sequential scan, so
    every engine routes to the carried ptpe step here; segment-parallel
    *streaming* (the tuple-fold analogue of MapConcatenate) lives in
    ``streaming.StreamingCounter``, which callers should prefer for
    window-by-window workloads.
    """
    # validate before the stateful early-return: a bogus engine must raise,
    # not silently count via the carried ptpe path
    if engine not in ("ptpe", "mapconcatenate", "hybrid"):
        raise ValueError(f"unknown engine {engine!r}")
    if state is not None or return_state:
        return _count_a1(stream, eps, lcap=lcap, use_kernel=use_kernel,
                         state=state, return_state=True)
    if engine == "ptpe":
        return _count_a1(stream, eps, lcap=lcap, use_kernel=use_kernel)
    if engine == "mapconcatenate":
        return _mapconcatenate(stream, eps, num_segments=num_segments,
                               lcap=lcap, use_kernel=use_kernel)
    if eps.M > crossover(eps.N):
        return _count_a1(stream, eps, lcap=lcap, use_kernel=use_kernel)
    return _mapconcatenate(stream, eps, num_segments=num_segments,
                           lcap=lcap, use_kernel=use_kernel)
