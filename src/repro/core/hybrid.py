"""Hybrid dispatcher — paper Algorithm 2 / Eq. 2.

Choose PTPE (episode-parallel single scan) when there are enough episodes to
saturate the machine, else MapConcatenate (segment-parallel). The paper's
utilization bound ``S > MP × B_MP × T_B × f(N)`` translates on TPU to
"enough episode lanes per core": our unit of episode parallelism is a
VPU lane tile (128 episodes), and segment parallelism is worth its
concatenate overhead only below ``U × f(N)`` episodes with the paper's
empirically fitted ``f(N) = a/N + b`` (Fig. 8 — the *reciprocal* fit beat
the linear one; we re-fit a, b on this host in benchmarks/fig8).
"""

from __future__ import annotations

from repro.kernels.tally import record_fallback

from .count_a1 import A1State, DEFAULT_LCAP, count_a1 as _count_a1
from .mapconcat import (
    mapconcatenate as _mapconcatenate,
    mapconcatenate_kernel as _mapconcatenate_kernel,
    mapconcatenate_sharded_kernel as _mapconcatenate_sharded_kernel)
from .episodes import EpisodeBatch
from .events import EventStream

# Re-fit by benchmarks/fig8_crossover.py (written into EXPERIMENTS.md §Paper);
# defaults follow the paper's shape: crossover shrinks with episode size.
FN_A = 420.0
FN_B = 40.0

# Auto-selection of the in-kernel MapConcatenate by stream length: streams
# at least this long amortize the segmented grid's launch/layout overhead
# (the serial per-segment event walk shrinks to ~n/P while PTPE's stays n).
MAPC_KERNEL_MIN_EVENTS = 2048
# ... and by episode count: below one VPU lane tile, episode parallelism
# cannot fill even a single core's lanes, so the time axis must supply the
# parallelism — the paper's low-M regime where MapConcatenate wins (Fig. 7)
MAPC_KERNEL_MAX_EPISODES = 128


# Probe result cached per process: the answer (TPU present / interpret
# mode) cannot change mid-process, and the old per-dispatch re-probe both
# re-imported the kernel plane on every call and tallied
# ``fallback:hybrid_mapc_probe`` once per *dispatch* on CPU hosts —
# inflating the fallback family and taxing the hybrid's hot path.
_PROBE_CACHE: bool | None = None


def _mapc_kernel_available() -> bool:
    """Whether the segmented-kernel dispatch would actually engage (TPU or
    interpret mode) — the hybrid upgrade must not silently reroute plain
    CPU runs onto the slower XLA MapConcatenate.  Probed once per
    process; the degradation is tallied once, not per dispatch."""
    global _PROBE_CACHE
    if _PROBE_CACHE is None:
        try:
            from repro.kernels import ops as kops
            kops.kernel_mode()
            _PROBE_CACHE = True
        except (ImportError, NotImplementedError):
            record_fallback("hybrid_mapc_probe")
            _PROBE_CACHE = False
    return _PROBE_CACHE


def _reset_probe_cache() -> None:
    """Test hook: forget the cached probe (e.g. after flipping the
    interpret-mode environment)."""
    global _PROBE_CACHE
    _PROBE_CACHE = None


def shard_devices() -> int:
    """Power-of-two device count the segment axis can shard over (1 on a
    single-device host — the sharded mapping then stands down)."""
    from .mapconcat import shard_device_count
    return shard_device_count()


def parallel_units() -> int:
    """Segment-parallel capacity — the paper's MP×B_MP×T_B term is the
    machine's parallel slots; ours is the device count the Map step can
    shard over. On a single device MapConcatenate has no hardware to use
    (fig7: PTPE wins at every M there, with up to 10× dispatcher regret
    under a mis-tuned constant — hence capacity-aware, not fixed)."""
    import jax
    return jax.device_count()


def f_of_n(n: int, a: float = FN_A, b: float = FN_B) -> float:
    return a / max(n, 1) + b


def crossover(n: int) -> int:
    """#episodes above which PTPE wins (Eq. 2 RHS).

    The capacity term is the machine's *segment-parallel* slots beyond
    the one PTPE always gets.  On a single-device host that difference
    is 0 — but only honestly so when the segmented kernel cannot engage:
    with the kernel available, one device still runs the (episode tile ×
    time segment) grid, so the segment axis has one real unit of its own
    and the crossover is ``f(N)`` rather than a degenerate 0 that
    declares episode-parallel the winner at every M regardless of
    ``f(N)``.  (The calibrated policy supersedes this entirely when a
    table is installed.)"""
    units = parallel_units()
    if units <= 1:
        units = 2 if _mapc_kernel_available() else 1
    return int((units - 1) * f_of_n(n))


def count_dispatch(stream: EventStream, eps: EpisodeBatch,
                   engine: str = "hybrid", use_kernel: bool = True,
                   num_segments: int = 8, lcap: int = DEFAULT_LCAP,
                   state: A1State | None = None,
                   return_state: bool = False):
    """Exact A1 counts through the selected computation-to-core mapping.

    Engines: ``"ptpe"`` (episode-parallel single scan),
    ``"mapconcatenate"`` (segment-parallel XLA Map + Concatenate tree),
    ``"mapconcat_kernel"`` (the in-kernel MapConcatenate — one Pallas
    launch whose grid is episode tile × time segment with the Concatenate
    fold fused on-chip; falls back to the XLA mapping bit-identically when
    the kernel dispatch declines), ``"mapconcat_sharded"`` (the
    multi-device form — one segmented Pallas launch per mesh ``data``
    device with the per-device tuples all-gathered and folded replicated;
    degrades to the single-device kernel, the XLA shard_map Map step, or
    plain ``mapconcatenate``, bit-identically, as devices/kernels become
    unavailable), or ``"hybrid"`` (Eq. 2 dispatcher — which additionally
    upgrades the segment-parallel side to the kernel mapping on streams of
    >= ``MAPC_KERNEL_MIN_EVENTS`` events when ``use_kernel`` is set, and
    to the *sharded* kernel mapping when the mesh has more than one
    usable device).

    ``use_kernel`` and ``lcap`` are plumbed into every mapping — including
    MapConcatenate's exactness fallback — so hybrid/mapconcatenate callers
    control the fallback engine the same way ptpe callers do.

    Stateful mode (``state``/``return_state``) carries the bounded-list
    machines across calls and returns ``(counts, A1State)`` with cumulative
    raw counts (see ``count_a1`` — with ``use_kernel`` the chunk runs
    through the state-in/state-out Pallas kernel when available).
    Cross-window machine carry is inherently a single sequential scan, so
    every engine routes to the carried ptpe step here; segment-parallel
    *streaming* (the tuple-fold analogue of MapConcatenate) lives in
    ``streaming.StreamingCounter``, which callers should prefer for
    window-by-window workloads.
    """
    # validate before the stateful early-return: a bogus engine must raise,
    # not silently count via the carried ptpe path
    if engine not in ("ptpe", "mapconcatenate", "mapconcat_kernel",
                      "mapconcat_sharded", "hybrid"):
        raise ValueError(f"unknown engine {engine!r}")
    if state is not None or return_state:
        return _count_a1(stream, eps, lcap=lcap, use_kernel=use_kernel,
                         state=state, return_state=True)
    if engine == "ptpe":
        return _count_a1(stream, eps, lcap=lcap, use_kernel=use_kernel)
    if engine == "mapconcat_sharded":
        return _mapconcatenate_sharded_kernel(
            stream, eps, num_segments=num_segments, lcap=lcap,
            use_kernel=use_kernel)
    if engine == "mapconcat_kernel":
        return _mapconcatenate_kernel(stream, eps, num_segments=num_segments,
                                      lcap=lcap, use_kernel=use_kernel)
    if engine == "mapconcatenate":
        return _mapconcatenate(stream, eps, num_segments=num_segments,
                               lcap=lcap, use_kernel=use_kernel)
    # hybrid: consult the dispatch policy — the calibrated cost table
    # when one is installed (core.calibrate), else exactly the Eq. 2
    # heuristic above (the policy's heuristic branch replicates it, so
    # behavior without a table is unchanged).  Results are bit-identical
    # across engines; only wall clock rides on this choice.
    from .calibrate import get_policy
    choice = get_policy().choose(
        n_events=len(stream), n_episode=eps.N, m=eps.M,
        use_kernel=use_kernel,
        kernel_ok=use_kernel and _mapc_kernel_available(),
        shard_devices=shard_devices(), default_segments=num_segments)
    if choice.engine == "ptpe":
        return _count_a1(stream, eps, lcap=lcap, use_kernel=use_kernel)
    if choice.engine == "mapconcat_sharded":
        # multi-device: each mesh device takes one segment group —
        # throughput scales with hardware, not just segment count
        return _mapconcatenate_sharded_kernel(
            stream, eps, num_segments=choice.num_segments, lcap=lcap,
            use_kernel=use_kernel)
    if choice.engine == "mapconcat_kernel":
        return _mapconcatenate_kernel(
            stream, eps, num_segments=choice.num_segments, lcap=lcap,
            use_kernel=use_kernel)
    return _mapconcatenate(stream, eps, num_segments=choice.num_segments,
                           lcap=lcap, use_kernel=use_kernel)
