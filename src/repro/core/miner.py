"""Top-level frequent-episode miner (Problem 1 driver).

Level-wise loop: generate candidates (host, cheap) → count with the two-pass
GPU-paper pipeline (A2 cull → A1 exact, mapping chosen by the Hybrid rule) →
keep frequent → join to next level. ``mine_partitions`` processes a stream
window-by-window — the paper's "real-time responsiveness by processing
partitions of the data stream in turn" (chip-on-chip loop).
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from . import candidates as _cand
from . import twopass as _tp
from .episodes import EpisodeBatch
from .events import PAD_TYPE, EventStream, count_level1


@dataclasses.dataclass
class LevelStats:
    level: int
    num_candidates: int
    num_survived_a2: int
    num_frequent: int
    seconds: float


@dataclasses.dataclass
class MiningResult:
    frequent: list[EpisodeBatch]     # per level (index 0 = size-1 episodes)
    counts: list[np.ndarray]         # exact counts for each frequent batch
    stats: list[LevelStats]


def mine(stream: EventStream, intervals, theta: int, max_level: int = 4,
         engine: str = "hybrid", two_pass: bool = True,
         use_kernel: bool = True) -> MiningResult:
    """Mine all frequent serial episodes up to ``max_level`` nodes.

    ``intervals`` — the constraint set I: array-like [(tlo, thi), ...].
    """
    frequent, counts, stats = [], [], []

    # level 1 — plain occurrence counts (histogram; see events.count_level1)
    t0 = time.perf_counter()
    c1 = _cand.level1(stream.num_types)
    cnt1 = count_level1(stream, c1.etypes[:, 0])
    keep = cnt1 >= theta
    frequent.append(c1.select(keep))
    counts.append(cnt1[keep])
    stats.append(LevelStats(1, c1.M, c1.M, int(keep.sum()),
                            time.perf_counter() - t0))

    level = 2
    while level <= max_level and frequent[-1].M > 0:
        t0 = time.perf_counter()
        if level == 2:
            cand = _cand.level2(frequent[0].etypes[:, 0], intervals)
        else:
            cand = _cand.join_next_level(frequent[-1])
        if cand is None or cand.M == 0:
            break
        counter = _tp.count_two_pass if two_pass else _tp.count_one_pass
        res = counter(stream, cand, theta, engine=engine,
                      use_kernel=use_kernel)
        keep = res.frequent
        frequent.append(cand.select(keep))
        counts.append(res.counts[keep])
        stats.append(LevelStats(level, cand.M, int(res.survived.sum()),
                                int(keep.sum()), time.perf_counter() - t0))
        level += 1
    return MiningResult(frequent=frequent, counts=counts, stats=stats)


def mine_partitions(streams, intervals, theta_per_window: int,
                    max_level: int = 4, mode: str = "per_window",
                    carry: bool = True, overlap_dedup: bool = True, **kw):
    """Chip-on-chip streaming mode: mine each partition window in turn and
    yield (window_index, MiningResult).

    ``carry=True`` (default) threads every counting machine across window
    boundaries via ``streaming.StreamingMiner``, so occurrences spanning a
    boundary are counted in the window where they complete — the seed's
    restart-per-window loop silently dropped them. θ applies per window
    (``mode="per_window"``) or to cumulative counts (``mode="cumulative"``,
    whose final window reproduces one-shot ``mine`` on the concatenation).

    ``overlap_dedup`` drops events at-or-before the previous window's last
    timestamp, so legacy overlapping windows (``partition_windows`` with
    ``overlap_ms > 0`` — the old workaround for the boundary loss this
    engine fixes) aren't double-counted. Disable it when feeding a true
    partition whose boundary may split a group of equal timestamps.

    ``carry=False`` reproduces the legacy restart-per-window miner exactly.
    """
    if not carry:
        for i, st in enumerate(streams):
            yield i, mine(st, intervals, theta_per_window,
                          max_level=max_level, **kw)
        return
    from .streaming import StreamingMiner
    miner = StreamingMiner(intervals, theta_per_window, max_level=max_level,
                           mode=mode, **kw)
    t_seen = None
    idx = 0
    it = iter(streams)
    cur = next(it, None)
    while cur is not None:
        nxt = next(it, None)
        st = cur
        keep = st.types != PAD_TYPE
        if overlap_dedup and t_seen is not None:
            keep = keep & (st.times > t_seen)
        st = EventStream(st.types[keep], st.times[keep], st.num_types)
        if len(st):
            t_seen = int(st.times[st.types != PAD_TYPE][-1])
        yield idx, miner.update(st, final=nxt is None)
        idx += 1
        cur = nxt
