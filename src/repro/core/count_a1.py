"""Vectorized A1 counting (Algorithm 1) with bounded per-level lists.

Algorithm 1's state is a list of recent timestamps per episode level; the
list walk is data-dependent control flow — the exact thing the paper pays for
on the GPU in registers/local-memory/divergence (§5.3, Fig. 10) and that a
TPU pays for in un-vectorizable gathers. We bound each list to ``LCAP`` slots
kept in a circular buffer, turning the walk into a masked reduction over a
dense i32[M, N, LCAP] tile.

Correctness containment: bounding can only *undercount* (a live witness may
be evicted while newer entries fail the lower bound). We detect possibly-live
evictions exactly — an evicted level-i entry ``v`` is dead iff
``t - v > thi[i]`` (its only consumer is level i+1 within ``thi[i]``) — and
flag the episode. Flagged episodes are recounted by the sequential oracle
(``ref.count_a1_sequential``), so the public ``count_a1`` is always exact.
Tests sweep LCAP and assert the flag ⇒ recount path restores oracle equality.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .episodes import EpisodeBatch
from .events import TIME_NEG_INF, EventStream

DEFAULT_LCAP = 4


def step_bounded_list(s, ptr, count, ovf, etypes, tlo, thi, e, t,
                      dup=False):
    """One event against M bounded-list (A1) state machines.

    Args:
      s:    i32[M, N, L] circular timestamp buffers (TIME_NEG_INF = empty)
      ptr:  i32[M, N] next write slot per level
      count: i32[M]; ovf: bool[M] possibly-live-eviction flag
      etypes i32[M, N]; tlo/thi i32[M, N-1]; e/t scalar i32
      dup:  scalar bool — a later event shares this timestamp. Needed for
            exact eviction accounting: a fresh entry at time t covers an
            evicted one for consumers at t' > t, but not at t' == t
            (the (0, thi] lower bound is strict).

    Returns (s', ptr', count', ovf').
    """
    m, n, cap = s.shape
    match = etypes == e  # [M, N]
    delta = t - s[:, :-1, :]  # [M, N-1, L]
    witness = (delta > tlo[:, :, None]) & (delta <= thi[:, :, None])
    ok = witness.any(axis=-1)  # [M, N-1]
    advance = jnp.concatenate(
        [jnp.ones_like(match[:, :1]), ok], axis=1) & match  # [M, N]
    complete = advance[:, -1]  # [M]
    store = advance.at[:, -1].set(False)  # last level never stores
    store = store & ~complete[:, None]  # completion short-circuits the walk

    # circular append at ptr where store
    onehot = jax.nn.one_hot(ptr, cap, dtype=jnp.bool_)  # [M, N, L]
    write = store[:, :, None] & onehot
    # live-eviction detection: evicted value v still matters iff t-v <= thi[i]
    # (level N-1 has no outgoing edge; it never stores anyway)
    evicted = jnp.where(write, s, TIME_NEG_INF)  # [M, N, L]
    v = evicted.max(axis=-1)  # [M, N] value being overwritten (or NEG_INF)
    thi_out = jnp.concatenate(  # outgoing-edge upper bound per level
        [thi, jnp.zeros_like(thi[:, :1])], axis=1)  # [M, N]
    tlo_out = jnp.concatenate(
        [tlo, jnp.zeros_like(tlo[:, :1])], axis=1)  # [M, N]
    # Obs 5.1: with a zero lower bound the newest entry dominates — eviction
    # is provably safe for strictly-later consumers; only a real lower bound
    # (or a same-timestamp successor event) can make an old witness live.
    live = (v > TIME_NEG_INF) & (t - v <= thi_out) & ((tlo_out > 0) | dup)
    ovf_new = ovf | live.any(axis=-1)

    s_new = jnp.where(write, t, s)
    ptr_new = jnp.where(store, (ptr + 1) % cap, ptr)
    # completion: full reset
    s_new = jnp.where(complete[:, None, None], TIME_NEG_INF, s_new)
    ptr_new = jnp.where(complete[:, None], 0, ptr_new)
    return s_new, ptr_new, count + complete.astype(count.dtype), ovf_new


def dup_flags(ev_types, ev_times):
    """bool[n]: a later *real* event shares this event's timestamp.
    (Events are time-sorted, so it suffices to look at the successor.)"""
    from .events import PAD_TYPE
    nxt_same = jnp.concatenate(
        [(ev_times[1:] == ev_times[:-1]) & (ev_types[1:] != PAD_TYPE),
         jnp.zeros((1,), jnp.bool_)])
    return nxt_same


@jax.jit
def _scan_count_a1(etypes, tlo, thi, ev_types, ev_times, s0):
    m, n = etypes.shape
    ptr0 = jnp.zeros((m, n), dtype=jnp.int32)
    c0 = jnp.zeros((m,), dtype=jnp.int32)
    ovf0 = jnp.zeros((m,), dtype=jnp.bool_)
    dups = dup_flags(ev_types, ev_times)

    def body(carry, ev):
        s, ptr, c, ovf = carry
        e, t, d = ev
        return step_bounded_list(s, ptr, c, ovf, etypes, tlo, thi, e, t,
                                 d), None

    (_, _, count, ovf), _ = jax.lax.scan(
        body, (s0, ptr0, c0, ovf0), (ev_types, ev_times, dups))
    return count, ovf


def count_a1_vectorized(stream: EventStream, eps: EpisodeBatch,
                        lcap: int = DEFAULT_LCAP):
    """Bounded-list scan. Returns (count i64[M], overflow bool[M])."""
    if eps.N == 1:
        counts = np.array(
            [(stream.types == e).sum() for e in eps.etypes[:, 0]], np.int64)
        return counts, np.zeros(eps.M, dtype=bool)
    s0 = jnp.full((eps.M, eps.N, lcap), TIME_NEG_INF, dtype=jnp.int32)
    count, ovf = _scan_count_a1(
        jnp.asarray(eps.etypes), jnp.asarray(eps.tlo), jnp.asarray(eps.thi),
        jnp.asarray(stream.types), jnp.asarray(stream.times), s0)
    return np.asarray(count, np.int64), np.asarray(ovf)


def count_a1(stream: EventStream, eps: EpisodeBatch,
             lcap: int = DEFAULT_LCAP, use_kernel: bool = True) -> np.ndarray:
    """Exact Algorithm-1 counts: vectorized fast path + oracle fallback for
    episodes whose bounded lists may have evicted a live witness."""
    if use_kernel:
        try:
            from repro.kernels import ops as kops
            counts, ovf = kops.a1_count(stream, eps, lcap=lcap)
        except (ImportError, NotImplementedError):
            counts, ovf = count_a1_vectorized(stream, eps, lcap=lcap)
    else:
        counts, ovf = count_a1_vectorized(stream, eps, lcap=lcap)
    if ovf.any():
        idx = np.nonzero(ovf)[0]
        exact = ref.count_a1_sequential(stream, eps.select(idx))
        counts = counts.copy()
        counts[idx] = exact
    return counts
