"""Vectorized A1 counting (Algorithm 1) with bounded per-level lists.

Algorithm 1's state is a list of recent timestamps per episode level; the
list walk is data-dependent control flow — the exact thing the paper pays for
on the GPU in registers/local-memory/divergence (§5.3, Fig. 10) and that a
TPU pays for in un-vectorizable gathers. We bound each list to ``LCAP`` slots
kept in a circular buffer, turning the walk into a masked reduction over a
dense i32[M, N, LCAP] tile.

Correctness containment: bounding can only *undercount* (a live witness may
be evicted while newer entries fail the lower bound). We detect possibly-live
evictions exactly — an evicted level-i entry ``v`` is dead iff
``t - v > thi[i]`` (its only consumer is level i+1 within ``thi[i]``) — and
flag the episode. Flagged episodes are recounted by the sequential oracle
(``ref.count_a1_sequential``), so the public ``count_a1`` is always exact.
Tests sweep LCAP and assert the flag ⇒ recount path restores oracle equality.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.tally import record_fallback

from . import ref
from .episodes import EpisodeBatch
from .events import TIME_NEG_INF, EventStream, count_level1

DEFAULT_LCAP = 4


def step_bounded_list(s, ptr, count, ovf, etypes, tlo, thi, e, t,
                      dup=False):
    """One event against M bounded-list (A1) state machines.

    Args:
      s:    i32[M, N, L] circular timestamp buffers (TIME_NEG_INF = empty)
      ptr:  i32[M, N] next write slot per level
      count: i32[M]; ovf: bool[M] possibly-live-eviction flag
      etypes i32[M, N]; tlo/thi i32[M, N-1]; e/t scalar i32
      dup:  scalar bool — a later event shares this timestamp. Needed for
            exact eviction accounting: a fresh entry at time t covers an
            evicted one for consumers at t' > t, but not at t' == t
            (the (0, thi] lower bound is strict).

    Returns (s', ptr', count', ovf').
    """
    m, n, cap = s.shape
    match = etypes == e  # [M, N]
    delta = t - s[:, :-1, :]  # [M, N-1, L]
    witness = (delta > tlo[:, :, None]) & (delta <= thi[:, :, None])
    ok = witness.any(axis=-1)  # [M, N-1]
    advance = jnp.concatenate(
        [jnp.ones_like(match[:, :1]), ok], axis=1) & match  # [M, N]
    complete = advance[:, -1]  # [M]
    store = advance.at[:, -1].set(False)  # last level never stores
    store = store & ~complete[:, None]  # completion short-circuits the walk

    # circular append at ptr where store
    onehot = jax.nn.one_hot(ptr, cap, dtype=jnp.bool_)  # [M, N, L]
    write = store[:, :, None] & onehot
    # live-eviction detection: evicted value v still matters iff t-v <= thi[i]
    # (level N-1 has no outgoing edge; it never stores anyway)
    evicted = jnp.where(write, s, TIME_NEG_INF)  # [M, N, L]
    v = evicted.max(axis=-1)  # [M, N] value being overwritten (or NEG_INF)
    thi_out = jnp.concatenate(  # outgoing-edge upper bound per level
        [thi, jnp.zeros_like(thi[:, :1])], axis=1)  # [M, N]
    tlo_out = jnp.concatenate(
        [tlo, jnp.zeros_like(tlo[:, :1])], axis=1)  # [M, N]
    # Obs 5.1: with a zero lower bound the newest entry dominates — eviction
    # is provably safe for strictly-later consumers; only a real lower bound
    # (or a same-timestamp successor event) can make an old witness live.
    live = (v > TIME_NEG_INF) & (t - v <= thi_out) & ((tlo_out > 0) | dup)
    ovf_new = ovf | live.any(axis=-1)

    s_new = jnp.where(write, t, s)
    ptr_new = jnp.where(store, (ptr + 1) % cap, ptr)
    # completion: full reset
    s_new = jnp.where(complete[:, None, None], TIME_NEG_INF, s_new)
    ptr_new = jnp.where(complete[:, None], 0, ptr_new)
    return s_new, ptr_new, count + complete.astype(count.dtype), ovf_new


def dup_flags(ev_types, ev_times):
    """bool[n]: a later *real* event shares this event's timestamp.
    (Events are time-sorted, so it suffices to look at the successor.)"""
    from .events import PAD_TYPE
    nxt_same = jnp.concatenate(
        [(ev_times[1:] == ev_times[:-1]) & (ev_types[1:] != PAD_TYPE),
         jnp.zeros((1,), jnp.bool_)])
    return nxt_same


@dataclasses.dataclass
class A1State:
    """Carry of the M bounded-list machines between stream chunks.

    Device arrays; thread the state returned by one chunk's scan into the
    next chunk's call — after a carried call the *passed* state may have been
    donated (its buffers reused), so never touch it again. ``ovf`` is sticky:
    once an episode's bounded lists may have evicted a live witness, every
    later count for it must be restored by an oracle recount over the full
    concatenated history (``StreamingCounter`` does this automatically).
    """

    s: jax.Array      # i32[M, N, L] circular timestamp buffers
    ptr: jax.Array    # i32[M, N] next write slot
    count: jax.Array  # i32[M] completions so far
    ovf: jax.Array    # bool[M] possibly-live-eviction flag (sticky)


def init_a1_state(eps: EpisodeBatch, lcap: int = DEFAULT_LCAP) -> A1State:
    """Fresh (empty-list) machines for ``eps``."""
    return A1State(
        s=jnp.full((eps.M, eps.N, lcap), TIME_NEG_INF, dtype=jnp.int32),
        ptr=jnp.zeros((eps.M, eps.N), dtype=jnp.int32),
        count=jnp.zeros((eps.M,), dtype=jnp.int32),
        ovf=jnp.zeros((eps.M,), dtype=jnp.bool_))


def _a1_scan_core(etypes, tlo, thi, ev_types, ev_times, s, ptr, c, ovf):
    dups = dup_flags(ev_types, ev_times)

    def body(carry, ev):
        s_, ptr_, c_, ovf_ = carry
        e, t, d = ev
        return step_bounded_list(s_, ptr_, c_, ovf_, etypes, tlo, thi, e, t,
                                 d), None

    carry, _ = jax.lax.scan(body, (s, ptr, c, ovf),
                            (ev_types, ev_times, dups))
    return carry


@functools.lru_cache(maxsize=None)
def _a1_carry_scan():
    """jit'd carried scan; donates the state buffers so a long-running
    stream reuses one persistent allocation per shape bucket (donation is a
    no-op warning on backends that don't support it, e.g. CPU)."""
    donate = (5, 6, 7, 8) if jax.default_backend() != "cpu" else ()
    return jax.jit(_a1_scan_core, donate_argnums=donate)


@jax.jit
def _scan_count_a1(etypes, tlo, thi, ev_types, ev_times, s0):
    m, n = etypes.shape
    ptr0 = jnp.zeros((m, n), dtype=jnp.int32)
    c0 = jnp.zeros((m,), dtype=jnp.int32)
    ovf0 = jnp.zeros((m,), dtype=jnp.bool_)
    _, _, count, ovf = _a1_scan_core(etypes, tlo, thi, ev_types, ev_times,
                                     s0, ptr0, c0, ovf0)
    return count, ovf


def count_a1_vectorized(stream: EventStream, eps: EpisodeBatch,
                        lcap: int = DEFAULT_LCAP, state: A1State | None = None,
                        return_state: bool = False):
    """Bounded-list scan. Returns (count i64[M], overflow bool[M]) — plus the
    carried ``A1State`` when ``return_state`` is set.

    With ``state`` the machines resume where the previous chunk left them
    instead of rebuilding per call; chunked counting is then bit-identical to
    one call on the concatenation **provided chunk boundaries never split a
    group of equal timestamps** (the successor-duplicate flags feeding the
    eviction accounting are computed per chunk). ``StreamingCounter`` holds
    back the trailing tie group to guarantee that invariant.
    """
    if eps.N == 1:
        counts = count_level1(stream, eps.etypes[:, 0])
        if state is not None:
            counts = counts + np.asarray(state.count, np.int64)
        if return_state:
            # 1-node machines never store timestamps; only the count moves
            st = state if state is not None else init_a1_state(eps, lcap)
            st = dataclasses.replace(st,
                                     count=jnp.asarray(counts, jnp.int32))
            return counts, np.zeros(eps.M, dtype=bool), st
        return counts, np.zeros(eps.M, dtype=bool)
    if state is None:
        state = init_a1_state(eps, lcap)
    s, ptr, c, ovf = _a1_carry_scan()(
        jnp.asarray(eps.etypes), jnp.asarray(eps.tlo), jnp.asarray(eps.thi),
        jnp.asarray(stream.types), jnp.asarray(stream.times),
        state.s, state.ptr, state.count, state.ovf)
    new_state = A1State(s=s, ptr=ptr, count=c, ovf=ovf)
    counts = np.asarray(c, np.int64)
    ovf_np = np.asarray(ovf)
    if return_state:
        return counts, ovf_np, new_state
    return counts, ovf_np


def count_a1(stream: EventStream, eps: EpisodeBatch,
             lcap: int = DEFAULT_LCAP, use_kernel: bool = True,
             state: A1State | None = None, return_state: bool = False):
    """Exact Algorithm-1 counts: vectorized fast path + oracle fallback for
    episodes whose bounded lists may have evicted a live witness.

    Stateful mode (``state``/``return_state``): the machines resume from the
    carried state and return ``(counts, A1State)`` with *cumulative* counts
    over everything the state has seen. With ``use_kernel`` the chunk runs
    through the state-in/state-out Pallas kernel
    (``kernels.ops.a1_count_stateful``) when the dispatch policy allows,
    falling back to the carried XLA scan otherwise — bit-identical either
    way. The oracle fallback cannot run here — the caller sees only this
    chunk, so exactness for ``state.ovf``-flagged episodes must be restored
    by recounting the concatenated history (``StreamingCounter.counts``
    does).
    """
    if state is not None or return_state:
        if use_kernel and eps.N > 1:
            try:
                from repro.kernels import ops as kops
                counts, _, new_state = kops.a1_count_stateful(
                    stream, eps, state=state, lcap=lcap)
                return counts, new_state
            except (ImportError, NotImplementedError):
                record_fallback("a1_stateful")
        out = count_a1_vectorized(stream, eps, lcap=lcap, state=state,
                                  return_state=True)
        counts, _, new_state = out
        return counts, new_state
    if use_kernel:
        try:
            from repro.kernels import ops as kops
            counts, ovf = kops.a1_count(stream, eps, lcap=lcap)
        except (ImportError, NotImplementedError):
            record_fallback("a1_count")
            counts, ovf = count_a1_vectorized(stream, eps, lcap=lcap)
    else:
        counts, ovf = count_a1_vectorized(stream, eps, lcap=lcap)
    if ovf.any():
        idx = np.nonzero(ovf)[0]
        exact = ref.count_a1_sequential(stream, eps.select(idx))
        counts = counts.copy()
        counts[idx] = exact
    return counts
