"""Exact cross-window streaming engine (chip-on-chip loop, PR 1 tentpole).

The paper's real-time claim rests on "processing partitions of the data
stream in turn"; the companion accelerator-transformation paper
(arXiv:0905.2203) makes *sustained* throughput across those partitions the
benchmark that matters. The seed's ``mine_partitions`` rebuilt every counting
machine at each window boundary, silently losing occurrences that span
partitions. This module replaces that with carried machines whose
window-by-window counts are **bit-identical to one-shot counting on the
concatenated stream**:

``StreamingCounter``
    Exact cumulative non-overlapped A1 counts for a fixed ``EpisodeBatch``
    over incrementally arriving windows. Three engines:

    * ``"ptpe"``        — the bounded-list machines with their
      (s, ptr, count, ovf) carry threaded across windows (episode-parallel,
      one machine set). With ``use_kernel`` (the default) the carry lives in
      the state-in/state-out Pallas kernel's brick layout and every window
      is one ``a1_count_state_kernel`` launch — the chip-on-chip loop stays
      on the accelerator; when the dispatch policy declines (CPU without
      interpret mode) the carried XLA scan runs instead, bit-identically.
    * ``"mapconcatenate"`` — segment-parallel streaming: each window is cut
      into phase-shifted segment scans and their (a, count, b) tuples are
      stitched onto a carried tuple with an incremental left fold — the
      associative form of the paper's Concatenate tree (Fig. 6). Because a
      segment's tuple needs ``W`` ticks of lookahead (its crossing zone), the
      commit frontier trails the ingest frontier by ``W``; ``finalize()``
      flushes the tail. With ``use_kernel`` (the default) each commit runs
      as ONE segmented Pallas launch — grid = (episode tile × time
      segment), Map step and Concatenate fold fused on-chip
      (``kernels.a1_count.a1_mapconcat_kernel``) — whose pre-stitched
      tuple folds onto the carry; the per-launch segment count is still
      chosen from the committed span vs ``W``. ``engine="mapconcat_kernel"``
      is accepted as an alias that forces this path's selection. On a
      multi-device host the commit additionally shards over the mesh
      ``data`` axis: each device runs one segmented launch on its
      contiguous segment group and the per-device tuples are all-gathered
      and folded replicated (``kernels.ops.a1_mapconcat_sharded_tuples``),
      with the per-commit segment count chosen device-count-aware (at
      least one stitch-safe segment per device when the span allows;
      commits too short to shard take the single-device launch,
      bit-identically). ``engine="mapconcat_sharded"`` is the alias that
      forces the segment-parallel engine with this residency preferred.
      ``state_dict`` stays in the device-count-independent canonical
      layout either way — a checkpoint written under sharded residency on
      an 8-device mesh restores onto a single-device counter (and vice
      versa) with identical subsequent counts.
    * ``"hybrid"``      — Eq. 2 dispatcher applied once at construction.

    Exactness containment is inherited from the one-shot engines: bounded
    lists flag possibly-live evictions (``ovf``) and unstitchable tuples flag
    ``unmatched``; flagged episodes are recounted by the exact engine over
    the retained concatenated history, so ``counts()`` is always exact.

    Two boundary subtleties make the bit-exact claim real:

    * *tie-group holdback* — the per-chunk successor-duplicate flags that
      feed A1's eviction accounting can't see across a boundary that splits
      a group of equal timestamps, so ingestion holds back the trailing tie
      group and prepends it to the next window (``finalize()`` flushes it);
    * *shape-bucketed staging* — each window is padded to a power-of-two
      event-buffer bucket before hitting the jit'd scans, so windows after
      the first reuse warm compile caches and (off-CPU) donated state
      buffers; ``run()`` additionally stages window p+1's device transfer
      while window p counts.

``StreamingA2Counter``
    The relaxed upper-bound machines (Obs. 5.1: single slot per level is
    complete state) carried the same way — unconditionally exact under any
    partitioning, used by the streaming two-pass cull.

``StreamingMiner``
    Level-wise mining over the carried counters with per-window θ
    (``mode="per_window"``: θ applies to counts *completed in* each window,
    boundary-spanning occurrences included) or cumulative θ
    (``mode="cumulative"``: θ applies to counts over the whole stream so
    far; the final window's report equals one-shot ``mine`` on the
    concatenation). Two-pass culling stays sound across windows: cumulative
    A2 dominates cumulative A1 (Thm. 5.1 on the concatenation), and the
    per-window cull uses the safe bound
    ``a1_delta(p) <= a2_cum(p) - a1_known(p-1)``. Episodes are promoted to
    exact counting lazily; a promoted episode's machines catch up by
    replaying the retained window history.
"""

from __future__ import annotations

import collections
import dataclasses
import hashlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.tally import record_fallback
from repro.obs import span as _obs_span

from . import candidates as _cand
from .count_a1 import (A1State, DEFAULT_LCAP, _a1_carry_scan, count_a1,
                       init_a1_state)
from .count_a2 import A2State, count_single_slot, init_a2_state
from .episodes import EpisodeBatch
from .events import (PAD_TYPE, TIME_NEG_INF, EventStream, count_level1,
                     type_histogram)
from .mapconcat import _map_all_segments, fold_pair
from .miner import LevelStats, MiningResult

_EMPTY_I32 = np.empty(0, np.int32)


def bucket_size(n: int, minimum: int = 128) -> int:
    """Next power-of-two event-buffer length >= max(n, minimum) — bounds the
    number of distinct scan shapes (and therefore jit compiles) to
    O(log max_window)."""
    b = max(minimum, 1)
    while b < n:
        b *= 2
    return b


def _split_tie_tail(types: np.ndarray, times: np.ndarray):
    """Split off the trailing group of events sharing the final timestamp.

    Everything before the cut can be fed to the carried scans now: each fed
    event's successor-duplicate flag is decidable without future events
    (the tie tail's own flags may depend on the *next* window's first
    timestamp)."""
    if times.size == 0:
        return (types, times), (types[:0], times[:0])
    cut = int(np.searchsorted(times, times[-1], side="left"))
    return (types[:cut], times[:cut]), (types[cut:], times[cut:])


def _opt_pack(v) -> np.ndarray:
    """Optional int → i64[0 or 1] (checkpointable encoding of None)."""
    return np.asarray([] if v is None else [int(v)], np.int64)


def _opt_unpack(a) -> int | None:
    a = np.asarray(a).reshape(-1)
    return None if a.size == 0 else int(a[0])


def _state_sub(d: dict, prefix: str) -> dict:
    """Slice a flat state dict down to the keys under ``prefix``."""
    return {k[len(prefix):]: v for k, v in d.items() if k.startswith(prefix)}


class _OracleA1:
    """Exact Algorithm-1 machine for ONE episode with explicit carried state
    (``ref.count_a1_sequential``, stateful form).

    Bounded-memory recovery rests on this: a flagged episode's count is
    restored by replaying only the retained suffix from its known-exact
    state at the suffix base, instead of re-scanning the whole stream from
    genesis. ``lists[i]`` holds the level-``i`` partial-occurrence
    timestamps in chronological order (the oracle walks them newest-first).
    """

    __slots__ = ("et", "tlo", "thi", "n", "lists", "count")

    def __init__(self, etypes, tlo, thi, lists=None, count: int = 0):
        self.et = [int(x) for x in np.asarray(etypes).reshape(-1)]
        self.tlo = [int(x) for x in np.asarray(tlo).reshape(-1)]
        self.thi = [int(x) for x in np.asarray(thi).reshape(-1)]
        self.n = len(self.et)
        self.lists = ([list(lst) for lst in lists] if lists is not None
                      else [[] for _ in range(self.n)])
        self.count = int(count)

    def copy(self) -> "_OracleA1":
        return _OracleA1(self.et, self.tlo, self.thi, self.lists, self.count)

    def feed(self, types: np.ndarray, times: np.ndarray) -> int:
        """Scan a chunk of events; returns the cumulative exact count."""
        n, et, tlo, thi = self.n, self.et, self.tlo, self.thi
        s, count = self.lists, self.count
        for e, t in zip(np.asarray(types).tolist(),
                        np.asarray(times).tolist()):
            if e < 0:  # PAD_TYPE
                continue
            completed = False
            for i in range(n - 1, -1, -1):  # top-down over levels
                if e != et[i]:
                    continue
                if i == 0:
                    s[0].append(t)
                    continue
                for t_prev in reversed(s[i - 1]):
                    if tlo[i - 1] < t - t_prev <= thi[i - 1]:
                        if i == n - 1:
                            count += 1
                            s = [[] for _ in range(n)]
                            completed = True
                        else:
                            s[i].append(t)
                        break
                if completed:
                    break
        self.lists, self.count = s, count
        return count

    def pruned(self, t_frontier: int) -> list[list[int]]:
        """Live entries only: a level-``i`` entry ``v`` is dead once
        ``t - v > thi[i]`` for every future ``t >= t_frontier`` (its sole
        consumer is level i+1 within ``thi[i]``)."""
        out = []
        for i in range(self.n):
            if i >= self.n - 1:
                out.append([])  # the top level never stores
            else:
                out.append([v for v in self.lists[i]
                            if t_frontier - v <= self.thi[i]])
        return out


def _lists_from_slots(s_row: np.ndarray, ptr_row: np.ndarray):
    """Bounded circular buffers → oracle lists (chronological order).

    Valid as an *exact* oracle seed only for an unflagged episode: with
    ``ovf`` clear every eviction so far was provably dead, so the surviving
    entries are behaviorally complete state. Slot ``ptr`` is the next write
    slot, hence slots ptr, ptr+1, … (mod cap) run oldest→newest."""
    n, cap = s_row.shape
    lists = []
    for lvl in range(n):
        p = int(ptr_row[lvl])
        vals = [int(s_row[lvl, (p + k) % cap]) for k in range(cap)]
        lists.append([v for v in vals if v > int(TIME_NEG_INF)])
    return lists


def _slots_from_lists(lists, lcap: int):
    """Oracle lists → bounded circular buffers, or None if any level's live
    entries overflow ``lcap`` (the episode then stays in the oracle
    escrow)."""
    n = len(lists)
    s = np.full((n, lcap), TIME_NEG_INF, np.int32)
    ptr = np.zeros(n, np.int32)
    for lvl, vals in enumerate(lists):
        if len(vals) > lcap:
            return None
        for k, v in enumerate(vals):
            s[lvl, k] = v
        ptr[lvl] = len(vals) % lcap
    return s, ptr


@dataclasses.dataclass
class _Staged:
    """A window prepared for dispatch: holdback applied, history recorded,
    (ptpe) padded + transferred to device ahead of the blocking read."""

    feed_types: object   # np.ndarray (mapc) or jax.Array (ptpe, padded)
    feed_times: object
    n: int               # real fed events
    final: bool


class StreamingCounter:
    """Exact cumulative A1 counts of ``eps`` over an arriving partition.

    Feed successive non-overlapping, time-ordered windows with ``update``
    (or the prefetching ``run``); call ``finalize`` after the last window to
    flush the holdback/commit tail. ``counts()``/``update()`` return exact
    int64[M] cumulative counts — flagged episodes are restored against the
    retained history, exactly like the one-shot engines restore against the
    full stream.
    """

    def __init__(self, eps: EpisodeBatch, engine: str = "hybrid",
                 lcap: int = DEFAULT_LCAP, num_segments: int = 8,
                 use_kernel: bool = True, keep_history: bool = True,
                 min_bucket: int = 128, executor=None,
                 checkpoint_interval: int | None = None):
        if engine in ("mapconcat_kernel", "mapconcat_sharded"):
            # aliases: the segment-parallel engine with the Pallas path
            # forced (sharded residency engages on its own whenever the
            # mesh has more than one usable device)
            engine, use_kernel = "mapconcatenate", True
        if engine not in ("ptpe", "mapconcatenate", "hybrid"):
            raise ValueError(f"unknown engine {engine!r}")
        if checkpoint_interval is not None and checkpoint_interval < 1:
            raise ValueError("checkpoint_interval must be >= 1")
        self.eps = eps
        self.lcap = lcap
        self.num_segments = num_segments
        self.use_kernel = use_kernel
        self.keep_history = keep_history
        self.min_bucket = min_bucket
        self.executor = executor
        self.ckpt_interval = checkpoint_interval
        self.bounded = checkpoint_interval is not None
        self._kernel = False  # carried-Pallas path (resolved per engine)
        self._mapc_kernel = False  # segmented-Pallas path (mapconcatenate)
        self._shard_d = 1   # mesh data-axis width the commits shard over
        # exact cum counts per window (bounded mode caps the tail retained)
        self.snapshots = (collections.deque(maxlen=8) if self.bounded
                          else [])
        self.windows_seen = 0
        self.finalized = False
        self._num_types: int | None = None
        self._held_t = _EMPTY_I32
        self._held_tt = _EMPTY_I32
        self._hist: list[tuple[np.ndarray, np.ndarray]] = []
        self._consumed = 0  # events dispatched into the machines so far
        self._t_last: int | None = None
        if eps.N == 1:
            self.engine = "level1"
            self._cum = np.zeros(eps.M, np.int64)
            return
        if engine == "hybrid":
            # dispatch policy: calibrated cost table when installed, else
            # exactly the old Eq. 2 resolution (M vs crossover(N))
            from . import hybrid as _hybrid
            from .calibrate import get_policy
            engine = get_policy().choose_stream(
                n_episode=eps.N, m=eps.M, use_kernel=use_kernel,
                kernel_ok=(use_kernel
                           and _hybrid._mapc_kernel_available()),
                shard_devices=_hybrid.shard_devices()).engine
        self.engine = engine
        self._et = jnp.asarray(eps.etypes)
        self._tlo = jnp.asarray(eps.tlo)
        self._thi = jnp.asarray(eps.thi)
        if engine == "ptpe":
            self._state = init_a1_state(eps, lcap)
            if use_kernel:
                self._try_enable_kernel()
        else:
            self._w = np.asarray(eps.max_span, np.int64)
            self._w_dev = jnp.asarray(self._w, jnp.int32)
            self._wmax = int(self._w.max())
            self._carry = None        # (a, c, b, flag) each jnp [K, M]
            self._ovf = np.zeros(eps.M, bool)
            self._tau_c: int | None = None
            self._buf_t = _EMPTY_I32  # committed-lookback + pending events
            self._buf_tt = _EMPTY_I32
            if use_kernel:
                self._try_enable_mapc_kernel()
        if self.bounded:
            # suffix-only retention: fed chunks since the last machine-state
            # checkpoint, the checkpointed state itself, and the oracle
            # escrow for episodes whose exact lists overflow lcap
            self._suffix: list[tuple[np.ndarray, np.ndarray]] = []
            self._escrow: dict[int, _OracleA1] = {}
            self._base_consumed = 0
            self._wsb = 0  # fed windows since the last base advance
            self._bstate = {
                "s": np.full((eps.M, eps.N, lcap), TIME_NEG_INF, np.int32),
                "ptr": np.zeros((eps.M, eps.N), np.int32),
                "count": np.zeros(eps.M, np.int32),
                "ovf": np.zeros(eps.M, bool)}

    # --------------------------------------------------- kernel residency

    def _try_enable_kernel(self) -> None:
        """Switch the ptpe engine onto the state-in/state-out Pallas kernel
        when the dispatch policy allows (TPU, or interpret mode requested).
        The carried machine state then lives in the kernel's brick layout
        across windows — packed once here, never per window — so the
        hottest loop stays on-chip. When the probe declines, the carried
        XLA scan remains the engine (bit-identical either way)."""
        try:
            from repro.kernels import ops as kops
            self._interp = kops.kernel_mode()
        except (ImportError, NotImplementedError):
            record_fallback("stream_a1_residency")
            return
        self._kops = kops
        self._kernel = True
        self._ket, self._ktlo, self._kthi = kops.episode_layout(
            self.eps, inclusive_lower=False)
        self._kst = kops.a1_state_layout(self._state)
        self._state = None  # authoritative state is the kernel brick now

    def _try_enable_mapc_kernel(self) -> None:
        """Segment-parallel analogue of ``_try_enable_kernel``: when the
        dispatch policy allows, each commit batch runs as one segmented
        Pallas launch (grid = episode tile × time segment, Concatenate
        fold fused on-chip — ``kernels.a1_count.a1_mapconcat_kernel``)
        whose pre-stitched tuple folds onto the carried tuple, instead of
        an XLA Map step plus a host-side per-segment fold loop. The
        episode/phase bricks are packed once here; the segment count per
        launch is still chosen from the committed span vs W (see
        ``_dispatch_mapc``). On a multi-device host the commits
        additionally shard: one segmented launch per mesh ``data`` device
        (its contiguous segment group), per-device tuples all-gathered and
        folded replicated — the residency itself is host-local state, so
        checkpoints stay portable across device counts."""
        try:
            from repro.kernels import ops as kops
            self._interp = kops.kernel_mode()
        except (ImportError, NotImplementedError):
            record_fallback("stream_mapc_residency")
            return
        self._kops = kops
        self._mapc_kernel = True
        self._shard_d = kops.shard_device_count()
        (self._ket, self._ktlo, self._kthi, self._kcum,
         self._kw) = kops.mapconcat_layout(self.eps, inclusive_lower=False)

    def _host_state(self) -> A1State:
        """The carried machines in canonical episode-major layout (unpacks
        the kernel brick when the kernel path is resident)."""
        if self._kernel:
            return self._kops.a1_state_unpack(*self._kst, self.eps.M,
                                              self.eps.N)
        return self._state

    def _set_host_state(self, st: A1State) -> None:
        """Install canonical-layout machine state (repacks into the kernel
        brick when the kernel path is resident)."""
        if self._kernel:
            self._kst = self._kops.a1_state_layout(st)
        else:
            self._state = st

    # ------------------------------------------------------------ ingest

    def _prepare(self, window: EventStream | None, final: bool) -> _Staged:
        """Host side of one window: strip padding, validate the partition
        contract, apply tie-group holdback, record history, and (ptpe) stage
        the padded chunk onto the device. Mutates holdback/history, so
        prepare calls must stay in window order — but none of this depends
        on the *device* state, which is what lets ``run`` overlap window
        p+1's transfer with window p's scan."""
        with _obs_span("stream.prepare", final=final):
            return self._prepare_impl(window, final)

    def _prepare_impl(self, window: EventStream | None,
                      final: bool) -> _Staged:
        if window is None:
            t = tt = _EMPTY_I32
        else:
            real = window.types != PAD_TYPE
            t = window.types[real]
            tt = window.times[real]
            if self._num_types is None:
                self._num_types = window.num_types
        if t.size:
            if self._t_last is not None and int(tt[0]) < self._t_last:
                raise ValueError(
                    "streaming windows must be a time-ordered partition "
                    f"(window starts at {int(tt[0])} < frontier "
                    f"{self._t_last}); dedup overlapping windows first")
            self._t_last = int(tt[-1])
            if self.keep_history and not self.bounded:
                self._hist.append((t, tt))
        chunk_t = np.concatenate([self._held_t, t])
        chunk_tt = np.concatenate([self._held_tt, tt])
        if final:
            feed, held = (chunk_t, chunk_tt), (_EMPTY_I32, _EMPTY_I32)
        else:
            feed, held = _split_tie_tail(chunk_t, chunk_tt)
        self._held_t, self._held_tt = held
        n = feed[0].size
        if self.bounded and self.engine != "level1" and n:
            # fed (post-holdback) chunks: exactly what the machines consume,
            # so a suffix replay from the base state reproduces the scans
            self._suffix.append((np.asarray(feed[0], np.int32).copy(),
                                 np.asarray(feed[1], np.int32).copy()))
        if self.engine == "ptpe" and n:
            b = bucket_size(n, self.min_bucket)
            if self._kernel:
                # kernel event brick (types; times; dup) — the per-chunk dup
                # flags are exact because the tie-group holdback above
                # guarantees the chunk never ends inside a tie group
                ev = self._kops.event_brick(feed[0], feed[1], with_dup=True,
                                            length=b)
                return _Staged(jax.device_put(ev), None, n, final)
            ft = np.full(b, PAD_TYPE, np.int32)
            ftt = np.full(b, feed[1][-1], np.int32)
            ft[:n] = feed[0]
            ftt[:n] = feed[1]
            return _Staged(jax.device_put(ft), jax.device_put(ftt), n, final)
        return _Staged(feed[0], feed[1], n, final)

    # ---------------------------------------------------------- dispatch

    def _dispatch(self, staged: _Staged) -> None:
        self._consumed += staged.n
        if self.engine == "level1":
            if staged.n:
                sub = EventStream(staged.feed_types, staged.feed_times,
                                  self._num_types)
                self._cum += count_level1(sub, self.eps.etypes[:, 0])
            return
        if self.engine == "ptpe":
            if staged.n:
                if self._kernel:
                    s, po, c, ovf = self._kst
                    args = (self._ket, self._ktlo, self._kthi,
                            staged.feed_types, s, po, c, ovf)
                    if self.executor is not None:
                        out = self.executor.a1_kernel_scan(
                            args, self.eps.N, self.lcap, self._interp)
                    else:
                        with _obs_span("stream.launch", kind="a1_state"):
                            out = self._kops.a1_state_call(
                                *args, n_levels=self.eps.N, lcap=self.lcap,
                                interpret=self._interp)
                    c, ovf, s, po = out
                    self._kst = (s, po, c, ovf)
                else:
                    st = self._state
                    args = (self._et, self._tlo, self._thi,
                            staged.feed_types, staged.feed_times,
                            st.s, st.ptr, st.count, st.ovf)
                    if self.executor is not None:
                        s, ptr, c, ovf = self.executor.a1_scan(args)
                    else:
                        with _obs_span("stream.launch", kind="a1_scan"):
                            s, ptr, c, ovf = _a1_carry_scan()(*args)
                    self._state = A1State(s=s, ptr=ptr, count=c, ovf=ovf)
        else:
            self._dispatch_mapc(staged)
        if self.bounded:
            self._wsb += 1
            if staged.final or self._wsb >= self.ckpt_interval:
                self._advance_base()

    def _dispatch_mapc(self, staged: _Staged) -> None:
        if staged.n:
            self._buf_t = np.concatenate([self._buf_t, staged.feed_types])
            self._buf_tt = np.concatenate([self._buf_tt, staged.feed_times])
        if self._buf_tt.size == 0:
            return
        if self._tau_c is None:
            self._tau_c = int(self._buf_tt[0]) - 1
        t_f = int(self._buf_tt[-1])
        w = self._wmax
        if staged.final:
            tau_next = t_f
            if tau_next <= self._tau_c:
                return
        else:
            # a segment's tuple needs W ticks of lookahead (crossing zone),
            # and segments shorter than W are not stitch-safe — commit only
            # when the frontier has moved far enough past the last commit
            tau_next = t_f - w
            if tau_next - self._tau_c <= w:
                return
        with _obs_span("stream.commit"):
            span = tau_next - self._tau_c
            # device-count-aware segment count: with a sharded residency the
            # commit wants at least one stitch-safe (> W) segment per mesh
            # device, so the limit grows to cover the data axis; spans too
            # short to reach one-segment-per-device keep q < d and take the
            # single-device launch below (same counts either way)
            q_limit = max(self.num_segments, self._shard_d)
            q = 1
            safe = [1]  # stitch-safe power-of-two segment counts
            while q * 2 <= q_limit and span // (q * 2) > w:
                q *= 2
                safe.append(q)
            # per-commit q: the calibrated policy may prefer fewer, wider
            # segments than the max-parallelism heuristic (the candidate
            # list is safety-filtered here; heuristic keeps the max)
            from .calibrate import get_policy
            q, _src = get_policy().choose_segments(
                safe[::-1], engine=("mapconcat_kernel"
                                    if self._mapc_kernel
                                    else "mapconcatenate"),
                n_episode=self.eps.N, m=self.eps.M,
                n_events=int(self._buf_tt.size),
                devices=self._shard_d)
            tau = np.round(np.linspace(self._tau_c, tau_next,
                                       q + 1)).astype(np.int64)
            tau[0], tau[-1] = self._tau_c, tau_next
            lo = np.searchsorted(self._buf_tt, tau[:-1] - w, side="right")
            hi = np.searchsorted(self._buf_tt, tau[1:] + w, side="right")
            lw = bucket_size(int((hi - lo).max()), self.min_bucket)
            wt = np.full((q, lw), PAD_TYPE, np.int32)
            wtt = np.zeros((q, lw), np.int32)
            for i in range(q):
                wt[i, : hi[i] - lo[i]] = self._buf_t[lo[i]: hi[i]]
                wtt[i, : hi[i] - lo[i]] = self._buf_tt[lo[i]: hi[i]]
        use_kernel = self._mapc_kernel
        if use_kernel and lw > self._kops.MAX_SEG_BRICK_LW:
            # the padded window brick would exceed segment_bricks'
            # VMEM admission bound; run this commit on the XLA engine
            # (bit-identical carry — residency resumes next commit)
            record_fallback("stream_mapc_brick")
            use_kernel = False
        if use_kernel:
            # one segmented launch: Map + on-chip fold over this commit's
            # q segments; its pre-stitched tuple folds onto the carry. On
            # a multi-device mesh (and q covering every device) the launch
            # shards — one contiguous segment group per device, tuples
            # all-gathered and folded replicated.
            segs = self._kops.segment_bricks(wt, wtt, tau, length=lw)
            kargs = (self._ket, self._ktlo, self._kthi, self._kcum,
                     self._kw, segs)
            if self._shard_d > 1 and q >= self._shard_d:
                if self.executor is not None:
                    a, c, b, f, ovf = self.executor.mapc_sharded_scan(
                        kargs, self.eps.N, self.lcap, self._interp,
                        self._shard_d)
                else:
                    with _obs_span("stream.launch", kind="a1_mapc_shard"):
                        a, c, b, f, ovf = \
                            self._kops.a1_mapconcat_sharded_tuples(
                                *kargs, n_levels=self.eps.N, lcap=self.lcap,
                                interpret=self._interp,
                                num_devices=self._shard_d)
            elif self.executor is not None:
                a, c, b, f, ovf = self.executor.mapc_kernel_scan(
                    kargs, self.eps.N, self.lcap, self._interp)
            else:
                with _obs_span("stream.launch", kind="a1_mapc"):
                    a, c, b, f, ovf = self._kops.a1_mapconcat_tuples(
                        *kargs, n_levels=self.eps.N, lcap=self.lcap,
                        interpret=self._interp)
            k, m = self.eps.N, self.eps.M
            self._ovf |= np.asarray(ovf[0, :m] != 0)
            tup = (a[:k, :m], c[:k, :m], b[:k, :m], f[:k, :m] != 0)
            self._carry = (tup if self._carry is None
                           else fold_pair(self._carry, tup))
            self._tau_c = tau_next
            keep = self._buf_tt > tau_next - w  # next segment's lookback
            self._buf_t = self._buf_t[keep]
            self._buf_tt = self._buf_tt[keep]
            return
        margs = (jnp.asarray(wt), jnp.asarray(wtt), self._et, self._tlo,
                 self._thi, jnp.asarray(tau), self._w_dev)
        if self.executor is not None:
            a, c, b, ovf = self.executor.mapc_scan(margs, self.lcap)
        else:
            with _obs_span("stream.launch", kind="mapc_scan"):
                a, c, b, ovf = _map_all_segments(*margs, self.lcap)
        self._ovf |= np.asarray(ovf.any(axis=(0, 1)))
        i0 = 0
        if self._carry is None:
            self._carry = (a[0], c[0], b[0],
                           jnp.zeros(a[0].shape, jnp.bool_))
            i0 = 1
        for i in range(i0, q):
            self._carry = fold_pair(
                self._carry,
                (a[i], c[i], b[i], jnp.zeros(a[i].shape, jnp.bool_)))
        self._tau_c = tau_next
        keep = self._buf_tt > tau_next - w  # retain next segment's lookback
        self._buf_t = self._buf_t[keep]
        self._buf_tt = self._buf_tt[keep]

    # ------------------------------------------------------------ reads

    def counts(self) -> np.ndarray:
        """Exact cumulative counts over everything committed so far (for
        mapconcatenate, the commit frontier trails ingestion by W until
        ``finalize``)."""
        if self.engine == "level1":
            return self._cum.copy()
        if self.engine == "ptpe":
            if self._kernel:
                c = np.asarray(self._kst[2][0, : self.eps.M], np.int64)
                flagged = np.asarray(self._kst[3][0, : self.eps.M] != 0)
            else:
                c = np.asarray(self._state.count, np.int64)
                flagged = np.asarray(self._state.ovf).copy()
        else:
            if self._carry is None:
                return np.zeros(self.eps.M, np.int64)
            c = np.asarray(self._carry[1][0], np.int64)
            flagged = np.asarray(self._carry[3][0]) | self._ovf
        if flagged.any():
            if self.bounded:
                c = self._restore_exact_bounded(c.copy(), flagged)
            else:
                c = self._restore_exact(c, flagged)
        return c

    def _restore_exact(self, c: np.ndarray, flagged: np.ndarray):
        """Recount flagged episodes with the exact one-shot engine over the
        retained history (trimmed to what the machines have consumed)."""
        if not self.keep_history:
            raise RuntimeError(
                "episodes were flagged for exact recount but keep_history "
                "is off; re-run with keep_history=True")
        types = np.concatenate([t for t, _ in self._hist] or [_EMPTY_I32])
        times = np.concatenate([tt for _, tt in self._hist] or [_EMPTY_I32])
        if self.engine == "ptpe":
            # dispatched events are always a prefix of the ingested history;
            # count them explicitly — run() may already have *prepared* (and
            # history-recorded) the next window while this one's counts are
            # being read
            n = self._consumed
        else:
            n = int(np.searchsorted(times, self._tau_c, side="right"))
        stream = EventStream(types[:n], times[:n], self._num_types)
        idx = np.nonzero(flagged)[0]
        c = c.copy()
        c[idx] = count_a1(stream, self.eps.select(idx), lcap=self.lcap,
                          use_kernel=self.use_kernel)
        return c

    # ------------------------------------------------- bounded memory

    def _suffix_concat(self) -> tuple[np.ndarray, np.ndarray]:
        if not self._suffix:
            return _EMPTY_I32, _EMPTY_I32
        return (np.concatenate([t for t, _ in self._suffix]),
                np.concatenate([tt for _, tt in self._suffix]))

    def _suffix_take(self, tt_all: np.ndarray) -> int:
        """How many retained-suffix events the recovery replay must cover:
        everything the machines consumed since the base (ptpe), or the
        committed prefix up to the commit frontier τ_c (mapconcatenate) —
        never the events ``run()`` has merely prefetched."""
        if self.engine == "ptpe":
            return self._consumed - self._base_consumed
        if self._tau_c is None:
            return 0
        return int(np.searchsorted(tt_all, self._tau_c, side="right"))

    def _restore_exact_bounded(self, c: np.ndarray, flagged: np.ndarray):
        """Recount flagged episodes by replaying only the retained suffix
        from their known-exact base state (checkpointed machine state for
        episodes unflagged at the base, oracle escrow otherwise)."""
        t_all, tt_all = self._suffix_concat()
        take = self._suffix_take(tt_all)
        for i in np.nonzero(flagged)[0].tolist():
            orc = self._escrow.get(i)
            if orc is not None:
                orc = orc.copy()  # counts() is a read — never mutate escrow
            else:
                orc = _OracleA1(
                    self.eps.etypes[i], self.eps.tlo[i], self.eps.thi[i],
                    _lists_from_slots(self._bstate["s"][i],
                                      self._bstate["ptr"][i]),
                    int(self._bstate["count"][i]))
            c[i] = orc.feed(t_all[:take], tt_all[:take])
        return c

    def _shadow_scan(self, feed_t: np.ndarray, feed_tt: np.ndarray):
        """Advance the mapconcatenate engine's base shadow (a bounded-list
        A1 state) over the consumed suffix in one carried scan — the
        per-interval machine-state checkpoint the exact recovery replays
        from."""
        b = self._bstate
        if feed_t.size == 0:
            return (b["s"].copy(), b["ptr"].copy(), b["count"].copy(),
                    b["ovf"].copy())
        nb = bucket_size(feed_t.size, self.min_bucket)
        ft = np.full(nb, PAD_TYPE, np.int32)
        ftt = np.full(nb, feed_tt[-1], np.int32)
        ft[:feed_t.size] = feed_t
        ftt[:feed_tt.size] = feed_tt
        s, ptr, cnt, ovf = _a1_carry_scan()(
            self._et, self._tlo, self._thi, jnp.asarray(ft),
            jnp.asarray(ftt), jnp.asarray(b["s"]), jnp.asarray(b["ptr"]),
            jnp.asarray(b["count"]), jnp.asarray(b["ovf"]))
        return (np.asarray(s).copy(), np.asarray(ptr).copy(),
                np.asarray(cnt).copy(), np.asarray(ovf).copy())

    def _advance_base(self) -> None:
        """Per-interval machine-state checkpoint (bounded mode).

        Resolves every flagged episode exactly — replaying the retained
        suffix from the base state through its oracle — then folds resolved
        machines back into the vectorized state (flags cleared), keeps
        unresolvable ones in the oracle escrow, and drops the consumed
        suffix. Retained history is thereby O(checkpoint interval) windows
        regardless of stream length, and flags no longer accumulate into
        ever-growing genesis recounts."""
        with _obs_span("stream.checkpoint", engine=self.engine):
            self._advance_base_impl()

    def _advance_base_impl(self) -> None:
        self._wsb = 0
        t_all, tt_all = self._suffix_concat()
        take = self._suffix_take(tt_all)
        feed_t, feed_tt = t_all[:take], tt_all[:take]
        if self.engine == "ptpe":
            st = self._host_state()
            s = np.asarray(st.s).copy()
            ptr = np.asarray(st.ptr).copy()
            cnt = np.asarray(st.count).copy()
            ovf = np.asarray(st.ovf).copy()
        else:
            s, ptr, cnt, ovf = self._shadow_scan(feed_t, feed_tt)
        pend = sorted(set(np.nonzero(ovf)[0].tolist()) | set(self._escrow))
        if pend:
            t_f = int(feed_tt[-1]) if take else None
            escrow: dict[int, _OracleA1] = {}
            for i in pend:
                orc = self._escrow.get(i)
                if orc is None:
                    orc = _OracleA1(
                        self.eps.etypes[i], self.eps.tlo[i], self.eps.thi[i],
                        _lists_from_slots(self._bstate["s"][i],
                                          self._bstate["ptr"][i]),
                        int(self._bstate["count"][i]))
                orc.feed(feed_t, feed_tt)
                cnt[i] = orc.count
                lists = orc.pruned(t_f) if t_f is not None else orc.lists
                fit = _slots_from_lists(lists, self.lcap)
                if fit is None:
                    escrow[i] = orc
                    ovf[i] = True
                else:
                    s[i], ptr[i] = fit
                    ovf[i] = False
            self._escrow = escrow
        self._bstate = {"s": s, "ptr": ptr, "count": cnt, "ovf": ovf}
        self._base_consumed += take
        self._suffix = ([(t_all[take:], tt_all[take:])]
                        if t_all.size > take else [])
        if self.engine == "ptpe":
            # fold the resolution back so future scans run from exact state
            self._set_host_state(A1State(
                s=jnp.asarray(s), ptr=jnp.asarray(ptr),
                count=jnp.asarray(cnt), ovf=jnp.asarray(ovf)))

    @property
    def retained_windows(self) -> int:
        """Raw event-chunk windows currently held for exact recovery —
        O(checkpoint interval) in bounded mode, O(stream) otherwise."""
        if self.engine == "level1":
            return 0
        if self.bounded:
            return len(self._suffix)
        return len(self._hist)

    def _snapshot(self) -> np.ndarray:
        out = self.counts()
        self.snapshots.append(out)
        self.windows_seen += 1
        return out

    # ----------------------------------------------------------- public

    def fast_forward(self, p: int) -> None:
        """Declare the first ``p`` miner windows out of scope for this
        (virgin) counter — bounded-history mining starts late-born counters
        at the retained-suffix horizon instead of replaying from genesis."""
        if self.windows_seen or self._consumed:
            raise RuntimeError("fast_forward on a non-virgin counter")
        self.windows_seen = p

    def state_dict(self) -> dict[str, np.ndarray]:
        """Dynamic machine state as a flat ``{str: np.ndarray}`` pytree —
        checkpointable through ``checkpoint.ckpt`` and restorable with
        ``load_state_dict`` onto a counter constructed with the same
        configuration. Every leaf is an owned copy (safe to stash as a
        retry snapshot while the counter keeps running)."""
        d = {"windows_seen": np.asarray(self.windows_seen, np.int64),
             "finalized": np.asarray(int(self.finalized), np.int64),
             "consumed": np.asarray(self._consumed, np.int64),
             "num_types": _opt_pack(self._num_types),
             "t_last": _opt_pack(self._t_last),
             "held_t": self._held_t.copy(),
             "held_tt": self._held_tt.copy()}
        for j, snap in enumerate(list(self.snapshots)[-3:]):
            d[f"snap/{j}"] = np.asarray(snap, np.int64).copy()
        if self.engine == "level1":
            d["cum"] = self._cum.copy()
            return d
        if self.engine == "ptpe":
            # canonical episode-major layout regardless of residency: a
            # checkpoint written by the kernel path restores onto a scan
            # counter and vice versa (the kernel brick round-trips through
            # a1_state_unpack / a1_state_layout)
            st = self._host_state()
            d["s"] = np.asarray(st.s).copy()
            d["ptr"] = np.asarray(st.ptr).copy()
            d["count"] = np.asarray(st.count).copy()
            d["ovf"] = np.asarray(st.ovf).copy()
        else:
            d["mapc_ovf"] = self._ovf.copy()
            d["tau_c"] = _opt_pack(self._tau_c)
            d["buf_t"] = self._buf_t.copy()
            d["buf_tt"] = self._buf_tt.copy()
            if self._carry is not None:
                for name, arr in zip(("a", "c", "b", "f"), self._carry):
                    d[f"carry/{name}"] = np.asarray(arr).copy()
        if self.bounded:
            for k, v in self._bstate.items():
                d[f"base/{k}"] = v.copy()
            d["base_consumed"] = np.asarray(self._base_consumed, np.int64)
            d["wsb"] = np.asarray(self._wsb, np.int64)
            for j, (t, tt) in enumerate(self._suffix):
                d[f"suffix/{j}/t"] = t.copy()
                d[f"suffix/{j}/tt"] = tt.copy()
            for i, orc in self._escrow.items():
                d[f"escrow/{i}/count"] = np.asarray(orc.count, np.int64)
                for j, lst in enumerate(orc.lists):
                    d[f"escrow/{i}/l{j}"] = np.asarray(lst, np.int64)
        elif self.keep_history:
            for j, (t, tt) in enumerate(self._hist):
                d[f"hist/{j}/t"] = t.copy()
                d[f"hist/{j}/tt"] = tt.copy()
        return d

    def load_state_dict(self, d: dict) -> None:
        """Inverse of ``state_dict`` (configuration must match)."""
        d = {k: np.asarray(v) for k, v in d.items()}
        self.windows_seen = int(d["windows_seen"])
        self.finalized = bool(int(d["finalized"]))
        self._consumed = int(d["consumed"])
        self._num_types = _opt_unpack(d["num_types"])
        self._t_last = _opt_unpack(d["t_last"])
        self._held_t = d["held_t"].astype(np.int32)
        self._held_tt = d["held_tt"].astype(np.int32)
        snaps = [d[f"snap/{j}"].astype(np.int64) for j in range(3)
                 if f"snap/{j}" in d]
        if self.bounded:
            self.snapshots = collections.deque(snaps,
                                               maxlen=self.snapshots.maxlen)
        else:
            self.snapshots = snaps
        if self.engine == "level1":
            self._cum = d["cum"].astype(np.int64)
            return
        if self.engine == "ptpe":
            self._set_host_state(A1State(
                s=jnp.asarray(d["s"].astype(np.int32)),
                ptr=jnp.asarray(d["ptr"].astype(np.int32)),
                count=jnp.asarray(d["count"].astype(np.int32)),
                ovf=jnp.asarray(d["ovf"].astype(bool))))
        else:
            self._ovf = d["mapc_ovf"].astype(bool)
            self._tau_c = _opt_unpack(d["tau_c"])
            self._buf_t = d["buf_t"].astype(np.int32)
            self._buf_tt = d["buf_tt"].astype(np.int32)
            if "carry/a" in d:
                self._carry = tuple(
                    jnp.asarray(d[f"carry/{name}"].astype(
                        bool if name == "f" else np.int32))
                    for name in ("a", "c", "b", "f"))
            else:
                self._carry = None
        if self.bounded:
            self._bstate = {
                "s": d["base/s"].astype(np.int32),
                "ptr": d["base/ptr"].astype(np.int32),
                "count": d["base/count"].astype(np.int32),
                "ovf": d["base/ovf"].astype(bool)}
            self._base_consumed = int(d["base_consumed"])
            self._wsb = int(d["wsb"])
            self._suffix = []
            j = 0
            while f"suffix/{j}/t" in d:
                self._suffix.append((d[f"suffix/{j}/t"].astype(np.int32),
                                     d[f"suffix/{j}/tt"].astype(np.int32)))
                j += 1
            self._escrow = {}
            for i in sorted({int(k.split("/")[1]) for k in d
                             if k.startswith("escrow/")}):
                lists, j = [], 0
                while f"escrow/{i}/l{j}" in d:
                    lists.append([int(x) for x in d[f"escrow/{i}/l{j}"]])
                    j += 1
                self._escrow[i] = _OracleA1(
                    self.eps.etypes[i], self.eps.tlo[i], self.eps.thi[i],
                    lists, int(d[f"escrow/{i}/count"]))
        elif self.keep_history:
            self._hist = []
            j = 0
            while f"hist/{j}/t" in d:
                self._hist.append((d[f"hist/{j}/t"].astype(np.int32),
                                   d[f"hist/{j}/tt"].astype(np.int32)))
                j += 1

    def update(self, window: EventStream, final: bool = False) -> np.ndarray:
        """Ingest one window; returns exact cumulative counts. ``final``
        additionally flushes the holdback/commit tail (equivalent to calling
        ``finalize`` but folded into this window's snapshot)."""
        if self.finalized:
            raise RuntimeError("counter already finalized")
        self._dispatch(self._prepare(window, final))
        self.finalized = final
        return self._snapshot()

    def finalize(self) -> np.ndarray:
        """Flush held-back events and commit the mapconcatenate tail; the
        returned counts cover every event ever ingested and equal one-shot
        counting on the concatenation."""
        if self.finalized:
            return self.snapshots[-1]
        self._dispatch(self._prepare(None, final=True))
        self.finalized = True
        return self._snapshot()

    def run(self, windows, final: bool = True):
        """Pipelined generator over ``windows``: window p+1's host work and
        device transfer are issued before blocking on window p's counts, so
        the accelerator never waits on ingest. Yields one exact cumulative
        count vector per window; the last one is finalized."""
        it = iter(windows)
        cur = next(it, None)
        if cur is None:
            return
        nxt = next(it, None)
        staged = self._prepare(cur, final and nxt is None)
        while staged is not None:
            self._dispatch(staged)
            last = nxt is None
            cur, nxt = nxt, (next(it, None) if nxt is not None else None)
            staged = (self._prepare(cur, final and nxt is None)
                      if cur is not None else None)
            self.finalized = self.finalized or (final and last)
            yield self._snapshot()


class StreamingA2Counter:
    """Carried relaxed upper-bound (Algorithm 3) machines. A single slot per
    level is complete state (Obs. 5.1), so chunked counting is
    unconditionally bit-exact — no holdback, no flags, no history. With
    ``use_kernel`` (and the dispatch policy allowing) the carried tile
    lives in the Pallas kernel's (NP, MP) layout across windows."""

    def __init__(self, eps: EpisodeBatch, min_bucket: int = 128,
                 executor=None, bounded: bool = False,
                 use_kernel: bool = True):
        self.eps = eps
        self._relaxed = eps.relaxed()
        self.min_bucket = min_bucket
        self.executor = executor
        self.bounded = bounded
        self.use_kernel = use_kernel
        self.snapshots = collections.deque(maxlen=8) if bounded else []
        self.windows_seen = 0
        self._kernel = False
        if eps.N == 1:
            self._state = None
            self._cum = np.zeros(eps.M, np.int64)
        else:
            self._state = init_a2_state(self._relaxed)
            self._et = jnp.asarray(self._relaxed.etypes)
            self._tlo = jnp.asarray(self._relaxed.tlo) - 1  # inclusive lower
            self._thi = jnp.asarray(self._relaxed.thi)
            if use_kernel:
                self._try_enable_kernel()

    def _try_enable_kernel(self) -> None:
        """See ``StreamingCounter._try_enable_kernel`` — single-slot
        analogue (carried (s, cnt) tile in kernel layout)."""
        try:
            from repro.kernels import ops as kops
            self._interp = kops.kernel_mode()
        except (ImportError, NotImplementedError):
            record_fallback("stream_a2_residency")
            return
        self._kops = kops
        self._kernel = True
        self._ket, self._ktlo, self._kthi = kops.episode_layout(
            self._relaxed, inclusive_lower=True)
        self._kst = kops.a2_state_layout(self._state)
        self._state = None

    def _host_state(self) -> A2State:
        if self._kernel:
            return self._kops.a2_state_unpack(*self._kst, self.eps.M,
                                              self.eps.N)
        return self._state

    def _set_host_state(self, st: A2State) -> None:
        if self._kernel:
            self._kst = self._kops.a2_state_layout(st)
        else:
            self._state = st

    def update(self, window: EventStream, final: bool = False) -> np.ndarray:
        real = window.types != PAD_TYPE
        n = int(real.sum())
        if self.eps.N == 1:
            if n:
                self._cum += count_level1(window, self.eps.etypes[:, 0])
            out = self._cum.copy()
        elif n == 0:
            out = (np.asarray(self._kst[1][0, : self.eps.M], np.int64)
                   if self._kernel
                   else np.asarray(self._state.count, np.int64))
        elif self._kernel:
            b = bucket_size(n, self.min_bucket)
            ev = self._kops.event_brick(window.types[real],
                                        window.times[real],
                                        with_dup=False, length=b)
            s, c = self._kst
            args = (self._ket, self._ktlo, self._kthi,
                    jax.device_put(ev), s, c)
            if self.executor is not None:
                c, s = self.executor.a2_kernel_scan(args, self.eps.N,
                                                    self._interp)
            else:
                with _obs_span("stream.launch", kind="a2_state"):
                    c, s = self._kops.a2_state_call(
                        *args, n_levels=self.eps.N, interpret=self._interp)
            self._kst = (s, c)
            out = np.asarray(c[0, : self.eps.M], np.int64)
        else:
            sub = EventStream(window.types[real], window.times[real],
                              window.num_types)
            padded = sub.padded_to(bucket_size(n, self.min_bucket))
            if self.executor is not None:
                st = self._state
                s, c = self.executor.a2_scan(
                    (self._et, self._tlo, self._thi,
                     jnp.asarray(padded.types), jnp.asarray(padded.times),
                     st.s, st.count))
                self._state = A2State(s=s, count=c)
                out = np.asarray(c, np.int64)
            else:
                with _obs_span("stream.launch", kind="a2_scan"):
                    out, self._state = count_single_slot(
                        padded, self._relaxed, inclusive_lower=True,
                        state=self._state, return_state=True)
        self.snapshots.append(out)
        self.windows_seen += 1
        return out

    def fast_forward(self, p: int) -> None:
        """See ``StreamingCounter.fast_forward``."""
        if self.windows_seen:
            raise RuntimeError("fast_forward on a non-virgin counter")
        self.windows_seen = p

    def state_dict(self) -> dict[str, np.ndarray]:
        d = {"windows_seen": np.asarray(self.windows_seen, np.int64)}
        for j, snap in enumerate(list(self.snapshots)[-3:]):
            d[f"snap/{j}"] = np.asarray(snap, np.int64).copy()
        if self.eps.N == 1:
            d["cum"] = self._cum.copy()
        else:
            st = self._host_state()  # canonical layout; see StreamingCounter
            d["s"] = np.asarray(st.s).copy()
            d["count"] = np.asarray(st.count).copy()
        return d

    def load_state_dict(self, d: dict) -> None:
        d = {k: np.asarray(v) for k, v in d.items()}
        self.windows_seen = int(d["windows_seen"])
        snaps = [d[f"snap/{j}"].astype(np.int64) for j in range(3)
                 if f"snap/{j}" in d]
        if self.bounded:
            self.snapshots = collections.deque(snaps,
                                               maxlen=self.snapshots.maxlen)
        else:
            self.snapshots = snaps
        if self.eps.N == 1:
            self._cum = d["cum"].astype(np.int64)
        else:
            self._set_host_state(A2State(
                s=jnp.asarray(d["s"].astype(np.int32)),
                count=jnp.asarray(d["count"].astype(np.int32))))


@dataclasses.dataclass(frozen=True)
class StagedWindow:
    """Host-side prepared form of one partition window: PAD stripped and
    the level-1 type histogram precomputed. Produced by
    ``StreamingMiner.stage`` so the service scheduler can run this pure
    host work for window p+1 while window p's scans occupy the device;
    ``update`` accepts it in place of the raw window. Staging mutates no
    miner state — a staged window can be dropped (retry rewind) and
    re-staged freely."""

    stream: EventStream
    hist: np.ndarray
    n_events: int


class StreamingMiner:
    """Level-wise frequent-episode mining over carried counting machines.

    ``update(window)`` returns a per-window ``MiningResult``; in
    ``mode="per_window"`` its counts are per-window *deltas* of the exact
    cumulative counts — boundary-spanning occurrences included (the seed's
    restart-per-window loop lost exactly those). Attribution can trail the
    ingest frontier slightly: the tie-group holdback defers the last
    timestamp group, and the mapconcatenate engine commits W ticks behind
    ingestion, so an occurrence completing in window p's final W ticks may
    land in window p+1's delta. The deltas always sum to the exact total.
    In ``mode="cumulative"`` counts are totals over the stream so far, and
    the final window's report is bit-identical to one-shot ``mine`` on the
    concatenated stream.

    Candidate sets evolve with the frequent sets, so counters are keyed by
    batch content; a batch (or a two-pass promotion) appearing mid-stream
    replays the retained window history to catch its machines up — exactness
    is never traded for the cull.

    ``history_limit=K`` bounds memory for long-lived sessions: the retained
    window history, every counter's recovery suffix, and the counter table
    itself stay O(K) instead of O(stream length). Counters checkpoint their
    machine state every K windows and recover flagged episodes by replaying
    only the suffix since the checkpoint (see ``_advance_base``); growing a
    tracked set appends a *fragment* counter for just the new episodes, so
    existing counters are never rebuilt and every counter stays exact from
    its own birth. The semantic trade, precisely: a counter born after the
    horizon — a newly promoted subset, or a whole candidate batch whose key
    first appears (or reappears after >K idle windows, which evicts it) —
    counts from the retained suffix, not from genesis. Per-window deltas
    re-synchronize within the replayed suffix (windows are much longer
    than episode spans), so ``mode="per_window"`` serving stays exact in
    practice even under candidate churn; ``mode="cumulative"`` totals are
    exact only for counters whose key lineage stays within the horizon —
    cumulative-exact bounded mining under churn would need cross-key
    machine-state transplant (ROADMAP follow-on).
    """

    def __init__(self, intervals, theta: int, max_level: int = 4,
                 mode: str = "per_window", engine: str = "hybrid",
                 two_pass: bool = True, use_kernel: bool = True,
                 lcap: int = DEFAULT_LCAP, num_segments: int = 8,
                 history_limit: int | None = None, executor=None):
        if mode not in ("per_window", "cumulative"):
            raise ValueError(f"unknown mode {mode!r}")
        if history_limit is not None and history_limit < 1:
            raise ValueError("history_limit must be >= 1")
        self.intervals = intervals
        self.theta = theta
        self.max_level = max_level
        self.mode = mode
        self.engine = engine
        self.two_pass = two_pass
        self.use_kernel = use_kernel
        self.lcap = lcap
        self.num_segments = num_segments
        self.history_limit = history_limit
        self.executor = executor
        self._history: list[EventStream] = []
        self._hist_base = 0  # miner windows dropped from the history head
        self._p = 0
        self._num_types: int | None = None
        self._l1_cum: np.ndarray | None = None
        self._l1_prev: np.ndarray | None = None
        self._a2: dict = {}       # batch key -> StreamingA2Counter
        self._exact: dict = {}    # batch key -> (tracked idx, StreamingCounter)
        self._known: dict = {}    # batch key -> exact cum known last window
        self._known2: dict = {}   # batch key -> exact cum known 2 windows ago
        self._last_seen: dict = {}  # batch key -> last window it was counted

    @staticmethod
    def _key(eps: EpisodeBatch):
        return (eps.N, eps.etypes.tobytes(), eps.tlo.tobytes(),
                eps.thi.tobytes())

    def _make_counter(self, eps: EpisodeBatch) -> StreamingCounter:
        return StreamingCounter(
            eps, engine=self.engine, lcap=self.lcap,
            num_segments=self.num_segments, use_kernel=self.use_kernel,
            executor=self.executor, checkpoint_interval=self.history_limit)

    def _update_fragments(self, frags, window: EventStream, final: bool):
        """Advance every fragment of a tracked set; returns the
        concatenated (cumulative, window p-1, window p-2) count vectors in
        tracked order (zeros where a fragment is too young to have the
        older snapshot)."""
        cums, prevs, prev2s = [], [], []
        for f in frags:
            cums.append(self._sync(f, window, final))
            zeros = np.zeros(f.eps.M, np.int64)
            prevs.append(f.snapshots[-2] if len(f.snapshots) >= 2
                         else zeros)
            prev2s.append(f.snapshots[-3] if len(f.snapshots) >= 3
                          else zeros)
        return (np.concatenate(cums), np.concatenate(prevs),
                np.concatenate(prev2s))

    def _sync(self, counter, window: EventStream, final: bool) -> np.ndarray:
        """Feed any history windows this counter has not seen (a batch that
        first appears — or grows — at window p replays windows 0..p-1; with
        ``history_limit`` set, only the retained suffix), then the current
        window."""
        if counter.windows_seen < self._hist_base:
            counter.fast_forward(self._hist_base)
        while counter.windows_seen < self._p:
            counter.update(self._history[counter.windows_seen
                                         - self._hist_base])
        return counter.update(window, final=final)

    def _count_level(self, cand: EpisodeBatch, window: EventStream,
                     final: bool):
        """Counts + masks for one candidate batch at the current window.
        Returns (counts, frequent, survived, seed).

        ``seed`` gates candidate *generation* for the next level. In
        per-window mode an occurrence completing in window p may lean on
        sub-episode occurrences that completed up to W ticks before p
        started, so sub-episodes are seeded on their support over the last
        TWO windows (sound whenever windows are at least W long) — the
        reported ``frequent`` mask still uses the true per-window delta.
        """
        key = self._key(cand)
        m = cand.M
        zeros = np.zeros(m, np.int64)
        self._last_seen[key] = self._p
        if self.two_pass:
            a2c = self._a2.get(key)
            if a2c is None:
                a2c = self._a2[key] = StreamingA2Counter(
                    cand, executor=self.executor,
                    bounded=self.history_limit is not None,
                    use_kernel=self.use_kernel)
            a2_cum = self._sync(a2c, window, final)
            a2_prev = (a2c.snapshots[-2] if len(a2c.snapshots) >= 2
                       else zeros)
            if self.mode == "per_window":
                # safe cull: a1_delta(p) <= a2_cum(p) - a1_known(p-1)
                survived = a2_cum - self._known.get(key, zeros) >= self.theta
            else:
                survived = a2_cum >= self.theta  # Thm 5.1 on the concat
            tracked_prev = self._exact[key][0] if key in self._exact \
                else np.empty(0, np.int64)
            new_ids = np.setdiff1d(np.nonzero(survived)[0], tracked_prev)
            tracked = np.concatenate([tracked_prev, new_ids])
        else:
            a2_cum = a2_prev = None
            survived = np.ones(m, bool)
            tracked = np.arange(m, dtype=np.int64)
        if tracked.size:
            # fragment per promotion wave: growing the tracked set never
            # rebuilds (and never resets) existing counters — only the
            # newly promoted episodes get a counter, synced over the
            # retained history. Episodes therefore stay exact from their
            # own fragment's birth regardless of later promotions (and the
            # promotion replay cost drops from O(tracked) to O(new)).
            frags = list(self._exact[key][1]) if key in self._exact else []
            covered = sum(f.eps.M for f in frags)
            if covered < tracked.size:
                frags.append(self._make_counter(
                    cand.select(tracked[covered:])))
            self._exact[key] = (tracked, frags)
            cum_t, prev_t, prev2_t = self._update_fragments(
                frags, window, final)
        if self.mode == "per_window":
            counts = (a2_cum - a2_prev) if self.two_pass else zeros.copy()
            if tracked.size:
                counts[tracked] = cum_t - prev_t
            # two-window support: exact for tracked, safe UB for culled
            if self.two_pass:
                seed_ub = a2_cum - self._known2.get(key, zeros)
            else:
                seed_ub = zeros.copy()
            if tracked.size:
                seed_ub[tracked] = cum_t - prev2_t
            seed = seed_ub >= self.theta
        else:
            counts = a2_cum.copy() if self.two_pass else zeros.copy()
            if tracked.size:
                counts[tracked] = cum_t
            seed = None  # cumulative: seed == frequent
        known = zeros.copy()
        if tracked.size:
            known[tracked] = cum_t
        self._known2[key] = self._known.get(key, zeros)
        self._known[key] = known
        frequent = survived & (counts >= self.theta)
        if seed is None:
            seed = frequent
        return counts, frequent, survived, seed

    def stage(self, window: EventStream) -> StagedWindow:
        """Run ``update``'s pure host-side prefix — PAD strip plus the
        level-1 histogram — without touching miner state, so the scheduler
        can prepare window p+1 while window p is on device."""
        real = window.types != PAD_TYPE
        w = EventStream(window.types[real], window.times[real],
                        window.num_types)
        return StagedWindow(w, type_histogram(w), int(real.sum()))

    def update(self, window: EventStream | StagedWindow,
               final: bool = False) -> MiningResult:
        """Mine one partition window (raw or pre-``stage``d); returns a
        per-window ``MiningResult`` (same shape the one-shot miner
        produces)."""
        staged = (window if isinstance(window, StagedWindow)
                  else self.stage(window))
        w, wh = staged.stream, staged.hist
        if self._num_types is None:
            self._num_types = w.num_types
            self._l1_cum = np.zeros(w.num_types, np.int64)
        frequent, counts, stats = [], [], []

        t0 = time.perf_counter()
        self._l1_cum += wh
        c1 = _cand.level1(self._num_types)
        if self.mode == "per_window":
            l1 = wh[c1.etypes[:, 0]]
            prev = (self._l1_prev if self._l1_prev is not None
                    else np.zeros_like(wh))
            seed1 = (wh + prev)[c1.etypes[:, 0]] >= self.theta
            self._l1_prev = wh
        else:
            l1 = self._l1_cum[c1.etypes[:, 0]]
            seed1 = l1 >= self.theta
        keep1 = l1 >= self.theta
        frequent.append(c1.select(keep1))
        counts.append(l1[keep1])
        stats.append(LevelStats(1, c1.M, c1.M, int(keep1.sum()),
                                time.perf_counter() - t0))

        # the seed chain drives candidate generation; the reported frequent
        # sets use the mode's own θ criterion (identical in cumulative mode)
        seed_batch = c1.select(seed1)
        level = 2
        while level <= self.max_level and seed_batch is not None \
                and seed_batch.M > 0:
            t0 = time.perf_counter()
            if level == 2:
                cand = _cand.level2(seed_batch.etypes[:, 0], self.intervals)
            else:
                cand = _cand.join_next_level(seed_batch)
            if cand is None or cand.M == 0:
                break
            cvec, freq, surv, seed = self._count_level(cand, w, final)
            frequent.append(cand.select(freq))
            counts.append(cvec[freq])
            stats.append(LevelStats(level, cand.M, int(surv.sum()),
                                    int(freq.sum()),
                                    time.perf_counter() - t0))
            seed_batch = cand.select(seed)
            level += 1
        self._history.append(w)
        self._p += 1
        if self.history_limit is not None:
            while len(self._history) > self.history_limit:
                self._history.pop(0)
                self._hist_base += 1
            stale = [k for k, seen in self._last_seen.items()
                     if self._p - seen > self.history_limit]
            for k in stale:
                for dd in (self._a2, self._exact, self._known, self._known2,
                           self._last_seen):
                    dd.pop(k, None)
        return MiningResult(frequent=frequent, counts=counts, stats=stats)

    @property
    def retained_windows(self) -> int:
        """Raw windows alive anywhere in the miner (shared history plus
        per-counter recovery suffixes) — the quantity ``history_limit``
        caps at O(checkpoint interval) instead of O(stream length)."""
        n = len(self._history)
        for _, frags in self._exact.values():
            for ctr in frags:
                n = max(n, ctr.retained_windows)
        return n

    @staticmethod
    def _key_hash(key) -> str:
        return hashlib.sha1(repr(key).encode()).hexdigest()[:12]

    def state_dict(self) -> dict[str, np.ndarray]:
        """Full dynamic mining state as a flat ``{str: np.ndarray}`` pytree
        (counters included), checkpointable through ``checkpoint.ckpt``;
        ``load_state_dict`` on a miner constructed with the same
        configuration resumes bit-identically — mid-stream save/restore and
        the service's retry-from-snapshot both ride on this."""
        d = {"p": np.asarray(self._p, np.int64),
             "hist_base": np.asarray(self._hist_base, np.int64),
             "num_types": _opt_pack(self._num_types)}
        if self._l1_cum is not None:
            d["l1_cum"] = self._l1_cum.copy()
        if self._l1_prev is not None:
            d["l1_prev"] = self._l1_prev.copy()
        for j, w in enumerate(self._history):
            d[f"history/{j}/types"] = w.types.copy()
            d[f"history/{j}/times"] = w.times.copy()
        keys = (set(self._a2) | set(self._exact) | set(self._known)
                | set(self._known2) | set(self._last_seen))
        for key in keys:
            h = self._key_hash(key)
            n = key[0]
            et = np.frombuffer(key[1], np.int32).reshape(-1, n).copy()
            m = et.shape[0]
            d[f"cand/{h}/etypes"] = et
            d[f"cand/{h}/tlo"] = np.frombuffer(
                key[2], np.int32).reshape(m, max(n - 1, 0)).copy()
            d[f"cand/{h}/thi"] = np.frombuffer(
                key[3], np.int32).reshape(m, max(n - 1, 0)).copy()
            if key in self._a2:
                for sk, v in self._a2[key].state_dict().items():
                    d[f"a2/{h}/{sk}"] = v
            if key in self._exact:
                tracked, frags = self._exact[key]
                d[f"tracked/{h}"] = np.asarray(tracked, np.int64).copy()
                d[f"fragsizes/{h}"] = np.asarray(
                    [f.eps.M for f in frags], np.int64)
                for fi, f in enumerate(frags):
                    for sk, v in f.state_dict().items():
                        d[f"exact/{h}/{fi}/{sk}"] = v
            if key in self._known:
                d[f"known/{h}"] = self._known[key].copy()
            if key in self._known2:
                d[f"known2/{h}"] = self._known2[key].copy()
            if key in self._last_seen:
                d[f"seen/{h}"] = np.asarray(self._last_seen[key], np.int64)
        return d

    def load_state_dict(self, d: dict) -> None:
        """Inverse of ``state_dict`` (configuration must match)."""
        d = {k: np.asarray(v) for k, v in d.items()}
        self._p = int(d["p"])
        self._hist_base = int(d["hist_base"])
        self._num_types = _opt_unpack(d["num_types"])
        self._l1_cum = (d["l1_cum"].astype(np.int64)
                        if "l1_cum" in d else None)
        self._l1_prev = (d["l1_prev"].astype(np.int64)
                         if "l1_prev" in d else None)
        self._history = []
        j = 0
        while f"history/{j}/types" in d:
            self._history.append(EventStream(
                d[f"history/{j}/types"].astype(np.int32),
                d[f"history/{j}/times"].astype(np.int32), self._num_types))
            j += 1
        self._a2, self._exact = {}, {}
        self._known, self._known2, self._last_seen = {}, {}, {}
        for h in sorted({k.split("/")[1] for k in d
                         if k.startswith("cand/")}):
            et = d[f"cand/{h}/etypes"].astype(np.int32)
            m, n = et.shape
            cand = EpisodeBatch(
                et, d[f"cand/{h}/tlo"].astype(np.int32).reshape(m, n - 1),
                d[f"cand/{h}/thi"].astype(np.int32).reshape(m, n - 1))
            key = self._key(cand)
            a2_sub = _state_sub(d, f"a2/{h}/")
            if a2_sub:
                a2c = StreamingA2Counter(
                    cand, executor=self.executor,
                    bounded=self.history_limit is not None,
                    use_kernel=self.use_kernel)
                a2c.load_state_dict(a2_sub)
                self._a2[key] = a2c
            if f"tracked/{h}" in d:
                tracked = d[f"tracked/{h}"].astype(np.int64)
                frags, ofs = [], 0
                for fi, sz in enumerate(
                        d[f"fragsizes/{h}"].astype(np.int64).tolist()):
                    ctr = self._make_counter(
                        cand.select(tracked[ofs:ofs + sz]))
                    ctr.load_state_dict(_state_sub(d, f"exact/{h}/{fi}/"))
                    frags.append(ctr)
                    ofs += sz
                self._exact[key] = (tracked, frags)
            if f"known/{h}" in d:
                self._known[key] = d[f"known/{h}"].astype(np.int64)
            if f"known2/{h}" in d:
                self._known2[key] = d[f"known2/{h}"].astype(np.int64)
            if f"seen/{h}" in d:
                self._last_seen[key] = int(d[f"seen/{h}"])
