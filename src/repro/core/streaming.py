"""Exact cross-window streaming engine (chip-on-chip loop, PR 1 tentpole).

The paper's real-time claim rests on "processing partitions of the data
stream in turn"; the companion accelerator-transformation paper
(arXiv:0905.2203) makes *sustained* throughput across those partitions the
benchmark that matters. The seed's ``mine_partitions`` rebuilt every counting
machine at each window boundary, silently losing occurrences that span
partitions. This module replaces that with carried machines whose
window-by-window counts are **bit-identical to one-shot counting on the
concatenated stream**:

``StreamingCounter``
    Exact cumulative non-overlapped A1 counts for a fixed ``EpisodeBatch``
    over incrementally arriving windows. Three engines:

    * ``"ptpe"``        — the bounded-list scan with its (s, ptr, count, ovf)
      carry threaded across windows (episode-parallel, one machine set).
    * ``"mapconcatenate"`` — segment-parallel streaming: each window is cut
      into phase-shifted segment scans and their (a, count, b) tuples are
      stitched onto a carried tuple with an incremental left fold — the
      associative form of the paper's Concatenate tree (Fig. 6). Because a
      segment's tuple needs ``W`` ticks of lookahead (its crossing zone), the
      commit frontier trails the ingest frontier by ``W``; ``finalize()``
      flushes the tail.
    * ``"hybrid"``      — Eq. 2 dispatcher applied once at construction.

    Exactness containment is inherited from the one-shot engines: bounded
    lists flag possibly-live evictions (``ovf``) and unstitchable tuples flag
    ``unmatched``; flagged episodes are recounted by the exact engine over
    the retained concatenated history, so ``counts()`` is always exact.

    Two boundary subtleties make the bit-exact claim real:

    * *tie-group holdback* — the per-chunk successor-duplicate flags that
      feed A1's eviction accounting can't see across a boundary that splits
      a group of equal timestamps, so ingestion holds back the trailing tie
      group and prepends it to the next window (``finalize()`` flushes it);
    * *shape-bucketed staging* — each window is padded to a power-of-two
      event-buffer bucket before hitting the jit'd scans, so windows after
      the first reuse warm compile caches and (off-CPU) donated state
      buffers; ``run()`` additionally stages window p+1's device transfer
      while window p counts.

``StreamingA2Counter``
    The relaxed upper-bound machines (Obs. 5.1: single slot per level is
    complete state) carried the same way — unconditionally exact under any
    partitioning, used by the streaming two-pass cull.

``StreamingMiner``
    Level-wise mining over the carried counters with per-window θ
    (``mode="per_window"``: θ applies to counts *completed in* each window,
    boundary-spanning occurrences included) or cumulative θ
    (``mode="cumulative"``: θ applies to counts over the whole stream so
    far; the final window's report equals one-shot ``mine`` on the
    concatenation). Two-pass culling stays sound across windows: cumulative
    A2 dominates cumulative A1 (Thm. 5.1 on the concatenation), and the
    per-window cull uses the safe bound
    ``a1_delta(p) <= a2_cum(p) - a1_known(p-1)``. Episodes are promoted to
    exact counting lazily; a promoted episode's machines catch up by
    replaying the retained window history.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import candidates as _cand
from .count_a1 import (A1State, DEFAULT_LCAP, _a1_carry_scan, count_a1,
                       init_a1_state)
from .count_a2 import count_single_slot, init_a2_state
from .episodes import EpisodeBatch
from .events import PAD_TYPE, EventStream, count_level1, type_histogram
from .hybrid import crossover
from .mapconcat import _map_all_segments, fold_pair
from .miner import LevelStats, MiningResult

_EMPTY_I32 = np.empty(0, np.int32)


def bucket_size(n: int, minimum: int = 128) -> int:
    """Next power-of-two event-buffer length >= max(n, minimum) — bounds the
    number of distinct scan shapes (and therefore jit compiles) to
    O(log max_window)."""
    b = max(minimum, 1)
    while b < n:
        b *= 2
    return b


def _split_tie_tail(types: np.ndarray, times: np.ndarray):
    """Split off the trailing group of events sharing the final timestamp.

    Everything before the cut can be fed to the carried scans now: each fed
    event's successor-duplicate flag is decidable without future events
    (the tie tail's own flags may depend on the *next* window's first
    timestamp)."""
    if times.size == 0:
        return (types, times), (types[:0], times[:0])
    cut = int(np.searchsorted(times, times[-1], side="left"))
    return (types[:cut], times[:cut]), (types[cut:], times[cut:])


@dataclasses.dataclass
class _Staged:
    """A window prepared for dispatch: holdback applied, history recorded,
    (ptpe) padded + transferred to device ahead of the blocking read."""

    feed_types: object   # np.ndarray (mapc) or jax.Array (ptpe, padded)
    feed_times: object
    n: int               # real fed events
    final: bool


class StreamingCounter:
    """Exact cumulative A1 counts of ``eps`` over an arriving partition.

    Feed successive non-overlapping, time-ordered windows with ``update``
    (or the prefetching ``run``); call ``finalize`` after the last window to
    flush the holdback/commit tail. ``counts()``/``update()`` return exact
    int64[M] cumulative counts — flagged episodes are restored against the
    retained history, exactly like the one-shot engines restore against the
    full stream.
    """

    def __init__(self, eps: EpisodeBatch, engine: str = "hybrid",
                 lcap: int = DEFAULT_LCAP, num_segments: int = 8,
                 use_kernel: bool = False, keep_history: bool = True,
                 min_bucket: int = 128):
        if engine not in ("ptpe", "mapconcatenate", "hybrid"):
            raise ValueError(f"unknown engine {engine!r}")
        self.eps = eps
        self.lcap = lcap
        self.num_segments = num_segments
        self.use_kernel = use_kernel
        self.keep_history = keep_history
        self.min_bucket = min_bucket
        self.snapshots: list[np.ndarray] = []  # exact cum counts per window
        self.windows_seen = 0
        self.finalized = False
        self._num_types: int | None = None
        self._held_t = _EMPTY_I32
        self._held_tt = _EMPTY_I32
        self._hist: list[tuple[np.ndarray, np.ndarray]] = []
        self._consumed = 0  # events dispatched into the machines so far
        self._t_last: int | None = None
        if eps.N == 1:
            self.engine = "level1"
            self._cum = np.zeros(eps.M, np.int64)
            return
        if engine == "hybrid":
            engine = "ptpe" if eps.M > crossover(eps.N) else "mapconcatenate"
        self.engine = engine
        self._et = jnp.asarray(eps.etypes)
        self._tlo = jnp.asarray(eps.tlo)
        self._thi = jnp.asarray(eps.thi)
        if engine == "ptpe":
            self._state = init_a1_state(eps, lcap)
        else:
            self._w = np.asarray(eps.max_span, np.int64)
            self._w_dev = jnp.asarray(self._w, jnp.int32)
            self._wmax = int(self._w.max())
            self._carry = None        # (a, c, b, flag) each jnp [K, M]
            self._ovf = np.zeros(eps.M, bool)
            self._tau_c: int | None = None
            self._buf_t = _EMPTY_I32  # committed-lookback + pending events
            self._buf_tt = _EMPTY_I32

    # ------------------------------------------------------------ ingest

    def _prepare(self, window: EventStream | None, final: bool) -> _Staged:
        """Host side of one window: strip padding, validate the partition
        contract, apply tie-group holdback, record history, and (ptpe) stage
        the padded chunk onto the device. Mutates holdback/history, so
        prepare calls must stay in window order — but none of this depends
        on the *device* state, which is what lets ``run`` overlap window
        p+1's transfer with window p's scan."""
        if window is None:
            t = tt = _EMPTY_I32
        else:
            real = window.types != PAD_TYPE
            t = window.types[real]
            tt = window.times[real]
            if self._num_types is None:
                self._num_types = window.num_types
        if t.size:
            if self._t_last is not None and int(tt[0]) < self._t_last:
                raise ValueError(
                    "streaming windows must be a time-ordered partition "
                    f"(window starts at {int(tt[0])} < frontier "
                    f"{self._t_last}); dedup overlapping windows first")
            self._t_last = int(tt[-1])
            if self.keep_history:
                self._hist.append((t, tt))
        chunk_t = np.concatenate([self._held_t, t])
        chunk_tt = np.concatenate([self._held_tt, tt])
        if final:
            feed, held = (chunk_t, chunk_tt), (_EMPTY_I32, _EMPTY_I32)
        else:
            feed, held = _split_tie_tail(chunk_t, chunk_tt)
        self._held_t, self._held_tt = held
        n = feed[0].size
        if self.engine == "ptpe" and n:
            b = bucket_size(n, self.min_bucket)
            ft = np.full(b, PAD_TYPE, np.int32)
            ftt = np.full(b, feed[1][-1], np.int32)
            ft[:n] = feed[0]
            ftt[:n] = feed[1]
            return _Staged(jax.device_put(ft), jax.device_put(ftt), n, final)
        return _Staged(feed[0], feed[1], n, final)

    # ---------------------------------------------------------- dispatch

    def _dispatch(self, staged: _Staged) -> None:
        self._consumed += staged.n
        if self.engine == "level1":
            if staged.n:
                sub = EventStream(staged.feed_types, staged.feed_times,
                                  self._num_types)
                self._cum += count_level1(sub, self.eps.etypes[:, 0])
            return
        if self.engine == "ptpe":
            if staged.n:
                st = self._state
                s, ptr, c, ovf = _a1_carry_scan()(
                    self._et, self._tlo, self._thi,
                    staged.feed_types, staged.feed_times,
                    st.s, st.ptr, st.count, st.ovf)
                self._state = A1State(s=s, ptr=ptr, count=c, ovf=ovf)
            return
        self._dispatch_mapc(staged)

    def _dispatch_mapc(self, staged: _Staged) -> None:
        if staged.n:
            self._buf_t = np.concatenate([self._buf_t, staged.feed_types])
            self._buf_tt = np.concatenate([self._buf_tt, staged.feed_times])
        if self._buf_tt.size == 0:
            return
        if self._tau_c is None:
            self._tau_c = int(self._buf_tt[0]) - 1
        t_f = int(self._buf_tt[-1])
        w = self._wmax
        if staged.final:
            tau_next = t_f
            if tau_next <= self._tau_c:
                return
        else:
            # a segment's tuple needs W ticks of lookahead (crossing zone),
            # and segments shorter than W are not stitch-safe — commit only
            # when the frontier has moved far enough past the last commit
            tau_next = t_f - w
            if tau_next - self._tau_c <= w:
                return
        span = tau_next - self._tau_c
        q = 1
        while q * 2 <= self.num_segments and span // (q * 2) > w:
            q *= 2
        tau = np.round(np.linspace(self._tau_c, tau_next,
                                   q + 1)).astype(np.int64)
        tau[0], tau[-1] = self._tau_c, tau_next
        lo = np.searchsorted(self._buf_tt, tau[:-1] - w, side="right")
        hi = np.searchsorted(self._buf_tt, tau[1:] + w, side="right")
        lw = bucket_size(int((hi - lo).max()), self.min_bucket)
        wt = np.full((q, lw), PAD_TYPE, np.int32)
        wtt = np.zeros((q, lw), np.int32)
        for i in range(q):
            wt[i, : hi[i] - lo[i]] = self._buf_t[lo[i]: hi[i]]
            wtt[i, : hi[i] - lo[i]] = self._buf_tt[lo[i]: hi[i]]
        a, c, b, ovf = _map_all_segments(
            jnp.asarray(wt), jnp.asarray(wtt), self._et, self._tlo,
            self._thi, jnp.asarray(tau), self._w_dev, self.lcap)
        self._ovf |= np.asarray(ovf.any(axis=(0, 1)))
        i0 = 0
        if self._carry is None:
            self._carry = (a[0], c[0], b[0],
                           jnp.zeros(a[0].shape, jnp.bool_))
            i0 = 1
        for i in range(i0, q):
            self._carry = fold_pair(
                self._carry,
                (a[i], c[i], b[i], jnp.zeros(a[i].shape, jnp.bool_)))
        self._tau_c = tau_next
        keep = self._buf_tt > tau_next - w  # retain next segment's lookback
        self._buf_t = self._buf_t[keep]
        self._buf_tt = self._buf_tt[keep]

    # ------------------------------------------------------------ reads

    def counts(self) -> np.ndarray:
        """Exact cumulative counts over everything committed so far (for
        mapconcatenate, the commit frontier trails ingestion by W until
        ``finalize``)."""
        if self.engine == "level1":
            return self._cum.copy()
        if self.engine == "ptpe":
            c = np.asarray(self._state.count, np.int64)
            flagged = np.asarray(self._state.ovf).copy()
        else:
            if self._carry is None:
                return np.zeros(self.eps.M, np.int64)
            c = np.asarray(self._carry[1][0], np.int64)
            flagged = np.asarray(self._carry[3][0]) | self._ovf
        if flagged.any():
            c = self._restore_exact(c, flagged)
        return c

    def _restore_exact(self, c: np.ndarray, flagged: np.ndarray):
        """Recount flagged episodes with the exact one-shot engine over the
        retained history (trimmed to what the machines have consumed)."""
        if not self.keep_history:
            raise RuntimeError(
                "episodes were flagged for exact recount but keep_history "
                "is off; re-run with keep_history=True")
        types = np.concatenate([t for t, _ in self._hist] or [_EMPTY_I32])
        times = np.concatenate([tt for _, tt in self._hist] or [_EMPTY_I32])
        if self.engine == "ptpe":
            # dispatched events are always a prefix of the ingested history;
            # count them explicitly — run() may already have *prepared* (and
            # history-recorded) the next window while this one's counts are
            # being read
            n = self._consumed
        else:
            n = int(np.searchsorted(times, self._tau_c, side="right"))
        stream = EventStream(types[:n], times[:n], self._num_types)
        idx = np.nonzero(flagged)[0]
        c = c.copy()
        c[idx] = count_a1(stream, self.eps.select(idx), lcap=self.lcap,
                          use_kernel=self.use_kernel)
        return c

    def _snapshot(self) -> np.ndarray:
        out = self.counts()
        self.snapshots.append(out)
        self.windows_seen += 1
        return out

    # ----------------------------------------------------------- public

    def update(self, window: EventStream, final: bool = False) -> np.ndarray:
        """Ingest one window; returns exact cumulative counts. ``final``
        additionally flushes the holdback/commit tail (equivalent to calling
        ``finalize`` but folded into this window's snapshot)."""
        if self.finalized:
            raise RuntimeError("counter already finalized")
        self._dispatch(self._prepare(window, final))
        self.finalized = final
        return self._snapshot()

    def finalize(self) -> np.ndarray:
        """Flush held-back events and commit the mapconcatenate tail; the
        returned counts cover every event ever ingested and equal one-shot
        counting on the concatenation."""
        if self.finalized:
            return self.snapshots[-1]
        self._dispatch(self._prepare(None, final=True))
        self.finalized = True
        return self._snapshot()

    def run(self, windows, final: bool = True):
        """Pipelined generator over ``windows``: window p+1's host work and
        device transfer are issued before blocking on window p's counts, so
        the accelerator never waits on ingest. Yields one exact cumulative
        count vector per window; the last one is finalized."""
        it = iter(windows)
        cur = next(it, None)
        if cur is None:
            return
        nxt = next(it, None)
        staged = self._prepare(cur, final and nxt is None)
        while staged is not None:
            self._dispatch(staged)
            last = nxt is None
            cur, nxt = nxt, (next(it, None) if nxt is not None else None)
            staged = (self._prepare(cur, final and nxt is None)
                      if cur is not None else None)
            self.finalized = self.finalized or (final and last)
            yield self._snapshot()


class StreamingA2Counter:
    """Carried relaxed upper-bound (Algorithm 3) machines. A single slot per
    level is complete state (Obs. 5.1), so chunked counting is
    unconditionally bit-exact — no holdback, no flags, no history."""

    def __init__(self, eps: EpisodeBatch, min_bucket: int = 128):
        self.eps = eps
        self._relaxed = eps.relaxed()
        self.min_bucket = min_bucket
        self.snapshots: list[np.ndarray] = []
        self.windows_seen = 0
        if eps.N == 1:
            self._state = None
            self._cum = np.zeros(eps.M, np.int64)
        else:
            self._state = init_a2_state(self._relaxed)

    def update(self, window: EventStream, final: bool = False) -> np.ndarray:
        real = window.types != PAD_TYPE
        n = int(real.sum())
        if self.eps.N == 1:
            if n:
                self._cum += count_level1(window, self.eps.etypes[:, 0])
            out = self._cum.copy()
        elif n == 0:
            out = np.asarray(self._state.count, np.int64)
        else:
            sub = EventStream(window.types[real], window.times[real],
                              window.num_types)
            padded = sub.padded_to(bucket_size(n, self.min_bucket))
            out, self._state = count_single_slot(
                padded, self._relaxed, inclusive_lower=True,
                state=self._state, return_state=True)
        self.snapshots.append(out)
        self.windows_seen += 1
        return out


class StreamingMiner:
    """Level-wise frequent-episode mining over carried counting machines.

    ``update(window)`` returns a per-window ``MiningResult``; in
    ``mode="per_window"`` its counts are per-window *deltas* of the exact
    cumulative counts — boundary-spanning occurrences included (the seed's
    restart-per-window loop lost exactly those). Attribution can trail the
    ingest frontier slightly: the tie-group holdback defers the last
    timestamp group, and the mapconcatenate engine commits W ticks behind
    ingestion, so an occurrence completing in window p's final W ticks may
    land in window p+1's delta. The deltas always sum to the exact total.
    In ``mode="cumulative"`` counts are totals over the stream so far, and
    the final window's report is bit-identical to one-shot ``mine`` on the
    concatenated stream.

    Candidate sets evolve with the frequent sets, so counters are keyed by
    batch content; a batch (or a two-pass promotion) appearing mid-stream
    replays the retained window history to catch its machines up — exactness
    is never traded for the cull. Memory grows with history; windowed
    eviction is a ROADMAP follow-on.
    """

    def __init__(self, intervals, theta: int, max_level: int = 4,
                 mode: str = "per_window", engine: str = "hybrid",
                 two_pass: bool = True, use_kernel: bool = True,
                 lcap: int = DEFAULT_LCAP, num_segments: int = 8):
        if mode not in ("per_window", "cumulative"):
            raise ValueError(f"unknown mode {mode!r}")
        self.intervals = intervals
        self.theta = theta
        self.max_level = max_level
        self.mode = mode
        self.engine = engine
        self.two_pass = two_pass
        self.use_kernel = use_kernel
        self.lcap = lcap
        self.num_segments = num_segments
        self._history: list[EventStream] = []
        self._p = 0
        self._num_types: int | None = None
        self._l1_cum: np.ndarray | None = None
        self._l1_prev: np.ndarray | None = None
        self._a2: dict = {}       # batch key -> StreamingA2Counter
        self._exact: dict = {}    # batch key -> (tracked idx, StreamingCounter)
        self._known: dict = {}    # batch key -> exact cum known last window
        self._known2: dict = {}   # batch key -> exact cum known 2 windows ago

    @staticmethod
    def _key(eps: EpisodeBatch):
        return (eps.N, eps.etypes.tobytes(), eps.tlo.tobytes(),
                eps.thi.tobytes())

    def _sync(self, counter, window: EventStream, final: bool) -> np.ndarray:
        """Feed any history windows this counter has not seen (a batch that
        first appears — or grows — at window p replays windows 0..p-1), then
        the current window."""
        while counter.windows_seen < self._p:
            counter.update(self._history[counter.windows_seen])
        return counter.update(window, final=final)

    def _count_level(self, cand: EpisodeBatch, window: EventStream,
                     final: bool):
        """Counts + masks for one candidate batch at the current window.
        Returns (counts, frequent, survived, seed).

        ``seed`` gates candidate *generation* for the next level. In
        per-window mode an occurrence completing in window p may lean on
        sub-episode occurrences that completed up to W ticks before p
        started, so sub-episodes are seeded on their support over the last
        TWO windows (sound whenever windows are at least W long) — the
        reported ``frequent`` mask still uses the true per-window delta.
        """
        key = self._key(cand)
        m = cand.M
        zeros = np.zeros(m, np.int64)
        if self.two_pass:
            a2c = self._a2.get(key)
            if a2c is None:
                a2c = self._a2[key] = StreamingA2Counter(cand)
            a2_cum = self._sync(a2c, window, final)
            a2_prev = (a2c.snapshots[-2] if len(a2c.snapshots) >= 2
                       else zeros)
            if self.mode == "per_window":
                # safe cull: a1_delta(p) <= a2_cum(p) - a1_known(p-1)
                survived = a2_cum - self._known.get(key, zeros) >= self.theta
            else:
                survived = a2_cum >= self.theta  # Thm 5.1 on the concat
            tracked_prev = self._exact[key][0] if key in self._exact \
                else np.empty(0, np.int64)
            tracked = np.union1d(tracked_prev, np.nonzero(survived)[0])
        else:
            a2_cum = a2_prev = None
            survived = np.ones(m, bool)
            tracked = np.arange(m, dtype=np.int64)
        ctr = None
        if tracked.size:
            prev = self._exact.get(key)
            if prev is not None and prev[0].size == tracked.size:
                ctr = prev[1]
            else:
                ctr = StreamingCounter(
                    cand.select(tracked), engine=self.engine, lcap=self.lcap,
                    num_segments=self.num_segments,
                    use_kernel=self.use_kernel)
            self._exact[key] = (tracked, ctr)
            cum_t = self._sync(ctr, window, final)
            prev_t = (ctr.snapshots[-2] if len(ctr.snapshots) >= 2
                      else np.zeros(tracked.size, np.int64))
            prev2_t = (ctr.snapshots[-3] if len(ctr.snapshots) >= 3
                       else np.zeros(tracked.size, np.int64))
        if self.mode == "per_window":
            counts = (a2_cum - a2_prev) if self.two_pass else zeros.copy()
            if tracked.size:
                counts[tracked] = cum_t - prev_t
            # two-window support: exact for tracked, safe UB for culled
            if self.two_pass:
                seed_ub = a2_cum - self._known2.get(key, zeros)
            else:
                seed_ub = zeros.copy()
            if tracked.size:
                seed_ub[tracked] = cum_t - prev2_t
            seed = seed_ub >= self.theta
        else:
            counts = a2_cum.copy() if self.two_pass else zeros.copy()
            if tracked.size:
                counts[tracked] = cum_t
            seed = None  # cumulative: seed == frequent
        known = zeros.copy()
        if tracked.size:
            known[tracked] = cum_t
        self._known2[key] = self._known.get(key, zeros)
        self._known[key] = known
        frequent = survived & (counts >= self.theta)
        if seed is None:
            seed = frequent
        return counts, frequent, survived, seed

    def update(self, window: EventStream, final: bool = False) -> MiningResult:
        """Mine one partition window; returns a per-window ``MiningResult``
        (same shape the one-shot miner produces)."""
        real = window.types != PAD_TYPE
        w = EventStream(window.types[real], window.times[real],
                        window.num_types)
        if self._num_types is None:
            self._num_types = w.num_types
            self._l1_cum = np.zeros(w.num_types, np.int64)
        frequent, counts, stats = [], [], []

        t0 = time.perf_counter()
        wh = type_histogram(w)
        self._l1_cum += wh
        c1 = _cand.level1(self._num_types)
        if self.mode == "per_window":
            l1 = wh[c1.etypes[:, 0]]
            prev = (self._l1_prev if self._l1_prev is not None
                    else np.zeros_like(wh))
            seed1 = (wh + prev)[c1.etypes[:, 0]] >= self.theta
            self._l1_prev = wh
        else:
            l1 = self._l1_cum[c1.etypes[:, 0]]
            seed1 = l1 >= self.theta
        keep1 = l1 >= self.theta
        frequent.append(c1.select(keep1))
        counts.append(l1[keep1])
        stats.append(LevelStats(1, c1.M, c1.M, int(keep1.sum()),
                                time.perf_counter() - t0))

        # the seed chain drives candidate generation; the reported frequent
        # sets use the mode's own θ criterion (identical in cumulative mode)
        seed_batch = c1.select(seed1)
        level = 2
        while level <= self.max_level and seed_batch is not None \
                and seed_batch.M > 0:
            t0 = time.perf_counter()
            if level == 2:
                cand = _cand.level2(seed_batch.etypes[:, 0], self.intervals)
            else:
                cand = _cand.join_next_level(seed_batch)
            if cand is None or cand.M == 0:
                break
            cvec, freq, surv, seed = self._count_level(cand, w, final)
            frequent.append(cand.select(freq))
            counts.append(cvec[freq])
            stats.append(LevelStats(level, cand.M, int(surv.sum()),
                                    int(freq.sum()),
                                    time.perf_counter() - t0))
            seed_batch = cand.select(seed)
            level += 1
        self._history.append(w)
        self._p += 1
        return MiningResult(frequent=frequent, counts=counts, stats=stats)
