"""Event-stream container (paper Def. 2.1).

A spike-train / symbolic event stream is a time-ordered sequence of
``(event_type, time)`` pairs. We store it struct-of-arrays:

  * ``types`` — int32[n], event types drawn from ``0 .. num_types-1``.
    ``PAD_TYPE`` (-1) marks padding (never matches an episode level).
  * ``times`` — int32[n], non-decreasing integer ticks. The engine works in
    integer ticks (default: milliseconds) so that all inter-event-constraint
    arithmetic is exact on TPU (i32 lanes) and oracle equality is bit-exact.

``TIME_NEG_INF`` is the sentinel for "no timestamp seen" in state machines:
far enough below any real tick that `t - TIME_NEG_INF` never satisfies an
upper bound, with headroom against i32 overflow.
"""

from __future__ import annotations

import dataclasses

import numpy as np

PAD_TYPE = np.int32(-1)
TIME_NEG_INF = np.int32(-(2**30))


@dataclasses.dataclass(frozen=True)
class EventStream:
    """Time-ordered event stream over a finite alphabet."""

    types: np.ndarray  # int32[n]
    times: np.ndarray  # int32[n], non-decreasing
    num_types: int

    def __post_init__(self):
        types = np.asarray(self.types, dtype=np.int32)
        times = np.asarray(self.times, dtype=np.int32)
        object.__setattr__(self, "types", types)
        object.__setattr__(self, "times", times)
        if types.shape != times.shape or types.ndim != 1:
            raise ValueError(f"types/times must be 1-D and equal length, "
                             f"got {types.shape} vs {times.shape}")
        real = types != PAD_TYPE
        if real.any():
            rt = times[real]
            if (np.diff(rt) < 0).any():
                raise ValueError("event times must be non-decreasing")
            if types[real].min() < 0 or types[real].max() >= self.num_types:
                raise ValueError("event types out of range")

    def __len__(self) -> int:
        return int((self.types != PAD_TYPE).sum())

    @property
    def span(self) -> tuple[int, int]:
        """(first_time, last_time) over real events."""
        real = self.types != PAD_TYPE
        rt = self.times[real]
        return (int(rt[0]), int(rt[-1])) if rt.size else (0, 0)

    def padded_to(self, n: int) -> "EventStream":
        """Right-pad with PAD_TYPE events to length ``n`` (static shapes)."""
        cur = self.types.shape[0]
        if cur > n:
            raise ValueError(f"stream length {cur} > pad target {n}")
        if cur == n:
            return self
        pad_t = np.full(n - cur, PAD_TYPE, dtype=np.int32)
        # Padding timestamps: keep monotone (repeat last time).
        last = self.times[-1] if cur else np.int32(0)
        pad_ts = np.full(n - cur, last, dtype=np.int32)
        return EventStream(np.concatenate([self.types, pad_t]),
                           np.concatenate([self.times, pad_ts]),
                           self.num_types)

    @staticmethod
    def from_pairs(pairs, num_types: int) -> "EventStream":
        """Build from an iterable of (type, time); sorts by time (stable)."""
        arr = sorted(pairs, key=lambda p: p[1])
        types = np.array([p[0] for p in arr], dtype=np.int32)
        times = np.array([p[1] for p in arr], dtype=np.int32)
        return EventStream(types, times, num_types)


def type_histogram(stream: EventStream) -> np.ndarray:
    """int64[num_types] occurrence count per event type (padding excluded).

    Every occurrence of a 1-node episode is trivially non-overlapped, so
    level-1 counting is a histogram. One O(n) ``np.bincount`` replaces the
    O(num_types·n) per-type equality scans this codebase used to copy-paste.
    """
    real = stream.types != PAD_TYPE
    return np.bincount(stream.types[real],
                       minlength=stream.num_types).astype(np.int64)


def count_level1(stream: EventStream, etypes) -> np.ndarray:
    """int64[M] counts for 1-node episodes with types ``etypes`` (i32[M])."""
    return type_histogram(stream)[np.asarray(etypes, dtype=np.int64)]
