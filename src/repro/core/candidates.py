"""Level-wise candidate generation (paper §5: "candidate generation is
executed sequentially on a CPU" — it is orders of magnitude cheaper than
counting).

Standard serial-episode Apriori with inter-event constraints (after [10]):

  * level 1: every event type (no edges);
  * level 2: every ordered pair of frequent types × every interval in I;
  * level N: join frequent (N-1)-episodes α, β when α[1:] == β[:-1]
    including edge constraints; candidate = α extended by β's last node+edge.

The anti-monotonicity that justifies the join is over *contiguous
sub-episodes*: any N-1 contiguous sub-episode of a frequent N-episode is
frequent (each occurrence of α contains an occurrence of both its prefix and
its suffix with the same inter-event delays).
"""

from __future__ import annotations

import numpy as np

from .episodes import EpisodeBatch


def level1(num_types: int) -> EpisodeBatch:
    et = np.arange(num_types, dtype=np.int32)[:, None]
    z = np.zeros((num_types, 0), np.int32)
    return EpisodeBatch(et, z, z)


def level2(freq1_types: np.ndarray, intervals) -> EpisodeBatch:
    """All ordered pairs of frequent 1-episodes × each (tlo, thi] in I."""
    ts = np.asarray(freq1_types, np.int32)
    ivs = np.asarray(intervals, np.int32).reshape(-1, 2)
    pairs = np.stack(np.meshgrid(ts, ts, indexing="ij"), -1).reshape(-1, 2)
    et = np.repeat(pairs, len(ivs), axis=0)
    iv = np.tile(ivs, (len(pairs), 1))
    return EpisodeBatch(et, iv[:, :1], iv[:, 1:])


def join_next_level(freq: EpisodeBatch) -> EpisodeBatch | None:
    """Suffix-prefix join of frequent N-episodes into (N+1)-candidates."""
    m, n = freq.etypes.shape
    if m == 0:
        return None
    # key = (types[1:], tlo[1:], thi[1:]) suffix / (types[:-1], ...) prefix
    def key(et, tl, th):
        return (tuple(et), tuple(tl), tuple(th))

    by_prefix: dict = {}
    for j in range(m):
        k = key(freq.etypes[j, :-1], freq.tlo[j, : n - 2] if n > 1 else (),
                freq.thi[j, : n - 2] if n > 1 else ())
        by_prefix.setdefault(k, []).append(j)

    et_out, tlo_out, thi_out = [], [], []
    for i in range(m):
        k = key(freq.etypes[i, 1:], freq.tlo[i, 1:] if n > 1 else (),
                freq.thi[i, 1:] if n > 1 else ())
        for j in by_prefix.get(k, ()):
            et_out.append(np.concatenate(
                [freq.etypes[i], freq.etypes[j, -1:]]))
            tlo_out.append(np.concatenate([freq.tlo[i], freq.tlo[j, -1:]]))
            thi_out.append(np.concatenate([freq.thi[i], freq.thi[j, -1:]]))
    if not et_out:
        return None
    return EpisodeBatch(np.stack(et_out), np.stack(tlo_out),
                        np.stack(thi_out))
