"""Calibrated dispatch — a measured cost-model policy for engine choice.

The paper's Algorithm 2 picks PTPE vs MapConcatenate from a hand-fitted
``f(N) = a/N + b`` (Eq. 2).  That constant is a property of ONE hardware
envelope; this port has five engines (ptpe scan/kernel, MapConcatenate
XLA/kernel/sharded) plus free parameters (``num_segments``, ``block_e``),
and fig7 showed the hand heuristic paying up to 2× regret on real
configs.  The companion paper (arxiv 0905.2203) draws the same lesson:
the winning computation-to-core mapping must be *measured*, not assumed.

This module is the measured replacement:

* ``measure_grid`` times every *available* engine over a small
  (N, M, n, q) grid on the actual hardware — warm-measured, the first
  (jit-compiling) sample discarded, same discipline as the batcher's
  fusion-gate EWMAs.  Engines whose kernel dispatch would decline
  (plain-CPU hosts) are skipped rather than silently measured through
  their XLA fallback and mislabeled.
* ``fit_table`` fits one least-squares cost model per engine over
  features seeded by the analytic roofline side (``analytic_seconds`` —
  the launch CLI passes the constants from ``launch/roofline.py``),
  minimizing *relative* error so small configs are not drowned by large
  ones (the dispatcher compares ratios, not absolutes).
* ``CalibrationTable`` round-trips through a versioned JSON schema with
  atomic writes, cached per device kind under the service data dir and
  invalidated whenever the device fingerprint or ``CODE_VERSION``
  changes.
* ``DispatchPolicy`` is the process-global consult point for
  ``hybrid.count_dispatch``, ``StreamingCounter`` and the batcher's
  fusion gate.  With no table it reproduces today's heuristic exactly;
  either way results are bit-identical — only the engine choice (and
  therefore wall clock) differs.  Every decision is exported as
  ``dispatch_policy_total{engine=...,source=calibrated|heuristic}``.

Module-level imports stay stdlib-only so the analysis plane (VMEM grid
check) can read tables without pulling in jax/numpy; measurement and
fitting import their heavy dependencies lazily.
"""

from __future__ import annotations

import dataclasses
import json
import os
import re
import threading

from repro.obs import REGISTRY

SCHEMA_VERSION = 1
# Bump whenever the feature vector, analytic model, or engine set changes:
# a cached table fitted by older code must not steer newer dispatch.
CODE_VERSION = "cal1-feat6-eng4"

ENGINES = ("ptpe", "mapconcatenate", "mapconcat_kernel",
           "mapconcat_sharded")

# Feature vector for the per-engine linear model, scaled to O(1) at grid
# magnitudes so the least-squares system stays well conditioned.
FEATURE_NAMES = ("bias", "events", "episode_cells", "work", "segments",
                 "analytic_ms")
EPS_SECONDS = 1e-7

ENV_TABLE = "REPRO_POLICY_TABLE"
ENV_TABLE_DIR = "REPRO_CALIBRATION_DIR"
ENV_DATA_DIR = "REPRO_DATA_DIR"


def features(n_episode: int, m: int, n_events: int, q: int,
             analytic_s: float) -> list[float]:
    cells = float(m) * n_episode
    return [1.0,
            n_events / 4096.0,
            cells / 1024.0,
            cells * n_events / float(1 << 22),
            q / 8.0,
            analytic_s * 1e3]


def analytic_seconds(engine: str, n_episode: int, m: int, n_events: int,
                     q: int, devices: int, hw: dict) -> float:
    """Crude roofline seed for one dispatch (not a prediction — a
    *feature*; the fit supplies the host-specific scale).

    Every engine touches ~4 bytes per (event × episode-cell) interaction;
    the segment-parallel family adds a per-segment fold tuple (a, count,
    b) and the sharded form pays the all-gather over ICI instead of HBM.
    The in-kernel mapping halves effective traffic (the fold stays in
    VMEM).  Constants come from ``launch/roofline.py`` via the caller.
    """
    work_bytes = 4.0 * m * n_episode * n_events
    fold_bytes = 16.0 * m * n_episode * max(q, 1)
    t_work = work_bytes / hw["hbm_bw"]
    if engine == "ptpe":
        return t_work
    if engine == "mapconcatenate":
        return t_work + fold_bytes / hw["hbm_bw"]
    if engine == "mapconcat_kernel":
        return 0.5 * t_work + fold_bytes / hw["hbm_bw"]
    if engine == "mapconcat_sharded":
        return (0.5 * t_work / max(devices, 1)
                + fold_bytes / hw["ici_bw"])
    raise ValueError(f"unknown engine {engine!r}")


# --------------------------------------------------------------- grid spec


@dataclasses.dataclass(frozen=True)
class GridSpec:
    """The (N, M, n, q) calibration grid.  ``interval`` bounds the
    inter-event constraint of the synthetic candidates, which makes the
    per-episode span W ≈ ``interval[1] * (N-1)`` — the analysis plane's
    VMEM pass sweeps the same points (ROADMAP correctness follow-on (c))
    so admission bounds and the policy grid cannot drift apart."""

    episode_sizes: tuple = (2, 3, 5)          # N
    episode_counts: tuple = (16, 128, 512)    # M
    event_counts: tuple = (1024, 4096)        # n
    segment_counts: tuple = (1, 4, 8)         # q (mapc engines only)
    interval: tuple = (5, 10)
    num_types: int = 26
    repeats: int = 3
    warmup: int = 1
    seed: int = 0

    @classmethod
    def smoke(cls) -> "GridSpec":
        """CI-sized grid: one compile + one timed sample per point,
        streams short enough that interpret-mode kernels stay cheap."""
        return cls(episode_sizes=(2, 3), episode_counts=(16, 128),
                   event_counts=(512, 2048), segment_counts=(1, 4),
                   repeats=1)

    def max_span(self, n_episode: int) -> int:
        return self.interval[1] * max(n_episode - 1, 1)

    def points(self):
        """Admission-relevant grid points as (N, M, n, q, W) tuples —
        the shape the VMEM pass consumes (no timing, no jax)."""
        out = []
        for n_ep in self.episode_sizes:
            for m in self.episode_counts:
                for n_ev in self.event_counts:
                    for q in self.segment_counts:
                        out.append((n_ep, m, n_ev, q,
                                    self.max_span(n_ep)))
        return out


# ------------------------------------------------------------------ table


def _atomic_write(path: str, text: str) -> None:
    d = os.path.dirname(path) or "."
    os.makedirs(d, exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        f.write(text)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


@dataclasses.dataclass
class CalibrationTable:
    """Fitted per-engine cost model + the grid it was measured on."""

    device_kind: str
    hw: dict                       # analytic constants used by the fit
    coeffs: dict                   # engine -> list[float] (FEATURE dim)
    grid: list                     # measured points (dicts)
    segment_counts: list           # q candidates the fit saw
    schema: int = SCHEMA_VERSION
    code_version: str = CODE_VERSION
    meta: dict = dataclasses.field(default_factory=dict)

    def predict(self, engine: str, *, n_episode: int, m: int,
                n_events: int, q: int = 1, devices: int = 1) -> float | None:
        """Predicted wall seconds for one dispatch; ``None`` for engines
        the calibration could not measure on this host."""
        c = self.coeffs.get(engine)
        if c is None:
            return None
        a = analytic_seconds(engine, n_episode, m, n_events, q, devices,
                             self.hw)
        phi = features(n_episode, m, n_events, q, a)
        return max(sum(ci * xi for ci, xi in zip(c, phi)), EPS_SECONDS)

    def to_doc(self) -> dict:
        return {"schema": self.schema, "code_version": self.code_version,
                "device_kind": self.device_kind, "hw": self.hw,
                "features": list(FEATURE_NAMES), "coeffs": self.coeffs,
                "segment_counts": list(self.segment_counts),
                "grid": self.grid, "meta": self.meta}

    @classmethod
    def from_doc(cls, doc: dict) -> "CalibrationTable | None":
        """Decode + validate; ``None`` (never raise) on any mismatch so a
        stale cache degrades to the heuristic instead of crashing."""
        try:
            if doc.get("schema") != SCHEMA_VERSION:
                return None
            if doc.get("code_version") != CODE_VERSION:
                return None
            coeffs = {e: [float(x) for x in v]
                      for e, v in doc["coeffs"].items()}
            if any(len(v) != len(FEATURE_NAMES)
                   for v in coeffs.values()):
                return None
            return cls(device_kind=str(doc["device_kind"]),
                       hw={k: float(v) for k, v in doc["hw"].items()},
                       coeffs=coeffs, grid=list(doc.get("grid", [])),
                       segment_counts=[int(q) for q in
                                       doc.get("segment_counts", [1])],
                       schema=int(doc["schema"]),
                       code_version=str(doc["code_version"]),
                       meta=dict(doc.get("meta", {})))
        except (KeyError, TypeError, ValueError):
            return None

    def save(self, path: str) -> str:
        _atomic_write(path, json.dumps(self.to_doc(), indent=1))
        return path


def load_table(path: str) -> CalibrationTable | None:
    """Load + validate a cached table; ``None`` if missing/stale."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError):
        return None
    return CalibrationTable.from_doc(doc)


def device_fingerprint() -> str:
    """Cache key: platform, device kind, device count, and whether the
    kernels run in interpret mode (interpret timings must never steer a
    compiled host, or vice versa)."""
    import jax

    from repro.kernels.tally import interpret_requested
    dev = jax.devices()[0]
    kind = getattr(dev, "device_kind", dev.platform)
    tag = f"{dev.platform}:{kind}x{jax.device_count()}"
    if interpret_requested():
        tag += "+interpret"
    return tag


def _table_filename(fingerprint: str) -> str:
    return re.sub(r"[^A-Za-z0-9._+-]", "_", fingerprint) + ".json"


def calibration_dir(data_dir: str | None = None) -> str:
    base = (data_dir or os.environ.get(ENV_TABLE_DIR)
            or os.path.join(os.environ.get(ENV_DATA_DIR, "serve-data"),
                            "calibration"))
    return base


def default_table_path(data_dir: str | None = None) -> str:
    """Per-device-kind cache location under the service data dir."""
    return os.path.join(calibration_dir(data_dir),
                        _table_filename(device_fingerprint()))


# ------------------------------------------------------- measurement + fit


def available_engines(use_kernel: bool = True) -> list[str]:
    """Engines whose dispatch actually engages on this host.  The kernel
    probe is the cached one in ``hybrid`` (tallied once per process) so
    calibration never records an XLA fallback's wall clock under a
    kernel engine's name."""
    from . import hybrid
    out = ["ptpe", "mapconcatenate"]
    if use_kernel and hybrid._mapc_kernel_available():
        out.append("mapconcat_kernel")
        if hybrid.shard_devices() > 1:
            out.append("mapconcat_sharded")
    return out


def _synth_stream(n_events: int, num_types: int, seed: int):
    import numpy as np

    from .events import EventStream
    rng = np.random.default_rng(seed)
    dt = rng.integers(1, 4, size=n_events)
    return EventStream(
        types=rng.integers(0, num_types, size=n_events).astype(np.int32),
        times=np.cumsum(dt).astype(np.int32), num_types=num_types)


def _synth_episodes(m: int, n_episode: int, num_types: int,
                    interval: tuple, seed: int):
    import numpy as np

    from .episodes import EpisodeBatch
    rng = np.random.default_rng(seed)
    et = rng.integers(0, num_types,
                      size=(m, n_episode)).astype(np.int32)
    tlo = np.full((m, n_episode - 1), interval[0], np.int32)
    thi = np.full((m, n_episode - 1), interval[1], np.int32)
    return EpisodeBatch(et, tlo, thi)


def measure_grid(spec: GridSpec | None = None, *,
                 engines: list[str] | None = None,
                 progress=None) -> list[dict]:
    """Time every available engine over the grid on this hardware.

    Returns one dict per (engine, N, M, n, q) point.  Warm-measured: the
    first ``spec.warmup`` calls are discarded (jit compile), the median
    of ``spec.repeats`` timed calls is kept.
    """
    import time as _time

    import numpy as np

    from . import hybrid
    spec = spec or GridSpec()
    engines = list(engines) if engines is not None else available_engines()
    devices = hybrid.shard_devices()
    streams = {n: _synth_stream(n, spec.num_types, spec.seed + n)
               for n in spec.event_counts}
    points: list[dict] = []
    for engine in engines:
        qs = spec.segment_counts if engine != "ptpe" else (1,)
        for n_ep in spec.episode_sizes:
            for m in spec.episode_counts:
                eps = _synth_episodes(m, n_ep, spec.num_types,
                                      spec.interval,
                                      spec.seed + n_ep * 1000 + m)
                for n_ev in spec.event_counts:
                    stream = streams[n_ev]
                    for q in qs:
                        def run():
                            return np.asarray(hybrid.count_dispatch(
                                stream, eps, engine=engine,
                                num_segments=q))
                        for _ in range(spec.warmup):
                            run()
                        ts = []
                        for _ in range(spec.repeats):
                            t0 = _time.perf_counter()
                            run()
                            ts.append(_time.perf_counter() - t0)
                        sec = float(np.median(ts))
                        pt = {"engine": engine, "n_episode": n_ep,
                              "m": m, "n_events": n_ev, "q": q,
                              "devices": devices,
                              "seconds": round(sec, 6)}
                        points.append(pt)
                        if progress is not None:
                            progress(pt)
    return points


def fit_table(points: list[dict], hw: dict, *,
              device_kind: str | None = None,
              meta: dict | None = None) -> CalibrationTable:
    """Per-engine least squares over ``features``, weighted by 1/t so the
    fit minimizes *relative* error — the dispatcher compares engines by
    ratio, and an absolute fit would let the slowest grid corner drown
    the small configs the service actually dispatches."""
    import numpy as np
    coeffs: dict[str, list[float]] = {}
    qs = sorted({int(p["q"]) for p in points}) or [1]
    for engine in ENGINES:
        rows = [p for p in points if p["engine"] == engine]
        if len(rows) < len(FEATURE_NAMES):
            continue
        x = np.array([features(p["n_episode"], p["m"], p["n_events"],
                               p["q"],
                               analytic_seconds(engine, p["n_episode"],
                                                p["m"], p["n_events"],
                                                p["q"],
                                                p.get("devices", 1), hw))
                      for p in rows])
        y = np.array([max(p["seconds"], EPS_SECONDS) for p in rows])
        w = 1.0 / y
        c, *_ = np.linalg.lstsq(x * w[:, None], np.ones_like(y),
                                rcond=None)
        coeffs[engine] = [float(v) for v in c]
    kind = device_kind if device_kind is not None else device_fingerprint()
    return CalibrationTable(device_kind=kind, hw=dict(hw), coeffs=coeffs,
                            grid=list(points), segment_counts=qs,
                            meta=dict(meta or {}))


# ----------------------------------------------------------------- policy


@dataclasses.dataclass(frozen=True)
class DispatchChoice:
    engine: str
    num_segments: int
    source: str                    # "calibrated" | "heuristic"
    predicted_s: float | None = None


def _bucket(n: int) -> int:
    return 1 << max(int(n) - 1, 1).bit_length()


class DispatchPolicy:
    """Engine/q selection consulted by hybrid, streaming and the
    batcher.  Stateless apart from a per-shape decision cache (dispatch
    runs per window commit — the consult must cost a dict lookup, not a
    model evaluation)."""

    def __init__(self, table: CalibrationTable | None = None,
                 path: str | None = None):
        self.table = table
        self.path = path
        self._cache: dict = {}

    @property
    def source(self) -> str:
        return "calibrated" if self.table is not None else "heuristic"

    def _record(self, choice: DispatchChoice) -> DispatchChoice:
        REGISTRY.counter("dispatch_policy_total", engine=choice.engine,
                         source=choice.source).inc()
        return choice

    # ------------------------------------------------------ one-shot path

    def choose(self, *, n_events: int, n_episode: int, m: int,
               use_kernel: bool = True, kernel_ok: bool = False,
               shard_devices: int = 1,
               default_segments: int = 8) -> DispatchChoice:
        """Engine + segment count for one ``count_dispatch`` call."""
        key = ("one", n_episode, m, _bucket(n_events), use_kernel,
               kernel_ok, shard_devices, default_segments)
        choice = self._cache.get(key)
        if choice is None:
            if self.table is not None:
                choice = self._calibrated_choice(
                    n_events=n_events, n_episode=n_episode, m=m,
                    use_kernel=use_kernel, kernel_ok=kernel_ok,
                    shard_devices=shard_devices,
                    default_segments=default_segments)
            else:
                choice = self._heuristic_choice(
                    n_events=n_events, n_episode=n_episode, m=m,
                    use_kernel=use_kernel, kernel_ok=kernel_ok,
                    shard_devices=shard_devices,
                    default_segments=default_segments)
            self._cache[key] = choice
        return self._record(choice)

    def _candidates(self, *, use_kernel: bool, kernel_ok: bool,
                    shard_devices: int) -> list[tuple[str, int]]:
        qs = [max(int(q), 1) for q in (self.table.segment_counts or [1])]
        cands = [("ptpe", 1)]
        cands += [("mapconcatenate", q) for q in qs]
        if use_kernel and kernel_ok:
            cands += [("mapconcat_kernel", q) for q in qs]
            if shard_devices > 1:
                cands += [("mapconcat_sharded", q) for q in qs]
        return cands

    def _calibrated_choice(self, *, n_events, n_episode, m, use_kernel,
                           kernel_ok, shard_devices,
                           default_segments) -> DispatchChoice:
        best = None
        n_b = _bucket(n_events)
        for engine, q in self._candidates(use_kernel=use_kernel,
                                          kernel_ok=kernel_ok,
                                          shard_devices=shard_devices):
            t = self.table.predict(engine, n_episode=n_episode, m=m,
                                   n_events=n_b, q=q,
                                   devices=shard_devices)
            if t is not None and (best is None or t < best[2]):
                best = (engine, q, t)
        if best is None:
            return self._heuristic_choice(
                n_events=n_events, n_episode=n_episode, m=m,
                use_kernel=use_kernel, kernel_ok=kernel_ok,
                shard_devices=shard_devices,
                default_segments=default_segments)
        return DispatchChoice(best[0], best[1], "calibrated", best[2])

    def _heuristic_choice(self, *, n_events, n_episode, m, use_kernel,
                          kernel_ok, shard_devices,
                          default_segments) -> DispatchChoice:
        """Exactly today's Eq. 2 dispatcher (see ``hybrid``): PTPE above
        the capacity-scaled crossover, the segmented kernel where the
        stream is long and the batch cannot fill a lane tile."""
        from . import hybrid
        mapc_kernel = (use_kernel and kernel_ok
                       and n_events >= hybrid.MAPC_KERNEL_MIN_EVENTS)
        kern = ("mapconcat_sharded" if shard_devices > 1
                else "mapconcat_kernel")
        if m > hybrid.crossover(n_episode):
            if mapc_kernel and m <= hybrid.MAPC_KERNEL_MAX_EPISODES:
                engine = kern
            else:
                engine = "ptpe"
        elif mapc_kernel:
            engine = kern
        else:
            engine = "mapconcatenate"
        return DispatchChoice(engine, default_segments, "heuristic")

    # ----------------------------------------------------- streaming path

    def choose_stream(self, *, n_episode: int, m: int,
                      use_kernel: bool = True, kernel_ok: bool = False,
                      shard_devices: int = 1,
                      n_hint: int | None = None) -> DispatchChoice:
        """Resolve a streaming session's ``hybrid`` to ptpe vs the
        segment-parallel side (``StreamingCounter`` upgrades the latter
        to the kernel/sharded forms itself).  ``n_hint`` defaults to the
        largest calibrated stream length — streaming is the long-stream
        regime by construction."""
        key = ("stream", n_episode, m, use_kernel, kernel_ok,
               shard_devices, n_hint)
        choice = self._cache.get(key)
        if choice is None:
            choice = self._stream_choice(
                n_episode=n_episode, m=m, use_kernel=use_kernel,
                kernel_ok=kernel_ok, shard_devices=shard_devices,
                n_hint=n_hint)
            self._cache[key] = choice
        return self._record(choice)

    def _stream_choice(self, *, n_episode, m, use_kernel, kernel_ok,
                       shard_devices, n_hint) -> DispatchChoice:
        if self.table is None:
            from . import hybrid
            engine = ("ptpe" if m > hybrid.crossover(n_episode)
                      else "mapconcatenate")
            return DispatchChoice(engine, 0, "heuristic")
        n = n_hint or max((p["n_events"] for p in self.table.grid),
                          default=4096)
        t_ptpe = self.table.predict("ptpe", n_episode=n_episode, m=m,
                                    n_events=n, q=1,
                                    devices=shard_devices)
        best_mapc = None
        for engine, q in self._candidates(use_kernel=use_kernel,
                                          kernel_ok=kernel_ok,
                                          shard_devices=shard_devices):
            if engine == "ptpe":
                continue
            t = self.table.predict(engine, n_episode=n_episode, m=m,
                                   n_events=n, q=q,
                                   devices=shard_devices)
            if t is not None and (best_mapc is None or t < best_mapc):
                best_mapc = t
        if t_ptpe is None and best_mapc is None:
            return self._stream_heuristic(n_episode, m)
        if best_mapc is None or (t_ptpe is not None
                                 and t_ptpe <= best_mapc):
            return DispatchChoice("ptpe", 0, "calibrated", t_ptpe)
        return DispatchChoice("mapconcatenate", 0, "calibrated",
                              best_mapc)

    def _stream_heuristic(self, n_episode: int, m: int) -> DispatchChoice:
        from . import hybrid
        engine = ("ptpe" if m > hybrid.crossover(n_episode)
                  else "mapconcatenate")
        return DispatchChoice(engine, 0, "heuristic")

    def choose_segments(self, candidates: list[int], *, engine: str,
                        n_episode: int, m: int, n_events: int,
                        devices: int = 1) -> tuple[int, str]:
        """Pick a segment count from the caller's *safety-filtered*
        candidate list (stitch bounds stay the caller's job).  Heuristic
        policy keeps the caller's first preference."""
        if not candidates:
            raise ValueError("empty segment candidate list")
        if self.table is None:
            return candidates[0], "heuristic"
        key = ("q", engine, n_episode, m, _bucket(n_events),
               tuple(candidates), devices)
        got = self._cache.get(key)
        if got is None:
            n_b = _bucket(n_events)
            scored = []
            for q in candidates:
                t = self.table.predict(engine, n_episode=n_episode, m=m,
                                       n_events=n_b, q=q,
                                       devices=devices)
                if t is not None:
                    scored.append((t, q))
            got = (min(scored)[1], "calibrated") if scored \
                else (candidates[0], "heuristic")
            self._cache[key] = got
        return got

    # -------------------------------------------------- fusion-gate prior

    def predict_single(self, engine: str, *, n_episode: int, m: int,
                       n_events: int | None = None, q: int = 1,
                       devices: int = 1) -> float | None:
        """Calibrated standalone-dispatch estimate for the batcher's
        fusion gate (``None`` under the heuristic: the gate keeps its
        optimistic fuse-first prior).  ``n_events`` defaults to the
        largest calibrated stream length — seam keys deliberately drop
        the adaptive event-axis length."""
        if self.table is None:
            return None
        if n_events is None:
            n_events = max((p["n_events"] for p in self.table.grid),
                           default=4096)
        return self.table.predict(engine, n_episode=n_episode, m=m,
                                  n_events=_bucket(n_events), q=q,
                                  devices=devices)

    def stats(self) -> dict:
        out = {"source": self.source, "table_path": self.path,
               "device_kind": (self.table.device_kind
                               if self.table else None),
               "code_version": (self.table.code_version
                                if self.table else CODE_VERSION),
               "grid_points": len(self.table.grid) if self.table else 0,
               "engines": (sorted(self.table.coeffs)
                           if self.table else []),
               "decisions": {}}
        for labels, metric in REGISTRY.family_items(
                "dispatch_policy_total"):
            k = (f"{labels.get('engine', '?')}/"
                 f"{labels.get('source', '?')}")
            out["decisions"][k] = (out["decisions"].get(k, 0)
                                   + metric.value)
        return out


# ------------------------------------------------------- process singleton

_POLICY_LOCK = threading.Lock()
_POLICY: DispatchPolicy | None = None


def get_policy() -> DispatchPolicy:
    """The process-global policy.  Resolution order: an explicitly
    installed table (``set_policy``/``install_table``), then the
    ``REPRO_POLICY_TABLE`` / ``REPRO_CALIBRATION_DIR`` environment
    opt-ins, else the heuristic.  There is deliberately no implicit
    cwd-relative auto-load: a table changes dispatch behavior and must
    be asked for."""
    global _POLICY
    pol = _POLICY
    if pol is None:
        with _POLICY_LOCK:
            pol = _POLICY
            if pol is None:
                pol = _POLICY = _bootstrap_policy()
    return pol


def _bootstrap_policy() -> DispatchPolicy:
    path = os.environ.get(ENV_TABLE)
    if path:
        table = load_table(path)
        if table is not None and _matches_device(table):
            return DispatchPolicy(table, path)
        return DispatchPolicy()
    cal_dir = os.environ.get(ENV_TABLE_DIR)
    if cal_dir:
        try:
            path = os.path.join(cal_dir,
                                _table_filename(device_fingerprint()))
        except Exception:
            return DispatchPolicy()
        table = load_table(path)
        if table is not None:
            return DispatchPolicy(table, path)
    return DispatchPolicy()


def _matches_device(table: CalibrationTable) -> bool:
    try:
        return table.device_kind == device_fingerprint()
    except Exception:
        return False


def set_policy(policy: DispatchPolicy | None) -> None:
    """Install (or with ``None`` reset) the process policy."""
    global _POLICY
    with _POLICY_LOCK:
        _POLICY = policy


def clear_policy() -> None:
    set_policy(None)


def install_table(table_or_path, *,
                  require_device_match: bool = True) -> DispatchPolicy:
    """Install a calibration table as the process policy.  A stale or
    wrong-device table degrades to the heuristic (and says so in
    ``stats()``) rather than steering with foreign timings."""
    if isinstance(table_or_path, CalibrationTable):
        table, path = table_or_path, None
    else:
        path = str(table_or_path)
        table = load_table(path)
    if table is not None and require_device_match \
            and not _matches_device(table):
        table = None
    pol = DispatchPolicy(table, path)
    set_policy(pol)
    return pol


def policy_stats() -> dict:
    return get_policy().stats()


def calibrate_and_save(spec: GridSpec | None = None, *,
                       hw: dict, out_path: str | None = None,
                       data_dir: str | None = None, progress=None,
                       install: bool = True) -> tuple[CalibrationTable,
                                                      str]:
    """One-shot calibration: measure, fit, cache atomically per device
    kind, and (by default) install as the process policy."""
    spec = spec or GridSpec()
    points = measure_grid(spec, progress=progress)
    table = fit_table(points, hw,
                      meta={"spec": dataclasses.asdict(spec)})
    path = out_path or default_table_path(data_dir)
    table.save(path)
    if install:
        set_policy(DispatchPolicy(table, path))
    return table, path
