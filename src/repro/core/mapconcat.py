"""MapConcatenate (paper §5.2.2) — segment-parallel counting.

The stream is split into P (power-of-two) time segments; each segment runs
K = N phase-shifted A1 machines per episode — machine k starts
``Σ_{i≤k} thi^i`` *before* the segment boundary, covering the k-events-before
/ (N-k)-after boundary splits of Fig. 4. Each machine emits a tuple
``(a, count, b)`` (Fig. 5):

  a — end time of its first completion in ``(τ_p, τ_p + W]``  (else τ_p)
  count — completions with end time in ``(τ_p, τ_{p+1}]``
  b — end time of its first completion after τ_{p+1}, found by crossing into
      the next segment up to ``τ_{p+1} + W`` inclusive  (else τ_{p+1})
      — inclusive because an occurrence spanning exactly W from a first
      event exactly on the boundary completes at ``τ + W``; excluding that
      tick made both sides blind to the straddler and the stitch silently
      continued with the wrong phase machine (no flag, undercount)

Machines reset on every completion (non-overlap), which makes them memoryless
at completion points — that is what lets a log₂(P) Concatenate tree stitch
adjacent tuples by matching ``b_left == a_right`` (Fig. 6).

The paper argues (but does not prove) that one of the N phases always
reproduces the reference trajectory; we additionally track an ``unmatched``
flag through the tree and recount flagged episodes with the single-scan
engine, so the public API is exact even on adversarial streams.

Distribution: ``mapconcatenate_sharded`` shard_maps the XLA Map step over
the mesh ``data`` (= segment) axis; the (a, count, b) tuples are O(P·N)
scalars, so the Concatenate tree runs replicated after an ``all_gather`` —
the TPU analogue of the paper's single-kernel-launch concatenate.
``mapconcatenate_sharded_kernel`` is the production form: one segmented
*Pallas* launch per device (its contiguous segment group, in-group fold
fused on-chip) with only the pre-stitched per-device tuples all-gathered
for the replicated final fold (``kernels.ops.a1_mapconcat_sharded_count``).

On-chip: ``mapconcatenate_kernel`` routes the whole computation into one
Pallas launch (``kernels/a1_count.a1_mapconcat_kernel``) whose grid is
(episode tile × time segment) with the Concatenate fold fused across the
segment axis — the literal single-kernel-launch form. The shared pieces
that keep the kernel and XLA paths from drifting live here:
``phase_cum`` (machine start offsets), ``stitch_zones`` (the
boundary-inclusive a/b/count zones), and ``fold_pair_unrolled`` (the
gather-free first-match stitch, bit-identical to ``fold_pair``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.tally import record_fallback

from .count_a1 import DEFAULT_LCAP, count_a1 as _count_a1_exact, \
    dup_flags, step_bounded_list
from .episodes import EpisodeBatch
from .events import PAD_TYPE, TIME_NEG_INF, EventStream, count_level1


# ---------------------------------------------------------------- Map step


def phase_cum(thi):
    """Per-phase start offsets: ``cum[m, k] = Σ_{i<k} thi[m, i]`` — machine
    ``k`` of episode ``m`` starts that many ticks before the segment
    boundary (Fig. 4's k-before/(N-k)-after split coverage). Single source
    of truth for the XLA Map step, the sharded Map step, and the Pallas
    segmented kernels' ``cum`` brick (``kernels.ops.mapconcat_layout``)."""
    thi = jnp.asarray(thi)
    return jnp.cumsum(
        jnp.concatenate([jnp.zeros_like(thi[:, :1]), thi], axis=1), axis=1)


def stitch_zones(t, tau_lo, tau_hi, w):
    """Boundary-inclusive tuple zones for one event time ``t`` against the
    segment ``(tau_lo, tau_hi]`` with per-episode max span ``w``.

    Returns (in_seg, a_zone, live_zone, crossing):
      in_seg   — completion counts toward this segment's ``count``
      a_zone   — completion may be recorded as the tuple's first-``a``
      live_zone — the segment's machines may still consume this event
      crossing — completion is a ``b``-crossing into the next segment

    The ``a``/``live`` zones are inclusive at ``tau + w``: an occurrence
    spanning exactly W whose first event sits exactly on the boundary
    completes at ``tau + W``, and both sides of the stitch must see it (the
    PR 1 silent-undercount fix). Shared by ``_segment_scan`` and the Pallas
    segmented kernels (``kernels/a1_count._a1_mapc_body`` /
    ``a2_count._a2_mapc_body``) so the two paths cannot drift.
    """
    in_seg = (t > tau_lo) & (t <= tau_hi)
    a_zone = t <= tau_lo + w
    live_zone = t <= tau_hi + w
    crossing = t > tau_hi
    return in_seg, a_zone, live_zone, crossing


def _segment_scan(ev_types, ev_times, etypes, tlo, thi, starts, tau_lo,
                  tau_hi, w, lcap):
    """Run K phase machines × M episodes over one segment's event window.

    Args:
      ev_types/ev_times: i32[Lw] (padded with PAD_TYPE)
      etypes: i32[M, N]; tlo/thi: i32[M, N-1]
      starts: i32[K, M] phase start times (machine ignores events at t<=start)
      tau_lo, tau_hi: scalar i32 segment boundaries (τ_p, τ_{p+1}]
      w: i32[M] max occurrence span per episode
      lcap: static list capacity

    Returns (a, count, b, ovf): each [K, M] (ovf bool).
    """
    k, m = starts.shape
    n = etypes.shape[1]
    # derive inits from tau so carries are device-varying under shard_map
    # (numerically a no-op: vzero == 0, vfalse == False)
    vzero = (tau_lo * 0).astype(jnp.int32)
    vfalse = tau_lo != tau_lo
    s0 = jnp.full((k, m, n, lcap), TIME_NEG_INF, jnp.int32) + vzero
    ptr0 = jnp.zeros((k, m, n), jnp.int32) + vzero
    cnt0 = jnp.zeros((k, m), jnp.int32) + vzero
    ovf0 = jnp.zeros((k, m), jnp.bool_) | vfalse
    a0 = jnp.full((k, m), tau_lo, jnp.int32)
    b0 = jnp.full((k, m), tau_hi, jnp.int32)
    done0 = jnp.zeros((k, m), jnp.bool_) | vfalse
    a_set0 = jnp.zeros((k, m), jnp.bool_) | vfalse

    step = jax.vmap(  # over phases; episode dim handled inside the step
        step_bounded_list,
        in_axes=(0, 0, 0, 0, None, None, None, None, None, None))
    dups = dup_flags(ev_types, ev_times)

    def body(carry, ev):
        s, ptr, cnt, ovf, a, b, done, a_set = carry
        e, t, d = ev
        # zone predicates shared with the Pallas segmented kernels (see
        # stitch_zones for the tau + W inclusivity that PR 1 fixed)
        seg_z, a_z, live_z, cross_z = stitch_zones(t, tau_lo, tau_hi,
                                                   w[None, :])
        in_window = (t > starts) & live_z & ~done  # [K, M]
        # Run the raw machine step, then mask its effects per (phase, episode)
        s2, ptr2, cdelta, ovf2 = step(s, ptr, jnp.zeros_like(cnt), ovf,
                                      etypes, tlo, thi, e, t, d)
        complete = (cdelta > 0) & in_window
        live = in_window & (e != PAD_TYPE)
        s = jnp.where(live[:, :, None, None], s2, s)
        ptr = jnp.where(live[:, :, None], ptr2, ptr)
        ovf = jnp.where(live, ovf2, ovf)
        # bookkeeping on completions
        in_seg = complete & seg_z
        cnt = cnt + in_seg.astype(cnt.dtype)
        rec_a = in_seg & ~a_set & a_z
        a = jnp.where(rec_a, t, a)
        a_set = a_set | rec_a
        crossing = complete & cross_z
        b = jnp.where(crossing, t, b)
        done = done | crossing
        return (s, ptr, cnt, ovf, a, b, done, a_set), None

    carry0 = (s0, ptr0, cnt0, ovf0, a0, b0, done0, a_set0)
    (s, ptr, cnt, ovf, a, b, done, a_set), _ = jax.lax.scan(
        body, carry0, (ev_types, ev_times, dups))
    return a, cnt, b, ovf


# ------------------------------------------------------- Concatenate step


def fold_pair(left, right):
    """Stitch adjacent tuple blocks (paper Fig. 6, one tree level).

    ``left``/``right`` are (a, c, b, flag) with shape [..., K, M] — the K
    axis is the phase-machine axis, any leading axes broadcast (the balanced
    tree passes [P/2, K, M]; the streaming left-fold passes [K, M]). Matches
    left machine k's crossing end-time ``b`` against the right block's first
    in-zone completions ``a`` and returns the merged block. The operation is
    associative, which is what lets the streaming engine replace the
    balanced tree with an incremental left fold over arriving windows.
    """
    al, cl, bl, fl = left
    ar, cr, br, fr = right
    eq = bl[..., :, None, :] == ar[..., None, :, :]  # [..., K, K', M]
    matched = eq.any(axis=-2)  # [..., K, M]
    idx = jnp.argmax(eq, axis=-2)  # [..., K, M] first matching k'
    cr_g = jnp.take_along_axis(cr, idx, axis=-2)
    br_g = jnp.take_along_axis(br, idx, axis=-2)
    fr_g = jnp.take_along_axis(fr, idx, axis=-2)
    return al, cl + cr_g, br_g, fl | fr_g | ~matched


def fold_pair_unrolled(left, right, k: int):
    """``fold_pair`` restricted to [K, M] blocks with the first-match select
    unrolled over the (static, small) phase axis — no ``argmax`` /
    ``take_along_axis`` gathers, so it lowers inside a Pallas kernel.

    Bit-identical to ``fold_pair``: the reversed ``where`` sweep keeps the
    *lowest* matching k' (argmax-of-bool semantics), and an unmatched left
    machine falls through to the k' = 0 entries exactly as ``argmax`` over
    an all-false column does — garbage count, but flagged. The segmented
    kernels' fused Concatenate stage is this fold applied left-to-right
    across the segment grid axis (associativity per ``fold_pair``).
    """
    al, cl, bl, fl = left
    ar, cr, br, fr = right
    matched = jnp.zeros_like(fl)
    cr_g = jnp.broadcast_to(cr[0:1], cl.shape)
    br_g = jnp.broadcast_to(br[0:1], bl.shape)
    fr_g = jnp.broadcast_to(fr[0:1], fl.shape)
    for kp in range(k - 1, -1, -1):
        sel = bl == ar[kp:kp + 1]  # [K, M]
        matched = matched | sel
        cr_g = jnp.where(sel, cr[kp:kp + 1], cr_g)
        br_g = jnp.where(sel, br[kp:kp + 1], br_g)
        fr_g = jnp.where(sel, fr[kp:kp + 1], fr_g)
    return al, cl + cr_g, br_g, fl | fr_g | ~matched


def concatenate_tree(a, c, b, flag):
    """Fold P segments' tuples pairwise, log2(P) levels (paper Fig. 6).

    Args: a/c/b: i32[P, K, M]; flag: bool[P, K, M] (unmatched-so-far).
    Returns (count i32[M], bad bool[M]) for the phase-0 leftmost machine.
    """
    p = a.shape[0]
    while p > 1:
        a, c, b, flag = fold_pair(
            (a[0::2], c[0::2], b[0::2], flag[0::2]),
            (a[1::2], c[1::2], b[1::2], flag[1::2]))
        p //= 2
    return c[0, 0], flag[0, 0]


# ------------------------------------------------------------- public API


def make_segments(stream: EventStream, num_segments: int, w_max: int):
    """Host-side segmentation: boundaries + padded per-segment event windows.

    Segment p covers (τ_p, τ_{p+1}]; its window additionally includes the
    lookback (τ_p - W) and crossing zone (τ_{p+1} + W). Returns
    (tau i64[P+1], types i32[P, Lw], times i32[P, Lw]).
    """
    t0, t1 = stream.span
    total = max(int(t1 - t0), 1)
    p = max(num_segments, 1)
    while p > 1 and total // p <= max(w_max, 1):
        p //= 2  # keep segment length > W so zones don't overlap boundaries
    tau = np.round(np.linspace(t0 - 1, t1, p + 1)).astype(np.int64)
    real = stream.types != PAD_TYPE
    ts = stream.times[real]
    tys = stream.types[real]
    windows = []
    for i in range(p):
        lo = np.searchsorted(ts, tau[i] - w_max, side="right")
        hi = np.searchsorted(ts, tau[i + 1] + w_max, side="right")
        windows.append((lo, hi))
    lw = max(hi - lo for lo, hi in windows) if windows else 1
    wt = np.full((p, lw), PAD_TYPE, np.int32)
    wtt = np.full((p, lw), 0, np.int32)
    for i, (lo, hi) in enumerate(windows):
        wt[i, : hi - lo] = tys[lo:hi]
        wtt[i, : hi - lo] = ts[lo:hi]
    return tau, wt, wtt


@functools.partial(jax.jit, static_argnames=("lcap",))
def _map_all_segments(wt, wtt, etypes, tlo, thi, tau, w, lcap):
    """vmap the Map step over P segments. Returns a/c/b [P,K,M] + ovf."""
    n = etypes.shape[1]
    cum = phase_cum(thi)  # [M, N] — Σ_{i<k} thi^i
    tau32 = tau.astype(jnp.int32)

    def one_segment(ev_t, ev_tt, tau_lo, tau_hi):
        starts = (tau_lo - cum.T).astype(jnp.int32)  # [K=N, M]
        return _segment_scan(ev_t, ev_tt, etypes, tlo, thi, starts, tau_lo,
                             tau_hi, w, lcap)

    return jax.vmap(one_segment)(wt, wtt, tau32[:-1], tau32[1:])


def shard_device_count() -> int:
    """Largest power-of-two device count the segment axis can shard over
    (segment counts are powers of two, so a ragged mesh would idle
    devices); 1 means the sharded paths stand down. Single source of
    truth for every sharded dispatch decision — ``kernels.ops``,
    ``hybrid.shard_devices``, and the mesh builders all delegate here so
    the kernel path, the XLA fallback, and the launcher mesh can never
    disagree on the device set."""
    import jax
    d = jax.device_count()
    p = 1
    while p * 2 <= d:
        p *= 2
    return p


def data_mesh(num_devices: int | None = None):
    """1-D ``("data",)`` mesh over the first ``num_devices`` (default:
    ``shard_device_count()``) devices — the mesh the sharded
    streaming/counting paths shard segments over
    (``launch.mesh.make_stream_mesh`` re-exports this for launchers)."""
    import jax
    from jax.sharding import Mesh

    if num_devices is None:
        num_devices = shard_device_count()
    return Mesh(np.array(jax.devices()[:num_devices]), ("data",))


def mapconcatenate_sharded(stream: EventStream, eps: EpisodeBatch,
                           mesh=None, axis: str = "data",
                           lcap: int = DEFAULT_LCAP,
                           use_kernel: bool = False) -> np.ndarray:
    """Distributed MapConcatenate: the Map step shard_maps over the mesh
    ``axis`` (one segment per device — the paper's one-thread-block-per-
    segment), the O(P·N) tuples are all_gather'd, and the Concatenate tree
    folds replicated. Exactness fallback as in ``mapconcatenate``;
    ``use_kernel`` selects the fallback engine. ``mesh=None`` builds the
    default power-of-two ``data`` mesh (``data_mesh``)."""
    import jax
    from jax.sharding import PartitionSpec as P

    if eps.N == 1:
        return count_level1(stream, eps.etypes[:, 0])
    if mesh is None:
        mesh = data_mesh()
    p = mesh.shape[axis]
    w = eps.max_span
    w_max = int(w.max())
    tau, wt, wtt = make_segments(stream, p, w_max)
    if wt.shape[0] != p:  # stream too short for p segments — fall back
        return mapconcatenate(stream, eps, num_segments=wt.shape[0],
                              lcap=lcap, use_kernel=use_kernel)
    n = eps.N
    cum = np.asarray(phase_cum(eps.thi))  # [M, N]
    taus = np.stack([tau[:-1], tau[1:]], axis=1).astype(np.int32)  # [P, 2]

    def map_step(ev_t, ev_tt, tau_pair):
        # one segment per device; [1, ...] block shapes from shard_map
        ev_t, ev_tt, tau_pair = ev_t[0], ev_tt[0], tau_pair[0]
        starts = (tau_pair[0] - jnp.asarray(cum).T).astype(jnp.int32)
        a, c, b, ovf = _segment_scan(
            ev_t, ev_tt, jnp.asarray(eps.etypes), jnp.asarray(eps.tlo),
            jnp.asarray(eps.thi), starts, tau_pair[0], tau_pair[1],
            jnp.asarray(w, jnp.int32), lcap)
        out = jnp.stack([a, c, b, ovf.astype(jnp.int32)])[None]  # [1,4,K,M]
        return jax.lax.all_gather(out, axis, axis=0, tiled=True)

    from jax.experimental.shard_map import shard_map
    fn = shard_map(map_step, mesh=mesh,
                   in_specs=(P(axis), P(axis), P(axis)),
                   out_specs=P(None), check_rep=False)
    gathered = np.asarray(jax.jit(fn)(
        jnp.asarray(wt), jnp.asarray(wtt), jnp.asarray(taus)))  # [P,4,K,M]
    a, c, b, ovf = (jnp.asarray(gathered[:, i]) for i in range(4))
    flag0 = jnp.zeros(a.shape, jnp.bool_)
    count, bad = concatenate_tree(a, c, b, flag0)
    count = np.asarray(count, np.int64)
    bad = np.asarray(bad) | np.asarray(ovf.astype(bool).any(axis=(0, 1)))
    if bad.any():
        idx = np.nonzero(bad)[0]
        count = count.copy()
        count[idx] = _count_a1_exact(stream, eps.select(idx), lcap=lcap,
                                     use_kernel=use_kernel)
    return count


def mapconcatenate(stream: EventStream, eps: EpisodeBatch,
                   num_segments: int = 8,
                   lcap: int = DEFAULT_LCAP,
                   use_kernel: bool = False) -> np.ndarray:
    """Exact A1 counts via segment-parallel Map + Concatenate tree.

    Falls back to the single-scan engine for episodes whose tuples failed to
    stitch or whose bounded lists flagged a live eviction; ``use_kernel``
    controls whether that fallback may take the Pallas kernel path (plumbed
    from ``hybrid.count_dispatch`` so hybrid/mapconcatenate callers steer the
    fallback the same way ptpe callers do).
    """
    if eps.N == 1:
        return count_level1(stream, eps.etypes[:, 0])
    w = eps.max_span
    w_max = int(w.max())
    tau, wt, wtt = make_segments(stream, num_segments, w_max)
    a, c, b, ovf = _map_all_segments(
        jnp.asarray(wt), jnp.asarray(wtt), jnp.asarray(eps.etypes),
        jnp.asarray(eps.tlo), jnp.asarray(eps.thi), jnp.asarray(tau),
        jnp.asarray(w, dtype=jnp.int32), lcap)
    flag0 = jnp.zeros(a.shape, jnp.bool_)
    count, bad = concatenate_tree(a, c, b, flag0)
    count = np.asarray(count, np.int64)
    bad = np.asarray(bad) | np.asarray(ovf.any(axis=(0, 1)))
    if bad.any():
        idx = np.nonzero(bad)[0]
        count = count.copy()
        count[idx] = _count_a1_exact(stream, eps.select(idx), lcap=lcap,
                                     use_kernel=use_kernel)
    return count


def mapconcatenate_sharded_kernel(stream: EventStream, eps: EpisodeBatch,
                                  num_segments: int = 8,
                                  lcap: int = DEFAULT_LCAP,
                                  use_kernel: bool = True,
                                  num_devices: int | None = None
                                  ) -> np.ndarray:
    """Mesh-sharded in-kernel MapConcatenate — the cross-device half of
    the paper's mapping: the committed span is cut into one contiguous
    segment group per mesh ``data`` device, each device runs ONE segmented
    Pallas launch (grid = episode tile × local segments, in-group
    Concatenate fused on-chip — the same ``a1_mapconcat_kernel`` brick the
    single-device path uses), the O(P·N) per-device (a, count, b) tuples
    are all-gathered, and the final stitch folds replicated
    (``fold_pair`` is associative across arbitrary cut points, which is
    what makes the device boundaries invisible in the counts).

    Exactness containment is identical to ``mapconcatenate``: unmatched
    stitches and possibly-live evictions are recounted by the exact
    single-scan engine. Degrades gracefully — kernel dispatch declined
    (CPU without interpret mode) falls to the XLA shard_map Map step when
    a multi-device mesh exists and to plain ``mapconcatenate`` otherwise;
    fewer than two usable devices (or a stream too short to give every
    device a stitch-safe segment) falls to the single-device kernel. Same
    counts on every path.
    """
    if eps.N == 1:
        return count_level1(stream, eps.etypes[:, 0])
    try:
        from repro.kernels import ops as kops
        count, bad = kops.a1_mapconcat_sharded_count(
            stream, eps, num_segments=num_segments, lcap=lcap,
            num_devices=num_devices)
    except (ImportError, NotImplementedError):
        record_fallback("mapc_sharded")
        d = shard_device_count() if num_devices is None else num_devices
        if d >= 2:
            return mapconcatenate_sharded(stream, eps, mesh=data_mesh(d),
                                          lcap=lcap, use_kernel=use_kernel)
        return mapconcatenate(stream, eps, num_segments=num_segments,
                              lcap=lcap, use_kernel=use_kernel)
    if bad.any():
        idx = np.nonzero(bad)[0]
        count = count.copy()
        count[idx] = _count_a1_exact(stream, eps.select(idx), lcap=lcap,
                                     use_kernel=use_kernel)
    return count


def mapconcatenate_kernel(stream: EventStream, eps: EpisodeBatch,
                          num_segments: int = 8,
                          lcap: int = DEFAULT_LCAP,
                          use_kernel: bool = True) -> np.ndarray:
    """In-kernel MapConcatenate: one Pallas launch whose grid is
    (episode tile × time segment) runs the Map step's K = N phase machines
    per segment *and* the Concatenate fold on-chip
    (``kernels.a1_count.a1_mapconcat_kernel``), so the time axis is a grid
    axis instead of one long serial ``fori_loop`` and each segment's event
    window is DMA'd per grid step instead of the whole stream being
    broadcast-resident.

    Exactness containment is identical to ``mapconcatenate``: episodes whose
    tuples failed to stitch (``unmatched``) or whose bounded lists flagged a
    live eviction are recounted by the exact single-scan engine. When the
    kernel dispatch policy declines (CPU without interpret mode), falls back
    to the XLA ``mapconcatenate`` — same counts either way.
    """
    if eps.N == 1:
        return count_level1(stream, eps.etypes[:, 0])
    try:
        from repro.kernels import ops as kops
        count, bad = kops.a1_mapconcat_count(stream, eps,
                                             num_segments=num_segments,
                                             lcap=lcap)
    except (ImportError, NotImplementedError):
        record_fallback("mapc_kernel")
        return mapconcatenate(stream, eps, num_segments=num_segments,
                              lcap=lcap, use_kernel=use_kernel)
    if bad.any():
        idx = np.nonzero(bad)[0]
        count = count.copy()
        count[idx] = _count_a1_exact(stream, eps.select(idx), lcap=lcap,
                                     use_kernel=use_kernel)
    return count
