"""Sharding policy: parameter PartitionSpecs + activation constraints.

2-D sharding: parameters are FSDP-sharded over the data axes (``data``, and
``pod`` when present) and tensor-parallel over ``model``. Activations keep
batch on data axes and let XLA SPMD insert the TP collectives implied by the
weight shardings. KV caches shard their *sequence* dim over ``model`` at
decode (flash-decoding-style partition — XLA emits the partial-softmax
combine collectives), and over (data×model) for the 500k single-sequence
cell.

Every rule guards divisibility — a dim that doesn't divide the axis product
falls back to replication (e.g. kv-heads=8 on a 16-wide model axis).

``act()`` is the activation-constraint shim: model code tags activations by
name; the launcher installs a mesh-aware rule set; with none installed it is
an identity (single-device tests)."""

from __future__ import annotations

import contextlib
import threading
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .config import ModelConfig

# ------------------------------------------------------- activation shim

_TLS = threading.local()


def act(x, name: str):
    """Apply the installed activation constraint for ``name`` (or no-op).

    The spec is sanitized against the concrete shape: axes that don't divide
    their dim fall back to replicated, missing trailing dims are padded with
    None — one rule serves every arch/cell combination.

    An entry ``"model?"`` marks a *candidate* dim: exactly one of the
    candidates — the first whose size divides the model axis — receives
    "model". This lets e.g. attention logits [B, g, r, qc, S] shard over
    kv-heads when they divide (llama r=16), else over q-groups, else over
    the q-chunk dim (always 128-multiple) — GQA head counts vary per arch.
    """
    rules = getattr(_TLS, "rules", None)
    mesh = getattr(_TLS, "mesh", None)
    if not rules or name not in rules or mesh is None:
        return x
    spec = rules[name]
    entries = list(spec) + [None] * (x.ndim - len(spec))
    fixed = []
    placed = False
    for dim, axes in zip(x.shape, entries[: x.ndim]):
        if axes == "model?":
            if not placed and dim % axis_size(mesh, "model") == 0 \
                    and dim > 0:
                fixed.append("model")
                placed = True
            else:
                fixed.append(None)
            continue
        if axes is not None and dim % axis_size(mesh, axes) != 0:
            axes = None
        fixed.append(axes)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*fixed)))


@contextlib.contextmanager
def activation_rules(mesh, rules: dict):
    old = (getattr(_TLS, "rules", None), getattr(_TLS, "mesh", None))
    _TLS.rules, _TLS.mesh = rules, mesh
    try:
        yield
    finally:
        _TLS.rules, _TLS.mesh = old


# ------------------------------------------------------------ mesh utils


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    """The data-parallel/FSDP axes: ('pod','data') on multi-pod meshes."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _maybe(mesh: Mesh, axes, dim: int):
    """axes if dim divides their product else None (replicate)."""
    return axes if dim % axis_size(mesh, axes) == 0 else None


# ----------------------------------------------------------- param rules


# Rules: leaf-path suffix → axis assignment for the TRAILING dims. Leading
# dims (e.g. the stacked [num_periods] axis of scanned blocks — absent on
# tail layers) are padded with None, so one rule serves both layouts.
_TRAILING_RULES: list[tuple[tuple[str, ...], tuple]] = [
    (("attn.wq", "attn.wk", "attn.wv"), ("DP", "TP", None)),
    (("attn.wo",), ("TP", None, "DP")),
    (("attn.bq", "attn.bk", "attn.bv"), ("TP", None)),
    (("mlp.wi", "mlp.wg", "shared.wi", "shared.wg"), ("DP", "TP")),
    (("mlp.wo", "shared.wo"), ("TP", "DP")),
    (("experts.wi", "experts.wg"), ("TP", "DP", None)),
    (("experts.wo",), ("TP", None, "DP")),
    (("router",), ("DP", None)),
    (("mamba.in_proj",), ("DP", "TP")),
    (("mamba.out_proj",), ("TP", "DP")),
    (("mamba.conv_w",), (None, "TP")),
    (("mamba.conv_b", "mamba.dt_bias", "mamba.d_skip"), ("TP",)),
    (("mamba.x_proj", "mamba.a_log"), ("TP", None)),
]


def _leaf_spec(mesh: Mesh, cfg: ModelConfig, path: str, shape) -> P:
    dp = dp_axes(mesh)
    nd = len(shape)

    def m(axes, dim):  # shorthand with divisibility guard
        if axes == "DP":
            axes = dp
        elif axes == "TP":
            axes = "model"
        return _maybe(mesh, axes, dim) if axes is not None else None

    if path.endswith("embed"):
        return P(m("TP", shape[0]), m("DP", shape[1]))
    if path.endswith("lm_head"):
        return P(m("DP", shape[0]), m("TP", shape[1]))
    if "norm" in path or path.endswith(("ln1", "ln2")):
        return P(*([None] * nd))
    for suffixes, axes in _TRAILING_RULES:
        if path.endswith(suffixes):
            k = len(axes)
            tail = [m(a, shape[nd - k + i]) for i, a in enumerate(axes)]
            return P(*([None] * (nd - k) + tail))
    return P(*([None] * nd))  # default: replicate


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return ".".join(parts)


def param_pspecs(mesh: Mesh, cfg: ModelConfig, param_tree) -> Any:
    """PartitionSpec tree matching ``param_tree`` (arrays or SDS)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_spec(mesh, cfg, _path_str(path),
                                      leaf.shape),
        param_tree)


def param_shardings(mesh: Mesh, cfg: ModelConfig, param_tree) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_pspecs(mesh, cfg, param_tree))


# ----------------------------------------------------------- batch rules


def batch_pspecs(mesh: Mesh, batch_tree) -> Any:
    """tokens/labels [B,S] and embeddings [B,S,D]: batch over dp axes
    (replicated when B doesn't divide — the B=1 long-context cell)."""
    dp = dp_axes(mesh)

    def spec(leaf):
        b = leaf.shape[0]
        rest = [None] * (len(leaf.shape) - 1)
        return P(_maybe(mesh, dp, b), *rest)

    return jax.tree.map(spec, batch_tree)


def cache_pspecs(mesh: Mesh, cfg: ModelConfig, cache_tree,
                 shard_seq: str = "model") -> Any:
    """Decode-cache specs. Attention KV [reps, B, Smax, Hkv, Dh]: batch→dp,
    seq→``shard_seq`` ("model", "all" = data+model for B=1, or "none").
    Mamba h [reps, B, din, st] / conv [reps, B, conv-1, din]: din→model."""
    dp = dp_axes(mesh)
    seq_axes = {"model": "model", "all": dp + ("model",),
                "none": None}[shard_seq]

    def spec(leaf):
        shp = leaf.shape
        nd = len(shp)
        lead = [None] * (nd - 4)  # scanned caches carry [num_periods]
        if nd >= 4 and shp[-1] == cfg.head_dim \
                and shp[-2] == cfg.num_kv_heads:   # attn KV [.., B, S, H, Dh]
            return P(*lead, _maybe(mesh, dp, shp[-4]),
                     _maybe(mesh, seq_axes, shp[-3]),
                     _maybe(mesh, "model", shp[-2]) if seq_axes is None
                     else None, None)
        lead = [None] * (nd - 3)
        if nd >= 3 and shp[-1] == cfg.ssm_state:   # mamba h [.., B, din, st]
            return P(*lead, _maybe(mesh, dp, shp[-3]),
                     _maybe(mesh, "model", shp[-2]), None)
        if nd >= 3 and shp[-1] == cfg.d_inner:     # conv tail [.., B, c-1, di]
            return P(*lead, _maybe(mesh, dp, shp[-3]), None,
                     _maybe(mesh, "model", shp[-1]))
        return P(*([None] * nd))

    return jax.tree.map(spec, cache_tree)


def default_activation_rules(mesh: Mesh, cfg: ModelConfig,
                             kind: str = "train") -> dict:
    """Activation pins by tag. These are what keep XLA's SPMD propagation
    honest inside scan/remat bodies (without them the partitioner replicates
    whole-batch attention logits — measured: 60× FLOP/memory blow-up on the
    gemma3 train cell)."""
    dp = dp_axes(mesh)
    if kind == "decode":
        attn_logits = P(dp, None, None, None, "model")  # S = cache, sharded
        hidden = P(dp, None, None)
        q_heads = P(dp, None, None, None)  # model axis is spent on cache-S
    else:
        # PERF#1a: q/head-sharded attention logits — one "model?" candidate
        # lands on kv-groups (llama r=16) or the q-chunk dim (always
        # divisible) for awkward head counts (yi 56H, qwen 40H).
        attn_logits = P(dp, "model?", "model?", "model?", None)
        # PERF#1b: sequence-parallel residual stream (Megatron-SP): the
        # scan-saved per-layer carry shards S over model → 16× less
        # activation memory; XLA inserts all-gather at qkv / reduce-scatter
        # after wo (collective cost measured in §Perf).
        # PERF#4: NOT for ssm/hybrid families — mamba's chunked scan wants
        # the full local sequence, and SP only added per-layer gathers
        # (measured: falcon-mamba train 7.9% → 6.1% MFU-bound regression,
        # reverted for those families).
        sp = not (cfg.family in ("ssm", "hybrid") or cfg.attn_every)
        hidden = P(dp, "model" if sp else None, None)
        # PERF#2: q heads TP-sharded (the projection was otherwise computed
        # replicated across the model axis: +16× qkv/wo FLOPs)
        q_heads = P(dp, None, "model?", None)
    return {
        # [B, S, D] block boundaries / embeddings
        "hidden": hidden,
        # [B, r, g, qc, S] attention logits (rep-major head layout)
        "attn_logits": attn_logits,
        # [B, qc, r, g, Dh] per-chunk attention outputs — pin so the
        # (r,g)→H merge stays expressible (or gathers, never replicates)
        "attn_out": P(dp, None, "model?", "model?", None),
        # [B, S, H, Dh] q projection (TP on heads when divisible)
        "q_heads": q_heads,
        # [B, S, Hkv, Dh] k/v projections (kv-head counts rarely divide TP;
        # replicated-over-model is the cheap, correct default)
        "kv": P(dp, None, None, None),
        # [B, S, F] dense FFN inner
        "ffn_inner": P(dp, None, "model"),
        # [G, S, E, C] routing one-hots
        "moe_dispatch": P(dp, None, "model", None),
        # [G, E, C, D/F] expert compute
        "moe_inner": P(dp, "model", None, None),
        # [B, L, din, st] mamba scan elements/states
        "mamba_state": P(dp, None, "model", None),
        # [B, chunk, V] CE-loss logits
        "logits_chunk": P(dp, None, "model"),
    }
