"""Model configuration + layer-pattern helpers for the architecture zoo.

A config describes an LM-family transformer backbone: dense / MoE / SSM /
hybrid, with GQA attention, optional sliding-window locality, optional
Mamba-1 mixers, and a stubbed modality frontend for [audio]/[vlm] entries
(inputs arrive as precomputed frame/patch embeddings).

Heterogeneous layer stacks (jamba's 1:7 attn:mamba interleave, gemma's 5:1
local:global) are expressed as a repeating **period**: `layer_kind(cfg, i)`
and friends are pure functions of the layer index, and the stack scans over
periods so compiled HLO size is O(period), not O(num_layers).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str              # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0        # 0 → d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    moe_every: int = 1       # MoE at layers i % moe_every == moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25
    d_ff_shared: int = 0     # shared-expert ffn width (0 = none)

    # --- attention ---
    qkv_bias: bool = False
    window: int = 0          # sliding-window size for local layers (0 = full)
    global_every: int = 0    # 1 global layer per this many (gemma3: 6)
    rope_theta: float = 1e4

    # --- mamba / hybrid ---
    attn_every: int = 0      # jamba: 1 attention layer per this many (8)
    attn_offset: int = 0
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2

    # --- mlp ---
    mlp_variant: str = "swiglu"   # swiglu | gelu

    # --- frontend ---
    stub_frontend: bool = False   # audio/vlm: inputs are embeddings

    # --- execution policy ---
    remat: bool = True
    scan_layers: bool = True
    logits_chunk: int = 512       # sequence chunk for the CE loss
    attn_q_chunk: int = 1024      # query-block size for chunked attention
    mamba_chunk: int = 256        # chunk length for the chunked SSM scan
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    moment_dtype: str = "float32" # AdamW moments (bf16 on the largest archs)

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.num_heads)

    # ------------------------------------------------ layer-pattern helpers

    def layer_kind(self, i: int) -> str:
        """"attn" or "mamba" for layer i."""
        if self.family == "ssm":
            return "mamba"
        if self.attn_every:
            return ("attn" if i % self.attn_every == self.attn_offset
                    else "mamba")
        return "attn"

    def is_moe_layer(self, i: int) -> bool:
        if not self.num_experts:
            return False
        return i % self.moe_every == self.moe_offset

    def is_global_attn(self, i: int) -> bool:
        """Full-context attention layer? (vs sliding-window local)"""
        if not self.window:
            return True
        if not self.global_every:
            return False
        return i % self.global_every == self.global_every - 1

    @property
    def period(self) -> int:
        """Smallest repeating layer pattern (for scan-over-periods)."""
        p = 1
        for q in (self.moe_every if self.num_experts else 1,
                  self.attn_every or 1, self.global_every or 1):
            p = _lcm(p, q)
        return p

    @property
    def num_periods(self) -> int:
        """Full periods covered by the layer scan."""
        return self.num_layers // self.period

    @property
    def tail_layers(self) -> int:
        """Remainder layers applied unstacked after the scan (gemma3-1b:
        26 = 4×6 + 2)."""
        return self.num_layers % self.period

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def params_per_layer(self, i: int) -> int:
        """Parameter count of layer i (for 6·N·D model-FLOPs accounting)."""
        d, f = self.d_model, self.d_ff
        n = 0
        if self.layer_kind(i) == "attn":
            di = (self.num_heads + 2 * self.num_kv_heads) * self.head_dim
            n += d * di + self.num_heads * self.head_dim * d
            if self.qkv_bias:
                n += di
        else:
            din, st = self.d_inner, self.ssm_state
            n += d * 2 * din + din * self.ssm_conv
            n += din * (st * 2 + 1) + din * 2  # B,C,dt_proj(+A,D approx)
            n += din * d
        if self.is_moe_layer(i):
            e = self.num_experts
            n += d * e  # router
            n += e * self._ffn_params(d, f)
            if self.d_ff_shared:
                n += self._ffn_params(d, self.d_ff_shared)
        elif self.layer_kind(i) == "attn" or self.family != "ssm":
            if f:
                n += self._ffn_params(d, f)
        n += 2 * d  # norms
        return n

    def _ffn_params(self, d: int, f: int) -> int:
        return d * f * (3 if self.mlp_variant == "swiglu" else 2)

    def num_params(self, embeddings: bool = True) -> int:
        n = sum(self.params_per_layer(i) for i in range(self.num_layers))
        n += self.d_model  # final norm
        if embeddings:
            n += 2 * self.vocab_size * self.d_model  # embed + lm head
        return n

    def num_active_params_per_token(self) -> int:
        """Active parameters (MoE top-k) — for 6·N_active·D."""
        n = 0
        for i in range(self.num_layers):
            pl_ = self.params_per_layer(i)
            if self.is_moe_layer(i):
                e, k = self.num_experts, self.top_k
                expert_p = e * self._ffn_params(self.d_model, self.d_ff)
                pl_ = pl_ - expert_p + k * self._ffn_params(self.d_model,
                                                            self.d_ff)
            n += pl_
        n += self.d_model + 2 * self.vocab_size * self.d_model
        return n


def _lcm(a: int, b: int) -> int:
    from math import gcd
    return a * b // gcd(a, b)


def scaled_down(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    base = dict(
        num_layers=cfg.period * 2, d_model=64,
        num_heads=4, num_kv_heads=max(1, min(cfg.num_kv_heads, 2)),
        head_dim=16, d_ff=128, vocab_size=256,
        num_experts=min(cfg.num_experts, 4) if cfg.num_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        window=min(cfg.window, 8) if cfg.window else 0,
        ssm_state=8, ssm_expand=2, ssm_conv=4,
        logits_chunk=16, attn_q_chunk=16, mamba_chunk=8,
        dtype="float32", param_dtype="float32",
        name=cfg.name + "-smoke",
    )
    base.update(overrides)
    return dataclasses.replace(cfg, **base)
