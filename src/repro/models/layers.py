"""Core transformer layers: RMSNorm, RoPE, GQA attention (chunked for long
context, KV-cached for decode), gated/plain MLPs. Pure JAX; distribution
comes from pjit sharding constraints (models/sharding.py)."""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .sharding import act

# --------------------------------------------------------------- norms


def rms_norm(x, scale, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------- RoPE


def rope_angles(positions, head_dim: int, theta: float):
    """positions i32[...]; returns (sin, cos) f32[..., head_dim/2]."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (np.arange(0, half) * 2.0 / head_dim))
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., half]
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x, sin, cos):
    """x [..., S, H, Dh]; sin/cos [..., S, half] broadcast over heads."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    s, c = sin[..., None, :], cos[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],
                           axis=-1).astype(x.dtype)


# ----------------------------------------------------------- attention


class AttnParams(NamedTuple):
    wq: jax.Array            # [D, H, Dh]
    wk: jax.Array            # [D, Hkv, Dh]
    wv: jax.Array            # [D, Hkv, Dh]
    wo: jax.Array            # [H, Dh, D]
    bq: jax.Array | None = None
    bk: jax.Array | None = None
    bv: jax.Array | None = None


def init_attn(key, cfg: ModelConfig, dtype) -> dict:
    d, h, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    std = d ** -0.5
    p = {
        "wq": jax.random.normal(k1, (d, h, dh), dtype) * std,
        "wk": jax.random.normal(k2, (d, hkv, dh), dtype) * std,
        "wv": jax.random.normal(k3, (d, hkv, dh), dtype) * std,
        "wo": jax.random.normal(k4, (h, dh, d), dtype) * std,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, dh), dtype)
        p["bk"] = jnp.zeros((hkv, dh), dtype)
        p["bv"] = jnp.zeros((hkv, dh), dtype)
    return p


def _qkv(p, cfg: ModelConfig, x, positions):
    q = act(jnp.einsum("bsd,dhk->bshk", x, p["wq"]), "q_heads")
    k = act(jnp.einsum("bsd,dhk->bshk", x, p["wk"]), "kv")
    v = act(jnp.einsum("bsd,dhk->bshk", x, p["wv"]), "kv")
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    sin, cos = rope_angles(positions, cfg.head_dim, cfg.rope_theta)
    return apply_rope(q, sin, cos), apply_rope(k, sin, cos), v


def attention(p, cfg: ModelConfig, x, positions, window: int = 0):
    """Self-attention over full sequences (train / prefill).

    Causal; optional sliding window. Query-chunked (``attn_q_chunk``) so the
    largest transient is [B, H, qc, S] — flash-style memory shape without a
    custom kernel (XLA fuses the row-softmax into the QK product).
    Returns (y, (k, v)) — k/v returned for prefill cache construction.
    """
    b, s, d = x.shape
    q, k, v = _qkv(p, cfg, x, positions)
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    rep = h // hkv
    scale = dh ** -0.5
    qc = min(cfg.attn_q_chunk, s)
    n_chunks = (s + qc - 1) // qc
    assert s % qc == 0, f"seq {s} not divisible by q-chunk {qc}"
    # REP-MAJOR head layout (H = r·hkv + g): the (r, g) → H merge after the
    # chunk loop then carries the model-axis sharding on its OUTER
    # component, which SPMD can express — minor-dim sharding forced an
    # "involuntary full rematerialization" (replicated wo matmuls, +45%
    # step FLOPs on llama3-405b train; see §Perf). Weight layouts are
    # initialized in this convention (checkpoints would be permuted once
    # at load).
    qg = q.reshape(b, s, rep, hkv, dh)
    kpos = positions

    def one_chunk(i):
        qi = jax.lax.dynamic_slice_in_dim(qg, i * qc, qc, axis=1)
        qpos = jax.lax.dynamic_slice_in_dim(positions, i * qc, qc, axis=-1)
        logits = jnp.einsum("bqrgk,bsgk->brgqs", qi, k) * scale
        logits = act(logits.astype(jnp.float32), "attn_logits")
        mask = qpos[..., :, None] >= kpos[..., None, :]  # causal [B, qc, S]
        if window:
            mask &= (qpos[..., :, None] - kpos[..., None, :]) < window
        logits = jnp.where(mask[:, None, None], logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        return act(jnp.einsum("brgqs,bsgk->bqrgk", w, v), "attn_out")

    if n_chunks == 1:
        o = one_chunk(0)
    else:
        # checkpoint each chunk: lax.map otherwise stacks every chunk's
        # logits as backward residuals — the full S×S matrix we chunked to
        # avoid (measured: 16 GiB/layer on the gemma3 train cell)
        o = jax.lax.map(jax.checkpoint(one_chunk), jnp.arange(n_chunks))
        o = jnp.moveaxis(o, 0, 1).reshape(b, s, rep, hkv, dh)
    # bf16 output dtype on the TP-reduced projection → the partial-sum
    # all-reduce ships bf16, not f32 (MXU still accumulates f32) — PERF#3
    y = jnp.einsum("bshk,hkd->bsd", o.reshape(b, s, h, dh), p["wo"],
                   preferred_element_type=x.dtype)
    return y, (k, v)


def decode_attention(p, cfg: ModelConfig, x, cache_k, cache_v, pos,
                     window: int = 0):
    """One-token decode against a KV cache.

    x [B, 1, D]; cache_k/v [B, Smax, Hkv, Dh]; pos scalar i32 (current index).
    Returns (y [B,1,D], new_cache_k, new_cache_v).
    """
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = _qkv(p, cfg, x, positions)
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k.astype(cache_k.dtype), pos, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v.astype(cache_v.dtype), pos, axis=1)
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    rep = h // hkv
    smax = cache_k.shape[1]
    qg = q.reshape(b, 1, rep, hkv, dh)  # rep-major (see attention())
    logits = jnp.einsum("bqrgk,bsgk->brgqs", qg, cache_k) * dh ** -0.5
    # [B, r, g, 1, Smax] — the rule's trailing axis is the cache seq,
    # sharded at decode (flash-decoding-style partition)
    logits = act(logits.astype(jnp.float32), "attn_logits")
    kpos = jnp.arange(smax)
    mask = kpos <= pos
    if window:
        mask &= kpos > pos - window
    logits = jnp.where(mask[None, None, None, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    o = jnp.einsum("brgqs,bsgk->bqrgk", w, cache_v)
    y = jnp.einsum("bshk,hkd->bsd", o.reshape(b, 1, h, dh), p["wo"])
    return y, cache_k, cache_v


# ----------------------------------------------------------------- MLP


def init_mlp(key, cfg: ModelConfig, dtype, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    std = d ** -0.5
    if cfg.mlp_variant == "swiglu":
        k1, k2, k3 = jax.random.split(key, 3)
        return {"wi": jax.random.normal(k1, (d, f), dtype) * std,
                "wg": jax.random.normal(k2, (d, f), dtype) * std,
                "wo": jax.random.normal(k3, (f, d), dtype) * f ** -0.5}
    k1, k2 = jax.random.split(key, 2)
    return {"wi": jax.random.normal(k1, (d, f), dtype) * std,
            "wo": jax.random.normal(k2, (f, d), dtype) * f ** -0.5}


def mlp(p, cfg: ModelConfig, x):
    if cfg.mlp_variant == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])
    else:
        h = jax.nn.gelu(x @ p["wi"])
    # bf16-out on the TP-reduced projection (see attention wo) — PERF#3
    return jnp.einsum("bsf,fd->bsd", act(h, "ffn_inner"), p["wo"],
                      preferred_element_type=x.dtype)
