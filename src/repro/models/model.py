"""Top-level LM: embeddings → block stack → norm → (chunked) logits/loss,
plus prefill/decode entry points with explicit cache pytrees.

[audio]/[vlm] archs use the stubbed frontend: the batch carries precomputed
frame/patch embeddings [B, S, D] instead of token ids (backbone-only scope,
per the assignment)."""

from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import rms_norm
from .sharding import act
from .transformer import apply_stack, init_stack

AUX_LOSS_COEF = 0.01


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ------------------------------------------------------------------ init


def init_params(key, cfg: ModelConfig) -> dict:
    ke, ks, kh = jax.random.split(key, 3)
    dtype = _dtype(cfg)
    p = {
        "blocks": init_stack(ks, cfg, dtype),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
        "lm_head": jax.random.normal(
            kh, (cfg.d_model, cfg.vocab_size), dtype) * cfg.d_model ** -0.5,
    }
    if not cfg.stub_frontend:
        p["embed"] = jax.random.normal(
            ke, (cfg.vocab_size, cfg.d_model), dtype)
    else:  # frontend stub still needs an embed for decode-time token feeds
        p["embed"] = jax.random.normal(
            ke, (cfg.vocab_size, cfg.d_model), dtype)
    return p


def param_specs(cfg: ModelConfig) -> Any:
    """ShapeDtypeStruct pytree of params — dry-run without allocation."""
    return jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg))


# ----------------------------------------------------------------- embed


def embed_inputs(params, cfg: ModelConfig, batch) -> jax.Array:
    if "embeddings" in batch:  # stubbed modality frontend
        return act(batch["embeddings"].astype(_dtype(cfg)), "hidden")
    return act(params["embed"][batch["tokens"]].astype(_dtype(cfg)),
               "hidden")


# ------------------------------------------------------------------ loss


def chunked_ce_loss(h, lm_head, labels, chunk: int):
    """Cross-entropy without materializing [B, S, V] logits: scan over
    sequence chunks (memory hot-spot fix for 128k-vocab archs)."""
    b, s, d = h.shape
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk

    @jax.checkpoint  # don't stack per-chunk logits as scan residuals
    def chunk_loss(i):
        hc = jax.lax.dynamic_slice_in_dim(h, i * chunk, chunk, axis=1)
        yc = jax.lax.dynamic_slice_in_dim(labels, i * chunk, chunk, axis=1)
        logits = act((hc @ lm_head).astype(jnp.float32),   # [B, c, V]
                     "logits_chunk")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None],
                                   axis=-1)[..., 0]
        return jnp.sum(lse - gold)

    def body(acc, i):
        return acc + chunk_loss(i), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                            jnp.arange(nc))
    return total / (b * s)


def loss_fn(params, cfg: ModelConfig, batch):
    """Next-token CE (+ MoE aux). batch: tokens|embeddings, labels."""
    x = embed_inputs(params, cfg, batch)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    h, _, aux = apply_stack(params["blocks"], cfg, x, positions, "train")
    h = rms_norm(h, params["final_norm"])
    ce = chunked_ce_loss(h, params["lm_head"], batch["labels"],
                         cfg.logits_chunk)
    return ce + AUX_LOSS_COEF * aux, {"ce": ce, "aux": aux}


# -------------------------------------------------------------- serving


class DecodeState(NamedTuple):
    caches: Any        # per-period-position stacked cache pytrees
    pos: jax.Array     # scalar i32 — next write index


def make_decode_caches(cfg: ModelConfig, batch_size: int, max_seq: int):
    """Zero-initialized cache pytree (structure mirrors apply_stack ys)."""
    dtype = _dtype(cfg)

    def one(j, reps=None):
        lead = (reps,) if reps is not None else ()
        if cfg.layer_kind(j) == "attn":
            shp = lead + (batch_size, max_seq, cfg.num_kv_heads,
                          cfg.head_dim)
            return (jnp.zeros(shp, dtype), jnp.zeros(shp, dtype))
        h = jnp.zeros(lead + (batch_size, cfg.d_inner, cfg.ssm_state),
                      jnp.float32)
        conv = jnp.zeros(lead + (batch_size, cfg.ssm_conv - 1, cfg.d_inner),
                         dtype)
        return (h, conv)

    return {"scan": [one(j, cfg.num_periods) for j in range(cfg.period)],
            "tail": [one(j) for j in range(cfg.tail_layers)]}


def decode_cache_specs(cfg: ModelConfig, batch_size: int, max_seq: int):
    return jax.eval_shape(
        functools.partial(make_decode_caches, cfg, batch_size, max_seq))


def prefill(params, cfg: ModelConfig, batch):
    """Forward over a full prompt; returns (last-position logits,
    per-layer caches). Attention caches cover [0, S); decode continues at S.
    """
    x = embed_inputs(params, cfg, batch)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    h, caches, _ = apply_stack(params["blocks"], cfg, x, positions,
                               "prefill")
    h = rms_norm(h, params["final_norm"])
    logits = (h[:, -1:, :] @ params["lm_head"]).astype(jnp.float32)
    return logits, caches


def decode_step(params, cfg: ModelConfig, tokens, state: DecodeState):
    """One serving step: tokens [B, 1] i32 → (logits [B, 1, V], new state).
    For stub-frontend archs the decoded modality token still goes through
    the (stub) embed table — backbone-only scope."""
    x = params["embed"][tokens].astype(_dtype(cfg))
    b = x.shape[0]
    positions = jnp.full((b, 1), state.pos, jnp.int32)
    h, new_caches, _ = apply_stack(params["blocks"], cfg, x, positions,
                                   "decode", caches=state.caches,
                                   pos=state.pos)
    h = rms_norm(h, params["final_norm"])
    logits = (h @ params["lm_head"]).astype(jnp.float32)
    return logits, DecodeState(caches=new_caches, pos=state.pos + 1)
