from .config import ModelConfig, scaled_down
from .model import (DecodeState, decode_cache_specs, decode_step,
                    embed_inputs, init_params, loss_fn, make_decode_caches,
                    param_specs, prefill)

__all__ = ["ModelConfig", "scaled_down", "DecodeState", "decode_step",
           "decode_cache_specs", "embed_inputs", "init_params", "loss_fn",
           "make_decode_caches", "param_specs", "prefill"]
