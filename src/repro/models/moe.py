"""Capacity-factor top-k MoE (GShard/Switch-style dense dispatch).

Routing is expressed as one-hot dispatch/combine einsums so the layer is
fully shardable under pjit: experts live on the ``model`` mesh axis, tokens
on ``data``; XLA lowers the dispatch einsums to all-to-all-style collectives
on the expert axis. Over-capacity tokens are dropped (standard
capacity-factor semantics) — the combine weights of dropped tokens are zero
so the residual stream passes them through.

Shapes: tokens grouped per sequence (G=batch, S=seq); capacity
C = ceil(S · top_k · cf / E). Transients are [G, S, E, C] one-hots —
per-device this is modest after sharding but is the layer's memory hot spot
(see EXPERIMENTS.md §Perf for the capacity/layout iteration).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import init_mlp, mlp
from .sharding import act


def init_moe(key, cfg: ModelConfig, dtype) -> dict:
    d, e, f = cfg.d_model, cfg.num_experts, cfg.d_ff
    kr, ke, ks = jax.random.split(key, 3)
    if cfg.mlp_variant == "swiglu":
        k1, k2, k3 = jax.random.split(ke, 3)
        experts = {
            "wi": jax.random.normal(k1, (e, d, f), dtype) * d ** -0.5,
            "wg": jax.random.normal(k2, (e, d, f), dtype) * d ** -0.5,
            "wo": jax.random.normal(k3, (e, f, d), dtype) * f ** -0.5,
        }
    else:
        k1, k2 = jax.random.split(ke, 2)
        experts = {
            "wi": jax.random.normal(k1, (e, d, f), dtype) * d ** -0.5,
            "wo": jax.random.normal(k2, (e, f, d), dtype) * f ** -0.5,
        }
    p = {"router": jax.random.normal(kr, (d, e), jnp.float32) * d ** -0.5,
         "experts": experts}
    if cfg.d_ff_shared:
        p["shared"] = init_mlp(ks, cfg, dtype, d_ff=cfg.d_ff_shared)
    return p


def capacity(cfg: ModelConfig, s: int) -> int:
    c = int(s * cfg.top_k * cfg.capacity_factor / cfg.num_experts) + 1
    return min(max(c, cfg.top_k), s)


def moe_layer(p, cfg: ModelConfig, x):
    """x [G, S, D] → [G, S, D]."""
    g, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    c = capacity(cfg, s)
    logits = (x.astype(jnp.float32) @ p["router"])  # [G, S, E]
    gates = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(gates, k)                       # [G, S, K]
    topv = topv / jnp.clip(topv.sum(-1, keepdims=True), 1e-9)  # renormalize
    # position of each (token, k) within its expert's buffer
    onehot = jax.nn.one_hot(topi, e, dtype=jnp.int32)          # [G, S, K, E]
    pos = jnp.cumsum(onehot.reshape(g, s * k, e), axis=1)
    pos = (pos.reshape(g, s, k, e) - 1) * onehot - (1 - onehot)
    pos = pos.max(axis=-1)                                     # [G, S, K]
    keep = (pos >= 0) & (pos < c)
    # combine[g,s,e,c] = gate weight of token s in slot c of expert e.
    # PERF#5: the [G,S,E,C] one-hots are the layer's dominant transient —
    # build them in the model dtype (bf16 gate weights are plenty: they are
    # renormalized probabilities), halving dispatch traffic/memory.
    ohdtype = x.dtype
    combine = jnp.einsum(
        "gske,gskc->gsec",
        jax.nn.one_hot(topi, e, dtype=ohdtype)
        * (topv * keep)[..., None].astype(ohdtype),
        jax.nn.one_hot(jnp.where(keep, pos, 0), c, dtype=ohdtype)
        * keep[..., None].astype(ohdtype))
    combine = act(combine, "moe_dispatch")
    dispatch = (combine > 0.0)
    xe = act(jnp.einsum("gsec,gsd->gecd", dispatch.astype(x.dtype), x),
             "moe_inner")
    h = _expert_ffn(p["experts"], cfg, xe)                     # [G, E, C, D]
    h = act(h, "moe_inner")
    y = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), h)
    if cfg.d_ff_shared:
        y = y + mlp(p["shared"], cfg, x)
    return y, _aux_loss(gates, topi, e)


def _expert_ffn(pe, cfg: ModelConfig, xe):
    """xe [G, E, C, D] → [G, E, C, D] through per-expert FFNs."""
    if cfg.mlp_variant == "swiglu":
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, pe["wg"])) \
            * jnp.einsum("gecd,edf->gecf", xe, pe["wi"])
    else:
        h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", xe, pe["wi"]))
    return jnp.einsum("gecf,efd->gecd", h, pe["wo"])


def _aux_loss(gates, topi, e):
    """Switch-style load-balancing auxiliary loss."""
    me = gates.mean(axis=(0, 1))                                  # [E]
    ce = jax.nn.one_hot(topi[..., 0], e).mean(axis=(0, 1))        # [E]
    return e * jnp.sum(me * ce)
