"""Block stack: heterogeneous layers (attn/mamba × dense/MoE × local/global)
arranged as a repeating period, scanned over periods with rematerialization.

Scanning over periods (not layers) keeps the compiled HLO O(period) while
supporting jamba's 1:7 attn:mamba interleave and gemma's 5:1 local:global
pattern exactly. Per-position parameters/caches are pytrees stacked along a
leading [num_periods] axis — `lax.scan` consumes them directly.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (attention, decode_attention, init_attn, init_mlp, mlp,
                     rms_norm)
from .mamba import init_mamba, mamba_decode, mamba_layer
from .moe import init_moe, moe_layer
from .sharding import act


# ------------------------------------------------------------------ init


def init_block(key, cfg: ModelConfig, i: int, dtype) -> dict:
    """Parameters for layer i (structure depends only on i % period)."""
    k1, k2, k3 = jax.random.split(key, 3)
    p: dict[str, Any] = {"ln1": jnp.zeros((cfg.d_model,), jnp.float32)}
    if cfg.layer_kind(i) == "attn":
        p["attn"] = init_attn(k1, cfg, dtype)
    else:
        p["mamba"] = init_mamba(k1, cfg, dtype)
    if cfg.family != "ssm":
        p["ln2"] = jnp.zeros((cfg.d_model,), jnp.float32)
        if cfg.is_moe_layer(i):
            p["moe"] = init_moe(k2, cfg, dtype)
        else:
            p["mlp"] = init_mlp(k2, cfg, dtype)
    return p


def init_stack(key, cfg: ModelConfig, dtype) -> dict:
    """{"scan": per-period-position params stacked over num_periods,
    "tail": unstacked params for the remainder layers}."""
    period, reps, tail = cfg.period, cfg.num_periods, cfg.tail_layers
    kscan, ktail = jax.random.split(key)
    out = []
    keys = jax.random.split(kscan, period * reps).reshape(period, reps, 2)
    for j in range(period):
        per_rep = [init_block(keys[j, r], cfg, j, dtype)
                   for r in range(reps)]
        out.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_rep))
    tkeys = jax.random.split(ktail, max(tail, 1))
    tail_params = [init_block(tkeys[j], cfg, j, dtype) for j in range(tail)]
    return {"scan": out, "tail": tail_params}


# ----------------------------------------------------------------- apply


def apply_block(p, cfg: ModelConfig, i: int, x, positions,
                mode: str, cache=None, pos=None):
    """One layer. mode: "train" | "prefill" | "decode".
    Returns (x, new_cache_or_None, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    x = act(x, "hidden")
    h = rms_norm(x, p["ln1"])
    kind = cfg.layer_kind(i)
    window = 0 if cfg.is_global_attn(i) else cfg.window
    if kind == "attn":
        if mode == "decode":
            y, ck, cv = decode_attention(p["attn"], cfg, h, cache[0],
                                         cache[1], pos, window=window)
            new_cache = (ck, cv)
        else:
            y, (k, v) = attention(p["attn"], cfg, h, positions,
                                  window=window)
            new_cache = (k, v) if mode == "prefill" else None
    else:
        if mode == "decode":
            y, hs, conv = mamba_decode(p["mamba"], cfg, h, cache[0],
                                       cache[1])
            new_cache = (hs, conv)
        else:
            y, (hs, conv) = mamba_layer(p["mamba"], cfg, h)
            new_cache = (hs, conv) if mode == "prefill" else None
    # pin mixer/MLP outputs to the residual sharding BEFORE the add (helps
    # SPMD place the TP partial-sum reduction next to the slice); decode
    # defers the reduction instead (PERF#4: the early pin cost ~0.3 ms on
    # the O(1)-state long_500k cells)
    pin = (lambda t: t) if mode == "decode" else (lambda t: act(t, "hidden"))
    x = x + pin(y)
    if cfg.family != "ssm":
        h2 = rms_norm(x, p["ln2"])
        if cfg.is_moe_layer(i):
            y2, aux = moe_layer(p["moe"], cfg, h2)
        else:
            y2 = mlp(p["mlp"], cfg, h2)
        x = x + pin(y2)
    return act(x, "hidden"), new_cache, aux


def apply_stack(stack, cfg: ModelConfig, x, positions, mode: str,
                caches=None, pos=None):
    """Scan the full periods, then apply the tail layers unstacked.
    caches: {"scan": per-position stacked pytrees, "tail": per-layer list}.
    Returns (x, new_caches_or_None, total_aux)."""
    scan_params, tail_params = stack["scan"], stack["tail"]
    scan_caches = caches["scan"] if caches is not None else None
    tail_caches = caches["tail"] if caches is not None else None

    def one_block(params_j, j, xc, cache_j):
        return apply_block(params_j, cfg, j, xc, positions, mode,
                           cache=cache_j, pos=pos)

    if cfg.remat:
        # per-block remat INSIDE the period scan: the period backward then
        # keeps at most one block's internals live (a period can hold 8
        # heterogeneous layers — jamba), while the scan saves only the
        # period-boundary carry.
        one_block = jax.checkpoint(
            one_block, policy=jax.checkpoint_policies.nothing_saveable,
            static_argnums=(1,))

    def period_body(carry, xs):
        xc, auxc = carry
        params_j, caches_j = xs
        new_caches_j = []
        for j in range(cfg.period):
            cj = caches_j[j] if caches_j is not None else None
            xc, nc, aux = one_block(params_j[j], j, xc, cj)
            new_caches_j.append(nc)
            auxc = auxc + aux
        ys = new_caches_j if mode != "train" else None
        return (xc, auxc), ys

    body = period_body
    aux0 = jnp.zeros((), jnp.float32)
    if cfg.num_periods > 0 and cfg.scan_layers:
        (x, aux), ys = jax.lax.scan(body, (x, aux0),
                                    (scan_params, scan_caches))
    elif cfg.num_periods > 0:
        aux = aux0
        ys_list = []
        for r in range(cfg.num_periods):
            params_r = jax.tree.map(lambda a: a[r], scan_params)
            caches_r = jax.tree.map(lambda a: a[r], scan_caches) \
                if scan_caches is not None else None
            (x, aux), ys_r = body((x, aux), (params_r, caches_r))
            ys_list.append(ys_r)
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys_list) \
            if ys_list and ys_list[0] is not None else None
    else:
        aux, ys = aux0, None

    # ---- tail layers (remainder of an incomplete final period)
    new_tail = []
    for j, pj in enumerate(tail_params):
        cj = tail_caches[j] if tail_caches is not None else None
        blk = functools.partial(apply_block, pj, cfg, j, mode=mode, pos=pos)
        if cfg.remat:
            blk = jax.checkpoint(
                blk, policy=jax.checkpoint_policies.nothing_saveable,
                static_argnums=())
        x, nc, auxj = blk(x, positions, cache=cj)
        aux = aux + auxj
        new_tail.append(nc)
    if mode == "train":
        return x, None, aux
    return x, {"scan": ys, "tail": new_tail}, aux
