"""Mamba-1 selective SSM block (falcon-mamba / jamba mixers).

TPU adaptation: the CUDA "selective scan" kernel becomes a **chunked
associative scan** — the sequence is cut into ``mamba_chunk`` pieces; inside
a chunk the diagonal recurrence h_t = a_t·h_{t-1} + b_t runs as
``lax.associative_scan`` (log-depth, VPU-friendly), and a tiny sequential
``lax.scan`` carries the state across chunks. This bounds the live
intermediate to [B, chunk, d_inner, d_state] instead of the full sequence —
the same blocking idea the paper applies to episode state (fit the working
set in fast memory, carry a small boundary state).

Decode is O(1): one recurrence step on the carried state.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .sharding import act


def init_mamba(key, cfg: ModelConfig, dtype) -> dict:
    d, din, st = cfg.d_model, cfg.d_inner, cfg.ssm_state
    ks = jax.random.split(key, 7)
    p = {
        "in_proj": jax.random.normal(ks[0], (d, 2 * din), dtype) * d ** -0.5,
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, din), dtype) * 0.2,
        "conv_b": jnp.zeros((din,), dtype),
        "x_proj": jax.random.normal(ks[2], (din, 2 * st + 1), dtype)
        * din ** -0.5,
        "dt_bias": jnp.zeros((din,), jnp.float32) + 0.5,
        "a_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, st + 1, dtype=jnp.float32), (din, st))),
        "d_skip": jnp.ones((din,), jnp.float32),
        "out_proj": jax.random.normal(ks[3], (din, d), dtype) * din ** -0.5,
    }
    return p


def _ssm_inputs(p, cfg: ModelConfig, xz):
    """Shared front: conv + projections. xz [B, L, 2*din] from in_proj.
    Returns (x [B,L,din] post-conv/silu, z, delta, bmat, cmat)."""
    din, st = cfg.d_inner, cfg.ssm_state
    x, z = jnp.split(xz, 2, axis=-1)
    # causal depthwise conv over L
    pad = cfg.ssm_conv - 1
    xp = jnp.pad(x, ((0, 0), (pad, 0), (0, 0)))
    x = sum(xp[:, i: i + x.shape[1]] * p["conv_w"][i]
            for i in range(cfg.ssm_conv)) + p["conv_b"]
    x = jax.nn.silu(x)
    proj = x @ p["x_proj"]                                   # [B, L, 2st+1]
    dt = jax.nn.softplus(proj[..., 0:1].astype(jnp.float32)
                         + p["dt_bias"])                     # [B, L, din]
    bmat = proj[..., 1: 1 + st].astype(jnp.float32)          # [B, L, st]
    cmat = proj[..., 1 + st:].astype(jnp.float32)            # [B, L, st]
    return x, z, dt, bmat, cmat


def _scan_chunked(a, b, h0, chunk: int):
    """h_t = a_t * h_{t-1} + b_t over axis 1, chunked associative scan.
    a/b [B, L, din, st]; h0 [B, din, st]. Returns (h_all [B,L,din,st], h_L).
    """
    bsz, seq, din, st = a.shape
    nc = seq // chunk
    assert seq % chunk == 0, f"L={seq} % chunk={chunk} != 0"
    ar = a.reshape(bsz, nc, chunk, din, st)
    br = b.reshape(bsz, nc, chunk, din, st)

    def combine(x, y):
        ax, bx = x
        ay, by = y
        return ax * ay, ay * bx + by

    def chunk_step(h, ab):
        ac, bc = ab  # [B, chunk, din, st] (scanned over nc)
        pa, pb = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        h_all = pa * h[:, None] + pb
        return h_all[:, -1], h_all

    hL, h_states = jax.lax.scan(chunk_step, h0,
                                (jnp.moveaxis(ar, 1, 0),
                                 jnp.moveaxis(br, 1, 0)))
    h_states = jnp.moveaxis(h_states, 0, 1).reshape(bsz, seq, din, st)
    return h_states, hL


def mamba_layer(p, cfg: ModelConfig, x_in, h0=None, conv_state=None):
    """Full-sequence mixer. x_in [B, L, D] → (y [B, L, D], (h_L, conv_tail)).

    The returned state makes prefill → decode handoff possible."""
    bsz, seq, _ = x_in.shape
    din, st = cfg.d_inner, cfg.ssm_state
    xz = x_in @ p["in_proj"]
    x, z, dt, bmat, cmat = _ssm_inputs(p, cfg, xz)
    a = -jnp.exp(p["a_log"])                                  # [din, st]
    abar = act(jnp.exp(dt[..., None] * a), "mamba_state")     # [B,L,din,st]
    bbar = act(dt[..., None] * bmat[..., None, :]
               * x.astype(jnp.float32)[..., None], "mamba_state")
    if h0 is None:
        h0 = jnp.zeros((bsz, din, st), jnp.float32)
    h_states, hL = _scan_chunked(abar, bbar, h0, min(cfg.mamba_chunk, seq))
    y = jnp.einsum("blds,bls->bld", h_states, cmat)
    y = y + x.astype(jnp.float32) * p["d_skip"]
    y = (y.astype(x_in.dtype)) * jax.nn.silu(z)
    out = y @ p["out_proj"]
    conv_tail = xz[:, -(cfg.ssm_conv - 1):, :din] if cfg.ssm_conv > 1 \
        else None
    return out, (hL, conv_tail)


def mamba_decode(p, cfg: ModelConfig, x_in, h, conv_state):
    """One-token step. x_in [B, 1, D]; h [B, din, st];
    conv_state [B, ssm_conv-1, din] (raw in_proj x history).
    Returns (y [B,1,D], h', conv_state')."""
    din, st = cfg.d_inner, cfg.ssm_state
    xz = x_in @ p["in_proj"]                                  # [B, 1, 2din]
    x_raw = xz[..., :din]
    z = xz[..., din:]
    window = jnp.concatenate([conv_state, x_raw], axis=1)     # [B, conv, din]
    x = (window * p["conv_w"]).sum(axis=1, keepdims=True) + p["conv_b"]
    x = jax.nn.silu(x)
    proj = x @ p["x_proj"]
    dt = jax.nn.softplus(proj[..., 0:1].astype(jnp.float32) + p["dt_bias"])
    bmat = proj[..., 1: 1 + st].astype(jnp.float32)
    cmat = proj[..., 1 + st:].astype(jnp.float32)
    a = -jnp.exp(p["a_log"])
    abar = jnp.exp(dt[:, 0, :, None] * a)                     # [B, din, st]
    bbar = dt[:, 0, :, None] * bmat[:, 0, None, :] \
        * x.astype(jnp.float32)[:, 0, :, None]
    h = abar * h + bbar
    y = jnp.einsum("bds,bs->bd", h, cmat[:, 0])[:, None, :]
    y = y + x.astype(jnp.float32) * p["d_skip"]
    y = y.astype(x_in.dtype) * jax.nn.silu(z)
    out = y @ p["out_proj"]
    new_conv = jnp.concatenate([conv_state[:, 1:], x_raw], axis=1)
    return out, h, new_conv
