"""Deterministic fault injection for the wire transport.

The recovery guarantees of the networked mining service (service/wire.py,
service/client.py, service/daemon.py) are only worth stating if they are
*proved* under faults — and proofs need reproducible faults. Everything
here is driven by a seeded ``numpy`` generator: the same ``FaultSpec``
produces the same drop/duplicate/truncate/delay decisions on every run,
so a test that recovers bit-exactly under seed 7 recovers bit-exactly
under seed 7 forever.

Two fault families:

* **frame faults** (``FaultInjector``) — applied on the client's send
  path, before bytes reach the socket. ``drop`` swallows a frame (the
  server never sees it; the client's reply deadline fires and it
  reconnects + resyncs), ``duplicate`` sends it twice (the server's
  per-session sequence numbers must dedup the replay), ``truncate``
  sends a prefix and then severs the connection (the server sees a torn
  frame or EOF mid-header and must fail clean), ``delay`` sleeps before
  sending (exercises reply deadlines without killing the link).

* **process faults** (``kill_point``) — a deterministic choice of how
  many window commits the server survives before ``SIGKILL``-ing itself
  (``WireServer(crash_after_commits=...)``). Randomized-but-seeded kill
  points are how the crash-recovery tests sweep window boundaries
  without flaking.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Per-frame fault probabilities (independent draws, in the order
    drop → truncate → duplicate → delay) plus the seed that makes the
    whole sequence reproducible. ``max_faults`` caps total injections so
    a high-probability spec cannot livelock a bounded-deadline run."""

    seed: int = 0
    drop: float = 0.0
    duplicate: float = 0.0
    truncate: float = 0.0
    delay: float = 0.0
    delay_s: float = 0.01
    max_faults: int | None = None

    @property
    def active(self) -> bool:
        return any(p > 0 for p in
                   (self.drop, self.duplicate, self.truncate, self.delay))


class FaultInjector:
    """Turns one outgoing frame into zero, one, or two frames (plus an
    optional pre-send sleep and an optional connection cut).

    ``plan(frame)`` returns ``(chunks, cut)``: the byte strings to send
    in order, and whether to sever the connection afterwards. The caller
    owns the socket; the injector only decides. Decisions and counts are
    recorded in ``injected`` for assertions and load-gen summaries."""

    def __init__(self, spec: FaultSpec):
        self.spec = spec
        self.rng = np.random.default_rng(spec.seed)
        self.frames = 0
        self.injected: dict[str, int] = {
            "drop": 0, "duplicate": 0, "truncate": 0, "delay": 0}

    @property
    def total_injected(self) -> int:
        return sum(self.injected.values())

    def _budget_left(self) -> bool:
        return (self.spec.max_faults is None
                or self.total_injected < self.spec.max_faults)

    def plan(self, frame: bytes) -> tuple[list[bytes], bool]:
        """(chunks to send, sever-connection-after). Draws are made in a
        fixed order on every frame so the decision stream depends only on
        (seed, frame index), never on which probabilities are zero."""
        self.frames += 1
        s = self.spec
        # one draw per fault family per frame keeps the stream aligned
        # across specs that differ only in probabilities
        r_drop, r_trunc, r_dup, r_delay, r_frac = self.rng.random(5)
        if not self._budget_left():
            return [frame], False
        if r_delay < s.delay:
            self.injected["delay"] += 1
            time.sleep(s.delay_s)
        if r_drop < s.drop:
            self.injected["drop"] += 1
            return [], False
        if r_trunc < s.truncate and len(frame) > 1:
            self.injected["truncate"] += 1
            cut_at = 1 + int(r_frac * (len(frame) - 1))
            return [frame[:cut_at]], True
        if r_dup < s.duplicate:
            self.injected["duplicate"] += 1
            return [frame, frame], False
        return [frame], False


def kill_point(seed: int, lo: int, hi: int) -> int:
    """Deterministic randomized crash point: the number of window commits
    the server should survive before SIGKILL, drawn uniformly from
    [lo, hi). The crash-recovery tests sweep seeds, not points — every
    seed is a different window boundary, and every run of the same seed
    is the same boundary."""
    if hi <= lo:
        raise ValueError(f"empty kill window [{lo}, {hi})")
    return int(np.random.default_rng(seed).integers(lo, hi))
