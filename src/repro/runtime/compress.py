"""Error-feedback int8 gradient compression for the cross-pod all-reduce.

The ``pod`` axis crosses the slow inter-pod links; compressing the gradient
all-reduce there is the classic distributed-optimization trick (1-bit
Adam / error-feedback SGD lineage). We use per-tensor-scaled int8 with an
error-feedback residual so compression noise is unbiased over time:

    q = round(g / s);  residual' = g - q·s;  allreduce(q)·s / n_pods

``compressed_psum_ef`` is written for shard_map over the pod axis; the
compression wrapper is exercised numerically in tests (error feedback →
convergence-preserving) and its collective-bytes saving shows up in the
§Perf log of the multi-pod train cells."""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def quantize(g, scale=None):
    """g → (int8 q, f32 scale). Symmetric per-tensor scaling."""
    amax = jnp.max(jnp.abs(g)) if scale is None else scale
    s = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / s), -127, 127).astype(jnp.int8)
    return q, s


def dequantize(q, s):
    return q.astype(jnp.float32) * s


def compressed_psum_ef(grads: Any, residual: Any, axis_name: str):
    """Error-feedback int8 psum over ``axis_name`` (inside shard_map).

    Scale agreement FIRST (pmax of local amax — per-device scales cannot be
    summed), then quantize, int8-wire psum, dequantize once. The residual
    carries each device's quantization error into the next step, making the
    compression noise unbiased over time. Returns (sum_tree, residual')."""
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        amax = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis_name)
        s = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / s), -127, 127).astype(jnp.int8)
        new_r = gf - q.astype(jnp.float32) * s
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return total.astype(jnp.float32) * s, new_r

    flat = jax.tree.map(one, grads, residual,
                        is_leaf=lambda x: hasattr(x, "dtype"))
    out = jax.tree.map(lambda t: t[0], flat,
                       is_leaf=lambda t: isinstance(t, tuple))
    r = jax.tree.map(lambda t: t[1], flat,
                     is_leaf=lambda t: isinstance(t, tuple))
    return out, r


def zero_residual(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
