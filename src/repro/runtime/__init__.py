from .compress import (compressed_psum_ef, dequantize, quantize,
                       zero_residual)
from .faultinject import FaultInjector, FaultSpec, kill_point
from .ft import (ElasticRuntime, StepFailure, StepWatchdog, WatchdogConfig,
                 plan_elastic_mesh)

__all__ = ["StepWatchdog", "WatchdogConfig", "StepFailure",
           "ElasticRuntime", "plan_elastic_mesh", "quantize", "dequantize",
           "compressed_psum_ef", "zero_residual",
           "FaultInjector", "FaultSpec", "kill_point"]
