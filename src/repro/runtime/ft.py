"""Fault-tolerance runtime: step watchdog/retry, straggler detection,
elastic re-meshing.

On a real multi-pod deployment the failure signals come from the cluster
manager and jax.distributed heartbeats; the *policies* below are the
framework layer: deterministic retry from the last good state, p99-based
straggler deadlines, and rebuilding the mesh from the live device set at
checkpoint boundaries. They are unit-tested by fault injection
(tests/test_ft.py) — the policies, not the transport, are what this repo
can prove without hardware."""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable

import numpy as np


class StepFailure(RuntimeError):
    pass


@dataclasses.dataclass
class WatchdogConfig:
    window: int = 50           # rolling step-time window
    deadline_factor: float = 3.0   # deadline = p99 × factor
    min_deadline_s: float = 30.0
    max_retries: int = 3


class StepWatchdog:
    """Tracks step times; flags stragglers; retries failed/overdue steps.

    The step callable must be *functionally pure* (state in, state out) —
    exactly what our jitted train_step is — so a retry is safe: the last
    good state is re-presented unchanged."""

    def __init__(self, cfg: WatchdogConfig = WatchdogConfig(),
                 clock: Callable[[], float] = time.perf_counter):
        self.cfg = cfg
        self.clock = clock
        self.times = deque(maxlen=cfg.window)
        self.straggler_steps: list[int] = []
        self.retries = 0

    def deadline(self) -> float:
        if len(self.times) < 5:
            return float("inf")
        p99 = float(np.percentile(np.asarray(self.times), 99))
        return max(p99 * self.cfg.deadline_factor, self.cfg.min_deadline_s)

    def run_step(self, step_idx: int, fn: Callable[[], Any]) -> Any:
        """Run fn with retry-on-exception; record duration; flag stragglers.
        Returns fn's result. Raises StepFailure after max_retries."""
        attempt = 0
        while True:
            t0 = self.clock()
            try:
                out = fn()
                dt = self.clock() - t0
                if dt > self.deadline():
                    self.straggler_steps.append(step_idx)
                self.times.append(dt)
                return out
            except StepFailure:
                raise
            except Exception:
                attempt += 1
                self.retries += 1
                if attempt > self.cfg.max_retries:
                    raise StepFailure(
                        f"step {step_idx} failed {attempt} times")


@dataclasses.dataclass
class ElasticState:
    devices: list
    mesh_shape: tuple
    generation: int = 0


def plan_elastic_mesh(num_devices: int, model_parallel: int,
                      pod_size: int = 256) -> tuple:
    """Mesh shape for the *live* device count: drop to the largest usable
    power-of-two data extent; keep TP fixed (model shards must stay whole).
    Returns (shape, axis_names)."""
    if num_devices % model_parallel:
        num_devices -= num_devices % model_parallel
    data = num_devices // model_parallel
    # largest power of two <= data (keeps batch divisibility simple)
    d = 1
    while d * 2 <= data:
        d *= 2
    if num_devices >= 2 * pod_size:
        pods = num_devices // pod_size
        return ((pods, (d * model_parallel // pod_size // pods) or 1,
                 model_parallel), ("pod", "data", "model"))
    return ((d, model_parallel), ("data", "model"))


class ElasticRuntime:
    """Rebuilds the mesh from the live device set at safe points
    (checkpoint boundaries). ``device_probe`` abstracts the cluster
    manager; tests inject shrinking/growing device lists."""

    def __init__(self, device_probe: Callable[[], list],
                 model_parallel: int):
        self.probe = device_probe
        self.model_parallel = model_parallel
        self.state = ElasticState(devices=list(device_probe()),
                                  mesh_shape=())

    def maybe_remesh(self) -> tuple[bool, ElasticState]:
        live = list(self.probe())
        if len(live) == len(self.state.devices):
            return False, self.state
        shape, axes = plan_elastic_mesh(len(live), self.model_parallel)
        self.state = ElasticState(devices=live, mesh_shape=(shape, axes),
                                  generation=self.state.generation + 1)
        return True, self.state
