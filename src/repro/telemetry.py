"""Model-telemetry → event-stream bridge (the chip-on-chip integration).

The paper's loop is: one chip (MEA) emits spike events, another mines them
in real time. A training/serving pod is itself a spiking system: MoE
routers fire discrete (layer, expert) events per token. This module turns
those routing decisions into ``EventStream``s in the miner's tick domain,
so the SAME two-pass engine that mines cortical cultures mines expert
co-activation cascades ("which expert sequences fire together, in order,
within k tokens") — used by examples/chip_on_chip.py.
"""

from __future__ import annotations

import numpy as np

from repro.core.events import EventStream


def routing_events(topk_indices: np.ndarray, num_experts: int,
                   layers: list[int] | None = None,
                   ticks_per_token: int = 1) -> EventStream:
    """Encode expert-routing decisions as an event stream.

    Args:
      topk_indices: i32[L, T, K] — per layer, per token, the top-k expert
        ids chosen by the router (batch already flattened into T).
      num_experts: router width E.
      layers: which layers to encode (default: all).
      ticks_per_token: time distance between consecutive tokens.

    Event alphabet: type = layer_pos * E + expert_id; time = token index.
    Simultaneous events (same token, k experts, several layers) are exactly
    the tie case the engine's inclusive-lower A2 handles (DESIGN.md §2).
    """
    l, t, k = topk_indices.shape
    layers = list(range(l)) if layers is None else layers
    pairs = []
    for li, layer in enumerate(layers):
        for tok in range(t):
            for j in range(k):
                e = int(topk_indices[layer, tok, j])
                pairs.append((li * num_experts + e,
                              (tok + 1) * ticks_per_token))
    return EventStream.from_pairs(pairs, num_types=len(layers) * num_experts)


def decode_expert_episode(etype: int, num_experts: int) -> tuple[int, int]:
    """type → (layer_pos, expert_id)."""
    return etype // num_experts, etype % num_experts
