"""Model-telemetry → event-stream bridge (the chip-on-chip integration).

The paper's loop is: one chip (MEA) emits spike events, another mines them
in real time. A training/serving pod is itself a spiking system: MoE
routers fire discrete (layer, expert) events per token. This module turns
those routing decisions into ``EventStream``s in the miner's tick domain,
so the SAME two-pass engine that mines cortical cultures mines expert
co-activation cascades ("which expert sequences fire together, in order,
within k tokens") — used by examples/chip_on_chip.py.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.events import EventStream
from repro.obs.registry import REGISTRY


class ThroughputMeter:
    """Sustained events/sec accounting for the streaming loop.

    The chip-on-chip constraint is *sustained* throughput — the miner keeps
    up with the MEA only if events/sec over the whole session stays above
    the acquisition rate, not just within one warm window. Wrap each
    window's processing in ``start()``/``stop(n_events)``; ``summary()``
    reports both the sustained rate and the steady-state rate with the
    first (compile-warming) window excluded, plus p50/p99 window-latency
    percentiles (the serving SLO the multi-tenant scheduler watches).
    ``label`` names the meter (one per session in the mining service).

    Every ``stop()`` also feeds the process-global metrics registry
    (``repro.obs``): ``session_events_total{session=<label>}`` and the
    ``window_latency_s{session=<label>}`` histogram — the meter's exact
    rows stay authoritative for ``summary()``; the registry series are
    the exported/health-snapshot view of the same measurements.
    """

    def __init__(self, label: str | None = None):
        self.label = label
        self.rows: list[tuple[int, float]] = []  # (n_events, seconds)
        self.spans: list[tuple[float, float]] = []  # absolute (start, stop)
        self._t0: float | None = None

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self, n_events: int) -> float:
        if self._t0 is None:
            raise RuntimeError("stop() without start()")
        t1 = time.perf_counter()
        dt = t1 - self._t0
        self.spans.append((self._t0, t1))
        self._t0 = None
        self.rows.append((int(n_events), dt))
        session = self.label if self.label is not None else "_unlabeled"
        REGISTRY.counter("session_events_total",
                         session=session).inc(int(n_events))
        REGISTRY.counter("session_windows_total", session=session).inc()
        REGISTRY.histogram("window_latency_s", session=session).observe(dt)
        return dt

    def mark(self) -> int:
        """Opaque rewind point (row count) for ``truncate``. Take one
        before speculative work — e.g. the scheduler snapshots a session
        before a step it may retry — and rewind to it on failure."""
        return len(self.rows)

    def truncate(self, mark: int) -> None:
        """Discard every row (and its wall-clock span) recorded after
        ``mark``, un-counting windows a retried step will re-measure.
        The registry series are monotone by design and keep the
        discarded measurements; the meter's rows stay authoritative for
        ``summary()``."""
        del self.rows[mark:]
        del self.spans[mark:]

    def abort(self) -> None:
        """Drop an open ``start()`` without recording a row — the
        in-flight window died (step failure) and its partial time must
        not leak into the next measurement. Safe when no start is open."""
        self._t0 = None

    @property
    def events(self) -> int:
        return sum(n for n, _ in self.rows)

    @property
    def seconds(self) -> float:
        return sum(dt for _, dt in self.rows)

    @property
    def events_per_sec(self) -> float:
        return self.events / self.seconds if self.seconds > 0 else 0.0

    def latency_percentiles(self, qs=(50, 99)) -> dict[str, float]:
        """Window-latency percentiles in seconds, keyed ``p50``/``p99``/…"""
        if not self.rows:
            return {f"p{q}": 0.0 for q in qs}
        lat = np.asarray([dt for _, dt in self.rows])
        return {f"p{q}": float(np.percentile(lat, q)) for q in qs}

    def summary(self) -> dict:
        warm = self.rows[1:] if len(self.rows) > 1 else self.rows
        warm_ev = sum(n for n, _ in warm)
        warm_s = sum(dt for _, dt in warm)
        out = {
            "windows": len(self.rows),
            "events": self.events,
            "seconds": self.seconds,
            "events_per_sec": self.events_per_sec,
            "steady_events_per_sec": warm_ev / warm_s if warm_s > 0 else 0.0,
        }
        if self.label is not None:
            out["label"] = self.label
        for k, v in self.latency_percentiles().items():
            out[f"{k}_latency_s"] = v
        return out


class MeterBank:
    """Labeled per-session meters plus a cross-session aggregate.

    ``meter(label)`` returns (creating on first use) the session's own
    ``ThroughputMeter``. Per-session summaries use the session's *observed*
    step times — in batched serving that includes barrier/co-tenant wait,
    which is exactly the latency a tenant experiences. The aggregate's
    ``events_per_sec`` is instead computed over the *wall-clock union span*
    of all measurements: concurrent sessions overlap in time, so dividing
    fleet events by summed per-session busy seconds would under-report the
    fleet rate by ~the session count. (Falls back to busy-seconds when no
    absolute spans were recorded, e.g. hand-filled rows.)"""

    def __init__(self):
        self.meters: dict[str, ThroughputMeter] = {}

    def meter(self, label: str) -> ThroughputMeter:
        m = self.meters.get(label)
        if m is None:
            m = self.meters[label] = ThroughputMeter(label=label)
        return m

    def aggregate(self) -> ThroughputMeter:
        agg = ThroughputMeter(label="aggregate")
        for m in self.meters.values():
            agg.rows.extend(m.rows)
            agg.spans.extend(m.spans)
        return agg

    def summary(self) -> dict:
        agg = self.aggregate()
        out = agg.summary()
        if agg.spans:
            wall = (max(t1 for _, t1 in agg.spans)
                    - min(t0 for t0, _ in agg.spans))
            out["wall_seconds"] = wall
            out["events_per_sec"] = agg.events / wall if wall > 0 else 0.0
        return {
            "sessions": {label: m.summary()
                         for label, m in sorted(self.meters.items())},
            "aggregate": out,
        }


def routing_events(topk_indices: np.ndarray, num_experts: int,
                   layers: list[int] | None = None,
                   ticks_per_token: int = 1) -> EventStream:
    """Encode expert-routing decisions as an event stream.

    Args:
      topk_indices: i32[L, T, K] — per layer, per token, the top-k expert
        ids chosen by the router (batch already flattened into T).
      num_experts: router width E.
      layers: which layers to encode (default: all).
      ticks_per_token: time distance between consecutive tokens.

    Event alphabet: type = layer_pos * E + expert_id; time = token index.
    Simultaneous events (same token, k experts, several layers) are exactly
    the tie case the engine's inclusive-lower A2 handles (DESIGN.md §2).
    """
    nl, t, k = topk_indices.shape
    layers = list(range(nl)) if layers is None else layers
    pairs = []
    for li, layer in enumerate(layers):
        for tok in range(t):
            for j in range(k):
                e = int(topk_indices[layer, tok, j])
                pairs.append((li * num_experts + e,
                              (tok + 1) * ticks_per_token))
    return EventStream.from_pairs(pairs, num_types=len(layers) * num_experts)


def decode_expert_episode(etype: int, num_experts: int) -> tuple[int, int]:
    """type → (layer_pos, expert_id)."""
    return etype // num_experts, etype % num_experts
