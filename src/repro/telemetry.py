"""Model-telemetry → event-stream bridge (the chip-on-chip integration).

The paper's loop is: one chip (MEA) emits spike events, another mines them
in real time. A training/serving pod is itself a spiking system: MoE
routers fire discrete (layer, expert) events per token. This module turns
those routing decisions into ``EventStream``s in the miner's tick domain,
so the SAME two-pass engine that mines cortical cultures mines expert
co-activation cascades ("which expert sequences fire together, in order,
within k tokens") — used by examples/chip_on_chip.py.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.events import EventStream


class ThroughputMeter:
    """Sustained events/sec accounting for the streaming loop.

    The chip-on-chip constraint is *sustained* throughput — the miner keeps
    up with the MEA only if events/sec over the whole session stays above
    the acquisition rate, not just within one warm window. Wrap each
    window's processing in ``start()``/``stop(n_events)``; ``summary()``
    reports both the sustained rate and the steady-state rate with the
    first (compile-warming) window excluded.
    """

    def __init__(self):
        self.rows: list[tuple[int, float]] = []  # (n_events, seconds)
        self._t0: float | None = None

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self, n_events: int) -> float:
        if self._t0 is None:
            raise RuntimeError("stop() without start()")
        dt = time.perf_counter() - self._t0
        self._t0 = None
        self.rows.append((int(n_events), dt))
        return dt

    @property
    def events(self) -> int:
        return sum(n for n, _ in self.rows)

    @property
    def seconds(self) -> float:
        return sum(dt for _, dt in self.rows)

    @property
    def events_per_sec(self) -> float:
        return self.events / self.seconds if self.seconds > 0 else 0.0

    def summary(self) -> dict:
        warm = self.rows[1:] if len(self.rows) > 1 else self.rows
        warm_ev = sum(n for n, _ in warm)
        warm_s = sum(dt for _, dt in warm)
        return {
            "windows": len(self.rows),
            "events": self.events,
            "seconds": self.seconds,
            "events_per_sec": self.events_per_sec,
            "steady_events_per_sec": warm_ev / warm_s if warm_s > 0 else 0.0,
        }


def routing_events(topk_indices: np.ndarray, num_experts: int,
                   layers: list[int] | None = None,
                   ticks_per_token: int = 1) -> EventStream:
    """Encode expert-routing decisions as an event stream.

    Args:
      topk_indices: i32[L, T, K] — per layer, per token, the top-k expert
        ids chosen by the router (batch already flattened into T).
      num_experts: router width E.
      layers: which layers to encode (default: all).
      ticks_per_token: time distance between consecutive tokens.

    Event alphabet: type = layer_pos * E + expert_id; time = token index.
    Simultaneous events (same token, k experts, several layers) are exactly
    the tie case the engine's inclusive-lower A2 handles (DESIGN.md §2).
    """
    l, t, k = topk_indices.shape
    layers = list(range(l)) if layers is None else layers
    pairs = []
    for li, layer in enumerate(layers):
        for tok in range(t):
            for j in range(k):
                e = int(topk_indices[layer, tok, j])
                pairs.append((li * num_experts + e,
                              (tok + 1) * ticks_per_token))
    return EventStream.from_pairs(pairs, num_types=len(layers) * num_experts)


def decode_expert_episode(etype: int, num_experts: int) -> tuple[int, int]:
    """type → (layer_pos, expert_id)."""
    return etype // num_experts, etype % num_experts
