import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver.

For every (architecture × input shape × mesh) cell:
``jit(step).lower(**ShapeDtypeStructs).compile()`` on the production mesh —
proving the distribution config is coherent without hardware — then record
``memory_analysis()`` (fits-in-HBM evidence), ``cost_analysis()``, and the
loop-corrected HLO summary (collective bytes, dot FLOPs, traffic proxy) into
one JSON per cell for §Dry-run / §Roofline of EXPERIMENTS.md.

The XLA_FLAGS line above MUST precede any jax import (jax locks the device
count at first init); nothing else in the repo sets it globally.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3_405b \
      --shape train_4k --mesh both --out experiments/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all [--force]
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402


from repro.configs import ARCHS, canonical, get_config  # noqa: E402
from repro.launch import hlo_analysis  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.shapes import SHAPES, cell_is_runnable  # noqa: E402
from repro.launch.steps import build_cell  # noqa: E402

MESHES = {"single": False, "multi": True}


def _mem_dict(mem) -> dict:
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    out["per_device_total_bytes"] = (
        out.get("argument_size_in_bytes", 0)
        + out.get("temp_size_in_bytes", 0)
        + out.get("output_size_in_bytes", 0)
        - out.get("alias_size_in_bytes", 0))
    return out


def run_cell(arch: str, shape: str, mesh_name: str, out_dir: Path,
             force: bool = False, keep_hlo: bool = False) -> dict:
    arch = canonical(arch)
    out_path = out_dir / f"{arch}__{shape}__{mesh_name}.json"
    if out_path.exists() and not force:
        rec = json.loads(out_path.read_text())
        if rec.get("status") in ("ok", "skip"):
            print(f"[cached] {arch} × {shape} × {mesh_name}: "
                  f"{rec['status']}")
            return rec
    runnable, why = cell_is_runnable(arch, shape)
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
           "timestamp": time.strftime("%Y-%m-%d %H:%M:%S")}
    if not runnable:
        rec.update(status="skip", reason=why)
        out_path.write_text(json.dumps(rec, indent=1))
        print(f"[skip]   {arch} × {shape} × {mesh_name}: {why}")
        return rec
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=MESHES[mesh_name])
    chips = mesh.devices.size
    try:
        t0 = time.perf_counter()
        with mesh:
            jfn, sds = build_cell(cfg, mesh, shape)
            lowered = jfn.lower(*sds)
            t_lower = time.perf_counter() - t0
            t0 = time.perf_counter()
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        text = compiled.as_text()
        hs = hlo_analysis.analyze(text)
        cell = SHAPES[shape]
        rec.update(
            status="ok",
            chips=int(chips),
            seconds={"lower": round(t_lower, 2),
                     "compile": round(t_compile, 2)},
            memory=_mem_dict(mem),
            cost={k: float(v) for k, v in cost.items()
                  if isinstance(v, (int, float))},
            hlo=hs.to_json(),
            tokens=cell.global_batch * (cell.seq_len
                                        if cell.kind != "decode" else 1),
            model={"params": cfg.num_params(),
                   "active_params": cfg.num_active_params_per_token(),
                   "seq_len": cell.seq_len,
                   "global_batch": cell.global_batch,
                   "kind": cell.kind},
        )
        print(f"[ok]     {arch} × {shape} × {mesh_name}: "
              f"{rec['memory']['per_device_total_bytes']/2**30:.2f} GiB/dev,"
              f" lower {t_lower:.0f}s compile {t_compile:.0f}s")
        if keep_hlo:
            (out_dir / f"{arch}__{shape}__{mesh_name}.hlo.txt"
             ).write_text(text)
    except Exception as e:  # a failing cell is a bug — record it loudly
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-4000:])
        print(f"[ERROR]  {arch} × {shape} × {mesh_name}: {e}")
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    archs = ARCHS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_err = 0
    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
                rec = run_cell(arch, shape, mesh_name, out_dir,
                               force=args.force, keep_hlo=args.keep_hlo)
                n_err += rec["status"] == "error"
    if n_err:
        raise SystemExit(f"{n_err} cell(s) failed")


if __name__ == "__main__":
    main()
