"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import; everything else (tests, benches) sees the real single device.

Mesh shapes: single pod = (16, 16) over ("data", "model") — 256 v5e chips;
multi-pod = (2, 16, 16) over ("pod", "data", "model") — 512 chips, the
``pod`` axis crossing the (slow) inter-pod links. Parameters FSDP-shard over
("pod","data") and TP over "model" (models/sharding.py)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = jax.device_count()
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))


def make_stream_mesh(num_devices: int | None = None):
    """1-D ``("data",)`` mesh for the sharded streaming/MapConcatenate
    paths: one contiguous segment group per device, power-of-two device
    count (segment counts are powers of two — a ragged mesh would idle
    devices). Delegates to ``core.mapconcat.data_mesh`` so launchers and
    the counting engines agree on the device set."""
    from repro.core.mapconcat import data_mesh
    return data_mesh(num_devices)
