"""Chip-on-chip mining driver — the paper's own workload as a launcher.

Streams partition windows of a spike train (recorded or synthetic MEA
data) through the two-pass mining engine, printing per-window frequent
episodes in (near) real time — the paper's §6.5 "mining evolving neuronal
circuits" loop. Distribution uses the MapConcatenate segment axis; on a
multi-device host pass --distributed to shard_map the Map step.

Usage:
  PYTHONPATH=src python -m repro.launch.mine --seconds 30 --theta 40 \
      --max-level 3 --window-ms 10000
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import mine, mine_partitions
from repro.data import partition_windows, sym26


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--seconds", type=int, default=30)
    ap.add_argument("--theta", type=int, default=40,
                    help="support threshold per window")
    ap.add_argument("--max-level", type=int, default=3)
    ap.add_argument("--window-ms", type=int, default=10_000)
    ap.add_argument("--interval", type=int, nargs=2, default=(5, 10),
                    metavar=("TLO", "THI"))
    ap.add_argument("--engine", default="hybrid",
                    choices=["hybrid", "ptpe", "mapconcatenate"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    stream, truth = sym26(seconds=args.seconds, seed=args.seed)
    print(f"[mine] {len(stream)} events over {args.seconds}s; "
          f"planted: {truth['short'][0]} and {truth['long'][0]} "
          f"with delays {truth['short'][1]}")
    window_theta = max(2, args.theta * args.window_ms
                       // (args.seconds * 1000))
    windows = partition_windows(stream, args.window_ms,
                                overlap_ms=args.interval[1] * args.max_level)
    for widx, res in mine_partitions(windows, [tuple(args.interval)],
                                     window_theta,
                                     max_level=args.max_level,
                                     engine=args.engine):
        t = sum(s.seconds for s in res.stats)
        top = []
        if len(res.frequent) >= args.max_level:
            lv = res.frequent[-1]
            order = np.argsort(-res.counts[-1])[:3]
            top = [(lv.etypes[i].tolist(), int(res.counts[-1][i]))
                   for i in order]
        culls = [f"L{s.level}:{s.num_candidates}→{s.num_survived_a2}"
                 f"→{s.num_frequent}" for s in res.stats[1:]]
        print(f"[mine] window {widx:3d}  {t*1e3:7.1f} ms  "
              f"{'  '.join(culls)}  top: {top}")


if __name__ == "__main__":
    main()
