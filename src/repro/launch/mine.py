"""Chip-on-chip mining driver — the paper's own workload as a launcher.

Streams partition windows of a spike train (recorded or synthetic MEA
data) through the two-pass mining engine, printing per-window frequent
episodes in (near) real time — the paper's §6.5 "mining evolving neuronal
circuits" loop.

Two modes:

* ``--stream`` (default) — the carried-machine streaming engine
  (``core.streaming.StreamingMiner``): counts are exact across window
  boundaries (occurrences spanning a partition cut are counted in the
  window where they complete), windows partition the stream with no
  overlap, and sustained events/sec is reported via
  ``telemetry.ThroughputMeter``. ``--theta-mode cumulative`` applies θ to
  whole-stream counts instead of per-window deltas.
* ``--restart`` — the legacy restart-per-window loop (machines rebuilt at
  every boundary; overlap windows paper over the boundary loss). Kept as
  the baseline the streaming benchmark measures against.

Usage:
  PYTHONPATH=src python -m repro.launch.mine --seconds 30 --theta 40 \
      --max-level 3 --window-ms 10000
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.core import mine_partitions
from repro.core.streaming import StreamingMiner
from repro.data import partition_windows, sym26
from repro.telemetry import ThroughputMeter


def _report(widx, res, max_level):
    t = sum(s.seconds for s in res.stats)
    top = []
    if len(res.frequent) >= max_level:
        lv = res.frequent[-1]
        order = np.argsort(-res.counts[-1])[:3]
        top = [(lv.etypes[i].tolist(), int(res.counts[-1][i]))
               for i in order]
    culls = [f"L{s.level}:{s.num_candidates}→{s.num_survived_a2}"
             f"→{s.num_frequent}" for s in res.stats[1:]]
    print(f"[mine] window {widx:3d}  {t*1e3:7.1f} ms  "
          f"{'  '.join(culls)}  top: {top}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--seconds", type=int, default=30)
    ap.add_argument("--theta", type=int, default=40,
                    help="support threshold (per window, or cumulative "
                         "with --theta-mode cumulative)")
    ap.add_argument("--max-level", type=int, default=3)
    ap.add_argument("--window-ms", type=int, default=10_000)
    ap.add_argument("--interval", type=int, nargs=2, default=(5, 10),
                    metavar=("TLO", "THI"))
    ap.add_argument("--engine", default="hybrid",
                    choices=["hybrid", "ptpe", "mapconcatenate", "mapconcat_kernel",
                             "mapconcat_sharded"])
    ap.add_argument("--seed", type=int, default=0)
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--stream", action="store_true", default=True,
                      help="carried-machine streaming engine (default)")
    mode.add_argument("--restart", dest="stream", action="store_false",
                      help="legacy restart-per-window baseline")
    ap.add_argument("--theta-mode", default="window",
                    choices=["window", "cumulative"],
                    help="apply θ to per-window deltas or cumulative counts")
    args = ap.parse_args()

    stream, truth = sym26(seconds=args.seconds, seed=args.seed)
    print(f"[mine] {len(stream)} events over {args.seconds}s; "
          f"planted: {truth['short'][0]} and {truth['long'][0]} "
          f"with delays {truth['short'][1]}")
    window_theta = max(2, args.theta * args.window_ms
                       // (args.seconds * 1000))

    if not args.stream:
        windows = partition_windows(
            stream, args.window_ms,
            overlap_ms=args.interval[1] * args.max_level)
        for widx, res in mine_partitions(windows, [tuple(args.interval)],
                                         window_theta,
                                         max_level=args.max_level,
                                         engine=args.engine, carry=False):
            _report(widx, res, args.max_level)
        return

    theta = args.theta if args.theta_mode == "cumulative" else window_theta
    miner = StreamingMiner(
        [tuple(args.interval)], theta, max_level=args.max_level,
        mode="cumulative" if args.theta_mode == "cumulative"
        else "per_window", engine=args.engine)
    meter = ThroughputMeter()
    windows = list(partition_windows(stream, args.window_ms))
    for widx, w in enumerate(windows):
        meter.start()
        res = miner.update(w, final=widx == len(windows) - 1)
        meter.stop(len(w))
        _report(widx, res, args.max_level)
    s = meter.summary()
    print(f"[mine] sustained {s['events_per_sec']:,.0f} ev/s over "
          f"{s['windows']} windows ({s['events']} events, "
          f"{s['seconds']*1e3:.1f} ms); steady-state "
          f"{s['steady_events_per_sec']:,.0f} ev/s")


if __name__ == "__main__":
    main()
