"""CLI for the kernel-contract auditor (``repro.analysis``).

Usage::

    python -m repro.launch.audit [--fail-on-violation] \
        [--root src/repro] [--summary experiments/bench/audit_summary.json] \
        [--json] [--skip-trace] [--skip-sentinel] [--budget-mib 15]

Pass 1 (contract linter) and Pass 3 (VMEM budget) always run; they are
pure source/arithmetic and take milliseconds.  Pass 2 (trace audit +
recompilation sentinel) imports jax and the engines — skip it with
``--skip-trace`` for a fast editor hook, or keep the trace audit but
drop the (slower) streaming sentinel with ``--skip-sentinel``.

Exit status: 0 when no active findings (suppressed waivers don't fail
the audit; they are listed in the report), 1 otherwise — CI gates on
this via ``--fail-on-violation``.  Without the flag the exit status is
always 0, so local runs can be wired into non-blocking tooling.
"""

from __future__ import annotations

import argparse
import pathlib
import sys


def build_report(root: pathlib.Path, trace: bool, sentinel: bool,
                 budget_bytes: int | None = None):
    from repro.analysis import vmem
    from repro.analysis.contracts import lint_tree
    from repro.analysis.findings import Report

    report = Report()
    active, waived, summary = lint_tree(root)
    report.extend(active, waived, **summary)

    budget = budget_bytes or vmem.VMEM_BUDGET_BYTES
    try:
        from repro.kernels.ops import MAX_SEG_BRICK_LW
    except ImportError:  # audited tree may predate the policy constant
        MAX_SEG_BRICK_LW = 0
    if MAX_SEG_BRICK_LW:
        vf, vs = vmem.check_vmem(MAX_SEG_BRICK_LW, budget=budget)
        report.extend(vf, **vs)
        # the dispatch-calibration grid must stay inside the same
        # admission envelope, or the fitted policy measures fallbacks
        from repro.core.calibrate import GridSpec
        cf, cs = vmem.check_calibration_grid(
            GridSpec().points(), MAX_SEG_BRICK_LW, budget=budget)
        report.extend(cf, **cs)

    if trace:
        from repro.analysis import tracecheck
        tf, ts = tracecheck.run(sentinel=sentinel)
        report.extend(tf, **ts)

    from repro.kernels.tally import KERNEL_CALLS
    report.summary["kernel_calls"] = dict(KERNEL_CALLS)
    return report


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.launch.audit",
        description="kernel-contract auditor (see repro.analysis)")
    p.add_argument("--root", default="src/repro",
                   help="source root for the contract linter")
    p.add_argument("--fail-on-violation", action="store_true",
                   help="exit 1 when any active finding remains")
    p.add_argument("--summary", default=None, metavar="PATH",
                   help="also write the JSON report to PATH")
    p.add_argument("--json", action="store_true",
                   help="print the JSON report instead of the human one")
    p.add_argument("--skip-trace", action="store_true",
                   help="skip Pass 2 entirely (no jax import)")
    p.add_argument("--skip-sentinel", action="store_true",
                   help="run Pass 2 without the streaming recompile "
                        "sentinel")
    p.add_argument("--budget-mib", type=float, default=None,
                   help="override the VMEM budget (MiB)")
    args = p.parse_args(argv)

    root = pathlib.Path(args.root)
    if not root.is_dir():
        print(f"audit: source root {root} not found", file=sys.stderr)
        return 2
    budget = int(args.budget_mib * 2**20) if args.budget_mib else None
    report = build_report(root, trace=not args.skip_trace,
                          sentinel=not args.skip_sentinel,
                          budget_bytes=budget)

    print(report.to_json() if args.json else report.format())
    if args.summary:
        out = pathlib.Path(args.summary)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(report.to_json() + "\n")
    return 1 if (args.fail_on_violation and not report.ok) else 0


if __name__ == "__main__":
    sys.exit(main())
