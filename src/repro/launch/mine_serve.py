"""Multi-tenant mining service driver — many electrode-array sessions on
shared devices, per-window frequent-episode deltas per session.

Simulates the paper's chip-on-chip loop at fleet scale: N synthetic MEA
streams (different seeds, firing rates, and window sizes) are ingested
through the service's admission/backpressure front, mined concurrently
with cross-session batched scans and bounded per-session memory, and each
tenant's episode deltas are printed as they complete. Per-session results
are bit-identical to a standalone ``StreamingMiner`` (the exactness tests
assert this); the service only changes throughput.

Usage:
  PYTHONPATH=src python -m repro.launch.mine_serve --sessions 4 \
      --seconds 10 --theta 4 --max-level 3

Service mode (``--listen``) skips the in-process demo loop and serves the
fault-tolerant wire protocol instead (see service/wire.py); add
``--daemon`` to detach, then drive it with ``--daemon-status`` /
``--daemon-stop`` or the ``repro.launch.wire_load`` load generator:

  PYTHONPATH=src python -m repro.launch.mine_serve \
      --listen unix:/tmp/fem.sock --daemon --data-dir /tmp/fem-data
  PYTHONPATH=src python -m repro.launch.wire_load \
      --connect unix:/tmp/fem.sock --sessions 4 --seconds 10 --verify
  PYTHONPATH=src python -m repro.launch.mine_serve \
      --daemon-stop --data-dir /tmp/fem-data
"""

from __future__ import annotations

import argparse
import contextlib
import json
import sys

from repro.data import partition_windows, sym26
from repro.obs import REGISTRY, TRACER
from repro.obs.jaxprof import capture_step
from repro.service import (BackpressureError, MiningService, SchedulerPolicy,
                           SessionConfig)


def _print_deltas(svc, max_level, limit=2):
    for sid in list(svc.scheduler.sessions):
        for d in svc.poll(sid):
            top = sorted(d.episodes(level=max_level),
                         key=lambda ec: -ec[1])[:limit]
            tail = " FINAL" if d.final else ""
            print(f"[serve] {sid} window {d.window_idx:3d} "
                  f"({d.n_events:4d} ev) top-L{max_level}: {top}{tail}")


def _service_mode(args) -> int:
    """--listen/--daemon-*: run (or manage) the wire-served daemon."""
    from repro.service.daemon import DaemonConfig, MiningDaemon

    cfg = DaemonConfig(
        address=args.listen or "127.0.0.1:0", data_dir=args.data_dir,
        checkpoint_every=args.checkpoint_every,
        max_sessions=max(args.sessions, 1), queue_depth=args.queue_depth,
        pipeline_depth=args.pipeline_depth,
        batching=not args.no_batching, policy_table=args.policy_table)
    if args.daemon_status:
        doc = MiningDaemon.status(cfg.pidfile_path)
        if doc is None:
            print(f"[serve] no daemon (pidfile {cfg.pidfile_path})")
            return 1
        print(f"[serve] daemon pid {doc['pid']} on {doc['address']} "
              f"(data: {doc['data_dir']})")
        return 0
    if args.daemon_stop:
        ok = MiningDaemon.stop(cfg.pidfile_path)
        print("[serve] daemon stopped." if ok
              else "[serve] daemon did not stop in time.")
        return 0 if ok else 1
    daemon = MiningDaemon(cfg)
    if args.daemon:
        doc = daemon.start_detached()
        print(f"[serve] daemon pid {doc['pid']} on {doc['address']} "
              f"(data: {doc['data_dir']})")
        return 0
    daemon.run()
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sessions", type=int, default=4)
    ap.add_argument("--seconds", type=int, default=10)
    ap.add_argument("--theta", type=int, default=4)
    ap.add_argument("--max-level", type=int, default=3)
    ap.add_argument("--interval", type=int, nargs=2, default=(5, 10),
                    metavar=("TLO", "THI"))
    ap.add_argument("--engine", default="hybrid",
                    choices=["hybrid", "ptpe", "mapconcatenate", "mapconcat_kernel",
                             "mapconcat_sharded"])
    ap.add_argument("--theta-mode", default="window",
                    choices=["window", "cumulative"])
    ap.add_argument("--history-limit", type=int, default=8,
                    help="bounded-memory checkpoint interval (windows)")
    ap.add_argument("--queue-depth", type=int, default=4,
                    help="per-session ingest cap (backpressure beyond)")
    ap.add_argument("--no-batching", action="store_true")
    ap.add_argument("--pipeline-depth", type=int, default=2,
                    help="step staging depth: 2 double-buffers the next "
                         "step's host prepare (snapshots, PAD strip, "
                         "histograms) under the current step's device "
                         "work; 1 restores the serial schedule")
    ap.add_argument("--fusion-gate", default="on", choices=["on", "off"],
                    help="gate cross-session fusion on the measured "
                         "cost model (off = always fuse multi-lane "
                         "shape groups)")
    ap.add_argument("--max-concurrent-lanes", type=int, default=None,
                    metavar="N",
                    help="concurrent session threads per batched step "
                         "(default: host core count, min 2); extra "
                         "lanes run in later affinity-ordered chunks")
    ap.add_argument("--no-kernel", action="store_true",
                    help="force the XLA-scan engines (default: carried "
                         "Pallas kernels when the dispatch policy allows)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the serving run's span trace as Chrome "
                         "trace-event JSON (load in Perfetto / "
                         "chrome://tracing); PATH.jsonl gets the raw spans")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the final metrics-registry snapshot "
                         "(flat JSON) after the run")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="capture one jax.profiler trace of the serving "
                         "loop into DIR (TensorBoard/Perfetto)")
    ap.add_argument("--listen", default=None, metavar="ADDR",
                    help='serve the wire protocol on "host:port" or '
                         '"unix:/path" instead of the in-process demo '
                         "(foreground unless --daemon)")
    ap.add_argument("--daemon", action="store_true",
                    help="with --listen: detach and run as a daemon "
                         "(pidfile + log under --data-dir)")
    ap.add_argument("--data-dir", default="serve-data", metavar="DIR",
                    help="checkpoint/recovery store for --listen mode")
    ap.add_argument("--checkpoint-every", type=int, default=1,
                    metavar="N",
                    help="checkpoint every N committed windows (1 = "
                         "exact recovery at every window boundary)")
    ap.add_argument("--policy-table", default=None, metavar="PATH",
                    help="install a calibrated dispatch table (see "
                         "repro.launch.calibrate); stale/wrong-device "
                         "tables degrade to the heuristic")
    ap.add_argument("--calibrate", action="store_true",
                    help="run a smoke-grid calibration pass on this "
                         "host first, cache the fitted table per device "
                         "kind under --data-dir, and serve with it (the "
                         "full grid lives in repro.launch.calibrate)")
    ap.add_argument("--daemon-status", action="store_true",
                    help="report the daemon behind --data-dir and exit")
    ap.add_argument("--daemon-stop", action="store_true",
                    help="SIGTERM the daemon behind --data-dir (graceful "
                         "drain + checkpoint) and exit")
    args = ap.parse_args()

    if args.calibrate and not (args.daemon_status or args.daemon_stop):
        # measure + fit on this host, cache per device kind under the
        # service data dir, and serve through the fitted policy
        from repro.core.calibrate import GridSpec, calibrate_and_save
        from repro.launch.calibrate import ROOFLINE_HW
        table, path = calibrate_and_save(
            GridSpec.smoke(), hw=ROOFLINE_HW,
            out_path=args.policy_table,
            data_dir=f"{args.data_dir}/calibration")
        args.policy_table = path
        print(f"[serve] calibrated {sorted(table.coeffs)} on "
              f"{table.device_kind}; table cached at {path}")

    if args.daemon_status or args.daemon_stop or args.listen:
        return _service_mode(args)

    svc = MiningService(
        policy=SchedulerPolicy(max_sessions=max(args.sessions, 1),
                               max_pending_windows=args.queue_depth,
                               pipeline_depth=args.pipeline_depth,
                               fusion_gate=args.fusion_gate == "on",
                               max_concurrent_lanes=args.max_concurrent_lanes,
                               policy_table=args.policy_table),
        batching=not args.no_batching)

    feeds = {}
    for i in range(args.sessions):
        rate = 10.0 + 10.0 * (i % 3)
        window_ms = (1000, 2000, 4000)[i % 3]
        stream, _ = sym26(seconds=args.seconds, rate_hz=rate, seed=i)
        cfg = SessionConfig(
            intervals=(tuple(args.interval),), theta=args.theta,
            theta_mode=("cumulative" if args.theta_mode == "cumulative"
                        else "per_window"),
            max_level=args.max_level, window_ms=window_ms,
            engine=args.engine, history_limit=args.history_limit,
            use_kernel=not args.no_kernel)
        sid = svc.create_session(f"array-{i}", cfg)
        wins = list(partition_windows(stream, window_ms))
        feeds[sid] = [(w, j == len(wins) - 1) for j, w in enumerate(wins)]
        print(f"[serve] admitted {sid}: {len(stream)} events, "
              f"{len(wins)} windows of {window_ms} ms at {rate:.0f} Hz")

    # interleaved ingest: each producer pushes until backpressure, the
    # scheduler pumps, repeat — the real-time loop in miniature
    shed = 0
    prof = (capture_step(args.profile_dir) if args.profile_dir
            else contextlib.nullcontext())
    with prof:
        while any(feeds.values()):
            for sid, wins in feeds.items():
                while wins:
                    w, final = wins[0]
                    try:
                        svc.ingest(sid, w, final=final)
                    except BackpressureError:
                        shed += 1
                        break
                    wins.pop(0)
            svc.pump()
            _print_deltas(svc, args.max_level)

    stats = svc.stats()
    agg = stats["aggregate"]
    print(f"[serve] {args.sessions} sessions: sustained "
          f"{agg['events_per_sec']:,.0f} ev/s aggregate "
          f"({agg['events']} events, {agg['seconds']*1e3:.0f} ms busy); "
          f"p99 window latency "
          f"{agg['p99_latency_s']*1e3:.1f} ms")
    for sid, s in stats["sessions"].items():
        print(f"[serve]   {sid}: {s['events_per_sec']:,.0f} ev/s, "
              f"p50 {s['p50_latency_s']*1e3:.1f} ms, "
              f"p99 {s['p99_latency_s']*1e3:.1f} ms")
    if "batcher" in stats:
        print(f"[serve] batcher fused {stats['batcher']['fused_requests']} "
              f"scans into {stats['batcher']['batches']} device batches "
              f"over {stats['batcher']['flush_groups']} group flushes; "
              f"gate: {stats['batcher']['fusion_gate']}; "
              f"backpressure deferrals: {shed}")
        print(f"[serve] pipeline overlap "
              f"{stats['scheduler']['pipeline_overlap_s']*1e3:.0f} ms of "
              f"next-step staging under device work")
    cal = stats.get("calibration", {})
    if cal.get("decisions"):
        print(f"[serve] dispatch policy: {cal['source']} "
              f"({cal['grid_points']} grid points); "
              f"decisions {cal['decisions']}")
    if stats["kernel"]["fallbacks"] or stats["kernel"]["recompiles"]:
        print(f"[serve] kernel fallbacks: {stats['kernel']['fallbacks']} "
              f"recompiles: {stats['kernel']['recompiles']}")
    if args.trace_out:
        n = TRACER.export_chrome(args.trace_out)
        TRACER.export_jsonl(args.trace_out + ".jsonl")
        print(f"[serve] wrote {n} spans to {args.trace_out} "
              f"(Perfetto/chrome://tracing) and {args.trace_out}.jsonl")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(REGISTRY.snapshot(), f, indent=2, sort_keys=True)
        print(f"[serve] wrote metrics snapshot to {args.metrics_out}")


if __name__ == "__main__":
    sys.exit(main())
