"""One-shot dispatch calibration CLI (ROADMAP "Calibrated dispatch").

Times every available engine over a small (N, M, n, q) grid on the
actual hardware, fits the per-engine cost model (``core.calibrate``)
seeded by the roofline constants this module shares with
``launch/roofline.py``, and caches the fitted table per device kind
under the service data dir — atomically, with a versioned schema that
invalidates on device-kind or code-version change.

Usage:
  PYTHONPATH=src python -m repro.launch.calibrate            # full grid
  PYTHONPATH=src python -m repro.launch.calibrate --smoke    # CI-sized
      [--out PATH] [--data-dir DIR] [--repeats K] [--hlo] [--json-out P]

The cached table is consulted when a process opts in: serving via
``mine_serve --calibrate/--policy-table``, anything via the
``REPRO_POLICY_TABLE`` / ``REPRO_CALIBRATION_DIR`` environment hooks.
``--hlo`` additionally lowers the PTPE scan core for one representative
grid point and records the loop-corrected HLO traffic
(``launch/hlo_analysis``) next to the fit — the measured-bytes
cross-check for the analytic seed.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.core.calibrate import (CalibrationTable, GridSpec,
                                  calibrate_and_save, device_fingerprint)

from .roofline import HBM_BW, ICI_BW, PEAK_FLOPS

# the analytic-seed hardware envelope, shared with the roofline pass so
# the dispatcher's cost model and the dry-run analysis cannot disagree
# about what the hardware is
ROOFLINE_HW = {"peak_flops": PEAK_FLOPS, "hbm_bw": HBM_BW,
               "ici_bw": ICI_BW}


def hlo_traffic_probe(n_episode: int = 3, m: int = 64,
                      n_events: int = 1024, lcap: int = 4) -> dict:
    """Lower the PTPE scan core at one grid point and return the
    loop-corrected HLO traffic/FLOP totals plus the HBM-implied
    seconds — the measured-bytes sanity check for ``analytic_seconds``."""
    import jax
    import jax.numpy as jnp

    from repro.core.count_a1 import _a1_scan_core
    from repro.core.events import TIME_NEG_INF

    from .hlo_analysis import analyze

    et = jnp.zeros((m, n_episode), jnp.int32)
    tlo = jnp.full((m, n_episode - 1), 5, jnp.int32)
    thi = jnp.full((m, n_episode - 1), 10, jnp.int32)
    ev_t = jnp.zeros((n_events,), jnp.int32)
    ev_tt = jnp.arange(n_events, dtype=jnp.int32)
    s = jnp.full((m, n_episode, lcap), TIME_NEG_INF, jnp.int32)
    text = jax.jit(_a1_scan_core).lower(
        et, tlo, thi, ev_t, ev_tt, s, jnp.zeros((m, n_episode), jnp.int32),
        jnp.zeros((m,), jnp.int32), jnp.zeros((m,), jnp.bool_)) \
        .compile().as_text()
    summ = analyze(text)
    return {"point": {"n_episode": n_episode, "m": m,
                      "n_events": n_events, "lcap": lcap},
            "traffic_bytes": summ.traffic_bytes,
            "dot_flops": summ.dot_flops,
            "hbm_implied_s": summ.traffic_bytes / HBM_BW}


def run(spec: GridSpec, *, out_path: str | None, data_dir: str | None,
        hlo: bool = False, quiet: bool = False) -> tuple[CalibrationTable,
                                                         str]:
    def progress(pt):
        if not quiet:
            print(f"[calibrate] {pt['engine']:>18} N={pt['n_episode']} "
                  f"M={pt['m']:<4} n={pt['n_events']:<5} q={pt['q']:<2} "
                  f"-> {pt['seconds']*1e3:8.2f} ms")
    table, path = calibrate_and_save(
        spec, hw=ROOFLINE_HW, out_path=out_path, data_dir=data_dir,
        progress=progress)
    if hlo:
        table.meta["hlo"] = hlo_traffic_probe()
        table.save(path)
    return table, path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Calibrate the dispatch cost model on this host.")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized grid (one warmup + one sample per "
                         "point, short streams)")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="table path (default: per-device-kind cache "
                         "under the service data dir)")
    ap.add_argument("--data-dir", default=None, metavar="DIR",
                    help="calibration cache dir (default: "
                         "$REPRO_CALIBRATION_DIR or "
                         "$REPRO_DATA_DIR/calibration or "
                         "serve-data/calibration)")
    ap.add_argument("--repeats", type=int, default=None,
                    help="timed samples per grid point (first, "
                         "jit-compiling call always discarded)")
    ap.add_argument("--hlo", action="store_true",
                    help="record the loop-corrected HLO traffic of the "
                         "PTPE scan core next to the fit "
                         "(launch/hlo_analysis)")
    ap.add_argument("--json-out", default=None, metavar="PATH",
                    help="also dump the fitted table document here")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    spec = GridSpec.smoke() if args.smoke else GridSpec()
    if args.repeats is not None:
        import dataclasses
        spec = dataclasses.replace(spec, repeats=max(args.repeats, 1))
    print(f"[calibrate] device {device_fingerprint()}, "
          f"{'smoke' if args.smoke else 'full'} grid "
          f"({len(spec.points())} admission points)")
    table, path = run(spec, out_path=args.out, data_dir=args.data_dir,
                      hlo=args.hlo, quiet=args.quiet)
    print(f"[calibrate] fitted {sorted(table.coeffs)} over "
          f"{len(table.grid)} measured points; cached at {path}")
    if "hlo" in table.meta:
        h = table.meta["hlo"]
        print(f"[calibrate] HLO cross-check: {h['traffic_bytes']:.3e} B "
              f"-> {h['hbm_implied_s']*1e6:.1f} us HBM-implied at the "
              f"probe point")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(table.to_doc(), f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
