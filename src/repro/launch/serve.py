"""Batched serving driver: prefill a batch of prompts, then decode with a
shared KV/SSM cache — the serve-side end-to-end example (CPU-scale with
--smoke; shaped for the production mesh on real hardware).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch falcon_mamba_7b \
      --smoke --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import init_params, make_decode_caches


def serve_batch(cfg, *, batch: int, prompt_len: int, gen: int,
                seed: int = 0, mesh=None, greedy: bool = True):
    key = jax.random.PRNGKey(seed)
    params = init_params(key, cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(seed + 1),
                                 (batch, prompt_len), 0, cfg.vocab_size)
    batch_in = {"tokens": prompts}
    if cfg.stub_frontend:
        emb = jax.random.normal(jax.random.PRNGKey(seed + 2),
                                (batch, prompt_len, cfg.d_model)) * 0.02
        batch_in = {"embeddings": emb}

    prefill_fn = jax.jit(make_prefill_step(cfg, mesh))
    decode_fn = jax.jit(make_decode_step(cfg, mesh))

    t0 = time.perf_counter()
    logits, prefill_caches = prefill_fn(params, batch_in)
    t_prefill = time.perf_counter() - t0

    # build decode caches sized prompt+gen and splice the prefill caches in
    max_seq = prompt_len + gen
    caches = make_decode_caches(cfg, batch, max_seq)
    caches = _splice(cfg, caches, prefill_caches, prompt_len)
    pos = jnp.asarray(prompt_len, jnp.int32)
    tok = logits.argmax(-1).astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.perf_counter()
    for _ in range(gen - 1):
        logits, caches, pos = decode_fn(params, tok, caches, pos)
        tok = logits.argmax(-1).astype(jnp.int32)
        out_tokens.append(tok)
    t_decode = time.perf_counter() - t0
    toks = jnp.concatenate(out_tokens, axis=1)
    return toks, {"prefill_s": t_prefill, "decode_s": t_decode,
                  "tok_per_s": batch * (gen - 1) / max(t_decode, 1e-9)}


def _splice(cfg, caches, prefill_caches, prompt_len: int):
    """Copy prefill KV/SSM states into the zero-initialized decode caches.
    Attention: write [0, prompt_len); mamba: take the final (h, conv)."""
    def splice_pos(j, dc, pc, scan_axis):
        if cfg.layer_kind(j) == "attn":
            k, v = pc
            # prefill k/v: [reps?, B, S, Hkv, Dh] → pad the seq dim
            dk, dv = dc

            def put(dst, src):
                pad = [(0, 0)] * src.ndim
                axis = src.ndim - 3
                pad[axis] = (0, dst.shape[axis] - src.shape[axis])
                return jnp.pad(src.astype(dst.dtype), pad)

            return (put(dk, k), put(dv, v))
        h, conv = pc
        dh_, dconv = dc
        if conv is None:
            return (h.astype(dh_.dtype), dconv)
        take = dconv.shape[-2]
        conv_tail = conv[..., -take:, :]
        return (h.astype(dh_.dtype), conv_tail.astype(dconv.dtype))

    out_scan = [splice_pos(j, caches["scan"][j], prefill_caches["scan"][j],
                           True) for j in range(cfg.period)]
    out_tail = [splice_pos(j, caches["tail"][j], prefill_caches["tail"][j],
                           False) for j in range(cfg.tail_layers)]
    return {"scan": out_scan, "tail": out_tail}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    cfg = get_smoke_config(args.arch) if args.smoke \
        else get_config(args.arch)
    mesh = make_host_mesh()
    with mesh:
        toks, stats = serve_batch(cfg, batch=args.batch,
                                  prompt_len=args.prompt_len, gen=args.gen,
                                  mesh=mesh)
    print(f"[serve] generated {toks.shape} tokens; "
          f"prefill {stats['prefill_s']:.2f}s, "
          f"{stats['tok_per_s']:.1f} tok/s decode")


if __name__ == "__main__":
    main()
