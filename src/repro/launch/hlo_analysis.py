"""Loop-aware HLO text analysis for the roofline terms.

``compiled.cost_analysis()`` visits every computation ONCE — `while` bodies
(our scan-over-layers, q-chunk maps, CE-loss chunks) are under-counted by
their trip counts (verified empirically: a 7-iteration scan of a matmul
reports exactly one body's flops). This module parses ``compiled.as_text()``
into a computation call graph, reads each while's
``backend_config={"known_trip_count":{"n":...}}`` (fallback: the comparison
constant in its condition computation), and walks the graph with
multiplicities to produce:

  * collective_bytes — Σ operand bytes over all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute (per-device shard
    sizes, trip-count-scaled);
  * dot_flops — 2·(out numel)·K per dot (trip-count-scaled): loop-corrected
    matmul FLOPs, the dominant compute of every assigned arch;
  * per-collective-kind byte breakdown for the §Perf iteration log.

Unit-tested against jitted modules with known content
(tests/test_hlo_analysis.py)."""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# count plain and -start forms; never -done (operand = the in-flight tuple)
_COLL_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(-start)?\(")
_OPLINE_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_CALLS_RE = re.compile(
    r"(?:condition|body|to_apply)=%([\w\.\-]+)"
    r"|(?:calls|branch_computations)=\{([^}]*)\}"
    r"|calls=%([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\((.*)\)\s*->.*\{$")


def _numel(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _first_shape_bytes(seg: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(seg):
        total += _numel(dims) * _DTYPE_BYTES.get(dt, 4)
    return total


@dataclasses.dataclass
class Op:
    name: str
    body: str                      # full RHS text
    shape: tuple[str, str] | None  # (dtype, dims) of output (first shape)


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    ops: list
    symbols: dict                  # op/param name -> (dtype, dims)
    cond_constant: int | None = None


def parse_hlo(text: str) -> tuple[dict, str | None]:
    comps: dict[str, Computation] = {}
    entry_name = None
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        hdr = _HDR_RE.match(stripped)
        if hdr and stripped.endswith("{"):
            name = hdr.group(2)
            cur = Computation(name=name, is_entry=bool(hdr.group(1)),
                              ops=[], symbols={})
            comps[name] = cur
            if cur.is_entry:
                entry_name = name
            # parameter declarations: "pname: f32[32,64]"
            for pname, dt, dims in re.findall(
                    r"([\w\.\-]+):\s*(\w+)\[([\d,]*)\]", hdr.group(3)):
                cur.symbols[pname] = (dt, dims)
            continue
        if stripped.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _OPLINE_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        shp = _SHAPE_RE.search(rhs)
        cur.symbols[name] = (shp.group(1), shp.group(2)) if shp else None
        cur.ops.append(Op(name=name, body=rhs,
                          shape=cur.symbols[name]))
        cm = re.search(r"constant\((\d+)\)", rhs)
        if cm:
            v = int(cm.group(1))
            if cur.cond_constant is None or v > cur.cond_constant:
                cur.cond_constant = v
    return comps, entry_name


def _op_calls(op: Op) -> list[str]:
    out = []
    for g1, g2, g3 in _CALLS_RE.findall(op.body):
        if g1:
            out.append(g1)
        if g3:
            out.append(g3)
        if g2:
            out += [x.strip().lstrip("%") for x in g2.split(",") if x.strip()]
    return out


def _dot_flops(op: Op, comp: Computation) -> float:
    if not re.search(r"\bdot\(", op.body) or op.shape is None:
        return 0.0
    out_n = _numel(op.shape[1])
    inside = op.body[op.body.index("dot(") + 4:]
    operands = re.findall(r"%([\w\.\-]+)", inside[: inside.index(")")])
    k = 1
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.body)
    if m and operands:
        lhs = comp.symbols.get(operands[0])
        if lhs:
            lhs_dims = [int(d) for d in lhs[1].split(",") if d]
            for i in (int(x) for x in m.group(1).split(",") if x):
                if i < len(lhs_dims):
                    k *= lhs_dims[i]
    return 2.0 * out_n * k


def _while_trips(op: Op, comps: dict) -> float:
    m = _TRIP_RE.search(op.body)
    if m:
        return float(m.group(1))
    cm = re.search(r"condition=%([\w\.\-]+)", op.body)
    if cm and cm.group(1) in comps:
        c = comps[cm.group(1)].cond_constant
        if c:
            return float(c)
    return 1.0


@dataclasses.dataclass
class HloSummary:
    collective_bytes: float
    collective_breakdown: dict
    dot_flops: float
    while_trip_counts: dict
    traffic_bytes: float = 0.0   # loop-corrected HBM proxy (reads+writes)

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


_NO_TRAFFIC = ("parameter", "constant", "get-tuple-element", "tuple(",
               "bitcast")


def analyze(text: str) -> HloSummary:
    comps, entry_name = parse_hlo(text)
    breakdown: dict[str, float] = defaultdict(float)
    trips_seen: dict[str, float] = {}
    total_flops = 0.0
    traffic = 0.0
    # fusion-called computations: their internals are register-resident; the
    # fusion op's own operands/output already account for the HBM traffic
    fusion_comps: set[str] = set()
    for comp in comps.values():
        for op in comp.ops:
            if re.search(r"\bfusion\(", op.body):
                fusion_comps.update(_op_calls(op))

    def op_traffic(op: Op, comp: Computation) -> float:
        if any(t in op.body for t in _NO_TRAFFIC):
            return 0.0
        out = 0.0
        if op.shape:
            out += _numel(op.shape[1]) * _DTYPE_BYTES.get(op.shape[0], 4)
        if "(" in op.body:
            paren = op.body[op.body.index("("):]
            for nm in re.findall(r"%([\w\.\-]+)",
                                 paren[: paren.find(")") + 1]):
                sym = comp.symbols.get(nm)
                if sym:
                    out += _numel(sym[1]) * _DTYPE_BYTES.get(sym[0], 4)
        return out

    def walk(comp: Computation, mult: float, depth: int = 0):
        nonlocal total_flops, traffic
        if depth > 32:
            return
        for op in comp.ops:
            cm = _COLL_RE.search(op.body)
            if cm:
                # operands are %name refs — resolve via the symbol table;
                # fall back to the op's own output shape
                paren = op.body[op.body.index("("):]
                names = re.findall(r"%([\w\.\-]+)",
                                   paren[: paren.find(")") + 1])
                nbytes = 0
                for nm in names:
                    sym = comp.symbols.get(nm)
                    if sym:
                        nbytes += _numel(sym[1]) * _DTYPE_BYTES.get(sym[0], 4)
                if nbytes == 0 and op.shape:
                    nbytes = _numel(op.shape[1]) \
                        * _DTYPE_BYTES.get(op.shape[0], 4)
                breakdown[cm.group(1)] += mult * nbytes
            f = _dot_flops(op, comp)
            if f:
                total_flops += mult * f
            if comp.name not in fusion_comps:
                traffic += mult * op_traffic(op, comp)
            is_while = re.search(r"\bwhile\(", op.body)
            trips = _while_trips(op, comps) if is_while else 1.0
            body_name = None
            if is_while:
                bm = re.search(r"body=%([\w\.\-]+)", op.body)
                body_name = bm.group(1) if bm else None
                if body_name:
                    trips_seen[body_name] = trips
            for callee in _op_calls(op):
                c = comps.get(callee)
                if c is None:
                    continue
                walk(c, mult * (trips if callee == body_name else 1.0),
                     depth + 1)

    if entry_name:
        walk(comps[entry_name], 1.0)
    return HloSummary(collective_bytes=float(sum(breakdown.values())),
                      collective_breakdown=dict(breakdown),
                      dot_flops=total_flops,
                      while_trip_counts=trips_seen,
                      traffic_bytes=traffic)
