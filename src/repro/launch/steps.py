"""Step functions (train / prefill / decode) + their sharded jit builders.

``build_*`` return (jitted_fn, example_inputs_SDS, in_shardings) ready for
``.lower().compile()`` — used by both the dry-run driver and the real
train/serve entrypoints."""

from __future__ import annotations

import contextlib
import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import (DecodeState, decode_step, loss_fn,
                          param_specs, prefill)
from repro.models import sharding as shd
from repro.models.config import ModelConfig
from repro.optim import adamw_init, adamw_update, cosine_schedule

from .shapes import SHAPES, batch_specs, decode_state_specs


# ------------------------------------------------------------ step fns


def _rules_ctx(mesh, cfg, kind):
    """Activation pins are installed at TRACE time: the with-block inside
    the step function executes while jit traces it."""
    if mesh is None:
        return contextlib.nullcontext()
    return shd.activation_rules(
        mesh, shd.default_activation_rules(mesh, cfg, kind))


def make_train_step(cfg: ModelConfig, mesh: Mesh | None = None,
                    peak_lr: float = 3e-4, warmup: int = 100,
                    total_steps: int = 10_000):
    def train_step(params, opt, batch):
        with _rules_ctx(mesh, cfg, "train"):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: loss_fn(p, cfg, batch), has_aux=True)(params)
            lr = cosine_schedule(opt.step, peak_lr, warmup, total_steps)
            new_p, new_opt, gnorm = adamw_update(params, grads, opt, lr=lr)
        out = {"loss": loss, "ce": metrics["ce"], "aux": metrics["aux"],
               "gnorm": gnorm, "lr": lr}
        return new_p, new_opt, out

    return train_step


def make_prefill_step(cfg: ModelConfig, mesh: Mesh | None = None):
    def prefill_step(params, batch):
        with _rules_ctx(mesh, cfg, "prefill"):
            return prefill(params, cfg, batch)

    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh: Mesh | None = None):
    def serve_decode(params, tokens, caches, pos):
        with _rules_ctx(mesh, cfg, "decode"):
            logits, st = decode_step(params, cfg, tokens,
                                     DecodeState(caches=caches, pos=pos))
        return logits, st.caches, st.pos

    return serve_decode


# --------------------------------------------------------- jit builders


def _ns(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree)


def optimizer_specs(cfg: ModelConfig, p_sds):
    return jax.eval_shape(
        functools.partial(adamw_init,
                          moment_dtype=jnp.dtype(cfg.moment_dtype)), p_sds)


def build_train(cfg: ModelConfig, mesh: Mesh, cell):
    p_sds = param_specs(cfg)
    o_sds = optimizer_specs(cfg, p_sds)
    b_sds = batch_specs(cfg, cell)
    p_sh = shd.param_shardings(mesh, cfg, p_sds)
    o_sh = _opt_shardings(mesh, cfg, o_sds, p_sh)
    b_sh = _ns(mesh, shd.batch_pspecs(mesh, b_sds))
    fn = make_train_step(cfg, mesh)
    jfn = jax.jit(fn, in_shardings=(p_sh, o_sh, b_sh),
                  out_shardings=(p_sh, o_sh, None),
                  donate_argnums=(0, 1))
    return jfn, (p_sds, o_sds, b_sds)


def _opt_shardings(mesh, cfg, o_sds, p_sh):
    """Moments inherit parameter shardings; step scalar replicated."""
    step_sh = NamedSharding(mesh, P())
    return type(o_sds)(step=step_sh,
                       m=jax.tree.map(lambda s, _: s, p_sh, o_sds.m),
                       v=jax.tree.map(lambda s, _: s, p_sh, o_sds.v))


def build_prefill(cfg: ModelConfig, mesh: Mesh, cell):
    p_sds = param_specs(cfg)
    b_sds = batch_specs(cfg, cell)
    p_sh = shd.param_shardings(mesh, cfg, p_sds)
    b_sh = _ns(mesh, shd.batch_pspecs(mesh, b_sds))
    cache_sds = jax.eval_shape(
        lambda p, b: make_prefill_step(cfg)(p, b)[1], p_sds, b_sds)
    cache_sh = _ns(mesh, shd.cache_pspecs(mesh, cfg, cache_sds,
                                          shard_seq="none"))
    fn = make_prefill_step(cfg, mesh)
    jfn = jax.jit(fn, in_shardings=(p_sh, b_sh),
                  out_shardings=(None, cache_sh))
    return jfn, (p_sds, b_sds)


def build_decode(cfg: ModelConfig, mesh: Mesh, cell):
    p_sds = param_specs(cfg)
    b_sds = batch_specs(cfg, cell)
    cache_sds, pos_sds = decode_state_specs(cfg, cell)
    seq_mode = "all" if cell.global_batch == 1 else "model"
    p_sh = shd.param_shardings(mesh, cfg, p_sds)
    b_sh = _ns(mesh, shd.batch_pspecs(mesh, b_sds))
    c_sh = _ns(mesh, shd.cache_pspecs(mesh, cfg, cache_sds,
                                      shard_seq=seq_mode))
    pos_sh = NamedSharding(mesh, P())
    fn = make_decode_step(cfg, mesh)
    jfn = jax.jit(fn, in_shardings=(p_sh, b_sh["tokens"], c_sh, pos_sh),
                  out_shardings=(None, c_sh, pos_sh),
                  donate_argnums=(2,))
    return jfn, (p_sds, b_sds["tokens"], cache_sds, pos_sds)


def build_cell(cfg: ModelConfig, mesh: Mesh, shape_name: str):
    cell = SHAPES[shape_name]
    if cell.kind == "train":
        return build_train(cfg, mesh, cell)
    if cell.kind == "prefill":
        return build_prefill(cfg, mesh, cell)
    return build_decode(cfg, mesh, cell)
