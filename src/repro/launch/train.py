"""End-to-end training driver with checkpoint/restart, watchdog retry and
straggler accounting — runnable on this CPU container with a reduced config
(examples/train_lm.py) and shaped for the production mesh on real hardware.

Usage (CPU-scale):
  PYTHONPATH=src python -m repro.launch.train --arch gemma3_1b --smoke \
      --steps 30 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt
from repro.configs import get_config, get_smoke_config
from repro.data.tokens import synthetic_lm_batches
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.models import init_params
from repro.models.config import ModelConfig
from repro.optim import adamw_init
from repro.runtime import StepWatchdog, WatchdogConfig


def train_loop(cfg: ModelConfig, *, steps: int, batch: int, seq: int,
               ckpt_dir: str | None = None, ckpt_every: int = 10,
               seed: int = 0, mesh=None, log_every: int = 5,
               resume: bool = True):
    key = jax.random.PRNGKey(seed)
    params = init_params(key, cfg)
    opt = adamw_init(params, moment_dtype=jnp.dtype(cfg.moment_dtype))
    start_step = 0
    fingerprint = ckpt.config_fingerprint(cfg)
    if ckpt_dir and resume and ckpt.latest_step(ckpt_dir) is not None:
        (params, opt), start_step = ckpt.restore(
            ckpt_dir, (params, opt), config_hash=fingerprint)
        print(f"[train] resumed from step {start_step}")

    step_fn = jax.jit(make_train_step(cfg, mesh))
    watchdog = StepWatchdog(WatchdogConfig(min_deadline_s=60.0))
    batches = synthetic_lm_batches(cfg, batch, seq, seed=seed,
                                   start=start_step)
    losses = []
    t0 = time.perf_counter()
    for step, data in zip(range(start_step, steps), batches):
        def do_step(data=data):
            nonlocal params, opt
            params, opt, metrics = step_fn(params, opt, data)
            return metrics

        metrics = watchdog.run_step(step, do_step)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % log_every == 0 or step == steps - 1:
            dt = time.perf_counter() - t0
            print(f"[train] step {step:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['gnorm']):7.3f} "
                  f"({dt / max(len(losses), 1):.2f}s/step)")
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            ckpt.save(ckpt_dir, step + 1, (params, opt),
                      config_hash=fingerprint)
    if ckpt_dir:
        ckpt.save(ckpt_dir, steps, (params, opt), config_hash=fingerprint)
    return params, opt, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    cfg = get_smoke_config(args.arch) if args.smoke \
        else get_config(args.arch)
    mesh = make_host_mesh()
    with mesh:
        _, _, losses = train_loop(cfg, steps=args.steps, batch=args.batch,
                                  seq=args.seq, ckpt_dir=args.ckpt_dir,
                                  seed=args.seed, mesh=mesh)
    print(f"[train] first loss {losses[0]:.4f} → last {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
