"""Assigned input-shape cells and ShapeDtypeStruct builders.

Every (arch × shape) cell is defined here; ``input_specs`` returns
allocation-free ShapeDtypeStruct stand-ins for the step function's inputs
(the shannon/kernels pattern): weak-type-correct, shardable.

``long_500k`` runs only for sub-quadratic archs (ssm / hybrid /
mostly-sliding-window gemma3) — the skip list is data, not policy, so the
dry-run driver and EXPERIMENTS.md table stay in sync with DESIGN.md
§Arch-applicability."""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, canonical, get_config
from repro.models import make_decode_caches
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524_288, 1),
}

# archs with a sub-quadratic (or O(1)-state) long-context path
LONG_CONTEXT_OK = {"falcon_mamba_7b", "jamba_1_5_large_398b", "gemma3_1b"}


def cell_is_runnable(arch: str, shape: str) -> tuple[bool, str]:
    arch = canonical(arch)
    if shape == "long_500k" and arch not in LONG_CONTEXT_OK:
        return False, ("pure full-attention arch: 500k context has no "
                       "sub-quadratic path (DESIGN.md §Arch-applicability)")
    return True, ""


def all_cells():
    for arch in ARCHS:
        for shape in SHAPES:
            yield arch, shape


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    b, s = cell.global_batch, cell.seq_len
    if cell.kind == "train":
        out = {"labels": _sds((b, s), jnp.int32)}
        if cfg.stub_frontend:
            out["embeddings"] = _sds((b, s, cfg.d_model), jnp.bfloat16)
        else:
            out["tokens"] = _sds((b, s), jnp.int32)
        return out
    if cell.kind == "prefill":
        if cfg.stub_frontend:
            return {"embeddings": _sds((b, s, cfg.d_model), jnp.bfloat16)}
        return {"tokens": _sds((b, s), jnp.int32)}
    # decode: one new token against a seq_len cache
    return {"tokens": _sds((b, 1), jnp.int32)}


def decode_state_specs(cfg: ModelConfig, cell: ShapeCell):
    caches = jax.eval_shape(functools.partial(
        make_decode_caches, cfg, cell.global_batch, cell.seq_len))
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return caches, pos


def input_specs(arch: str, shape: str) -> dict:
    """Everything the cell's step function consumes, as SDS pytrees."""
    cfg = get_config(arch)
    cell = SHAPES[shape]
    out = {"batch": batch_specs(cfg, cell)}
    if cell.kind == "decode":
        caches, pos = decode_state_specs(cfg, cell)
        out["caches"], out["pos"] = caches, pos
    return out
