"""Wire load generator: drive a served mining daemon like a fleet of
electrode arrays, optionally through a deterministic fault injector.

Each simulated array gets its own ``MiningClient`` (own session, own
sequence numbers) and streams its partition windows over the wire,
polling deltas as they complete — the chip-on-chip loop with a real
transport in the middle. ``--faults`` wraps every client socket in a
``FaultInjector`` (seed-driven drop/duplicate/truncate/delay, see
runtime/faultinject.py) so retry, dedup, and reconnect paths are
exercised deterministically; ``--verify`` re-mines every received window
with a local ``StreamingMiner`` and asserts bit-identical episode counts
— the transport must never change the math, faults or not.

Usage:
  PYTHONPATH=src python -m repro.launch.wire_load \
      --connect unix:/tmp/fem.sock --sessions 4 --seconds 10 \
      --faults --fault-seed 7 --verify
"""

from __future__ import annotations

import argparse
import json
import socket
import time
from concurrent.futures import ThreadPoolExecutor

from repro.data import partition_windows, sym26
from repro.runtime.faultinject import FaultInjector, FaultSpec
from repro.service import SessionConfig
from repro.service.client import MiningClient
from repro.service.session import MiningSession
from repro.service.wire import delta_payload


class FaultySocket:
    """Socket proxy routing sends through a ``FaultInjector``: frames are
    dropped, duplicated, truncated (then the connection severed, as a
    real half-written TCP segment would), or delayed — deterministically
    from the injector's seed."""

    def __init__(self, sock: socket.socket, injector: FaultInjector):
        self._sock = sock
        self._inj = injector

    def sendall(self, data: bytes) -> None:
        chunks, cut = self._inj.plan(data)
        for c in chunks:
            self._sock.sendall(c)
        if cut:
            self._sock.close()  # sever: the client must reconnect

    def __getattr__(self, name):
        return getattr(self._sock, name)


class FaultyClient(MiningClient):
    """MiningClient whose outbound frames pass through a FaultInjector."""

    def __init__(self, *a, fault_spec: FaultSpec | None = None, **kw):
        super().__init__(*a, **kw)
        self.injector = FaultInjector(fault_spec or FaultSpec())

    def _connect(self):
        sock = super()._connect()
        if self.injector.spec.active:
            return FaultySocket(sock, self.injector)
        return sock


def make_array_config(i: int, theta: int = 3, max_level: int = 3,
                      engine: str = "hybrid",
                      two_pass: bool | None = None) -> SessionConfig:
    """Per-array configs matching the mine_serve demo fleet: staggered
    rates and window sizes so shape buckets differ across tenants."""
    kw = {} if two_pass is None else {"two_pass": two_pass}
    return SessionConfig(
        theta=theta, max_level=max_level, engine=engine,
        window_ms=(1000, 2000, 4000)[i % 3], **kw)


def array_stream(i: int, seconds: int):
    rate = 10.0 + 10.0 * (i % 3)
    stream, _ = sym26(seconds=seconds, rate_hz=rate, seed=i)
    return stream


def _drive_array(i: int, c: FaultyClient, cfg: SessionConfig, *,
                 seconds: int, verify: bool, deadline_s: float,
                 close: bool) -> tuple[dict, bool]:
    """Submit one array's windows, drain its deltas, optionally verify
    against a local re-mine.  Each producer owns its client exclusively
    (``MiningClient`` is not thread-safe across producers)."""
    wins = list(partition_windows(array_stream(i, seconds),
                                  cfg.window_ms))
    for j, w in enumerate(wins):
        c.submit(w, final=(j == len(wins) - 1))
    deltas = c.drain(deadline_s=deadline_s)
    deltas.sort(key=lambda d: d["window_idx"])
    row = {"windows": len(wins), "deltas": len(deltas),
           "events": sum(int(w.types.shape[0]) for w in wins),
           "reconnects": c.reconnects, "applied": c.applied,
           "durable": c.durable}
    ok = True
    if verify:
        local = MiningSession(f"local-{i}", cfg)
        for j, w in enumerate(wins):
            local.enqueue(w, final=(j == len(wins) - 1))
        while local.queue_depth:
            p = local.prepare()
            local.commit(p, local.execute(p))
        ref = [delta_payload(d) for d in local.poll()]
        match = ([r["episodes"] for r in ref]
                 == [g["episodes"] for g in deltas])
        row["verified"] = match
        ok = match and len(deltas) == len(wins)
    if close:
        c.close_session()
    else:
        c.close()
    return row, ok


def run_load(address: str, sessions: int = 2, seconds: int = 6, *,
             theta: int = 3, max_level: int = 3, engine: str = "hybrid",
             fault_spec: FaultSpec | None = None, verify: bool = False,
             deadline_s: float = 240.0, session_prefix: str = "array",
             close: bool = True, producers: int = 1) -> dict:
    """Stream ``sessions`` synthetic arrays into the daemon at
    ``address``; returns a per-session report (windows, deltas,
    reconnects, injected faults, verification result).

    ``producers`` > 1 drives that many arrays concurrently, one thread
    per in-flight session (capped at ``producers``) — the honest
    fleet-scale mode: a serial producer bottlenecks the daemon on one
    submitting thread and understates batched throughput.  ``producers
    <= 1`` keeps the deterministic serial schedule (faults still
    deterministic per client: each client owns its injector and seed).
    """
    report = {"sessions": {}, "faults": {}, "ok": True,
              "producers": max(producers, 1)}
    clients = []
    for i in range(sessions):
        cfg = make_array_config(i, theta=theta, max_level=max_level,
                                engine=engine)
        c = FaultyClient(address, f"{session_prefix}-{i}", cfg,
                         fault_spec=fault_spec, rng_seed=1000 + i,
                         deadline_s=deadline_s)
        clients.append((i, c, cfg))

    t0 = time.monotonic()
    def drive(item):
        i, c, cfg = item
        return i, c, _drive_array(i, c, cfg, seconds=seconds,
                                  verify=verify, deadline_s=deadline_s,
                                  close=close)
    if report["producers"] > 1:
        with ThreadPoolExecutor(
                max_workers=min(report["producers"],
                                max(sessions, 1))) as pool:
            done = list(pool.map(drive, clients))
    else:
        done = [drive(item) for item in clients]
    for i, c, (row, ok) in done:
        report["ok"] = report["ok"] and ok
        if getattr(c, "injector", None) is not None:
            for k, v in c.injector.injected.items():
                report["faults"][k] = report["faults"].get(k, 0) + v
        report["sessions"][f"{session_prefix}-{i}"] = row
    report["elapsed_s"] = time.monotonic() - t0
    return report


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Load-generate against a served mining daemon.")
    ap.add_argument("--connect", required=True,
                    help='"host:port" or "unix:/path"')
    ap.add_argument("--sessions", type=int, default=2)
    ap.add_argument("--seconds", type=int, default=6)
    ap.add_argument("--theta", type=int, default=3)
    ap.add_argument("--max-level", type=int, default=3)
    ap.add_argument("--engine", default="hybrid")
    ap.add_argument("--faults", action="store_true",
                    help="inject wire faults (deterministic per seed)")
    ap.add_argument("--fault-seed", type=int, default=0)
    ap.add_argument("--fault-drop", type=float, default=0.08)
    ap.add_argument("--fault-dup", type=float, default=0.08)
    ap.add_argument("--fault-truncate", type=float, default=0.04)
    ap.add_argument("--verify", action="store_true",
                    help="re-mine locally and assert bit-identical")
    ap.add_argument("--producers", type=int, default=1, metavar="N",
                    help="concurrent producer threads (default 1 = "
                         "serial; use ~sessions for an honest "
                         "fleet-scale load)")
    ap.add_argument("--deadline", type=float, default=240.0)
    ap.add_argument("--json-out", default=None, metavar="PATH")
    args = ap.parse_args(argv)

    spec = FaultSpec(seed=args.fault_seed, drop=args.fault_drop,
                     duplicate=args.fault_dup,
                     truncate=args.fault_truncate) if args.faults \
        else FaultSpec()
    report = run_load(args.connect, sessions=args.sessions,
                      seconds=args.seconds, theta=args.theta,
                      max_level=args.max_level, engine=args.engine,
                      fault_spec=spec, verify=args.verify,
                      deadline_s=args.deadline,
                      producers=args.producers)
    for sid, row in report["sessions"].items():
        print(f"[load] {sid}: {row['deltas']}/{row['windows']} windows, "
              f"{row['reconnects']} reconnects"
              + (f", verified={row['verified']}" if "verified" in row
                 else ""))
    if report["faults"]:
        print(f"[load] injected faults: {report['faults']}")
    print(f"[load] elapsed {report['elapsed_s']:.1f}s ok={report['ok']}")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    import sys
    sys.exit(main())
