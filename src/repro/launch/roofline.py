"""Roofline analysis over the dry-run JSONs (EXPERIMENTS.md §Roofline).

Hardware model (TPU v5e): 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.

Per (arch × shape × mesh) cell, three per-chip time terms:

  compute    = HLO_FLOPs_per_device / 197e12
  memory     = HLO_bytes_per_device / 819e9
  collective = collective_bytes_per_device / 50e9

Sources: FLOPs/bytes use the LOOP-CORRECTED HLO walk (hlo_analysis.py) —
``cost_analysis()`` visits while bodies once, so its raw numbers are also
shown as the (undercounted) lower bound. MODEL_FLOPS = 6·N(_active)·tokens
for train, 2·N_active·tokens for inference, GLOBAL, divided by chips for
the ratio. The dominant term is the bottleneck; `useful` =
MODEL_FLOPS / HLO_FLOPs catches remat/replication waste.

Usage:
  PYTHONPATH=src python -m repro.launch.roofline [--dir experiments/dryrun]
      [--json out.json] [--markdown out.md]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

PEAK_FLOPS = 197e12    # bf16 / chip
HBM_BW = 819e9         # B/s / chip
ICI_BW = 50e9          # B/s / link

TERM_NAMES = ("compute", "memory", "collective")


def cell_terms(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    chips = rec["chips"]
    hlo = rec["hlo"]
    kind = rec["model"]["kind"]
    # per-device quantities (SPMD module shapes are shard-local)
    flops_dev = hlo["dot_flops"]
    traffic_dev = hlo["traffic_bytes"]
    coll_dev = hlo["collective_bytes"]
    cost_flops = rec["cost"].get("flops", 0.0)
    cost_bytes = rec["cost"].get("bytes accessed", 0.0)
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = traffic_dev / HBM_BW
    coll_s = coll_dev / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": coll_s}
    dominant = max(terms, key=terms.get)
    n = rec["model"]["active_params"]
    tokens = rec["tokens"]
    mult = 6.0 if kind == "train" else 2.0
    model_flops_global = mult * n * tokens
    model_flops_dev = model_flops_global / chips
    step_s = max(terms.values())
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "dominant": dominant,
        "step_time_s": step_s,
        "model_flops_global": model_flops_global,
        "hlo_flops_dev": flops_dev,
        "useful_flops_ratio": (model_flops_dev / flops_dev)
        if flops_dev else 0.0,
        "roofline_fraction": (model_flops_dev / PEAK_FLOPS) / step_s
        if step_s else 0.0,
        "mfu_bound": (model_flops_global / (chips * PEAK_FLOPS)) / step_s
        if step_s else 0.0,
        "cost_flops_dev_raw": cost_flops,
        "cost_bytes_dev_raw": cost_bytes,
        "collective_breakdown": hlo["collective_breakdown"],
        "mem_gib_dev": rec["memory"]["per_device_total_bytes"] / 2 ** 30,
        "fits_16g": rec["memory"]["per_device_total_bytes"] < 16 * 2 ** 30,
    }


def load_cells(d: Path) -> list[dict]:
    out = []
    for f in sorted(d.glob("*.json")):
        rec = json.loads(f.read_text())
        t = cell_terms(rec)
        if t:
            out.append(t)
        elif rec.get("status") == "skip":
            out.append({"arch": rec["arch"], "shape": rec["shape"],
                        "mesh": rec["mesh"], "skip": rec["reason"]})
    return out


def to_markdown(cells: list[dict]) -> str:
    rows = ["| arch | shape | mesh | compute s | memory s | collective s |"
            " dominant | MFU-bound | useful | GiB/dev | fits |",
            "|---|---|---|---|---|---|---|---|---|---|---|"]
    for c in cells:
        if "skip" in c:
            rows.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} | "
                        f"— | — | — | SKIP | — | — | — | — |")
            continue
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} "
            f"| {c['compute_s']:.3e} | {c['memory_s']:.3e} "
            f"| {c['collective_s']:.3e} | **{c['dominant']}** "
            f"| {c['mfu_bound']:.2%} | {c['useful_flops_ratio']:.2f} "
            f"| {c['mem_gib_dev']:.1f} "
            f"| {'✓' if c['fits_16g'] else '✗'} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--json", default="experiments/roofline.json")
    ap.add_argument("--markdown", default="experiments/roofline.md")
    ap.add_argument("--mesh", default=None, choices=[None, "single",
                                                     "multi"])
    args = ap.parse_args()
    cells = load_cells(Path(args.dir))
    if args.mesh:
        cells = [c for c in cells if c["mesh"] == args.mesh]
    Path(args.json).parent.mkdir(parents=True, exist_ok=True)
    Path(args.json).write_text(json.dumps(cells, indent=1))
    md = to_markdown(cells)
    Path(args.markdown).write_text(md + "\n")
    print(md)


if __name__ == "__main__":
    main()
