"""qwen1.5-32b [dense] — QKV-bias llama-style dense transformer
[hf:Qwen/Qwen1.5-0.5B (family); hf]."""

from repro.models.config import ModelConfig, scaled_down

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
    mlp_variant="swiglu",
)

SMOKE = scaled_down(CONFIG, qkv_bias=True)
