"""musicgen-large [audio] — decoder-only LM over EnCodec tokens
[arXiv:2306.05284; hf]. Backbone only: the EnCodec frontend is a stub
(input_specs provides precomputed frame embeddings). MusicGen uses a plain
(non-gated) transformer FFN; positions here use RoPE (framework-wide choice,
noted in DESIGN.md)."""

from repro.models.config import ModelConfig, scaled_down

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,      # MHA (GQA kv=32)
    d_ff=8192,
    vocab_size=2048,
    mlp_variant="gelu",
    stub_frontend=True,
)

SMOKE = scaled_down(CONFIG)
