"""moonshot-v1-16b-a3b [moe] — kimi/moonlight DeepSeek-style fine-grained
MoE: 64 experts top-6, narrow d_ff=1408 per expert
[hf:moonshotai/Moonlight-16B-A3B; hf]."""

from repro.models.config import ModelConfig, scaled_down

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    num_experts=64,
    top_k=6,
    moe_every=1,
    d_ff_shared=2816,     # 2 shared experts (DeepSeekMoE-style), 2×1408
    mlp_variant="swiglu",
)

SMOKE = scaled_down(CONFIG, d_ff_shared=64)
