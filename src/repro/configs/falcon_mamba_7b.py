"""falcon-mamba-7b [ssm] — pure Mamba-1, attention-free, d_ff=0 (the mamba
mixer is the whole block) [arXiv:2410.05355; unverified]. O(1)-state decode
makes it the long_500k showcase."""

from repro.models.config import ModelConfig, scaled_down

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=65024,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
)

SMOKE = scaled_down(CONFIG, num_heads=0, num_kv_heads=0, d_ff=0,
                    head_dim=0)
