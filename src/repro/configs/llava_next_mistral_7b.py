"""llava-next-mistral-7b [vlm] — mistral-7b backbone behind an anyres vision
frontend [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]. Backbone only:
the CLIP tower + anyres tiling is a stub (input_specs provides precomputed
patch embeddings alongside text)."""

from repro.models.config import ModelConfig, scaled_down

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    mlp_variant="swiglu",
    stub_frontend=True,
)

SMOKE = scaled_down(CONFIG)
