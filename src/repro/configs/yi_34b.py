"""yi-34b [dense] — llama-arch GQA [arXiv:2403.04652; hf]."""

from repro.models.config import ModelConfig, scaled_down

CONFIG = ModelConfig(
    name="yi-34b",
    family="dense",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    mlp_variant="swiglu",
    rope_theta=5e6,
)

SMOKE = scaled_down(CONFIG)
