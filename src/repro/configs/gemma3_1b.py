"""gemma3-1b [dense] — 5:1 local:global sliding-window attention,
window 512, kv=1, head_dim 256, 262k vocab [hf:google/gemma-3-1b-pt;
unverified]. 26 layers = 4 full (5 local + 1 global) periods + 2 tail
layers (exercises the unstacked-tail path)."""

from repro.models.config import ModelConfig, scaled_down

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    window=512,
    global_every=6,
    mlp_variant="gelu",
    rope_theta=1e6,
)

SMOKE = scaled_down(CONFIG, num_layers=8, window=8, head_dim=16)
