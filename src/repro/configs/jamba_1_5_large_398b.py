"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave with MoE
16e top-2 on alternate layers [arXiv:2403.19887; hf]. Period = lcm(8, 2) = 8:
one attention layer (position 3) per 8, MoE at odd positions."""

from repro.models.config import ModelConfig, scaled_down

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    num_experts=16,
    top_k=2,
    moe_every=2,
    moe_offset=1,
    attn_every=8,
    attn_offset=3,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    mlp_variant="swiglu",
    moment_dtype="bfloat16",
)

SMOKE = scaled_down(CONFIG)
