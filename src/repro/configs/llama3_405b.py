"""llama3-405b [dense] — GQA kv=8, 128k vocab [arXiv:2407.21783;
unverified]. The FSDP/TP stress case: AdamW moments are kept in bf16 so
params+grads+moments fit v5e HBM at 256 chips (see DESIGN.md §5)."""

from repro.models.config import ModelConfig, scaled_down

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    num_layers=126,
    d_model=16384,
    num_heads=128,
    num_kv_heads=8,
    d_ff=53248,
    vocab_size=128256,
    mlp_variant="swiglu",
    rope_theta=5e5,
    moment_dtype="bfloat16",
)

SMOKE = scaled_down(CONFIG)
