"""Architecture registry: one module per assigned arch (+ the paper's own
mining workload config). ``get_config(name)`` returns the full published
config; ``get_smoke_config(name)`` the reduced same-family config."""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig, scaled_down

ARCHS = [
    "musicgen_large",
    "dbrx_132b",
    "moonshot_v1_16b_a3b",
    "qwen1_5_32b",
    "llama3_405b",
    "gemma3_1b",
    "yi_34b",
    "jamba_1_5_large_398b",
    "falcon_mamba_7b",
    "llava_next_mistral_7b",
]

ALIASES = {a.replace("_", "-"): a for a in ARCHS}


def canonical(name: str) -> str:
    key = name.replace("-", "_").replace(".", "_")
    if key in ARCHS:
        return key
    if name in ALIASES:
        return ALIASES[name]
    raise KeyError(f"unknown arch {name!r}; known: {ARCHS}")


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    smoke = getattr(mod, "SMOKE", None)
    return smoke if smoke is not None else scaled_down(mod.CONFIG)
