"""dbrx-132b [moe] — 16-expert top-4 fine-grained MoE
[hf:databricks/dbrx-base; unverified]. Every layer is MoE."""

from repro.models.config import ModelConfig, scaled_down

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    num_experts=16,
    top_k=4,
    moe_every=1,
    mlp_variant="swiglu",
    rope_theta=5e5,
)

SMOKE = scaled_down(CONFIG)
