"""Nested host-side spans with ring-buffer storage and trace exporters.

``TRACER.span("stream.prepare", session="array-0")`` is a context
manager: two ``perf_counter`` reads, a thread-local stack push/pop, and
one deque append on exit — O(1), allocation-light, exception-safe (the
span closes in ``__exit__`` whatever the body raises), and **never**
syncs the device (device-side time is visible as the host wall time of
the dispatch call, which on accelerator backends is a lower bound; use
``obs.jaxprof.capture_step`` for the real device timeline).

Span names are dotted ``layer.phase`` strings; the window lifecycle uses

    service.ingest -> schedule.step -> schedule.snapshot ->
    session.mine_window -> stream.prepare -> batch.barrier_wait ->
    batch.gate -> batch.pad_fuse -> batch.device_launch ->
    batch.self_launch -> stream.launch -> stream.commit ->
    stream.checkpoint -> schedule.stage

(``schedule.stage`` is the pipelined scheduler's double-buffered host
prepare for the *next* step, running on a session thread while other
lanes hold the device; ``batch.gate`` is a zero-width marker recording
each flush group's fusion-gate decision; ``batch.self_launch`` is a
lane's own standalone dispatch when the gate declines fusion.)

Exports: ``export_jsonl`` (one span per line, absolute timestamps) and
``export_chrome`` (Chrome trace-event JSON — open in Perfetto or
``chrome://tracing``). ``step_breakdown()`` reduces the buffered spans of
every completed scheduler step to the per-phase attribution (barrier
wait vs pad/fuse host work vs device launch vs per-session staging) that
makes the batched-vs-unbatched gap diagnosable.
"""

from __future__ import annotations

import json
import threading
import time
from collections import namedtuple

SpanEvent = namedtuple("SpanEvent", "name tid t0 dur depth args")

# step_breakdown phase classes (leaf spans only — parents like
# session.mine_window contain them and are never summed)
_HOST_PHASES = frozenset(
    {"stream.prepare", "stream.commit", "stream.checkpoint"})
# batch.self_launch: a lane's own dispatch when the fusion gate declines
# to fuse — device time on the lane's thread, same as stream.launch
_DEVICE_PHASES = frozenset({"stream.launch", "batch.self_launch"})
_FLUSH_PHASES = frozenset({"batch.pad_fuse", "batch.device_launch"})
_WAIT_PHASE = "batch.barrier_wait"
_SNAPSHOT_PHASE = "schedule.snapshot"
_STAGE_PHASE = "schedule.stage"
_GATE_PHASE = "batch.gate"
_STEP_PHASE = "schedule.step"
_MINE_PHASE = "session.mine_window"


class _Span:
    __slots__ = ("_tracer", "_name", "_args", "_t0", "_depth", "_active")

    def __init__(self, tracer, name, args):
        self._tracer = tracer
        self._name = name
        self._args = args

    def __enter__(self):
        tr = self._tracer
        self._active = tr.enabled
        if self._active:
            stack = tr._stack()
            self._depth = len(stack)
            stack.append(self._name)
            self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        if self._active:
            t1 = time.perf_counter()
            tr = self._tracer
            tr._stack().pop()
            tr._events.append(SpanEvent(
                self._name, threading.get_ident(), self._t0,
                t1 - self._t0, self._depth, self._args))
        return False


class Tracer:
    """Ring buffer of completed spans, shared process-wide."""

    def __init__(self, capacity: int = 65536):
        self.enabled = True
        self.capacity = capacity
        from collections import deque
        self._events = deque(maxlen=capacity)
        self._local = threading.local()
        # export origin: perf_counter epoch pinned to wall time once
        self._origin = time.perf_counter()
        self._wall0 = time.time()

    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def span(self, name: str, **args) -> _Span:
        return _Span(self, name, args or None)

    def current(self) -> str | None:
        """Innermost open span name on this thread (or None)."""
        st = self._stack()
        return st[-1] if st else None

    def events(self) -> list[SpanEvent]:
        return list(self._events)

    def clear(self) -> None:
        self._events.clear()

    # ---------------------------------------------------------- exports

    def export_jsonl(self, path) -> int:
        """One span per line: {name, ts (unix s), dur_s, tid, depth,
        args}. Returns the number of spans written."""
        events = self.events()
        with open(path, "w") as f:
            for e in events:
                f.write(json.dumps({
                    "name": e.name,
                    "ts": self._wall0 + (e.t0 - self._origin),
                    "dur_s": e.dur,
                    "tid": e.tid,
                    "depth": e.depth,
                    "args": e.args or {},
                }) + "\n")
        return len(events)

    def export_chrome(self, path) -> int:
        """Chrome trace-event JSON (Perfetto-loadable): complete ("X")
        events, ts/dur in microseconds, one renamed row per thread.
        Returns the number of spans written."""
        events = self.events()
        tids: dict[int, int] = {}
        rows = []
        for e in events:
            tid = tids.setdefault(e.tid, len(tids))
            rows.append({
                "name": e.name,
                "cat": e.name.split(".", 1)[0],
                "ph": "X",
                "ts": (e.t0 - self._origin) * 1e6,
                "dur": e.dur * 1e6,
                "pid": 0,
                "tid": tid,
                "args": e.args or {},
            })
        meta = [{"name": "thread_name", "ph": "M", "pid": 0, "tid": small,
                 "args": {"name": f"worker-{small}" if small else "main"}}
                for small in sorted(tids.values())]
        with open(path, "w") as f:
            json.dump({"traceEvents": meta + rows,
                       "displayTimeUnit": "ms"}, f)
        return len(rows)


def step_breakdown(events=None, tracer=None) -> dict:
    """Per-phase attribution over every completed ``schedule.step`` span
    in the buffer.

    For each step the critical-path thread t* (largest summed span time
    inside the step window) is decomposed into per-session host staging,
    mining host work (t*'s ``session.mine_window`` time not inside any
    leaf phase: candidate generation, level logic, result assembly),
    pure barrier wait, and device launch; flush-leader work (pad/fuse +
    fused launch, serialized under the batcher lock) is attributed
    step-globally and subtracted from t*'s measured wait —
    while t* was parked, that is what it was waiting *on*. The result
    sums to the step wall modulo thread spawn/join overhead; ``coverage``
    reports the attributed fraction so the benchmark's 10% attribution
    bound is checkable from the output alone.

    Pipelined-scheduler additions: ``stage_s`` is t*'s double-buffered
    next-step staging (it extends t*'s critical path to the join);
    ``pipeline_overlap_s`` is *all* lanes' staging inside the step — the
    host work removed from the next step's serial prepare; ``gate``
    counts ``batch.gate`` fusion decisions by verdict.
    """
    if events is None:
        events = (tracer or TRACER).events()
    steps = [e for e in events if e.name == _STEP_PHASE]
    out = {
        "steps": 0, "wall_s": 0.0, "snapshot_s": 0.0, "bucket_pad_s": 0.0,
        "mine_host_s": 0.0, "barrier_wait_s": 0.0, "pad_fuse_s": 0.0,
        "device_launch_s": 0.0, "stage_s": 0.0, "pipeline_overlap_s": 0.0,
        "attributed_s": 0.0, "gate": {},
    }
    zero = {"host": 0.0, "dev": 0.0, "wait": 0.0, "flush": 0.0,
            "mine": 0.0, "stage": 0.0}
    for step in steps:
        w0, w1 = step.t0, step.t0 + step.dur
        inside = [e for e in events
                  if e is not step and e.t0 >= w0 - 1e-9
                  and e.t0 + e.dur <= w1 + 1e-9]
        snapshot = sum(e.dur for e in inside if e.name == _SNAPSHOT_PHASE)
        per_tid: dict[int, dict] = {}
        for e in inside:
            b = per_tid.setdefault(e.tid, dict(zero))
            if e.name in _HOST_PHASES:
                b["host"] += e.dur
            elif e.name in _DEVICE_PHASES:
                b["dev"] += e.dur
            elif e.name == _WAIT_PHASE:
                b["wait"] += e.dur
            elif e.name in _FLUSH_PHASES:
                b["flush"] += e.dur
            elif e.name == _MINE_PHASE:
                b["mine"] += e.dur
            elif e.name == _STAGE_PHASE:
                b["stage"] += e.dur
            elif e.name == _GATE_PHASE and e.args:
                d = str(e.args.get("decision"))
                out["gate"][d] = out["gate"].get(d, 0) + 1
        pad_fuse = sum(e.dur for e in inside if e.name == "batch.pad_fuse")
        fused_launch = sum(e.dur for e in inside
                           if e.name == "batch.device_launch")
        # the step joins every lane thread, and a lane's double-buffered
        # staging runs after its mining — the critical path is mining (or
        # its leaf decomposition) plus that thread's staging tail
        star = (max(per_tid.values(),
                    key=lambda b: max(b["mine"], b["host"] + b["dev"]
                                      + b["wait"] + b["flush"])
                    + b["stage"])
                if per_tid else dict(zero))
        # t*'s mine_window time not inside any leaf phase: candidate
        # generation and the rest of the level loop's host work
        mine_host = max(star["mine"] - (star["host"] + star["dev"]
                                        + star["wait"] + star["flush"]), 0.0)
        # other threads' flush work overlaps t*'s barrier wait (whichever
        # thread completed the group runs the launch while its members
        # park), so credit it against the wait — capped at the wait
        # actually seen, since flushes concurrent with t*'s own work cost
        # the step nothing
        flush_global = pad_fuse + fused_launch
        credit = min(max(flush_global - star["flush"], 0.0), star["wait"])
        flush_attr = star["flush"] + credit
        pad_share = pad_fuse / flush_global if flush_global > 0 else 0.0
        out["steps"] += 1
        out["wall_s"] += step.dur
        out["snapshot_s"] += snapshot
        out["bucket_pad_s"] += star["host"]
        out["mine_host_s"] += mine_host
        out["barrier_wait_s"] += star["wait"] - credit
        out["pad_fuse_s"] += flush_attr * pad_share
        out["device_launch_s"] += flush_attr * (1.0 - pad_share) + star["dev"]
        out["stage_s"] += star["stage"]
        # total staging overlapped with the step across all lanes — the
        # host work the double-buffer removed from the next step's
        # serial-prepare critical path
        out["pipeline_overlap_s"] += sum(b["stage"]
                                         for b in per_tid.values())
        out["attributed_s"] += (snapshot + star["host"] + star["dev"]
                                + mine_host + (star["wait"] - credit)
                                + flush_attr + star["stage"])
    out["coverage"] = (out["attributed_s"] / out["wall_s"]
                       if out["wall_s"] > 0 else 0.0)
    return out


TRACER = Tracer()


def span(name: str, **args) -> _Span:
    """Module-level shorthand for ``TRACER.span``."""
    return TRACER.span(name, **args)
