"""Process-global labeled metrics registry (pure stdlib).

One ``Registry`` holds named metric *families*; a family plus a label set
names one series, e.g. ``kernel_calls{kind=a1_state}`` or
``window_latency_s{session=array-3}``. Three kinds:

* ``Counter`` — monotonic count (kernel dispatches, fallbacks, fused
  requests, recompiles). ``_force_set`` exists only for the
  ``KERNEL_CALLS`` dict facade in ``kernels.tally``.
* ``Gauge`` — last-write-wins level (queue depth, live sessions,
  heartbeat timestamp).
* ``Histogram`` — count/sum/min/max plus fixed log-spaced bucket counts
  (window latency); ``quantile()`` interpolates within a bucket, good to
  a bucket's width — the per-session meters keep exact rows for the
  precise p50/p99 the service SLO reports.

``snapshot()`` renders everything into one flat, deterministically
ordered ``{series_name: value}`` dict; ``delta(before, after)`` diffs two
snapshots (the idiom for "what did this step do"). Mutations take a
single module lock — metric updates happen per window / per flush, not
per event, so contention is nil.

This module deliberately imports nothing beyond the stdlib: the
dependency-light ``kernels.tally`` (importable even when jax is not)
builds its back-compat tally view on top of it.
"""

from __future__ import annotations

import threading
import time

# default Histogram bounds: 1 ms .. ~100 s, quarter-decade log steps —
# wide enough for interpret-mode windows, fine enough near the SLO band
_DEFAULT_BUCKETS = tuple(10.0 ** (e / 4.0) for e in range(-12, 9))


def _series_name(name: str, labels: tuple[tuple[str, str], ...]) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in labels)
    return f"{name}{{{inner}}}"


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int | float = 1) -> None:
        with _LOCK:
            self.value += n

    def _force_set(self, v) -> None:
        """Facade hook (``KERNEL_CALLS[k] = v``); not part of the normal
        counter contract — counters are monotonic everywhere else."""
        with _LOCK:
            self.value = v


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        with _LOCK:
            self.value = v

    def inc(self, n: float = 1) -> None:
        with _LOCK:
            self.value += n

    def set_now(self) -> None:
        """Heartbeat idiom: record the current unix time."""
        self.set(time.time())


class Histogram:
    __slots__ = ("count", "sum", "min", "max", "bounds", "bucket_counts")

    def __init__(self, bounds: tuple[float, ...] = _DEFAULT_BUCKETS):
        self.count = 0
        self.sum = 0.0
        self.min = None
        self.max = None
        self.bounds = bounds
        self.bucket_counts = [0] * (len(bounds) + 1)  # last = +inf

    def observe(self, v: float) -> None:
        with _LOCK:
            self.count += 1
            self.sum += v
            if self.min is None or v < self.min:
                self.min = v
            if self.max is None or v > self.max:
                self.max = v
            lo, hi = 0, len(self.bounds)
            while lo < hi:  # first bound >= v
                mid = (lo + hi) // 2
                if self.bounds[mid] < v:
                    lo = mid + 1
                else:
                    hi = mid
            self.bucket_counts[lo] += 1

    def quantile(self, q: float) -> float:
        """Bucket-interpolated quantile in [0, 1]; 0.0 when empty."""
        if self.count == 0:
            return 0.0
        rank = q * self.count
        seen = 0
        for i, c in enumerate(self.bucket_counts):
            if seen + c >= rank and c:
                lo = self.bounds[i - 1] if i else (self.min or 0.0)
                hi = (self.bounds[i] if i < len(self.bounds)
                      else (self.max or lo))
                frac = (rank - seen) / c
                return lo + (hi - lo) * frac
            seen += c
        return self.max or 0.0

    def to_dict(self) -> dict:
        return {
            "count": self.count,
            "sum": self.sum,
            "min": self.min,
            "max": self.max,
            "p50": self.quantile(0.5),
            "p99": self.quantile(0.99),
        }


_LOCK = threading.RLock()


class Registry:
    """Families of labeled Counters/Gauges/Histograms."""

    def __init__(self):
        # name -> {sorted label tuple -> metric}
        self._families: dict[str, dict[tuple, object]] = {}
        self._kinds: dict[str, type] = {}

    # ------------------------------------------------------------ lookup

    def _get(self, cls, name: str, labels: dict, **ctor):
        key = tuple(sorted((k, str(v)) for k, v in labels.items()))
        with _LOCK:
            fam = self._families.setdefault(name, {})
            known = self._kinds.setdefault(name, cls)
            if known is not cls:
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{known.__name__}, requested {cls.__name__}")
            m = fam.get(key)
            if m is None:
                m = fam[key] = cls(**ctor)
            return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, _bounds=None, **labels) -> Histogram:
        ctor = {"bounds": _bounds} if _bounds is not None else {}
        return self._get(Histogram, name, labels, **ctor)

    # ---------------------------------------------------------- querying

    def family_items(self, name: str) -> list[tuple[dict, object]]:
        """(labels dict, metric) pairs of one family, label-sorted."""
        with _LOCK:
            fam = self._families.get(name, {})
            return [(dict(key), m) for key, m in sorted(fam.items())]

    def clear_family(self, name: str) -> None:
        with _LOCK:
            self._families.pop(name, None)

    def reset(self) -> None:
        """Drop every family (tests / process-level reuse)."""
        with _LOCK:
            self._families.clear()
            self._kinds.clear()

    def snapshot(self) -> dict:
        """Flat ``{series_name: value}`` with deterministic ordering.
        Counters/gauges render as numbers, histograms as dicts."""
        out = {}
        with _LOCK:
            for name in sorted(self._families):
                for key, m in sorted(self._families[name].items()):
                    sname = _series_name(name, key)
                    if isinstance(m, Histogram):
                        out[sname] = m.to_dict()
                    else:
                        out[sname] = m.value
        return out

    @staticmethod
    def delta(before: dict, after: dict) -> dict:
        """Numeric difference of two snapshots (series absent from
        ``before`` count from zero; histogram entries diff count/sum)."""
        out = {}
        for k, v in after.items():
            prev = before.get(k)
            if isinstance(v, dict):
                pc = prev["count"] if isinstance(prev, dict) else 0
                ps = prev["sum"] if isinstance(prev, dict) else 0.0
                d = {"count": v["count"] - pc, "sum": v["sum"] - ps}
                if d["count"] or d["sum"]:
                    out[k] = d
            else:
                d = v - (prev if isinstance(prev, (int, float)) else 0)
                if d:
                    out[k] = d
        return out


REGISTRY = Registry()
