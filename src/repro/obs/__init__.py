"""Unified observability plane for the mining service.

Three coordinated layers, all cheap enough to be on by default:

* ``registry`` — a process-global labeled metrics registry
  (:class:`~repro.obs.registry.Counter` /
  :class:`~repro.obs.registry.Gauge` /
  :class:`~repro.obs.registry.Histogram` families with ``snapshot()`` and
  ``delta()``). Every pre-existing telemetry fragment now feeds it: the
  ``kernels.tally.KERNEL_CALLS`` dispatch tally and its
  ``fallback:<site>`` kinds, the scheduler's queue-depth / backpressure /
  shed / watchdog-retry accounting, the batcher's fusion and pad-waste
  counters, and the per-session ``telemetry.ThroughputMeter`` rows. The
  old views (``dict(KERNEL_CALLS)``, ``MeterBank.summary()``) remain as
  thin facades over the same numbers.

* ``trace`` — nested host-side spans threaded through the full window
  lifecycle (``ingest -> schedule -> bucket/pad -> fused launch -> kernel
  dispatch -> commit/stitch -> checkpoint``). Spans land in a fixed-size
  ring buffer (O(1) per span, two clock reads, no device sync) and export
  as JSONL or Chrome trace-event JSON — load the latter straight into
  Perfetto / ``chrome://tracing``. ``step_breakdown()`` turns one
  scheduler step's spans into the per-phase attribution (barrier wait vs
  pad/fuse host work vs device launch) the batching regression needs.

* ``jaxprof`` — device-side hooks: ``jax.profiler`` trace annotations
  around the instrumented kernel entry points, an always-on recompilation
  listener feeding a ``recompiles{kernel=...}`` counter, and an optional
  one-step ``jax.profiler`` capture (``mine_serve --profile-dir``).

Import cost discipline: ``registry`` and ``trace`` are pure stdlib (the
dependency-light ``kernels.tally`` imports them); ``jaxprof`` defers its
jax imports to call time.
"""

from . import jaxprof, registry, trace
from .registry import REGISTRY
from .trace import TRACER, span

__all__ = ["REGISTRY", "TRACER", "jaxprof", "registry", "span", "trace"]
