"""Device-side observability hooks (lazy jax imports).

* ``annotate(name)`` — a ``jax.profiler.TraceAnnotation`` around an
  instrumented kernel entry point, so device timelines captured with
  ``capture_step`` (or any profiler session) carry the dispatch names the
  host spans use. Degrades to a no-op context manager when jax (or the
  profiler) is unavailable.

* ``ensure_recompile_listener()`` — the always-on generalization of the
  TR205 sentinel (``analysis.tracecheck``): a logging handler on jax's
  compile-log channels feeding ``REGISTRY`` counters
  ``recompiles{kernel=<name>}``. Idempotent and self-healing: the TR205
  sentinel's ``finally`` switches ``jax_log_compiles`` back off after an
  audit, so every call re-checks the config flag and re-enables it. The
  two compile-log loggers get ``propagate=False`` (capture, don't spill
  onto the console) — the same containment the sentinel applies
  temporarily, made permanent.

* ``capture_step(profile_dir)`` — one ``jax.profiler`` trace session
  around a block (``mine_serve --profile-dir`` wraps one scheduler step
  in it). Capture failures count into
  ``profiler_capture_errors`` instead of raising: profiling must never
  take down the serving loop.
"""

from __future__ import annotations

import contextlib
import logging
import re

from .registry import REGISTRY

# same message shape the TR205 sentinel parses — one source of truth
# would couple obs to the analysis plane, so the regex is duplicated and
# tests/test_obs.py pins the two against each other
_COMPILE_RE = re.compile(r"Compiling ([\w.<>-]+) with global shapes")
_LOGGER_NAMES = ("jax._src.interpreters.pxla", "jax._src.dispatch")

_HANDLER: _RecompileHandler | None = None


class _RecompileHandler(logging.Handler):
    def __init__(self):
        super().__init__(level=logging.WARNING)

    def emit(self, record):
        m = _COMPILE_RE.search(record.getMessage())
        if m:
            REGISTRY.counter("recompiles", kernel=m.group(1)).inc()


def ensure_recompile_listener() -> bool:
    """Install (or re-arm) the recompilation listener. Returns True when
    the listener is active. Safe to call per dispatch — after the first
    install it is one import plus one config-flag read."""
    global _HANDLER
    try:
        import jax
    except ImportError:
        return False
    if _HANDLER is None:
        _HANDLER = _RecompileHandler()
        for name in _LOGGER_NAMES:
            lg = logging.getLogger(name)
            lg.addHandler(_HANDLER)
            lg.setLevel(logging.WARNING)
            lg.propagate = False
    if not jax.config.jax_log_compiles:
        jax.config.update("jax_log_compiles", True)
    return True


def annotate(name: str):
    """Context manager naming the enclosed dispatch on the device
    timeline; also keeps the recompile listener armed (the entry points
    are the one place every engine passes through)."""
    ensure_recompile_listener()
    try:
        from jax.profiler import TraceAnnotation
    except ImportError:
        return contextlib.nullcontext()
    return TraceAnnotation(name)


@contextlib.contextmanager
def capture_step(profile_dir):
    """Capture one ``jax.profiler`` trace of the enclosed block into
    ``profile_dir`` (TensorBoard/Perfetto-readable). Never raises out of
    the capture machinery itself."""
    started = False
    try:
        import jax
        jax.profiler.start_trace(str(profile_dir))
        started = True
    except Exception:
        REGISTRY.counter("profiler_capture_errors").inc()
    try:
        yield
    finally:
        if started:
            try:
                jax.profiler.stop_trace()
            except Exception:
                REGISTRY.counter("profiler_capture_errors").inc()
