"""Kernel-contract auditor — the repo's static-analysis plane.

Every regression this repo has shipped so far was a *silent contract
violation*: the streaming hot path bypassing the on-chip counters
(PR 3), a stitch-zone undercount (PR 1), kernel/XLA fold drift (PR 4).
The dispatch plane's invariants — one interpret accessor, tallied
dispatches and downgrades, donated state bricks, layout-contract brick
shapes, VMEM-admissible launches — are machine-checkable, so this
package checks them instead of relying on reviewer vigilance.

Three passes, one CLI (``python -m repro.launch.audit``):

  * ``contracts``  — Pass 1, AST kernel-contract linter (KC101–KC106):
    no import of the audited code, pure source analysis.
  * ``tracecheck`` — Pass 2, trace-time hot-path auditor (TR201–TR205):
    jit-traces the counting entry points on small shapes, audits
    jaxprs/HLO for host callbacks, dtype drift and donation, and runs a
    multi-window recompilation sentinel against a compile budget.
  * ``vmem``       — Pass 3, static VMEM budget checker (VM301–VM303):
    recomputes per-launch footprints from the layout contracts over the
    admitted dispatch envelope.

Findings can be waived in place with a ``# audit-ok: <RULE> reason``
trailing comment (see ``findings``); waivers surface in the JSON report
rather than vanishing.  The dependency direction is one-way: this
package may import the engines to trace them, the engines never import
this package (policy constants like ``ops.MAX_SEG_BRICK_LW`` live with
the dispatch code and are *validated* here).
"""

from .findings import Finding, Report  # noqa: F401
