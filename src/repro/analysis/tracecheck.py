"""Pass 2 — trace-time hot-path auditor (rules TR201–TR205).

Jit-traces the public counting entry points on small shapes and audits
what the compiler will actually run, catching hot-path regressions that
no unit test asserts on:

  TR201  host callback primitive (``pure_callback`` / ``io_callback`` /
         ``debug_callback``) inside a counting jaxpr — a device→host
         round-trip per call.
  TR202  non-integer (or 64-bit) dtype in a counting jaxpr.  The whole
         counting plane is i32/bool by contract; a float or x64 value
         means a weak-type promotion crept in (doubling VMEM traffic).
  TR203  host custom-call in the compiled HLO (the compiled-artifact
         twin of TR201, via ``launch/hlo_analysis``).
  TR204  carried-scan jit factory without buffer donation — a
         long-running stream then reallocates its machine state every
         chunk on accelerator backends.
  TR205  jit cache misses over a scripted multi-window streaming session
         exceed the per-entry-point budget.  Shape-bucketing exists so
         streaming compiles each entry point once or twice (one steady
         bucket + one flush shape); compile churn is a real latency tax
         the service bench cannot attribute.

Unlike Pass 1/3 this pass imports jax and the engines; run it under
``REPRO_KERNEL_INTERPRET=1`` on CPU hosts so the kernel residency paths
are traced too.
"""

from __future__ import annotations

import inspect
import logging
import re

import numpy as np

from .findings import Finding

# dtypes the counting plane may touch (TR202)
_ALLOWED_DTYPES = {"int32", "bool"}

# compile-log names whose recompiles are budgeted (TR205); anything else
# (one-off helpers like convert_element_type) compiles per shape by design
MONITORED_COMPILES = (
    "_a1_scan_core", "_a2_scan_core", "_map_all_segments",
    "a1_count_state_kernel", "a2_count_state_kernel",
    "a1_mapconcat_kernel", "a2_mapconcat_kernel",
)
COMPILE_BUDGET = 2  # per monitored entry point per session

_COMPILE_RE = re.compile(r"Compiling ([\w.<>-]+) with global shapes")


# ---------------------------------------------------------------- jaxpr


def _sub_jaxprs(params: dict):
    import jax.extend.core as jex_core
    kinds = (jex_core.Jaxpr, jex_core.ClosedJaxpr)
    for v in params.values():
        vs = v if isinstance(v, (list, tuple)) else [v]
        for x in vs:
            if isinstance(x, kinds):
                yield x.jaxpr if isinstance(x, jex_core.ClosedJaxpr) else x


def iter_eqns(jaxpr):
    """All equations of ``jaxpr`` including nested sub-jaxprs (scan/cond
    bodies, pjit calls, pallas kernels)."""
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn.params):
            yield from iter_eqns(sub)


def audit_jaxpr(name: str, jaxpr) -> list[Finding]:
    """TR201 (callbacks) + TR202 (dtype discipline) over one jaxpr."""
    findings = []
    seen_dtypes = set()
    for v in list(jaxpr.invars) + list(jaxpr.outvars):
        if hasattr(v.aval, "dtype"):
            seen_dtypes.add(str(v.aval.dtype))
    for eqn in iter_eqns(jaxpr):
        prim = eqn.primitive.name
        if "callback" in prim or "outside_call" in prim:
            findings.append(Finding(
                "TR201", name, 0,
                f"host callback primitive '{prim}' on the hot path"))
        for v in eqn.outvars:
            if hasattr(v.aval, "dtype"):
                seen_dtypes.add(str(v.aval.dtype))
    bad = sorted(d for d in seen_dtypes if d not in _ALLOWED_DTYPES)
    if bad:
        findings.append(Finding(
            "TR202", name, 0,
            f"non-i32 dtypes {bad} in counting jaxpr — weak-type or x64 "
            "promotion on the hot path"))
    return findings


# ------------------------------------------------------ entry registry


def _small_inputs(m=2, n=3, lcap=4, e=8):
    """Tiny episode/stream/state arrays shared by the traced entries."""
    import jax.numpy as jnp
    i32 = jnp.int32
    et = jnp.zeros((m, n), i32)
    tlo = jnp.zeros((m, n - 1), i32)
    thi = jnp.full((m, n - 1), 5, i32)
    ev_t = jnp.zeros((e,), i32)
    ev_tt = jnp.arange(e, dtype=i32)
    return et, tlo, thi, ev_t, ev_tt, m, n, lcap, e


def entry_points():
    """name -> zero-arg thunk returning a ClosedJaxpr of that entry
    traced on small shapes (the per-engine seams of ``count_dispatch``,
    plus the cross-session batcher's vmapped twins)."""
    import jax
    import jax.numpy as jnp
    from repro.core.count_a1 import _a1_scan_core
    from repro.core.count_a2 import _a2_scan_core
    from repro.core.events import TIME_NEG_INF
    from repro.core.mapconcat import _map_all_segments
    from repro.service.batcher import (_vmapped_a1, _vmapped_a2,
                                       _vmapped_mapc)

    i32 = jnp.int32
    et, tlo, thi, ev_t, ev_tt, m, n, lcap, e = _small_inputs()
    s1 = jnp.full((m, n, lcap), TIME_NEG_INF, i32)
    ptr = jnp.zeros((m, n), i32)
    c = jnp.zeros((m,), i32)
    ovf = jnp.zeros((m,), jnp.bool_)
    s2 = jnp.full((m, n), TIME_NEG_INF, i32)
    q = 2  # segments
    wt = jnp.zeros((q, e), i32)
    wtt = jnp.broadcast_to(ev_tt, (q, e))
    tau = jnp.array([0, e // 2, e], i32)
    w = jnp.full((m,), 10, i32)  # per-episode max occurrence span

    a1_args = (et, tlo, thi, ev_t, ev_tt, s1, ptr, c, ovf)
    a2_args = (et, tlo, thi, ev_t, ev_tt, s2, c)
    mapc_args = (wt, wtt, et, tlo, thi, tau, w)
    lane = lambda x: x[None]  # noqa: E731 — one-lane batcher axis

    return {
        "count_a1._a1_scan_core":
            lambda: jax.make_jaxpr(_a1_scan_core)(*a1_args),
        "count_a2._a2_scan_core":
            lambda: jax.make_jaxpr(_a2_scan_core)(*a2_args),
        "mapconcat._map_all_segments":
            lambda: jax.make_jaxpr(
                lambda *a: _map_all_segments(*a, lcap))(*mapc_args),
        "batcher._vmapped_a1":
            lambda: jax.make_jaxpr(_vmapped_a1())(
                *[lane(x) for x in a1_args]),
        "batcher._vmapped_a2":
            lambda: jax.make_jaxpr(_vmapped_a2())(
                *[lane(x) for x in a2_args]),
        "batcher._vmapped_mapc":
            lambda: jax.make_jaxpr(_vmapped_mapc(lcap))(
                *[lane(x) for x in mapc_args]),
    }


def audit_entry_points() -> tuple[list[Finding], dict]:
    """TR201/TR202 over every registered entry point."""
    findings = []
    traced = []
    for name, thunk in entry_points().items():
        findings.extend(audit_jaxpr(name, thunk().jaxpr))
        traced.append(name)
    return findings, {"entry_points_traced": traced}


# ------------------------------------------------------------- TR203/4


def audit_hlo() -> tuple[list[Finding], dict]:
    """Compile the PTPE cores and audit the HLO artifact (TR203), with
    traffic totals from ``launch.hlo_analysis`` in the summary."""
    import jax
    from repro.core.count_a1 import _a1_scan_core
    from repro.core.count_a2 import _a2_scan_core
    from repro.core.events import TIME_NEG_INF
    from repro.launch.hlo_analysis import analyze
    import jax.numpy as jnp

    et, tlo, thi, ev_t, ev_tt, m, n, lcap, e = _small_inputs()
    s1 = jnp.full((m, n, lcap), TIME_NEG_INF, jnp.int32)
    cases = {
        "count_a1._a1_scan_core": (_a1_scan_core, (
            et, tlo, thi, ev_t, ev_tt, s1,
            jnp.zeros((m, n), jnp.int32), jnp.zeros((m,), jnp.int32),
            jnp.zeros((m,), jnp.bool_))),
        "count_a2._a2_scan_core": (_a2_scan_core, (
            et, tlo, thi, ev_t, ev_tt,
            jnp.full((m, n), TIME_NEG_INF, jnp.int32),
            jnp.zeros((m,), jnp.int32))),
    }
    findings, traffic = [], {}
    for name, (fn, args) in cases.items():
        text = jax.jit(fn).lower(*args).compile().as_text()
        if "custom-call" in text and "callback" in text:
            findings.append(Finding(
                "TR203", name, 0,
                "host-callback custom-call in compiled HLO"))
        traffic[name] = dict(analyze(text).__dict__)
    return findings, {"hlo_traffic": traffic}


def audit_donation() -> tuple[list[Finding], dict]:
    """TR204 — the carried-scan factories must configure buffer donation
    (checked on source: the runtime disables it on CPU by design, so the
    jit object itself cannot be inspected portably)."""
    from repro.core.count_a1 import _a1_carry_scan
    from repro.core.count_a2 import _a2_carry_scan
    findings = []
    for fac in (_a1_carry_scan, _a2_carry_scan):
        src = inspect.getsource(fac)
        if "donate_argnums" not in src:
            findings.append(Finding(
                "TR204", f"{fac.__module__}.{fac.__name__}", 0,
                "carried-scan factory without donate_argnums — machine "
                "state reallocates every chunk on accelerators"))
    return findings, {}


# --------------------------------------------------- recompile sentinel


class _CompileLog(logging.Handler):
    def __init__(self):
        super().__init__(level=logging.WARNING)
        self.names: list[str] = []

    def emit(self, record):
        m = _COMPILE_RE.search(record.getMessage())
        if m:
            self.names.append(m.group(1))


def recompile_sentinel(n_windows: int = 10,
                       budget: int = COMPILE_BUDGET):
    """TR205 — run a scripted ``n_windows``-window streaming session per
    engine and fail any monitored entry point compiling more than
    ``budget`` times.  Shape buckets make steady-state windows hit the
    jit cache; a miss per window is the regression this guards."""
    import jax
    from repro.core.episodes import EpisodeBatch
    from repro.core.events import EventStream
    from repro.core.streaming import StreamingCounter

    eps = EpisodeBatch(
        etypes=np.array([[0, 1, 2], [1, 2, 3]], np.int32),
        tlo=np.zeros((2, 2), np.int32),
        thi=np.full((2, 2), 8, np.int32))
    rng = np.random.default_rng(7)

    def windows():
        t0 = 0
        for _ in range(n_windows):
            k = int(rng.integers(40, 90))  # varied sizes, same bucket
            tt = np.sort(rng.integers(t0, t0 + 500, k)).astype(np.int32)
            ty = rng.integers(0, 4, k).astype(np.int32)
            t0 += 500
            yield EventStream(types=ty, times=tt, num_types=4)

    handler = _CompileLog()
    loggers = [logging.getLogger("jax._src.interpreters.pxla"),
               logging.getLogger("jax._src.dispatch")]
    saved = [(lg, lg.level, lg.propagate) for lg in loggers]
    jax.config.update("jax_log_compiles", True)
    for lg in loggers:
        lg.addHandler(handler)
        lg.setLevel(logging.WARNING)
        lg.propagate = False  # capture, don't spill onto the console
    try:
        for engine in ("ptpe", "mapconcatenate"):
            sc = StreamingCounter(eps, engine=engine)
            for win in windows():
                sc.update(win)
            sc.finalize()
    finally:
        jax.config.update("jax_log_compiles", False)
        for lg, lvl, prop in saved:
            lg.removeHandler(handler)
            lg.setLevel(lvl)
            lg.propagate = prop

    counts: dict[str, int] = {}
    for name in handler.names:
        for mon in MONITORED_COMPILES:
            if mon in name:
                counts[mon] = counts.get(mon, 0) + 1
    findings = [
        Finding("TR205", mon, 0,
                f"{c} jit compiles across a {n_windows}-window streaming "
                f"session (budget {budget}) — shape bucketing is not "
                "holding")
        for mon, c in sorted(counts.items()) if c > budget]
    summary = {"recompiles": counts,
               "recompile_budget": budget,
               "compile_events_total": len(handler.names)}
    return findings, summary


def run(sentinel: bool = True):
    """All of Pass 2. Returns (findings, summary)."""
    findings, summary = audit_entry_points()
    for fn in (audit_hlo, audit_donation) + \
            ((recompile_sentinel,) if sentinel else ()):
        f, s = fn()
        findings.extend(f)
        summary.update(s)
    return findings, summary
