"""Pass 3 — static VMEM budget checker (rules VM301–VM304).

Recomputes, from the layout contracts alone, the VMEM-resident bytes of
each Pallas launch the dispatch policy can admit — the same arithmetic
the BlockSpecs in ``kernels/a1_count.py`` / ``a2_count.py`` imply — and
fails any admitted configuration whose footprint exceeds the budget.
Before this pass, an oversized commit window overflowed VMEM with a
Mosaic allocation error at runtime as the only signal; now the admission
bound (``ops.MAX_SEG_BRICK_LW``) is checked against the budget at audit
time, and the runtime guard in ``ops.segment_bricks`` keeps the bound.

The model is deliberately conservative:
  * every operand block is counted on both the input and output side
    (aliased pairs included — Mosaic still windows both), and
  * everything is doubled for the pipeline's double buffering.

Per-block bytes = prod(block shape) × 4 (the counting plane is i32-only,
enforced by the Pass 2 dtype rule).

The *admitted envelope* (``ADMITTED``) is part of the audited policy: it
mirrors what dispatch actually accepts today (N padded to sublanes up to
``MAX_N``, ``lcap`` up to ``MAX_LCAP``, event chunks up to
``DEFAULT_BLOCK_E``, segment windows up to ``MAX_SEG_BRICK_LW``).
Widening the envelope without budget headroom turns the audit red before
it can turn a run red.
"""

from __future__ import annotations

from .findings import Finding

# layout constants — mirrors kernels/a2_count.py (the analysis plane must
# not import the jax kernel stack; audited against it in tests)
LANES = 128
SUBLANES = 8
SEG_ROWS = 5
DEFAULT_BLOCK_E = 1024
EV_ROWS = 3  # types; times; dup

ITEM_BYTES = 4    # i32 everywhere in the counting plane
DOUBLE_BUF = 2    # Pallas pipeline double buffering

# ~16 MiB of VMEM per TPU core; leave 1 MiB headroom for Mosaic
# scratch/semaphores the block model cannot see
VMEM_BUDGET_BYTES = 15 * (1 << 20)

# admitted dispatch envelope (see module docstring)
MAX_N = 16
MAX_LCAP = 16

_POLICY_PATH = "repro/kernels/ops.py"  # where the admission policy lives


def _round_up(x: int, q: int) -> int:
    return -(-x // q) * q


def _blocks_bytes(blocks) -> int:
    total = 0
    for shape in blocks:
        n = 1
        for d in shape:
            n *= d
        total += n
    return total * ITEM_BYTES * DOUBLE_BUF


def a1_state_footprint(n_levels: int, lcap: int, block_m: int = LANES,
                       block_e: int = DEFAULT_BLOCK_E) -> int:
    """VMEM bytes of one ``a1_count_state_kernel`` grid step."""
    np_ = _round_up(max(n_levels, 1), SUBLANES)
    ins = ([(np_, block_m)] * 3            # et / tlo / thi
           + [(EV_ROWS, block_e)]          # event chunk
           + [(np_, lcap, block_m)] * 2    # s / po bricks
           + [(SUBLANES, block_m)] * 2)    # cnt / ovf
    outs = ([(SUBLANES, block_m)] * 2      # cnt / ovf
            + [(np_, lcap, block_m)] * 2)  # s / po (aliased)
    return _blocks_bytes(ins + outs)


def a2_state_footprint(n_levels: int, block_m: int = LANES,
                       block_e: int = DEFAULT_BLOCK_E) -> int:
    """VMEM bytes of one ``a2_count_state_kernel`` grid step."""
    np_ = _round_up(max(n_levels, 1), SUBLANES)
    ins = ([(np_, block_m)] * 3            # et / tlo / thi
           + [(EV_ROWS, block_e)]          # event chunk
           + [(np_, block_m)]              # s tile
           + [(SUBLANES, block_m)])        # cnt
    outs = [(SUBLANES, block_m), (np_, block_m)]
    return _blocks_bytes(ins + outs)


def mapconcat_footprint(n_levels: int, lw: int,
                        block_m: int = LANES) -> int:
    """VMEM bytes of one segmented (MapConcatenate) kernel grid step.

    The A1 and A2 variants have identical block sets — ``lcap`` affects
    only in-register state, not the windowed operands."""
    np_ = _round_up(max(n_levels, 1), SUBLANES)
    ins = ([(np_, block_m)] * 4            # et / tlo / thi / cum
           + [(SUBLANES, block_m)]         # w
           + [(1, SEG_ROWS, lw)])          # segment event brick
    outs = [(np_, block_m)] * 4 + [(SUBLANES, block_m)]
    return _blocks_bytes(ins + outs)


def check_vmem(max_seg_brick_lw: int,
               budget: int = VMEM_BUDGET_BYTES):
    """Sweep the admitted dispatch envelope against the VMEM budget.

    ``max_seg_brick_lw`` is the admission bound the runtime enforces
    (``ops.MAX_SEG_BRICK_LW`` — passed in so this module stays
    import-light). Returns (findings, summary).
    """
    findings: list[Finding] = []
    worst = {"a1_state": 0, "a2_state": 0, "mapconcat": 0}

    for n in range(2, MAX_N + 1):
        for lcap in (4, 8, MAX_LCAP):
            b = a1_state_footprint(n, lcap)
            worst["a1_state"] = max(worst["a1_state"], b)
            if b > budget:
                findings.append(Finding(
                    "VM301", _POLICY_PATH, 0,
                    f"a1 state launch (N={n}, lcap={lcap}) needs "
                    f"{b / 2**20:.1f} MiB VMEM > budget "
                    f"{budget / 2**20:.1f} MiB"))
        b = a2_state_footprint(n)
        worst["a2_state"] = max(worst["a2_state"], b)
        if b > budget:
            findings.append(Finding(
                "VM301", _POLICY_PATH, 0,
                f"a2 state launch (N={n}) needs {b / 2**20:.1f} MiB "
                f"VMEM > budget {budget / 2**20:.1f} MiB"))
        # largest admitted segment window — the policy constant under test
        b = mapconcat_footprint(n, max_seg_brick_lw)
        worst["mapconcat"] = max(worst["mapconcat"], b)
        if b > budget:
            findings.append(Finding(
                "VM302", _POLICY_PATH, 0,
                f"segmented launch (N={n}, LW={max_seg_brick_lw}) needs "
                f"{b / 2**20:.1f} MiB VMEM > budget "
                f"{budget / 2**20:.1f} MiB — lower MAX_SEG_BRICK_LW"))

    if max_seg_brick_lw % LANES:
        findings.append(Finding(
            "VM303", _POLICY_PATH, 0,
            f"MAX_SEG_BRICK_LW={max_seg_brick_lw} is not a multiple of "
            f"the {LANES}-lane window padding — admission and padding "
            "quanta must agree"))

    summary = {f"vmem_worst_{k}_bytes": v for k, v in worst.items()}
    summary["vmem_budget_bytes"] = budget
    return findings, summary


def check_calibration_grid(points, max_seg_brick_lw: int,
                           budget: int = VMEM_BUDGET_BYTES):
    """Sweep the dispatch-calibration grid against the same bounds
    (rule VM304).

    ``points`` is ``core.calibrate.GridSpec.points()`` — (N, M, n, q, W)
    tuples, no jax import.  A grid point whose implied segment brick
    exceeds ``max_seg_brick_lw`` would make ``ops.segment_bricks``
    decline at measurement time, so the calibration pass would record
    the XLA fallback's wall clock under a kernel engine label and the
    fitted policy would dispatch on a lie; a point over the VMEM budget
    is the same admission bug one layer down.  The brick estimate
    mirrors the runtime: ceil(n/q) events per segment plus one event
    per overlap timestep W (same-timestamp pileups are the runtime
    guard's job), rounded up to the lane quantum.
    """
    findings: list[Finding] = []
    worst_lw = worst_bytes = 0
    for (n_ep, m, n_ev, q, w) in points:
        lw = _round_up(-(-n_ev // max(q, 1)) + w, LANES)
        worst_lw = max(worst_lw, lw)
        if lw > max_seg_brick_lw:
            findings.append(Finding(
                "VM304", _POLICY_PATH, 0,
                f"calibration grid point (N={n_ep}, M={m}, n={n_ev}, "
                f"q={q}, W={w}) implies segment brick LW={lw} > admitted "
                f"MAX_SEG_BRICK_LW={max_seg_brick_lw} — the kernel "
                "engines would decline and the fit would mislabel the "
                "XLA fallback"))
            continue
        b = mapconcat_footprint(n_ep, lw)
        worst_bytes = max(worst_bytes, b)
        if b > budget:
            findings.append(Finding(
                "VM304", _POLICY_PATH, 0,
                f"calibration grid point (N={n_ep}, M={m}, n={n_ev}, "
                f"q={q}) needs {b / 2**20:.1f} MiB VMEM > budget "
                f"{budget / 2**20:.1f} MiB — shrink the grid or raise "
                "the admission bound"))
    summary = {"vmem_calibration_points": len(list(points)),
               "vmem_calibration_worst_lw": worst_lw,
               "vmem_calibration_worst_bytes": worst_bytes}
    return findings, summary
