"""Pass 1 — AST kernel-contract linter (rules KC101–KC107).

Enforces the dispatch-plane conventions the engines already follow, so a
new engine (or a refactor of an old one) cannot quietly drop them:

  KC101  ``interpret=`` literal at a call site.  The interpret flag must
         thread through ``ops._mode`` / ``kernel_mode`` so one env
         accessor governs every launch; a literal pins a kernel to one
         mode and splits the jit cache.
  KC102  raw Pallas kernel called outside its defining module by a
         function that never touches ``KERNEL_CALLS``.  Untallied
         dispatches make the tally lie — PR 3's silent-bypass bug.
  KC103  ``pallas_call`` inside a state-carried wrapper (function name
         contains ``state``) without ``input_output_aliases``.  An
         unaliased carry reallocates the machine bricks every chunk.
  KC104  ``pl.BlockSpec`` block shape written as an all-literal tuple.
         Brick shapes must come from the shared layout contract
         (``LANES``/``SUBLANES``/``lcap``/``block_e`` names) so kernel
         and host packers cannot drift apart.
  KC105  ``except NotImplementedError`` degradation arm around kernel
         dispatch that never calls ``record_fallback``.  Silent
         downgrades are invisible to telemetry and benchmarks.
  KC106  direct ``os.environ`` read of the interpret-mode variables
         outside ``kernels/tally.py``.  One accessor
         (``interpret_requested``) owns the env aliases.
  KC107  shadow dispatch tally outside the accessor module: a direct
         ``REGISTRY.counter("kernel_calls", ...)`` write, or a
         ``KERNEL_CALLS["fallback:..."]`` write instead of
         ``record_fallback``.  The ``kernel_calls`` registry family is
         owned by ``kernels/tally.py`` — a second writer lets the audit
         artifact, the health snapshot, and exported metrics drift.

``lint_source`` lints one snippet (used by the analyzer's own tests);
``lint_tree`` walks a source root and applies ``# audit-ok:`` markers.
"""

from __future__ import annotations

import ast
import pathlib

from .findings import Finding, split_suppressed

# raw pallas_call wrappers; calls anywhere outside their defining modules
# must be fronted by a KERNEL_CALLS tally (KC102)
KERNEL_WRAPPERS = frozenset({
    "a1_count_kernel", "a1_count_state_kernel", "a1_mapconcat_kernel",
    "a2_count_kernel", "a2_count_state_kernel", "a2_mapconcat_kernel",
})
KERNEL_DEF_MODULES = ("kernels/a1_count.py", "kernels/a2_count.py")

INTERPRET_ENV_VARS = ("REPRO_KERNEL_INTERPRET", "REPRO_INTERPRET_KERNELS")
ENV_ACCESSOR_MODULE = "kernels/tally.py"
# the registry family kernels/tally.py owns; KC107 rejects other writers
TALLY_FAMILY = "kernel_calls"


def _call_name(node: ast.Call) -> str:
    """Trailing name of the called object (``kops.a1_count`` -> a1_count)."""
    f = node.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return ""


def _contains_call(tree, name: str) -> bool:
    return any(isinstance(n, ast.Call) and _call_name(n) == name
               for n in ast.walk(tree))


def _touches_kernel_calls(fn: ast.AST) -> bool:
    return any(isinstance(n, ast.Name) and n.id == "KERNEL_CALLS"
               or isinstance(n, ast.Attribute) and n.attr == "KERNEL_CALLS"
               for n in ast.walk(fn))


def _handler_names(handler: ast.ExceptHandler) -> set[str]:
    t = handler.type
    elts = t.elts if isinstance(t, ast.Tuple) else [t] if t else []
    out = set()
    for e in elts:
        if isinstance(e, ast.Name):
            out.add(e.id)
        elif isinstance(e, ast.Attribute):
            out.add(e.attr)
    return out


def _uses_kernel_plane(body) -> bool:
    """Does this ``try`` body import or call into ``repro.kernels``?"""
    for stmt in body:
        for n in ast.walk(stmt):
            if isinstance(n, ast.ImportFrom) and n.module and \
                    n.module.startswith("repro.kernels"):
                return True
            if isinstance(n, ast.Name) and n.id == "kops":
                return True
            if isinstance(n, ast.Call) and \
                    _call_name(n) == "kernel_mode":
                return True
    return False


def _const_str(node) -> str | None:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


def _is_os_environ(node) -> bool:
    return (isinstance(node, ast.Attribute) and node.attr == "environ"
            and isinstance(node.value, ast.Name)
            and node.value.id == "os")


def lint_source(source: str, path: str) -> list[Finding]:
    """Lint one module's source. Returns raw findings (no suppression —
    ``lint_tree`` applies the ``# audit-ok`` markers)."""
    tree = ast.parse(source, filename=path)
    findings: list[Finding] = []
    posix = pathlib.PurePosixPath(path).as_posix()
    in_kernel_def = posix.endswith(KERNEL_DEF_MODULES)
    in_accessor = posix.endswith(ENV_ACCESSOR_MODULE)

    funcs = [n for n in ast.walk(tree)
             if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]

    for node in ast.walk(tree):
        # KC101 — interpret literal at a call site
        if isinstance(node, ast.Call):
            for kw in node.keywords:
                if kw.arg == "interpret" and \
                        isinstance(kw.value, ast.Constant) and \
                        isinstance(kw.value.value, bool):
                    findings.append(Finding(
                        "KC101", path, kw.value.lineno,
                        f"interpret={kw.value.value} literal — thread the "
                        "flag through ops._mode()/kernel_mode() instead"))

        # KC104 — all-literal BlockSpec block shape
        if isinstance(node, ast.Call) and \
                _call_name(node) == "BlockSpec" and node.args:
            shape = node.args[0]
            if isinstance(shape, ast.Tuple) and shape.elts and all(
                    isinstance(e, ast.Constant) and
                    isinstance(e.value, int) for e in shape.elts):
                vals = [e.value for e in shape.elts]
                if max(vals) > 1:  # (1, 1)-style degenerate specs are fine
                    findings.append(Finding(
                        "KC104", path, shape.lineno,
                        f"literal block shape {tuple(vals)} — derive brick "
                        "shapes from the layout contract "
                        "(LANES/SUBLANES/lcap/block_e)"))

        # KC105 — unrecorded kernel→XLA degradation
        if isinstance(node, ast.Try) and _uses_kernel_plane(node.body):
            for h in node.handlers:
                if "NotImplementedError" not in _handler_names(h):
                    continue
                body = ast.Module(body=h.body, type_ignores=[])
                if not _contains_call(body, "record_fallback"):
                    findings.append(Finding(
                        "KC105", path, h.lineno,
                        "kernel→XLA degradation arm without "
                        "record_fallback() — downgrade is invisible "
                        "to the dispatch tally"))

        # KC106 — direct env read of the interpret aliases
        if not in_accessor:
            key = None
            if isinstance(node, ast.Subscript) and \
                    _is_os_environ(node.value):
                key = _const_str(node.slice)
            elif isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "get" and \
                    _is_os_environ(node.func.value) and node.args:
                key = _const_str(node.args[0])
            if key in INTERPRET_ENV_VARS:
                findings.append(Finding(
                    "KC106", path, node.lineno,
                    f"direct os.environ read of {key} — use "
                    "kernels.tally.interpret_requested()"))

        # KC107 — shadow dispatch tally outside the accessor module
        if not in_accessor:
            if isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Attribute) and \
                    node.func.attr in ("counter", "gauge", "histogram") \
                    and node.args and _const_str(node.args[0]) \
                    == TALLY_FAMILY:
                findings.append(Finding(
                    "KC107", path, node.lineno,
                    f"direct registry write to the {TALLY_FAMILY!r} "
                    "family — the dispatch tally is owned by "
                    "kernels.tally (KERNEL_CALLS / record_fallback)"))
            if isinstance(node, ast.Subscript) and (
                    isinstance(node.value, ast.Name)
                    and node.value.id == "KERNEL_CALLS"
                    or isinstance(node.value, ast.Attribute)
                    and node.value.attr == "KERNEL_CALLS"):
                key = _const_str(node.slice)
                if key is not None and key.startswith("fallback:"):
                    findings.append(Finding(
                        "KC107", path, node.lineno,
                        f"KERNEL_CALLS[{key!r}] written directly — "
                        "record a degradation through "
                        "kernels.tally.record_fallback(site)"))

    for fn in funcs:
        # KC102 — untallied raw kernel dispatch outside defining module
        if not in_kernel_def:
            calls = [n for n in ast.walk(fn) if isinstance(n, ast.Call)
                     and _call_name(n) in KERNEL_WRAPPERS]
            if calls and not _touches_kernel_calls(fn):
                findings.append(Finding(
                    "KC102", path, calls[0].lineno,
                    f"{_call_name(calls[0])}() dispatched without a "
                    "KERNEL_CALLS tally in the same function"))

        # KC103 — state-carried pallas_call without donation aliases
        if "state" in fn.name:
            for n in ast.walk(fn):
                if isinstance(n, ast.Call) and \
                        _call_name(n) == "pallas_call" and not any(
                            kw.arg == "input_output_aliases"
                            for kw in n.keywords):
                    findings.append(Finding(
                        "KC103", path, n.lineno,
                        f"state-carried pallas_call in {fn.name}() "
                        "without input_output_aliases — the machine "
                        "bricks reallocate every chunk"))

    return findings


def lint_tree(root) -> tuple[list[Finding], list[Finding], dict]:
    """Lint every ``*.py`` under ``root``.

    Returns (active findings, suppressed findings, summary dict); paths
    in findings are relative to ``root``'s parent so reports read like
    ``repro/core/...``.
    """
    root = pathlib.Path(root)
    findings: list[Finding] = []
    sources: dict[str, list[str]] = {}
    n_files = 0
    for py in sorted(root.rglob("*.py")):
        rel = py.relative_to(root.parent).as_posix()
        text = py.read_text()
        sources[rel] = text.splitlines()
        findings.extend(lint_source(text, rel))
        n_files += 1
    active, waived = split_suppressed(findings, sources)
    return active, waived, {"files_linted": n_files}
