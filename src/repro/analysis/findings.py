"""Finding records + suppression comments shared by the audit passes.

A finding pins one violation to a (rule, file, line) triple with a
human-readable message.  Any finding can be suppressed at its source line
with a trailing marker comment::

    segs = build_bricks(...)  # audit-ok: KC104 scalar-prefetch row

The marker names the rule it waives (one rule per marker; repeat the
marker to waive several) and should carry a short justification after the
rule id — the linter does not parse the justification, reviewers do.
Suppressions are themselves reported (``Report.suppressed``) so a waiver
can never disappear silently from the JSON artifact.
"""

from __future__ import annotations

import dataclasses
import json
import re

_SUPPRESS_RE = re.compile(r"#\s*audit-ok:\s*([A-Z]+\d+)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    rule: str      # e.g. "KC105"
    path: str      # repo-relative path
    line: int      # 1-based
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def suppressed_rules(source_line: str) -> set[str]:
    """Rule ids waived by ``# audit-ok: <RULE>`` markers on this line."""
    return set(_SUPPRESS_RE.findall(source_line))


def split_suppressed(findings, source_lines_by_path):
    """Partition ``findings`` into (active, suppressed) using the marker
    comment on each finding's own source line.

    ``source_lines_by_path`` maps repo-relative path -> list of lines.
    """
    active, waived = [], []
    for f in findings:
        lines = source_lines_by_path.get(f.path)
        line = lines[f.line - 1] if lines and 0 < f.line <= len(lines) \
            else ""
        (waived if f.rule in suppressed_rules(line) else active).append(f)
    return active, waived


@dataclasses.dataclass
class Report:
    """Aggregated audit result across passes."""

    findings: list = dataclasses.field(default_factory=list)
    suppressed: list = dataclasses.field(default_factory=list)
    summary: dict = dataclasses.field(default_factory=dict)

    def extend(self, findings, suppressed=(), **summary) -> None:
        self.findings.extend(findings)
        self.suppressed.extend(suppressed)
        self.summary.update(summary)

    @property
    def ok(self) -> bool:
        return not self.findings

    def to_json(self) -> str:
        return json.dumps({
            "ok": self.ok,
            "findings": [f.to_json() for f in self.findings],
            "suppressed": [f.to_json() for f in self.suppressed],
            "summary": self.summary,
        }, indent=2, sort_keys=True)

    def format(self) -> str:
        out = [f.format() for f in self.findings]
        out += [f"{f.format()}  [suppressed]" for f in self.suppressed]
        verdict = "AUDIT CLEAN" if self.ok else \
            f"AUDIT FAILED: {len(self.findings)} finding(s)"
        if self.suppressed:
            verdict += f" ({len(self.suppressed)} suppressed)"
        out.append(verdict)
        return "\n".join(out)
