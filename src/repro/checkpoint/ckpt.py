"""Atomic checkpoint save/restore for sharded pytrees.

Two-phase protocol: leaves are written into ``step_N.tmp/`` (one .npy per
leaf keyed by its tree path + this host's process index), fsynced, a
manifest (step, config hash, leaf index, tree structure) is written LAST,
and the directory is atomically renamed to ``step_N/``. A crash at any
point leaves either a complete checkpoint or an ignorable ``.tmp`` — the
restore path only ever sees manifests of complete checkpoints, and boots
from the newest one (torn checkpoints are skipped, older complete ones are
used instead: the restart path after a node failure).

On a real multi-host cluster each host writes only its addressable shards
(shard-per-host layout); this container is single-host so leaves are whole.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
from pathlib import Path

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


def config_fingerprint(obj) -> str:
    return hashlib.sha256(repr(obj).encode()).hexdigest()[:16]


def save(root: str | os.PathLike, step: int, tree, config_hash: str = "",
         process_index: int | None = None) -> Path:
    root = Path(root)
    root.mkdir(parents=True, exist_ok=True)
    pidx = jax.process_index() if process_index is None else process_index
    final = root / f"step_{step:08d}"
    tmp = root / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    index = []
    for path, leaf in leaves:
        key = _path_str(path)
        fname = f"{key.replace('/', '.')}.p{pidx}.npy"
        arr = np.asarray(leaf)
        with open(tmp / fname, "wb") as f:
            np.save(f, arr)
            f.flush()
            os.fsync(f.fileno())
        index.append({"key": key, "file": fname, "shape": list(arr.shape),
                      "dtype": str(arr.dtype)})
    manifest = {"step": step, "config_hash": config_hash,
                "process_index": pidx, "leaves": index,
                "treedef": jax.tree_util.tree_structure(tree).__repr__()}
    mpath = tmp / "MANIFEST.json"
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    return final


def latest_step(root: str | os.PathLike) -> int | None:
    root = Path(root)
    if not root.exists():
        return None
    steps = []
    for d in root.iterdir():
        if d.is_dir() and d.name.startswith("step_") \
                and not d.name.endswith(".tmp") \
                and (d / "MANIFEST.json").exists():
            steps.append(int(d.name.split("_")[1]))
    return max(steps) if steps else None


def read_leaf(root: str | os.PathLike, key: str, step: int | None = None,
              default=None):
    """Read one leaf of a complete checkpoint by its tree-path key,
    without materializing the rest of the tree. Used by the wire server's
    boot recovery to fetch transport-layer leaves (``wire/last_seq``)
    that ride in the session checkpoint but are not part of the session's
    ``load_state_dict`` contract. Returns ``default`` when the key (or
    any complete checkpoint) is absent."""
    root = Path(root)
    if step is None:
        step = latest_step(root)
    if step is None:
        return default
    d = root / f"step_{step:08d}"
    manifest = json.loads((d / "MANIFEST.json").read_text())
    for e in manifest["leaves"]:
        if e["key"] == key:
            return np.load(d / e["file"])
    return default


def prune(root: str | os.PathLike, keep: int = 2) -> int:
    """Delete all but the newest ``keep`` complete checkpoints under
    ``root`` (plus any torn ``.tmp`` debris). A daemon checkpointing
    every committed window would otherwise grow the store without bound.
    Returns directories removed."""
    root = Path(root)
    if not root.exists():
        return 0
    removed = 0
    complete = []
    for d in root.iterdir():
        if not d.is_dir():
            continue
        if d.name.endswith(".tmp"):
            shutil.rmtree(d, ignore_errors=True)
            removed += 1
        elif d.name.startswith("step_") and (d / "MANIFEST.json").exists():
            complete.append(d)
    complete.sort(key=lambda d: int(d.name.split("_")[1]))
    for d in complete[:-keep] if keep else complete:
        shutil.rmtree(d, ignore_errors=True)
        removed += 1
    return removed


def restore(root: str | os.PathLike, tree_like, step: int | None = None,
            config_hash: str = "", process_index: int | None = None):
    """Load into the structure of ``tree_like`` (arrays or SDS). Returns
    (tree, step). Raises FileNotFoundError if no complete checkpoint."""
    root = Path(root)
    if step is None:
        step = latest_step(root)
    if step is None:
        raise FileNotFoundError(f"no complete checkpoint under {root}")
    pidx = jax.process_index() if process_index is None else process_index
    d = root / f"step_{step:08d}"
    manifest = json.loads((d / "MANIFEST.json").read_text())
    if config_hash and manifest["config_hash"] \
            and manifest["config_hash"] != config_hash:
        raise ValueError(
            f"checkpoint config hash {manifest['config_hash']} != "
            f"{config_hash} — refusing to restore a different model")
    by_key = {e["key"]: e for e in manifest["leaves"]}
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    out = []
    for path, leaf in leaves:
        key = _path_str(path)
        e = by_key[key]
        arr = np.load(d / e["file"].replace(f".p{manifest['process_index']}",
                                            f".p{pidx}"))
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out), step
