from .ckpt import config_fingerprint, latest_step, restore, save

__all__ = ["save", "restore", "latest_step", "config_fingerprint"]
