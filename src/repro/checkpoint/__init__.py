from .ckpt import (config_fingerprint, latest_step, prune, read_leaf,
                   restore, save)

__all__ = ["save", "restore", "latest_step", "config_fingerprint",
           "read_leaf", "prune"]
