"""Paper Fig. 9: one-pass (A1 on everything) vs two-pass (A2 cull → A1)
execution time, elimination rates, and speedups across datasets/thresholds.
"""

from __future__ import annotations

import numpy as np

from repro.core import count_one_pass, count_two_pass

from .common import (Report, culture_stream, random_candidates, sym26_stream,
                     timeit)


def run(seconds: int = 20) -> Report:
    rep = Report("fig9_twopass")
    streams = {"sym26": sym26_stream(seconds=seconds)[0]}
    for name in ("synth-33", "synth-34", "synth-35"):
        streams[name] = culture_stream(name, seconds=seconds)
    for sname, stream in streams.items():
        for n, m in ((3, 512), (4, 1024)):
            eps = random_candidates(m, n, seed=n * 7 + len(sname))
            for theta_frac, tname in ((0.5, "high"), (0.1, "low")):
                # θ as a fraction of the busiest 1-event count
                counts1 = np.array([(stream.types == t).sum()
                                    for t in range(stream.num_types)])
                theta = max(2, int(counts1.max() * theta_frac
                                   * (0.05 if n >= 4 else 0.15)))
                t2 = timeit(lambda: count_two_pass(stream, eps, theta,
                                                   engine="ptpe"),
                            repeats=2)
                t1 = timeit(lambda: count_one_pass(stream, eps, theta,
                                                   engine="ptpe"),
                            repeats=2)
                res = count_two_pass(stream, eps, theta, engine="ptpe")
                r1 = count_one_pass(stream, eps, theta, engine="ptpe")
                assert (res.frequent == r1.frequent).all(), \
                    "two-pass changed the frequent set!"
                rep.add(f"{sname}_N{n}_{tname}", t2,
                        one_pass_s=round(t1, 4), two_pass_s=round(t2, 4),
                        speedup=round(t1 / t2, 2),
                        eliminated=round(res.eliminated_frac, 4),
                        theta=theta)
    rep.save()
    return rep


if __name__ == "__main__":
    run()
