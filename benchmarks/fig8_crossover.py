"""Paper Table 1 + Fig. 8: crossover points (#episodes below which
MapConcatenate wins) per episode size, and the f(N) = a/N + b vs a·N + b
fit comparison.

Segment parallelism needs real parallel hardware (the paper's thread
blocks; our mesh devices) — on one device PTPE wins at any M (fig7). The
crossover is therefore measured in a subprocess with 8 host devices, where
``mapconcatenate_sharded`` genuinely fans segments out."""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

import numpy as np

from .common import Report

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, time
    import numpy as np
    import jax
    from repro.core import count_dispatch
    from repro.core.mapconcat import mapconcatenate_sharded
    from repro.data import sym26
    from benchmarks.common import random_candidates, timeit

    mesh = jax.make_mesh((8,), ("data",))
    stream, _ = sym26(seconds=%SECONDS%, seed=0)
    out = {}
    for n in (2, 3, 4, 5, 6):
        probes = []
        for m in (8, 16, 32, 64, 128, 256):
            eps = random_candidates(m, n, seed=n * 31 + m)
            t_p = timeit(lambda: count_dispatch(stream, eps, engine="ptpe"),
                         repeats=2)
            t_m = timeit(lambda: mapconcatenate_sharded(stream, eps, mesh),
                         repeats=2)
            probes.append((m, t_p, t_m))
        # crossover: first M where PTPE <= MapConcat (log-interp between)
        x = probes[-1][0]
        prev = None
        for m, t_p, t_m in probes:
            r = t_p / t_m
            if r <= 1.0:
                if prev is None:
                    x = m
                else:
                    pm, pr = prev
                    f = np.log(pr) / max(np.log(pr) - np.log(r), 1e-9)
                    x = int(np.exp(np.log(pm) + f * (np.log(m)
                                                     - np.log(pm))))
                break
            prev = (m, r)
        out[n] = {"crossover": x,
                  "probes": [(m, round(tp, 4), round(tm, 4))
                             for m, tp, tm in probes]}
    print(json.dumps(out))
""")


def run(seconds: int = 15) -> Report:
    rep = Report("fig8_crossover")
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    script = _SCRIPT.replace("%SECONDS%", str(seconds))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, cwd="/root/repo")
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    data = json.loads(out.stdout.strip().splitlines()[-1])
    ns = np.array(sorted(int(k) for k in data), float)
    xs = np.array([data[str(int(n))]["crossover"] for n in ns], float)
    for n, x in zip(ns, xs):
        rep.add(f"crossover_N{int(n)}", 0.0, crossover=int(x),
                probes=data[str(int(n))]["probes"])
    A1 = np.stack([1.0 / ns, np.ones_like(ns)], 1)
    A2 = np.stack([ns, np.ones_like(ns)], 1)
    c1, res1, *_ = np.linalg.lstsq(A1, xs, rcond=None)
    c2, res2, *_ = np.linalg.lstsq(A2, xs, rcond=None)
    r1 = float(res1[0]) if len(res1) else 0.0
    r2 = float(res2[0]) if len(res2) else 0.0
    rep.add("fit", 0.0, recip_a=round(float(c1[0]), 1),
            recip_b=round(float(c1[1]), 1), recip_resid=round(r1, 1),
            linear_resid=round(r2, 1),
            reciprocal_fit_better=bool(r1 <= r2))
    rep.save()
    return rep


if __name__ == "__main__":
    run()
