"""Paper Fig. 11: accelerator engine vs optimized sequential CPU baseline.

The paper's GPU-vs-quad-core comparison maps to: our lane-vectorized XLA
engine (the "GPU" role — episodes on vector lanes) vs (a) the literal
sequential pseudocode (pure Python, the paper's Algorithm 1 as written) and
(b) an optimized sequential implementation (numpy per-event batch update —
the "hand-optimized CPU code" arm). Speedups reported at several batch
widths; the paper reports ~15× for its dataset/threshold point."""

from __future__ import annotations

import numpy as np

from repro.core import count_a1_sequential
from repro.core.count_a1 import count_a1_vectorized
from repro.core.events import TIME_NEG_INF

from .common import Report, random_candidates, sym26_stream, timeit


def count_a1_numpy_batch(stream, eps, lcap: int = 4):
    """Optimized sequential baseline: one Python loop over events, numpy
    over the episode batch (no JIT) — a fair 'optimized CPU' arm."""
    m, n = eps.etypes.shape
    s = np.full((m, n, lcap), TIME_NEG_INF, np.int64)
    ptr = np.zeros((m, n), np.int64)
    count = np.zeros(m, np.int64)
    et, tlo, thi = eps.etypes, eps.tlo, eps.thi
    for e, t in zip(stream.types, stream.times):
        match = et == e
        delta = t - s[:, :-1, :]
        ok = ((delta > tlo[:, :, None]) & (delta <= thi[:, :, None])
              ).any(-1)
        advance = np.concatenate([np.ones((m, 1), bool), ok], 1) & match
        complete = advance[:, -1]
        store = advance.copy()
        store[:, -1] = False
        store &= ~complete[:, None]
        idx = np.nonzero(store)
        s[idx[0], idx[1], ptr[idx]] = t
        ptr[idx] = (ptr[idx] + 1) % lcap
        s[complete] = TIME_NEG_INF
        ptr[complete] = 0
        count += complete
    return count


def run(seconds: int = 10) -> Report:
    rep = Report("fig11_engine_vs_seq")
    stream, _ = sym26_stream(seconds=seconds)
    for m in (64, 512, 2048):
        eps = random_candidates(m, 4, seed=m)
        t_vec = timeit(lambda: count_a1_vectorized(stream, eps), repeats=2)
        t_np = timeit(lambda: count_a1_numpy_batch(stream, eps),
                      repeats=1, warmup=0)
        if m <= 64:  # the pure-Python oracle is too slow for bigger M
            t_py = timeit(lambda: count_a1_sequential(stream, eps),
                          repeats=1, warmup=0)
        else:
            t_py = float("nan")
        # correctness cross-check at every width
        np.testing.assert_array_equal(
            count_a1_numpy_batch(stream, eps),
            count_a1_vectorized(stream, eps)[0])
        rep.add(f"M{m}", t_vec,
                engine_s=round(t_vec, 4), numpy_seq_s=round(t_np, 4),
                python_seq_s=(round(t_py, 4) if t_py == t_py else "n/a"),
                speedup_vs_numpy=round(t_np / t_vec, 1),
                speedup_vs_python=(round(t_py / t_vec, 1)
                                   if t_py == t_py else "n/a"))
    rep.save()
    return rep


if __name__ == "__main__":
    run()
