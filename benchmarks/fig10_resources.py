"""Paper Fig. 10: A1 vs A2 resource profiles.

The paper profiles registers / local-memory loads / divergent branches on
the GTX280. The TPU/JAX analogues we can measure without hardware:

  * state bytes per episode lane (the VREG/VMEM pressure that bounds how
    many episode machines fit per core — the exact quantity Obs. 5.1
    shrinks: N·LCAP·4 B for A1 vs N·4 B for A2);
  * jaxpr/HLO op counts of one scan step (static instruction pressure);
  * measured per-event·episode throughput of each engine (the end effect
    the paper's Fig. 10 explains).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import count_single_slot
from repro.core.count_a1 import DEFAULT_LCAP, count_a1_vectorized
from repro.core.count_a2 import step_single_slot
from repro.core.count_a1 import step_bounded_list
from repro.core.events import TIME_NEG_INF

from .common import Report, random_candidates, sym26_stream, timeit


def _op_count(fn, *args) -> int:
    jaxpr = jax.make_jaxpr(fn)(*args)
    return sum(1 for _ in jaxpr.jaxpr.eqns)


def run(seconds: int = 20) -> Report:
    rep = Report("fig10_resources")
    stream, _ = sym26_stream(seconds=seconds)
    m, n, lcap = 512, 4, DEFAULT_LCAP
    eps = random_candidates(m, n, seed=1)

    # --- static resource profile
    et = jnp.asarray(eps.etypes)
    tlo, thi = jnp.asarray(eps.tlo), jnp.asarray(eps.thi)
    s_a2 = jnp.full((m, n), TIME_NEG_INF, jnp.int32)
    s_a1 = jnp.full((m, n, lcap), TIME_NEG_INF, jnp.int32)
    ptr = jnp.zeros((m, n), jnp.int32)
    cnt = jnp.zeros((m,), jnp.int32)
    ovf = jnp.zeros((m,), jnp.bool_)
    ops_a2 = _op_count(
        lambda s, c: step_single_slot(s, c, et, tlo, thi, 3, 100),
        s_a2, cnt)
    ops_a1 = _op_count(
        lambda s, p, c, o: step_bounded_list(s, p, c, o, et, tlo, thi, 3,
                                             100, False),
        s_a1, ptr, cnt, ovf)
    rep.add("state_bytes_per_episode", 0.0,
            a1=int(n * lcap * 4 + n * 4), a2=int(n * 4),
            ratio=round((n * lcap * 4 + n * 4) / (n * 4), 2))
    rep.add("step_op_count", 0.0, a1=ops_a1, a2=ops_a2,
            ratio=round(ops_a1 / ops_a2, 2))

    # --- dynamic: per-(event·episode) throughput
    t_a2 = timeit(lambda: count_single_slot(stream, eps.relaxed(),
                                            inclusive_lower=True))
    t_a1 = timeit(lambda: count_a1_vectorized(stream, eps))
    ev = len(stream)
    rep.add("throughput", t_a2,
            a2_ev_eps_per_s=round(ev * m / t_a2 / 1e6, 1),
            a1_ev_eps_per_s=round(ev * m / t_a1 / 1e6, 1),
            a2_speedup_over_a1=round(t_a1 / t_a2, 2))
    rep.save()
    return rep


if __name__ == "__main__":
    run()
