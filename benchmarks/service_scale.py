"""Service scaling: sessions × ingest-rate sweep, aggregate sustained
events/sec.

The companion accelerator paper (arXiv:0905.2203) frames the mining
engines as a shared accelerator service; the figure of merit at fleet
scale is aggregate sustained events/sec across tenants, not one stream's
latency. This benchmark admits S concurrent synthetic electrode-array
sessions (three rate/window classes, so same-class tenants share shape
buckets), pushes every session's partition windows through the
ingest → schedule → batched-mine → poll loop, and reports:

* aggregate sustained events/sec (all sessions' events over the wall
  time of the drain loop — the number that must beat the fleet's summed
  acquisition rates for the chip-on-chip claim);
* per-class p50/p99 window latency;
* batcher fusion counters (requests fused into vmapped device batches),
  with an unbatched run at the largest S for comparison.

Measured columns are steady state: before the timed sweep, one untimed
warmup fleet runs at the largest S in each mode so every (kind,
shape-bucket, lane-bucket) jit compile is paid outside the measurement.
Without it the comparison is compile-order, not architecture — the mode
that happens to run first pays every cold compile and the later one
inherits the warm caches. ``--cold`` skips the warmup to measure
first-contact behavior (expect the batched column to trail there: fused
lane-bucket compiles are extra work the serial baseline never does).
The fusion win this benchmark exists to track — one dispatch per bucket
instead of S — needs host parallelism or an accelerator to show; on a
single-core host the scheduler's adaptive lane cap keeps the batched
path near-serial and the columns converge.

Usage:
  PYTHONPATH=src python benchmarks/service_scale.py [--smoke]
      [--sessions 2 4 8] [--seconds 8] [--cold]
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

try:  # package mode (python -m benchmarks.run)
    from .common import Report
except ImportError:  # direct script mode
    sys.path.insert(0, str(Path(__file__).resolve().parent))
    from common import Report

from repro.data import partition_windows, sym26  # noqa: E402
from repro.obs import TRACER, span  # noqa: E402
from repro.obs.trace import step_breakdown  # noqa: E402
from repro.service import (MiningService, SchedulerPolicy,  # noqa: E402
                           SessionConfig)

CLASSES = (  # (rate_hz, window_ms): three tenant shapes
    (15.0, 2000), (25.0, 2000), (40.0, 4000))


def _feeds(num_sessions: int, seconds: int):
    feeds = []
    for i in range(num_sessions):
        rate, window_ms = CLASSES[i % len(CLASSES)]
        stream, _ = sym26(seconds=seconds, rate_hz=rate, seed=100 + i)
        cfg = SessionConfig(intervals=((5, 10),), theta=3, max_level=3,
                            window_ms=window_ms, history_limit=8)
        wins = list(partition_windows(stream, window_ms))
        feeds.append((f"array-{i}", cfg, wins, len(stream)))
    return feeds


def _run_fleet(num_sessions: int, seconds: int, batching: bool):
    feeds = _feeds(num_sessions, seconds)
    svc = MiningService(
        policy=SchedulerPolicy(max_sessions=num_sessions,
                               max_pending_windows=64),
        batching=batching)
    for sid, cfg, wins, _ in feeds:
        svc.create_session(sid, cfg)
    # obs spans time the drain loop (bench.fleet is the wall clock) and
    # step_breakdown() attributes it per phase — barrier wait vs pad/fuse
    # host work vs device launch — from the same trace the service writes
    TRACER.clear()
    with span("bench.fleet", sessions=num_sessions, batched=batching):
        for sid, _, wins, _ in feeds:
            for j, w in enumerate(wins):
                svc.ingest(sid, w, final=j == len(wins) - 1)
        svc.pump()
    wall = next(e.dur for e in reversed(TRACER.events())
                if e.name == "bench.fleet")
    bd = step_breakdown()
    total_events = sum(n for _, _, _, n in feeds)
    total_windows = sum(len(wins) for _, _, wins, _ in feeds)
    stats = svc.stats()
    return {
        "wall_s": wall,
        "events": total_events,
        "windows": total_windows,
        "agg_ev_per_s": total_events / wall if wall > 0 else 0.0,
        "p50_latency_s": stats["aggregate"]["p50_latency_s"],
        "p99_latency_s": stats["aggregate"]["p99_latency_s"],
        "fused": (stats["batcher"]["fused_requests"] if batching else 0),
        "batches": (stats["batcher"]["batches"] if batching else 0),
        "flush_groups": (stats["batcher"]["flush_groups"]
                         if batching else 0),
        "gate": (stats["batcher"]["fusion_gate"] if batching else {}),
        "breakdown": bd,
    }


def _phase_cols(bd: dict) -> dict:
    return {
        "steps": bd["steps"],
        "snapshot_s": round(bd["snapshot_s"], 4),
        "bucket_pad_s": round(bd["bucket_pad_s"], 4),
        "mine_host_s": round(bd["mine_host_s"], 4),
        "barrier_wait_s": round(bd["barrier_wait_s"], 4),
        "pad_fuse_s": round(bd["pad_fuse_s"], 4),
        "device_launch_s": round(bd["device_launch_s"], 4),
        "stage_s": round(bd["stage_s"], 4),
        "pipeline_overlap_s": round(bd["pipeline_overlap_s"], 4),
        "phase_coverage": round(bd["coverage"], 4),
    }


def run(sessions=(2, 4, 8), seconds: int = 8, trace_out: str | None = None,
        cold: bool = False):
    rep = Report("service_scale")
    if not cold:
        # steady-state measurement: pay every jit compile (standalone
        # and fused lane buckets) before the timed sweep, both modes
        s = max(sessions)
        print(f"[service-bench] warmup: {s}-session fleet per mode "
              f"(untimed, populates jit caches)")
        _run_fleet(s, seconds, batching=True)
        _run_fleet(s, seconds, batching=False)
    for s in sessions:
        r = _run_fleet(s, seconds, batching=True)
        rep.add(f"batched/s{s}", r["wall_s"],
                sessions=s, events=r["events"], windows=r["windows"],
                agg_ev_per_s=round(r["agg_ev_per_s"]),
                p99_ms=round(r["p99_latency_s"] * 1e3, 1),
                fused=r["fused"], batches=r["batches"],
                flush_groups=r["flush_groups"],
                gate_fuse=r["gate"].get("fuse", 0),
                gate_standalone=r["gate"].get("standalone", 0),
                **_phase_cols(r["breakdown"]))
        bd = r["breakdown"]
        print(f"[service-bench] {s:2d} sessions (batched): "
              f"{r['agg_ev_per_s']:,.0f} ev/s aggregate over "
              f"{r['windows']} windows, p99 {r['p99_latency_s']*1e3:.0f} ms,"
              f" {r['fused']} scans fused into {r['batches']} batches"
              f" over {r['flush_groups']} group flushes (gate {r['gate']})")
        print(f"[service-bench]    phases: wait {bd['barrier_wait_s']:.2f}s"
              f" pad/fuse {bd['pad_fuse_s']:.2f}s"
              f" launch {bd['device_launch_s']:.2f}s"
              f" mine-host {bd['mine_host_s']:.2f}s"
              f" stage-overlap {bd['pipeline_overlap_s']:.2f}s"
              f" ({bd['coverage']:.0%} of step wall attributed)")
        if trace_out:
            # trace of the LAST batched fleet size survives (per-run clear)
            n = TRACER.export_chrome(trace_out)
            print(f"[service-bench] wrote {n} spans to {trace_out}")
    s = max(sessions)
    r = _run_fleet(s, seconds, batching=False)
    rep.add(f"unbatched/s{s}", r["wall_s"],
            sessions=s, events=r["events"], windows=r["windows"],
            agg_ev_per_s=round(r["agg_ev_per_s"]),
            p99_ms=round(r["p99_latency_s"] * 1e3, 1),
            flush_groups=0, gate_fuse=0, gate_standalone=0,
            **_phase_cols(r["breakdown"]))
    print(f"[service-bench] {s:2d} sessions (unbatched baseline): "
          f"{r['agg_ev_per_s']:,.0f} ev/s aggregate")
    rep.save()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: short streams, 8-session cap")
    ap.add_argument("--sessions", type=int, nargs="+",
                    default=None)
    ap.add_argument("--seconds", type=int, default=None)
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="export the largest batched fleet's span trace "
                         "as Chrome trace-event JSON (Perfetto-loadable)")
    ap.add_argument("--cold", action="store_true",
                    help="skip the per-mode warmup fleet: measure "
                         "first-contact (compile-bound) behavior")
    args = ap.parse_args()
    if args.smoke:
        sessions = tuple(args.sessions or (2, 8))
        seconds = args.seconds or 6
    else:
        sessions = tuple(args.sessions or (2, 4, 8, 16))
        seconds = args.seconds or 12
    run(sessions=sessions, seconds=seconds, trace_out=args.trace_out,
        cold=args.cold)


if __name__ == "__main__":
    main()
