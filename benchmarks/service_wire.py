"""Wire-transport overhead and fault-recovery cost.

What the in-process service benchmarks (service_scale.py) cannot see:
the price of the fault-tolerant transport itself. Three rows per
configuration:

* ``inproc`` — the same windows through ``MiningService`` directly
  (no sockets): the floor.
* ``wire`` — through ``WireServer``/``MiningClient`` over a Unix
  socket with per-window checkpointing: framing + CRC + JSON deltas +
  durability, the honest serving cost.
* ``wire-faults`` — same, with the deterministic fault injector
  duplicating/truncating frames: what retries, dedup, and reconnects
  add under a nasty link.

Derived columns report events/sec and the wire/in-process overhead
ratio, so a regression in the transport (or an accidentally chatty
client) shows up as a ratio jump even when absolute times drift with
the host.
"""

from __future__ import annotations

import shutil
import tempfile

from repro.data import partition_windows, sym26
from repro.launch.wire_load import FaultyClient, run_load
from repro.runtime.faultinject import FaultSpec
from repro.service import MiningService, SessionConfig
from repro.service.wire import WireServer

from .common import Report, timeit


def _windows(seconds: int, window_ms: int = 2000, seed: int = 3):
    stream, _ = sym26(seconds=seconds, seed=seed)
    wins = list(partition_windows(stream, window_ms))
    n_events = sum(int(w.types.shape[0]) for w in wins)
    return wins, n_events


def _run_inproc(cfg: SessionConfig, wins) -> None:
    svc = MiningService()
    sid = svc.create_session("bench", cfg)
    for j, w in enumerate(wins):
        svc.ingest(sid, w, final=(j == len(wins) - 1))
        svc.pump()
    svc.poll(sid)
    svc.close_session(sid)


def _run_wire(cfg: SessionConfig, wins, spec: FaultSpec,
              data_dir: str | None) -> None:
    svc = MiningService()
    srv = WireServer(svc, "unix:" + tempfile.mktemp(suffix=".sock"),
                     data_dir=data_dir)
    addr = srv.start()
    try:
        c = FaultyClient(addr, "bench", cfg, fault_spec=spec,
                         rng_seed=5, deadline_s=240.0)
        for j, w in enumerate(wins):
            c.submit(w, final=(j == len(wins) - 1))
        c.drain(deadline_s=240.0)
        c.close_session()
    finally:
        srv.shutdown(drain=False)


def _run_fleet(sessions: int, producers: int, seconds: int,
               data_dir: str) -> dict:
    """A whole fleet against one server: ``producers`` concurrent
    client threads (1 = the old serial producer)."""
    svc = MiningService()
    srv = WireServer(svc, "unix:" + tempfile.mktemp(suffix=".sock"),
                     data_dir=data_dir)
    addr = srv.start()
    try:
        return run_load(addr, sessions=sessions, seconds=seconds,
                        producers=producers,
                        session_prefix=f"fleet{producers}")
    finally:
        srv.shutdown(drain=False)


def run(seconds: int = 8, theta: int = 3, max_level: int = 3,
        fleet_sessions: int = 4):
    rep = Report("service_wire")
    cfg = SessionConfig(theta=theta, max_level=max_level, window_ms=2000)
    wins, n_events = _windows(seconds)
    quiet = FaultSpec()
    nasty = FaultSpec(seed=11, duplicate=0.10, truncate=0.05)

    t_inproc = timeit(lambda: _run_inproc(cfg, wins), repeats=3, warmup=1)
    rep.add("inproc", t_inproc, windows=len(wins), n_events=n_events,
            events_per_sec=round(n_events / t_inproc))

    tmp = tempfile.mkdtemp(prefix="wirebench-")
    try:
        t_wire = timeit(lambda: _run_wire(cfg, wins, quiet, tmp),
                        repeats=3, warmup=1)
        rep.add("wire", t_wire, windows=len(wins), n_events=n_events,
                events_per_sec=round(n_events / t_wire),
                overhead_x=round(t_wire / t_inproc, 3))

        t_faults = timeit(lambda: _run_wire(cfg, wins, nasty, tmp),
                          repeats=3, warmup=1)
        rep.add("wire-faults", t_faults, windows=len(wins),
                n_events=n_events,
                events_per_sec=round(n_events / t_faults),
                overhead_x=round(t_faults / t_inproc, 3))

        # fleet rows: the same multi-session load serial vs threaded —
        # the serial producer's wall clock includes every other array's
        # idle wait, so only the threaded row is an honest fleet number
        base = None
        for producers in (1, fleet_sessions):
            load = _run_fleet(fleet_sessions, producers, seconds, tmp)
            ev = sum(r["events"] for r in load["sessions"].values())
            t = load["elapsed_s"]
            base = base or t
            rep.add(f"fleet-s{fleet_sessions}-p{producers}", t,
                    sessions=fleet_sessions, producers=producers,
                    n_events=ev, events_per_sec=round(ev / t),
                    ok=load["ok"],
                    speedup_vs_serial=round(base / t, 3))
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    return rep.save()


if __name__ == "__main__":
    run()
