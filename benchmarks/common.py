"""Shared benchmark harness: workloads analogous to the paper's datasets,
timing helpers, CSV/JSON emission.

Datasets: ``sym26`` mirrors the paper's 26-neuron inhomogeneous-Poisson
model with embedded causal chains; ``synth-33/34/35`` stand in for the
Wagenaar cortical-culture recordings (2-1-33/34/35) — same alphabet size,
three densities — honestly labeled synthetic (the originals are not
redistributable here).
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from repro.core import EpisodeBatch
from repro.data import sym26

OUT_DIR = Path("experiments/bench")


def timeit(fn, *, repeats: int = 3, warmup: int = 1,
           reduce=np.median) -> float:
    """Wall seconds over ``repeats`` (after warmup for jit caches),
    reduced by ``reduce`` — median for throughput-style rows; pass
    ``min`` when *comparing* engines, since scheduler noise on a shared
    host is strictly additive and min is the robust estimator there."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(reduce(ts))


def timeit_group(fns: dict, *, repeats: int = 5, warmup: int = 1,
                 reduce=min) -> dict:
    """Time several callables round-robin (A B C A B C ...) and reduce
    per callable.  For *ratios* between the results (e.g. the fig7
    regret column) this is the only fair protocol on a shared host:
    back-to-back blocks put each engine in a different contention
    window, and block-to-block load swings show up as engine
    differences.  Interleaving gives every round the same environment;
    a min-reduce then discards the rounds a background burst polluted."""
    for fn in fns.values():
        for _ in range(warmup):
            fn()
    ts = {k: [] for k in fns}
    for _ in range(repeats):
        for k, fn in fns.items():
            t0 = time.perf_counter()
            fn()
            ts[k].append(time.perf_counter() - t0)
    return {k: float(reduce(v)) for k, v in ts.items()}


def sym26_stream(seconds: int = 30, seed: int = 0):
    stream, truth = sym26(seconds=seconds, seed=seed)
    return stream, truth


def culture_stream(name: str, seconds: int = 30):
    """synth-33/34/35: rising firing densities (the paper's day-33/34/35
    cultures showed increasingly bursty activity)."""
    rates = {"synth-33": 15.0, "synth-34": 25.0, "synth-35": 40.0}
    stream, _ = sym26(seconds=seconds, rate_hz=rates[name],
                      seed=hash(name) % 2**31)
    return stream


def random_candidates(m: int, n: int, num_types: int = 26,
                      interval=(5, 10), seed: int = 0,
                      include=None) -> EpisodeBatch:
    """M random N-node candidates with the given inter-event interval; the
    planted chains can be prepended via ``include``."""
    rng = np.random.default_rng(seed)
    et = rng.integers(0, num_types, size=(m, n)).astype(np.int32)
    if include is not None:
        for i, chain in enumerate(include[: m]):
            et[i, :] = np.asarray(chain[:n] + chain[: max(0, n - len(chain))],
                                  np.int32)[:n]
    tlo = np.full((m, n - 1), interval[0], np.int32)
    thi = np.full((m, n - 1), interval[1], np.int32)
    return EpisodeBatch(et, tlo, thi)


class Report:
    def __init__(self, name: str):
        self.name = name
        self.rows = []

    def add(self, label: str, seconds: float, **derived):
        self.rows.append({"label": label, "seconds": seconds, **derived})
        d = ",".join(f"{k}={v}" for k, v in derived.items())
        print(f"{self.name}/{label},{seconds*1e6:.0f},{d}")

    def save(self):
        OUT_DIR.mkdir(parents=True, exist_ok=True)
        (OUT_DIR / f"{self.name}.json").write_text(
            json.dumps(self.rows, indent=1))
        return self.rows
