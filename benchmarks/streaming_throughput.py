"""Sustained streaming throughput: carried machines vs restart-per-window.

The companion accelerator paper (arXiv:0905.2203) makes sustained
events/sec across stream partitions the figure of merit. This benchmark
counts a fixed candidate batch over a sym26 spike stream window-by-window
three ways:

* ``kernel``  — ``StreamingCounter`` with the carried Pallas kernel:
  machine state resident in the kernel's brick layout, one
  state-in/state-out launch per window (compiled on TPU; interpret mode
  with ``--kernel interpret`` — an emulation-speed *path* check on CPU,
  not a fair timing).
* ``carry``   — the carried XLA scan (``use_kernel=False``):
  shape-bucketed staging, window p+1 staged while window p counts.
* ``restart`` — the seed behavior: a fresh one-shot count per window
  (state rebuilt, per-window shapes recompiled as they vary, boundary
  occurrences lost).

Reported per window size: sustained events/sec (whole session), steady
events/sec (first, compile-warming window excluded), and the boundary
occurrences the restart baseline lost (both carried variants are asserted
bit-equal to one-shot counting on the full stream before any timing is
trusted).

A ``--segments`` sweep benchmarks the in-kernel MapConcatenate
(segments × window size): ``StreamingCounter`` on the segmented-kernel
residency (one Pallas launch per commit, grid = episode tile × time
segment, Concatenate fold fused on-chip). Besides wall-clock ev/s it
records the *serial-step proxy* — the longest per-segment event walk of a
one-shot segmentation, i.e. the per-worker critical path the paper's
mapping shortens from n to ~n/P + 2W. On CPU CI (interpret mode =
emulation speed) the proxy is the meaningful scaling signal; on TPU the
wall clock is.

A ``--devices`` sweep benchmarks the mesh-sharded residency
(``engine="mapconcat_sharded"``): one child process per device count
(``XLA_FLAGS=--xla_force_host_platform_device_count=d`` must precede the
jax import, hence subprocesses) runs the sharded streaming counter and
reports wall clock plus the per-*device* serial-step proxy — the longest
per-device segment-group walk, ceil(P/d) × steps-per-segment, i.e. the
critical path the data-axis sharding divides by d while the all-gathered
tuple fold stays O(P·N). Forced host devices share the physical CPU, so
wall clock is the TPU-side signal and the proxy the CPU CI one, as above.

Usage:
  PYTHONPATH=src python benchmarks/streaming_throughput.py \
      [--seconds 12] [--m 128] [--n 3] [--windows-ms 2000 4000 8000] \
      [--kernel auto|interpret|off] [--segments 1 2 4 8] \
      [--devices 1 2 4 8]
"""

from __future__ import annotations

import argparse
import os
import sys
from pathlib import Path

import numpy as np

try:  # package mode (python -m benchmarks.run)
    from .common import Report, random_candidates, sym26_stream
except ImportError:  # direct script mode
    sys.path.insert(0, str(Path(__file__).resolve().parent))
    from common import Report, random_candidates, sym26_stream

from repro.core import StreamingCounter, count_a1  # noqa: E402
from repro.data import partition_windows  # noqa: E402
from repro.obs import TRACER  # noqa: E402
from repro.telemetry import ThroughputMeter  # noqa: E402

_HOST_SPANS = ("stream.prepare", "stream.commit", "stream.checkpoint")


def stream_phases() -> dict:
    """Host-vs-device split of the buffered stream.* spans: prepare /
    commit / checkpoint are host-side staging, launch is the dispatch
    call's wall time (a device lower bound on accelerator backends)."""
    host = dev = 0.0
    for e in TRACER.events():
        if e.name in _HOST_SPANS:
            host += e.dur
        elif e.name == "stream.launch":
            dev += e.dur
    return {"host_s": round(host, 4), "device_s": round(dev, 4)}


def bench_carry(windows, eps, engine, use_kernel=False, num_segments=8):
    ctr = StreamingCounter(eps, engine=engine, use_kernel=use_kernel,
                           num_segments=num_segments)
    meter = ThroughputMeter()
    TRACER.clear()  # per-run phase attribution (stream_phases)
    gen = ctr.run(windows)
    for w in windows:
        meter.start()
        out = next(gen)
        meter.stop(len(w))
    return out, meter, ctr


def serial_step_proxy(stream, eps, num_segments):
    """Longest per-segment event walk of a one-shot P-way segmentation —
    the per-worker critical path (fori_loop trips per grid step) that the
    segmented kernel shortens from n to ~n/P + 2W. Interpret-mode CI uses
    this as the scaling signal; compiled runs use the wall clock."""
    from repro.core import make_segments
    w_max = int(np.asarray(eps.max_span).max())
    tau, wt, _ = make_segments(stream, num_segments, w_max)
    return int(wt.shape[1]), int(wt.shape[0])


def _sharded_child(d: int, seconds: int, m: int, n: int, windows_ms,
                   num_segments: int = 8):
    """Body of one ``--devices`` child (this process's XLA_FLAGS already
    forced ``d`` host devices): sharded streaming counter per window
    size, exactness asserted, rows printed as one JSON line."""
    import json

    try:
        from repro.kernels import ops as kops
    except ImportError:
        kops = None

    stream, truth = sym26_stream(seconds=seconds)
    eps = random_candidates(m, n,
                            include=[truth["short"][0], truth["long"][0]])
    oracle = count_a1(stream, eps, use_kernel=False)
    rows = []
    for wms in windows_ms:
        windows = list(partition_windows(stream, wms))
        calls0 = kops.KERNEL_CALLS["a1_mapc_shard"] if kops else 0
        final, meter, ctr = bench_carry(windows, eps, "mapconcat_sharded",
                                        use_kernel=True,
                                        num_segments=num_segments)
        np.testing.assert_array_equal(
            final, oracle,
            err_msg=f"sharded counts diverged at {wms}ms devices={d}")
        s = meter.summary()
        # a capable counter may still take single-device launches on every
        # commit (spans too short for one stitch-safe segment per device);
        # tag the mode — and claim the d-way proxy division — only when
        # sharded launches actually ran
        sharded_ran = (kops is not None
                       and kops.KERNEL_CALLS["a1_mapc_shard"] > calls0)
        d_eff = max(ctr._shard_d, 1) if sharded_ran else 1
        steps, p_eff = serial_step_proxy(stream, eps,
                                         max(num_segments, d_eff))
        per_dev = steps * -(p_eff // -d_eff)  # ceil(P/d) groups per device
        mode = ("sharded-kernel" if sharded_ran
                else ("kernel" if ctr._mapc_kernel else "fallback-xla"))
        rows.append({
            "label": f"mapcs/w{wms}/d{d}", "seconds": s["seconds"],
            "devices": d_eff, "segments": p_eff,
            "windows": s["windows"], "events": s["events"],
            "ev_per_s": round(s["events_per_sec"]),
            "steady_ev_per_s": round(s["steady_events_per_sec"]),
            "serial_steps_per_device": per_dev,
            "proxy_speedup_vs_1dev": round(p_eff * steps / per_dev, 3),
            "mapc_mode": mode})
    print(json.dumps(rows))


def _sharded_sweep(rep, devices, seconds, m, n, windows_ms, kernel):
    """Parent side of ``--devices``: one subprocess per device count (the
    forced-host-device flag must precede the jax import)."""
    import json
    import subprocess

    script = Path(__file__).resolve()
    root = script.parent.parent
    for d in devices:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={d}"
        env["PYTHONPATH"] = str(root / "src")
        if kernel == "interpret":
            env["REPRO_KERNEL_INTERPRET"] = "1"
        cmd = [sys.executable, str(script), "--sharded-child", str(d),
               "--seconds", str(seconds), "--m", str(m), "--n", str(n),
               "--windows-ms"] + [str(w) for w in windows_ms]
        out = subprocess.run(cmd, env=env, capture_output=True, text=True,
                             cwd=str(root))
        if out.returncode != 0:
            print(f"[stream-bench] devices={d} sweep failed:\n"
                  f"{out.stderr[-2000:]}", file=sys.stderr)
            continue
        for row in json.loads(out.stdout.strip().splitlines()[-1]):
            label = row.pop("label")
            seconds_row = row.pop("seconds")
            rep.add(label, seconds_row, **row)
            print(f"[stream-bench] {label} ({row['mapc_mode']}): "
                  f"{row['steady_ev_per_s']:,} ev/s steady, "
                  f"{row['serial_steps_per_device']} serial steps/device "
                  f"({row['proxy_speedup_vs_1dev']:.2f}x vs 1-dev)")


def bench_restart(windows, eps):
    meter = ThroughputMeter()
    total = np.zeros(eps.M, np.int64)
    for w in windows:
        meter.start()
        total += count_a1(w, eps, use_kernel=False)
        meter.stop(len(w))
    return total, meter


def run(seconds: int = 12, m: int = 128, n: int = 3,
        windows_ms=(2000, 4000, 8000), engine: str = "ptpe",
        kernel: str = "auto", segments=(), devices=()):
    if kernel == "interpret":
        os.environ["REPRO_KERNEL_INTERPRET"] = "1"
    stream, truth = sym26_stream(seconds=seconds)
    eps = random_candidates(m, n,
                            include=[truth["short"][0], truth["long"][0]])
    oracle = count_a1(stream, eps, use_kernel=False)
    rep = Report("streaming_throughput")

    if segments and kernel != "off":
        # segmented-kernel sweep: segments × window size, exactness
        # asserted per cell, serial-step proxy vs the 1-segment kernel
        steps1, _ = serial_step_proxy(stream, eps, 1)
        for wms in windows_ms:
            windows = list(partition_windows(stream, wms))
            for p in segments:
                final, meter, ctr = bench_carry(
                    windows, eps, "mapconcatenate", use_kernel=True,
                    num_segments=p)
                np.testing.assert_array_equal(
                    final, oracle,
                    err_msg=f"segmented-kernel counts diverged at "
                            f"{wms}ms P={p}")
                steps, p_eff = serial_step_proxy(stream, eps, p)
                s = meter.summary()
                mode = ("kernel" if ctr._mapc_kernel else "fallback-xla")
                rep.add(f"mapck/w{wms}/p{p}", s["seconds"],
                        segments=p_eff, windows=s["windows"],
                        events=s["events"],
                        ev_per_s=round(s["events_per_sec"]),
                        steady_ev_per_s=round(s["steady_events_per_sec"]),
                        serial_steps_per_segment=steps,
                        proxy_speedup_vs_1seg=round(steps1 / steps, 3),
                        mapc_mode=mode, **stream_phases())
                print(f"[stream-bench] mapck w={wms}ms P={p_eff} "
                      f"({mode}): {s['steady_events_per_sec']:,.0f} ev/s "
                      f"steady, serial steps/segment {steps} "
                      f"({steps1 / steps:.2f}x vs 1-seg)")

    if devices and kernel != "off":
        # mesh-sharded sweep: one subprocess per device count
        _sharded_sweep(rep, devices, seconds, m, n, windows_ms, kernel)

    for wms in windows_ms:
        windows = list(partition_windows(stream, wms))
        kernel_line = ""
        if kernel != "off":
            kfinal, meter_k, kctr = bench_carry(windows, eps, engine,
                                                use_kernel=True)
            np.testing.assert_array_equal(
                kfinal, oracle,
                err_msg=f"kernel-carry counts diverged at {wms}ms")
            sk = meter_k.summary()
            mode = ("interpret" if kernel == "interpret"
                    else ("compiled" if kctr._kernel else "fallback-scan"))
            rep.add(f"kernel/w{wms}", sk["seconds"],
                    windows=sk["windows"], events=sk["events"],
                    ev_per_s=round(sk["events_per_sec"]),
                    steady_ev_per_s=round(sk["steady_events_per_sec"]),
                    kernel_mode=mode, **stream_phases())
            kernel_line = (f"kernel({mode}) "
                           f"{sk['steady_events_per_sec']:,.0f} ev/s vs ")
        final, meter_c, _ = bench_carry(windows, eps, engine)
        carry_phases = stream_phases()
        np.testing.assert_array_equal(
            final, oracle,
            err_msg=f"carry counts diverged from one-shot at {wms}ms")
        restart_total, meter_r = bench_restart(windows, eps)
        lost = int((oracle - restart_total).sum())
        sc, sr = meter_c.summary(), meter_r.summary()
        rep.add(f"carry/w{wms}", sc["seconds"],
                windows=sc["windows"], events=sc["events"],
                ev_per_s=round(sc["events_per_sec"]),
                steady_ev_per_s=round(sc["steady_events_per_sec"]),
                **carry_phases)
        rep.add(f"restart/w{wms}", sr["seconds"],
                windows=sr["windows"], events=sr["events"],
                ev_per_s=round(sr["events_per_sec"]),
                steady_ev_per_s=round(sr["steady_events_per_sec"]),
                boundary_occurrences_lost=lost)
        speedup = (sr["seconds"] / sc["seconds"]) if sc["seconds"] else 0.0
        print(f"[stream-bench] window {wms} ms: {kernel_line}carry "
              f"{sc['steady_events_per_sec']:,.0f} ev/s steady vs restart "
              f"{sr['steady_events_per_sec']:,.0f} ev/s "
              f"({speedup:.2f}x wall), restart lost {lost} boundary "
              f"occurrences")
    rep.save()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--seconds", type=int, default=12)
    ap.add_argument("--m", type=int, default=128,
                    help="candidate batch size")
    ap.add_argument("--n", type=int, default=3, help="episode size")
    ap.add_argument("--windows-ms", type=int, nargs="+",
                    default=[2000, 4000, 8000])
    ap.add_argument("--engine", default="ptpe",
                    choices=["ptpe", "mapconcatenate", "hybrid", "mapconcat_kernel"])
    ap.add_argument("--kernel", default="auto",
                    choices=["auto", "interpret", "off"],
                    help="carried-kernel variant: auto = dispatch policy "
                         "decides (compiled on TPU, scan fallback on CPU), "
                         "interpret = force interpret-mode kernels "
                         "(path check; emulation speed), off = skip")
    ap.add_argument("--segments", type=int, nargs="*", default=[],
                    help="in-kernel MapConcatenate sweep: one "
                         "segmented-kernel run per (window size, P)")
    ap.add_argument("--devices", type=int, nargs="*", default=[],
                    help="mesh-sharded sweep: one forced-host-device-count "
                         "subprocess per d, sharded streaming counter per "
                         "window size")
    ap.add_argument("--sharded-child", type=int, default=None,
                    help=argparse.SUPPRESS)  # internal: --devices child
    args = ap.parse_args()
    if args.sharded_child is not None:
        _sharded_child(args.sharded_child, args.seconds, args.m, args.n,
                       args.windows_ms)
        return
    run(seconds=args.seconds, m=args.m, n=args.n,
        windows_ms=args.windows_ms, engine=args.engine, kernel=args.kernel,
        segments=tuple(args.segments), devices=tuple(args.devices))


if __name__ == "__main__":
    main()
