"""Sustained streaming throughput: carried machines vs restart-per-window.

The companion accelerator paper (arXiv:0905.2203) makes sustained
events/sec across stream partitions the figure of merit. This benchmark
counts a fixed candidate batch over a sym26 spike stream window-by-window
three ways:

* ``kernel``  — ``StreamingCounter`` with the carried Pallas kernel:
  machine state resident in the kernel's brick layout, one
  state-in/state-out launch per window (compiled on TPU; interpret mode
  with ``--kernel interpret`` — an emulation-speed *path* check on CPU,
  not a fair timing).
* ``carry``   — the carried XLA scan (``use_kernel=False``):
  shape-bucketed staging, window p+1 staged while window p counts.
* ``restart`` — the seed behavior: a fresh one-shot count per window
  (state rebuilt, per-window shapes recompiled as they vary, boundary
  occurrences lost).

Reported per window size: sustained events/sec (whole session), steady
events/sec (first, compile-warming window excluded), and the boundary
occurrences the restart baseline lost (both carried variants are asserted
bit-equal to one-shot counting on the full stream before any timing is
trusted).

A ``--segments`` sweep benchmarks the in-kernel MapConcatenate
(segments × window size): ``StreamingCounter`` on the segmented-kernel
residency (one Pallas launch per commit, grid = episode tile × time
segment, Concatenate fold fused on-chip). Besides wall-clock ev/s it
records the *serial-step proxy* — the longest per-segment event walk of a
one-shot segmentation, i.e. the per-worker critical path the paper's
mapping shortens from n to ~n/P + 2W. On CPU CI (interpret mode =
emulation speed) the proxy is the meaningful scaling signal; on TPU the
wall clock is.

Usage:
  PYTHONPATH=src python benchmarks/streaming_throughput.py \
      [--seconds 12] [--m 128] [--n 3] [--windows-ms 2000 4000 8000] \
      [--kernel auto|interpret|off] [--segments 1 2 4 8]
"""

from __future__ import annotations

import argparse
import os
import sys
from pathlib import Path

import numpy as np

try:  # package mode (python -m benchmarks.run)
    from .common import Report, random_candidates, sym26_stream
except ImportError:  # direct script mode
    sys.path.insert(0, str(Path(__file__).resolve().parent))
    from common import Report, random_candidates, sym26_stream

from repro.core import StreamingCounter, count_a1  # noqa: E402
from repro.data import partition_windows  # noqa: E402
from repro.telemetry import ThroughputMeter  # noqa: E402


def bench_carry(windows, eps, engine, use_kernel=False, num_segments=8):
    ctr = StreamingCounter(eps, engine=engine, use_kernel=use_kernel,
                           num_segments=num_segments)
    meter = ThroughputMeter()
    gen = ctr.run(windows)
    for w in windows:
        meter.start()
        out = next(gen)
        meter.stop(len(w))
    return out, meter, ctr


def serial_step_proxy(stream, eps, num_segments):
    """Longest per-segment event walk of a one-shot P-way segmentation —
    the per-worker critical path (fori_loop trips per grid step) that the
    segmented kernel shortens from n to ~n/P + 2W. Interpret-mode CI uses
    this as the scaling signal; compiled runs use the wall clock."""
    from repro.core import make_segments
    w_max = int(np.asarray(eps.max_span).max())
    tau, wt, _ = make_segments(stream, num_segments, w_max)
    return int(wt.shape[1]), int(wt.shape[0])


def bench_restart(windows, eps):
    meter = ThroughputMeter()
    total = np.zeros(eps.M, np.int64)
    for w in windows:
        meter.start()
        total += count_a1(w, eps, use_kernel=False)
        meter.stop(len(w))
    return total, meter


def run(seconds: int = 12, m: int = 128, n: int = 3,
        windows_ms=(2000, 4000, 8000), engine: str = "ptpe",
        kernel: str = "auto", segments=()):
    if kernel == "interpret":
        os.environ["REPRO_KERNEL_INTERPRET"] = "1"
    stream, truth = sym26_stream(seconds=seconds)
    eps = random_candidates(m, n,
                            include=[truth["short"][0], truth["long"][0]])
    oracle = count_a1(stream, eps, use_kernel=False)
    rep = Report("streaming_throughput")

    if segments and kernel != "off":
        # segmented-kernel sweep: segments × window size, exactness
        # asserted per cell, serial-step proxy vs the 1-segment kernel
        steps1, _ = serial_step_proxy(stream, eps, 1)
        for wms in windows_ms:
            windows = list(partition_windows(stream, wms))
            for p in segments:
                final, meter, ctr = bench_carry(
                    windows, eps, "mapconcatenate", use_kernel=True,
                    num_segments=p)
                np.testing.assert_array_equal(
                    final, oracle,
                    err_msg=f"segmented-kernel counts diverged at "
                            f"{wms}ms P={p}")
                steps, p_eff = serial_step_proxy(stream, eps, p)
                s = meter.summary()
                mode = ("kernel" if ctr._mapc_kernel else "fallback-xla")
                rep.add(f"mapck/w{wms}/p{p}", s["seconds"],
                        segments=p_eff, windows=s["windows"],
                        events=s["events"],
                        ev_per_s=round(s["events_per_sec"]),
                        steady_ev_per_s=round(s["steady_events_per_sec"]),
                        serial_steps_per_segment=steps,
                        proxy_speedup_vs_1seg=round(steps1 / steps, 3),
                        mapc_mode=mode)
                print(f"[stream-bench] mapck w={wms}ms P={p_eff} "
                      f"({mode}): {s['steady_events_per_sec']:,.0f} ev/s "
                      f"steady, serial steps/segment {steps} "
                      f"({steps1 / steps:.2f}x vs 1-seg)")

    for wms in windows_ms:
        windows = list(partition_windows(stream, wms))
        kernel_line = ""
        if kernel != "off":
            kfinal, meter_k, kctr = bench_carry(windows, eps, engine,
                                                use_kernel=True)
            np.testing.assert_array_equal(
                kfinal, oracle,
                err_msg=f"kernel-carry counts diverged at {wms}ms")
            sk = meter_k.summary()
            mode = ("interpret" if kernel == "interpret"
                    else ("compiled" if kctr._kernel else "fallback-scan"))
            rep.add(f"kernel/w{wms}", sk["seconds"],
                    windows=sk["windows"], events=sk["events"],
                    ev_per_s=round(sk["events_per_sec"]),
                    steady_ev_per_s=round(sk["steady_events_per_sec"]),
                    kernel_mode=mode)
            kernel_line = (f"kernel({mode}) "
                           f"{sk['steady_events_per_sec']:,.0f} ev/s vs ")
        final, meter_c, _ = bench_carry(windows, eps, engine)
        np.testing.assert_array_equal(
            final, oracle,
            err_msg=f"carry counts diverged from one-shot at {wms}ms")
        restart_total, meter_r = bench_restart(windows, eps)
        lost = int((oracle - restart_total).sum())
        sc, sr = meter_c.summary(), meter_r.summary()
        rep.add(f"carry/w{wms}", sc["seconds"],
                windows=sc["windows"], events=sc["events"],
                ev_per_s=round(sc["events_per_sec"]),
                steady_ev_per_s=round(sc["steady_events_per_sec"]))
        rep.add(f"restart/w{wms}", sr["seconds"],
                windows=sr["windows"], events=sr["events"],
                ev_per_s=round(sr["events_per_sec"]),
                steady_ev_per_s=round(sr["steady_events_per_sec"]),
                boundary_occurrences_lost=lost)
        speedup = (sr["seconds"] / sc["seconds"]) if sc["seconds"] else 0.0
        print(f"[stream-bench] window {wms} ms: {kernel_line}carry "
              f"{sc['steady_events_per_sec']:,.0f} ev/s steady vs restart "
              f"{sr['steady_events_per_sec']:,.0f} ev/s "
              f"({speedup:.2f}x wall), restart lost {lost} boundary "
              f"occurrences")
    rep.save()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--seconds", type=int, default=12)
    ap.add_argument("--m", type=int, default=128,
                    help="candidate batch size")
    ap.add_argument("--n", type=int, default=3, help="episode size")
    ap.add_argument("--windows-ms", type=int, nargs="+",
                    default=[2000, 4000, 8000])
    ap.add_argument("--engine", default="ptpe",
                    choices=["ptpe", "mapconcatenate", "hybrid", "mapconcat_kernel"])
    ap.add_argument("--kernel", default="auto",
                    choices=["auto", "interpret", "off"],
                    help="carried-kernel variant: auto = dispatch policy "
                         "decides (compiled on TPU, scan fallback on CPU), "
                         "interpret = force interpret-mode kernels "
                         "(path check; emulation speed), off = skip")
    ap.add_argument("--segments", type=int, nargs="*", default=[],
                    help="in-kernel MapConcatenate sweep: one "
                         "segmented-kernel run per (window size, P)")
    args = ap.parse_args()
    run(seconds=args.seconds, m=args.m, n=args.n,
        windows_ms=args.windows_ms, engine=args.engine, kernel=args.kernel,
        segments=tuple(args.segments))


if __name__ == "__main__":
    main()
