"""Paper Fig. 7: PTPE vs MapConcatenate vs Hybrid across episode sizes and
support thresholds (θ controls how many candidates survive to be counted,
i.e. the episode-batch width M)."""

from __future__ import annotations

from repro.core import count_dispatch

from .common import Report, random_candidates, sym26_stream, timeit


def run(seconds: int = 20) -> Report:
    rep = Report("fig7_mapping")
    stream, _ = sym26_stream(seconds=seconds)
    for n in (2, 3, 4, 5, 6):
        for m, regime in ((16, "few"), (512, "many")):
            eps = random_candidates(m, n, seed=n * 100 + m)
            t_ptpe = timeit(lambda: count_dispatch(stream, eps,
                                                   engine="ptpe"))
            t_mc = timeit(lambda: count_dispatch(stream, eps,
                                                 engine="mapconcatenate"))
            t_hy = timeit(lambda: count_dispatch(stream, eps,
                                                 engine="hybrid"))
            best = min(t_ptpe, t_mc)
            rep.add(f"N{n}_M{m}", t_hy, ptpe_s=round(t_ptpe, 4),
                    mapconcat_s=round(t_mc, 4), hybrid_s=round(t_hy, 4),
                    regime=regime,
                    hybrid_regret=round(t_hy / best, 3),
                    winner="ptpe" if t_ptpe < t_mc else "mapconcat")
    rep.save()
    return rep


if __name__ == "__main__":
    run()
