"""Paper Fig. 7: PTPE vs MapConcatenate vs Hybrid across episode sizes and
support thresholds (θ controls how many candidates survive to be counted,
i.e. the episode-batch width M).

A ``--segments`` sweep additionally times the in-kernel MapConcatenate
(``engine="mapconcat_kernel"``: one Pallas launch, grid = episode tile ×
time segment) per segment count, recording the serial-step proxy — the
per-segment event walk the two-axis grid shortens from n to ~n/P + 2W —
alongside wall clock (interpret mode is emulation speed; the proxy is the
CPU-CI scaling signal)."""

from __future__ import annotations

import numpy as np

from repro.core import count_dispatch, make_segments
from repro.core.calibrate import get_policy
from repro.core.hybrid import _mapc_kernel_available, shard_devices

from .common import (Report, random_candidates, sym26_stream, timeit,
                     timeit_group)


def run(seconds: int = 20, segments=()) -> Report:
    rep = Report("fig7_mapping")
    stream, _ = sym26_stream(seconds=seconds)
    policy = get_policy()
    for n in (2, 3, 4, 5, 6):
        for m, regime in ((16, "few"), (512, "many")):
            eps = random_candidates(m, n, seed=n * 100 + m)
            # the regret column is a ratio of these three, so they are
            # sampled interleaved (same contention window per round)
            # rather than in back-to-back blocks like the
            # throughput-style rows below
            ts = timeit_group(
                {"ptpe": lambda: count_dispatch(stream, eps,
                                                engine="ptpe"),
                 "mapc": lambda: count_dispatch(stream, eps,
                                                engine="mapconcatenate"),
                 "hybrid": lambda: count_dispatch(stream, eps,
                                                  engine="hybrid")},
                repeats=5, warmup=2)
            t_ptpe, t_mc, t_hy = ts["ptpe"], ts["mapc"], ts["hybrid"]
            best = min(t_ptpe, t_mc)
            # what the dispatcher chose (and on whose authority) for
            # these rows — the regret column's paper trail
            choice = policy.choose(
                n_events=len(stream), n_episode=n, m=m,
                kernel_ok=_mapc_kernel_available(),
                shard_devices=shard_devices())
            rep.add(f"N{n}_M{m}", t_hy, ptpe_s=round(t_ptpe, 4),
                    mapconcat_s=round(t_mc, 4), hybrid_s=round(t_hy, 4),
                    regime=regime,
                    hybrid_regret=round(t_hy / best, 3),
                    hybrid_engine=choice.engine,
                    policy_source=choice.source,
                    winner="ptpe" if t_ptpe < t_mc else "mapconcat")
    if segments:
        # tag whether the Pallas path engages here, or the rows would
        # record the XLA fallback's wall clock labeled as kernel numbers
        mode = "kernel" if _mapc_kernel_available() else "fallback-xla"
        n, m = 3, 16  # the low-M regime the segmented mapping targets
        eps = random_candidates(m, n, seed=n * 100 + m)
        w_max = int(np.asarray(eps.max_span).max())
        steps1 = int(make_segments(stream, 1, w_max)[1].shape[1])
        for p in segments:
            t_k = timeit(lambda: count_dispatch(
                stream, eps, engine="mapconcat_kernel", num_segments=p))
            tau, wt, _ = make_segments(stream, p, w_max)
            steps = int(wt.shape[1])
            rep.add(f"mapck_N{n}_M{m}_P{p}", t_k,
                    segments=int(wt.shape[0]),
                    mapck_s=round(t_k, 4),
                    serial_steps_per_segment=steps,
                    proxy_speedup_vs_1seg=round(steps1 / steps, 3),
                    mapc_mode=mode)
    rep.save()
    return rep


if __name__ == "__main__":
    run(segments=(1, 2, 4, 8))
