"""Benchmark entrypoint — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--quick]

Prints ``name,us_per_call,derived`` CSV lines (the harness contract) and
writes JSON rows under experiments/bench/. The dry-run/roofline benchmarks
(40-cell table) live in repro.launch.dryrun / repro.launch.roofline — they
need the 512-device flag and are not imported here."""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="shorter streams (CI mode)")
    ap.add_argument("--only", default=None,
                    help="comma-separated figure names (fig7,fig8,...)")
    args = ap.parse_args()
    seconds = 8 if args.quick else 20

    from . import (fig7_mapping, fig8_crossover, fig9_twopass,
                   fig10_resources, fig11_engine_vs_sequential,
                   service_scale, service_wire, streaming_throughput)
    figs = {
        "fig7": lambda: fig7_mapping.run(seconds=min(seconds, 20),
                                         segments=(1, 2, 4, 8)),
        "fig8": lambda: fig8_crossover.run(seconds=min(seconds, 15)),
        "fig9": lambda: fig9_twopass.run(seconds=min(seconds, 20)),
        "fig10": lambda: fig10_resources.run(seconds=min(seconds, 20)),
        "fig11": lambda: fig11_engine_vs_sequential.run(
            seconds=min(seconds, 10)),
        "stream": lambda: streaming_throughput.run(
            seconds=min(seconds, 12),
            segments=(1, 2) if args.quick else (1, 2, 4),
            devices=(1, 2) if args.quick else (1, 2, 4)),
        "service": lambda: service_scale.run(
            sessions=(2, 8) if args.quick else (2, 4, 8),
            seconds=min(seconds, 8)),
        "wire": lambda: service_wire.run(seconds=min(seconds, 8)),
    }
    chosen = args.only.split(",") if args.only else list(figs)
    t0 = time.perf_counter()
    print("name,us_per_call,derived")
    for name in chosen:
        figs[name]()
    print(f"# total {time.perf_counter() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
