"""Kernel-carried streaming equivalence (interpret mode).

The bug this suite pins down: stateful calls used to bypass the Pallas
kernels silently, so the streaming/service hot path never executed a
kernel line no matter what ``use_kernel`` said — and CPU CI could not see
it. Every test here (a) forces the interpret-mode dispatch policy, (b)
asserts via ``kernels.ops.KERNEL_CALLS`` that the kernel path actually
ran, and (c) asserts chunked stateful-kernel counts are bit-identical to
one-shot counting on the concatenated stream.
"""

import numpy as np
import pytest

from repro.core import (EpisodeBatch, EventStream, StreamingA2Counter,
                        StreamingCounter, StreamingMiner, count_a1,
                        count_a1_sequential, count_a2, count_a2_sequential,
                        count_dispatch, count_two_pass, mine)
from repro.core.count_a1 import count_a1_vectorized
from repro.core.count_a2 import count_single_slot
from repro.kernels import ops

NUM_TYPES = 5


@pytest.fixture(autouse=True)
def _interpret_kernels(monkeypatch):
    """Force the kernel dispatch policy on (interpret mode) and zero the
    dispatch tally, so each test can assert the Pallas path executed.
    The hybrid's availability probe is cached per process, so flipping
    the environment must also drop the cache — both ways, or a suite
    running earlier (or later) in the same process sees a stale answer."""
    from repro.core import hybrid
    monkeypatch.setenv("REPRO_KERNEL_INTERPRET", "1")
    hybrid._reset_probe_cache()
    ops.reset_kernel_calls()
    yield
    hybrid._reset_probe_cache()


def tie_heavy_stream(seed, n=160):
    rng = np.random.default_rng(seed)
    gaps = rng.choice([0, 0, 1, 2], size=n)
    times = (np.cumsum(gaps) + 1).astype(np.int32)
    types = rng.integers(0, NUM_TYPES, size=n).astype(np.int32)
    return EventStream(types, times, NUM_TYPES)


def batch():
    return EpisodeBatch(
        np.int32([[0, 1, 2], [1, 2, 3], [2, 2, 0], [4, 0, 1]]),
        np.int32([[1, 0], [0, 2], [0, 0], [0, 0]]),
        np.int32([[5, 6], [4, 7], [3, 3], [6, 2]]))


def split_by_index(stream, k):
    n = stream.types.shape[0]
    cuts = [0] + [n * j // k for j in range(1, k)] + [n]
    return [EventStream(stream.types[a:b], stream.times[a:b],
                        stream.num_types)
            for a, b in zip(cuts[:-1], cuts[1:])]


# ------------------------------------------------------ layout round-trip


def test_a1_state_layout_round_trip():
    """Host [M, N, L] layout → kernel brick → host is the identity, for a
    state mid-stream (populated lists, advanced pointers, sticky flags)."""
    stream = tie_heavy_stream(7)
    eps = batch()
    _, _, st = count_a1_vectorized(stream, eps, lcap=2, return_state=True)
    back = ops.a1_state_unpack(*ops.a1_state_layout(st), eps.M, eps.N)
    np.testing.assert_array_equal(np.asarray(back.s), np.asarray(st.s))
    np.testing.assert_array_equal(np.asarray(back.ptr), np.asarray(st.ptr))
    np.testing.assert_array_equal(np.asarray(back.count),
                                  np.asarray(st.count))
    np.testing.assert_array_equal(np.asarray(back.ovf), np.asarray(st.ovf))


def test_a2_state_layout_round_trip():
    stream = tie_heavy_stream(8)
    eps = batch().relaxed()
    _, st = count_single_slot(stream, eps, inclusive_lower=True,
                              return_state=True)
    back = ops.a2_state_unpack(*ops.a2_state_layout(st), eps.M, eps.N)
    np.testing.assert_array_equal(np.asarray(back.s), np.asarray(st.s))
    np.testing.assert_array_equal(np.asarray(back.count),
                                  np.asarray(st.count))


# ------------------------------------------- stateful one-shot-chunk APIs


def test_stateful_apis_run_kernel_and_match_scan():
    """count_a1/count_a2/count_dispatch/count_two_pass stateful modes with
    ``use_kernel=True`` must execute the Pallas kernels (instrumented) and
    equal both the scan-stateful and the one-shot results."""
    stream = tie_heavy_stream(2)
    eps = batch()
    ok = np.nonzero(np.diff(stream.times) > 0)[0] + 1
    cut = int(ok[len(ok) // 2])
    chunks = [EventStream(stream.types[:cut], stream.times[:cut], NUM_TYPES),
              EventStream(stream.types[cut:], stream.times[cut:], NUM_TYPES)]
    st_a1 = st_a2 = st_tp = st_d = None
    for ch in chunks:
        c_a1, st_a1 = count_a1(ch, eps, state=st_a1, return_state=True)
        c_a2, st_a2 = count_a2(ch, eps, state=st_a2, return_state=True)
        tp, st_tp = count_two_pass(ch, eps, theta=2, state=st_tp,
                                   return_state=True)
        c_d, st_d = count_dispatch(ch, eps, engine="hybrid", state=st_d,
                                   return_state=True)
    assert ops.KERNEL_CALLS["a1_state"] >= 4  # a1 + two_pass + dispatch × 2
    assert ops.KERNEL_CALLS["a2_state"] >= 4  # a2 + two_pass pass-1 × 2
    np.testing.assert_array_equal(c_a1, count_a1(stream, eps,
                                                 use_kernel=False))
    np.testing.assert_array_equal(c_d, c_a1)
    np.testing.assert_array_equal(c_a2, count_a2(stream, eps,
                                                 use_kernel=False))
    one = count_two_pass(stream, eps, theta=2, use_kernel=False)
    np.testing.assert_array_equal(tp.counts, one.counts)
    np.testing.assert_array_equal(tp.survived, one.survived)
    # the carried state itself is bit-identical to the scan engine's
    _, _, want = count_a1_vectorized(stream, eps, return_state=True)
    np.testing.assert_array_equal(np.asarray(st_a1.s), np.asarray(want.s))
    np.testing.assert_array_equal(np.asarray(st_a1.ptr),
                                  np.asarray(want.ptr))


@pytest.mark.parametrize("lcap", [1, 2, 4])
def test_stateful_kernel_lcap_sweep_ovf_parity(lcap):
    """Eviction-flag (ovf) parity under chunking: the kernel-carried flags
    match the scan-carried flags at every capacity, and flagged episodes
    restore to the oracle through the usual recount."""
    stream = tie_heavy_stream(1, n=200)
    eps = batch()
    ok = np.nonzero(np.diff(stream.times) > 0)[0] + 1
    cuts = [0, int(ok[len(ok) // 3]), int(ok[2 * len(ok) // 3]),
            stream.types.shape[0]]
    k_state = s_state = None
    for a, b in zip(cuts[:-1], cuts[1:]):
        ch = EventStream(stream.types[a:b], stream.times[a:b], NUM_TYPES)
        kc, kovf, k_state = ops.a1_count_stateful(ch, eps, state=k_state,
                                                  lcap=lcap)
        sc, sovf, s_state = count_a1_vectorized(ch, eps, lcap=lcap,
                                                state=s_state,
                                                return_state=True)
    np.testing.assert_array_equal(kc, sc)
    np.testing.assert_array_equal(kovf, sovf)
    np.testing.assert_array_equal(np.asarray(k_state.ovf),
                                  np.asarray(s_state.ovf))
    oracle = count_a1_sequential(stream, eps)
    exact = ~kovf
    np.testing.assert_array_equal(kc[exact], oracle[exact])


# -------------------------------------------------- streaming counters


@pytest.mark.parametrize("k", [1, 2, 3, 8])
def test_streaming_counter_kernel_carried_equals_one_shot(k):
    """Window-by-window kernel-carried counts == one-shot on the
    concatenation, including mid-tie-group splits (the index splits land
    inside tie groups of the tie-heavy stream)."""
    for seed in (0, 3):
        stream = tie_heavy_stream(seed)
        eps = batch()
        oracle = count_a1_sequential(stream, eps)
        ops.reset_kernel_calls()
        ctr = StreamingCounter(eps, engine="ptpe", use_kernel=True)
        assert ctr._kernel, "kernel residency must engage under interpret"
        for w in split_by_index(stream, k):
            ctr.update(w)
        np.testing.assert_array_equal(ctr.finalize(), oracle)
        assert ops.KERNEL_CALLS["a1_state"] >= 1


@pytest.mark.parametrize("lcap", [1, 2])
def test_streaming_counter_kernel_flagged_restored(lcap):
    """Tiny capacities force live-eviction flags through the kernel path;
    counts() must still restore exactness via the history recount."""
    stream = tie_heavy_stream(1, n=200)
    eps = batch()
    oracle = count_a1_sequential(stream, eps)
    ctr = StreamingCounter(eps, engine="ptpe", lcap=lcap, use_kernel=True)
    assert ctr._kernel
    for w in split_by_index(stream, 3):
        ctr.update(w)
    np.testing.assert_array_equal(ctr.finalize(), oracle)
    assert ops.KERNEL_CALLS["a1_state"] >= 1


def test_streaming_counter_kernel_bounded_checkpointing():
    """Bounded mode (checkpoint_interval) unpacks the kernel brick at each
    base advance and repacks the resolved state — still exact."""
    stream = tie_heavy_stream(4, n=240)
    eps = batch()
    oracle = count_a1_sequential(stream, eps)
    for lcap in (1, 2):
        ctr = StreamingCounter(eps, engine="ptpe", lcap=lcap,
                               checkpoint_interval=2, use_kernel=True)
        assert ctr._kernel
        for w in split_by_index(stream, 5):
            ctr.update(w)
        np.testing.assert_array_equal(ctr.finalize(), oracle)


def test_streaming_a2_counter_kernel_carried():
    stream = tie_heavy_stream(5)
    eps = batch()
    want = count_a2_sequential(stream, eps.relaxed())
    ctr = StreamingA2Counter(eps, use_kernel=True)
    assert ctr._kernel
    for w in split_by_index(stream, 4):
        out = ctr.update(w)
    np.testing.assert_array_equal(out, want)
    assert ops.KERNEL_CALLS["a2_state"] >= 1


def test_streaming_state_dict_round_trip_through_kernel_layout():
    """state_dict → load_state_dict → resume: the carried kernel-layout
    state round-trips through the canonical checkpoint form, and the
    resumed counter (still on the kernel path) finishes bit-identically.
    A scan-engine counter must also restore the same checkpoint (layout
    portability across dispatch modes)."""
    stream = tie_heavy_stream(6, n=200)
    eps = batch()
    oracle = count_a1_sequential(stream, eps)
    wins = split_by_index(stream, 4)
    src = StreamingCounter(eps, engine="ptpe", use_kernel=True)
    assert src._kernel
    for w in wins[:2]:
        src.update(w)
    sd = src.state_dict()
    resumed = StreamingCounter(eps, engine="ptpe", use_kernel=True)
    resumed.load_state_dict(sd)
    assert resumed._kernel
    ops.reset_kernel_calls()
    for w in wins[2:]:
        resumed.update(w)
    np.testing.assert_array_equal(resumed.finalize(), oracle)
    assert ops.KERNEL_CALLS["a1_state"] >= 1
    # same checkpoint restores onto the scan engine (and vice-versa shape)
    scan = StreamingCounter(eps, engine="ptpe", use_kernel=False)
    scan.load_state_dict(sd)
    for w in wins[2:]:
        scan.update(w)
    np.testing.assert_array_equal(scan.finalize(), oracle)


# ------------------------------------------------- miner: engine × twopass


@pytest.mark.parametrize("engine", ["ptpe", "mapconcatenate", "hybrid"])
@pytest.mark.parametrize("two_pass", [True, False])
def test_streaming_miner_kernel_equals_one_shot(engine, two_pass):
    """Cumulative kernel-carried mining ends bit-identical to one-shot
    ``mine`` on the concatenation for every engine × two-pass combination
    (the acceptance matrix). The kernel instrumentation must show the
    carried Pallas path ran whenever the ptpe machines are in play."""
    from repro.data import embedded_chain_stream
    st = embedded_chain_stream(NUM_TYPES, [1, 2, 3], (2, 6),
                               num_occurrences=25, noise_events=200,
                               t_max=15_000, seed=11)
    one = mine(st, intervals=[(2, 6)], theta=10, max_level=3,
               engine=engine, two_pass=two_pass)
    ops.reset_kernel_calls()
    miner = StreamingMiner([(2, 6)], 10, max_level=3, mode="cumulative",
                           engine=engine, two_pass=two_pass,
                           use_kernel=True)
    wins = split_by_index(st, 3)
    for i, w in enumerate(wins):
        res = miner.update(w, final=i == len(wins) - 1)
    assert len(res.frequent) == len(one.frequent)
    for fa, fb, ca, cb in zip(res.frequent, one.frequent,
                              res.counts, one.counts):
        np.testing.assert_array_equal(fa.etypes, fb.etypes)
        np.testing.assert_array_equal(fa.tlo, fb.tlo)
        np.testing.assert_array_equal(fa.thi, fb.thi)
        np.testing.assert_array_equal(ca, cb)
    if two_pass:
        assert ops.KERNEL_CALLS["a2_state"] >= 1
    if engine == "ptpe":
        assert ops.KERNEL_CALLS["a1_state"] >= 1


# ------------------------------------------------------- config plumbing


def test_use_kernel_defaults_unified():
    """The PR-3 satellite: StreamingCounter no longer defaults to False
    while everything above it defaults to True."""
    import inspect
    from repro.service import SessionConfig
    assert inspect.signature(
        StreamingCounter.__init__).parameters["use_kernel"].default is True
    assert inspect.signature(
        StreamingA2Counter.__init__).parameters["use_kernel"].default is True
    assert inspect.signature(
        StreamingMiner.__init__).parameters["use_kernel"].default is True
    assert SessionConfig().use_kernel is True


def test_count_dispatch_validates_engine_in_stateful_mode():
    """The PR-3 satellite: a bogus engine must raise even when a carried
    state short-circuits the engine dispatch."""
    stream = tie_heavy_stream(9)
    eps = batch()
    with pytest.raises(ValueError, match="bogus"):
        count_dispatch(stream, eps, engine="bogus", return_state=True)
    _, st = count_dispatch(stream, eps, engine="ptpe", return_state=True)
    with pytest.raises(ValueError, match="bogus"):
        count_dispatch(stream, eps, engine="bogus", state=st)


def test_scan_fallback_when_kernel_unavailable(monkeypatch):
    """Without a TPU or interpret mode the carried calls silently use the
    XLA scans — same bits, no kernel dispatches."""
    monkeypatch.delenv("REPRO_KERNEL_INTERPRET", raising=False)
    monkeypatch.delenv("REPRO_INTERPRET_KERNELS", raising=False)
    stream = tie_heavy_stream(0)
    eps = batch()
    ops.reset_kernel_calls()
    ctr = StreamingCounter(eps, engine="ptpe", use_kernel=True)
    assert not ctr._kernel
    for w in split_by_index(stream, 2):
        ctr.update(w)
    np.testing.assert_array_equal(ctr.finalize(),
                                  count_a1_sequential(stream, eps))
    assert ops.KERNEL_CALLS["a1_state"] == 0
