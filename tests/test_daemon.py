"""Daemon lifecycle + crash recovery: the mining service survives
disconnects, SIGTERM drains, and SIGKILL-at-a-window-boundary restarts
with bit-identical counts.

The load-bearing claims:

* a daemon SIGKILLed mid-stream at a randomized (but seeded) window
  boundary, restarted cold from its checkpoint store, and resumed from
  the last durable sequence number produces *bit-identical* per-window
  episode counts — for every engine × two-pass combination;
* SIGTERM during in-flight work commits staged windows (drain +
  quiesce + checkpoint) before exit: nothing queued is lost, nothing
  is double-counted across the restart;
* the pidfile lifecycle (start/status/stop, stale-pidfile cleanup) and
  the heartbeat gauges in ``stats()`` behave as the ops runbook says.

All daemons here are real subprocesses over Unix sockets — in-process
threads cannot be SIGKILLed honestly.
"""

import json
import os
import signal
import subprocess
import sys
import time
from pathlib import Path

import numpy as np
import pytest

from repro.core import EventStream
from repro.runtime.faultinject import kill_point
from repro.service import MiningSession, SessionConfig
from repro.service.client import MiningClient
from repro.service.daemon import MiningDaemon
from repro.service.wire import delta_payload

NUM_TYPES = 5
SRC = str(Path(__file__).resolve().parents[1] / "src")


def tie_heavy_stream(seed, n=240):
    rng = np.random.default_rng(seed)
    gaps = rng.choice([0, 0, 1, 2], size=n)
    times = (np.cumsum(gaps) + 1).astype(np.int32)
    types = rng.integers(0, NUM_TYPES, size=n).astype(np.int32)
    return EventStream(types, times, NUM_TYPES)


def split_by_index(stream, k):
    n = stream.types.shape[0]
    cuts = [0] + [n * j // k for j in range(1, k)] + [n]
    return [EventStream(stream.types[a:b], stream.times[a:b],
                        stream.num_types)
            for a, b in zip(cuts[:-1], cuts[1:])]


def local_reference(cfg, wins):
    s = MiningSession("ref", cfg)
    for j, w in enumerate(wins):
        s.enqueue(w, final=(j == len(wins) - 1))
    while s.queue_depth:
        p = s.prepare()
        s.commit(p, s.execute(p))
    return [delta_payload(d) for d in s.poll()]


def spawn_daemon(tmp_path, crash_after=None, extra=()):
    """Foreground daemon subprocess on a Unix socket under tmp_path;
    returns (Popen, address) once the pidfile reports the bound socket."""
    sock = tmp_path / "d.sock"
    data = tmp_path / "data"
    argv = [sys.executable, "-m", "repro.service.daemon",
            "--listen", f"unix:{sock}", "--data-dir", str(data),
            *extra]
    if crash_after is not None:
        argv += ["--crash-after-commits", str(crash_after)]
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")
    proc = subprocess.Popen(argv, env=env, stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True)
    pidfile = data / "daemon.pid"
    deadline = time.monotonic() + 120.0
    while time.monotonic() < deadline:
        if proc.poll() is not None:
            raise RuntimeError(
                f"daemon died at boot:\n{proc.stdout.read()}")
        doc = MiningDaemon.read_pidfile(pidfile)
        if doc and doc.get("address"):
            return proc, doc["address"]
        time.sleep(0.05)
    proc.kill()
    raise TimeoutError("daemon never became ready")


def stop_daemon(proc):
    if proc.poll() is None:
        proc.send_signal(signal.SIGTERM)
        try:
            proc.wait(timeout=90)
        except subprocess.TimeoutExpired:
            proc.kill()
            raise


# ----------------------------------------------------------- lifecycle


def test_daemon_lifecycle_pidfile_heartbeat_sigterm(tmp_path):
    proc, addr = spawn_daemon(tmp_path)
    pidfile = tmp_path / "data" / "daemon.pid"
    try:
        doc = MiningDaemon.status(pidfile)
        assert doc is not None and doc["pid"] == proc.pid
        assert doc["address"] == addr

        cfg = SessionConfig(intervals=((0, 4),), theta=3, max_level=3)
        c = MiningClient(addr, "hb", cfg, rng_seed=0)
        pong = c.ping()
        assert pong["op"] == "ping" and not pong["draining"]
        stats = c.stats()
        # the heartbeat thread feeds the obs gauges the runbook monitors
        assert stats["daemon"]["heartbeat_ts"] > 0
        assert stats["daemon"]["uptime_s"] >= 0
        assert time.time() - stats["daemon"]["heartbeat_ts"] < 30
        c.close()

        # graceful stop via the pidfile (SIGTERM + wait)
        assert MiningDaemon.stop(pidfile, timeout_s=90)
        proc.wait(timeout=30)
        assert proc.returncode == 0
        assert MiningDaemon.status(pidfile) is None
    finally:
        if proc.poll() is None:
            proc.kill()


def test_stale_pidfile_detected_after_sigkill(tmp_path):
    proc, addr = spawn_daemon(tmp_path)
    pidfile = tmp_path / "data" / "daemon.pid"
    proc.kill()  # SIGKILL: no cleanup, pidfile left behind
    proc.wait(timeout=30)
    deadline = time.monotonic() + 10
    while MiningDaemon.status(pidfile) is not None \
            and time.monotonic() < deadline:
        time.sleep(0.05)
    assert MiningDaemon.status(pidfile) is None  # stale → cleaned up
    assert not pidfile.exists()


def test_sigterm_midstream_commits_staged_windows(tmp_path):
    """Satellite acceptance: SIGTERM lands while submitted windows are
    still queued/staged (pipeline_depth=2 daemon default). The drain
    handler must quiesce staged preps and mine + checkpoint everything
    queued; after a cold restart every window is present exactly once,
    bit-identical to an unperturbed run."""
    cfg = SessionConfig(intervals=((0, 4),), theta=3, max_level=3,
                        history_limit=4)
    wins = split_by_index(tie_heavy_stream(11, n=220), 5)

    proc, addr = spawn_daemon(tmp_path)
    c = MiningClient(addr, "term", cfg, rng_seed=3, deadline_s=180.0)
    for j, w in enumerate(wins):
        c.submit(w, final=(j == len(wins) - 1))
    # SIGTERM immediately: most windows are still pending or staged
    proc.send_signal(signal.SIGTERM)
    proc.wait(timeout=120)
    assert proc.returncode == 0
    c.close()

    proc2, addr2 = spawn_daemon(tmp_path)
    try:
        c2 = MiningClient(addr2, "term", cfg, rng_seed=4,
                          deadline_s=180.0)
        c2.next_seq = c.next_seq  # same producer, resumed
        got = sorted(c2.drain(deadline_s=180),
                     key=lambda d: d["window_idx"])
        ref = local_reference(cfg, wins)
        assert len(got) == len(ref), \
            "SIGTERM dropped or duplicated a staged window"
        assert [r["episodes"] for r in ref] == [g["episodes"] for g in got]
        stats = c2.stats()
        assert stats["recovery"]["cold_boots"] >= 1
        assert stats["recovery"]["sessions_restored"] >= 1
        c2.close()
    finally:
        stop_daemon(proc2)


# --------------------------------------------- SIGKILL crash recovery


# fixed per-combination seeds: the kill point must be deterministic run
# to run (PYTHONHASHSEED randomizes hash(), so no hash()-derived seeds)
_CRASH_SEEDS = {("hybrid", False): 101, ("hybrid", True): 102,
                ("ptpe", False): 103, ("ptpe", True): 104,
                ("mapconcatenate", False): 105,
                ("mapconcatenate", True): 106}


@pytest.mark.parametrize("engine", ["hybrid", "ptpe", "mapconcatenate"])
@pytest.mark.parametrize("two_pass", [False, True])
def test_sigkill_restart_resume_bit_identical(tmp_path, engine, two_pass):
    """The headline acceptance: SIGKILL the daemon mid-stream at a
    randomized (seeded, deterministic) window boundary, restart it cold,
    let the client resume from the last-acked sequence number — final
    per-window counts are bit-identical to an uninterrupted run, for
    every engine × two-pass combination.

    A supervisor thread restarts the daemon the moment it dies, the way
    a process manager would; the client rides through the outage on its
    reconnect/backoff path without ever seeing an error."""
    import threading

    seed = _CRASH_SEEDS[(engine, two_pass)]
    cfg = SessionConfig(intervals=((0, 4),), theta=3, max_level=3,
                        engine=engine, two_pass=two_pass, history_limit=4)
    wins = split_by_index(tie_heavy_stream(17, n=200), 5)
    crash_at = kill_point(seed, 1, len(wins))  # a real window boundary

    proc, addr = spawn_daemon(tmp_path, crash_after=crash_at)
    procs = [proc]
    crashed = threading.Event()

    def supervise():
        proc.wait()
        if proc.returncode == -signal.SIGKILL:
            crashed.set()
            procs.append(spawn_daemon(tmp_path)[0])  # clean restart

    sup = threading.Thread(target=supervise, daemon=True)
    sup.start()
    c = MiningClient(addr, "kill", cfg, rng_seed=seed, deadline_s=240.0)
    try:
        for j, w in enumerate(wins):
            c.submit(w, final=(j == len(wins) - 1))
        got = sorted(c.drain(deadline_s=240),
                     key=lambda d: d["window_idx"])
        sup.join(timeout=240)
        assert crashed.is_set(), \
            f"daemon was not SIGKILLed at commit {crash_at}"
        ref = local_reference(cfg, wins)
        assert len(got) == len(ref), \
            f"crash at commit {crash_at}: windows lost or duplicated"
        for r, g in zip(ref, got):
            assert r["episodes"] == g["episodes"], \
                f"window {r['window_idx']} diverged across SIGKILL"
        stats = c.stats()
        assert stats["recovery"]["cold_boots"] >= 1
        assert stats["recovery"]["sessions_restored"] >= 1
        c.close()
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()


def test_sessions_manifest_written_atomically(tmp_path):
    proc, addr = spawn_daemon(tmp_path)
    try:
        cfg = SessionConfig(intervals=((0, 4),), theta=3)
        c = MiningClient(addr, "m0", cfg, rng_seed=0)
        c.open()
        w = tie_heavy_stream(0, n=40)
        c.submit(w)
        manifest = tmp_path / "data" / "SESSIONS.json"
        deadline = time.monotonic() + 30
        while not manifest.exists() and time.monotonic() < deadline:
            time.sleep(0.05)
        doc = json.loads(manifest.read_text())
        assert "m0" in doc["sessions"]
        assert doc["sessions"]["m0"]["theta"] == 3
        c.close()
    finally:
        stop_daemon(proc)
