"""Launch-layer units: shape cells, input specs, sharding rules (these run
single-device; the full lower+compile path is exercised by the dry-run)."""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.launch.shapes import (SHAPES, LONG_CONTEXT_OK,
                                 cell_is_runnable, input_specs)
from repro.models import param_specs
from repro.models import sharding as shd


def test_40_cells_defined():
    cells = [(a, s) for a in ARCHS for s in SHAPES]
    assert len(cells) == 40
    runnable = [c for c in cells if cell_is_runnable(*c)[0]]
    # 7 full-attention archs skip long_500k
    assert len(runnable) == 40 - 7
    for a in LONG_CONTEXT_OK:
        assert cell_is_runnable(a, "long_500k")[0]


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_shapes(arch):
    for shape, cell in SHAPES.items():
        if not cell_is_runnable(arch, shape)[0]:
            continue
        spec = input_specs(arch, shape)
        cfg = get_config(arch)
        b = spec["batch"]
        if cell.kind == "train":
            assert b["labels"].shape == (cell.global_batch, cell.seq_len)
        if cell.kind == "decode":
            assert b["tokens"].shape == (cell.global_batch, 1)
            assert "caches" in spec and "pos" in spec
        if cfg.stub_frontend and cell.kind != "decode":
            assert b["embeddings"].shape[-1] == cfg.d_model
            assert "tokens" not in b


def test_param_spec_divisibility_guards():
    """Every generated PartitionSpec must divide its dim on the 16×16 mesh
    (validated abstractly — no 256 devices needed)."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:  # 16×16 shape view over the 1×1 physical mesh
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    for arch in ARCHS:
        cfg = get_config(arch)
        sds = param_specs(cfg)
        pspecs = shd.param_pspecs(FakeMesh(), cfg, sds)

        def check(leaf, spec):
            for dim, axes in zip(leaf.shape, tuple(spec)):
                if axes is None:
                    continue
                assert dim % shd.axis_size(FakeMesh(), axes) == 0, \
                    f"{arch}: {leaf.shape} vs {spec}"

        jax.tree.map(check, sds, pspecs,
                     is_leaf=lambda x: hasattr(x, "shape"))


def test_act_sanitizes_indivisible_dims():
    mesh = jax.make_mesh((1,), ("model",))

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    with shd.activation_rules(FakeMesh(), {"x": P("data", "model?",
                                                  "model?")}):
        # act() should fall back without error paths even for odd dims;
        # we only validate the spec surgery (no real 256-device apply)
        import repro.models.sharding as S
        rules = S._TLS.rules
        assert "x" in rules


def test_hybrid_dispatcher_capacity_aware(monkeypatch):
    from repro.core import hybrid
    assert hybrid.parallel_units() >= 1
    # single device, no segmented kernel → crossover 0 → PTPE always
    monkeypatch.setattr(hybrid, "parallel_units", lambda: 1)
    monkeypatch.setattr(hybrid, "_mapc_kernel_available", lambda: False)
    assert hybrid.crossover(4) == 0
    # with the kernel the lone device has a real segment axis: f(N)
    monkeypatch.setattr(hybrid, "_mapc_kernel_available", lambda: True)
    assert hybrid.crossover(4) == int(hybrid.f_of_n(4))
    monkeypatch.setattr(hybrid, "parallel_units", lambda: 257)
    assert hybrid.crossover(2) > hybrid.crossover(8) > 0


def test_roofline_cell_terms():
    from repro.launch.roofline import cell_terms
    rec = {
        "status": "ok", "chips": 256, "arch": "x", "shape": "train_4k",
        "mesh": "single", "tokens": 1000,
        "hlo": {"dot_flops": 1e15, "traffic_bytes": 1e12,
                "collective_bytes": 1e11, "collective_breakdown": {}},
        "cost": {"flops": 1e13, "bytes accessed": 1e10},
        "memory": {"per_device_total_bytes": 8 * 2 ** 30},
        "model": {"params": 1e9, "active_params": 1e9, "seq_len": 4096,
                  "global_batch": 256, "kind": "train"},
    }
    t = cell_terms(rec)
    assert t["dominant"] == "compute"
    assert t["fits_16g"]
    np.testing.assert_allclose(t["compute_s"], 1e15 / 197e12)
