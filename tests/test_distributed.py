"""Distributed tests (8 host devices, subprocess-isolated so the main
pytest process keeps its single-device view): shard_map MapConcatenate
equals the sequential oracle; compressed cross-pod psum is within
quantization tolerance of exact psum."""

import json
import os
import subprocess
import sys
import textwrap



def _run(script: str) -> dict:
    import pathlib
    root = pathlib.Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["PYTHONPATH"] = str(root / "src")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, cwd=str(root))
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_shard_map_mapconcatenate_equals_oracle():
    r = _run(textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import numpy as np
        import jax
        from repro.core import EpisodeBatch, count_a1_sequential
        from repro.core.mapconcat import mapconcatenate_sharded
        from repro.data import random_stream

        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        st = random_stream(6, 1200, 8000, seed=3)
        et = rng.integers(0, 6, size=(9, 3)).astype(np.int32)
        tlo = rng.integers(0, 5, size=(9, 2)).astype(np.int32)
        thi = (tlo + rng.integers(1, 8, size=(9, 2))).astype(np.int32)
        eps = EpisodeBatch(et, tlo, thi)
        want = count_a1_sequential(st, eps)
        got = mapconcatenate_sharded(st, eps, mesh, axis="data")
        print(json.dumps({"match": bool((want == got).all()),
                          "want": want.tolist(), "got": got.tolist()}))
    """))
    assert r["match"], r


def test_compressed_psum_close_to_exact():
    r = _run(textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.runtime import compressed_psum_ef, zero_residual

        mesh = jax.make_mesh((8,), ("pod",))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 64, 32)) * 0.1

        def f(gs):
            grads = {"w": gs[0]}
            out, r = compressed_psum_ef(grads, zero_residual(grads), "pod")
            exact = jax.tree.map(lambda x: jax.lax.psum(x, "pod"), grads)
            err = jnp.max(jnp.abs(out["w"] - exact["w"]))
            ref = jnp.max(jnp.abs(exact["w"]))
            # residual must equal the per-device quantization error bound
            rmax = jnp.max(jnp.abs(r["w"]))
            return err[None], ref[None], rmax[None]

        err, ref, rmax = jax.jit(shard_map(f, mesh=mesh, in_specs=P("pod"),
                                           out_specs=P("pod")))(g)
        print(json.dumps({"rel": float(err.max() / ref.max()),
                          "rmax": float(rmax.max())}))
    """))
    # Σ of 8 int8-rounded shards: error ≤ 8·(s/2) ≈ 8/254 of amax ≈ 3%
    assert r["rel"] < 0.05, r
    assert r["rmax"] > 0  # error feedback actually carries the residual
