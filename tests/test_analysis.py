"""Analyzer self-tests: every contract rule must fire on a seeded
violation, stay quiet on a clean twin, and report zero false positives
on the real tree; the CLI must gate (exit 0 clean / non-zero with an
injected violation); the VMEM model must pass the shipped policy and
fail an inflated one.
"""

import json
import pathlib
import shutil
import subprocess
import sys

import pytest

from repro.analysis.contracts import lint_source, lint_tree
from repro.analysis.findings import (Finding, Report, split_suppressed,
                                     suppressed_rules)
from repro.analysis import vmem

REPO = pathlib.Path(__file__).resolve().parent.parent
SRC_ROOT = REPO / "src" / "repro"


def rules_of(findings):
    return sorted(f.rule for f in findings)


# ---------------------------------------------------------------- pass 1
# each case: (rule, seeded-violation snippet, clean twin)

CASES = [
    ("KC101",
     "def f(x):\n    return pl.pallas_call(k, interpret=True)(x)\n",
     "def f(x, mode):\n    return pl.pallas_call(k, interpret=mode)(x)\n"),
    ("KC102",
     "def f(ev):\n    return a1_count_kernel(ev, n_levels=3)\n",
     "def f(ev):\n    KERNEL_CALLS['a1'] += 1\n"
     "    return a1_count_kernel(ev, n_levels=3)\n"),
    ("KC103",
     "def f_state(x):\n    return pl.pallas_call(k, grid=(1,))(x)\n",
     "def f_state(x):\n    return pl.pallas_call(\n"
     "        k, grid=(1,), input_output_aliases={0: 0})(x)\n"),
    ("KC104",
     "def f():\n    return pl.BlockSpec((8, 128), lambda i: (0, i))\n",
     "def f():\n"
     "    return pl.BlockSpec((SUBLANES, LANES), lambda i: (0, i))\n"),
    ("KC105",
     "def f(s):\n"
     "    try:\n"
     "        from repro.kernels import ops as kops\n"
     "        return kops.a1_count(s)\n"
     "    except (ImportError, NotImplementedError):\n"
     "        return slow(s)\n",
     "def f(s):\n"
     "    try:\n"
     "        from repro.kernels import ops as kops\n"
     "        return kops.a1_count(s)\n"
     "    except (ImportError, NotImplementedError):\n"
     "        record_fallback('site')\n"
     "        return slow(s)\n"),
    ("KC106",
     "import os\n"
     "FLAG = os.environ.get('REPRO_KERNEL_INTERPRET') == '1'\n",
     "from repro.kernels.tally import interpret_requested\n"
     "FLAG = interpret_requested()\n"),
    ("KC107",
     "def f():\n"
     "    REGISTRY.counter('kernel_calls', kind='a1').inc()\n",
     "def f():\n    KERNEL_CALLS['a1'] += 1\n"),
    ("KC107",
     "def f():\n    KERNEL_CALLS['fallback:site'] += 1\n",
     "def f():\n    record_fallback('site')\n"),
]


@pytest.mark.parametrize("rule,bad,clean", CASES,
                         ids=[c[0] for c in CASES])
def test_rule_fires_once_on_seeded_violation(rule, bad, clean):
    findings = lint_source(bad, "repro/core/fixture.py")
    assert rules_of(findings) == [rule]


@pytest.mark.parametrize("rule,bad,clean", CASES,
                         ids=[c[0] for c in CASES])
def test_rule_quiet_on_clean_twin(rule, bad, clean):
    assert lint_source(clean, "repro/core/fixture.py") == []


def test_kernel_def_modules_exempt_from_kc102():
    src = "def wrap(ev):\n    return a1_count_state_kernel(ev)\n"
    assert lint_source(src, "repro/kernels/a1_count.py") == []
    assert rules_of(lint_source(src, "repro/core/x.py")) == ["KC102"]


def test_env_accessor_module_exempt_from_kc106():
    src = "import os\nV = os.environ.get('REPRO_INTERPRET_KERNELS')\n"
    assert lint_source(src, "repro/kernels/tally.py") == []
    assert rules_of(lint_source(src, "repro/core/x.py")) == ["KC106"]


def test_tally_accessor_module_exempt_from_kc107():
    src = ("def record_fallback(site):\n"
           "    REGISTRY.counter('kernel_calls',"
           " kind='fallback:' + site).inc()\n")
    assert lint_source(src, "repro/kernels/tally.py") == []
    assert rules_of(lint_source(src, "repro/core/x.py")) == ["KC107"]


def test_suppression_marker_waives_and_reports():
    bad = ("def f():\n"
           "    return pl.BlockSpec((8, 128), t)  # audit-ok: KC104 why\n")
    findings = lint_source(bad, "repro/core/x.py")
    active, waived = split_suppressed(
        findings, {"repro/core/x.py": bad.splitlines()})
    assert active == [] and rules_of(waived) == ["KC104"]
    assert suppressed_rules("x = 1  # audit-ok: KC101") == {"KC101"}
    assert suppressed_rules("x = 1  # nothing here") == set()


def test_real_tree_is_clean():
    active, _, summary = lint_tree(SRC_ROOT)
    assert active == [], [f.format() for f in active]
    assert summary["files_linted"] > 50


# ---------------------------------------------------------------- pass 3


def test_vmem_policy_fits_budget():
    from repro.kernels.ops import MAX_SEG_BRICK_LW
    findings, summary = vmem.check_vmem(MAX_SEG_BRICK_LW)
    assert findings == [], [f.format() for f in findings]
    assert 0 < summary["vmem_worst_mapconcat_bytes"] \
        <= summary["vmem_budget_bytes"]


def test_vmem_flags_oversized_policy():
    findings, _ = vmem.check_vmem(1 << 22)
    assert findings and all(f.rule == "VM302" for f in findings)


def test_vmem_flags_unaligned_policy():
    findings, _ = vmem.check_vmem(100)
    assert "VM303" in rules_of(findings)


def test_vmem_footprint_monotone_in_window():
    small = vmem.mapconcat_footprint(4, 1 << 10)
    large = vmem.mapconcat_footprint(4, 1 << 17)
    assert small < large


def test_vmem_constants_match_kernel_layout():
    # the analysis plane mirrors the layout constants instead of
    # importing the jax kernel stack; hold the mirror to the source
    from repro.kernels import a2_count
    assert vmem.LANES == a2_count.LANES
    assert vmem.SUBLANES == a2_count.SUBLANES
    assert vmem.SEG_ROWS == a2_count.SEG_ROWS
    assert vmem.DEFAULT_BLOCK_E == a2_count.DEFAULT_BLOCK_E


def test_segment_bricks_enforces_admission_bound():
    import numpy as np
    from repro.kernels import ops
    wt = np.full((1, 128), -1, np.int32)
    wtt = np.zeros((1, 128), np.int32)
    tau = np.array([0, 100], np.int32)
    with pytest.raises(NotImplementedError):
        ops.segment_bricks(wt, wtt, tau, length=ops.MAX_SEG_BRICK_LW * 2)


# ---------------------------------------------------------------- pass 2


def test_trace_audit_clean_on_real_entry_points():
    from repro.analysis import tracecheck
    findings, summary = tracecheck.audit_entry_points()
    assert findings == [], [f.format() for f in findings]
    assert len(summary["entry_points_traced"]) >= 6


def test_jaxpr_audit_flags_callback_and_dtype():
    import jax
    import jax.numpy as jnp
    from repro.analysis.tracecheck import audit_jaxpr

    def leaky(x):
        jax.debug.callback(lambda v: None, x)
        return x.astype(jnp.float32) * 2.0

    jaxpr = jax.make_jaxpr(leaky)(jnp.ones((4,), jnp.int32)).jaxpr
    rules = rules_of(audit_jaxpr("leaky", jaxpr))
    assert "TR201" in rules and "TR202" in rules


def test_donation_audit_passes_current_factories():
    from repro.analysis import tracecheck
    findings, _ = tracecheck.audit_donation()
    assert findings == []


# ------------------------------------------------------------------ CLI


def run_cli(*args, env_extra=None):
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env.update(env_extra or {})
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.audit", *args],
        capture_output=True, text=True, env=env, cwd=REPO)


def test_cli_clean_tree_exits_zero(tmp_path):
    out = tmp_path / "summary.json"
    r = run_cli("--fail-on-violation", "--skip-trace",
                "--summary", str(out))
    assert r.returncode == 0, r.stdout + r.stderr
    assert "AUDIT CLEAN" in r.stdout
    data = json.loads(out.read_text())
    assert data["ok"] and data["findings"] == []


@pytest.mark.parametrize("rule,bad,clean", CASES,
                         ids=[c[0] for c in CASES])
def test_cli_injected_violation_exits_nonzero(tmp_path, rule, bad, clean):
    root = tmp_path / "repro"
    shutil.copytree(SRC_ROOT, root)
    (root / "core" / "injected_fixture.py").write_text(bad)
    r = run_cli("--fail-on-violation", "--skip-trace",
                "--root", str(root))
    assert r.returncode == 1, r.stdout + r.stderr
    assert rule in r.stdout


def test_cli_without_fail_flag_never_gates(tmp_path):
    root = tmp_path / "repro"
    shutil.copytree(SRC_ROOT, root)
    (root / "core" / "injected_fixture.py").write_text(CASES[0][1])
    r = run_cli("--skip-trace", "--root", str(root))
    assert r.returncode == 0
    assert "AUDIT FAILED" in r.stdout


# ------------------------------------------------------------- findings


def test_report_roundtrip():
    rep = Report()
    rep.extend([Finding("KC101", "a.py", 3, "msg")],
               [Finding("KC104", "b.py", 9, "waived")], files_linted=2)
    assert not rep.ok
    data = json.loads(rep.to_json())
    assert data["findings"][0]["rule"] == "KC101"
    assert data["suppressed"][0]["line"] == 9
    assert data["summary"]["files_linted"] == 2
    assert "AUDIT FAILED" in rep.format()
