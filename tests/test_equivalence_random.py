"""Randomized cross-engine equivalence harness.

The repo's exactness story rests on hand-picked adversarial cases
(mid-tie splits, exactly-τ+W straddlers). This harness pins the claim
down the other way: generate random (stream, episode batch, lcap,
segment count, window partition) tuples — τ ties, lcap-overflow
pressure, arbitrary cut points included — and assert that EVERY engine
returns bit-identical counts:

  * one-shot ``count_dispatch`` over ptpe / mapconcatenate /
    mapconcat_kernel / mapconcat_sharded == the sequential oracle;
  * ``count_two_pass`` per engine: exact counts for survivors, the A2
    upper bound and cull mask consistent with the scan reference;
  * ``StreamingCounter`` per engine × {unbounded, bounded} over the
    random window partition == the oracle (per-window snapshots of the
    two residencies equal each other, final counts equal the oracle);
  * ``StreamingA2Counter`` chunked == one-shot A2.

Hypothesis drives the sweep when installed (``REPRO_EQ_EXAMPLES``
scales it — 60 examples/function by default, so a default local run
generates 240+ cases; ``derandomize=True`` keeps CI subsets
deterministic); without hypothesis a fixed seed sweep runs the same
property. Kernel engines join the sweep automatically when the dispatch
policy allows (TPU or ``REPRO_KERNEL_INTERPRET=1``), and the sharded
engine exercises real multi-device dispatch when the process has >1
device (the CI job forces ``--xla_force_host_platform_device_count=8``).
Single-device runs still cover the sharded entry points' fallback
contract; the subprocess tests at the bottom always exercise the real
8-device sharded launches and the cross-device-count checkpoint
portability, regardless of the host process's device view.
"""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as hst
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # deterministic fallback sweep below
    HAVE_HYPOTHESIS = False

from repro.core import (EpisodeBatch, EventStream, StreamingA2Counter,
                        StreamingCounter, count_a1_sequential, count_a2,
                        count_dispatch, count_two_pass)

MAX_EXAMPLES = int(os.environ.get("REPRO_EQ_EXAMPLES", "60"))
FALLBACK_SEEDS = list(range(10))


def _kernel_available() -> bool:
    try:
        from repro.kernels import ops as kops
        kops.kernel_mode()
        return True
    except (ImportError, NotImplementedError):
        return False


def engines_under_test():
    """ptpe + XLA mapconcatenate always; the kernel engines when the
    dispatch policy engages them. mapconcat_sharded is included even
    single-device — its graceful-degradation contract (fall back to the
    single-device kernel / XLA paths, bit-identically) is part of what
    the harness pins down."""
    engines = ["ptpe", "mapconcatenate", "mapconcat_sharded"]
    if _kernel_available():
        engines.insert(2, "mapconcat_kernel")
    return engines


def make_case(seed: int):
    """One random case: tie-heavy stream, random episode batch (random τ
    bounds — equal timestamps land on zone boundaries), lcap chosen to
    sometimes force live evictions (the ovf exact-recount path), random
    segment count, random window cut points (mid-tie cuts included)."""
    rng = np.random.default_rng(seed)
    n_ev = int(rng.integers(150, 400))
    num_types = int(rng.integers(3, 7))
    gaps = rng.choice([0, 0, 1, 1, 2, 3, 8], size=n_ev)
    times = (np.cumsum(gaps) + 1).astype(np.int32)
    types = rng.integers(0, num_types, size=n_ev).astype(np.int32)
    stream = EventStream(types, times, num_types)
    n = int(rng.integers(2, 4))
    m = 6
    et = rng.integers(0, num_types, size=(m, n)).astype(np.int32)
    tlo = rng.integers(0, 4, size=(m, n - 1)).astype(np.int32)
    thi = (tlo + rng.integers(1, 7, size=(m, n - 1))).astype(np.int32)
    eps = EpisodeBatch(et, tlo, thi)
    lcap = int(rng.choice([1, 2, 4]))
    num_segments = int(rng.choice([2, 4, 8]))
    k = int(rng.integers(2, 6))
    cuts = np.sort(rng.choice(np.arange(1, n_ev), size=k - 1,
                              replace=False))
    return stream, eps, lcap, num_segments, cuts


def split_at(stream: EventStream, cuts) -> list[EventStream]:
    idx = [0] + [int(c) for c in cuts] + [len(stream.types)]
    return [EventStream(stream.types[a:b], stream.times[a:b],
                        stream.num_types)
            for a, b in zip(idx[:-1], idx[1:])]


# ------------------------------------------------------------ properties


def check_dispatch(seed: int):
    stream, eps, lcap, num_segments, _ = make_case(seed)
    want = count_a1_sequential(stream, eps)
    for engine in engines_under_test():
        got = count_dispatch(stream, eps, engine=engine, lcap=lcap,
                             num_segments=num_segments)
        np.testing.assert_array_equal(
            got, want, err_msg=f"seed {seed} engine {engine} "
                               f"lcap={lcap} P={num_segments}")


def check_two_pass(seed: int):
    stream, eps, lcap, num_segments, _ = make_case(seed)
    want = count_a1_sequential(stream, eps)
    a2_ref = count_a2(stream, eps, use_kernel=False)
    theta = max(1, int(np.median(a2_ref)))
    for engine in engines_under_test():
        res = count_two_pass(stream, eps, theta=theta, engine=engine,
                             lcap=lcap, num_segments=num_segments)
        msg = f"seed {seed} engine {engine} theta={theta}"
        np.testing.assert_array_equal(res.a2_counts, a2_ref, err_msg=msg)
        np.testing.assert_array_equal(res.survived, a2_ref >= theta,
                                      err_msg=msg)
        np.testing.assert_array_equal(res.counts[res.survived],
                                      want[res.survived], err_msg=msg)
        np.testing.assert_array_equal(
            res.frequent, res.survived & (res.counts >= theta),
            err_msg=msg)
        # Theorem 5.1 on the random case: the cull never removes a truly
        # frequent episode
        assert not ((want >= theta) & ~res.survived).any(), msg


def check_streaming(seed: int):
    stream, eps, lcap, num_segments, cuts = make_case(seed)
    want = count_a1_sequential(stream, eps)
    windows = split_at(stream, cuts)
    for engine in ("ptpe", "mapconcatenate", "mapconcat_sharded"):
        ctr = StreamingCounter(eps, engine=engine, lcap=lcap,
                               num_segments=num_segments)
        bnd = StreamingCounter(eps, engine=engine, lcap=lcap,
                               num_segments=num_segments,
                               checkpoint_interval=2)
        for i, w in enumerate(windows):
            final = i == len(windows) - 1
            got = ctr.update(w, final=final)
            got_b = bnd.update(w, final=final)
            np.testing.assert_array_equal(
                got_b, got, err_msg=f"seed {seed} engine {engine} "
                                    f"window {i}: bounded != unbounded")
        np.testing.assert_array_equal(
            got, want, err_msg=f"seed {seed} engine {engine} final")


def check_streaming_a2(seed: int):
    stream, eps, _, _, cuts = make_case(seed)
    want = count_a2(stream, eps, use_kernel=False)
    a2c = StreamingA2Counter(eps.relaxed())
    for w in split_at(stream, cuts):
        got = a2c.update(w)
    np.testing.assert_array_equal(got, want,
                                  err_msg=f"seed {seed} streaming A2")


if HAVE_HYPOTHESIS:
    _settings = settings(max_examples=MAX_EXAMPLES, deadline=None,
                         derandomize=True)

    @_settings
    @given(hst.integers(0, 10_000_000))
    def test_dispatch_engines_bit_equal(seed):
        check_dispatch(seed)

    @_settings
    @given(hst.integers(0, 10_000_000))
    def test_two_pass_bit_equal(seed):
        check_two_pass(seed)

    @_settings
    @given(hst.integers(0, 10_000_000))
    def test_streaming_modes_bit_equal(seed):
        check_streaming(seed)

    @_settings
    @given(hst.integers(0, 10_000_000))
    def test_streaming_a2_bit_equal(seed):
        check_streaming_a2(seed)
else:  # deterministic sweep over the same seed-driven strategy
    @pytest.mark.parametrize("seed", FALLBACK_SEEDS)
    def test_dispatch_engines_bit_equal(seed):
        check_dispatch(seed)

    @pytest.mark.parametrize("seed", FALLBACK_SEEDS)
    def test_two_pass_bit_equal(seed):
        check_two_pass(seed)

    @pytest.mark.parametrize("seed", FALLBACK_SEEDS)
    def test_streaming_modes_bit_equal(seed):
        check_streaming(seed)

    @pytest.mark.parametrize("seed", FALLBACK_SEEDS)
    def test_streaming_a2_bit_equal(seed):
        check_streaming_a2(seed)


# ----------------------------------------- real multi-device (subprocess)
#
# The host pytest process usually sees one device; these force 8 host
# devices (XLA_FLAGS must precede the jax import, hence subprocesses) and
# interpret-mode kernels, so the *real* sharded launches run on CPU CI.


_ROOT = Path(__file__).resolve().parent.parent


def _run(script: str, timeout: int = 900) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(_ROOT / "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["REPRO_KERNEL_INTERPRET"] = "1"
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, cwd=str(_ROOT),
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


_CASE_SRC = textwrap.dedent(f"""
    import sys
    sys.path.insert(0, {str(_ROOT / "tests")!r})
    from test_equivalence_random import make_case, split_at
""")


def test_sharded_dispatch_equals_oracle_8dev():
    """Random cases on a real 8-device mesh: the sharded engine (and its
    per-device-count variants) == segmented kernel == XLA == oracle, and
    the sharded kernel dispatch actually ran (KERNEL_CALLS)."""
    r = _run(_CASE_SRC + textwrap.dedent("""
        import json
        import numpy as np
        from repro.core import count_a1_sequential, count_dispatch
        from repro.core.mapconcat import mapconcatenate_sharded_kernel
        from repro.kernels import ops as kops

        checked = 0
        for seed in (11, 29, 47):
            stream, eps, lcap, num_segments, _ = make_case(seed)
            want = count_a1_sequential(stream, eps)
            for engine in ("mapconcatenate", "mapconcat_kernel",
                           "mapconcat_sharded"):
                got = count_dispatch(stream, eps, engine=engine,
                                     lcap=lcap,
                                     num_segments=num_segments)
                assert (got == want).all(), (seed, engine)
                checked += 1
            for d in (2, 4, 8):
                got = mapconcatenate_sharded_kernel(
                    stream, eps, num_segments=8, lcap=lcap,
                    num_devices=d)
                assert (got == want).all(), (seed, d)
                checked += 1
        print(json.dumps({"checked": checked,
                          "shard_calls":
                              kops.KERNEL_CALLS["a1_mapc_shard"]}))
    """))
    assert r["checked"] == 18
    assert r["shard_calls"] > 0


def test_sharded_streaming_equals_oracle_8dev():
    """Streaming sharded residency on a real mesh: per-commit sharded
    launches over random window partitions == oracle, including bounded
    mode and the lcap=1 ovf fallback."""
    r = _run(_CASE_SRC + textwrap.dedent("""
        import json
        import numpy as np
        from repro.core import StreamingCounter, count_a1_sequential
        from repro.kernels import ops as kops

        checked = 0
        for seed, lcap in ((5, 4), (13, 1)):
            stream, eps, _, num_segments, cuts = make_case(seed)
            want = count_a1_sequential(stream, eps)
            for interval in (None, 2):
                ctr = StreamingCounter(
                    eps, engine="mapconcat_sharded", lcap=lcap,
                    num_segments=num_segments,
                    checkpoint_interval=interval)
                assert ctr._shard_d == 8
                windows = split_at(stream, cuts)
                for i, w in enumerate(windows):
                    got = ctr.update(w, final=i == len(windows) - 1)
                assert (got == want).all(), (seed, lcap, interval)
                checked += 1
        print(json.dumps({"checked": checked,
                          "shard_calls":
                              kops.KERNEL_CALLS["a1_mapc_shard"]}))
    """))
    assert r["checked"] == 4
    assert r["shard_calls"] > 0


def test_state_dict_portable_8dev_to_1dev(tmp_path):
    """Checkpoint portability, sharded → single-device: a state_dict
    written under 8-device sharded residency restores onto this (single
    device, scan-residency) process's counter; the resumed counts equal
    the oracle on the full stream."""
    ck = tmp_path / "sharded.npz"
    stream, eps, lcap, num_segments, cuts = make_case(101)
    windows = split_at(stream, cuts)
    cut = len(windows) // 2
    r = _run(_CASE_SRC + textwrap.dedent(f"""
        import json
        import numpy as np
        from repro.core import StreamingCounter

        stream, eps, lcap, num_segments, cuts = make_case(101)
        windows = split_at(stream, cuts)
        ctr = StreamingCounter(eps, engine="mapconcat_sharded",
                               lcap=lcap, num_segments=num_segments)
        assert ctr._shard_d == 8
        for w in windows[:{cut}]:
            ctr.update(w)
        np.savez({str(ck)!r}, **ctr.state_dict())
        print(json.dumps({{"ok": True}}))
    """))
    assert r["ok"]
    resumed = StreamingCounter(eps, engine="mapconcatenate", lcap=lcap,
                               num_segments=num_segments)
    with np.load(ck) as d:
        resumed.load_state_dict(dict(d))
    for i, w in enumerate(windows[cut:]):
        got = resumed.update(w, final=cut + i == len(windows) - 1)
    np.testing.assert_array_equal(got, count_a1_sequential(stream, eps))


def test_state_dict_portable_1dev_to_8dev(tmp_path):
    """And the reverse: a single-device (scan-residency) checkpoint
    restores under 8-device sharded residency and finishes with
    oracle-exact counts."""
    ck = tmp_path / "single.npz"
    stream, eps, lcap, num_segments, cuts = make_case(202)
    windows = split_at(stream, cuts)
    cut = max(1, len(windows) // 2)
    ctr = StreamingCounter(eps, engine="mapconcatenate", lcap=lcap,
                           num_segments=num_segments)
    for w in windows[:cut]:
        ctr.update(w)
    np.savez(ck, **ctr.state_dict())
    want = count_a1_sequential(stream, eps)
    r = _run(_CASE_SRC + textwrap.dedent(f"""
        import json
        import numpy as np
        from repro.core import StreamingCounter
        from repro.kernels import ops as kops

        stream, eps, lcap, num_segments, cuts = make_case(202)
        windows = split_at(stream, cuts)
        ctr = StreamingCounter(eps, engine="mapconcat_sharded",
                               lcap=lcap, num_segments=num_segments)
        assert ctr._shard_d == 8
        with np.load({str(ck)!r}) as d:
            ctr.load_state_dict(dict(d))
        for i, w in enumerate(windows[{cut}:]):
            got = ctr.update(w, final={cut} + i == len(windows) - 1)
        print(json.dumps({{"counts": got.tolist()}}))
    """))
    np.testing.assert_array_equal(np.asarray(r["counts"]), want)
