"""Multi-tenant mining service: exactness, bounded memory, checkpointing,
admission/backpressure, and watchdog retry.

The load-bearing claims:

* batched multi-session serving is *bit-identical* to a standalone
  ``StreamingMiner`` per session, for every engine × two-pass combination
  (cross-session vmap batching and scheduling are throughput-only);
* with ``history_limit=K`` the retained window history is O(K), not
  O(stream length), while already-tracked counts stay exact — including
  under forced bounded-list overflows (the oracle-escrow recovery path);
* a session checkpointed mid-stream through ``checkpoint.ckpt`` and
  restored cold resumes bit-identically.
"""

import numpy as np
import pytest

from repro.core import (EpisodeBatch, EventStream, StreamingCounter,
                        StreamingMiner, count_a1_sequential)
from repro.service import (AdmissionError, BackpressureError, MiningService,
                           SchedulerPolicy, SessionConfig)

NUM_TYPES = 5


def tie_heavy_stream(seed, n=240):
    rng = np.random.default_rng(seed)
    gaps = rng.choice([0, 0, 1, 2], size=n)
    times = (np.cumsum(gaps) + 1).astype(np.int32)
    types = rng.integers(0, NUM_TYPES, size=n).astype(np.int32)
    return EventStream(types, times, NUM_TYPES)


def split_by_index(stream, k):
    n = stream.types.shape[0]
    cuts = [0] + [n * j // k for j in range(1, k)] + [n]
    return [EventStream(stream.types[a:b], stream.times[a:b],
                        stream.num_types)
            for a, b in zip(cuts[:-1], cuts[1:])]


def counting_batch():
    return EpisodeBatch(
        np.int32([[0, 1, 2], [1, 2, 3], [2, 2, 0], [4, 0, 1]]),
        np.int32([[1, 0], [0, 2], [0, 0], [0, 0]]),
        np.int32([[5, 6], [4, 7], [3, 3], [6, 2]]))


def assert_results_equal(a, b, msg=""):
    assert len(a.frequent) == len(b.frequent), msg
    for fa, fb, ca, cb in zip(a.frequent, b.frequent, a.counts, b.counts):
        np.testing.assert_array_equal(fa.etypes, fb.etypes, err_msg=msg)
        np.testing.assert_array_equal(fa.tlo, fb.tlo, err_msg=msg)
        np.testing.assert_array_equal(fa.thi, fb.thi, err_msg=msg)
        np.testing.assert_array_equal(ca, cb, err_msg=msg)


# ------------------------------------------------------------- exactness


@pytest.mark.parametrize("engine", ["hybrid", "ptpe", "mapconcatenate"])
@pytest.mark.parametrize("two_pass", [True, False])
def test_batched_service_bit_identical_to_standalone(engine, two_pass):
    """Acceptance: every engine × two-pass — per-session results from the
    batched multi-session service equal a standalone StreamingMiner run on
    that session's stream, window by window."""
    svc = MiningService()
    tenants = []
    for i, seed in enumerate((0, 3, 5)):
        cfg = SessionConfig(intervals=((0, 4),), theta=3, max_level=3,
                            engine=engine, two_pass=two_pass,
                            history_limit=4)
        sid = svc.create_session(f"t{i}", cfg)
        wins = split_by_index(tie_heavy_stream(seed, n=200 + 40 * i), 4)
        tenants.append((sid, cfg, wins))
        for j, w in enumerate(wins):
            svc.ingest(sid, w, final=j == len(wins) - 1)
    svc.pump()
    for sid, cfg, wins in tenants:
        deltas = svc.poll(sid)
        assert len(deltas) == len(wins)
        standalone = cfg.make_miner()
        for j, (d, w) in enumerate(zip(deltas, wins)):
            ref = standalone.update(w, final=j == len(wins) - 1)
            assert_results_equal(d.result, ref,
                                 f"{engine} two_pass={two_pass} "
                                 f"{sid} window {j}")


def test_batcher_actually_fuses_same_shape_sessions():
    """Same-bucket tenants must share one vmapped dispatch (the batching
    win is real, not just permitted)."""
    svc = MiningService()
    for i in range(4):
        sid = svc.create_session(
            f"t{i}", SessionConfig(intervals=((0, 4),), theta=3,
                                   max_level=3, history_limit=4))
        wins = split_by_index(tie_heavy_stream(i, n=200), 3)
        for j, w in enumerate(wins):
            svc.ingest(sid, w, final=j == len(wins) - 1)
    svc.pump()
    assert svc.batcher.batches > 0
    assert svc.batcher.fused_requests >= 2 * svc.batcher.batches


@pytest.mark.parametrize("engine", ["ptpe", "mapconcatenate"])
def test_heterogeneous_window_tenants_fuse_and_stay_exact(engine):
    """Adaptive L re-bucketing: tenants whose windows land in *different*
    event-buffer buckets (128 vs 512 events) must still fuse into shared
    vmapped dispatches — previously they fragmented into singleton groups
    keyed by L — and each tenant's fused results must stay bit-identical
    to a standalone miner on its own stream."""
    svc = MiningService()
    tenants = []
    for i, n in enumerate((180, 900, 260)):  # ~60 / ~300 / ~87 ev/window
        cfg = SessionConfig(intervals=((0, 4),), theta=3, max_level=3,
                            engine=engine, history_limit=4)
        sid = svc.create_session(f"t{i}", cfg)
        wins = split_by_index(tie_heavy_stream(i, n=n), 3)
        tenants.append((sid, cfg, wins))
        for j, w in enumerate(wins):
            svc.ingest(sid, w, final=j == len(wins) - 1)
    svc.pump()
    assert svc.batcher.batches > 0, \
        "heterogeneous-L tenants no longer fuse"
    assert svc.batcher.fused_requests >= 2 * svc.batcher.batches
    for sid, cfg, wins in tenants:
        deltas = svc.poll(sid)
        standalone = cfg.make_miner()
        for j, (d, w) in enumerate(zip(deltas, wins)):
            ref = standalone.update(w, final=j == len(wins) - 1)
            assert_results_equal(d.result, ref,
                                 f"{engine} {sid} window {j}")


@pytest.mark.parametrize("engine", ["ptpe", "mapconcatenate"])
def test_oversized_group_splits_and_stays_exact(engine):
    """Pad-waste guardrail: when one tenant's windows dwarf the fleet's
    (event buffers beyond max_pad_ratio × the smallest lane's), the fused
    group must split instead of padding every small lane to the giant —
    and each tenant's results must stay bit-identical to a standalone
    miner. The small tenants still fuse with each other."""
    svc = MiningService()
    svc.batcher.max_pad_ratio = 4.0
    tenants = []
    # three ~40-event windows (128 bucket) + one ~1300-event (2048 bucket)
    for i, n in enumerate((120, 130, 125, 4000)):
        cfg = SessionConfig(intervals=((0, 4),), theta=3, max_level=3,
                            engine=engine, history_limit=4)
        sid = svc.create_session(f"t{i}", cfg)
        wins = split_by_index(tie_heavy_stream(i, n=n), 3)
        tenants.append((sid, cfg, wins))
        for j, w in enumerate(wins):
            svc.ingest(sid, w, final=j == len(wins) - 1)
    svc.pump()
    assert svc.batcher.split_groups > 0, \
        "giant-window tenant no longer splits the fused group"
    assert svc.batcher.batches > 0  # the small lanes still fused
    for sid, cfg, wins in tenants:
        deltas = svc.poll(sid)
        standalone = cfg.make_miner()
        for j, (d, w) in enumerate(zip(deltas, wins)):
            ref = standalone.update(w, final=j == len(wins) - 1)
            assert_results_equal(d.result, ref,
                                 f"{engine} {sid} window {j} (split path)")


def test_pad_events_marks_segment_brick_tail_pad_for_every_mapc_kind():
    """Adaptive-L padding of segment bricks must rewrite the padded tail's
    *types* row to PAD_TYPE for BOTH segmented kinds ("mapck" and the
    sharded "mapcs") — a zero-filled tail is a stream of real type-0
    events and silently corrupts fused counts."""
    from repro.core.events import PAD_TYPE
    from repro.service.batcher import _pad_events
    segs = np.ones((2, 5, 128), np.int32)  # [P, 5, LW] brick, types row 0
    args = (None, None, None, None, None, segs)
    for kind in ("mapck", "mapcs"):
        padded = _pad_events(kind, args, 256)[5]
        assert padded.shape == (2, 5, 256)
        assert (np.asarray(padded[:, 0, 128:]) == PAD_TYPE).all(), kind
        assert (np.asarray(padded[:, 0, :128]) == 1).all(), kind


def test_split_disabled_keeps_single_group():
    """max_pad_ratio=None restores the old fuse-everything behavior (the
    split is a guardrail, not a semantics change)."""
    svc = MiningService()
    svc.batcher.max_pad_ratio = None
    for i, n in enumerate((120, 4000)):
        cfg = SessionConfig(intervals=((0, 4),), theta=3, max_level=2,
                            history_limit=4)
        sid = svc.create_session(f"t{i}", cfg)
        wins = split_by_index(tie_heavy_stream(i, n=n), 3)
        for j, w in enumerate(wins):
            svc.ingest(sid, w, final=j == len(wins) - 1)
    svc.pump()
    assert svc.batcher.split_groups == 0


# -------------------------------------------------------- bounded memory


@pytest.mark.parametrize("engine", ["ptpe", "mapconcatenate"])
@pytest.mark.parametrize("lcap", [1, 4])
def test_bounded_counter_capped_and_exact(engine, lcap):
    """Many windows through a checkpoint_interval counter: retained
    history stays O(interval) while cumulative counts match the oracle at
    every window — lcap=1 forces live evictions, exercising the
    oracle-escrow recovery instead of genesis recounts."""
    stream = tie_heavy_stream(1, n=600)
    eps = counting_batch()
    wins = split_by_index(stream, 20)
    ctr = StreamingCounter(eps, engine=engine, lcap=lcap,
                           checkpoint_interval=3)
    ref = StreamingCounter(eps, engine=engine, lcap=lcap)
    for i, w in enumerate(wins):
        final = i == len(wins) - 1
        got = ctr.update(w, final=final)
        want = ref.update(w, final=final)
        np.testing.assert_array_equal(got, want, err_msg=f"window {i}")
        assert ctr.retained_windows <= 4  # interval + current partial
    np.testing.assert_array_equal(got, count_a1_sequential(stream, eps))
    assert ref.retained_windows == len(wins)  # unbounded keeps everything


@pytest.mark.parametrize("two_pass", [True, False])
def test_bounded_miner_capped_and_exact(two_pass):
    """The miner-level cap: retained windows stay <= history_limit while
    per-window mining results equal the unbounded miner's (stationary
    stream: every candidate batch is promoted within the horizon)."""
    from repro.data import embedded_chain_stream
    st = embedded_chain_stream(NUM_TYPES, [1, 2, 3], (2, 6),
                               num_occurrences=60, noise_events=700,
                               t_max=50_000, seed=7)
    wins = split_by_index(st, 15)
    unbounded = StreamingMiner([(2, 6)], 6, max_level=3, two_pass=two_pass)
    bounded = StreamingMiner([(2, 6)], 6, max_level=3, two_pass=two_pass,
                             history_limit=4)
    for i, w in enumerate(wins):
        final = i == len(wins) - 1
        ru = unbounded.update(w, final=final)
        rb = bounded.update(w, final=final)
        assert_results_equal(rb, ru, f"window {i}")
        assert bounded.retained_windows <= 5
    assert unbounded.retained_windows == len(wins)
    assert bounded.retained_windows <= 4


def churny_stream():
    """A planted pair from t=0 plus a second pair that only starts midway:
    level-1 cumulative counts cross θ at different windows, so the level-2
    candidate key churns and the tracked set grows late — the scenario
    that used to rebuild (and silently reset) bounded counters."""
    rng = np.random.default_rng(0)
    pairs = []
    t = 10
    while t < 8000:
        pairs += [(0, t), (1, t + 2)]
        t += 80
    t = 4000
    while t < 8000:
        pairs += [(2, t + 1), (3, t + 3)]
        t += 90
    for _ in range(500):
        pairs.append((int(rng.integers(0, 6)), int(rng.integers(10, 8000))))
    return EventStream.from_pairs(pairs, 6)


def test_bounded_per_window_exact_under_candidate_churn():
    """per_window serving must stay bit-exact vs the unbounded miner even
    when candidate keys churn and promotions land after the horizon."""
    st = churny_stream()
    ws = split_by_index(st, 10)
    unb = StreamingMiner([(0, 5)], 5, max_level=2, two_pass=True)
    bnd = StreamingMiner([(0, 5)], 5, max_level=2, two_pass=True,
                         history_limit=3)
    for i, w in enumerate(ws):
        ru = unb.update(w, final=i == len(ws) - 1)
        rb = bnd.update(w, final=i == len(ws) - 1)
        assert_results_equal(rb, ru, f"churny window {i}")


def test_tracked_growth_appends_fragments_without_reset():
    """Growing a tracked set must append a fragment for the new episodes,
    never rebuild existing counters (a rebuild resets their genesis-exact
    counts in bounded mode)."""
    st = churny_stream()
    ws = split_by_index(st, 10)
    miner = StreamingMiner([(0, 5)], 5, max_level=2, mode="cumulative",
                           two_pass=True, history_limit=3)
    frag_ids: dict = {}
    for i, w in enumerate(ws):
        miner.update(w, final=i == len(ws) - 1)
        for key, (tracked, frags) in miner._exact.items():
            old = frag_ids.get(key)
            if old is not None:  # existing fragments keep their identity
                assert [id(f) for f in frags[:len(old)]] == old
            assert sum(f.eps.M for f in frags) == tracked.size
            frag_ids[key] = [id(f) for f in frags]


def test_bounded_miner_evicts_stale_counter_keys():
    """The counter table itself must not grow with candidate churn: keys
    idle past the horizon are dropped."""
    st = tie_heavy_stream(2, n=400)
    wins = split_by_index(st, 10)
    miner = StreamingMiner([(0, 4)], 3, max_level=3, history_limit=2)
    live_keys_per_window = []
    for i, w in enumerate(wins):
        res = miner.update(w, final=i == len(wins) - 1)
        # every counter table is keyed only by fresh keys: eviction pops
        # all tables together, so none may outlive _last_seen
        assert set(miner._a2) <= set(miner._last_seen)
        assert set(miner._exact) <= set(miner._last_seen)
        assert set(miner._known) <= set(miner._last_seen)
        for key, seen in miner._last_seen.items():
            assert miner._p - seen <= 2
        live_keys_per_window.append(len(miner._last_seen))
    # the table is bounded by the keys touched within the horizon, not by
    # the total churn over the stream (levels * (horizon + 1) is a loose
    # per-window cap: at most one fresh key per level per window)
    assert max(live_keys_per_window) <= 2 * 3


# ---------------------------------------------------------- checkpoints


def test_checkpoint_roundtrip_mid_stream(tmp_path):
    """Save streaming machine state through checkpoint/ckpt.py mid-stream,
    cold-restore into a fresh session, and finish: every resumed window's
    result is bit-identical to the uninterrupted run."""
    from repro.service.session import MiningSession
    cfg = SessionConfig(intervals=((0, 4),), theta=3, max_level=3,
                        history_limit=3)
    wins = split_by_index(tie_heavy_stream(4, n=300), 8)
    cut = 4

    oracle = MiningSession("s", cfg)
    for j, w in enumerate(wins):
        oracle.enqueue(w, final=j == len(wins) - 1)
    while oracle.pending:
        oracle.step()
    want = oracle.poll()

    first = MiningSession("s", cfg)
    for j, w in enumerate(wins[:cut]):
        first.enqueue(w)
        first.step()
    first.save(tmp_path)

    resumed = MiningSession("s", cfg).restore(tmp_path)  # fresh process
    assert resumed.windows_done == cut
    for j, w in enumerate(wins[cut:]):
        resumed.enqueue(w, final=cut + j == len(wins) - 1)
        resumed.step()
    got = resumed.poll()
    # unpolled pre-crash deltas survive the restore, then the resumed tail
    assert len(got) == len(wins)
    for d, ref in zip(got, want):
        assert d.window_idx == ref.window_idx
        assert_results_equal(d.result, ref.result,
                             f"resumed window {d.window_idx}")


def test_checkpoint_rejects_config_mismatch(tmp_path):
    from repro.service.session import MiningSession
    cfg = SessionConfig(intervals=((0, 4),), theta=3)
    s = MiningSession("s", cfg)
    s.enqueue(tie_heavy_stream(0, n=60))
    s.step()
    s.save(tmp_path)
    other = MiningSession("s", SessionConfig(intervals=((0, 4),), theta=99))
    with pytest.raises(ValueError, match="hash"):
        other.restore(tmp_path)


# ---------------------------------------------- admission / backpressure


def test_admission_control_and_backpressure():
    svc = MiningService(policy=SchedulerPolicy(max_sessions=2,
                                               max_pending_windows=2))
    cfg = SessionConfig(intervals=((0, 4),), theta=3)
    svc.create_session("a", cfg)
    svc.create_session("b", cfg)
    with pytest.raises(AdmissionError, match="capacity"):
        svc.create_session("c", cfg)
    with pytest.raises(AdmissionError, match="already"):
        svc.create_session("a", cfg)
    wins = split_by_index(tie_heavy_stream(0, n=120), 3)
    svc.ingest("a", wins[0])
    svc.ingest("a", wins[1])
    with pytest.raises(BackpressureError, match="depth"):
        svc.ingest("a", wins[2])
    svc.pump()
    svc.ingest("a", wins[2], final=True)  # queue drained → accepted again
    svc.pump()
    assert len(svc.poll("a")) == 3
    # closing a tenant frees its admission slot
    svc.close_session("b")
    svc.create_session("c", cfg)


def test_round_robin_fairness():
    """A firehose tenant must not starve a trickle tenant: after each
    scheduler step, served window counts stay within one batch of each
    other."""
    svc = MiningService(policy=SchedulerPolicy(max_pending_windows=16,
                                               max_batch_sessions=2))
    cfg = SessionConfig(intervals=((0, 4),), theta=3, max_level=2)
    svc.create_session("fire", cfg)
    svc.create_session("drip", cfg)
    fire = split_by_index(tie_heavy_stream(0, n=400), 8)
    drip = split_by_index(tie_heavy_stream(1, n=100), 2)
    for w in fire:
        svc.ingest("fire", w)
    for w in drip:
        svc.ingest("drip", w)
    svc.scheduler.step()
    # one step serviced BOTH tenants, not two windows of the firehose
    assert svc.session("fire").windows_done == 1
    assert svc.session("drip").windows_done == 1
    svc.pump()
    assert svc.session("drip").windows_done == 2
    assert svc.session("fire").windows_done == 8


# ------------------------------------------------------- watchdog retry


def test_watchdog_retry_restores_snapshot():
    """A failing step is retried from the pre-step state snapshot: no
    double-counting, no lost windows, results equal a clean run."""
    svc = MiningService()
    cfg = SessionConfig(intervals=((0, 4),), theta=3, max_level=3,
                        history_limit=4)
    sid = svc.create_session("flaky", cfg)
    wins = split_by_index(tie_heavy_stream(6, n=240), 4)

    sess = svc.session(sid)
    real_update = sess.miner.update
    fails = {"left": 2}

    def flaky_update(window, final=False):
        if fails["left"]:
            fails["left"] -= 1
            raise RuntimeError("injected device loss")
        return real_update(window, final=final)

    sess.miner.update = flaky_update
    for j, w in enumerate(wins):
        svc.ingest(sid, w, final=j == len(wins) - 1)
    svc.pump()
    assert svc.scheduler.watchdog.retries == 2
    deltas = svc.poll(sid)
    assert [d.window_idx for d in deltas] == list(range(len(wins)))
    clean = cfg.make_miner()
    for j, (d, w) in enumerate(zip(deltas, wins)):
        ref = clean.update(w, final=j == len(wins) - 1)
        assert_results_equal(d.result, ref, f"window {j} after retry")


def test_error_before_first_submit_does_not_wedge_cotenants():
    """A tenant whose step dies before its *first* batcher submit must
    not strand co-tenants parked in their flush groups: ``end_step`` in
    the worker's finally re-checks group readiness, so the good lanes'
    groups flush, the step fails cleanly, and the watchdog retry makes
    everyone whole — bit-identically."""
    cfg = SessionConfig(intervals=((0, 4),), theta=3, max_level=3,
                        history_limit=4)
    # all three lanes co-resident: the good tenants must be parked in
    # their groups when the bad one dies
    svc = MiningService(policy=SchedulerPolicy(max_sessions=3,
                                               max_concurrent_lanes=3))
    streams = [tie_heavy_stream(20 + i, n=200) for i in range(3)]
    sids = []
    for i, stream in enumerate(streams):
        sid = svc.create_session(f"wedge-{i}", cfg)
        for j, w in enumerate(split_by_index(stream, 4)):
            svc.ingest(sid, w, final=j == 3)
        sids.append(sid)
    bad = svc.session(sids[2])
    real_update = bad.miner.update
    fails = {"left": 2}

    def dying_update(window, final=False):
        if fails["left"]:  # raises before any scan reaches the batcher
            fails["left"] -= 1
            raise RuntimeError("dies before first submit")
        return real_update(window, final=final)

    bad.miner.update = dying_update
    svc.pump()  # must terminate: no wedged co-tenant threads
    assert svc.scheduler.watchdog.retries == 2
    for i, sid in enumerate(sids):
        deltas = svc.poll(sid)
        assert [d.window_idx for d in deltas] == [0, 1, 2, 3]
        standalone = cfg.make_miner()
        for j, (d, w) in enumerate(zip(deltas,
                                       split_by_index(streams[i], 4))):
            ref = standalone.update(w, final=j == 3)
            assert_results_equal(d.result, ref, f"{sid} window {j}")


def test_group_scoped_flush_mixed_fleet_stays_exact():
    """Group-scoped flushes across a mixed fleet: same-shape tenants
    fuse, the odd-engine tenant's groups fall through as singletons, an
    oversized tenant forces a ``_split_oversized`` cut — and every
    tenant stays bit-identical to its standalone miner. Also pins the
    new stats surface (flush groups, gate decisions, pipeline
    overlap)."""
    # wide lanes so the whole hybrid fleet parks in one chunk and the
    # oversized tenant lands in the same flush group as the small ones
    svc = MiningService(policy=SchedulerPolicy(max_sessions=5,
                                               max_concurrent_lanes=8))
    svc.batcher.max_pad_ratio = 4.0
    tenants = []
    for i, n in enumerate((120, 130, 125, 4000)):
        cfg = SessionConfig(intervals=((0, 4),), theta=3, max_level=3,
                            engine="ptpe", history_limit=4)
        sid = svc.create_session(f"mix-{i}", cfg)
        wins = split_by_index(tie_heavy_stream(i, n=n), 3)
        tenants.append((sid, cfg, wins))
    odd_cfg = SessionConfig(intervals=((0, 4),), theta=3, max_level=3,
                            engine="mapconcatenate", history_limit=4)
    sid = svc.create_session("mix-odd", odd_cfg)
    wins = split_by_index(tie_heavy_stream(9, n=200), 3)
    tenants.append((sid, odd_cfg, wins))
    for sid, _, wins in tenants:
        for j, w in enumerate(wins):
            svc.ingest(sid, w, final=j == len(wins) - 1)
    svc.pump()
    assert svc.batcher.flush_groups > 0
    assert svc.batcher.batches > 0          # same-shape lanes fused
    assert svc.batcher.split_groups > 0     # oversized lane split out
    assert svc.batcher.gate_decisions["singleton"] > 0  # lone lanes
    stats = svc.stats()
    assert stats["batcher"]["flush_groups"] == svc.batcher.flush_groups
    assert sum(stats["batcher"]["fusion_gate"].values()) == \
        svc.batcher.flush_groups + svc.batcher.split_groups
    assert stats["scheduler"]["pipeline_overlap_s"] > 0.0
    for sid, cfg, wins in tenants:
        deltas = svc.poll(sid)
        standalone = cfg.make_miner()
        for j, (d, w) in enumerate(zip(deltas, wins)):
            ref = standalone.update(w, final=j == len(wins) - 1)
            assert_results_equal(d.result, ref,
                                 f"{sid} window {j} (group-scoped flush)")


def test_watchdog_retry_double_buffered_no_double_count():
    """A mid-run failure lands while the scheduler has already staged the
    *next* step's windows (double-buffering). The rewind must drop those
    preps, restore every lane, and re-run — no window double-counted, no
    meter row duplicated, results bit-identical."""
    cfg = SessionConfig(intervals=((0, 4),), theta=3, max_level=3,
                        history_limit=4)
    svc = MiningService(policy=SchedulerPolicy(max_sessions=3))
    streams = [tie_heavy_stream(30 + i, n=220) for i in range(3)]
    sids = []
    for i, stream in enumerate(streams):
        sid = svc.create_session(f"dbuf-{i}", cfg)
        for j, w in enumerate(split_by_index(stream, 4)):
            svc.ingest(sid, w, final=j == 3)
        sids.append(sid)
    flaky = svc.session(sids[1])
    real_update = flaky.miner.update
    state = {"calls": 0, "failed": False}

    def flaky_update(window, final=False):
        state["calls"] += 1
        if state["calls"] == 3 and not state["failed"]:
            state["failed"] = True  # fail once, mid-run, step 3
            raise RuntimeError("transient mid-run failure")
        return real_update(window, final=final)

    flaky.miner.update = flaky_update
    svc.pump()
    assert svc.scheduler.watchdog.retries == 1
    for i, sid in enumerate(sids):
        s = svc.session(sid)
        assert s.windows_done == 4
        assert s.staged_count == 0
        # meter rows == committed windows: the rewind un-counted the
        # failed attempt (and the prestaged next step) exactly once
        assert len(s.meter.rows) == s.windows_done
        deltas = svc.poll(sid)
        assert [d.window_idx for d in deltas] == [0, 1, 2, 3]
        standalone = cfg.make_miner()
        for j, (d, w) in enumerate(zip(deltas,
                                       split_by_index(streams[i], 4))):
            ref = standalone.update(w, final=j == 3)
            assert_results_equal(d.result, ref, f"{sid} window {j}")


# ------------------------------------------------ scheduler error hygiene


def test_unknown_session_typed_error_and_evict_gauge():
    """Unknown session ids raise ``UnknownSessionError`` (a ``KeyError``
    subclass, so legacy guards still catch it), and evicting a tenant
    updates the queue-depth gauge instead of leaving it stale."""
    from repro.obs import REGISTRY
    from repro.service import UnknownSessionError

    assert issubclass(UnknownSessionError, KeyError)
    svc = MiningService(policy=SchedulerPolicy(max_sessions=2))
    cfg = SessionConfig(intervals=((0, 4),), theta=3)
    svc.create_session("a", cfg)
    wins = split_by_index(tie_heavy_stream(0, n=120), 3)
    with pytest.raises(UnknownSessionError, match="ghost"):
        svc.ingest("ghost", wins[0])
    with pytest.raises(UnknownSessionError, match="ghost"):
        svc.scheduler.evict("ghost")
    with pytest.raises(UnknownSessionError, match="ghost"):
        svc.poll("ghost")
    svc.ingest("a", wins[0])
    svc.ingest("a", wins[1])
    assert int(REGISTRY.gauge("scheduler_queue_depth").value) == 2
    svc.scheduler.evict("a")
    assert int(REGISTRY.gauge("scheduler_queue_depth").value) == 0
