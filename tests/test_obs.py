"""Observability-plane tests: span exception-safety and nesting (down
through a batched service run), registry snapshot/delta determinism, the
KERNEL_CALLS facade ≡ registry equivalence (including a forced
kernel→XLA degradation), Chrome trace-event export schema, and the
jaxprof/tracecheck recompile-regex pin."""

import json
import re
import threading

import numpy as np
import pytest

import jax

from repro.core.episodes import EpisodeBatch
from repro.core.streaming import StreamingCounter
from repro.data import partition_windows, sym26
from repro.kernels.tally import (KERNEL_CALLS, fallback_counts,
                                 record_fallback, reset_kernel_calls)
from repro.obs import REGISTRY, TRACER
from repro.obs.jaxprof import _COMPILE_RE, ensure_recompile_listener
from repro.obs.registry import Registry
from repro.obs.trace import Tracer, step_breakdown
from repro.service import MiningService, SchedulerPolicy, SessionConfig


# ------------------------------------------------------------------ spans


def test_span_closes_on_exception():
    tr = Tracer()
    with pytest.raises(ValueError):
        with tr.span("outer"):
            with tr.span("inner"):
                raise ValueError("boom")
    assert tr.current() is None  # both stacks unwound
    names = [e.name for e in tr.events()]
    assert names == ["inner", "outer"]  # closed inside-out, both recorded


def test_span_nesting_depth_and_args():
    tr = Tracer()
    with tr.span("a", step=1):
        assert tr.current() == "a"
        with tr.span("b"):
            assert tr.current() == "b"
    evs = tr.events()
    by_name = {e.name: e for e in evs}
    assert by_name["a"].depth == 0 and by_name["b"].depth == 1
    assert by_name["a"].args == {"step": 1}
    assert by_name["b"].t0 >= by_name["a"].t0
    assert by_name["b"].dur <= by_name["a"].dur


def test_span_disabled_records_nothing():
    tr = Tracer()
    tr.enabled = False
    with tr.span("x"):
        pass
    assert tr.events() == []


def test_spans_are_per_thread():
    tr = Tracer()
    gate = threading.Barrier(4)  # overlap the threads so tids are distinct

    def work(i):
        with tr.span("t", i=i):
            gate.wait(timeout=10)

    threads = [threading.Thread(target=work, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    evs = tr.events()
    assert len(evs) == 4
    assert len({e.tid for e in evs}) == 4
    assert all(e.depth == 0 for e in evs)  # no cross-thread stack bleed


# --------------------------------------------------------------- registry


def test_registry_snapshot_and_delta_determinism():
    reg = Registry()
    reg.counter("req_total", route="a").inc(3)
    reg.counter("req_total", route="b").inc()
    reg.gauge("depth").set(7)
    reg.histogram("lat_s").observe(0.01)
    reg.histogram("lat_s").observe(0.02)
    s1 = reg.snapshot()
    s2 = reg.snapshot()
    assert s1 == s2
    assert list(s1) == sorted(s1)  # deterministic ordering
    assert s1["req_total{route=a}"] == 3
    assert s1["depth"] == 7
    assert s1["lat_s"]["count"] == 2

    before = reg.snapshot()
    reg.counter("req_total", route="a").inc(2)
    reg.histogram("lat_s").observe(0.05)
    d = Registry.delta(before, reg.snapshot())
    assert d["req_total{route=a}"] == 2
    assert d["lat_s"]["count"] == 1
    assert "depth" not in d  # unchanged series dropped
    assert "req_total{route=b}" not in d


def test_registry_type_conflict_rejected():
    reg = Registry()
    reg.counter("thing")
    with pytest.raises(TypeError):
        reg.gauge("thing")


def test_histogram_quantiles_bracket_observations():
    reg = Registry()
    h = reg.histogram("h")
    for v in (0.001, 0.01, 0.1, 1.0):
        h.observe(v)
    d = h.to_dict()
    assert d["count"] == 4 and d["min"] == 0.001 and d["max"] == 1.0
    assert d["min"] <= d["p50"] <= d["p99"] <= d["max"] * 1.01


# --------------------------------------------- KERNEL_CALLS facade ≡ registry


def test_kernel_calls_view_is_the_registry():
    reset_kernel_calls()
    KERNEL_CALLS["a1"] += 3
    KERNEL_CALLS["a2_state"] += 1
    assert REGISTRY.counter("kernel_calls", kind="a1").value == 3
    assert dict(KERNEL_CALLS) == {"a1": 3, "a2_state": 1}
    assert KERNEL_CALLS["never_touched"] == 0  # Counter semantics
    record_fallback("some_site")
    assert KERNEL_CALLS["fallback:some_site"] == 1
    assert fallback_counts()["some_site"] == 1
    assert REGISTRY.snapshot()["kernel_calls{kind=fallback:some_site}"] == 1
    reset_kernel_calls()
    assert dict(KERNEL_CALLS) == {}
    assert "kernel_calls{kind=a1}" not in REGISTRY.snapshot()


def test_forced_degradation_lands_in_registry(monkeypatch):
    if jax.default_backend() == "tpu":
        pytest.skip("kernel dispatch cannot be declined on TPU")
    for var in ("REPRO_KERNEL_INTERPRET", "REPRO_INTERPRET_KERNELS"):
        monkeypatch.delenv(var, raising=False)
    reset_kernel_calls()
    eps = EpisodeBatch(np.array([[0, 1]], np.int32),
                       np.array([[2]], np.int32), np.array([[9]], np.int32))
    # no TPU, interpret not requested -> the kernel residency probe must
    # decline and the downgrade must land in the shared registry
    StreamingCounter(eps, engine="ptpe", use_kernel=True)
    assert KERNEL_CALLS["fallback:stream_a1_residency"] == 1
    assert REGISTRY.counter(
        "kernel_calls", kind="fallback:stream_a1_residency").value == 1
    assert fallback_counts() == {"stream_a1_residency": 1}
    reset_kernel_calls()


# ---------------------------------------------------------------- exports


def test_chrome_trace_schema(tmp_path):
    tr = Tracer()
    with tr.span("phase.outer", k="v"):
        with tr.span("phase.inner"):
            pass
    path = tmp_path / "trace.json"
    n = tr.export_chrome(path)
    assert n == 2
    doc = json.loads(path.read_text())
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    ms = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert len(xs) == 2 and len(ms) == 1
    for e in xs:
        assert {"name", "cat", "ph", "ts", "dur", "pid", "tid"} <= set(e)
        assert e["ts"] >= 0 and e["dur"] >= 0  # µs from trace origin
        assert e["tid"] == 0  # single thread remaps to small int
    assert ms[0]["name"] == "thread_name"
    inner, outer = sorted(xs, key=lambda e: e["ts"], reverse=True)
    assert inner["name"] == "phase.inner"
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1.0

    jl = tmp_path / "trace.jsonl"
    assert tr.export_jsonl(jl) == 2
    rows = [json.loads(line) for line in jl.read_text().splitlines()]
    assert [r["name"] for r in rows] == ["phase.inner", "phase.outer"]
    assert all({"name", "ts", "dur_s", "tid", "depth", "args"} <= set(r)
               for r in rows)


# ------------------------------------------------- service-threaded spans


def test_spans_nest_through_batched_service():
    TRACER.clear()
    svc = MiningService(policy=SchedulerPolicy(max_sessions=4))
    feeds = {}
    for i in range(2):
        stream, _ = sym26(seconds=1, rate_hz=10.0, seed=40 + i)
        sid = svc.create_session(f"obs-{i}", SessionConfig(window_ms=500))
        wins = list(partition_windows(stream, 500))
        feeds[sid] = wins
    for sid, wins in feeds.items():
        for j, w in enumerate(wins):
            svc.ingest(sid, w, final=j == len(wins) - 1)
    svc.pump()
    evs = TRACER.events()
    names = {e.name for e in evs}
    assert {"service.ingest", "schedule.step", "schedule.snapshot",
            "session.mine_window", "batch.barrier_wait"} <= names
    # every mine_window nests inside some schedule.step's window
    steps = [e for e in evs if e.name == "schedule.step"]
    for m in (e for e in evs if e.name == "session.mine_window"):
        assert any(s.t0 <= m.t0 and m.t0 + m.dur <= s.t0 + s.dur + 1e-6
                   for s in steps)
    bd = step_breakdown()
    assert bd["steps"] == len(steps) > 0
    assert 0.5 < bd["coverage"] <= 1.05

    stats = svc.stats()
    assert stats["scheduler"]["queue_depth"] == 0
    assert stats["scheduler"]["heartbeat_ts"] > 0
    assert "recompiles" in stats["kernel"]
    assert "fallbacks" in stats["kernel"]
    assert stats["metrics"]["scheduler_steps_total"] >= len(steps)
    for sid in feeds:
        assert f"session_windows_total{{session={sid}}}" in stats["metrics"]


# ---------------------------------------------------------------- jaxprof


def test_recompile_regex_pinned_to_tracecheck():
    from repro.analysis.tracecheck import _COMPILE_RE as tc_re
    assert _COMPILE_RE.pattern == tc_re.pattern


def test_recompile_listener_counts_compiles():
    assert ensure_recompile_listener()
    before = {labels["kernel"]: m.value
              for labels, m in REGISTRY.family_items("recompiles")}

    def _obs_probe_fn(x):
        return x * 2 + 1

    jax.jit(_obs_probe_fn)(np.arange(37, dtype=np.int32))
    after = {labels["kernel"]: m.value
             for labels, m in REGISTRY.family_items("recompiles")}
    grew = [k for k in after if after[k] > before.get(k, 0)]
    assert any("_obs_probe_fn" in k for k in grew), (before, after)


def test_recompile_regex_accepts_jax_names():
    m = re.match(_COMPILE_RE, "Compiling _a1_scan_core with global shapes "
                              "and types [ShapedArray(int32[128])].")
    assert m and m.group(1) == "_a1_scan_core"
