"""End-to-end miner behaviour: planted episodes are recovered."""

from repro.core import count_a1_sequential, mine, mine_partitions
from repro.data import embedded_chain_stream, partition_windows, sym26


def test_mine_recovers_planted_chain():
    chain, interval = [1, 3, 5], (5, 10)
    st = embedded_chain_stream(8, chain, interval, num_occurrences=60,
                               noise_events=1500, t_max=120_000, seed=3)
    res = mine(st, intervals=[interval], theta=50, max_level=3)
    lvl3 = res.frequent[2]
    found = {tuple(e) for e in lvl3.etypes.tolist()}
    assert tuple(chain) in found
    # the reported count must equal the exact oracle count
    idx = [tuple(e) for e in lvl3.etypes.tolist()].index(tuple(chain))
    want = count_a1_sequential(st, lvl3.select([idx]))[0]
    assert res.counts[2][idx] == want >= 50


def test_mine_two_pass_equals_one_pass_frequent_sets():
    st = embedded_chain_stream(6, [0, 2, 4], (2, 8), num_occurrences=40,
                               noise_events=800, t_max=60_000, seed=5)
    r2 = mine(st, intervals=[(2, 8)], theta=30, max_level=3, two_pass=True)
    r1 = mine(st, intervals=[(2, 8)], theta=30, max_level=3, two_pass=False)
    for a, b in zip(r2.frequent, r1.frequent):
        assert {tuple(e) for e in a.etypes.tolist()} == \
               {tuple(e) for e in b.etypes.tolist()}
    # two-pass must actually have culled something at level >= 2
    assert any(s.num_survived_a2 < s.num_candidates for s in r2.stats[1:])


def test_sym26_recovers_embedded_chains():
    st, truth = sym26(seconds=20, seed=0)
    chain, interval, n_planted = truth["short"]
    res = mine(st, intervals=[interval], theta=int(n_planted * 0.6),
               max_level=3)
    found = {tuple(e) for e in res.frequent[2].etypes.tolist()}
    assert tuple(chain) in found


def test_streaming_partitions():
    st = embedded_chain_stream(6, [1, 2, 3], (2, 6), num_occurrences=80,
                               noise_events=1000, t_max=80_000, seed=7)
    windows = list(partition_windows(st, window_ms=20_000, overlap_ms=12))
    assert len(windows) >= 4
    total = 0
    for _, res in mine_partitions(windows, [(2, 6)], theta_per_window=5,
                                  max_level=3):
        if len(res.frequent) < 3:  # window with too few events mined nothing
            continue
        lv3 = res.frequent[2]
        hits = [tuple(e) for e in lv3.etypes.tolist()]
        if (1, 2, 3) in hits:
            total += int(res.counts[2][hits.index((1, 2, 3))])
    assert total >= 60  # most planted occurrences recovered across windows
