"""Calibrated dispatch (core.calibrate): fit/cache round-trip, versioned
invalidation, heuristic-fallback parity, the policy consult points in
hybrid/streaming/batcher, and the fig7 regret regression pins.

The load-bearing claims:

* with no table the policy reproduces the Eq. 2 heuristic *exactly* —
  installing calibration changes wall clock only, never results;
* a cached table steers dispatch only when schema, code version, and
  device fingerprint all match — anything stale degrades to the
  heuristic instead of dispatching on foreign timings;
* the kernel probe is cached per process and its fallback tallied once,
  not once per dispatch (the fig7 N3_M512/N5_M512 regret root cause).
"""

import json

import numpy as np
import pytest

from repro.core import EpisodeBatch, EventStream, calibrate, hybrid
from repro.core.calibrate import (CalibrationTable, DispatchPolicy,
                                  FEATURE_NAMES, GridSpec, analytic_seconds,
                                  features, fit_table, install_table,
                                  load_table)
from repro.obs import REGISTRY

HW = {"peak_flops": 197e12, "hbm_bw": 819e9, "ici_bw": 50e9}


@pytest.fixture(autouse=True)
def _hermetic_policy():
    """Every test starts (and leaves) the process on the heuristic."""
    calibrate.clear_policy()
    REGISTRY.clear_family("dispatch_policy_total")
    yield
    calibrate.clear_policy()
    REGISTRY.clear_family("dispatch_policy_total")


def small_stream(n=64, num_types=5, seed=0):
    rng = np.random.default_rng(seed)
    return EventStream(
        types=rng.integers(0, num_types, size=n).astype(np.int32),
        times=np.cumsum(rng.integers(1, 3, size=n)).astype(np.int32),
        num_types=num_types)


def small_eps(m=4, n=3, num_types=5, seed=1):
    rng = np.random.default_rng(seed)
    et = rng.integers(0, num_types, size=(m, n)).astype(np.int32)
    tlo = np.full((m, n - 1), 1, np.int32)
    thi = np.full((m, n - 1), 8, np.int32)
    return EpisodeBatch(et, tlo, thi)


def synth_points(true, n_events=(1024, 4096), seed=0):
    """Grid timings generated from known per-engine linear models."""
    rng = np.random.default_rng(seed)
    pts = []
    for engine, coef in true.items():
        for n_ep in (2, 3, 5):
            for m in (16, 128, 512):
                for n_ev in n_events:
                    for q in ((1,) if engine == "ptpe" else (1, 4, 8)):
                        a = analytic_seconds(engine, n_ep, m, n_ev, q,
                                             1, HW)
                        phi = features(n_ep, m, n_ev, q, a)
                        y = sum(c * x for c, x in zip(coef, phi))
                        y *= 1.0 + rng.uniform(-0.01, 0.01)
                        pts.append({"engine": engine, "n_episode": n_ep,
                                    "m": m, "n_events": n_ev, "q": q,
                                    "devices": 1, "seconds": y})
    return pts


def make_table(true, device_kind="cpu:cpux1"):
    return fit_table(synth_points(true), HW, device_kind=device_kind)


# ptpe flat-ish; mapconcatenate cheap on events but scales with cells —
# so low M prefers mapc, high M prefers ptpe (the fig7 shape)
TRUE = {
    "ptpe": [2e-3, 1e-3, 1e-4, 1e-5, 0.0, 0.0],
    "mapconcatenate": [1e-3, 2e-4, 5e-3, 1e-4, 2e-4, 0.0],
}


# ------------------------------------------------------------ model + fit


def test_analytic_seconds_engine_shape():
    kw = dict(n_episode=3, m=128, n_events=4096, q=8, devices=4, hw=HW)
    t = {e: analytic_seconds(e, kw["n_episode"], kw["m"], kw["n_events"],
                             kw["q"], kw["devices"], kw["hw"])
         for e in calibrate.ENGINES}
    # the kernel halves effective traffic; sharding divides it by devices
    assert t["mapconcat_kernel"] < t["mapconcatenate"]
    assert t["mapconcat_sharded"] < t["mapconcat_kernel"]
    assert t["ptpe"] < t["mapconcatenate"]
    with pytest.raises(ValueError):
        analytic_seconds("nope", 3, 128, 4096, 8, 1, HW)


def test_fit_recovers_relative_ordering():
    table = make_table(TRUE)
    assert set(table.coeffs) == set(TRUE)
    for engine, coef in TRUE.items():
        for (n_ep, m, n_ev) in ((2, 16, 1024), (5, 512, 4096)):
            a = analytic_seconds(engine, n_ep, m, n_ev, 1, 1, HW)
            truth = sum(c * x for c, x in
                        zip(coef, features(n_ep, m, n_ev, 1, a)))
            got = table.predict(engine, n_episode=n_ep, m=m,
                                n_events=n_ev, q=1)
            assert got == pytest.approx(truth, rel=0.15)


def test_predict_unmeasured_engine_is_none():
    table = make_table(TRUE)
    assert table.predict("mapconcat_kernel", n_episode=3, m=16,
                         n_events=1024) is None


# ----------------------------------------------- cache + invalidation


def test_table_roundtrip(tmp_path):
    table = make_table(TRUE)
    path = str(tmp_path / "cal" / "t.json")
    table.save(path)
    back = load_table(path)
    assert back is not None
    assert back.device_kind == table.device_kind
    assert back.coeffs == table.coeffs
    assert back.segment_counts == table.segment_counts


@pytest.mark.parametrize("corrupt", [
    lambda d: d.update(schema=99),
    lambda d: d.update(code_version="cal0-ancient"),
    lambda d: d["coeffs"].update(ptpe=[1.0, 2.0]),  # wrong feature dim
    lambda d: d.pop("device_kind"),
])
def test_stale_table_is_rejected(tmp_path, corrupt):
    doc = make_table(TRUE).to_doc()
    corrupt(doc)
    path = tmp_path / "t.json"
    path.write_text(json.dumps(doc))
    assert load_table(str(path)) is None


def test_load_missing_or_garbage(tmp_path):
    assert load_table(str(tmp_path / "absent.json")) is None
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert load_table(str(bad)) is None


def test_install_wrong_device_degrades_to_heuristic(tmp_path):
    table = make_table(TRUE, device_kind="tpu:TPU v5ex8")
    path = str(tmp_path / "t.json")
    table.save(path)
    pol = install_table(path)
    assert pol.table is None and pol.source == "heuristic"
    # ... and without the match requirement it steers
    pol = install_table(path, require_device_match=False)
    assert pol.table is not None and pol.source == "calibrated"


def test_env_table_opt_in(tmp_path, monkeypatch):
    table = make_table(TRUE, device_kind=calibrate.device_fingerprint())
    path = str(tmp_path / "t.json")
    table.save(path)
    monkeypatch.setenv(calibrate.ENV_TABLE, path)
    calibrate.clear_policy()
    assert calibrate.get_policy().source == "calibrated"
    monkeypatch.setenv(calibrate.ENV_TABLE, str(tmp_path / "absent.json"))
    calibrate.clear_policy()
    assert calibrate.get_policy().source == "heuristic"


def test_default_table_path_is_fingerprint_scoped(monkeypatch):
    monkeypatch.setenv(calibrate.ENV_TABLE_DIR, "/cal")
    p = calibrate.default_table_path()
    assert p.startswith("/cal/") and p.endswith(".json")
    assert "/" not in p[len("/cal/"):]


# ------------------------------------------------------------- policy


def test_heuristic_parity_with_eq2(monkeypatch):
    """No table: choose() must reproduce hybrid's Eq. 2 exactly."""
    pol = DispatchPolicy()
    monkeypatch.setattr(hybrid, "crossover", lambda n: 100)
    # above crossover, no kernel -> ptpe
    c = pol.choose(n_events=4096, n_episode=3, m=512, kernel_ok=False)
    assert (c.engine, c.source) == ("ptpe", "heuristic")
    # below crossover, no kernel -> mapconcatenate
    c = pol.choose(n_events=4096, n_episode=3, m=16, kernel_ok=False)
    assert c.engine == "mapconcatenate"
    # long stream + small batch + kernel -> the segmented kernel
    c = pol.choose(n_events=4096, n_episode=3, m=16, kernel_ok=True)
    assert c.engine == "mapconcat_kernel"
    # ... upgraded to the sharded form on a multi-device mesh
    c = pol.choose(n_events=4096, n_episode=3, m=16, kernel_ok=True,
                   shard_devices=4)
    assert c.engine == "mapconcat_sharded"
    # short stream never takes the kernel
    c = pol.choose(n_events=512, n_episode=3, m=16, kernel_ok=True)
    assert c.engine == "mapconcatenate"


def test_regression_fig7_many_episode_rows_pin_ptpe():
    """The fig7 N3_M512/N5_M512 2x-regret pin: on a single-device host
    with no kernel the heuristic must hand M=512 to PTPE."""
    pol = DispatchPolicy()
    for n in (3, 5):
        c = pol.choose(n_events=20000, n_episode=n, m=512,
                       kernel_ok=False, shard_devices=1)
        assert c.engine == "ptpe", f"N{n}_M512 regressed to {c.engine}"


def test_calibrated_choice_is_argmin_and_cached():
    pol = DispatchPolicy(make_table(TRUE))
    lo = pol.choose(n_events=4096, n_episode=3, m=16, kernel_ok=False)
    hi = pol.choose(n_events=4096, n_episode=3, m=512, kernel_ok=False)
    assert lo.source == hi.source == "calibrated"
    assert lo.engine == "mapconcatenate"
    assert hi.engine == "ptpe"
    assert hi.predicted_s == pol.table.predict(
        "ptpe", n_episode=3, m=512, n_events=4096, q=1)
    # same shape -> cached decision object, and n is bucketed
    assert pol.choose(n_events=4000, n_episode=3, m=512,
                      kernel_ok=False) is hi


def test_calibrated_never_picks_unavailable_engine():
    pol = DispatchPolicy(make_table(TRUE))
    for m in (16, 128, 512):
        c = pol.choose(n_events=4096, n_episode=3, m=m, kernel_ok=False)
        assert c.engine in ("ptpe", "mapconcatenate")


def test_choose_stream_matches_regimes(monkeypatch):
    pol = DispatchPolicy(make_table(TRUE))
    assert pol.choose_stream(n_episode=3, m=512).engine == "ptpe"
    assert pol.choose_stream(n_episode=3, m=16).engine == "mapconcatenate"
    # heuristic branch defers to Eq. 2
    monkeypatch.setattr(hybrid, "crossover", lambda n: 100)
    heur = DispatchPolicy()
    assert heur.choose_stream(n_episode=3, m=512).engine == "ptpe"
    assert heur.choose_stream(n_episode=3, m=16).engine == "mapconcatenate"


def test_choose_segments_heuristic_keeps_caller_preference():
    pol = DispatchPolicy()
    q, src = pol.choose_segments([8, 4, 1], engine="mapconcatenate",
                                 n_episode=3, m=16, n_events=4096)
    assert (q, src) == (8, "heuristic")
    with pytest.raises(ValueError):
        pol.choose_segments([], engine="mapconcatenate", n_episode=3,
                            m=16, n_events=4096)


def test_choose_segments_calibrated_scores_candidates():
    pol = DispatchPolicy(make_table(TRUE))
    q, src = pol.choose_segments([8, 4, 1], engine="mapconcatenate",
                                 n_episode=3, m=16, n_events=4096)
    assert src == "calibrated"
    best = min((pol.table.predict("mapconcatenate", n_episode=3, m=16,
                                  n_events=4096, q=c), c)
               for c in (8, 4, 1))[1]
    assert q == best


def test_predict_single_none_under_heuristic():
    assert DispatchPolicy().predict_single(
        "ptpe", n_episode=3, m=16) is None
    got = DispatchPolicy(make_table(TRUE)).predict_single(
        "ptpe", n_episode=3, m=16)
    assert got is not None and got > 0


def test_decisions_exported_to_registry():
    pol = DispatchPolicy()
    for _ in range(3):
        pol.choose(n_events=4096, n_episode=3, m=512, kernel_ok=False)
    stats = pol.stats()
    assert stats["source"] == "heuristic"
    assert stats["decisions"] == {"ptpe/heuristic": 3}


# ------------------------------------------------- consult-point wiring


def test_hybrid_dispatch_bit_identical_across_policy(tmp_path):
    stream, eps = small_stream(), small_eps()
    ref = np.asarray(hybrid.count_dispatch(stream, eps, engine="ptpe"))
    got_heur = np.asarray(hybrid.count_dispatch(stream, eps,
                                                engine="hybrid"))
    # a table rigged so hybrid routes to mapconcatenate instead
    table = make_table({"ptpe": [1.0, 0, 0, 0, 0, 0],
                        "mapconcatenate": [1e-6, 0, 0, 0, 0, 0]})
    calibrate.set_policy(DispatchPolicy(table))
    got_cal = np.asarray(hybrid.count_dispatch(stream, eps,
                                               engine="hybrid"))
    np.testing.assert_array_equal(ref, got_heur)
    np.testing.assert_array_equal(ref, got_cal)
    dec = calibrate.policy_stats()["decisions"]
    assert dec.get("mapconcatenate/calibrated", 0) >= 1


def test_probe_cached_and_tallied_once():
    from repro.kernels.tally import fallback_counts
    hybrid._reset_probe_cache()
    REGISTRY.clear_family("kernel_calls")
    first = hybrid._mapc_kernel_available()
    for _ in range(5):
        assert hybrid._mapc_kernel_available() == first
    tallies = fallback_counts().get("hybrid_mapc_probe", 0)
    assert tallies == (0 if first else 1)
    hybrid._reset_probe_cache()


def test_crossover_capacity_and_kernel_aware(monkeypatch):
    monkeypatch.setattr(hybrid, "parallel_units", lambda: 1)
    monkeypatch.setattr(hybrid, "_mapc_kernel_available", lambda: False)
    assert hybrid.crossover(4) == 0
    # the segmented kernel gives a lone device one real segment axis
    monkeypatch.setattr(hybrid, "_mapc_kernel_available", lambda: True)
    assert hybrid.crossover(4) == int(hybrid.f_of_n(4))
    monkeypatch.setattr(hybrid, "parallel_units", lambda: 8)
    assert hybrid.crossover(2) > hybrid.crossover(8) > 0


def test_batcher_prior_decodes_seam_keys():
    from repro.service.batcher import _policy_prior
    assert _policy_prior(("a1", 16, 3, 4)) is None  # heuristic: no prior
    calibrate.set_policy(DispatchPolicy(make_table(TRUE)))
    one = _policy_prior(("a1", 16, 3, 4))
    assert one is not None and one > 0
    mapc = _policy_prior(("mapc", 16, 3, 8, 4))
    assert mapc is not None and mapc > 0
    # kernel-side seams carry shape tuples; unmeasured engine -> None
    assert _policy_prior(("a1k", 3, 4, False, (3, 16))) is not None
    assert _policy_prior(("mapck", 3, 4, False, (3, 16), 8)) is None


def test_service_stats_surface_calibration():
    from repro.service import MiningService
    svc = MiningService()
    stats = svc.stats()
    assert stats["calibration"]["source"] in ("heuristic", "calibrated")
    assert "decisions" in stats["calibration"]


# ------------------------------------------- measurement path (smoke)


def test_measure_fit_install_roundtrip(tmp_path):
    spec = GridSpec(episode_sizes=(2,), episode_counts=(4,),
                    event_counts=(64,), segment_counts=(1,),
                    repeats=1, warmup=1, num_types=5)
    seen = []
    pts = calibrate.measure_grid(spec, progress=seen.append)
    assert {p["engine"] for p in pts} >= {"ptpe", "mapconcatenate"}
    assert len(seen) == len(pts)
    assert all(p["seconds"] > 0 for p in pts)
    # too few points per engine for a 6-feature fit -> engine dropped,
    # prediction honestly None rather than extrapolated garbage
    table = fit_table(pts, HW, device_kind="test:x1")
    assert table.predict("ptpe", n_episode=2, m=4, n_events=64) is None


def test_calibrate_and_save_installs_and_caches(tmp_path):
    spec = GridSpec(episode_sizes=(2, 3), episode_counts=(4, 8, 16),
                    event_counts=(64, 128), segment_counts=(1, 2),
                    repeats=1, warmup=1, num_types=5)
    path = str(tmp_path / "cal" / "table.json")
    table, got_path = calibrate.calibrate_and_save(
        spec, hw=HW, out_path=path)
    assert got_path == path
    assert calibrate.get_policy().source == "calibrated"
    back = load_table(path)
    assert back is not None and back.coeffs == table.coeffs
    # the cached table steers a fresh process the same way
    calibrate.clear_policy()
    pol = install_table(path)
    assert pol.source == "calibrated"


# --------------------------------------------------- analysis-plane tie


def test_vmem_pass_covers_calibration_grid():
    from repro.analysis.vmem import check_calibration_grid
    from repro.kernels.ops import MAX_SEG_BRICK_LW
    pts = GridSpec().points()
    findings, summary = check_calibration_grid(pts, MAX_SEG_BRICK_LW)
    assert findings == []
    assert summary["vmem_calibration_points"] == len(pts)
    assert 0 < summary["vmem_calibration_worst_lw"] <= MAX_SEG_BRICK_LW
    # a tightened admission bound turns the same grid red
    findings, _ = check_calibration_grid(pts, 256)
    assert findings and all(f.rule == "VM304" for f in findings)


def test_calibrate_module_imports_stay_stdlib():
    """The analysis plane reads tables without jax/numpy: the module-
    level import set must stay stdlib-only (heavy deps are lazy)."""
    import ast
    import repro.core.calibrate as mod
    tree = ast.parse(open(mod.__file__).read())
    top = set()
    for node in tree.body:
        if isinstance(node, ast.Import):
            top |= {a.name.split(".")[0] for a in node.names}
        elif isinstance(node, ast.ImportFrom) and node.level == 0:
            top.add((node.module or "").split(".")[0])
    assert "jax" not in top and "numpy" not in top
