"""Counting-engine correctness: vectorized == sequential oracles, exactly."""

import numpy as np
import pytest

from repro.core import (EpisodeBatch, EventStream, count_a1, count_a2,
                        count_single_slot,
                        count_a1_sequential, count_a2_sequential,
                        count_occurrences_naive, mapconcatenate)
from repro.data import embedded_chain_stream, random_stream


def _random_batch(rng, m, n, num_types, tmax_iv=12):
    et = rng.integers(0, num_types, size=(m, n)).astype(np.int32)
    tlo = rng.integers(0, tmax_iv // 2, size=(m, n - 1)).astype(np.int32)
    thi = (tlo + rng.integers(1, tmax_iv, size=(m, n - 1))).astype(np.int32)
    return EpisodeBatch(et, tlo, thi)


# ------------------------------------------------------------- paper figure 2


def test_paper_fig2_example():
    """The worked example of §2: exactly one occurrence of
    A --(5,10]--> B --(10,15]--> C in the Fig. 2 stream."""
    # Fig.2-like stream: A@1 B@2 A@5 C@10 B@12 A@13 C@25 B@30 C@35 ...
    types = [0, 1, 0, 2, 1, 0, 2, 1, 2]
    times = [1, 2, 5, 10, 12, 13, 25, 30, 35]
    st = EventStream(np.int32(types), np.int32(times), 3)
    ep = EpisodeBatch.single([0, 1, 2], [5, 10], [10, 15])
    # A@5 → B@12 (Δ=7∈(5,10]) → C@25 (Δ=13∈(10,15]) : one occurrence
    assert count_a1_sequential(st, ep)[0] == 1
    assert count_a1(st, ep, use_kernel=False)[0] == 1


def test_nonoverlap_semantics():
    """Fig. 2 of the paper: 8 total occurrences of A→B but only 2
    non-overlapped (with loose constraints covering all of them)."""
    # A A B A B A B B  — the classic example shape
    types = [0, 0, 1, 0, 1, 0, 1, 1]
    times = [1, 2, 3, 4, 5, 6, 7, 8]
    st = EventStream(np.int32(types), np.int32(times), 2)
    ep = EpisodeBatch.single([0, 1], [0], [100])
    c = count_a1_sequential(st, ep)[0]
    # greedy non-overlap: A@1→B@3, A@4→B@5, A@6→B@7 = 3 non-overlapped
    assert c == 3
    assert count_a1(st, ep, use_kernel=False)[0] == c


# ------------------------------------------------- vectorized == sequential


@pytest.mark.parametrize("n", [2, 3, 4, 5])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_a2_vectorized_equals_oracle(n, seed):
    rng = np.random.default_rng(seed)
    st = random_stream(6, 400, 600, seed=seed)
    eps = _random_batch(rng, 37, n, 6).relaxed()
    want = count_a2_sequential(st, eps)  # inclusive-lower strengthening
    got = count_single_slot(st, eps, inclusive_lower=True)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("seed", [0, 1])
def test_a2_matches_paper_algorithm3_on_tiefree_streams(seed):
    """On strictly-increasing timestamps our strengthened A2 IS the paper's
    literal Algorithm 3."""
    rng = np.random.default_rng(seed)
    times = np.cumsum(rng.integers(1, 5, size=300)).astype(np.int32)
    types = rng.integers(0, 6, size=300).astype(np.int32)
    st = EventStream(types, times, 6)
    eps = _random_batch(rng, 23, 3, 6).relaxed()
    paper = count_a2_sequential(st, eps, inclusive_lower=False)
    ours = count_a2_sequential(st, eps, inclusive_lower=True)
    vec = count_single_slot(st, eps, inclusive_lower=True)
    np.testing.assert_array_equal(paper, ours)
    np.testing.assert_array_equal(vec, ours)


@pytest.mark.parametrize("n", [2, 3, 4, 5])
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_a1_vectorized_equals_oracle(n, seed):
    rng = np.random.default_rng(100 + seed)
    st = random_stream(5, 400, 500, seed=seed)  # dense stream stresses lists
    eps = _random_batch(rng, 29, n, 5)
    want = count_a1_sequential(st, eps)
    got = count_a1(st, eps, use_kernel=False)  # includes overflow fallback
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("lcap", [1, 2, 8])
def test_a1_lcap_overflow_fallback_restores_exactness(lcap):
    """Tiny list capacities must still give exact results via the
    live-eviction flag → sequential recount path."""
    rng = np.random.default_rng(7)
    st = random_stream(3, 500, 400, seed=9)  # very dense: many evictions
    eps = _random_batch(rng, 17, 3, 3)
    want = count_a1_sequential(st, eps)
    got = count_a1(st, eps, lcap=lcap, use_kernel=False)
    np.testing.assert_array_equal(got, want)


def test_a1_agrees_with_naive_earliest_completion():
    """Cross-check Algorithm 1 against an independent greedy searcher on
    small streams with distinct timestamps."""
    rng = np.random.default_rng(3)
    times = np.cumsum(rng.integers(1, 4, size=60)).astype(np.int32)
    types = rng.integers(0, 3, size=60).astype(np.int32)
    st = EventStream(types, times, 3)
    eps = _random_batch(rng, 11, 3, 3)
    a1 = count_a1_sequential(st, eps)
    naive = count_occurrences_naive(st, eps)
    np.testing.assert_array_equal(a1, naive)


# ---------------------------------------------------------- Theorem 5.1


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
def test_theorem_5_1_a2_upper_bounds_a1(seed):
    rng = np.random.default_rng(seed)
    st = random_stream(8, 300, 900, seed=seed)
    eps = _random_batch(rng, 50, 4, 8)
    a1 = count_a1_sequential(st, eps)
    a2 = count_a2(st, eps, use_kernel=False)
    assert (a2 >= a1).all(), (a1, a2)


# ---------------------------------------------------------- MapConcatenate


@pytest.mark.parametrize("num_segments", [2, 4, 8])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_mapconcatenate_equals_oracle(num_segments, seed):
    rng = np.random.default_rng(40 + seed)
    st = random_stream(6, 600, 3000, seed=seed)
    eps = _random_batch(rng, 13, 3, 6)
    want = count_a1_sequential(st, eps)
    got = mapconcatenate(st, eps, num_segments=num_segments)
    np.testing.assert_array_equal(got, want)


def test_mapconcatenate_embedded_chain():
    st = embedded_chain_stream(10, [2, 5, 7], (5, 10), num_occurrences=50,
                               noise_events=2000, t_max=60_000, seed=11)
    ep = EpisodeBatch.single([2, 5, 7], [5, 5], [10, 10])
    want = count_a1_sequential(st, ep)
    got = mapconcatenate(st, ep, num_segments=8)
    np.testing.assert_array_equal(got, want)
    assert got[0] >= 50  # all planted occurrences found


# ----------------------------------------------------------------- padding


def test_padding_is_neutral():
    st = random_stream(4, 100, 200, seed=5)
    padded = st.padded_to(160)
    rng = np.random.default_rng(2)
    eps = _random_batch(rng, 9, 3, 4)
    np.testing.assert_array_equal(count_a1(padded, eps, use_kernel=False),
                                  count_a1(st, eps, use_kernel=False))
    np.testing.assert_array_equal(count_a2(padded, eps, use_kernel=False),
                                  count_a2(st, eps, use_kernel=False))
