"""Wire transport: framing, exactly-once ingest, typed refusals, fuzz
safety, fault-injected exactness, and the quiesce-before-checkpoint
ordering fix.

The load-bearing claims:

* duplicated / retried / out-of-order batches never double-count — the
  per-session sequence horizon dedups replays and refuses gaps with
  typed statuses;
* malformed bytes (random mutations included) never crash a server
  thread: every failure is a typed STATUS frame or a clean close, and
  ``WireServer.unexpected`` stays empty;
* backpressure and shed decisions are observable as typed status codes
  and ``wire_*`` registry counters, not silent drops;
* checkpoints taken while the pipelined scheduler holds staged
  uncommitted preps first return them to the pending queues
  (``scheduler.quiesce``) — a restore replays each window exactly once.
"""

import json
import socket
import threading
import zlib

import numpy as np
import pytest

from repro.core import EventStream
from repro.obs import REGISTRY
from repro.service import (MiningService, MiningSession, SchedulerPolicy,
                           SessionConfig)
from repro.service.client import MiningClient
from repro.service.wire import (HEADER, MAGIC, PROTO_VERSION, Frame,
                                FrameType, Status, WireServer,
                                decode_events, delta_payload, encode_events,
                                encode_frame, parse_address, read_frame)

NUM_TYPES = 5


def tie_heavy_stream(seed, n=240):
    rng = np.random.default_rng(seed)
    gaps = rng.choice([0, 0, 1, 2], size=n)
    times = (np.cumsum(gaps) + 1).astype(np.int32)
    types = rng.integers(0, NUM_TYPES, size=n).astype(np.int32)
    return EventStream(types, times, NUM_TYPES)


def split_by_index(stream, k):
    n = stream.types.shape[0]
    cuts = [0] + [n * j // k for j in range(1, k)] + [n]
    return [EventStream(stream.types[a:b], stream.times[a:b],
                        stream.num_types)
            for a, b in zip(cuts[:-1], cuts[1:])]


def small_config(**kw):
    base = dict(intervals=((0, 4),), theta=3, max_level=3,
                history_limit=4)
    base.update(kw)
    return SessionConfig(**base)


def local_reference(cfg, wins):
    s = MiningSession("ref", cfg)
    for j, w in enumerate(wins):
        s.enqueue(w, final=(j == len(wins) - 1))
    while s.queue_depth:
        p = s.prepare()
        s.commit(p, s.execute(p))
    return [delta_payload(d) for d in s.poll()]


@pytest.fixture
def server(tmp_path):
    srv = WireServer(MiningService(), "127.0.0.1:0",
                     data_dir=tmp_path / "data")
    srv.start()
    yield srv
    srv.shutdown(drain=False)
    assert srv.unexpected == [], srv.unexpected


def raw_conn(srv):
    kind, target = parse_address(srv.address)
    sock = socket.socket(
        socket.AF_UNIX if kind == "unix" else socket.AF_INET,
        socket.SOCK_STREAM)
    sock.settimeout(30.0)
    sock.connect(target)
    return sock


def rpc(sock, frame):
    sock.sendall(encode_frame(frame))
    return read_frame(sock)


def open_session(sock, sid, cfg, req=9_000_000):
    from repro.service.wire import config_to_wire
    reply = rpc(sock, Frame(FrameType.OPEN_SESSION, req, json.dumps(
        {"session": sid, "config": config_to_wire(cfg)}).encode()))
    return reply


# --------------------------------------------------------------- framing


def test_frame_roundtrip_over_socketpair():
    a, b = socket.socketpair()
    f = Frame(FrameType.CONTROL, 123456789, b'{"op": "ping"}', flags=0)
    a.sendall(encode_frame(f))
    got = read_frame(b)
    assert (got.ftype, got.seq, got.payload) == (f.ftype, f.seq, f.payload)
    a.close(), b.close()


def test_events_roundtrip_and_validation():
    w = tie_heavy_stream(0, n=50)
    sid, stream, final = decode_events(encode_events("arr-0", w, True))
    assert sid == "arr-0" and final
    np.testing.assert_array_equal(stream.types, w.types)
    np.testing.assert_array_equal(stream.times, w.times)
    assert stream.num_types == w.num_types


@pytest.mark.parametrize("mutate,exc_code", [
    ("magic", Status.BAD_FRAME), ("version", Status.BAD_VERSION),
    ("crc", Status.BAD_CRC), ("length", Status.BAD_FRAME),
])
def test_torn_frames_raise_typed_errors(mutate, exc_code):
    from repro.service import wire
    raw = bytearray(encode_frame(Frame(FrameType.POLL, 7, b'{"a": 1}')))
    if mutate == "magic":
        raw[0] ^= 0xFF
    elif mutate == "version":
        raw[4] = 99
    elif mutate == "crc":
        raw[-1] ^= 0xFF  # flip a payload byte: CRC no longer matches
    elif mutate == "length":
        # huge declared length
        import struct
        struct.pack_into("!I", raw, 16, wire.MAX_PAYLOAD + 1)
    a, b = socket.socketpair()
    a.sendall(bytes(raw))
    a.close()
    with pytest.raises(wire.ProtocolError) as ei:
        read_frame(b)
    assert ei.value.code == exc_code
    b.close()


def test_parse_address_forms():
    assert parse_address("0.0.0.0:88") == ("tcp", ("0.0.0.0", 88))
    assert parse_address("unix:/tmp/x.sock") == ("unix", "/tmp/x.sock")
    assert parse_address(("h", 5)) == ("tcp", ("h", 5))
    with pytest.raises(ValueError):
        parse_address("nonsense")


# -------------------------------------------------- exactly-once ingest


def test_wire_serving_bit_identical_to_standalone(server):
    cfg = small_config()
    wins = split_by_index(tie_heavy_stream(3, n=200), 4)
    c = MiningClient(server.address, "t0", cfg, rng_seed=0)
    for j, w in enumerate(wins):
        c.submit(w, final=(j == len(wins) - 1))
    got = sorted(c.drain(deadline_s=120), key=lambda d: d["window_idx"])
    ref = local_reference(cfg, wins)
    assert [r["episodes"] for r in ref] == [g["episodes"] for g in got]
    c.close()


def poll_until(sock, sid, want, deadline_s=120.0, req_base=8_100_000):
    """Poll (without acking) until ``want`` deltas are cached — the
    auto-pump mines asynchronously."""
    import time
    deadline = time.monotonic() + deadline_s
    n = 0
    while time.monotonic() < deadline:
        n += 1
        reply = rpc(sock, Frame(FrameType.POLL, req_base + n, json.dumps(
            {"session": sid, "ack_through": -1}).encode()))
        deltas = json.loads(reply.payload)["deltas"]
        if len(deltas) >= want:
            return sorted(deltas, key=lambda d: d["window_idx"])
        time.sleep(0.01)
    raise AssertionError(f"timed out waiting for {want} deltas")


def test_duplicated_batch_frames_never_double_count(server):
    """The dedup acceptance: replaying an EVENT_BATCH (a retry after a
    lost ACK) yields one application and a dup ACK — and the mined counts
    equal a single-shot run. A ping between send and replay defeats the
    connection's at-most-once reply cache, forcing the replay down the
    sequence-number dedup path."""
    cfg = small_config()
    wins = split_by_index(tie_heavy_stream(5, n=160), 4)
    sock = raw_conn(server)
    open_session(sock, "dup", cfg)
    dup_acks = 0
    for j, w in enumerate(wins):
        frame = Frame(FrameType.EVENT_BATCH, j + 1,
                      encode_events("dup", w, final=(j == len(wins) - 1)))
        for replay in range(3):
            reply = rpc(sock, frame)
            assert reply.ftype == FrameType.ACK
            doc = json.loads(reply.payload)
            assert doc["applied"] == j + 1
            dup_acks += doc["duplicate"]
            rpc(sock, Frame(FrameType.CONTROL, 7_000_000 + 10 * j + replay,
                            json.dumps({"op": "ping"}).encode()))
    assert dup_acks == 2 * len(wins)  # every replay was deduped
    assert REGISTRY.counter("wire_dedup_hits_total").value >= dup_acks
    got = poll_until(sock, "dup", len(wins))
    ref = local_reference(cfg, wins)
    assert [r["episodes"] for r in ref] == [g["episodes"] for g in got]
    sock.close()


def test_sequence_gap_refused_with_out_of_order(server):
    cfg = small_config()
    sock = raw_conn(server)
    open_session(sock, "gap", cfg)
    w = tie_heavy_stream(1, n=40)
    reply = rpc(sock, Frame(FrameType.EVENT_BATCH, 5,
                            encode_events("gap", w)))
    assert reply.ftype == FrameType.STATUS
    doc = json.loads(reply.payload)
    assert doc["code"] == Status.OUT_OF_ORDER
    assert doc["expect"] == 1  # the client rewinds to this
    sock.close()


def test_poll_redelivers_until_acked(server):
    """At-least-once delivery: deltas stay cached until the client acks
    them via ``ack_through``; a reply lost to a dropped connection is
    re-delivered on the next poll."""
    cfg = small_config()
    sock = raw_conn(server)
    open_session(sock, "redeliver", cfg)
    w = tie_heavy_stream(2, n=60)
    rpc(sock, Frame(FrameType.EVENT_BATCH, 1, encode_events("redeliver", w)))
    p1 = poll_until(sock, "redeliver", 1)
    p2 = json.loads(rpc(sock, Frame(
        FrameType.POLL, 8_000_002,
        json.dumps({"session": "redeliver", "ack_through": -1}).encode()
    )).payload)["deltas"]
    assert p1 and p1 == p2  # unacked → redelivered
    p3 = json.loads(rpc(sock, Frame(
        FrameType.POLL, 8_000_003,
        json.dumps({"session": "redeliver",
                    "ack_through": p1[-1]["window_idx"]}).encode()
    )).payload)["deltas"]
    assert p3 == []  # acked → dropped from the cache
    sock.close()


# ----------------------------------------------------- typed refusals


def test_unknown_session_is_typed_status(server):
    sock = raw_conn(server)
    w = tie_heavy_stream(0, n=20)
    reply = rpc(sock, Frame(FrameType.EVENT_BATCH, 1,
                            encode_events("ghost", w)))
    assert reply.ftype == FrameType.STATUS
    assert json.loads(reply.payload)["code"] == Status.UNKNOWN_SESSION
    reply = rpc(sock, Frame(FrameType.POLL, 8_000_000,
                            json.dumps({"session": "ghost"}).encode()))
    assert json.loads(reply.payload)["code"] == Status.UNKNOWN_SESSION
    sock.close()


def test_admission_rejection_is_typed_status(tmp_path):
    svc = MiningService(policy=SchedulerPolicy(max_sessions=1))
    srv = WireServer(svc, "127.0.0.1:0", data_dir=tmp_path / "d")
    srv.start()
    try:
        sock = raw_conn(srv)
        r1 = open_session(sock, "a", small_config())
        assert r1.ftype == FrameType.SESSION_OK
        r2 = open_session(sock, "b", small_config(), req=9_000_001)
        assert r2.ftype == FrameType.STATUS
        assert json.loads(r2.payload)["code"] == Status.ADMISSION_REJECTED
        # same session, different config: also a typed refusal
        r3 = open_session(sock, "a", small_config(theta=4), req=9_000_002)
        assert json.loads(r3.payload)["code"] == Status.CONFIG_CONFLICT
        sock.close()
    finally:
        srv.shutdown(drain=False)
    assert srv.unexpected == []


def test_backpressure_surfaces_as_typed_status(tmp_path):
    svc = MiningService(policy=SchedulerPolicy(max_pending_windows=1))
    srv = WireServer(svc, "127.0.0.1:0", data_dir=tmp_path / "d",
                     auto_pump=False)
    srv.start()
    before = REGISTRY.counter("wire_backpressure_total").value
    try:
        sock = raw_conn(srv)
        open_session(sock, "bp", small_config())
        wins = split_by_index(tie_heavy_stream(7, n=80), 3)
        r1 = rpc(sock, Frame(FrameType.EVENT_BATCH, 1,
                             encode_events("bp", wins[0])))
        assert r1.ftype == FrameType.ACK
        r2 = rpc(sock, Frame(FrameType.EVENT_BATCH, 2,
                             encode_events("bp", wins[1])))
        assert r2.ftype == FrameType.STATUS
        doc = json.loads(r2.payload)
        assert doc["code"] == Status.BACKPRESSURE
        assert doc["queue_depth"] >= 1
        assert REGISTRY.counter("wire_backpressure_total").value > before
        # the refusal did not consume the seq: drain, retry, accepted
        svc.pump()
        r3 = rpc(sock, Frame(FrameType.EVENT_BATCH, 2,
                             encode_events("bp", wins[1])))
        assert r3.ftype == FrameType.ACK
        # ...and the counters surface in stats()
        stats = svc.stats()
        assert stats["wire"]["backpressure"] >= 1
        assert "recovery" in stats and "daemon" in stats
        sock.close()
    finally:
        srv.shutdown(drain=False)
    assert srv.unexpected == []


# ----------------------------------------------------------------- fuzz


def test_fuzz_random_mutations_never_crash_server(server):
    """Satellite acceptance: mutated frames and raw garbage produce typed
    STATUS frames or clean closes — never an unhandled exception in a
    server thread (``server.unexpected`` must stay empty)."""
    rng = np.random.default_rng(0xFE31)
    cfg = small_config()
    w = tie_heavy_stream(0, n=30)
    valid = [
        encode_frame(Frame(FrameType.OPEN_SESSION, 1, json.dumps(
            {"session": "fz", "config": {}}).encode())),
        encode_frame(Frame(FrameType.EVENT_BATCH, 1,
                           encode_events("fz", w))),
        encode_frame(Frame(FrameType.POLL, 2,
                           json.dumps({"session": "fz"}).encode())),
        encode_frame(Frame(FrameType.CONTROL, 3,
                           json.dumps({"op": "ping"}).encode())),
        encode_frame(Frame(FrameType.STATS, 4, b"")),
        # bogus frame type, valid framing
        encode_frame(Frame(99, 5, b"xx")),
    ]
    for trial in range(50):
        base = bytearray(valid[int(rng.integers(len(valid)))])
        nmut = int(rng.integers(1, 9))
        for _ in range(nmut):
            base[int(rng.integers(len(base)))] = int(rng.integers(256))
        if trial % 7 == 0:  # raw garbage, not even a frame
            base = bytearray(rng.integers(0, 256,
                                          int(rng.integers(1, 128)),
                                          dtype=np.uint8).tobytes())
        sock = raw_conn(server)
        try:
            sock.sendall(bytes(base))
            # a mutated length field can leave the server legitimately
            # waiting for bytes that never come — short timeout, then the
            # close delivers it a clean EOF
            sock.settimeout(1.0)
            try:
                sock.recv(1 << 16)  # STATUS reply or clean EOF — both fine
            except (TimeoutError, OSError):
                pass
        finally:
            sock.close()
    assert server.unexpected == [], server.unexpected
    # the server still serves correct traffic after the abuse
    c = MiningClient(server.address, "after-fuzz", cfg, rng_seed=1)
    wins = split_by_index(tie_heavy_stream(9, n=120), 3)
    for j, win in enumerate(wins):
        c.submit(win, final=(j == len(wins) - 1))
    got = sorted(c.drain(deadline_s=120), key=lambda d: d["window_idx"])
    ref = local_reference(cfg, wins)
    assert [r["episodes"] for r in ref] == [g["episodes"] for g in got]
    c.close()


def test_payload_garbage_keeps_connection_alive(server):
    """A syntactically valid frame with a garbage JSON payload is a
    payload-level error: typed STATUS, connection stays usable."""
    sock = raw_conn(server)
    reply = rpc(sock, Frame(FrameType.POLL, 11, b"\xff\xfenot json"))
    assert reply.ftype == FrameType.STATUS
    assert json.loads(reply.payload)["code"] == Status.BAD_FRAME
    # same connection still works
    reply = rpc(sock, Frame(FrameType.CONTROL, 12,
                            json.dumps({"op": "ping"}).encode()))
    assert reply.ftype == FrameType.CONTROL_OK
    sock.close()


# ----------------------------------- fault-injected client exactness


def test_faulty_link_still_bit_identical(server):
    """Deterministic drop/duplicate/truncate on the client's send path:
    retries, reconnects, and server-side dedup must keep the counts
    bit-identical to a clean run."""
    from repro.launch.wire_load import FaultyClient
    from repro.runtime.faultinject import FaultSpec

    cfg = small_config()
    wins = split_by_index(tie_heavy_stream(13, n=200), 5)
    c = FaultyClient(server.address, "faulty", cfg,
                     fault_spec=FaultSpec(seed=3, drop=0.15,
                                          duplicate=0.15, truncate=0.10),
                     rng_seed=4, deadline_s=120.0)
    for j, w in enumerate(wins):
        c.submit(w, final=(j == len(wins) - 1))
    got = sorted(c.drain(deadline_s=120), key=lambda d: d["window_idx"])
    assert c.injector.total_injected > 0  # the link really was nasty
    ref = local_reference(cfg, wins)
    assert [r["episodes"] for r in ref] == [g["episodes"] for g in got]
    c.close()


def test_fault_injector_is_deterministic():
    from repro.runtime.faultinject import FaultInjector, FaultSpec

    spec = FaultSpec(seed=42, drop=0.2, duplicate=0.2, truncate=0.1)
    frames = [bytes([i]) * (10 + i) for i in range(40)]
    a, b = FaultInjector(spec), FaultInjector(spec)
    plan_a = [a.plan(f) for f in frames]
    plan_b = [b.plan(f) for f in frames]
    assert plan_a == plan_b
    assert a.injected == b.injected
    assert a.total_injected > 0


# ------------------------------ quiesce-before-checkpoint (satellite)


def test_checkpoint_quiesces_staged_preps(tmp_path):
    """Regression for the graceful-shutdown ordering bug: with
    ``pipeline_depth=2`` the scheduler holds prepared-but-uncommitted
    windows that live in neither the pending queue nor the miner state.
    A checkpoint taken without quiescing silently drops them; the fix
    returns them to the queue first, so a cold restore mines every
    window exactly once."""
    svc = MiningService(policy=SchedulerPolicy(pipeline_depth=2))
    cfgs, feeds = {}, {}
    for i, seed in enumerate((0, 5)):
        cfg = small_config()
        sid = svc.create_session(f"q{i}", cfg)
        wins = split_by_index(tie_heavy_stream(seed, n=200), 4)
        cfgs[sid], feeds[sid] = cfg, wins
        for j, w in enumerate(wins):
            svc.ingest(sid, w, final=(j == len(wins) - 1))
    svc.scheduler.step()  # leaves next step's preps staged
    assert svc.scheduler._staged, "pipelined step should stage preps"
    staged_windows = {sid: prep.window_idx
                      for sid, prep in svc.scheduler._staged.items()}
    before = REGISTRY.counter("scheduler_quiesced_preps_total").value
    svc.checkpoint_all(tmp_path)  # must quiesce first
    assert REGISTRY.counter(
        "scheduler_quiesced_preps_total").value - before == len(
        staged_windows)
    assert not svc.scheduler._staged

    # cold restore into a fresh service: every window exactly once
    svc2 = MiningService(policy=SchedulerPolicy(pipeline_depth=2))
    for sid, cfg in cfgs.items():
        svc2.create_session(sid, cfg)
        svc2.session(sid).restore(tmp_path)
    svc2.pump()
    for sid, wins in feeds.items():
        got = [delta_payload(d) for d in svc2.poll(sid)]
        ref = local_reference(cfgs[sid], wins)
        assert len(got) == len(ref), \
            f"{sid}: staged window lost or duplicated across checkpoint"
        assert [r["episodes"] for r in ref] == [g["episodes"] for g in got]


# -------------------------------------------------- concurrent clients


def test_concurrent_sessions_over_one_server(server):
    cfgs = [small_config(), small_config(theta=2)]
    feeds = [split_by_index(tie_heavy_stream(s, n=150), 3)
             for s in (1, 8)]
    results = [None, None]

    def drive(i):
        c = MiningClient(server.address, f"conc-{i}", cfgs[i],
                         rng_seed=i)
        for j, w in enumerate(feeds[i]):
            c.submit(w, final=(j == len(feeds[i]) - 1))
        results[i] = sorted(c.drain(deadline_s=120),
                            key=lambda d: d["window_idx"])
        c.close()

    threads = [threading.Thread(target=drive, args=(i,)) for i in (0, 1)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=180)
    for i in (0, 1):
        ref = local_reference(cfgs[i], feeds[i])
        assert results[i] is not None, f"client {i} hung"
        assert ([r["episodes"] for r in ref]
                == [g["episodes"] for g in results[i]])


def test_crc_is_actually_checked():
    # direct: flipping one payload bit after encode breaks the CRC
    raw = bytearray(encode_frame(Frame(FrameType.STATS, 1, b"hello")))
    assert zlib.crc32(b"hello") == HEADER.unpack(raw[:HEADER.size])[6]
    assert HEADER.unpack(raw[:HEADER.size])[0] == MAGIC
    assert HEADER.unpack(raw[:HEADER.size])[1] == PROTO_VERSION
