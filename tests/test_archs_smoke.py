"""Per-architecture smoke tests: reduced same-family configs, one
forward/train step + prefill/decode on CPU; output shapes + no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke_config
from repro.models import (DecodeState, decode_step, init_params, loss_fn,
                          make_decode_caches, prefill)
from repro.optim import adamw_init, adamw_update

B, S = 2, 32


def _batch(cfg, key):
    kt, ke = jax.random.split(key)
    batch = {"labels": jax.random.randint(kt, (B, S), 0, cfg.vocab_size)}
    if cfg.stub_frontend:
        batch["embeddings"] = jax.random.normal(
            ke, (B, S, cfg.d_model), jnp.float32) * 0.02
    else:
        batch["tokens"] = jax.random.randint(ke, (B, S), 0, cfg.vocab_size)
    return batch


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch, rng):
    cfg = get_smoke_config(arch)
    params = init_params(rng, cfg)
    batch = _batch(cfg, rng)

    @jax.jit
    def step(params, opt, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch), has_aux=True)(params)
        new_p, new_opt, gnorm = adamw_update(params, grads, opt, lr=1e-3)
        return new_p, new_opt, loss, gnorm

    opt = adamw_init(params)
    new_p, new_opt, loss, gnorm = step(params, opt, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    assert np.isfinite(float(gnorm)), f"{arch}: grad norm not finite"
    assert float(loss) > 0
    # a second step must change the loss (training is actually happening)
    _, _, loss2, _ = step(new_p, new_opt, batch)
    assert float(loss2) != float(loss)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_smoke(arch, rng):
    cfg = get_smoke_config(arch)
    params = init_params(rng, cfg)
    batch = _batch(cfg, rng)
    logits, caches = jax.jit(lambda p, b: prefill(p, cfg, b))(params, batch)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: prefill NaN"

    # decode from a fresh cache (serve_step shape), a few tokens
    max_seq = S + 8
    state = DecodeState(caches=make_decode_caches(cfg, B, max_seq),
                        pos=jnp.asarray(0, jnp.int32))
    step = jax.jit(lambda p, t, s: decode_step(p, cfg, t, s))
    tok = jnp.zeros((B, 1), jnp.int32)
    for _ in range(3):
        logits, state = step(params, tok, state)
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all()), f"{arch}: decode NaN"
        tok = logits.argmax(-1).astype(jnp.int32)
    assert int(state.pos) == 3


def test_config_param_counts_match_published_scale():
    """Full configs must land near their published parameter counts."""
    from repro.configs import get_config
    expect = {  # name → (total params ±20%, where published)
        "llama3_405b": 405e9,
        "yi_34b": 34e9,
        "qwen1_5_32b": 32e9,
        "falcon_mamba_7b": 7e9,
        "llava_next_mistral_7b": 7e9,
        "dbrx_132b": 132e9,
        "jamba_1_5_large_398b": 398e9,
    }
    for name, want in expect.items():
        got = get_config(name).num_params()
        assert 0.75 * want < got < 1.30 * want, \
            f"{name}: {got/1e9:.1f}B vs published {want/1e9:.0f}B"
