"""Segmented-kernel (in-kernel MapConcatenate) equivalence suite.

Interpret-mode acceptance matrix for the two-axis grid: segmented-kernel
counts must be bit-identical to single-scan counting for every engine ×
two-pass × segment count, including adversarial mid-tie splits and
occurrences straddling a segment boundary at exactly τ+W (the PR 1
stitch-zone cases), with the ``unmatched``-flag fallback preserved; the
chunked event ``BlockSpec`` shared by the PTPE kernels must be a no-op on
counts; and ``KERNEL_CALLS`` must prove the new kernels actually execute.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (EpisodeBatch, EventStream, StreamingCounter,
                        StreamingMiner, count_a1, count_a1_sequential,
                        count_a2, count_dispatch, count_two_pass,
                        fold_pair, fold_pair_unrolled, make_segments,
                        mapconcatenate_kernel, mine)
from repro.core.mapconcat import _map_all_segments
from repro.kernels import ops

NUM_TYPES = 5


@pytest.fixture(autouse=True)
def _interpret_kernels(monkeypatch):
    """Force the kernel dispatch policy on (interpret mode) and zero the
    dispatch tally, so each test can assert the Pallas path executed.
    The hybrid's availability probe is cached per process, so flipping
    the environment must also drop the cache — both ways, or a suite
    running earlier (or later) in the same process sees a stale answer."""
    from repro.core import hybrid
    monkeypatch.setenv("REPRO_KERNEL_INTERPRET", "1")
    hybrid._reset_probe_cache()
    ops.reset_kernel_calls()
    yield
    hybrid._reset_probe_cache()


def tie_heavy_stream(seed, n=160):
    rng = np.random.default_rng(seed)
    gaps = rng.choice([0, 0, 1, 2], size=n)
    times = (np.cumsum(gaps) + 1).astype(np.int32)
    types = rng.integers(0, NUM_TYPES, size=n).astype(np.int32)
    return EventStream(types, times, NUM_TYPES)


def batch():
    """Repeated types, zero lower bounds (tie-sensitive), heterogeneous
    spans — the PR 1 stitch-zone batch."""
    return EpisodeBatch(
        np.int32([[0, 1, 2], [1, 2, 3], [2, 2, 0], [4, 0, 1]]),
        np.int32([[1, 0], [0, 2], [0, 0], [0, 0]]),
        np.int32([[5, 6], [4, 7], [3, 3], [6, 2]]))


def split_by_index(stream, k):
    n = stream.types.shape[0]
    cuts = [0] + [n * j // k for j in range(1, k)] + [n]
    return [EventStream(stream.types[a:b], stream.times[a:b],
                        stream.num_types)
            for a, b in zip(cuts[:-1], cuts[1:])]


# ------------------------------------------------------------ fold stitch


def test_fold_pair_unrolled_matches_fold_pair():
    """The kernel-safe unrolled stitch is bit-identical to the gather-based
    ``fold_pair`` — including unmatched tuples (flag set, k'=0 fallthrough
    count)."""
    for seed in range(8):
        rng = np.random.default_rng(seed)

        def tup():
            vals = [jnp.asarray(rng.integers(0, 6, size=(3, 7)), jnp.int32)
                    for _ in range(3)]
            return tuple(vals) + (jnp.asarray(rng.random((3, 7)) < 0.2),)

        left, right = tup(), tup()
        want = fold_pair(left, right)
        got = fold_pair_unrolled(left, right, 3)
        for w, g in zip(want, got):
            np.testing.assert_array_equal(np.asarray(w), np.asarray(g))
    # fully unmatchable pair: every flag must come back set
    big = tuple(jnp.full((2, 3), v, jnp.int32) for v in (0, 1, 50)) \
        + (jnp.zeros((2, 3), bool),)
    small = tuple(jnp.full((2, 3), v, jnp.int32) for v in (9, 1, 9)) \
        + (jnp.zeros((2, 3), bool),)
    assert np.asarray(fold_pair_unrolled(big, small, 2)[3]).all()


# --------------------------------------------- acceptance matrix (exact)


@pytest.mark.parametrize("num_segments", [1, 2, 4, 8])
def test_mapc_kernel_counts_equal_single_scan(num_segments):
    """Acceptance: in-kernel MapConcatenate == single-scan counting at
    every segment count, on tie-heavy streams whose index splits land
    mid-tie."""
    eps = batch()
    for seed in (0, 2, 5):
        st = tie_heavy_stream(seed, n=200)
        oracle = count_a1_sequential(st, eps)
        ops.reset_kernel_calls()
        got = mapconcatenate_kernel(st, eps, num_segments=num_segments)
        np.testing.assert_array_equal(got, oracle)
        assert ops.KERNEL_CALLS["a1_mapc"] >= 1


@pytest.mark.parametrize("engine", ["ptpe", "mapconcatenate",
                                    "mapconcat_kernel", "hybrid"])
@pytest.mark.parametrize("two_pass", [True, False])
@pytest.mark.parametrize("num_segments", [2, 8])
def test_engine_twopass_segments_matrix(engine, two_pass, num_segments):
    """Every engine × two-pass × segment count lands on the same counts
    and survivor sets as the kernel-free reference."""
    eps = batch()
    st = tie_heavy_stream(3, n=220)
    ref = count_two_pass(st, eps, theta=2, use_kernel=False)
    if two_pass:
        got = count_two_pass(st, eps, theta=2, engine=engine,
                             num_segments=num_segments)
        np.testing.assert_array_equal(got.counts, ref.counts)
        np.testing.assert_array_equal(got.survived, ref.survived)
        np.testing.assert_array_equal(got.frequent, ref.frequent)
    else:
        got = count_dispatch(st, eps, engine=engine,
                             num_segments=num_segments)
        np.testing.assert_array_equal(got,
                                      count_a1(st, eps, use_kernel=False))
    if engine == "mapconcat_kernel":
        assert ops.KERNEL_CALLS["a1_mapc"] >= 1
        if two_pass:
            assert ops.KERNEL_CALLS["a2_mapc"] >= 1


@pytest.mark.parametrize("num_segments", [2, 4, 8])
def test_a2_mapc_kernel_equals_exact_a2(num_segments):
    """Segmented pass-1: the A2 kernel count (after the unmatched
    fallback) is *the* A2 count — Theorem 5.1's cull stays sound."""
    eps = batch()
    for seed in (1, 4):
        st = tie_heavy_stream(seed, n=200)
        want = count_a2(st, eps, use_kernel=False)
        ops.reset_kernel_calls()
        got = count_a2(st, eps, segments=num_segments)
        np.testing.assert_array_equal(got, want)
        assert ops.KERNEL_CALLS["a2_mapc"] >= 1


def test_mapc_kernel_tuples_bit_identical_to_xla_fold():
    """Drift guard: the kernel's fused Concatenate state equals the XLA
    Map step's per-segment tuples folded left-to-right with ``fold_pair``
    — same zones (``stitch_zones``), same starts (``phase_cum``), same
    stitch."""
    eps = batch()
    st = tie_heavy_stream(7, n=300)
    w = np.asarray(eps.max_span)
    tau, wt, wtt = make_segments(st, 8, int(w.max()))
    a, c, b, ovf = _map_all_segments(
        jnp.asarray(wt), jnp.asarray(wtt), jnp.asarray(eps.etypes),
        jnp.asarray(eps.tlo), jnp.asarray(eps.thi), jnp.asarray(tau),
        jnp.asarray(w, jnp.int32), 4)
    carry = (a[0], c[0], b[0], jnp.zeros(a[0].shape, bool))
    for i in range(1, a.shape[0]):
        carry = fold_pair(carry, (a[i], c[i], b[i],
                                  jnp.zeros(a[i].shape, bool)))
    ka, kc, kb, kf, kovf = ops.a1_mapconcat_tuples(
        *ops.mapconcat_layout(eps, inclusive_lower=False),
        ops.segment_bricks(wt, wtt, tau),
        n_levels=eps.N, lcap=4, interpret=True)
    k, m = eps.N, eps.M
    for kern, ref in zip((ka, kc, kb), carry[:3]):
        np.testing.assert_array_equal(np.asarray(kern)[:k, :m],
                                      np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(kf)[:k, :m] != 0,
                                  np.asarray(carry[3]))
    np.testing.assert_array_equal(np.asarray(kovf)[0, :m] != 0,
                                  np.asarray(ovf.any(axis=(0, 1))))


# ------------------------------------------------- adversarial boundaries


def test_boundary_straddler_at_exactly_tau_plus_w():
    """An occurrence whose first event sits exactly on a segment boundary
    and whose completion lands exactly at τ+W (the PR 1 inclusive-zone
    case), plus a tie group square on the boundary — the kernel stitch
    must see both sides."""
    eps = EpisodeBatch(np.int32([[0, 1]]), np.int32([[0]]),
                       np.int32([[10]]))
    # times 1..99 → num_segments=2 boundary at τ=50 (asserted below)
    times = [1, 5, 20, 33, 47, 50, 50, 50, 60, 61, 75, 88, 99]
    types = [2, 0, 1, 2, 3, 0, 2, 2, 1, 0, 1, 2, 3]
    st = EventStream(np.int32(types), np.int32(times), NUM_TYPES)
    tau, _, _ = make_segments(st, 2, 10)
    assert int(tau[1]) == 50, "fixture drifted off the τ=50 boundary"
    oracle = count_a1_sequential(st, eps)
    for p in (2, 4):
        got = mapconcatenate_kernel(st, eps, num_segments=p)
        np.testing.assert_array_equal(got, oracle)
    a2got = count_a2(st, eps, segments=2)
    np.testing.assert_array_equal(a2got, count_a2(st, eps,
                                                  use_kernel=False))


def test_mid_tie_streaming_splits_mapc_kernel():
    """Streaming windows that cut inside tie groups, counted on the
    segmented-kernel residency — bit-identical to one-shot counting."""
    eps = batch()
    for seed in (0, 4):
        st = tie_heavy_stream(seed, n=240)
        oracle = count_a1_sequential(st, eps)
        for k in (2, 3, 8):
            ops.reset_kernel_calls()
            ctr = StreamingCounter(eps, engine="mapconcatenate",
                                   use_kernel=True)
            assert ctr._mapc_kernel, \
                "segmented-kernel residency must engage under interpret"
            for w in split_by_index(st, k):
                ctr.update(w)
            np.testing.assert_array_equal(ctr.finalize(), oracle)
            # multi-device hosts shard commits whose span covers the mesh
            assert (ops.KERNEL_CALLS["a1_mapc"]
                    + ops.KERNEL_CALLS["a1_mapc_shard"]) >= 1


# -------------------------------------------------- unmatched-flag fallback


def test_unmatched_flag_fallback_restores_exactness():
    """lcap=1 forces live evictions through the segmented kernel's
    per-phase lists; flagged episodes must come back via the exact
    recount."""
    eps = batch()
    st = tie_heavy_stream(1, n=220)
    oracle = count_a1_sequential(st, eps)
    counts, bad = ops.a1_mapconcat_count(st, eps, num_segments=4, lcap=1,
                                         force="interpret")
    assert bad.any(), "fixture no longer forces a flagged episode"
    got = mapconcatenate_kernel(st, eps, num_segments=4, lcap=1)
    np.testing.assert_array_equal(got, oracle)


def test_unmatched_flag_propagates_through_kernel_fold():
    """A doctored left-segment τ_{p+1} row makes its ``b`` default
    disagree with the right segment's ``a`` values, so the in-kernel fold
    must raise the unmatched flag rather than stitch silently."""
    eps = batch()
    st = tie_heavy_stream(2, n=200)
    w_max = int(np.asarray(eps.max_span).max())
    tau, wt, wtt = make_segments(st, 2, w_max)
    segs = ops.segment_bricks(wt, wtt, tau)
    # segment 0 now claims a boundary no machine can complete at, while
    # segment 1 still records its tuple against the real boundary
    segs = segs.at[0, 4, :].set(int(tau[-1]) + 10 * w_max)
    _, _, _, f, _ = ops.a1_mapconcat_tuples(
        *ops.mapconcat_layout(eps, inclusive_lower=False), segs,
        n_levels=eps.N, lcap=4, interpret=True)
    assert (np.asarray(f)[0, : eps.M] != 0).any()


# ------------------------------------------- chunked event streaming (PTPE)


def test_chunked_event_blockspec_is_count_invariant():
    """The event-axis grid chunking (fresh and state-carried wrappers
    share it) cannot change counts: tiny chunks == one chunk == XLA
    scan."""
    from repro.core.count_a1 import count_a1_vectorized
    eps = batch()
    st = tie_heavy_stream(6, n=300)
    et, tlo, thi = ops.episode_layout(eps, inclusive_lower=False)
    ev = ops.event_layout(st, with_dup=True)
    whole = ops.a1_count_kernel(et, tlo, thi, ev, n_levels=eps.N, lcap=4,
                                block_e=0, interpret=True)
    chunked = ops.a1_count_kernel(et, tlo, thi, ev, n_levels=eps.N, lcap=4,
                                  block_e=128, interpret=True)
    for wv, cv in zip(whole, chunked):
        np.testing.assert_array_equal(np.asarray(wv), np.asarray(cv))
    sc, so = count_a1_vectorized(st, eps, lcap=4)
    np.testing.assert_array_equal(
        np.asarray(chunked[0])[0, : eps.M].astype(np.int64), sc)
    np.testing.assert_array_equal(np.asarray(chunked[1])[0, : eps.M] != 0,
                                  so)


def test_long_stream_event_brick_chunks_and_counts():
    """Streams past DEFAULT_BLOCK_E pad to a chunk multiple and walk the
    multi-step event grid — counts (and the dispatch tally) unchanged."""
    rng = np.random.default_rng(11)
    n = 3000
    times = (np.cumsum(rng.choice([0, 1, 1, 2], size=n)) + 1).astype(np.int32)
    types = rng.integers(0, NUM_TYPES, size=n).astype(np.int32)
    st = EventStream(types, times, NUM_TYPES)
    eps = batch()
    ev = ops.event_layout(st, with_dup=True)
    assert ev.shape[1] % ops.DEFAULT_BLOCK_E == 0
    assert ev.shape[1] // ops.DEFAULT_BLOCK_E >= 2
    ops.reset_kernel_calls()
    kc, kovf = ops.a1_count(st, eps, lcap=4, force="interpret")
    assert ops.KERNEL_CALLS["a1"] == 1
    oracle = count_a1_sequential(st, eps)
    exact = ~kovf
    np.testing.assert_array_equal(kc[exact], oracle[exact])


def test_hybrid_auto_selects_mapc_kernel_on_long_streams():
    """Eq. 2 dispatcher upgrade: a sub-lane-tile batch on a long stream
    (the paper's low-M regime, Fig. 7) auto-selects the segmented kernel;
    a short stream keeps the classic dispatch (no kernel launch)."""
    from repro.core.hybrid import MAPC_KERNEL_MIN_EVENTS
    rng = np.random.default_rng(13)
    n = MAPC_KERNEL_MIN_EVENTS + 100
    times = np.cumsum(rng.choice([1, 1, 2], size=n)).astype(np.int32)
    types = rng.integers(0, NUM_TYPES, size=n).astype(np.int32)
    st = EventStream(types, times, NUM_TYPES)
    eps = batch()
    ops.reset_kernel_calls()
    got = count_dispatch(st, eps, engine="hybrid")
    # multi-device hosts upgrade the same decision to the sharded launch
    assert (ops.KERNEL_CALLS["a1_mapc"]
            + ops.KERNEL_CALLS["a1_mapc_shard"]) >= 1
    np.testing.assert_array_equal(got, count_a1(st, eps, use_kernel=False))
    ops.reset_kernel_calls()
    short = EventStream(types[:200], times[:200], NUM_TYPES)
    count_dispatch(short, eps, engine="hybrid")
    assert ops.KERNEL_CALLS["a1_mapc"] == 0
    assert ops.KERNEL_CALLS["a1_mapc_shard"] == 0


# --------------------------------------------------- miner / service level


@pytest.mark.parametrize("two_pass", [True, False])
def test_streaming_miner_mapc_kernel_equals_one_shot(two_pass):
    """Cumulative mining on the segmented-kernel engine ends bit-identical
    to one-shot ``mine`` on the concatenation."""
    from repro.data import embedded_chain_stream
    st = embedded_chain_stream(NUM_TYPES, [1, 2, 3], (2, 6),
                               num_occurrences=25, noise_events=200,
                               t_max=15_000, seed=11)
    one = mine(st, intervals=[(2, 6)], theta=10, max_level=3,
               engine="mapconcatenate", two_pass=two_pass)
    ops.reset_kernel_calls()
    miner = StreamingMiner([(2, 6)], 10, max_level=3, mode="cumulative",
                           engine="mapconcat_kernel", two_pass=two_pass)
    wins = split_by_index(st, 3)
    for i, w in enumerate(wins):
        res = miner.update(w, final=i == len(wins) - 1)
    assert len(res.frequent) == len(one.frequent)
    for fa, fb, ca, cb in zip(res.frequent, one.frequent,
                              res.counts, one.counts):
        np.testing.assert_array_equal(fa.etypes, fb.etypes)
        np.testing.assert_array_equal(ca, cb)
    assert (ops.KERNEL_CALLS["a1_mapc"]
            + ops.KERNEL_CALLS["a1_mapc_shard"]) >= 1


def test_batcher_fuses_segmented_kernel_launches():
    """The cross-session batcher's ``mapc_kernel_scan`` seam fuses
    same-shape segmented launches into one vmapped pallas_call —
    per-session results identical to standalone."""
    from repro.service import MiningService, SessionConfig
    svc = MiningService()
    tenants = []
    for i in range(3):
        cfg = SessionConfig(intervals=((0, 4),), theta=3, max_level=3,
                            engine="mapconcatenate", history_limit=4)
        sid = svc.create_session(f"t{i}", cfg)
        wins = split_by_index(tie_heavy_stream(i, n=220), 3)
        tenants.append((sid, cfg, wins))
        for j, w in enumerate(wins):
            svc.ingest(sid, w, final=j == len(wins) - 1)
    ops.reset_kernel_calls()
    svc.pump()
    assert (ops.KERNEL_CALLS["a1_mapc"]
            + ops.KERNEL_CALLS["a1_mapc_shard"]) >= 1
    assert svc.batcher.batches > 0
    for sid, cfg, wins in tenants:
        deltas = svc.poll(sid)
        standalone = cfg.make_miner()
        for j, (d, w) in enumerate(zip(deltas, wins)):
            ref = standalone.update(w, final=j == len(wins) - 1)
            assert len(d.result.frequent) == len(ref.frequent)
            for ca, cb in zip(d.result.counts, ref.counts):
                np.testing.assert_array_equal(ca, cb)
