"""Telemetry bridge: routing decisions → event streams the miner accepts,
plus the serving meters (labels, latency percentiles, per-session bank)."""

import numpy as np
import pytest

from repro.core import count_a1_sequential, mine
from repro.telemetry import (MeterBank, ThroughputMeter,
                             decode_expert_episode, routing_events)


def test_routing_events_roundtrip():
    nl, t, k, e = 2, 16, 2, 8
    rng = np.random.default_rng(0)
    topk = rng.integers(0, e, size=(nl, t, k)).astype(np.int32)
    stream = routing_events(topk, e)
    assert len(stream) == nl * t * k
    assert stream.num_types == nl * e
    # decode a type back
    layer, expert = decode_expert_episode(int(stream.types[0]), e)
    assert 0 <= layer < nl and 0 <= expert < e


def test_planted_routing_cascade_is_mined():
    """A deterministic cascade (expert 1 at layer 0 → expert 5 at layer 1,
    next token) must dominate the mined 2-episodes."""
    nl, t, k, e = 2, 200, 1, 8
    rng = np.random.default_rng(1)
    topk = rng.integers(0, e, size=(nl, t, k)).astype(np.int32)
    topk[0, ::4, 0] = 1   # layer0 expert1 at tokens 0,4,8...
    topk[1, 1::4, 0] = 5  # layer1 expert5 one token later
    stream = routing_events(topk, e)
    res = mine(stream, intervals=[(0, 2)], theta=int(t / 4 * 0.8),
               max_level=2)
    found = {tuple(ep) for ep in res.frequent[1].etypes.tolist()}
    want = (0 * e + 1, 1 * e + 5)  # L0e1 -> L1e5
    assert want in found
    # count is exact vs the oracle
    idx = [tuple(ep) for ep in res.frequent[1].etypes.tolist()].index(want)
    lv = res.frequent[1].select([idx])
    assert res.counts[1][idx] == count_a1_sequential(stream, lv)[0]


def _fill(meter, durations, n=100):
    """Deterministic rows (bypass the wall clock)."""
    for dt in durations:
        meter.rows.append((n, float(dt)))


def test_meter_label_and_percentiles():
    m = ThroughputMeter(label="array-7")
    _fill(m, [0.010] * 98 + [0.050, 0.500])
    s = m.summary()
    assert s["label"] == "array-7"
    assert s["p50_latency_s"] == 0.010
    # p99 of 100 rows sits between the 0.050 straggler and the 0.500 tail
    assert 0.050 <= s["p99_latency_s"] <= 0.500
    pcts = m.latency_percentiles(qs=(50, 90, 99))
    assert set(pcts) == {"p50", "p90", "p99"}
    assert pcts["p50"] <= pcts["p90"] <= pcts["p99"]


def test_meter_percentiles_empty():
    m = ThroughputMeter()
    assert m.latency_percentiles() == {"p50": 0.0, "p99": 0.0}
    s = m.summary()
    assert s["events_per_sec"] == 0.0 and "label" not in s


def test_meter_mark_truncate_abort_rewind():
    """The scheduler's retry path rewinds a meter through the public
    ``mark()``/``truncate()``/``abort()`` API (it used to reach into
    ``_t0`` directly): truncate discards rows *and* wall-clock spans
    recorded after the mark, abort drops an open start without a row,
    and the meter keeps working afterwards."""
    m = ThroughputMeter(label="rewind")
    m.start()
    m.stop(10)
    mark = m.mark()
    assert mark == 1
    # a speculative (to-be-retried) step records two windows...
    m.start()
    m.stop(20)
    m.start()
    m.stop(30)
    assert m.events == 60 and len(m.spans) == 3
    # ...then fails: rewind un-counts exactly the speculative rows
    m.truncate(mark)
    assert len(m.rows) == 1 and len(m.spans) == 1
    assert m.events == 10
    # abort drops an in-flight start (no row), is safe when idle, and
    # stop() after abort still refuses to run without a fresh start
    m.start()
    m.abort()
    m.abort()
    with pytest.raises(RuntimeError, match="stop\\(\\) without start"):
        m.stop(99)
    # the meter is whole after the rewind: the retried step re-measures
    m.start()
    m.stop(20)
    assert m.events == 30 and len(m.rows) == len(m.spans) == 2
    # truncate tolerates hand-filled rows with no matching spans
    bare = ThroughputMeter()
    _fill(bare, [0.1, 0.2, 0.3])
    bare.truncate(1)
    assert len(bare.rows) == 1 and bare.spans == []


def test_meter_bank_per_session_and_aggregate():
    bank = MeterBank()
    _fill(bank.meter("a"), [0.1, 0.1], n=100)
    _fill(bank.meter("b"), [0.1], n=300)
    assert bank.meter("a") is bank.meter("a")  # stable per label
    s = bank.summary()
    assert set(s["sessions"]) == {"a", "b"}
    assert s["sessions"]["a"]["label"] == "a"
    assert s["sessions"]["a"]["events"] == 200
    assert s["sessions"]["b"]["events_per_sec"] == 3000.0
    agg = s["aggregate"]
    assert agg["label"] == "aggregate"
    assert agg["events"] == 500 and agg["windows"] == 3
    assert np.isclose(agg["events_per_sec"], 500 / 0.3)


def test_meter_bank_aggregate_uses_wall_clock_for_concurrent_sessions():
    """Concurrent sessions overlap in time: the fleet rate must divide by
    the wall-clock union span, not the sum of per-session busy seconds
    (which under-reports by ~the session count)."""
    bank = MeterBank()
    for label in ("a", "b", "c", "d"):
        m = bank.meter(label)
        m.rows.append((1000, 1.0))
        m.spans.append((10.0, 11.0))  # all four ran during the same second
    agg = bank.summary()["aggregate"]
    assert agg["wall_seconds"] == 1.0
    assert agg["events_per_sec"] == 4000.0  # not 4000/4 from summed busy-s
