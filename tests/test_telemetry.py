"""Telemetry bridge: routing decisions → event streams the miner accepts."""

import numpy as np

from repro.core import EpisodeBatch, count_a1_sequential, mine
from repro.telemetry import decode_expert_episode, routing_events


def test_routing_events_roundtrip():
    l, t, k, e = 2, 16, 2, 8
    rng = np.random.default_rng(0)
    topk = rng.integers(0, e, size=(l, t, k)).astype(np.int32)
    stream = routing_events(topk, e)
    assert len(stream) == l * t * k
    assert stream.num_types == l * e
    # decode a type back
    layer, expert = decode_expert_episode(int(stream.types[0]), e)
    assert 0 <= layer < l and 0 <= expert < e


def test_planted_routing_cascade_is_mined():
    """A deterministic cascade (expert 1 at layer 0 → expert 5 at layer 1,
    next token) must dominate the mined 2-episodes."""
    l, t, k, e = 2, 200, 1, 8
    rng = np.random.default_rng(1)
    topk = rng.integers(0, e, size=(l, t, k)).astype(np.int32)
    topk[0, ::4, 0] = 1   # layer0 expert1 at tokens 0,4,8...
    topk[1, 1::4, 0] = 5  # layer1 expert5 one token later
    stream = routing_events(topk, e)
    res = mine(stream, intervals=[(0, 2)], theta=int(t / 4 * 0.8),
               max_level=2)
    found = {tuple(ep) for ep in res.frequent[1].etypes.tolist()}
    want = (0 * e + 1, 1 * e + 5)  # L0e1 -> L1e5
    assert want in found
    # count is exact vs the oracle
    idx = [tuple(ep) for ep in res.frequent[1].etypes.tolist()].index(want)
    lv = res.frequent[1].select([idx])
    assert res.counts[1][idx] == count_a1_sequential(stream, lv)[0]
