"""Fault-tolerance substrate tests: checkpoint atomicity/restart, watchdog
retry, straggler detection, elastic re-mesh planning."""

import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.runtime import (StepFailure, StepWatchdog, WatchdogConfig,
                           plan_elastic_mesh, ElasticRuntime)


@pytest.fixture
def tree():
    return {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
            "opt": [np.ones(3, np.int32), np.zeros((2, 2), np.float32)]}


def test_checkpoint_roundtrip(tmp_path, tree):
    ckpt.save(tmp_path, 7, tree, config_hash="abc")
    out, step = ckpt.restore(tmp_path, tree, config_hash="abc")
    assert step == 7
    np.testing.assert_array_equal(out["w"], tree["w"])
    np.testing.assert_array_equal(out["opt"][1], tree["opt"][1])


def test_restore_skips_torn_checkpoint(tmp_path, tree):
    ckpt.save(tmp_path, 5, tree)
    ckpt.save(tmp_path, 10, tree)
    # simulate a crash mid-write of step 15: manifest missing
    torn = tmp_path / "step_00000015"
    torn.mkdir()
    (torn / "w.p0.npy").write_bytes(b"garbage")
    assert ckpt.latest_step(tmp_path) == 10
    _, step = ckpt.restore(tmp_path, tree)
    assert step == 10


def test_restore_refuses_config_mismatch(tmp_path, tree):
    ckpt.save(tmp_path, 3, tree, config_hash="modelA")
    with pytest.raises(ValueError, match="config hash"):
        ckpt.restore(tmp_path, tree, config_hash="modelB")


def test_atomic_tmp_never_visible(tmp_path, tree):
    ckpt.save(tmp_path, 1, tree)
    leftover = tmp_path / "step_00000002.tmp"
    leftover.mkdir()
    (leftover / "MANIFEST.json").write_text("{}")
    assert ckpt.latest_step(tmp_path) == 1  # .tmp dirs are never counted


def test_watchdog_retries_then_succeeds():
    wd = StepWatchdog(WatchdogConfig(max_retries=3))
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return "ok"

    assert wd.run_step(0, flaky) == "ok"
    assert wd.retries == 2


def test_watchdog_gives_up():
    wd = StepWatchdog(WatchdogConfig(max_retries=2))
    with pytest.raises(StepFailure):
        wd.run_step(0, lambda: (_ for _ in ()).throw(RuntimeError("x")))


def test_watchdog_flags_stragglers():
    clock = {"t": 0.0}

    def fake_clock():
        return clock["t"]

    wd = StepWatchdog(WatchdogConfig(window=50, deadline_factor=2.0,
                                     min_deadline_s=0.5), clock=fake_clock)
    for i in range(20):  # steady 0.1 s steps
        wd.run_step(i, lambda: clock.__setitem__("t", clock["t"] + 0.1))
    wd.run_step(99, lambda: clock.__setitem__("t", clock["t"] + 5.0))
    assert 99 in wd.straggler_steps
    assert all(i not in wd.straggler_steps for i in range(20))


def test_elastic_mesh_plan_shrinks_to_usable_shape():
    shape, axes = plan_elastic_mesh(256, model_parallel=16)
    assert shape == (16, 16) and axes == ("data", "model")
    # lose 3 devices → largest power-of-two data extent with TP intact
    shape, axes = plan_elastic_mesh(253, model_parallel=16)
    assert shape[0] * shape[1] <= 253 and shape[1] == 16
    assert shape[0] & (shape[0] - 1) == 0  # power of two


def test_elastic_runtime_remesh_on_failure():
    live = {"devices": list(range(256))}
    rt = ElasticRuntime(lambda: live["devices"], model_parallel=16)
    changed, _ = rt.maybe_remesh()
    assert not changed
    live["devices"] = list(range(240))  # a host of 16 devices died
    changed, state = rt.maybe_remesh()
    assert changed and state.generation == 1
    assert state.mesh_shape[0][1] == 16  # TP preserved


def test_train_loop_resumes_from_checkpoint(tmp_path):
    """End-to-end: run 6 steps, 'crash', re-launch, verify continuation."""
    import dataclasses
    from repro.configs import get_smoke_config
    from repro.launch.train import train_loop

    cfg = get_smoke_config("gemma3_1b")
    cfg = dataclasses.replace(cfg, num_layers=2, d_model=32, num_heads=2,
                              head_dim=16, d_ff=64, vocab_size=64,
                              window=4, global_every=2)
    kw = dict(batch=2, seq=16, ckpt_dir=str(tmp_path), ckpt_every=3,
              log_every=100)
    train_loop(cfg, steps=6, **kw)
    assert ckpt.latest_step(tmp_path) == 6
    # relaunch for 9 total: must resume at 6, not restart
    _, _, losses = train_loop(cfg, steps=9, **kw)
    assert len(losses) == 3  # only the new steps ran
