"""Pallas kernel validation (interpret=True on CPU) — shape sweeps against
both the layout oracle (kernels/ref.py) and the paper pseudocode oracle."""

import numpy as np
import pytest

from repro.core import EpisodeBatch, count_a1_sequential, count_a2_sequential
from repro.core.count_a1 import count_a1_vectorized
from repro.data import random_stream
from repro.kernels import ops, ref as kref


def _batch(rng, m, n, num_types, relaxed=False):
    et = rng.integers(0, num_types, size=(m, n)).astype(np.int32)
    tlo = rng.integers(0, 5, size=(m, n - 1)).astype(np.int32)
    if relaxed:
        tlo = np.zeros_like(tlo)
    thi = (tlo + rng.integers(1, 10, size=(m, n - 1))).astype(np.int32)
    return EpisodeBatch(et, tlo, thi)


@pytest.mark.parametrize("m", [1, 7, 128, 300])
@pytest.mark.parametrize("n", [2, 3, 5, 8])
def test_a2_kernel_vs_sequential_oracle(m, n):
    rng = np.random.default_rng(n * 100 + m)
    st = random_stream(6, 250, 500, seed=m + n)
    eps = _batch(rng, m, n, 6, relaxed=True)
    want = count_a2_sequential(st, eps)
    got = ops.a2_count(st, eps, force="interpret")
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("m", [1, 64, 200])
@pytest.mark.parametrize("n", [2, 4, 6])
@pytest.mark.parametrize("lcap", [2, 4])
def test_a1_kernel_vs_vectorized_and_oracle(m, n, lcap):
    rng = np.random.default_rng(7 * n + m + lcap)
    st = random_stream(5, 250, 400, seed=m * n)
    eps = _batch(rng, m, n, 5)
    kc, kovf = ops.a1_count(st, eps, lcap=lcap, force="interpret")
    vc, vovf = count_a1_vectorized(st, eps, lcap=lcap)
    np.testing.assert_array_equal(kc, vc)  # kernel == XLA-scan engine
    np.testing.assert_array_equal(kovf, vovf)
    want = count_a1_sequential(st, eps)
    exact = ~kovf
    np.testing.assert_array_equal(kc[exact], want[exact])


def test_a2_kernel_layout_oracle_identity():
    """Kernel == its pure-jnp layout oracle on identical padded inputs."""
    rng = np.random.default_rng(0)
    st = random_stream(4, 150, 300, seed=1)
    eps = _batch(rng, 37, 4, 4, relaxed=True)
    et, tlo, thi = ops.episode_layout(eps, inclusive_lower=True)
    ev = ops.event_layout(st, with_dup=False)
    a = ops.a2_count_kernel(et, tlo, thi, ev, n_levels=4, interpret=True)
    b = kref.a2_count_ref(et, tlo, thi, ev, n_levels=4)
    np.testing.assert_array_equal(np.asarray(a)[0], np.asarray(b))


def test_a1_kernel_layout_oracle_identity():
    rng = np.random.default_rng(1)
    st = random_stream(4, 150, 300, seed=2)
    eps = _batch(rng, 29, 3, 4)
    et, tlo, thi = ops.episode_layout(eps, inclusive_lower=False)
    ev = ops.event_layout(st, with_dup=True)
    ac, ao = ops.a1_count_kernel(et, tlo, thi, ev, n_levels=3, lcap=4,
                                 interpret=True)
    bc, bo = kref.a1_count_ref(et, tlo, thi, ev, n_levels=3, lcap=4)
    np.testing.assert_array_equal(np.asarray(ac)[0], np.asarray(bc))
    np.testing.assert_array_equal(np.asarray(ao)[0].astype(bool),
                                  np.asarray(bo))


def test_kernel_dispatch_declines_on_cpu(monkeypatch):
    monkeypatch.delenv("REPRO_INTERPRET_KERNELS", raising=False)
    monkeypatch.delenv("REPRO_KERNEL_INTERPRET", raising=False)
    rng = np.random.default_rng(3)
    st = random_stream(4, 50, 100, seed=3)
    eps = _batch(rng, 8, 3, 4)
    with pytest.raises(NotImplementedError):
        ops.a2_count(st, eps.relaxed())


@pytest.mark.parametrize("n_events", [1, 127, 128, 129])
def test_event_padding_boundaries(n_events):
    rng = np.random.default_rng(n_events)
    st = random_stream(4, n_events, 300, seed=n_events)
    eps = _batch(rng, 16, 3, 4, relaxed=True)
    want = count_a2_sequential(st, eps)
    got = ops.a2_count(st, eps, force="interpret")
    np.testing.assert_array_equal(got, want)
