"""Window-based (WINEPI) baseline + connectivity reconstruction tests."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # keep the non-property tests collectable
    HAVE_HYPOTHESIS = False

from repro.core import EpisodeBatch, EventStream, mine
from repro.core.connectivity import reconstruct
from repro.core.windows import (count_windows, count_windows_bruteforce,
                                frequency_windows)
from repro.data import embedded_chain_stream


def test_windows_simple():
    # A@1 B@3 A@10 B@11 — episode A→B, window 5
    st_ = EventStream(np.int32([0, 1, 0, 1]), np.int32([1, 3, 10, 11]), 2)
    ep = EpisodeBatch.single([0, 1], [0], [100])
    got = count_windows(st_, ep, window=5)
    want = count_windows_bruteforce(st_, ep, window=5)
    np.testing.assert_array_equal(got, want)
    assert got[0] > 0


def _check_windows_equals_bruteforce(seed, n, window):
    rng = np.random.default_rng(seed)
    k = rng.integers(5, 40)
    times = np.cumsum(rng.integers(0, 4, size=k)).astype(np.int32) + 1
    types = rng.integers(0, 3, size=k).astype(np.int32)
    stream = EventStream(types, times, 3)
    et = rng.integers(0, 3, size=(4, n)).astype(np.int32)
    eps = EpisodeBatch(et, np.zeros((4, n - 1), np.int32),
                       np.full((4, n - 1), 5, np.int32))
    got = count_windows(stream, eps, window)
    want = count_windows_bruteforce(stream, eps, window)
    np.testing.assert_array_equal(got, want)


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(st.integers(0, 10_000), st.integers(2, 3), st.integers(2, 12))
    def test_windows_equals_bruteforce(seed, n, window):
        _check_windows_equals_bruteforce(seed, n, window)
else:  # deterministic sweep over the same seed-driven strategy
    @pytest.mark.parametrize("seed", [0, 7, 123, 4567, 9999])
    @pytest.mark.parametrize("n", [2, 3])
    @pytest.mark.parametrize("window", [2, 5, 12])
    def test_windows_equals_bruteforce(seed, n, window):
        _check_windows_equals_bruteforce(seed, n, window)


def test_window_frequency_monotone_in_window():
    stream = embedded_chain_stream(6, [0, 1, 2], (2, 6), 40, 500, 30_000,
                                   seed=2)
    ep = EpisodeBatch.single([0, 1, 2], [0, 0], [6, 6])
    f1 = frequency_windows(stream, ep, window=10)
    f2 = frequency_windows(stream, ep, window=40)
    assert 0 <= f1[0] <= f2[0] <= 1.0  # larger windows catch more


def test_connectivity_recovers_planted_edges():
    chain, interval = [1, 3, 5], (2, 8)
    stream = embedded_chain_stream(8, chain, interval, num_occurrences=80,
                                   noise_events=1200, t_max=90_000, seed=4)
    res = mine(stream, intervals=[interval], theta=40, max_level=3)
    g = reconstruct(stream, res)
    top = {(a, b) for a, b, w, c in g.top_edges(4)}
    assert (1, 3) in top and (3, 5) in top
    # planted edges must outrank any noise edge
    w_planted = min(g.weights[1, 3], g.weights[3, 5])
    noise = g.weights.copy()
    noise[1, 3] = noise[3, 5] = -np.inf
    assert w_planted > noise.max()
