"""Property-based tests (hypothesis) for the mining engine's invariants."""

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis; deterministic coverage of the "
           "same invariants lives in test_core_counting/test_streaming")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (EpisodeBatch, EventStream,  # noqa: E402
                        count_a1, count_a2,
                        count_a1_sequential, count_a2_sequential,
                        count_single_slot, mapconcatenate)


@st.composite
def stream_and_episode(draw, max_events=120, num_types=4, max_n=4):
    n_ev = draw(st.integers(4, max_events))
    gaps = draw(st.lists(st.integers(0, 6), min_size=n_ev, max_size=n_ev))
    times = np.cumsum(np.array(gaps, np.int64)).astype(np.int32) + 1
    types = np.array(
        draw(st.lists(st.integers(0, num_types - 1), min_size=n_ev,
                      max_size=n_ev)), np.int32)
    stream = EventStream(types, times, num_types)
    n = draw(st.integers(2, max_n))
    et = np.array(draw(st.lists(st.integers(0, num_types - 1), min_size=n,
                                max_size=n)), np.int32)
    tlo = np.array(draw(st.lists(st.integers(0, 5), min_size=n - 1,
                                 max_size=n - 1)), np.int32)
    width = np.array(draw(st.lists(st.integers(1, 8), min_size=n - 1,
                                   max_size=n - 1)), np.int32)
    eps = EpisodeBatch(et[None], tlo[None], (tlo + width)[None])
    return stream, eps


@settings(max_examples=150, deadline=None)
@given(stream_and_episode())
def test_vectorized_a1_equals_oracle(se):
    stream, eps = se
    want = count_a1_sequential(stream, eps)
    got = count_a1(stream, eps, use_kernel=False)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=150, deadline=None)
@given(stream_and_episode())
def test_vectorized_a2_equals_oracle(se):
    stream, eps = se
    want = count_a2_sequential(stream, eps.relaxed())
    got = count_single_slot(stream, eps.relaxed(), inclusive_lower=True)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=150, deadline=None)
@given(stream_and_episode())
def test_theorem_5_1_unconditional(se):
    """count(A2, α') >= count(A1, α) — with the inclusive-lower
    strengthening this must hold on EVERY stream, ties included."""
    stream, eps = se
    a1 = count_a1_sequential(stream, eps)
    a2 = count_a2(stream, eps, use_kernel=False)
    assert (a2 >= a1).all()


@settings(max_examples=60, deadline=None)
@given(stream_and_episode(max_events=200), st.integers(1, 3))
def test_mapconcatenate_segment_invariance(se, log_p):
    """Counts are invariant to the number of segments (and equal to the
    single-machine oracle) — the MapConcatenate correctness property."""
    stream, eps = se
    want = count_a1_sequential(stream, eps)
    got = mapconcatenate(stream, eps, num_segments=2 ** log_p)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=80, deadline=None)
@given(stream_and_episode(), st.integers(0, 50))
def test_count_monotone_in_prefix(se, cut):
    """Counting is monotone under stream extension: a prefix of the stream
    never yields MORE occurrences (non-overlap counts only complete)."""
    stream, eps = se
    k = max(2, len(stream.types) - cut)
    prefix = EventStream(stream.types[:k], stream.times[:k],
                         stream.num_types)
    a = count_a1_sequential(prefix, eps)
    b = count_a1_sequential(stream, eps)
    assert (b >= a).all()
